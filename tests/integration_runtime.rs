//! Integration tests over the runtime layer: artifact loading, manifest
//! contracts, state round-trips, and cross-language consistency between
//! the Rust analytic model spec and the Python-emitted manifest.
//!
//! These require `make artifacts` to have run; they are skipped (with a
//! loud message) when the artifacts directory is absent.

use adasplit::model::ModelSpec;
use adasplit::runtime::{Runtime, Tensor};

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load("artifacts").expect("runtime loads"))
}

#[test]
fn manifest_param_counts_match_rust_spec() {
    let Some(rt) = runtime() else { return };
    // the Rust-side FLOP model must agree exactly with the Python model
    for (tag, meta) in &rt.manifest.configs {
        let spec = ModelSpec::from_manifest(&rt.manifest, meta.num_classes);
        assert_eq!(spec.client_params(meta.k), meta.client_params, "{tag} client");
        assert_eq!(spec.server_params(meta.k), meta.server_params, "{tag} server");
        assert_eq!(spec.full_params(), meta.full_params, "{tag} full");
        assert_eq!(spec.proj_params(meta.k), meta.proj_params, "{tag} proj");
        assert_eq!(
            spec.act_elems(meta.k) * rt.manifest.batch,
            meta.act_shape.iter().product::<usize>(),
            "{tag} act"
        );
    }
}

#[test]
fn init_artifact_is_deterministic_and_seed_sensitive() {
    let Some(rt) = runtime() else { return };
    let art = rt.artifact("c10_mu1_init_client").unwrap();
    let a = art.call(&[], &[("seed", &Tensor::scalar(5.0))]).unwrap().into_state();
    let b = art.call(&[], &[("seed", &Tensor::scalar(5.0))]).unwrap().into_state();
    let c = art.call(&[], &[("seed", &Tensor::scalar(6.0))]).unwrap().into_state();
    assert_eq!(a.checksum(), b.checksum());
    assert_ne!(a.checksum(), c.checksum());
    // Adam moments start at zero, step at zero
    assert_eq!(a.get("state.t").unwrap().item(), 0.0);
    assert_eq!(a.get("state.mc.conv1.w").unwrap().mean_abs(), 0.0);
}

#[test]
fn client_step_round_trips_state_and_learns() {
    let Some(rt) = runtime() else { return };
    let init = rt.artifact("c10_mu1_init_client").unwrap();
    let step = rt.artifact("c10_mu1_client_step").unwrap();
    let mut state = init.call(&[], &[("seed", &Tensor::scalar(1.0))]).unwrap().into_state();

    // deterministic but non-degenerate inputs (constant images make the
    // NT-Xent similarity matrix uniform and the gradient vanish)
    let mut rng = adasplit::data::Rng::new(7);
    let xv: Vec<f32> = (0..32 * 32 * 32 * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let x = Tensor::new(vec![32, 32, 32, 3], xv).unwrap();
    let mut yv = vec![0.0f32; 32];
    for (i, y) in yv.iter_mut().enumerate() {
        *y = (i % 2) as f32;
    }
    let y = Tensor::new(vec![32], yv).unwrap();
    let ga = Tensor::zeros(&[32, 16, 16, 16]);
    let zero = Tensor::scalar(0.0);

    let names_before: Vec<String> = state.names().cloned().collect();
    let mut losses = Vec::new();
    for i in 0..5 {
        let mut out = step
            .call(
                &[&state],
                &[("x", &x), ("y", &y), ("beta", &zero), ("grad_a", &ga),
                  ("use_grad", &zero)],
            )
            .unwrap();
        out.write_state(&mut state);
        let loss = out.scalar("loss").unwrap();
        assert!(loss.is_finite(), "loss finite at step {i}");
        losses.push(loss);
    }
    // same keys after write-back (manifest round-trip guarantee)
    let names_after: Vec<String> = state.names().cloned().collect();
    assert_eq!(names_before, names_after);
    // step counter advanced, loss trending down on a fixed batch
    assert_eq!(state.get("state.t").unwrap().item(), 5.0);
    assert!(losses[4] < losses[0], "{losses:?}");
    assert!(!state.has_non_finite());
}

#[test]
fn artifact_rejects_bad_shapes_and_unresolved_inputs() {
    let Some(rt) = runtime() else { return };
    let step = rt.artifact("c10_mu1_client_fwd").unwrap();
    // missing input
    assert!(step.call(&[], &[]).is_err());
    // wrong shape
    let init = rt.artifact("c10_mu1_init_client").unwrap();
    let state = init.call(&[], &[("seed", &Tensor::scalar(1.0))]).unwrap().into_state();
    let root = state.sub("state");
    let bad_x = Tensor::zeros(&[32, 16, 16, 3]);
    assert!(step.call(&[&root], &[("x", &bad_x)]).is_err());
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(rt) = runtime() else { return };
    assert!(rt.artifact("does_not_exist").is_err());
}

#[test]
fn artifact_cache_compiles_once() {
    let Some(rt) = runtime() else { return };
    let before = rt.compiled_count();
    let _a = rt.artifact("c10_mu1_client_fwd").unwrap();
    let _b = rt.artifact("c10_mu1_client_fwd").unwrap();
    assert_eq!(rt.compiled_count(), before + 1);
}

#[test]
fn sl_grad_roundtrip_shapes() {
    let Some(rt) = runtime() else { return };
    let init_c = rt.artifact("c10_mu1_init_sl_client").unwrap();
    let init_s = rt.artifact("c10_mu1_init_sl_server").unwrap();
    let fwd = rt.artifact("c10_mu1_client_fwd").unwrap();
    let sstep = rt.artifact("c10_mu1_sl_server_step").unwrap();
    let cbwd = rt.artifact("c10_mu1_client_bwd").unwrap();

    let mut cstate = init_c.call(&[], &[("seed", &Tensor::scalar(1.0))]).unwrap().into_state();
    let mut sstate = init_s.call(&[], &[("seed", &Tensor::scalar(2.0))]).unwrap().into_state();

    let x = Tensor::full(&[32, 32, 32, 3], 0.05);
    let y = Tensor::zeros(&[32]);
    let acts = fwd
        .call(&[&cstate.sub("state")], &[("x", &x)])
        .unwrap()
        .take("acts")
        .unwrap();
    assert_eq!(acts.shape(), &[32, 16, 16, 16]);

    let mut out = sstep.call(&[&sstate], &[("a", &acts), ("y", &y)]).unwrap();
    out.write_state(&mut sstate);
    let grad_a = out.take("grad_a").unwrap();
    assert_eq!(grad_a.shape(), acts.shape());
    assert!(grad_a.mean_abs() > 0.0, "gradient must be nonzero");

    let before = cstate.checksum();
    let mut cb = cbwd.call(&[&cstate], &[("x", &x), ("grad_a", &grad_a)]).unwrap();
    cb.write_state(&mut cstate);
    assert_ne!(before, cstate.checksum(), "client params must move");
}

#[test]
fn server_eval_counts_bounded_by_valid() {
    let Some(rt) = runtime() else { return };
    let init_s = rt.artifact("c10_mu1_init_server").unwrap();
    let eval = rt.artifact("c10_mu1_server_eval").unwrap();
    let sstate = init_s.call(&[], &[("seed", &Tensor::scalar(3.0))]).unwrap().into_state();
    let root = sstate.sub("state");

    let a = Tensor::full(&[32, 16, 16, 16], 0.1);
    let y = Tensor::zeros(&[32]);
    let mut vv = vec![0.0f32; 32];
    for v in vv.iter_mut().take(7) {
        *v = 1.0;
    }
    let valid = Tensor::new(vec![32], vv).unwrap();
    let out = eval
        .call(&[&root], &[("a", &a), ("y", &y), ("valid", &valid)])
        .unwrap();
    let correct = out.scalar("correct").unwrap();
    assert!((0.0..=7.0).contains(&correct), "correct={correct} must respect valid mask");
}
