//! Property-based tests over the coordinator's pure invariants, driven by
//! the in-tree deterministic RNG (the registry `proptest` crate is not
//! available in this offline environment; the same shrink-free randomized
//! strategy is used: many seeded cases per property, with the failing seed
//! printed by the assertion message).

use adasplit::data::partition::imbalanced_sizes;
use adasplit::data::{build_partition, DatasetKind, Rng};
use adasplit::driver::{AsyncBounded, ClientSpeeds, Scheduler, SpeedPreset};
use adasplit::metrics::{c3_score, mean_std, Budgets};
use adasplit::model::ModelSpec;
use adasplit::orchestrator::UcbOrchestrator;
use adasplit::runtime::{Tensor, TensorStore};
use adasplit::util::Json;

const CASES: u64 = 200;

#[test]
fn prop_c3_monotone_and_bounded() {
    let mut r = Rng::new(11);
    for case in 0..CASES {
        let b = Budgets::new(r.uniform(0.1, 100.0), r.uniform(0.1, 100.0));
        let acc = r.uniform(0.0, 100.0);
        let bw = r.uniform(0.0, 200.0);
        let c = r.uniform(0.0, 200.0);
        let s = c3_score(acc, bw, c, &b);
        assert!((0.0..=1.0).contains(&s), "case {case}: s={s}");
        // more accuracy never hurts; more cost never helps
        assert!(c3_score(acc + 1.0, bw, c, &b) >= s, "case {case}");
        assert!(c3_score(acc, bw + 1.0, c, &b) <= s, "case {case}");
        assert!(c3_score(acc, bw, c + 1.0, &b) <= s, "case {case}");
    }
}

#[test]
fn prop_ucb_selection_size_and_membership() {
    let mut r = Rng::new(22);
    for case in 0..CASES {
        let n = 1 + r.below(12);
        let mut ucb = UcbOrchestrator::new(n, r.uniform(0.5, 1.0));
        for _ in 0..r.below(30) {
            let k = 1 + r.below(n);
            let sel = ucb.select(k);
            assert_eq!(sel.len(), k.min(n), "case {case}");
            assert!(sel.iter().all(|&i| i < n), "case {case}");
            // sorted unique
            assert!(sel.windows(2).all(|w| w[0] < w[1]), "case {case}");
            let obs: Vec<(usize, f64)> =
                sel.iter().map(|&i| (i, r.uniform(0.0, 10.0))).collect();
            ucb.update(&obs);
        }
    }
}

#[test]
fn prop_ucb_prefers_higher_loss_clients_eventually() {
    let mut r = Rng::new(33);
    for case in 0..50 {
        let n = 3 + r.below(5);
        let hot = r.below(n);
        let mut ucb = UcbOrchestrator::new(n, 0.9);
        for _ in 0..100 {
            let sel = ucb.select(n); // observe everyone
            let obs: Vec<(usize, f64)> = sel
                .iter()
                .map(|&i| (i, if i == hot { 8.0 } else { 0.5 }))
                .collect();
            ucb.update(&obs);
        }
        let top = ucb.select(1);
        assert_eq!(top, vec![hot], "case {case}: hot client must rank first");
    }
}

#[test]
fn prop_imbalanced_sizes_sum_and_positivity() {
    let mut r = Rng::new(44);
    for case in 0..CASES {
        let n = 1 + r.below(10);
        let base = 64 + r.below(512);
        let imb = r.uniform(1.0, 3.0);
        let sizes = imbalanced_sizes(n, base, imb);
        assert_eq!(sizes.len(), n, "case {case}");
        assert!(sizes.iter().all(|&s| s >= 32), "case {case}: {sizes:?}");
        let total: usize = sizes.iter().sum();
        let expect = n * base;
        assert!(
            (total as f64 - expect as f64).abs() / expect as f64 <= 0.30,
            "case {case}: total {total} vs {expect}"
        );
        // monotone when imbalance > 1
        if imb > 1.01 {
            assert!(sizes.windows(2).all(|w| w[0] <= w[1]), "case {case}");
        }
    }
}

#[test]
fn prop_weighted_sum_is_convex_combination() {
    let mut r = Rng::new(55);
    for case in 0..CASES {
        let len = 1 + r.below(100);
        let k = 1 + r.below(5);
        let stores: Vec<TensorStore> = (0..k)
            .map(|_| {
                let mut s = TensorStore::new();
                let data: Vec<f32> = (0..len).map(|_| r.normal_f32(0.0, 2.0)).collect();
                s.insert("state.p.w", Tensor::new(vec![len], data).unwrap());
                s
            })
            .collect();
        let mut w: Vec<f32> = (0..k).map(|_| r.next_f32() + 0.01).collect();
        let total: f32 = w.iter().sum();
        w.iter_mut().for_each(|x| *x /= total);

        let refs: Vec<&TensorStore> = stores.iter().collect();
        let mut dst = stores[0].clone();
        dst.set_weighted_sum(&refs, &w, |key| key.starts_with("state.p")).unwrap();
        let avg = dst.get("state.p.w").unwrap();
        for i in 0..len {
            let vals: Vec<f32> = stores
                .iter()
                .map(|s| s.get("state.p.w").unwrap().data()[i])
                .collect();
            let lo = vals.iter().cloned().fold(f32::MAX, f32::min);
            let hi = vals.iter().cloned().fold(f32::MIN, f32::max);
            let v = avg.data()[i];
            assert!(
                v >= lo - 1e-4 && v <= hi + 1e-4,
                "case {case}: {v} outside [{lo}, {hi}]"
            );
        }
    }
}

#[test]
fn prop_partition_labels_in_client_class_set() {
    let mut r = Rng::new(66);
    for case in 0..20 {
        let kind = if r.next_f64() < 0.5 {
            DatasetKind::MixedCifar
        } else {
            DatasetKind::MixedNonIid
        };
        let n = 1 + r.below(7);
        let parts = build_partition(kind, n, 64, 32, r.uniform(1.0, 2.0), case).unwrap();
        for i in 0..n {
            let c = parts.get(i);
            for &y in c.train_y.iter().chain(c.test_y.iter()) {
                assert!(
                    c.classes.contains(&(y as usize)),
                    "case {case}: label {y} outside {:?}",
                    c.classes
                );
                assert!((y as usize) < kind.num_classes(), "case {case}");
            }
        }
    }
}

#[test]
fn prop_flop_model_additivity() {
    let mut r = Rng::new(77);
    for _ in 0..CASES {
        let nc = 2 + r.below(100);
        let spec = ModelSpec::default_for(nc);
        for k in 1..=4 {
            let total = spec.client_fwd_flops(k) + spec.server_fwd_flops(k);
            assert!((total - spec.full_fwd_flops()).abs() < 1e-6);
            assert_eq!(spec.client_params(k) + spec.server_params(k), spec.full_params());
        }
    }
}

#[test]
fn prop_json_roundtrip_random_trees() {
    let mut r = Rng::new(88);
    fn gen(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.next_f64() < 0.5),
            2 => Json::Num((r.normal() * 100.0).round() / 4.0),
            3 => Json::Str(format!("k{}", r.below(1000))),
            4 => Json::Arr((0..r.below(5)).map(|_| gen(r, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..r.below(5) {
                    m.insert(format!("f{i}"), gen(r, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    for case in 0..CASES {
        let j = gen(&mut r, 3);
        let pretty = Json::parse(&j.to_string_pretty());
        let compact = Json::parse(&j.to_string_compact());
        assert_eq!(pretty.unwrap(), j, "case {case} pretty");
        assert_eq!(compact.unwrap(), j, "case {case} compact");
    }
}

fn random_preset(r: &mut Rng) -> SpeedPreset {
    match r.below(3) {
        0 => SpeedPreset::Uniform,
        1 => SpeedPreset::Lognormal { sigma: r.uniform(0.1, 1.5) },
        _ => SpeedPreset::Stragglers,
    }
}

#[test]
fn prop_async_sim_clock_monotone_and_staleness_bounded() {
    // over random fleets, bounds, caps, and speed presets: the simulated
    // round wall-clock never decreases, and no merged contribution is
    // ever staler than the bound
    let mut r = Rng::new(111);
    for case in 0..60 {
        let n = 1 + r.below(40);
        let bound = r.below(6);
        let participation = r.uniform(0.01, 1.0);
        let preset = random_preset(&mut r);
        let frac = r.uniform(0.0, 1.0);
        let speeds = ClientSpeeds::new(n, preset, frac, case);
        let mut s = AsyncBounded::new(n, bound, participation, &speeds);
        let mut prev_t = 0.0f64;
        for round in 0..50 {
            let plan = s.plan(round);
            assert!(
                plan.sim_time >= prev_t,
                "case {case} round {round}: clock {} < {prev_t}",
                plan.sim_time
            );
            prev_t = plan.sim_time;
            assert!(plan.sim_time.is_finite(), "case {case}");
            for (&i, &st) in plan.participants.iter().zip(&plan.staleness) {
                assert!(i < n, "case {case}");
                assert!(
                    st <= bound,
                    "case {case} round {round}: client {i} stale {st} > bound {bound}"
                );
            }
        }
    }
}

#[test]
fn prop_async_merge_set_never_empty() {
    // participation x straggler-frac must never starve a round: even a
    // 100%-straggler fleet at the minimum cap merges someone every round
    // (the driver waits for the fastest in-flight client)
    let mut r = Rng::new(222);
    for case in 0..60 {
        let n = 1 + r.below(30);
        let bound = r.below(8);
        // adversarial corners included: tiny participation, frac up to 1.0
        let participation = if r.next_f64() < 0.3 { 0.001 } else { r.uniform(0.01, 1.0) };
        let frac = if r.next_f64() < 0.3 { 1.0 } else { r.uniform(0.0, 1.0) };
        let speeds = ClientSpeeds::new(n, SpeedPreset::Stragglers, frac, case + 1000);
        let mut s = AsyncBounded::new(n, bound, participation, &speeds);
        for round in 0..40 {
            let plan = s.plan(round);
            assert!(
                !plan.participants.is_empty(),
                "case {case} (n={n} p={participation} frac={frac} s={bound}) \
                 round {round}: empty merge set"
            );
            assert!(
                plan.participants.windows(2).all(|w| w[0] < w[1]),
                "case {case} round {round}: participants not ascending-unique"
            );
            assert_eq!(plan.participants.len(), plan.staleness.len(), "case {case}");
        }
    }
}

#[test]
fn prop_lazy_partition_get_is_order_independent() {
    // shards are pure functions of (kind, id, seed): materialization
    // order can never change values
    let mut r = Rng::new(333);
    for case in 0..8 {
        let kind = if r.next_f64() < 0.5 {
            DatasetKind::MixedCifar
        } else {
            DatasetKind::MixedNonIid
        };
        let n = 2 + r.below(6);
        let a = build_partition(kind, n, 64, 32, 1.0, case).unwrap();
        let b = build_partition(kind, n, 64, 32, 1.0, case).unwrap();
        // touch a forward, b in a random order
        let order = r.permutation(n);
        let from_b: Vec<_> = order.iter().map(|&i| (i, b.get(i))).collect();
        for (i, shard_b) in from_b {
            let shard_a = a.get(i);
            assert_eq!(shard_a.train_x, shard_b.train_x, "case {case} client {i}");
            assert_eq!(shard_a.train_y, shard_b.train_y, "case {case} client {i}");
            assert_eq!(shard_a.test_x, shard_b.test_x, "case {case} client {i}");
        }
    }
}

#[test]
fn prop_mean_std_bounds() {
    let mut r = Rng::new(99);
    for case in 0..CASES {
        let n = 1 + r.below(50);
        let xs: Vec<f64> = (0..n).map(|_| r.uniform(-10.0, 10.0)).collect();
        let (m, s) = mean_std(&xs);
        let lo = xs.iter().cloned().fold(f64::MAX, f64::min);
        let hi = xs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(m >= lo - 1e-12 && m <= hi + 1e-12, "case {case}");
        assert!(s >= 0.0 && s <= (hi - lo) + 1e-12, "case {case}");
    }
}
