//! Tier-1 determinism lint (DESIGN.md §13).
//!
//! Two jobs:
//! 1. `tree_is_clean` runs `detlint` over the real `rust/src/` tree and
//!    fails with file:line diagnostics if any determinism invariant is
//!    violated — this is the enforcement point that makes D01–D05 part
//!    of `cargo test -q`.
//! 2. The `fixture_*` tests pin the linter itself: one deliberately-bad
//!    snippet per rule under `tests/detlint_fixtures/` must produce
//!    exactly the expected (rule, path, line), and the clean fixture —
//!    which exercises every sanctioned escape hatch — must produce
//!    nothing. Cargo does not compile files in test *subdirectories*,
//!    so the fixtures are data, not code.

use std::path::Path;

use adasplit::detlint::{lint_source, lint_tree, report, source_files, Rule};

fn src_root() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src"))
}

fn fixture(name: &str) -> String {
    let path = format!(
        concat!(env!("CARGO_MANIFEST_DIR"), "/tests/detlint_fixtures/{}"),
        name
    );
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("fixture {path}: {e}"))
}

#[test]
fn tree_is_clean() {
    let findings = lint_tree(src_root()).expect("lint_tree walks rust/src");
    assert!(
        findings.is_empty(),
        "determinism lint: {} finding(s). Fix the code, or — only with a real \
         order-independence argument — annotate the line with \
         `detlint: allow(<rule>, <reason>)`:\n{}",
        findings.len(),
        report(&findings)
    );
}

#[test]
fn tree_walk_sees_the_whole_crate() {
    // Guards against the walker silently skipping directories and the
    // clean-tree test passing vacuously.
    let files = source_files(src_root()).expect("walk rust/src");
    assert!(files.len() >= 40, "expected the full crate, walked only {} files", files.len());
    for needle in ["engine/mod.rs", "engine/sync.rs", "detlint/rules.rs", "driver/store.rs"] {
        assert!(
            files.iter().any(|f| f.to_string_lossy().replace('\\', "/").ends_with(needle)),
            "tree walk missed {needle}"
        );
    }
}

/// Assert `src` (linted as `path`) yields exactly `expected` as
/// (rule, line) pairs, every finding carrying `path` back verbatim.
fn assert_findings(path: &str, src: &str, expected: &[(Rule, usize)]) {
    let findings = lint_source(path, src);
    let got: Vec<(Rule, usize)> = findings.iter().map(|f| (f.rule, f.line)).collect();
    assert_eq!(
        got,
        expected,
        "lint of {path} produced:\n{}",
        report(&findings)
    );
    for f in &findings {
        assert_eq!(f.path, path);
        assert!(!f.msg.is_empty(), "finding without a message: {f}");
    }
}

#[test]
fn fixture_d01_hashmap_iteration_trips() {
    assert_findings(
        "rust/src/protocols/fixture.rs",
        &fixture("d01_hashmap_iter.rs"),
        &[(Rule::D01, 9)],
    );
}

#[test]
fn fixture_d02_wall_clock_trips_in_scoped_dirs_only() {
    let src = fixture("d02_wall_clock.rs");
    for scoped in ["rust/src/sim/fixture.rs", "rust/src/driver/fixture.rs", "rust/src/engine/fixture.rs"] {
        assert_findings(scoped, &src, &[(Rule::D02, 6)]);
    }
    // Wall clocks are fine outside the deterministic core (logging etc.).
    assert_findings("rust/src/util/fixture.rs", &src, &[]);
}

#[test]
fn fixture_d03_entropy_trips_everywhere_even_in_tests() {
    assert_findings("rust/src/util/fixture.rs", &fixture("d03_entropy.rs"), &[(Rule::D03, 8)]);
}

#[test]
fn fixture_d04_undocumented_unsafe_trips() {
    assert_findings(
        "rust/src/runtime/fixture.rs",
        &fixture("d04_undocumented_unsafe.rs"),
        &[(Rule::D04, 5)],
    );
}

#[test]
fn fixture_d05_float_sum_trips_in_merge_paths_only() {
    let src = fixture("d05_float_sum.rs");
    assert_findings("rust/src/engine/fixture.rs", &src, &[(Rule::D05, 6)]);
    assert_findings("rust/src/driver/fixture.rs", &src, &[(Rule::D05, 6)]);
    // Float sums outside engine/driver merge paths are metrics-grade.
    assert_findings("rust/src/metrics/fixture.rs", &src, &[]);
}

#[test]
fn fixture_d00_bad_allow_is_a_finding_and_suppresses_nothing() {
    assert_findings(
        "rust/src/util/fixture.rs",
        &fixture("d00_bad_allow.rs"),
        &[(Rule::D00, 6), (Rule::D03, 7)],
    );
}

#[test]
fn fixture_clean_all_escape_hatches_hold() {
    // Linted under driver/ — the *strictest* scope (D01+D02+D05 armed) —
    // the clean fixture's BTree iteration, justified allow, SAFETY
    // comment, min/max fold, integer-annotated sum, and cfg(test)-scoped
    // wall clock + map iteration must all pass.
    assert_findings("rust/src/driver/fixture.rs", &fixture("clean.rs"), &[]);
}

#[test]
fn every_rule_has_a_tripping_fixture() {
    // Structural completeness check: extending the Rule enum without a
    // fixture fails here, not in review.
    let covered = [
        (Rule::D00, "d00_bad_allow.rs"),
        (Rule::D01, "d01_hashmap_iter.rs"),
        (Rule::D02, "d02_wall_clock.rs"),
        (Rule::D03, "d03_entropy.rs"),
        (Rule::D04, "d04_undocumented_unsafe.rs"),
        (Rule::D05, "d05_float_sum.rs"),
    ];
    for (rule, file) in covered {
        // Scoped path arms every directory-gated rule.
        let findings = lint_source("rust/src/driver/fixture.rs", &fixture(file));
        assert!(
            findings.iter().any(|f| f.rule == rule),
            "{file} no longer trips {rule}:\n{}",
            report(&findings)
        );
    }
}
