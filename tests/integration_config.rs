//! Config / packaging integration: every shipped config parses and
//! validates, and runtime failure modes produce actionable errors.

use adasplit::config::{ExperimentConfig, ProtocolKind};
use adasplit::runtime::{Manifest, Runtime};

#[test]
fn shipped_configs_parse_and_validate() {
    for entry in std::fs::read_dir("configs").expect("configs dir") {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e == "toml").unwrap_or(false) {
            let cfg = ExperimentConfig::load_toml(&path)
                .unwrap_or_else(|e| panic!("{path:?}: {e}"));
            cfg.validate().unwrap();
        }
    }
}

#[test]
fn table_configs_carry_paper_budgets() {
    let c1 = ExperimentConfig::load_toml("configs/table1_noniid.toml").unwrap();
    assert_eq!(c1.protocol, ProtocolKind::AdaSplit);
    assert!((c1.budgets.bandwidth_gb - 84.64).abs() < 1e-9);
    assert!((c1.lambda - 1e-3).abs() < 1e-9);
    let c2 = ExperimentConfig::load_toml("configs/table2_cifar.toml").unwrap();
    assert!((c2.budgets.client_tflops - 11.77).abs() < 1e-9);
    assert!((c2.lambda - 1e-5).abs() < 1e-9);
}

#[test]
fn missing_artifacts_dir_is_actionable() {
    let Err(err) = Runtime::load("/nonexistent/artifacts") else {
        panic!("expected an error");
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("adasplit_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_artifact_files_all_exist() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let m = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    assert!(m.artifacts.len() >= 40, "expected the full artifact set");
    for (name, spec) in &m.artifacts {
        let p = std::path::Path::new("artifacts").join(&spec.file);
        assert!(p.exists(), "{name}: missing {p:?}");
    }
    // the five split configs the experiments need
    for tag in ["c10_mu1", "c10_mu2", "c10_mu3", "c10_mu4", "c50_mu1"] {
        assert!(m.configs.contains_key(tag), "missing config {tag}");
    }
}
