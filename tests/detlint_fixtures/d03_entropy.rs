// detlint fixture: D03 must fire on the ambient-entropy call below, in
// any directory, even inside #[cfg(test)] — pinned by
// tests/determinism_lint.rs.

#[cfg(test)]
mod tests {
    pub fn roll() -> u64 {
        rand::thread_rng().gen()
    }
}
