// detlint fixture: D01 must fire on the map iteration below — and
// nowhere else. The expected (rule, line) pair is pinned by
// tests/determinism_lint.rs.

use std::collections::HashMap;

pub fn total(map: &HashMap<u32, u32>) -> u32 {
    let mut t = 0;
    for (_, v) in map.iter() {
        t += v;
    }
    t
}
