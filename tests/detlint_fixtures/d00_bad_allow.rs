// detlint fixture: a reason-less allow directive is itself a finding
// (D00) and suppresses nothing — the D03 below must also fire. Pinned
// by tests/determinism_lint.rs.

pub fn roll() -> u64 {
    // detlint: allow(D03)
    rand::thread_rng().gen()
}
