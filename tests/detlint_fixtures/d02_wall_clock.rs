// detlint fixture: D02 must fire on the wall-clock read below when the
// file is linted under a sim/, driver/ or engine/ virtual path — and
// stay silent elsewhere. Pinned by tests/determinism_lint.rs.

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
