// detlint fixture: D05 must fire on the unordered float sum below when
// linted under an engine/ or driver/ virtual path — and stay silent
// elsewhere. Pinned by tests/determinism_lint.rs.

pub fn merge(xs: &[f32]) -> f32 {
    xs.iter().sum()
}
