// detlint fixture: D04 must fire on the undocumented unsafe block
// below — pinned by tests/determinism_lint.rs.

pub fn first(p: *const u8) -> u8 {
    unsafe { *p }
}
