// detlint fixture: the clean case. Every rule's sanctioned escape is
// exercised here — BTree iteration, a justified allow, a SAFETY
// comment, a min/max fold, an integer-annotated sum, and wall clock /
// map iteration confined to #[cfg(test)]. Linted under a driver/
// virtual path, this file must produce zero findings.

use std::collections::{BTreeMap, HashMap};

pub fn ordered_total(m: &BTreeMap<u32, u32>) -> u32 {
    m.values().sum::<u32>()
}

pub fn cache_size(c: &HashMap<u32, u32>) -> usize {
    // detlint: allow(D01, order-independent size count)
    c.values().count()
}

pub fn head(p: *const u8) -> u8 {
    // SAFETY: fixture contract — callers hand in a valid, initialized,
    // readable pointer (the test passes `&7u8`).
    unsafe { *p }
}

pub fn hottest(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NAN, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exemptions_hold() {
        let _ = std::time::Instant::now();
        let mut tm = HashMap::new();
        tm.insert(1u32, 2u32);
        for (k, v) in tm.iter() {
            assert_eq!(*v, k + 1);
        }
        assert_eq!(cache_size(&tm), 1);
        assert_eq!(head(&7u8), 7);
        assert!(hottest(&[1.0, 2.0]) == 2.0);
    }
}
