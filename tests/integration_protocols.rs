//! End-to-end protocol integration tests at CI scale: every protocol runs
//! a short experiment, learns past chance, and its cost profile has the
//! paper's qualitative shape (AdaSplit client compute << FL; local phase
//! free of traffic; server gradient doubles bandwidth; etc.).

use adasplit::config::{ExperimentConfig, ProtocolKind};
use adasplit::data::DatasetKind;
use adasplit::protocols::{run_protocol, run_protocol_recorded};
use adasplit::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load("artifacts").expect("runtime loads"))
}

fn quick(protocol: ProtocolKind) -> ExperimentConfig {
    ExperimentConfig {
        protocol,
        rounds: 4,
        samples_per_client: 96,
        test_per_client: 64,
        kappa: 0.5,
        ..ExperimentConfig::default()
    }
}

#[test]
fn every_protocol_learns_past_chance() {
    let Some(rt) = runtime() else { return };
    let chance = 10.0; // 10-class Mixed-CIFAR head
    for p in ProtocolKind::ALL {
        let r = run_protocol(&rt, &quick(p)).unwrap();
        assert!(
            r.best_accuracy > chance * 1.3,
            "{}: {:.2}% did not beat chance",
            p.name(),
            r.best_accuracy
        );
        assert!(r.bandwidth_gb > 0.0, "{} must communicate", p.name());
        assert!(r.client_tflops > 0.0);
        assert!(r.c3_score > 0.0 && r.c3_score <= 1.0);
    }
}

#[test]
fn adasplit_local_phase_has_zero_traffic() {
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(ProtocolKind::AdaSplit);
    cfg.rounds = 4;
    cfg.kappa = 0.5; // rounds 0-1 local, 2-3 global
    let (_, rec) = run_protocol_recorded(&rt, &cfg).unwrap();
    assert_eq!(rec.rounds[0].phase, "local");
    assert_eq!(rec.rounds[1].phase, "local");
    assert_eq!(rec.rounds[2].phase, "global");
    assert_eq!(rec.rounds[0].bandwidth_gb, 0.0, "local phase must be silent");
    assert_eq!(rec.rounds[1].bandwidth_gb, 0.0);
    assert!(rec.rounds[3].bandwidth_gb > 0.0, "global phase must transmit");
    // local phase never selects clients for the server
    assert!(rec.rounds[0].selected.is_empty());
    assert!(!rec.rounds[3].selected.is_empty());
}

#[test]
fn adasplit_client_compute_is_fraction_of_fl() {
    let Some(rt) = runtime() else { return };
    let ada = run_protocol(&rt, &quick(ProtocolKind::AdaSplit)).unwrap();
    let fed = run_protocol(&rt, &quick(ProtocolKind::FedAvg)).unwrap();
    // paper: ~3x reduction at mu=0.2. Allow slack but require a big gap.
    assert!(
        ada.client_tflops < fed.client_tflops / 2.0,
        "AdaSplit client compute {:.4} vs FedAvg {:.4}",
        ada.client_tflops,
        fed.client_tflops
    );
}

#[test]
fn adasplit_uses_less_bandwidth_than_classic_sl() {
    let Some(rt) = runtime() else { return };
    let ada = run_protocol(&rt, &quick(ProtocolKind::AdaSplit)).unwrap();
    let sl = run_protocol(&rt, &quick(ProtocolKind::SlBasic)).unwrap();
    assert!(
        ada.bandwidth_gb < sl.bandwidth_gb / 2.0,
        "AdaSplit {:.4}GB vs SL {:.4}GB",
        ada.bandwidth_gb,
        sl.bandwidth_gb
    );
}

#[test]
fn scaffold_doubles_fl_bandwidth() {
    let Some(rt) = runtime() else { return };
    let fed = run_protocol(&rt, &quick(ProtocolKind::FedAvg)).unwrap();
    let sca = run_protocol(&rt, &quick(ProtocolKind::Scaffold)).unwrap();
    let ratio = sca.bandwidth_gb / fed.bandwidth_gb;
    assert!((1.9..=2.1).contains(&ratio), "ratio {ratio}");
}

#[test]
fn server_gradient_ablation_doubles_global_traffic() {
    let Some(rt) = runtime() else { return };
    let base = run_protocol(&rt, &quick(ProtocolKind::AdaSplit)).unwrap();
    let mut cfg = quick(ProtocolKind::AdaSplit);
    cfg.server_grad_to_client = true;
    let grad = run_protocol(&rt, &cfg).unwrap();
    let ratio = grad.bandwidth_gb / base.bandwidth_gb;
    assert!((1.7..=2.1).contains(&ratio), "ratio {ratio}");
}

#[test]
fn kappa_one_means_pure_local_training() {
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(ProtocolKind::AdaSplit);
    cfg.kappa = 1.0;
    let r = run_protocol(&rt, &cfg).unwrap();
    assert_eq!(r.bandwidth_gb, 0.0, "kappa=1 must never talk to the server");
    assert_eq!(r.total_tflops, r.client_tflops, "no server compute either");
}

#[test]
fn eta_scales_selected_clients_and_traffic() {
    let Some(rt) = runtime() else { return };
    let mut lo = quick(ProtocolKind::AdaSplit);
    lo.eta = 0.2; // 1 of 5 clients
    let mut hi = quick(ProtocolKind::AdaSplit);
    hi.eta = 1.0; // all 5
    let rlo = run_protocol(&rt, &lo).unwrap();
    let rhi = run_protocol(&rt, &hi).unwrap();
    let ratio = rhi.bandwidth_gb / rlo.bandwidth_gb;
    assert!((4.0..=6.0).contains(&ratio), "eta 1.0/0.2 traffic ratio {ratio}");
}

#[test]
fn activation_l1_shrinks_payload() {
    let Some(rt) = runtime() else { return };
    let mut base = quick(ProtocolKind::AdaSplit);
    base.rounds = 8;
    base.kappa = 0.25; // 6 global rounds so the L1 has time to bite
    base.samples_per_client = 160;
    base.sparse_eps = 0.2;
    let dense = run_protocol(&rt, &base).unwrap();
    let mut cfg = base.clone();
    cfg.beta = 1e-1; // aggressive sparsity at this tiny scale
    let sparse = run_protocol(&rt, &cfg).unwrap();
    assert!(
        sparse.bandwidth_gb < dense.bandwidth_gb,
        "sparse {:.5} !< dense {:.5}",
        sparse.bandwidth_gb,
        dense.bandwidth_gb
    );
    // compute is untouched by payload sparsification
    assert!((sparse.client_tflops - dense.client_tflops).abs() < 1e-9);
}

#[test]
fn runs_are_deterministic_given_seed() {
    let Some(rt) = runtime() else { return };
    let a = run_protocol(&rt, &quick(ProtocolKind::AdaSplit)).unwrap();
    let b = run_protocol(&rt, &quick(ProtocolKind::AdaSplit)).unwrap();
    assert_eq!(a.best_accuracy, b.best_accuracy);
    assert_eq!(a.bandwidth_gb, b.bandwidth_gb);
    let c = run_protocol(&rt, &quick(ProtocolKind::AdaSplit).with_seed(9)).unwrap();
    // different seed => different data/init => (almost surely) different acc
    assert_ne!(a.best_accuracy, c.best_accuracy);
}

#[test]
fn fednova_handles_imbalanced_clients() {
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(ProtocolKind::FedNova);
    cfg.imbalance = 2.0; // client sizes 1:2:4:8:16 (geometric)
    let r = run_protocol(&rt, &cfg).unwrap();
    assert!(r.best_accuracy > 13.0, "FedNova under imbalance: {:.2}%", r.best_accuracy);
}

#[test]
fn mixed_noniid_protocols_run_on_50_class_head() {
    let Some(rt) = runtime() else { return };
    for p in [ProtocolKind::AdaSplit, ProtocolKind::FedAvg, ProtocolKind::SlBasic] {
        let mut cfg = quick(p);
        cfg.dataset = DatasetKind::MixedNonIid;
        cfg.budgets = adasplit::metrics::Budgets::paper_mixed_noniid();
        cfg.lambda = 1e-3;
        let r = run_protocol(&rt, &cfg).unwrap();
        // 50-class head, each client sees 10 classes; chance on own data = 10%
        assert!(
            r.best_accuracy > 3.0,
            "{} on NonIID: {:.2}%",
            p.name(),
            r.best_accuracy
        );
    }
}

#[test]
fn adasplit_masks_sparsify_with_large_lambda() {
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(ProtocolKind::AdaSplit);
    cfg.kappa = 0.25; // long global phase so masks actually train
    cfg.rounds = 8;
    cfg.eta = 1.0; // every client's mask updated every iteration
    cfg.samples_per_client = 320;
    cfg.lambda = 0.05; // heavy L1
    let r = run_protocol(&rt, &cfg).unwrap();
    assert!(
        r.mask_density < 0.9,
        "strong lambda must push mask entries to zero: {}",
        r.mask_density
    );
}
