//! Serial/parallel equivalence suite for the client-execution engine.
//!
//! The engine's contract (DESIGN.md §5) is that thread count is purely a
//! wall-clock knob: `--threads 4` must produce bit-identical `RunResult`
//! metrics to `--threads 1` for every protocol. The pure-engine tests run
//! everywhere; the protocol sweeps need `make artifacts` and skip loudly
//! otherwise, matching the other integration suites.

use adasplit::config::{ExperimentConfig, ProtocolKind};
use adasplit::data::Rng;
use adasplit::driver::{
    resolve_versions, AsyncBounded, BoundController, ClientSpeeds, ClientState, ClientStateStore,
    SampledSync, Scheduler, SnapshotRing, SpeedPreset, SyncAll, WindowDelta,
};
use adasplit::engine::{par_indexed, par_slice_mut, tree_reduce, ClientPool};
use adasplit::metrics::{AccuracyAccum, Budgets, CostMeter};
use adasplit::protocols::{run_protocol, RunResult};
use adasplit::runtime::{Runtime, Tensor, TensorStore};
use adasplit::sim::{EngineKind, Event, EventHeap, EventKind, MergePolicyKind};

fn assert_results_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.accuracy, b.accuracy, "{what} accuracy");
    assert_eq!(a.best_accuracy, b.best_accuracy, "{what} best_accuracy");
    assert_eq!(a.bandwidth_gb, b.bandwidth_gb, "{what} bandwidth");
    assert_eq!(a.client_tflops, b.client_tflops, "{what} client_tflops");
    assert_eq!(a.total_tflops, b.total_tflops, "{what} total_tflops");
    assert_eq!(a.c3_score, b.c3_score, "{what} c3");
    assert_eq!(a.mask_density, b.mask_density, "{what} mask_density");
    assert_eq!(
        a.sampled_clients_per_round, b.sampled_clients_per_round,
        "{what} sampled_clients_per_round"
    );
    assert_eq!(a.sim_time, b.sim_time, "{what} sim_time");
    assert_eq!(a.max_staleness, b.max_staleness, "{what} max_staleness");
}

// ---- pure engine determinism (no artifacts required) ----------------------

#[test]
fn float_reduction_is_thread_count_invariant() {
    // per-index work + in-order fan-in: the reduction tree is fixed, so
    // any worker count yields the same bits
    let work = |i: usize| -> anyhow::Result<f64> {
        let mut acc = 0.0f64;
        for k in 1..500 {
            acc += ((i as f64 + 1.0) / k as f64).sqrt().sin();
        }
        Ok(acc)
    };
    let reduce = |parts: &[f64]| parts.iter().sum::<f64>();
    let serial = reduce(&par_indexed(1, 48, work).unwrap());
    for threads in [2, 3, 4, 8] {
        let par = reduce(&par_indexed(threads, 48, work).unwrap());
        assert_eq!(serial.to_bits(), par.to_bits(), "threads={threads}");
    }
}

#[test]
fn slice_mut_is_thread_count_invariant() {
    let run = |threads: usize| -> Vec<f64> {
        let mut states: Vec<f64> = (0..33).map(|i| i as f64 * 0.1).collect();
        par_slice_mut(threads, &mut states, |i, s| {
            for _ in 0..100 {
                *s = (*s + i as f64).sin();
            }
            Ok(())
        })
        .unwrap();
        states
    };
    let serial = run(1);
    for threads in [2, 4, 8] {
        assert_eq!(serial, run(threads), "threads={threads}");
    }
}

#[test]
fn cost_meter_merge_in_id_order_matches_serial_accounting() {
    // serial: interleaved per-client adds; parallel: per-client deltas
    // merged in id order — fields are plain sums, so they agree exactly
    let mut serial = CostMeter::new();
    for i in 0..6usize {
        serial.add_client_flops(1e9 * (i + 1) as f64);
        serial.add_up(1000 * (i + 1));
        serial.add_down(500 * (i + 1));
    }
    let deltas: Vec<CostMeter> = (0..6usize)
        .map(|i| {
            let mut d = CostMeter::new();
            d.add_client_flops(1e9 * (i + 1) as f64);
            d.add_up(1000 * (i + 1));
            d.add_down(500 * (i + 1));
            d
        })
        .collect();
    let mut merged = CostMeter::new();
    for d in &deltas {
        merged.merge(d);
    }
    assert_eq!(serial.client_flops, merged.client_flops);
    assert_eq!(serial.up_bytes, merged.up_bytes);
    assert_eq!(serial.down_bytes, merged.down_bytes);
    assert_eq!(serial.bandwidth_gb(), merged.bandwidth_gb());
}

#[test]
fn accuracy_merge_in_id_order_matches_serial_eval() {
    let batches: &[(usize, f64, f64)] =
        &[(0, 8.0, 10.0), (0, 3.0, 6.0), (1, 5.0, 10.0), (2, 2.0, 4.0)];
    let mut serial = AccuracyAccum::new(3);
    for &(i, c, t) in batches {
        serial.add(i, c, t);
    }
    let mut merged = AccuracyAccum::new(3);
    for client in 0..3usize {
        let mut part = AccuracyAccum::new(3);
        for &(i, c, t) in batches.iter().filter(|(i, _, _)| *i == client) {
            part.add(i, c, t);
        }
        merged.merge(&part);
    }
    assert_eq!(serial.accuracy_pct(), merged.accuracy_pct());
    assert_eq!(serial.per_client_pct(), merged.per_client_pct());
    assert_eq!(serial.mean_client_pct(), merged.mean_client_pct());
}

#[test]
fn pool_is_usable_concurrently_with_shared_state() {
    let data: Vec<u64> = (0..1000).collect();
    let sums = ClientPool::new(4)
        .run(10, |i| Ok(data.iter().skip(i).step_by(10).sum::<u64>()))
        .unwrap();
    assert_eq!(sums.iter().sum::<u64>(), 1000 * 999 / 2);
}

// ---- persistent pool & sharded state (no artifacts required) --------------

#[test]
fn pool_reuse_is_bit_identical_and_spawn_free_after_warmup() {
    let work = |i: usize| -> anyhow::Result<f64> {
        let mut acc = 0.0f64;
        for k in 1..300 {
            acc += ((i as f64 + 2.0) * k as f64).cos() / k as f64;
        }
        Ok(acc)
    };
    for threads in [1usize, 4] {
        let pool = ClientPool::new(threads);
        let first = pool.run(40, work).unwrap();
        let spawned = pool.spawned_workers();
        assert!(spawned <= threads.saturating_sub(1), "threads={threads}");
        for call in 0..3 {
            // reused persistent pool vs a fresh transient pool per call
            assert_eq!(pool.run(40, work).unwrap(), first, "threads={threads} call={call}");
            assert_eq!(par_indexed(threads, 40, work).unwrap(), first, "fresh, call={call}");
            assert_eq!(pool.spawned_workers(), spawned, "no spawns after warm-up");
        }
        // run_mut through the same warm pool matches a fresh pool too
        let step = |i: usize, s: &mut f64| -> anyhow::Result<()> {
            *s = (*s * 1.5 + i as f64).sin();
            Ok(())
        };
        let mut reused: Vec<f64> = (0..40).map(|i| i as f64).collect();
        pool.run_mut(&mut reused, step).unwrap();
        let mut fresh: Vec<f64> = (0..40).map(|i| i as f64).collect();
        par_slice_mut(threads, &mut fresh, step).unwrap();
        assert_eq!(reused, fresh, "threads={threads}");
        assert_eq!(pool.spawned_workers(), spawned, "run_mut reuses the same workers");
    }
}

#[test]
fn pool_fail_fast_surfaces_lowest_index_error_and_survives_reuse() {
    for threads in [1usize, 4] {
        let pool = ClientPool::new(threads);
        // warm the pool with a clean run; later failures must not poison
        // the parked workers
        assert!(pool.run(8, Ok).is_ok());
        for call in 0..2 {
            let err = pool
                .run(64, |i| {
                    if i % 7 == 5 {
                        Err(anyhow::anyhow!("client {i} failed"))
                    } else {
                        Ok(i)
                    }
                })
                .unwrap_err();
            assert!(
                err.to_string().contains("client 5"),
                "threads={threads} call={call}: expected lowest-index error, got {err}"
            );
        }
        assert_eq!(pool.run(4, |i| Ok(i * 2)).unwrap(), vec![0, 2, 4, 6], "pool survives");
    }
}

#[test]
fn pool_meter_fan_in_tree_matches_exact_sums() {
    // the driver's tree fan-in in miniature: with dyadic per-client
    // values every f64 add is exact, so the balanced tree must reproduce
    // the plain totals for any participant count (the tree's shape is a
    // function of the count alone — that is the thread-parity argument)
    for n in [1usize, 2, 5, 16, 33] {
        let deltas: Vec<CostMeter> = (0..n)
            .map(|i| {
                let mut d = CostMeter::new();
                d.add_client_flops((i + 1) as f64 * 0.5);
                d.add_up(i + 1);
                d
            })
            .collect();
        let total = tree_reduce(deltas, |mut a, b| {
            a.merge(&b);
            a
        })
        .unwrap();
        let expect_flops: f64 = (0..n).map(|i| (i + 1) as f64 * 0.5).sum();
        assert_eq!(total.client_flops, expect_flops, "n={n}");
        assert_eq!(total.up_bytes, (n * (n + 1) / 2) as f64, "n={n}");
    }
}

#[test]
fn shard_fleet_scale_round_state_is_o_sample() {
    // the acceptance-criterion scale point, artifact-free: 100000 clients
    // at p = 0.005 — sampling, speed lookups, and client-state residency
    // must all track the ~500-client sample, never the fleet
    const FLEET: usize = 100_000;
    let sampler = SampledSync::new(FLEET, 0.005, 77);
    let speeds = ClientSpeeds::new(FLEET, SpeedPreset::Lognormal { sigma: 0.5 }, 0.0, 77);
    let dir = std::env::temp_dir().join(format!("adasplit-shard-it-{}", std::process::id()));
    let mut store = ClientStateStore::with_spill(FLEET, dir).unwrap();
    let tiny = |i: usize| -> anyhow::Result<ClientState> {
        let mut model = TensorStore::new();
        model.insert("state.t", Tensor::scalar(i as f32));
        let mut s = ClientState::new();
        s.insert("model", model);
        Ok(s)
    };
    let mut last_sample: Vec<usize> = Vec::new();
    for round in 0..3usize {
        let sample = sampler.participants(round);
        assert_eq!(sample.len(), 500, "round {round}: ceil(0.005 * 100000)");
        assert!(sample.windows(2).all(|w| w[0] < w[1]), "ascending unique ids");
        store.spill_except(&sample).unwrap();
        store.ensure_loaded(&sample, tiny).unwrap();
        assert_eq!(store.loaded_ids(), sample, "round {round}: residency == sample");
        // per-round speed lookups are pure functions of the id — no
        // fleet-sized table behind them
        for &i in sample.iter().take(16) {
            let (compute, network) = speeds.rates(i);
            assert!(compute > 0.0 && network > 0.0);
            assert_eq!(speeds.rates(i), (compute, network), "lookup is pure");
        }
        last_sample = sample;
    }
    // states keep their values across spill round trips
    let probe = last_sample[0];
    let t = store.get(probe).unwrap().get("model").unwrap().get("state.t").unwrap().item();
    assert_eq!(t, probe as f32);
}

// ---- scheduler determinism (no artifacts required) ------------------------

#[test]
fn sampled_sync_at_full_participation_equals_sync_all() {
    // the p = 1.0 degenerate case must be *exactly* SyncAll so that
    // `--participation 1.0` is bit-identical to the default scheduler
    let all = SyncAll::new(9);
    let sampled = SampledSync::new(9, 1.0, 123);
    for round in 0..32 {
        assert_eq!(sampled.participants(round), all.participants(round));
    }
}

#[test]
fn sampled_sync_is_invocation_deterministic() {
    // two schedulers built from the same (n, p, seed) draw the same
    // sample stream — the basis of repeat-run determinism; thread-count
    // invariance is automatic because sampling runs on the driver thread
    let draws = |seed: u64| -> Vec<Vec<usize>> {
        let s = SampledSync::new(200, 0.25, seed);
        (0..16).map(|r| s.participants(r)).collect()
    };
    assert_eq!(draws(5), draws(5));
    assert_ne!(draws(5), draws(6), "seed must matter");
    for sample in draws(5) {
        assert_eq!(sample.len(), 50, "ceil(0.25 * 200)");
        assert!(sample.windows(2).all(|w| w[0] < w[1]), "ascending unique ids");
    }
}

// ---- async scheduler determinism (no artifacts required) ------------------

#[test]
fn async_bounded_s0_uniform_plans_equal_sync_all_plans() {
    // the degenerate async case must schedule exactly like SyncAll:
    // same participants, zero staleness, same virtual clock
    let speeds = ClientSpeeds::new(9, SpeedPreset::Uniform, 0.0, 123);
    let mut sync = SyncAll::with_speeds(9, &speeds);
    let mut asynced = AsyncBounded::new(9, 0, 1.0, &speeds);
    for round in 0..32 {
        let a = sync.plan(round);
        let b = asynced.plan(round);
        assert_eq!(a.participants, b.participants, "round {round}");
        assert_eq!(b.staleness, vec![0; 9], "round {round}");
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "round {round}");
    }
}

#[test]
fn async_bounded_plan_stream_is_invocation_deterministic() {
    // two schedulers from the same (n, s, p, speeds) draw the same plan
    // stream; planning runs on the driver thread, so thread-count
    // invariance of a full run follows for free
    let stream = |seed: u64| -> Vec<(Vec<usize>, Vec<usize>, u64)> {
        let speeds = ClientSpeeds::new(40, SpeedPreset::Stragglers, 0.25, seed);
        let mut s = AsyncBounded::new(40, 2, 0.5, &speeds);
        (0..24)
            .map(|r| {
                let p = s.plan(r);
                (p.participants, p.staleness, p.sim_time.to_bits())
            })
            .collect()
    };
    assert_eq!(stream(5), stream(5));
    assert_ne!(stream(5), stream(6), "seed must matter");
    for (participants, staleness, _) in stream(5) {
        assert!(!participants.is_empty(), "merge set never empty");
        assert!(participants.windows(2).all(|w| w[0] < w[1]), "ascending unique");
        assert!(staleness.iter().all(|&st| st <= 2), "bound respected");
    }
}

#[test]
fn async_clock_unaffected_by_participants_peek() {
    // `Scheduler::participants` is a non-advancing peek: interleaving it
    // with `plan` must leave a stateful scheduler's plan stream (clients,
    // staleness, virtual clock) bit-identical to a peek-free run
    let speeds = ClientSpeeds::new(24, SpeedPreset::Stragglers, 0.3, 7);
    let mut clean = AsyncBounded::new(24, 3, 0.5, &speeds);
    let mut peeked = AsyncBounded::new(24, 3, 0.5, &speeds);
    for round in 0..40 {
        let peek = peeked.participants(round);
        assert_eq!(peek, peeked.participants(round), "round {round}: peeks agree");
        let a = clean.plan(round);
        let b = peeked.plan(round);
        assert_eq!(peek, b.participants, "round {round}: peek == next plan");
        assert_eq!(a.participants, b.participants, "round {round}");
        assert_eq!(a.staleness, b.staleness, "round {round}");
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "round {round}");
    }
}

// ---- delayed-gradient version resolution (no artifacts required) ----------

#[test]
fn delayed_version_resolution_hands_round_minus_s_weights() {
    // the tentpole contract in miniature: at round r, a participant the
    // scheduler reports s rounds stale is handed the broadcast snapshot
    // from round r - s — the model it actually pulled — while fresh
    // participants read the live state (no handle)
    let mut ring = SnapshotRing::new(4); // staleness bound 3
    for r in 0..8usize {
        let mut snap = TensorStore::new();
        snap.insert("pg.w", Tensor::full(&[2], r as f32));
        ring.push(r, snap).unwrap();
    }
    let versions = resolve_versions(&ring, 7, &[0, 1, 3, 1]).unwrap();
    assert!(versions[0].is_none(), "fresh participant reads the live model");
    let v = versions[1].as_ref().unwrap();
    assert_eq!(v.round(), 6, "s=1 at round 7 pulled round 6");
    assert_eq!(v.state().get("pg.w").unwrap().data(), &[6.0, 6.0]);
    let v = versions[2].as_ref().unwrap();
    assert_eq!(v.round(), 4, "s=3 at round 7 pulled round 4");
    assert_eq!(v.state().get("pg.w").unwrap().data(), &[4.0, 4.0]);
    assert_eq!(versions[3].as_ref().unwrap().round(), 6);
    // a version past the retained window is an invariant violation
    assert!(resolve_versions(&ring, 7, &[4]).is_err());
}

// ---- full-protocol equivalence (requires `make artifacts`) ----------------

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load("artifacts").expect("runtime loads"))
}

fn quick(protocol: ProtocolKind, threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        protocol,
        rounds: 3,
        samples_per_client: 64,
        test_per_client: 32,
        // one local + two global rounds, so AdaSplit's orchestrated
        // server path is exercised too
        kappa: 0.34,
        threads,
        ..ExperimentConfig::default()
    }
}

#[test]
fn every_protocol_is_thread_count_invariant() {
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let serial = run_protocol(&rt, &quick(p, 1)).unwrap();
        let par = run_protocol(&rt, &quick(p, 4)).unwrap();
        assert_eq!(serial.accuracy, par.accuracy, "{} accuracy", p.name());
        assert_eq!(
            serial.best_accuracy,
            par.best_accuracy,
            "{} best_accuracy",
            p.name()
        );
        assert_eq!(serial.bandwidth_gb, par.bandwidth_gb, "{} bandwidth", p.name());
        assert_eq!(
            serial.client_tflops,
            par.client_tflops,
            "{} client_tflops",
            p.name()
        );
        assert_eq!(serial.total_tflops, par.total_tflops, "{} total_tflops", p.name());
        assert_eq!(serial.c3_score, par.c3_score, "{} c3", p.name());
        assert_eq!(serial.mask_density, par.mask_density, "{} mask_density", p.name());
    }
}

#[test]
fn adasplit_server_grad_ablation_is_thread_count_invariant() {
    // the stale-gradient path routes per-client tensors through the
    // fan-out; make sure it stays deterministic too
    let Some(rt) = runtime() else { return };
    let mut serial_cfg = quick(ProtocolKind::AdaSplit, 1);
    serial_cfg.server_grad_to_client = true;
    let mut par_cfg = quick(ProtocolKind::AdaSplit, 4);
    par_cfg.server_grad_to_client = true;
    let serial = run_protocol(&rt, &serial_cfg).unwrap();
    let par = run_protocol(&rt, &par_cfg).unwrap();
    assert_eq!(serial.accuracy, par.accuracy);
    assert_eq!(serial.bandwidth_gb, par.bandwidth_gb);
    assert_eq!(serial.c3_score, par.c3_score);
}

// ---- old-vs-new parity pin (requires `make artifacts` + goldens) ----------

/// Pins the redesigned driver against pre-redesign metrics, protocol by
/// protocol. Goldens are recorded with
/// `ADASPLIT_WRITE_GOLDENS=1 cargo test -q --test engine_determinism`
/// (run once at the last pre-driver commit, or at any commit declared a
/// new numerical baseline) and committed to `tests/goldens/`. The test
/// skips loudly when the file is absent, like the artifact gate.
#[test]
fn driver_matches_recorded_protocol_goldens() {
    let Some(rt) = runtime() else { return };
    let golden_path = std::path::Path::new("tests/goldens/protocol_parity.json");
    let results: Vec<(ProtocolKind, RunResult)> = ProtocolKind::ALL
        .iter()
        .map(|&p| (p, run_protocol(&rt, &quick(p, 1)).unwrap()))
        .collect();

    if std::env::var("ADASPLIT_WRITE_GOLDENS").as_deref() == Ok("1") {
        let mut obj = std::collections::BTreeMap::new();
        for (p, r) in &results {
            let mut m = std::collections::BTreeMap::new();
            m.insert("accuracy".to_string(), adasplit::util::Json::Num(r.accuracy));
            m.insert("best_accuracy".to_string(), adasplit::util::Json::Num(r.best_accuracy));
            m.insert("bandwidth_gb".to_string(), adasplit::util::Json::Num(r.bandwidth_gb));
            m.insert("client_tflops".to_string(), adasplit::util::Json::Num(r.client_tflops));
            m.insert("total_tflops".to_string(), adasplit::util::Json::Num(r.total_tflops));
            m.insert("mask_density".to_string(), adasplit::util::Json::Num(r.mask_density));
            obj.insert(p.id().to_string(), adasplit::util::Json::Obj(m));
        }
        std::fs::create_dir_all("tests/goldens").unwrap();
        std::fs::write(golden_path, adasplit::util::Json::Obj(obj).to_string_pretty()).unwrap();
        eprintln!("WROTE goldens to {golden_path:?}");
        return;
    }

    let Ok(text) = std::fs::read_to_string(golden_path) else {
        eprintln!("SKIP: no goldens recorded (ADASPLIT_WRITE_GOLDENS=1 to record)");
        return;
    };
    let golden = adasplit::util::Json::parse(&text).expect("goldens parse");
    for (p, r) in &results {
        let g = golden.get(p.id()).expect("protocol present in goldens");
        let field = |k: &str| g.get(k).unwrap().as_f64().unwrap();
        assert_eq!(r.accuracy, field("accuracy"), "{} accuracy", p.name());
        assert_eq!(r.best_accuracy, field("best_accuracy"), "{} best", p.name());
        assert_eq!(r.bandwidth_gb, field("bandwidth_gb"), "{} bandwidth", p.name());
        assert_eq!(r.client_tflops, field("client_tflops"), "{} client_tflops", p.name());
        assert_eq!(r.total_tflops, field("total_tflops"), "{} total_tflops", p.name());
        assert_eq!(r.mask_density, field("mask_density"), "{} mask_density", p.name());
    }
}

// ---- SampledSync end-to-end (requires `make artifacts`) -------------------

#[test]
fn explicit_full_participation_is_bit_identical_to_default() {
    // `--participation 1.0` (explicit) must route through the exact same
    // code paths as the default SyncAll run: same scheduler behavior, no
    // spilling, parallel eval path
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let base = run_protocol(&rt, &quick(p, 2)).unwrap();
        let mut cfg = quick(p, 2);
        cfg.participation = 1.0;
        let explicit = run_protocol(&rt, &cfg).unwrap();
        assert_results_identical(&base, &explicit, p.name());
    }
}

#[test]
fn sampled_runs_are_thread_count_invariant() {
    // participant selection happens on the driver thread, so a sampled
    // run must stay bit-identical across worker counts
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let mut serial_cfg = quick(p, 1);
        serial_cfg.clients = 8;
        serial_cfg.participation = 0.5;
        let mut par_cfg = serial_cfg.clone();
        par_cfg.threads = 4;
        let serial = run_protocol(&rt, &serial_cfg).unwrap();
        let par = run_protocol(&rt, &par_cfg).unwrap();
        assert_results_identical(&serial, &par, p.name());
        assert_eq!(serial.sampled_clients_per_round, 4.0, "{} ceil(0.5*8)", p.name());
    }
}

#[test]
fn sampled_runs_are_repeat_invocation_deterministic() {
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(ProtocolKind::AdaSplit, 2);
    cfg.clients = 8;
    cfg.participation = 0.25;
    let a = run_protocol(&rt, &cfg).unwrap();
    let b = run_protocol(&rt, &cfg).unwrap();
    assert_results_identical(&a, &b, "repeat invocation");
    let mut other_seed = cfg.clone();
    other_seed.seed = 9;
    let c = run_protocol(&rt, &other_seed).unwrap();
    assert!(
        a.accuracy != c.accuracy || a.bandwidth_gb != c.bandwidth_gb,
        "different seed should draw different samples"
    );
}

#[test]
fn sampled_many_client_run_completes_with_pooled_state() {
    // the acceptance-criterion shape: lots of clients, small sample —
    // per-client state lives in the pooled store and inactive clients
    // spill, so the run completes without holding every state resident
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(ProtocolKind::FedAvg, 2);
    cfg.clients = 64;
    cfg.participation = 0.25;
    cfg.samples_per_client = 32;
    cfg.test_per_client = 32;
    cfg.rounds = 2;
    let r = run_protocol(&rt, &cfg).unwrap();
    assert_eq!(r.sampled_clients_per_round, 16.0, "ceil(0.25*64)");
    assert!(r.accuracy >= 0.0);
}

// ---- AsyncBounded end-to-end (requires `make artifacts`) ------------------

#[test]
fn async_s0_uniform_is_bit_identical_to_sync_all_for_every_protocol() {
    // the acceptance criterion: `--staleness-bound 0` with uniform speeds
    // must reproduce the default synchronous run bit-for-bit, protocol by
    // protocol — same participants every round, no stale contribution, no
    // decay scope, unscaled cost merging
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let base = run_protocol(&rt, &quick(p, 2)).unwrap();
        let mut cfg = quick(p, 2);
        cfg.staleness_bound = Some(0);
        let asynced = run_protocol(&rt, &cfg).unwrap();
        assert_results_identical(&base, &asynced, p.name());
        assert_eq!(asynced.scheduler, "async-bounded");
        assert_eq!(base.scheduler, "sync-all");
        assert_eq!(asynced.sim_time, cfg.rounds as f64, "uniform clock counts rounds");
    }
}

#[test]
fn async_runs_are_thread_count_invariant_for_every_protocol() {
    // planning happens on the driver thread and merges stay in id order,
    // so an async run with real staleness must be bit-identical across
    // worker counts for all seven protocols
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let mut serial_cfg = quick(p, 1);
        serial_cfg.clients = 8;
        serial_cfg.staleness_bound = Some(2);
        serial_cfg.client_speeds = SpeedPreset::Stragglers;
        serial_cfg.straggler_frac = 0.25;
        let mut par_cfg = serial_cfg.clone();
        par_cfg.threads = 4;
        let serial = run_protocol(&rt, &serial_cfg).unwrap();
        let par = run_protocol(&rt, &par_cfg).unwrap();
        assert_results_identical(&serial, &par, p.name());
    }
}

#[test]
fn async_runs_are_repeat_invocation_deterministic() {
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(ProtocolKind::AdaSplit, 2);
    cfg.clients = 8;
    cfg.staleness_bound = Some(1);
    cfg.client_speeds = SpeedPreset::Lognormal { sigma: 0.6 };
    let a = run_protocol(&rt, &cfg).unwrap();
    let b = run_protocol(&rt, &cfg).unwrap();
    assert_results_identical(&a, &b, "repeat invocation");
    let mut other_seed = cfg.clone();
    other_seed.seed = 9;
    let c = run_protocol(&rt, &other_seed).unwrap();
    assert!(
        a.sim_time != c.sim_time || a.accuracy != c.accuracy,
        "different seed should draw different speeds/schedules"
    );
}

// ---- delayed-gradient versioning end-to-end (requires `make artifacts`) ---

#[test]
fn delayed_s0_remains_bit_identical_for_every_protocol() {
    // acceptance criterion: with --delayed-gradients off (the default —
    // literally the unversioned code path) and with --staleness-bound 0
    // (everything fresh, the ring is pushed but never read), all seven
    // protocols reproduce the synchronous baseline bit-for-bit
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let base = run_protocol(&rt, &quick(p, 2)).unwrap();
        let mut s0 = quick(p, 2);
        s0.staleness_bound = Some(0);
        let cadence0 = run_protocol(&rt, &s0).unwrap();
        let mut v0 = s0.clone();
        v0.delayed_gradients = true;
        let versioned0 = run_protocol(&rt, &v0).unwrap();
        assert_results_identical(&base, &cadence0, p.name());
        assert_results_identical(&base, &versioned0, p.name());
        assert!(versioned0.delayed_gradients && !cadence0.delayed_gradients);
        assert_eq!(versioned0.max_staleness, 0, "{} s=0 is all-fresh", p.name());
    }
}

#[test]
fn delayed_gradients_change_fl_training_but_not_costs_or_schedule() {
    // with real staleness, true delayed gradients must train FedAvg
    // against *different* weights than the cadence-only approximation —
    // while the schedule (participants, staleness, sim-time) and every
    // metered cost stay identical, because versioning changes which
    // bits a client trains on, not what work is done
    let Some(rt) = runtime() else { return };
    let mut cadence_cfg = quick(ProtocolKind::FedAvg, 2);
    cadence_cfg.clients = 8;
    cadence_cfg.staleness_bound = Some(2);
    cadence_cfg.client_speeds = SpeedPreset::Stragglers;
    cadence_cfg.straggler_frac = 0.25;
    let mut delayed_cfg = cadence_cfg.clone();
    delayed_cfg.delayed_gradients = true;
    let (cadence, cadence_rec) =
        adasplit::protocols::run_protocol_recorded(&rt, &cadence_cfg).unwrap();
    let (delayed, delayed_rec) =
        adasplit::protocols::run_protocol_recorded(&rt, &delayed_cfg).unwrap();
    assert_eq!(cadence.bandwidth_gb, delayed.bandwidth_gb, "same bytes moved");
    assert_eq!(cadence.client_tflops, delayed.client_tflops, "same client work");
    assert_eq!(cadence.total_tflops, delayed.total_tflops, "same total work");
    assert_eq!(cadence.sim_time, delayed.sim_time, "same virtual clock");
    assert_eq!(cadence.max_staleness, delayed.max_staleness, "same schedule");
    // divergence is asserted on the continuous train-loss trajectory, not
    // the coarse eval accuracy (two different weight trajectories can tie
    // on a tiny test set's argmax count)
    let losses = |rec: &adasplit::metrics::Recorder| -> Vec<u64> {
        rec.rounds.iter().map(|r| r.train_loss.to_bits()).collect()
    };
    let max_stale = cadence_rec.rounds.iter().map(|r| r.max_staleness).max().unwrap_or(0);
    if max_stale > 0 {
        assert_ne!(
            losses(&cadence_rec),
            losses(&delayed_rec),
            "true delay (max staleness {max_stale}) must train against different weights"
        );
    } else {
        // nothing went stale under this seed: the modes must then agree
        assert_eq!(cadence.accuracy, delayed.accuracy);
        assert_eq!(losses(&cadence_rec), losses(&delayed_rec));
    }
}

#[test]
fn delayed_runs_are_thread_count_invariant_for_every_protocol() {
    // version handles are resolved on the driver thread and shared
    // read-only with the workers, so the versioned run must stay
    // bit-identical across worker counts — including the protocols whose
    // versioning degenerates to cadence-only (no broadcast state)
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let mut serial_cfg = quick(p, 1);
        serial_cfg.clients = 8;
        serial_cfg.staleness_bound = Some(2);
        serial_cfg.client_speeds = SpeedPreset::Stragglers;
        serial_cfg.straggler_frac = 0.25;
        serial_cfg.delayed_gradients = true;
        let mut par_cfg = serial_cfg.clone();
        par_cfg.threads = 4;
        let serial = run_protocol(&rt, &serial_cfg).unwrap();
        let par = run_protocol(&rt, &par_cfg).unwrap();
        assert_results_identical(&serial, &par, p.name());
    }
}

#[test]
fn delayed_with_sampling_spills_snapshots_and_stays_deterministic() {
    // async + participation cap + spilling client store + the *spilling
    // snapshot ring* all at once: repeated invocations must agree
    // bit-for-bit (spilled snapshots round-trip exactly), and a
    // different seed must diverge
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(ProtocolKind::Scaffold, 2);
    cfg.clients = 16;
    cfg.participation = 0.5;
    cfg.staleness_bound = Some(3);
    cfg.client_speeds = SpeedPreset::Lognormal { sigma: 0.6 };
    cfg.delayed_gradients = true;
    cfg.samples_per_client = 32;
    cfg.test_per_client = 32;
    let a = run_protocol(&rt, &cfg).unwrap();
    let b = run_protocol(&rt, &cfg).unwrap();
    assert_results_identical(&a, &b, "repeat invocation");
    assert!(a.delayed_gradients);
    let mut other_seed = cfg.clone();
    other_seed.seed = 9;
    let c = run_protocol(&rt, &other_seed).unwrap();
    assert!(
        a.sim_time != c.sim_time || a.accuracy != c.accuracy,
        "different seed should draw different speeds/schedules"
    );
}

// ---- adaptive bound controller (no artifacts required) --------------------

#[test]
fn adaptive_controller_same_seed_same_arm_sequence() {
    // the controller is a pure function of (seed, reward stream): replay
    // the same synthetic stream and the arm sequence must match exactly.
    // CI runs this suite twice back-to-back as a flake guard — any
    // hidden global state (time, ambient randomness) would surface as a
    // cross-run mismatch in the recorded sequences.
    let run = |seed: u64| -> Vec<usize> {
        let mut c = BoundController::new(8, 5, seed, Budgets::paper_mixed_cifar());
        let mut sequence = vec![c.current_bound()];
        for w in 0..40u64 {
            // arm-sensitive stream: looser bounds finish windows faster
            let d = WindowDelta {
                d_accuracy_pct: 0.8 + (w % 5) as f64 * 0.2,
                d_sim_time: 12.0 / (1.0 + c.current_bound() as f64),
                d_bandwidth_gb: 0.4,
                d_client_tflops: 0.2,
            };
            sequence.push(c.observe_window(&d).0);
        }
        sequence
    };
    assert_eq!(run(3), run(3), "same seed must replay the same arm sequence");
    let first = run(0);
    assert!(
        (1..64).any(|s| run(s) != first),
        "the seed must be able to change the exploration order"
    );
    // every sequence element is a real arm
    for b in run(3) {
        assert!([0usize, 1, 2, 4, 8].contains(&b), "unknown arm {b}");
    }
}

#[test]
fn adaptive_set_bound_invariants_hold_under_adversarial_switching() {
    // property test: random fleets x participation x straggler-frac x an
    // adversarial mid-run switch schedule. After every switch the
    // scheduler must still (1) never produce an empty merge set,
    // (2) never merge an update staler than the *current* bound, and
    // (3) never rewind the server clock.
    let mut r = Rng::new(4242);
    for case in 0..60u64 {
        let n = 1 + r.below(30);
        let initial_bound = r.below(9);
        let participation = if r.next_f64() < 0.3 { 0.001 } else { r.uniform(0.01, 1.0) };
        let frac = if r.next_f64() < 0.3 { 1.0 } else { r.uniform(0.0, 1.0) };
        let speeds = ClientSpeeds::new(n, SpeedPreset::Stragglers, frac, case);
        let mut s = AsyncBounded::new(n, initial_bound, participation, &speeds);
        let mut bound = initial_bound;
        let mut prev_t = 0.0f64;
        for round in 0..60 {
            if r.next_f64() < 0.35 {
                bound = r.below(9);
                assert!(s.set_bound(bound, round), "AsyncBounded supports switching");
            }
            assert_eq!(s.current_bound(), bound, "case {case} round {round}");
            let plan = s.plan(round);
            assert!(
                !plan.participants.is_empty(),
                "case {case} (n={n} p={participation} frac={frac}) round {round}: \
                 empty merge set after a switch"
            );
            assert!(
                plan.participants.windows(2).all(|w| w[0] < w[1]),
                "case {case} round {round}: participants not ascending-unique"
            );
            assert_eq!(plan.participants.len(), plan.staleness.len(), "case {case}");
            for (&i, &st) in plan.participants.iter().zip(&plan.staleness) {
                assert!(
                    st <= bound,
                    "case {case} round {round}: client {i} merged {st} rounds stale \
                     under current bound {bound}"
                );
            }
            assert!(
                plan.sim_time >= prev_t,
                "case {case} round {round}: clock {} < {prev_t}",
                plan.sim_time
            );
            prev_t = plan.sim_time;
        }
    }
}

// ---- adaptive bound end-to-end (requires `make artifacts`) ----------------

fn adaptive_quick(protocol: ProtocolKind, threads: usize) -> ExperimentConfig {
    let mut cfg = quick(protocol, threads);
    cfg.clients = 8;
    cfg.staleness_bound = Some(2);
    cfg.client_speeds = SpeedPreset::Stragglers;
    cfg.straggler_frac = 0.25;
    cfg.adaptive_bound = true;
    // one-round windows: a switch opportunity at every boundary of the
    // 3-round quick run
    cfg.adapt_window = 1;
    cfg
}

#[test]
fn adaptive_singleton_arm_is_bit_identical_to_fixed_bound_for_every_protocol() {
    // the acceptance criterion: a controller whose candidate set is the
    // single configured bound has nothing to decide — the run must be
    // bit-identical to the fixed `--staleness-bound` run, protocol by
    // protocol. set_bound to the active bound is a pure no-op, the
    // pre-training baseline eval is value-neutral, and — because this
    // config keeps the default eval_every = 1 — the window-boundary
    // evals land on rounds the fixed run evaluates anyway (a sparser
    // eval cadence would record extra boundary eval points instead;
    // training and schedule stay identical either way)
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let mut fixed_cfg = adaptive_quick(p, 2);
        fixed_cfg.adaptive_bound = false;
        let mut singleton_cfg = adaptive_quick(p, 2);
        singleton_cfg.adapt_arms = Some(vec![2]);
        let fixed = run_protocol(&rt, &fixed_cfg).unwrap();
        let adaptive = run_protocol(&rt, &singleton_cfg).unwrap();
        assert_results_identical(&fixed, &adaptive, p.name());
        assert!(adaptive.adaptive && !fixed.adaptive, "{} mode flags", p.name());
        assert_eq!(adaptive.final_bound, 2, "{} singleton arm", p.name());
        assert_eq!(adaptive.bound_switches, 0, "{} no switches", p.name());
        assert_eq!(fixed.final_bound, 2, "{} fixed bound recorded", p.name());
    }
}

#[test]
fn adaptive_runs_are_thread_count_invariant_for_every_protocol() {
    // controller decisions run on the driver thread off thread-count-
    // invariant metrics, so the whole adaptive run — including the arm
    // trajectory — must be bit-identical across worker counts
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let serial = run_protocol(&rt, &adaptive_quick(p, 1)).unwrap();
        let par = run_protocol(&rt, &adaptive_quick(p, 4)).unwrap();
        assert_results_identical(&serial, &par, p.name());
        assert_eq!(serial.final_bound, par.final_bound, "{} final bound", p.name());
        assert_eq!(
            serial.bound_switches, par.bound_switches,
            "{} switch count",
            p.name()
        );
    }
}

#[test]
fn adaptive_runs_are_repeat_invocation_deterministic() {
    // same seed ⇒ identical per-round bound trajectory (the run-level
    // arm sequence), not just identical summary metrics
    let Some(rt) = runtime() else { return };
    let cfg = adaptive_quick(ProtocolKind::FedAvg, 2);
    let (a, rec_a) = adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
    let (b, rec_b) = adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
    assert_results_identical(&a, &b, "repeat invocation");
    let bounds = |rec: &adasplit::metrics::Recorder| -> Vec<usize> {
        rec.rounds.iter().map(|r| r.bound).collect()
    };
    assert_eq!(bounds(&rec_a), bounds(&rec_b), "same seed, same arm sequence");
    assert_eq!(a.final_bound, *bounds(&rec_a).last().unwrap());
    let switches = bounds(&rec_a).windows(2).filter(|w| w[0] != w[1]).count();
    assert_eq!(a.bound_switches, switches, "switch count matches the trajectory");
    // every recorded bound is one of the clipped default arms {0,1,2}
    for b in bounds(&rec_a) {
        assert!(b <= 2, "recorded bound {b} above the configured ceiling");
    }
}

// ---- event engine: heap total order (no artifacts required) ---------------

#[test]
fn event_heap_total_order_is_insertion_order_invariant() {
    // the determinism keystone (DESIGN.md §11): simultaneous events drain
    // in (kind-rank, id) order no matter how they were pushed — arrivals
    // (ascending client id), then the merge, then eval, then the switch —
    // and earlier times always win over rank
    let t = 2.5;
    let batch = [
        Event::new(t, EventKind::Eval { merge: 3 }),
        Event::new(t, EventKind::ClientFinish { client: 7 }),
        Event::new(t, EventKind::ControllerSwitch { merge: 3 }),
        Event::new(t, EventKind::ClientFinish { client: 1 }),
        Event::new(t, EventKind::ServerMerge { merge: 3 }),
        Event::new(t, EventKind::ClientFinish { client: 4 }),
        Event::new(t + 1.0, EventKind::ClientFinish { client: 0 }),
        Event::new(t - 1.0, EventKind::ControllerSwitch { merge: 2 }),
    ];
    let expect = vec![
        EventKind::ControllerSwitch { merge: 2 },
        EventKind::ClientFinish { client: 1 },
        EventKind::ClientFinish { client: 4 },
        EventKind::ClientFinish { client: 7 },
        EventKind::ServerMerge { merge: 3 },
        EventKind::Eval { merge: 3 },
        EventKind::ControllerSwitch { merge: 3 },
        EventKind::ClientFinish { client: 0 },
    ];
    // deterministic permutations: every rotation, the reversal, and a
    // stride-3 interleave of the same event set
    let n = batch.len();
    let mut insertion_orders: Vec<Vec<Event>> = (0..n)
        .map(|shift| (0..n).map(|i| batch[(i + shift) % n]).collect())
        .collect();
    insertion_orders.push(batch.iter().rev().copied().collect());
    insertion_orders.push((0..n).map(|i| batch[(i * 3) % n]).collect());
    for (which, order) in insertion_orders.iter().enumerate() {
        let mut h = EventHeap::new();
        for &e in order {
            h.push(e);
        }
        let drained: Vec<EventKind> = std::iter::from_fn(|| h.pop()).map(|e| e.kind).collect();
        assert_eq!(drained, expect, "insertion order {which}");
        assert_eq!(h.popped(), n, "insertion order {which}: popped counter");
    }
}

// ---- event engine: degenerate-policy parity (requires `make artifacts`) ---

fn assert_trajectories_identical(
    a: &adasplit::metrics::Recorder,
    b: &adasplit::metrics::Recorder,
    what: &str,
) {
    // every recorded column must agree except `events`, which counts heap
    // traffic and is definitionally 0 under the rounds engine
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what} row count");
    for (i, (x, y)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert_eq!(x.round, y.round, "{what} row {i} round");
        assert_eq!(x.phase, y.phase, "{what} row {i} phase");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what} row {i} loss");
        assert_eq!(x.accuracy_pct, y.accuracy_pct, "{what} row {i} accuracy");
        assert_eq!(x.bandwidth_gb, y.bandwidth_gb, "{what} row {i} bandwidth");
        assert_eq!(x.client_tflops, y.client_tflops, "{what} row {i} client_tflops");
        assert_eq!(x.total_tflops, y.total_tflops, "{what} row {i} total_tflops");
        assert_eq!(x.mask_density, y.mask_density, "{what} row {i} mask_density");
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{what} row {i} sim_time");
        assert_eq!(x.max_staleness, y.max_staleness, "{what} row {i} max_staleness");
        assert_eq!(x.bound, y.bound, "{what} row {i} bound");
        assert_eq!(x.selected, y.selected, "{what} row {i} selected");
        assert_eq!(x.participants, y.participants, "{what} row {i} participants");
    }
}

#[test]
fn event_degenerate_policy_is_bit_identical_to_round_driver_for_every_protocol() {
    // the acceptance criterion: `--engine events --merge-policy round`
    // replays the configured scheduler as events and must reproduce the
    // barrier loop bit-for-bit — result metrics AND the full per-round
    // trajectory — for all seven protocols under each scheduler shape
    // (synchronous, sampled, async-bounded)
    let Some(rt) = runtime() else { return };
    let shapes: [(&str, fn(&mut ExperimentConfig)); 3] = [
        ("sync", |_| {}),
        ("sampled", |c| {
            c.clients = 8;
            c.participation = 0.5;
        }),
        ("async", |c| {
            c.clients = 8;
            c.staleness_bound = Some(2);
            c.client_speeds = SpeedPreset::Stragglers;
            c.straggler_frac = 0.25;
        }),
    ];
    for p in ProtocolKind::ALL {
        for (shape, tweak) in shapes {
            let mut rounds_cfg = quick(p, 2);
            tweak(&mut rounds_cfg);
            let mut events_cfg = rounds_cfg.clone();
            events_cfg.engine = EngineKind::Events;
            let what = format!("{} [{shape}]", p.name());
            let (a, rec_a) =
                adasplit::protocols::run_protocol_recorded(&rt, &rounds_cfg).unwrap();
            let (b, rec_b) =
                adasplit::protocols::run_protocol_recorded(&rt, &events_cfg).unwrap();
            assert_results_identical(&a, &b, &what);
            assert_trajectories_identical(&rec_a, &rec_b, &what);
            assert_eq!(a.scheduler, b.scheduler, "{what}: degenerate keeps the scheduler");
            assert_eq!(a.engine, "rounds", "{what}");
            assert_eq!(b.engine, "events", "{what}");
            assert_eq!(a.events_processed, 0, "{what}: barrier loop pops no events");
            assert!(b.events_processed > 0, "{what}: event loop must count its pops");
        }
    }
}

// ---- event engine: continuous policies (requires `make artifacts`) --------

fn event_quick(
    protocol: ProtocolKind,
    threads: usize,
    policy: MergePolicyKind,
) -> ExperimentConfig {
    let mut cfg = quick(protocol, threads);
    cfg.clients = 8;
    cfg.staleness_bound = Some(2);
    cfg.client_speeds = SpeedPreset::Stragglers;
    cfg.straggler_frac = 0.25;
    cfg.engine = EngineKind::Events;
    cfg.merge_policy = policy;
    cfg
}

#[test]
fn event_driver_is_thread_count_invariant_for_every_protocol() {
    // scheduling decisions (heap drain, policy triggers) run on the
    // driver thread; client work fans out through the same pool + ordered
    // fan-in as the round loop — so the continuous engine must be
    // bit-identical across worker counts for all seven protocols
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let serial = run_protocol(&rt, &event_quick(p, 1, MergePolicyKind::Arrival)).unwrap();
        let par = run_protocol(&rt, &event_quick(p, 4, MergePolicyKind::Arrival)).unwrap();
        assert_results_identical(&serial, &par, p.name());
        assert_eq!(
            serial.events_processed, par.events_processed,
            "{} event count",
            p.name()
        );
    }
}

#[test]
fn event_driver_replay_is_bit_stable_and_seed_sensitive() {
    // seeded replay: the same config drains the same event stream — full
    // trajectory and event count included — while a different seed draws
    // different speeds and must diverge
    let Some(rt) = runtime() else { return };
    let cfg = event_quick(ProtocolKind::FedAvg, 2, MergePolicyKind::Batch(2));
    let (a, rec_a) = adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
    let (b, rec_b) = adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
    assert_results_identical(&a, &b, "replay");
    assert_trajectories_identical(&rec_a, &rec_b, "replay");
    assert_eq!(a.events_processed, b.events_processed, "replayed event count");
    assert_eq!(a.scheduler, "event-driven");
    assert_eq!(a.merge_policy, "batch:2");
    let mut other_seed = cfg.clone();
    other_seed.seed = 9;
    let c = run_protocol(&rt, &other_seed).unwrap();
    assert!(
        a.sim_time != c.sim_time || a.accuracy != c.accuracy,
        "different seed should draw different speeds/schedules"
    );
}

#[test]
fn event_merge_policies_run_end_to_end_with_the_adaptive_controller() {
    // the acceptance criterion: a non-degenerate merge policy (batch and
    // arrival) runs every merge through the adaptive bound controller —
    // staleness stays under the *current* bound, the virtual clock is
    // monotone, and the bound column traces real controller arms
    let Some(rt) = runtime() else { return };
    for policy in [MergePolicyKind::Batch(2), MergePolicyKind::Arrival] {
        let mut cfg = event_quick(ProtocolKind::FedAvg, 2, policy);
        cfg.adaptive_bound = true;
        cfg.adapt_window = 1;
        let (r, rec) = adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
        let what = cfg.merge_policy.id();
        assert!(r.adaptive, "{what}: adaptive mode recorded");
        assert!(r.events_processed > 0, "{what}: events counted");
        assert_eq!(r.engine, "events", "{what}");
        let mut prev = 0.0f64;
        for (i, row) in rec.rounds.iter().enumerate() {
            assert!(!row.participants.is_empty(), "{what} row {i}: empty merge set");
            assert!(
                row.max_staleness <= row.bound.max(2),
                "{what} row {i}: staleness {} above bound {}",
                row.max_staleness,
                row.bound
            );
            assert!(row.sim_time >= prev, "{what} row {i}: clock regressed");
            prev = row.sim_time;
            assert!(row.bound <= 2, "{what} row {i}: arm above the configured ceiling");
            assert!(row.events > 0, "{what} row {i}: event column populated");
        }
        assert_eq!(r.final_bound, rec.rounds.last().unwrap().bound, "{what}");
    }
}

#[test]
fn async_with_sampling_cap_completes_and_reports_the_axis() {
    // async + participation cap + spilling store + lazy data all at once;
    // the recorded sim_time axis must be monotone and the stale decay
    // bounded by the configured staleness bound
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(ProtocolKind::FedAvg, 2);
    cfg.clients = 16;
    cfg.participation = 0.5;
    cfg.staleness_bound = Some(3);
    cfg.client_speeds = SpeedPreset::Stragglers;
    cfg.straggler_frac = 0.25;
    cfg.samples_per_client = 32;
    cfg.test_per_client = 32;
    let (r, rec) = adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
    assert_eq!(r.scheduler, "async-bounded");
    assert!(r.sim_time > 0.0);
    let mut prev = 0.0;
    for round in &rec.rounds {
        assert!(round.sim_time >= prev, "virtual clock monotone");
        prev = round.sim_time;
        assert!(round.max_staleness <= 3, "staleness bound respected");
        assert!(!round.participants.is_empty(), "merge set never empty");
    }
}

// ---- scenario engine: churn, rates, trace replay (requires artifacts) -----

fn tmp_trace(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("adasplit_trace_{tag}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn scenario_inert_recorder_is_bit_identical_to_closed_world_for_every_protocol() {
    // the tentpole gate: a run with no churn, no rate schedule, and only
    // the (inert) trace recorder attached must be bit-identical to the
    // plain event engine — the scenario layer is fully gated, so the
    // closed-world instruction stream is untouched for all seven
    // protocols
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let cfg = event_quick(p, 2, MergePolicyKind::Arrival);
        let (closed, closed_rec) =
            adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
        let path = tmp_trace(&format!("inert_{}", p.name()));
        let mut open_cfg = cfg.clone();
        open_cfg.trace_out = Some(path.clone());
        let (open, open_rec) =
            adasplit::protocols::run_protocol_recorded(&rt, &open_cfg).unwrap();
        assert_results_identical(&closed, &open, p.name());
        assert_trajectories_identical(&closed_rec, &open_rec, p.name());
        assert_eq!(closed.events_processed, open.events_processed, "{}", p.name());
        assert_eq!(closed.scenario, "none", "{}", p.name());
        assert_eq!(open.scenario, "synthetic", "{}", p.name());
        assert_eq!(open.churn_events + open.rate_events, 0, "{}", p.name());
        let trace = std::fs::read_to_string(&path).unwrap();
        assert_eq!(trace.lines().count(), 1, "{}: header-only trace", p.name());
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn scenario_churn_run_keeps_its_contracts_and_is_thread_count_invariant() {
    // open-world acceptance: under seeded Poisson churn the engine's
    // §11 contracts survive — merge sets never empty, staleness under
    // the live bound, monotone virtual clock — and the run stays
    // bit-identical across worker counts
    let Some(rt) = runtime() else { return };
    let mut cfg = event_quick(ProtocolKind::FedAvg, 1, MergePolicyKind::Arrival);
    cfg.churn = Some("join:4,leave:4".parse().unwrap());
    let (serial, rec) = adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
    let mut par_cfg = cfg.clone();
    par_cfg.threads = 4;
    let (par, par_rec) = adasplit::protocols::run_protocol_recorded(&rt, &par_cfg).unwrap();
    assert_results_identical(&serial, &par, "churn");
    assert_trajectories_identical(&rec, &par_rec, "churn");
    assert_eq!(serial.events_processed, par.events_processed, "churn event count");
    assert_eq!(serial.churn_events, par.churn_events, "churn applied count");
    assert_eq!(serial.scenario, "synthetic");
    assert!(
        serial.churn_events > 0,
        "rate-4 processes over the whole run must land at least one event"
    );
    let mut prev = 0.0f64;
    for (i, row) in rec.rounds.iter().enumerate() {
        assert!(!row.participants.is_empty(), "row {i}: empty merge set under churn");
        assert!(
            row.max_staleness <= 2,
            "row {i}: staleness {} above the live bound 2",
            row.max_staleness
        );
        assert!(row.sim_time >= prev, "row {i}: clock regressed under churn");
        prev = row.sim_time;
    }
}

#[test]
fn scenario_rate_schedule_run_is_bit_stable_and_counts_rate_events() {
    // flaky episodes re-time in-flight work through RateChange events;
    // the diurnal curve rides along silently (it is a pure function of
    // config, not an event source). The whole run must replay bit-for-bit
    let Some(rt) = runtime() else { return };
    let mut cfg = event_quick(ProtocolKind::FedAvg, 2, MergePolicyKind::Arrival);
    cfg.rate_schedule = Some("diurnal:6:0.4+flaky:1:4:0.5".parse().unwrap());
    let (a, rec_a) = adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
    let (b, rec_b) = adasplit::protocols::run_protocol_recorded(&rt, &cfg).unwrap();
    assert_results_identical(&a, &b, "rate schedule");
    assert_trajectories_identical(&rec_a, &rec_b, "rate schedule");
    assert_eq!(a.events_processed, b.events_processed, "rate event count");
    assert_eq!(a.churn_events, 0, "no churn configured");
    assert!(
        a.rate_events > 0,
        "rate-1 flaky process over the whole run must land at least one episode tick"
    );
    let mut prev = 0.0f64;
    for (i, row) in rec_a.rounds.iter().enumerate() {
        assert!(row.sim_time >= prev, "row {i}: clock regressed under rate changes");
        prev = row.sim_time;
    }
}

#[test]
fn trace_record_then_replay_is_bit_identical_across_thread_counts() {
    // the replay acceptance criterion: a recorded trace drives the run
    // bit-identically — same results, same full trajectory (the popped-
    // event counter is excluded: synthesis pops fizzled draws the
    // recorded stream never contains) — under `--threads 1` and `4` and
    // across repeat invocations
    let Some(rt) = runtime() else { return };
    let path = tmp_trace("replay");
    let mut rec_cfg = event_quick(ProtocolKind::FedAvg, 2, MergePolicyKind::Arrival);
    rec_cfg.churn = Some("join:2,leave:2".parse().unwrap());
    rec_cfg.rate_schedule = Some("flaky:1:4:0.5".parse().unwrap());
    rec_cfg.trace_out = Some(path.clone());
    let (recorded, recorded_traj) =
        adasplit::protocols::run_protocol_recorded(&rt, &rec_cfg).unwrap();
    assert_eq!(recorded.scenario, "synthetic");
    assert!(
        recorded.churn_events + recorded.rate_events > 0,
        "the recording run must apply at least one scenario event"
    );
    let mut prev_replay: Option<RunResult> = None;
    for threads in [1usize, 4, 4] {
        let mut replay_cfg = event_quick(ProtocolKind::FedAvg, threads, MergePolicyKind::Arrival);
        replay_cfg.trace_in = Some(path.clone());
        let (replayed, replayed_traj) =
            adasplit::protocols::run_protocol_recorded(&rt, &replay_cfg).unwrap();
        assert_results_identical(&recorded, &replayed, &format!("replay @{threads}T"));
        assert_trajectories_identical(
            &recorded_traj,
            &replayed_traj,
            &format!("replay @{threads}T"),
        );
        assert_eq!(replayed.scenario, "replay", "@{threads}T");
        assert_eq!(replayed.churn_events, recorded.churn_events, "@{threads}T");
        assert_eq!(replayed.rate_events, recorded.rate_events, "@{threads}T");
        if let Some(prev) = &prev_replay {
            assert_eq!(
                prev.events_processed, replayed.events_processed,
                "replay pop count is invocation- and thread-invariant"
            );
        }
        prev_replay = Some(replayed);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn trace_bytes_are_protocol_independent_and_replay_across_policies() {
    // the purity argument made testable: the synthesized stream is a
    // pure function of (seed, spec, n) — the protocol never feeds back
    // into it — so two different protocols under the same policy record
    // byte-identical traces (adaptive off: same fixed bound, same
    // timeline). A recorded trace also replays under a *different*
    // continuous policy: the stream is world-changes, not policy state
    let Some(rt) = runtime() else { return };
    let mut paths = Vec::new();
    for (tag, protocol) in [("fedavg", ProtocolKind::FedAvg), ("adasplit", ProtocolKind::AdaSplit)]
    {
        let path = tmp_trace(&format!("xproto_{tag}"));
        let mut cfg = event_quick(protocol, 2, MergePolicyKind::Arrival);
        cfg.churn = Some("join:2,leave:2".parse().unwrap());
        cfg.rate_schedule = Some("flaky:1:4:0.5".parse().unwrap());
        cfg.trace_out = Some(path.clone());
        adasplit::protocols::run_protocol(&rt, &cfg).unwrap();
        paths.push(path);
    }
    let a = std::fs::read_to_string(&paths[0]).unwrap();
    let b = std::fs::read_to_string(&paths[1]).unwrap();
    assert_eq!(a, b, "same config, different protocol: traces must be byte-identical");

    let mut replay_cfg = event_quick(ProtocolKind::FedAvg, 2, MergePolicyKind::Batch(2));
    replay_cfg.trace_in = Some(paths[0].clone());
    let replayed = adasplit::protocols::run_protocol(&rt, &replay_cfg).unwrap();
    assert_eq!(replayed.scenario, "replay", "arrival-recorded trace replays under batch:2");
    for p in &paths {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn scenario_zero_round_exit_reports_the_same_scheduler_as_the_normal_exit() {
    // regression (bugfix satellite): the `rounds == 0` early exit used
    // to report the wrapped scheduler's name unconditionally, so a
    // zero-round smoke run under a continuous policy disagreed with a
    // real run and tripped seed aggregation's scheduler-agreement check.
    // The config layer refuses rounds == 0, so this drives the engines
    // through the validation-free test entry
    let Some(rt) = runtime() else { return };
    let mut cfg = event_quick(ProtocolKind::FedAvg, 1, MergePolicyKind::Arrival);
    cfg.rounds = 0;
    let (r, rec) =
        adasplit::protocols::run_protocol_recorded_unvalidated(&rt, &cfg).unwrap();
    assert_eq!(
        r.scheduler, "event-driven",
        "continuous zero-round exit must present as the event scheduler"
    );
    assert_eq!(r.events_processed, 0, "nothing popped before the early exit");
    assert!(rec.rounds.is_empty(), "no merges, no rows");

    let mut degenerate = event_quick(ProtocolKind::FedAvg, 1, MergePolicyKind::Round);
    degenerate.rounds = 0;
    let (r, _) =
        adasplit::protocols::run_protocol_recorded_unvalidated(&rt, &degenerate).unwrap();
    assert_eq!(
        r.scheduler, "async-bounded",
        "degenerate zero-round exit passes the wrapped scheduler through"
    );
}

#[test]
fn scenario_zero_round_adaptive_baseline_eval_matches_the_round_driver() {
    // verification pin (bugfix satellite): the round driver runs its
    // pre-training baseline eval unconditionally before the loop, so at
    // rounds == 0 with --adaptive-bound both drivers perform exactly one
    // eval and nothing else — their cost meters must agree bit-for-bit
    let Some(rt) = runtime() else { return };
    let mut ev_cfg = event_quick(ProtocolKind::FedAvg, 1, MergePolicyKind::Arrival);
    ev_cfg.rounds = 0;
    ev_cfg.adaptive_bound = true;
    let (ev, ev_rec) =
        adasplit::protocols::run_protocol_recorded_unvalidated(&rt, &ev_cfg).unwrap();
    let mut rd_cfg = ev_cfg.clone();
    rd_cfg.engine = EngineKind::Rounds;
    rd_cfg.merge_policy = MergePolicyKind::Round;
    let (rd, rd_rec) =
        adasplit::protocols::run_protocol_recorded_unvalidated(&rt, &rd_cfg).unwrap();
    assert!(ev_rec.rounds.is_empty() && rd_rec.rounds.is_empty(), "no merges, no rows");
    assert_eq!(
        ev.bandwidth_gb.to_bits(),
        rd.bandwidth_gb.to_bits(),
        "baseline eval bandwidth"
    );
    assert_eq!(
        ev.client_tflops.to_bits(),
        rd.client_tflops.to_bits(),
        "baseline eval client compute"
    );
    assert_eq!(
        ev.total_tflops.to_bits(),
        rd.total_tflops.to_bits(),
        "baseline eval total compute"
    );
    // eval reads `&Env` (value- and cost-neutral), so a zero-round run
    // meters nothing on either driver — parity here is exact zeros, and
    // the real pin is that both adaptive zero-round runs complete with
    // agreeing summaries instead of erroring or diverging
    assert_eq!(ev.accuracy.to_bits(), rd.accuracy.to_bits(), "summary accuracy");
    assert_eq!(ev.rounds, 0);
    assert!(ev.adaptive && rd.adaptive);
}
