//! Serial/parallel equivalence suite for the client-execution engine.
//!
//! The engine's contract (DESIGN.md §5) is that thread count is purely a
//! wall-clock knob: `--threads 4` must produce bit-identical `RunResult`
//! metrics to `--threads 1` for every protocol. The pure-engine tests run
//! everywhere; the protocol sweeps need `make artifacts` and skip loudly
//! otherwise, matching the other integration suites.

use adasplit::config::{ExperimentConfig, ProtocolKind};
use adasplit::engine::{par_indexed, par_slice_mut, ClientPool};
use adasplit::metrics::{AccuracyAccum, CostMeter};
use adasplit::protocols::run_protocol;
use adasplit::runtime::Runtime;

// ---- pure engine determinism (no artifacts required) ----------------------

#[test]
fn float_reduction_is_thread_count_invariant() {
    // per-index work + in-order fan-in: the reduction tree is fixed, so
    // any worker count yields the same bits
    let work = |i: usize| -> anyhow::Result<f64> {
        let mut acc = 0.0f64;
        for k in 1..500 {
            acc += ((i as f64 + 1.0) / k as f64).sqrt().sin();
        }
        Ok(acc)
    };
    let reduce = |parts: &[f64]| parts.iter().sum::<f64>();
    let serial = reduce(&par_indexed(1, 48, work).unwrap());
    for threads in [2, 3, 4, 8] {
        let par = reduce(&par_indexed(threads, 48, work).unwrap());
        assert_eq!(serial.to_bits(), par.to_bits(), "threads={threads}");
    }
}

#[test]
fn slice_mut_is_thread_count_invariant() {
    let run = |threads: usize| -> Vec<f64> {
        let mut states: Vec<f64> = (0..33).map(|i| i as f64 * 0.1).collect();
        par_slice_mut(threads, &mut states, |i, s| {
            for _ in 0..100 {
                *s = (*s + i as f64).sin();
            }
            Ok(())
        })
        .unwrap();
        states
    };
    let serial = run(1);
    for threads in [2, 4, 8] {
        assert_eq!(serial, run(threads), "threads={threads}");
    }
}

#[test]
fn cost_meter_merge_in_id_order_matches_serial_accounting() {
    // serial: interleaved per-client adds; parallel: per-client deltas
    // merged in id order — fields are plain sums, so they agree exactly
    let mut serial = CostMeter::new();
    for i in 0..6usize {
        serial.add_client_flops(1e9 * (i + 1) as f64);
        serial.add_up(1000 * (i + 1));
        serial.add_down(500 * (i + 1));
    }
    let deltas: Vec<CostMeter> = (0..6usize)
        .map(|i| {
            let mut d = CostMeter::new();
            d.add_client_flops(1e9 * (i + 1) as f64);
            d.add_up(1000 * (i + 1));
            d.add_down(500 * (i + 1));
            d
        })
        .collect();
    let mut merged = CostMeter::new();
    for d in &deltas {
        merged.merge(d);
    }
    assert_eq!(serial.client_flops, merged.client_flops);
    assert_eq!(serial.up_bytes, merged.up_bytes);
    assert_eq!(serial.down_bytes, merged.down_bytes);
    assert_eq!(serial.bandwidth_gb(), merged.bandwidth_gb());
}

#[test]
fn accuracy_merge_in_id_order_matches_serial_eval() {
    let batches: &[(usize, f64, f64)] =
        &[(0, 8.0, 10.0), (0, 3.0, 6.0), (1, 5.0, 10.0), (2, 2.0, 4.0)];
    let mut serial = AccuracyAccum::new(3);
    for &(i, c, t) in batches {
        serial.add(i, c, t);
    }
    let mut merged = AccuracyAccum::new(3);
    for client in 0..3usize {
        let mut part = AccuracyAccum::new(3);
        for &(i, c, t) in batches.iter().filter(|(i, _, _)| *i == client) {
            part.add(i, c, t);
        }
        merged.merge(&part);
    }
    assert_eq!(serial.accuracy_pct(), merged.accuracy_pct());
    assert_eq!(serial.per_client_pct(), merged.per_client_pct());
    assert_eq!(serial.mean_client_pct(), merged.mean_client_pct());
}

#[test]
fn pool_is_usable_concurrently_with_shared_state() {
    let data: Vec<u64> = (0..1000).collect();
    let sums = ClientPool::new(4)
        .run(10, |i| Ok(data.iter().skip(i).step_by(10).sum::<u64>()))
        .unwrap();
    assert_eq!(sums.iter().sum::<u64>(), 1000 * 999 / 2);
}

// ---- full-protocol equivalence (requires `make artifacts`) ----------------

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load("artifacts").expect("runtime loads"))
}

fn quick(protocol: ProtocolKind, threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        protocol,
        rounds: 3,
        samples_per_client: 64,
        test_per_client: 32,
        // one local + two global rounds, so AdaSplit's orchestrated
        // server path is exercised too
        kappa: 0.34,
        threads,
        ..ExperimentConfig::default()
    }
}

#[test]
fn every_protocol_is_thread_count_invariant() {
    let Some(rt) = runtime() else { return };
    for p in ProtocolKind::ALL {
        let serial = run_protocol(&rt, &quick(p, 1)).unwrap();
        let par = run_protocol(&rt, &quick(p, 4)).unwrap();
        assert_eq!(serial.accuracy, par.accuracy, "{} accuracy", p.name());
        assert_eq!(
            serial.best_accuracy,
            par.best_accuracy,
            "{} best_accuracy",
            p.name()
        );
        assert_eq!(serial.bandwidth_gb, par.bandwidth_gb, "{} bandwidth", p.name());
        assert_eq!(
            serial.client_tflops,
            par.client_tflops,
            "{} client_tflops",
            p.name()
        );
        assert_eq!(serial.total_tflops, par.total_tflops, "{} total_tflops", p.name());
        assert_eq!(serial.c3_score, par.c3_score, "{} c3", p.name());
        assert_eq!(serial.mask_density, par.mask_density, "{} mask_density", p.name());
    }
}

#[test]
fn adasplit_server_grad_ablation_is_thread_count_invariant() {
    // the stale-gradient path routes per-client tensors through the
    // fan-out; make sure it stays deterministic too
    let Some(rt) = runtime() else { return };
    let mut serial_cfg = quick(ProtocolKind::AdaSplit, 1);
    serial_cfg.server_grad_to_client = true;
    let mut par_cfg = quick(ProtocolKind::AdaSplit, 4);
    par_cfg.server_grad_to_client = true;
    let serial = run_protocol(&rt, &serial_cfg).unwrap();
    let par = run_protocol(&rt, &par_cfg).unwrap();
    assert_eq!(serial.accuracy, par.accuracy);
    assert_eq!(serial.bandwidth_gb, par.bandwidth_gb);
    assert_eq!(serial.c3_score, par.c3_score);
}
