//! Loom model of the ClientPool dispatch protocol (DESIGN.md §13).
//!
//! Compiled ONLY under `RUSTFLAGS="--cfg loom"` (the `#![cfg(loom)]`
//! below makes this file empty otherwise, so plain `cargo test -q`
//! never needs the loom crate). The CI loom job adds loom as a
//! `[target.'cfg(loom)']` dependency and runs:
//!
//! ```sh
//! cargo add --target 'cfg(loom)' loom@0.7
//! LOOM_MAX_PREEMPTIONS=3 RUSTFLAGS="--cfg loom" \
//!     cargo test --release --test loom_pool
//! ```
//!
//! What the models check, across *every* interleaving loom can reach
//! within the preemption bound:
//! * the fan-out/fan-in handshake — job channel, shared-receiver mutex,
//!   `DoneGuard` send-on-drop, atomic claim index — delivers every slot
//!   exactly once and in index order;
//! * disjoint `&mut` hand-out through `SlicePtr` never loses a write
//!   (the data-race half of that argument is TSan/Miri's job; loom
//!   checks the protocol orderings that make it true);
//! * a failing task trips fail-fast such that the lowest-index error is
//!   reported no matter which worker observed it first;
//! * pool reuse (a second `run` on live workers) and `Drop` (channel
//!   close -> worker wake -> join) stay deadlock-free.
//!
//! Pools here use 2 threads (1 spawned worker + the caller): loom caps
//! models at 4 threads, and one worker is already enough to exercise
//! every cross-thread edge in the protocol.

#![cfg(loom)]

use adasplit::engine::ClientPool;

#[test]
fn run_returns_every_slot_in_order() {
    loom::model(|| {
        let pool = ClientPool::new(2);
        let out = pool.run(3, |i| Ok(i * 10)).unwrap();
        assert_eq!(out, vec![0, 10, 20]);
        // `pool` drops here: channel close must wake and join the worker
        // in every interleaving, or loom reports the leaked thread.
    });
}

#[test]
fn run_mut_writes_every_disjoint_slot() {
    loom::model(|| {
        let pool = ClientPool::new(2);
        let mut xs = [1u32, 2, 3];
        let out = pool.run_mut(&mut xs, |i, x| {
            *x += 10 * (i as u32 + 1);
            Ok(*x)
        });
        assert_eq!(out.unwrap(), vec![11, 22, 33]);
        assert_eq!(xs, [11, 22, 33]);
    });
}

#[test]
fn lowest_index_error_wins_in_every_interleaving() {
    loom::model(|| {
        let pool = ClientPool::new(2);
        let r = pool.run(3, |i| {
            if i == 1 {
                Err(anyhow::anyhow!("boom {i}"))
            } else {
                Ok(i)
            }
        });
        // Index 0 is always claimed (ascending) before index 1 and
        // succeeds, so whatever happens to index 2 — claimed and done,
        // or skipped by fail-fast — the reported error is index 1's.
        assert_eq!(r.unwrap_err().to_string(), "boom 1");
    });
}

#[test]
fn pool_reuse_keeps_the_protocol_sound_across_runs() {
    loom::model(|| {
        let pool = ClientPool::new(2);
        assert_eq!(pool.run(2, |i| Ok(i)).unwrap(), vec![0, 1]);
        // Second run reuses the parked worker: re-dispatch over the same
        // channel + a fresh done-channel must not deadlock or cross wires.
        assert_eq!(pool.run(2, |i| Ok(i + 1)).unwrap(), vec![1, 2]);
    });
}
