"""L2: AdaSplit compute graphs in JAX.

Defines the shared conv backbone (LeNet-style, adapted for 32x32x3 inputs),
its client/server split at every client fraction mu, and one complete
train/eval step per protocol variant. Every step is a pure function
``(state, batch, hyper) -> (state', metrics)`` with fwd + bwd + Adam inside,
so the Rust coordinator (L3) only moves flat f32 buffers.

Parameter updates route through the masked-Adam Pallas kernel
(kernels/masked_adam.py); the client objective routes through the NT-Xent
Pallas kernel (kernels/ntxent.py). This module is lowered once by aot.py and
never imported at runtime.

Naming discipline matters: states are plain nested dicts with stable keys,
because aot.py derives the Rust-side tensor names from the pytree paths.
"""

import jax
import jax.numpy as jnp

from compile.kernels.masked_adam import adam_tree
from compile.kernels.ntxent import ntxent_loss

# --------------------------------------------------------------------------
# Architecture spec (mirrored by rust/src/model/spec.rs — keep in sync)
# --------------------------------------------------------------------------

IMG = 32                     # input images are IMG x IMG x 3
CONV_CHANNELS = [16, 32, 64]  # conv1..conv3 output channels
FC1 = 128                    # fc1 width
PROJ_DIM = 64                # NT-Xent projection head output dim
BATCH = 32                   # static training/eval batch size
TAU = 0.07                   # NT-Xent temperature (paper §3.1)
LR = 1e-3                    # Adam lr, client and server (paper §4.4)
# The mask optimizer runs hotter than the model optimizer: with Adam the
# L1 pull on a CE-irrelevant mask entry is ~lr per step regardless of
# lambda's magnitude, so mask sparsity develops on a timescale of 1/lr
# steps. 0.02 puts that within this repo's (reduced-scale) runs; lambda
# still controls the CE-vs-sparsity competition per eq. 8.
MASK_LR = 2e-2
MASK_THRESH = 0.01           # |m| > thresh ==> parameter active (binarized)

BLOCKS = ["conv1", "conv2", "conv3", "fc1", "fc2"]
N_SPLITS = 4  # client may own blocks[:k] for k in 1..4 (mu = 0.2..0.8)


def act_shape(k):
    """Split-activation shape for a client owning the first k blocks."""
    if k <= 3:
        side = IMG // (2 ** k)
        return (BATCH, side, side, CONV_CHANNELS[k - 1])
    return (BATCH, FC1)


def act_feature_dim(k):
    """Feature dimension seen by the projection head (GAP over space)."""
    return CONV_CHANNELS[k - 1] if k <= 3 else FC1


# --------------------------------------------------------------------------
# Initialization
# --------------------------------------------------------------------------

def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def init_backbone(key, num_classes):
    """He-init of all five blocks. Returns {block: {w, b}}."""
    ks = jax.random.split(key, 5)
    p = {}
    cin = 3
    for i, cout in enumerate(CONV_CHANNELS):
        p[f"conv{i+1}"] = {
            "w": _he(ks[i], (3, 3, cin, cout), 3 * 3 * cin),
            "b": jnp.zeros((cout,), jnp.float32),
        }
        cin = cout
    flat = (IMG // 8) ** 2 * CONV_CHANNELS[-1]
    p["fc1"] = {"w": _he(ks[3], (flat, FC1), flat),
                "b": jnp.zeros((FC1,), jnp.float32)}
    p["fc2"] = {"w": _he(ks[4], (FC1, num_classes), FC1),
                "b": jnp.zeros((num_classes,), jnp.float32)}
    return p


def init_proj(key, k):
    d = act_feature_dim(k)
    return {"w": _he(key, (d, PROJ_DIM), d),
            "b": jnp.zeros((PROJ_DIM,), jnp.float32)}


def zeros_like_tree(t):
    return jax.tree_util.tree_map(jnp.zeros_like, t)


def ones_like_tree(t):
    return jax.tree_util.tree_map(jnp.ones_like, t)


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def _conv_block(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["b"])
    return jax.lax.reduce_window(
        y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def apply_blocks(params, names, x):
    """Run ``x`` through the listed blocks; handles the conv->fc flatten."""
    for name in names:
        if name.startswith("conv"):
            x = _conv_block(params[name], x)
        elif name == "fc1":
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            x = jax.nn.relu(x @ params[name]["w"] + params[name]["b"])
        else:  # fc2: logits, no activation
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            x = x @ params[name]["w"] + params[name]["b"]
    return x


def client_apply(pc, k, x):
    return apply_blocks(pc, BLOCKS[:k], x)


def server_apply(ps, k, a):
    return apply_blocks(ps, BLOCKS[k:], a)


def proj_apply(pp, a):
    """GAP (conv acts) or identity (fc acts) -> dense -> L2-normalize."""
    feat = a.mean(axis=(1, 2)) if a.ndim == 4 else a
    q = feat @ pp["w"] + pp["b"]
    return q / (jnp.linalg.norm(q, axis=1, keepdims=True) + 1e-8)


def _ce(logits, y):
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(y.astype(jnp.int32), logits.shape[-1],
                            dtype=logits.dtype)
    return -jnp.sum(onehot * logp, axis=-1)


def _correct(logits, y):
    return (jnp.argmax(logits, axis=-1) == y.astype(jnp.int32)).astype(jnp.float32)


# --------------------------------------------------------------------------
# State constructors (layouts consumed by aot.py + Rust via the manifest)
# --------------------------------------------------------------------------

def init_client_state(seed, k):
    """AdaSplit client: split blocks + projection head + Adam + step."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    kb, kp = jax.random.split(key)
    pc = {n: v for n, v in init_backbone(kb, 1).items() if n in BLOCKS[:k]}
    proj = init_proj(kp, k)
    return {"pc": pc, "proj": proj,
            "mc": zeros_like_tree(pc), "vc": zeros_like_tree(pc),
            "mp": zeros_like_tree(proj), "vp": zeros_like_tree(proj),
            "t": jnp.zeros((), jnp.float32)}


def init_server_state(seed, k, num_classes):
    """AdaSplit server: server blocks + per-client mask + Adam for both."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    ps = {n: v for n, v in init_backbone(key, num_classes).items()
          if n in BLOCKS[k:]}
    mask = ones_like_tree(ps)
    return {"ps": ps, "mask": mask,
            "ms": zeros_like_tree(ps), "vs": zeros_like_tree(ps),
            "mm": zeros_like_tree(mask), "vm": zeros_like_tree(mask),
            "t": jnp.zeros((), jnp.float32)}


def init_sl_client_state(seed, k):
    """Classic SL client: split blocks + Adam (no projection head)."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    pc = {n: v for n, v in init_backbone(key, 1).items() if n in BLOCKS[:k]}
    return {"pc": pc, "m": zeros_like_tree(pc), "v": zeros_like_tree(pc),
            "t": jnp.zeros((), jnp.float32)}


def init_sl_server_state(seed, k, num_classes):
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    ps = {n: v for n, v in init_backbone(key, num_classes).items()
          if n in BLOCKS[k:]}
    return {"ps": ps, "m": zeros_like_tree(ps), "v": zeros_like_tree(ps),
            "t": jnp.zeros((), jnp.float32)}


def init_fl_state(seed, num_classes):
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    p = init_backbone(key, num_classes)
    return {"p": p, "m": zeros_like_tree(p), "v": zeros_like_tree(p),
            "t": jnp.zeros((), jnp.float32)}


# --------------------------------------------------------------------------
# AdaSplit steps
# --------------------------------------------------------------------------

def client_step(state, x, y, beta, grad_a, use_grad, k):
    """One client-local iteration (both phases).

    Objective (paper §3.1 + §6.4):
      L = L_client(NT-Xent on H(a)) + beta * ||a||_1
          + use_grad * <a, grad_a>           (Table-5 row-2 ablation only)

    The linear <a, stop_grad(grad_a)> term injects the server gradient via
    the chain rule without a separate bwd artifact. Returns the split
    activations (stop-gradient) for the global-phase transmission.
    """
    def loss_fn(pc, proj):
        a = client_apply(pc, k, x)
        q = proj_apply(proj, a)
        l_ntx = ntxent_loss(q, y, TAU)
        # raw L1 per sample (paper §6.4): per-activation gradient = beta/B,
        # so the published beta range (1e-7 .. 1e-1) spans "no effect" to
        # "payload collapse"
        l_act = beta * jnp.sum(jnp.abs(a)) / a.shape[0]
        l_inj = use_grad * jnp.sum(a * jax.lax.stop_gradient(grad_a))
        return l_ntx + l_act + l_inj, (a, l_ntx)

    (grads_pc, grads_pp), (a, l_ntx) = jax.grad(
        loss_fn, argnums=(0, 1), has_aux=True)(state["pc"], state["proj"])
    t = state["t"] + 1.0
    pc, mc, vc = adam_tree(state["pc"], grads_pc, state["mc"], state["vc"],
                           t, LR)
    proj, mp, vp = adam_tree(state["proj"], grads_pp, state["mp"],
                             state["vp"], t, LR)
    new_state = {"pc": pc, "proj": proj, "mc": mc, "vc": vc,
                 "mp": mp, "vp": vp, "t": t}
    return {"state": new_state, "loss": l_ntx,
            "acts": jax.lax.stop_gradient(a)}


def client_fwd(pc, x, k):
    """Inference/eval forward through the client blocks."""
    return {"acts": client_apply(pc, k, x)}


def server_step(state, a, y, lam, k):
    """One AdaSplit server iteration for one client (eq. 7 + eq. 8).

    Forward uses the soft mask (p_eff = ps * mask); the parameter update is
    gated by the binarized mask |m| > MASK_THRESH via the masked-Adam
    kernel; the mask itself receives grad(CE) + lam * d||m||_1.
    Also emits grad_a for the Table-5 server-gradient ablation (ignored by
    the default protocol) and the mean active-mask density for logging.
    """
    def loss_fn(ps, mask, acts):
        p_eff = jax.tree_util.tree_map(lambda p, m: p * m, ps, mask)
        logits = server_apply(p_eff, k, acts)
        ce = jnp.mean(_ce(logits, y))
        # raw L1 (paper eq. 8: omega is the unnormalized L1 norm)
        reg = lam * sum(jnp.sum(jnp.abs(m))
                        for m in jax.tree_util.tree_leaves(mask))
        return ce + reg, (logits, ce)

    (gps, gmask, ga), (logits, ce) = jax.grad(
        loss_fn, argnums=(0, 1, 2), has_aux=True)(
        state["ps"], state["mask"], a)
    gate = jax.tree_util.tree_map(
        lambda m: (jnp.abs(m) > MASK_THRESH).astype(jnp.float32),
        state["mask"])
    t = state["t"] + 1.0
    ps, ms, vs = adam_tree(state["ps"], gps, state["ms"], state["vs"], t, LR,
                           gates=gate)
    mask, mm, vm = adam_tree(state["mask"], gmask, state["mm"], state["vm"],
                             t, MASK_LR)
    # ISTA-style projection: masks live in [0, 1]. Without it Adam + L1
    # oscillates dead entries around 0 (the binarized gate flickers); with
    # it they park at exactly 0 until a CE gradient resurrects them.
    mask = jax.tree_util.tree_map(lambda m: jnp.clip(m, 0.0, 1.0), mask)
    new_state = {"ps": ps, "mask": mask, "ms": ms, "vs": vs,
                 "mm": mm, "vm": vm, "t": t}
    nparam = sum(x.size for x in jax.tree_util.tree_leaves(gate))
    density = sum(jnp.sum(g)
                  for g in jax.tree_util.tree_leaves(gate)) / nparam
    return {"state": new_state, "loss": ce,
            "correct": jnp.sum(_correct(logits, y)),
            "grad_a": ga, "mask_density": density}


def server_eval(ps, mask, a, y, valid, k):
    """Per-client inference with the *binarized* mask (M^s * m_i)."""
    m_bin = jax.tree_util.tree_map(
        lambda m: (jnp.abs(m) > MASK_THRESH).astype(jnp.float32), mask)
    p_eff = jax.tree_util.tree_map(lambda p, m: p * m, ps, m_bin)
    logits = server_apply(p_eff, k, a)
    return {"correct": jnp.sum(_correct(logits, y) * valid),
            "loss_sum": jnp.sum(_ce(logits, y) * valid)}


# --------------------------------------------------------------------------
# Classic split learning (SL-basic / SplitFed) steps
# --------------------------------------------------------------------------

def sl_server_step(state, a, y, k):
    """Server half of one SL iteration: train server, emit grad_a."""
    def loss_fn(ps, acts):
        logits = server_apply(ps, k, acts)
        ce = jnp.mean(_ce(logits, y))
        return ce, (logits, ce)

    (gps, ga), (logits, ce) = jax.grad(
        loss_fn, argnums=(0, 1), has_aux=True)(state["ps"], a)
    t = state["t"] + 1.0
    ps, m, v = adam_tree(state["ps"], gps, state["m"], state["v"], t, LR)
    return {"state": {"ps": ps, "m": m, "v": v, "t": t},
            "loss": ce,
            "correct": jnp.sum(_correct(logits, y)),
            "grad_a": ga}


def sl_server_eval(ps, a, y, valid, k):
    logits = server_apply(ps, k, a)
    return {"correct": jnp.sum(_correct(logits, y) * valid),
            "loss_sum": jnp.sum(_ce(logits, y) * valid)}


def client_bwd(state, x, grad_a, k):
    """Client half of one SL iteration: pull grad_a through the client."""
    def loss_fn(pc):
        a = client_apply(pc, k, x)
        return jnp.sum(a * jax.lax.stop_gradient(grad_a))

    grads = jax.grad(loss_fn)(state["pc"])
    t = state["t"] + 1.0
    pc, m, v = adam_tree(state["pc"], grads, state["m"], state["v"], t, LR)
    return {"state": {"pc": pc, "m": m, "v": v, "t": t}}


# --------------------------------------------------------------------------
# Federated learning step (FedAvg / FedProx / Scaffold share one artifact)
# --------------------------------------------------------------------------

def fl_step(state, pg, c, ci, prox_mu, x, y):
    """One local FL iteration on the full model.

    grad' = grad(CE) + prox_mu * (p - pg) + (c - ci)
    FedAvg: prox_mu = 0, c = ci = 0.  FedProx: prox_mu > 0.
    Scaffold: c/ci control variates (maintained by the Rust coordinator).
    FedNova reuses the FedAvg step; normalization happens at aggregation.
    """
    def loss_fn(p):
        logits = apply_blocks(p, BLOCKS, x)
        ce = jnp.mean(_ce(logits, y))
        return ce, (logits, ce)

    grads, (logits, ce) = jax.grad(loss_fn, has_aux=True)(state["p"])
    grads = jax.tree_util.tree_map(
        lambda g, pp, pgg, cc, cii: g + prox_mu * (pp - pgg) + (cc - cii),
        grads, state["p"], pg, c, ci)
    t = state["t"] + 1.0
    p, m, v = adam_tree(state["p"], grads, state["m"], state["v"], t, LR)
    return {"state": {"p": p, "m": m, "v": v, "t": t},
            "loss": ce,
            "correct": jnp.sum(_correct(logits, y))}


def fl_eval(p, x, y, valid):
    logits = apply_blocks(p, BLOCKS, x)
    return {"correct": jnp.sum(_correct(logits, y) * valid),
            "loss_sum": jnp.sum(_ce(logits, y) * valid)}
