"""AOT lowering driver: JAX -> HLO text + manifest.json.

Runs ONCE at build time (`make artifacts`). Lowers every train/eval/init
step in model.py to HLO *text* (NOT a serialized HloModuleProto: jax >= 0.5
emits 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
parser reassigns ids — see /opt/xla-example/README.md) and records, per
artifact, the exact flattened argument/output order in a JSON manifest the
Rust runtime uses to marshal its flat f32 buffers.

Every artifact function takes a single dict argument and returns a dict, so
tensor names are the pytree paths — deterministic (sorted dict keys) and
identical between jax's flattening and the manifest.

Usage: python -m compile.aot --out ../artifacts [--only REGEX]
"""

import argparse
import json
import os
import re

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

F32 = jnp.float32


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


def path_str(path):
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def flatten_named(tree):
    """[(dotted_name, shape, dtype_str)] in jax flattening order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        out.append({"name": path_str(path),
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype)})
    return out


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def state_spec(init_fn):
    """Shape-only evaluation of an init function."""
    return jax.eval_shape(init_fn, spec(()))


# --------------------------------------------------------------------------
# Artifact registry
# --------------------------------------------------------------------------

def build_registry():
    """name -> (fn(args_dict) -> dict, example_args_dict)."""
    B = M.BATCH
    x_spec = spec((B, M.IMG, M.IMG, 3))
    y_spec = spec((B,))
    v_spec = spec((B,))
    s_spec = spec(())

    reg = {}

    def add(name, fn, args):
        assert name not in reg, name
        reg[name] = (fn, args)

    # (tag, num_classes, k, full): full => also SL + FL + grad-ablation
    configs = [("c10", 10, 1, True), ("c50", 50, 1, True),
               ("c10", 10, 2, False), ("c10", 10, 3, False),
               ("c10", 10, 4, False)]

    for tag, nc, k, full in configs:
        pre = f"{tag}_mu{k}"
        a_spec = spec(M.act_shape(k))

        cst = state_spec(lambda s, k=k: M.init_client_state(s, k))
        sst = state_spec(lambda s, k=k, nc=nc: M.init_server_state(s, k, nc))

        add(f"{pre}_init_client",
            lambda a, k=k: {"state": M.init_client_state(a["seed"], k)},
            {"seed": s_spec})
        add(f"{pre}_init_server",
            lambda a, k=k, nc=nc: {"state": M.init_server_state(a["seed"], k, nc)},
            {"seed": s_spec})

        add(f"{pre}_client_step",
            lambda a, k=k: M.client_step(a["state"], a["x"], a["y"],
                                         a["beta"], a["grad_a"],
                                         a["use_grad"], k),
            {"state": cst, "x": x_spec, "y": y_spec, "beta": s_spec,
             "grad_a": a_spec, "use_grad": s_spec})
        add(f"{pre}_client_fwd",
            lambda a, k=k: M.client_fwd(a["pc"], a["x"], k),
            {"pc": cst["pc"], "x": x_spec})
        add(f"{pre}_server_step",
            lambda a, k=k: M.server_step(a["state"], a["a"], a["y"],
                                         a["lam"], k),
            {"state": sst, "a": a_spec, "y": y_spec, "lam": s_spec})
        add(f"{pre}_server_eval",
            lambda a, k=k: M.server_eval(a["ps"], a["mask"], a["a"], a["y"],
                                         a["valid"], k),
            {"ps": sst["ps"], "mask": sst["mask"], "a": a_spec,
             "y": y_spec, "valid": v_spec})

        if full:
            scst = state_spec(lambda s, k=k: M.init_sl_client_state(s, k))
            ssst = state_spec(
                lambda s, k=k, nc=nc: M.init_sl_server_state(s, k, nc))
            add(f"{pre}_init_sl_client",
                lambda a, k=k: {"state": M.init_sl_client_state(a["seed"], k)},
                {"seed": s_spec})
            add(f"{pre}_init_sl_server",
                lambda a, k=k, nc=nc:
                    {"state": M.init_sl_server_state(a["seed"], k, nc)},
                {"seed": s_spec})
            add(f"{pre}_sl_server_step",
                lambda a, k=k: M.sl_server_step(a["state"], a["a"], a["y"], k),
                {"state": ssst, "a": a_spec, "y": y_spec})
            add(f"{pre}_sl_server_eval",
                lambda a, k=k: M.sl_server_eval(a["ps"], a["a"], a["y"],
                                                a["valid"], k),
                {"ps": ssst["ps"], "a": a_spec, "y": y_spec, "valid": v_spec})
            add(f"{pre}_client_bwd",
                lambda a, k=k: M.client_bwd(a["state"], a["x"], a["grad_a"], k),
                {"state": scst, "x": x_spec, "grad_a": a_spec})

    for tag, nc in [("c10", 10), ("c50", 50)]:
        fst = state_spec(lambda s, nc=nc: M.init_fl_state(s, nc))
        add(f"{tag}_init_fl",
            lambda a, nc=nc: {"state": M.init_fl_state(a["seed"], nc)},
            {"seed": s_spec})
        add(f"{tag}_fl_step",
            lambda a: M.fl_step(a["state"], a["pg"], a["c"], a["ci"],
                                a["prox_mu"], a["x"], a["y"]),
            {"state": fst, "pg": fst["p"], "c": fst["p"], "ci": fst["p"],
             "prox_mu": s_spec, "x": x_spec, "y": y_spec})
        add(f"{tag}_fl_eval",
            lambda a: M.fl_eval(a["p"], a["x"], a["y"], a["valid"]),
            {"p": fst["p"], "x": x_spec, "y": y_spec, "valid": v_spec})

    return reg


def config_meta():
    """Shape/count metadata mirrored into the manifest for L3 accounting."""
    def count(tree):
        return int(sum(x.size for x in jax.tree_util.tree_leaves(tree)))

    meta = {}
    for nc, tag in [(10, "c10"), (50, "c50")]:
        full = jax.eval_shape(lambda s, nc=nc: M.init_fl_state(s, nc),
                              spec(()))["p"]
        for k in range(1, 5):
            if tag == "c50" and k > 1:
                continue
            pc = {n: v for n, v in full.items() if n in M.BLOCKS[:k]}
            ps = {n: v for n, v in full.items() if n in M.BLOCKS[k:]}
            proj = jax.eval_shape(lambda s, k=k: M.init_proj(s, k),
                                  jax.ShapeDtypeStruct((2,), jnp.uint32))
            meta[f"{tag}_mu{k}"] = {
                "num_classes": nc,
                "k": k,
                "act_shape": list(M.act_shape(k)),
                "client_params": count(pc),
                "server_params": count(ps),
                "proj_params": count(proj),
                "full_params": count(full),
            }
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex filter on artifact names (dev aid)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    reg = build_registry()
    manifest = {
        "batch": M.BATCH,
        "img": M.IMG,
        "proj_dim": M.PROJ_DIM,
        "lr": M.LR,
        "tau": M.TAU,
        "mask_thresh": M.MASK_THRESH,
        "conv_channels": M.CONV_CHANNELS,
        "fc1": M.FC1,
        "configs": config_meta(),
        "artifacts": {},
    }

    only = re.compile(args.only) if args.only else None
    for name, (fn, ex_args) in sorted(reg.items()):
        if only and not only.search(name):
            continue
        lowered = jax.jit(fn).lower(ex_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, ex_args)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": flatten_named(ex_args),
            "outputs": flatten_named(out_shapes),
        }
        print(f"  {name}: {len(text)//1024} KiB, "
              f"{len(manifest['artifacts'][name]['inputs'])} in / "
              f"{len(manifest['artifacts'][name]['outputs'])} out")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
