"""L1 Pallas kernel: supervised NT-Xent contrastive loss (AdaSplit eq. 5).

This is the client-side gradient source that lets AdaSplit eliminate the
dependence on server gradients. Given L2-normalized embeddings ``q`` of a
batch and integer labels ``y`` (carried as f32), the loss is

    L = (1/|P|) * sum_i sum_{p in P_i} [ logsumexp_{j != i} (q_i.q_j / tau)
                                         - q_i.q_p / tau ]

where ``P_i`` is the set of in-batch indices sharing ``y_i`` (excluding i)
and |P| the total number of positive pairs (the paper sums; we normalize by
the pair count so the learning rate is batch-composition independent).

Both the forward loss and the analytic backward (dL/dq) are Pallas kernels
wired together with ``jax.custom_vjp`` — interpret mode only (CPU PJRT
cannot execute Mosaic custom-calls; see DESIGN.md §Hardware-Adaptation).

TPU mapping (estimated in DESIGN.md §Perf): the B x B similarity matrix is
a single MXU matmul per tile; with B = 32 and D = 64 the whole problem fits
one VMEM block (q: 8 KiB, S: 4 KiB), so BlockSpec is the identity map and
the kernel is memory-trivial — the win is fusing sim-matrix + masked
log-softmax + pair reduction into one kernel launch instead of five HLO ops.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _fwd_kernel(q_ref, y_ref, tau_ref, loss_ref):
    """loss_ref[0, 0] <- pair-normalized supervised NT-Xent loss."""
    q = q_ref[...]  # [B, D]
    y = y_ref[...]  # [B, 1]
    tau = tau_ref[0, 0]
    b = q.shape[0]

    sim = jnp.dot(q, q.T) / tau  # [B, B]
    eye = jnp.eye(b, dtype=sim.dtype)
    sim = sim + eye * NEG_INF  # exclude self-similarity everywhere

    # Row-wise logsumexp over j != i (self already masked to -inf).
    row_max = jnp.max(sim, axis=1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(sim - row_max), axis=1, keepdims=True)) + row_max

    pos = (y == y.T).astype(sim.dtype) * (1.0 - eye)  # [B, B] positive-pair mask
    npairs = jnp.sum(pos)
    per_pair = pos * (lse - sim)  # (lse_i - S_ip) on positive entries
    loss_ref[0, 0] = jnp.sum(per_pair) / jnp.maximum(npairs, 1.0)


def _bwd_kernel(q_ref, y_ref, tau_ref, dq_ref):
    """dq_ref <- dL/dq, analytically.

    With S = q q^T / tau, n_i = |P_i|, softmax p_ij over j != i:
        dL/dS_ij = (n_i * p_ij - [j in P_i]) / |P|      (j != i)
        dL/dq    = (G + G^T) q / tau                    (G = dL/dS)
    """
    q = q_ref[...]
    y = y_ref[...]
    tau = tau_ref[0, 0]
    b = q.shape[0]

    sim = jnp.dot(q, q.T) / tau
    eye = jnp.eye(b, dtype=sim.dtype)
    sim = sim + eye * NEG_INF

    row_max = jnp.max(sim, axis=1, keepdims=True)
    ex = jnp.exp(sim - row_max)
    p = ex / jnp.sum(ex, axis=1, keepdims=True)  # softmax rows, 0 on diag

    pos = (y == y.T).astype(sim.dtype) * (1.0 - eye)
    n_i = jnp.sum(pos, axis=1, keepdims=True)  # [B, 1]
    npairs = jnp.maximum(jnp.sum(pos), 1.0)

    g = (n_i * p - pos) / npairs  # [B, B]
    dq_ref[...] = jnp.dot(g + g.T, q) / tau


def _pallas_fwd(q, y, tau):
    loss = pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), q.dtype),
        interpret=True,
    )(q, y.reshape(-1, 1), jnp.full((1, 1), tau, q.dtype))
    return loss[0, 0]


def _pallas_bwd(q, y, tau):
    return pl.pallas_call(
        _bwd_kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(q, y.reshape(-1, 1), jnp.full((1, 1), tau, q.dtype))


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def ntxent_loss(q, y, tau=0.07):
    """Supervised NT-Xent loss over a batch of L2-normalized embeddings.

    Args:
      q:   [B, D] f32, assumed L2-normalized rows.
      y:   [B] f32 integer-valued class labels.
      tau: temperature (paper: 0.07). Static.

    Returns: scalar loss, 0.0 when the batch contains no positive pair.
    """
    return _pallas_fwd(q, y, tau)


def _vjp_fwd(q, y, tau):
    return _pallas_fwd(q, y, tau), (q, y)


def _vjp_bwd(tau, res, ct):
    q, y = res
    return (ct * _pallas_bwd(q, y, tau), jnp.zeros_like(y))


ntxent_loss.defvjp(_vjp_fwd, _vjp_bwd)
