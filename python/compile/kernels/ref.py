"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is written with the most naive jnp formulation possible —
no shared subexpressions with the kernels beyond the math itself — so a
bug in a kernel cannot be mirrored by the oracle.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def ntxent_loss_ref(q, y, tau=0.07):
    """Naive supervised NT-Xent (AdaSplit eq. 5), normalized by the number
    of positive pairs."""
    b = q.shape[0]
    sim = (q @ q.T) / tau
    mask_self = jnp.eye(b, dtype=bool)
    sim = jnp.where(mask_self, NEG_INF, sim)
    # logsumexp over j != i
    lse = jax.nn.logsumexp(sim, axis=1)
    pos = (y[:, None] == y[None, :]) & (~mask_self)
    per_pair = jnp.where(pos, lse[:, None] - sim, 0.0)
    npairs = jnp.sum(pos.astype(q.dtype))
    return jnp.sum(per_pair) / jnp.maximum(npairs, 1.0)


def ntxent_grad_ref(q, y, tau=0.07):
    """Autodiff gradient of the oracle loss."""
    return jax.grad(lambda qq: ntxent_loss_ref(qq, y, tau))(q)


def adam_ref(p, g, m, v, t, lr, gate=None,
             beta1=0.9, beta2=0.999, eps=1e-8):
    """Textbook (gated) Adam on a single tensor."""
    t = jnp.maximum(t, 1.0)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1 ** t)
    v_hat = v_new / (1.0 - beta2 ** t)
    step = lr * m_hat / (jnp.sqrt(v_hat) + eps)
    if gate is not None:
        step = step * gate
    return p - step, m_new, v_new
