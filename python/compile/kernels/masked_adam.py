"""L1 Pallas kernel: gated (masked) Adam parameter update (AdaSplit eq. 7).

AdaSplit's collaboration mechanism constrains each client to update only a
sparse partition of the server model:

    M^s <- M^s - alpha * m_hat_i * adam(grad)

where ``m_hat_i`` is the client's binarized mask. The same kernel with
``gate = 1`` is the plain Adam update used for every other parameter tree in
the system (client models, projection heads, masks themselves, FL models) —
so this single kernel is the parameter-update hot path of the entire stack.

The kernel is purely element-wise (VPU work, no MXU): each parameter tensor
is raveled, zero-padded to a multiple of ``CHUNK`` and processed over a 1-D
grid with one VMEM-resident block per program. Bias-corrected step size is
precomputed on the host graph and fed through a (1, 1) block so the kernel
itself has no transcendental ops.

Interpret mode only — see DESIGN.md §Hardware-Adaptation.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size along the flattened parameter axis. Perf note (EXPERIMENTS.md
# §Perf): interpret-mode lowering turns each grid step into an XLA loop
# iteration, so small chunks dominate runtime on CPU: CHUNK=1024 made the
# masked server step ~116 ms; 16384 cut it to 31.5 ms; 65536 to 29.6 ms
# (<6% further — practical roofline). On a real TPU the VMEM footprint at
# 65536 is 6 buffers x 256 KiB = 1.5 MiB — comfortably inside the ~16 MiB
# VMEM budget, and the kernel stays purely element-wise VPU work.
CHUNK = 65536
BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def _adam_kernel(lr_ref, p_ref, g_ref, m_ref, v_ref, gate_ref,
                 po_ref, mo_ref, vo_ref):
    lr_t = lr_ref[0, 0]
    g = g_ref[...]
    m = BETA1 * m_ref[...] + (1.0 - BETA1) * g
    v = BETA2 * v_ref[...] + (1.0 - BETA2) * g * g
    step = lr_t * m / (jnp.sqrt(v) + EPS)
    po_ref[...] = p_ref[...] - gate_ref[...] * step
    mo_ref[...] = m
    vo_ref[...] = v


def _update_flat(p, g, m, v, gate, lr_t):
    """Run the kernel over one raveled, padded [NB, CHUNK] tensor set."""
    nb = p.shape[0]
    blk = pl.BlockSpec((1, CHUNK), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = jax.ShapeDtypeStruct(p.shape, p.dtype)
    return pl.pallas_call(
        _adam_kernel,
        grid=(nb,),
        in_specs=[scalar, blk, blk, blk, blk, blk],
        out_specs=[blk, blk, blk],
        out_shape=(out, out, out),
        interpret=True,
    )(lr_t.reshape(1, 1), p, g, m, v, gate)


def _pad_ravel(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // CHUNK)
    flat = jnp.pad(flat, (0, nb * CHUNK - n))
    return flat.reshape(nb, CHUNK), n


def adam_leaf(p, g, m, v, gate, lr_t):
    """Gated Adam update of a single tensor. ``gate`` is None or same-shape."""
    shape = p.shape
    pf, n = _pad_ravel(p)
    gf, _ = _pad_ravel(g)
    mf, _ = _pad_ravel(m)
    vf, _ = _pad_ravel(v)
    if gate is None:
        gatef = jnp.ones_like(pf)
    else:
        gatef, _ = _pad_ravel(gate)
    po, mo, vo = _update_flat(pf, gf, mf, vf, gatef, lr_t)
    unravel = lambda a: a.reshape(-1)[:n].reshape(shape)
    return unravel(po), unravel(mo), unravel(vo)


def bias_corrected_lr(t, lr):
    """lr * sqrt(1 - b2^t) / (1 - b1^t), computed on the host graph."""
    t = jnp.maximum(t, 1.0)
    return lr * jnp.sqrt(1.0 - BETA2 ** t) / (1.0 - BETA1 ** t)


def adam_tree(params, grads, m, v, t, lr, gates=None):
    """Gated Adam over a pytree. ``t`` is the (already incremented) step.

    Returns (new_params, new_m, new_v). ``gates`` is None (ungated) or a
    pytree of same structure whose leaves multiply the update (eq. 7).
    """
    lr_t = bias_corrected_lr(t, lr)
    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(m)
    leaves_v = treedef.flatten_up_to(v)
    if gates is None:
        leaves_gate = [None] * len(leaves_p)
    else:
        leaves_gate = treedef.flatten_up_to(gates)
    new_p, new_m, new_v = [], [], []
    for p, g, mm, vv, gg in zip(leaves_p, leaves_g, leaves_m, leaves_v, leaves_gate):
        a, b, c = adam_leaf(p, g, mm, vv, gg, lr_t)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    unflatten = jax.tree_util.tree_unflatten
    return unflatten(treedef, new_p), unflatten(treedef, new_m), unflatten(treedef, new_v)
