"""L2 model graph tests: shapes, state layouts, learning signals, and the
semantic invariants the protocols rely on (mask gating, gradient injection,
split equivalence)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M

S0 = jnp.float32(0.0)


def _batch(seed=0, nclass=10):
    x = jax.random.normal(jax.random.PRNGKey(seed), (M.BATCH, M.IMG, M.IMG, 3))
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (M.BATCH,), 0,
                           nclass).astype(jnp.float32)
    return x, y


# ----------------------------------------------------------------------
# Shapes / split consistency
# ----------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 3, 4])
def test_act_shapes(k):
    x, _ = _batch()
    cs = M.init_client_state(S0, k)
    a = M.client_apply(cs["pc"], k, x)
    assert a.shape == M.act_shape(k)


@pytest.mark.parametrize("k", [1, 2, 3, 4])
def test_split_composes_to_full_model(k):
    """client_apply o server_apply == apply_blocks on the full backbone."""
    x, _ = _batch(2)
    p = M.init_backbone(jax.random.PRNGKey(5), 10)
    pc = {n: v for n, v in p.items() if n in M.BLOCKS[:k]}
    ps = {n: v for n, v in p.items() if n in M.BLOCKS[k:]}
    full = M.apply_blocks(p, M.BLOCKS, x)
    split = M.server_apply(ps, k, M.client_apply(pc, k, x))
    assert_allclose(np.asarray(full), np.asarray(split), rtol=1e-5, atol=1e-5)


def test_logit_shapes():
    x, _ = _batch()
    for nc in (10, 50):
        p = M.init_backbone(jax.random.PRNGKey(0), nc)
        assert M.apply_blocks(p, M.BLOCKS, x).shape == (M.BATCH, nc)


def test_proj_normalized():
    x, _ = _batch(3)
    cs = M.init_client_state(S0, 1)
    a = M.client_apply(cs["pc"], 1, x)
    q = M.proj_apply(cs["proj"], a)
    assert_allclose(np.asarray(jnp.linalg.norm(q, axis=1)),
                    np.ones(M.BATCH), rtol=1e-4)


# ----------------------------------------------------------------------
# AdaSplit client step
# ----------------------------------------------------------------------


def test_client_step_trains():
    """Repeated NT-Xent steps on a fixed batch decrease the loss."""
    x, y = _batch(7, nclass=2)
    st = M.init_client_state(S0, 1)
    ga = jnp.zeros(M.act_shape(1))
    step = jax.jit(lambda s: M.client_step(s, x, y, jnp.float32(0.0), ga,
                                           jnp.float32(0.0), 1))
    first = None
    for i in range(20):
        out = step(st)
        st = out["state"]
        if first is None:
            first = float(out["loss"])
    assert float(out["loss"]) < first
    assert float(st["t"]) == 20.0


def test_client_step_grad_injection_changes_update():
    """use_grad=1 with nonzero grad_a must alter the parameter update."""
    x, y = _batch(8)
    st = M.init_client_state(S0, 1)
    ga = jnp.ones(M.act_shape(1)) * 0.1
    o0 = M.client_step(st, x, y, jnp.float32(0.0), ga, jnp.float32(0.0), 1)
    o1 = M.client_step(st, x, y, jnp.float32(0.0), ga, jnp.float32(1.0), 1)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()),
        o0["state"]["pc"], o1["state"]["pc"])
    assert max(jax.tree_util.tree_leaves(d)) > 0
    # loss metric reports the NT-Xent part only, identical in both
    assert float(o0["loss"]) == pytest.approx(float(o1["loss"]), rel=1e-6)


def test_client_step_act_l1_shrinks_activations():
    x, y = _batch(9)
    ga = jnp.zeros(M.act_shape(1))

    def run(beta, n=30):
        st = M.init_client_state(S0, 1)
        step = jax.jit(lambda s: M.client_step(
            s, x, y, jnp.float32(beta), ga, jnp.float32(0.0), 1))
        for _ in range(n):
            out = step(st)
            st = out["state"]
        return float(jnp.mean(jnp.abs(out["acts"])))

    assert run(1.0) < run(0.0)


# ----------------------------------------------------------------------
# AdaSplit server step / masks
# ----------------------------------------------------------------------


def test_server_step_trains_and_masks_sparsify():
    x, y = _batch(11)
    cs = M.init_client_state(S0, 1)
    a = M.client_apply(cs["pc"], 1, x)
    st = M.init_server_state(S0, 1, 10)
    step = jax.jit(lambda s: M.server_step(s, a, y, jnp.float32(1e-2), 1))
    losses, densities = [], []
    for _ in range(30):
        out = step(st)
        st = out["state"]
        losses.append(float(out["loss"]))
        densities.append(float(out["mask_density"]))
    assert losses[-1] < losses[0]
    assert densities[0] == 1.0  # masks start fully dense


def test_server_gate_freezes_masked_params():
    """Parameters whose mask is below threshold must not move (eq. 7)."""
    x, y = _batch(12)
    cs = M.init_client_state(S0, 1)
    a = M.client_apply(cs["pc"], 1, x)
    st = M.init_server_state(S0, 1, 10)
    # kill the mask of fc2.w entirely
    st["mask"]["fc2"]["w"] = jnp.zeros_like(st["mask"]["fc2"]["w"])
    out = M.server_step(st, a, y, jnp.float32(0.0), 1)
    assert_allclose(np.asarray(out["state"]["ps"]["fc2"]["w"]),
                    np.asarray(st["ps"]["fc2"]["w"]))
    # unmasked params still move
    assert float(jnp.abs(out["state"]["ps"]["fc2"]["b"]
                         - st["ps"]["fc2"]["b"]).max()) > 0


def test_server_eval_binarized_mask():
    x, y = _batch(13)
    cs = M.init_client_state(S0, 1)
    a = M.client_apply(cs["pc"], 1, x)
    st = M.init_server_state(S0, 1, 10)
    valid = jnp.ones((M.BATCH,))
    out = M.server_eval(st["ps"], st["mask"], a, y, valid, 1)
    assert 0.0 <= float(out["correct"]) <= M.BATCH
    # zero valid mask => zero counts
    out0 = M.server_eval(st["ps"], st["mask"], a, y, jnp.zeros((M.BATCH,)), 1)
    assert float(out0["correct"]) == 0.0
    assert float(out0["loss_sum"]) == 0.0


# ----------------------------------------------------------------------
# Classic SL steps
# ----------------------------------------------------------------------


def test_sl_roundtrip_trains_both_halves():
    """SL-basic loop: fwd -> server step -> client bwd reduces CE."""
    x, y = _batch(14, nclass=4)
    cst = M.init_sl_client_state(S0, 1)
    sst = M.init_sl_server_state(S0, 1, 10)
    losses = []
    for _ in range(25):
        a = M.client_apply(cst["pc"], 1, x)
        so = M.sl_server_step(sst, a, y, 1)
        sst = so["state"]
        co = M.client_bwd(cst, x, so["grad_a"], 1)
        cst = co["state"]
        losses.append(float(so["loss"]))
    assert losses[-1] < losses[0]
    assert float(cst["t"]) == 25.0


def test_sl_grad_a_matches_autodiff():
    """grad_a from sl_server_step == d CE / d a by direct autodiff."""
    x, y = _batch(15)
    cst = M.init_sl_client_state(S0, 1)
    sst = M.init_sl_server_state(S0, 1, 10)
    a = M.client_apply(cst["pc"], 1, x)
    so = M.sl_server_step(sst, a, y, 1)
    ref = jax.grad(lambda aa: jnp.mean(M._ce(
        M.server_apply(sst["ps"], 1, aa), y)))(a)
    assert_allclose(np.asarray(so["grad_a"]), np.asarray(ref),
                    rtol=1e-4, atol=1e-6)


# ----------------------------------------------------------------------
# FL step
# ----------------------------------------------------------------------


def test_fl_step_trains():
    x, y = _batch(16, nclass=3)
    st = M.init_fl_state(S0, 10)
    zeros = M.zeros_like_tree(st["p"])
    step = jax.jit(lambda s: M.fl_step(s, s["p"], zeros, zeros,
                                       jnp.float32(0.0), x, y))
    losses = []
    for _ in range(25):
        out = step(st)
        st = out["state"]
        losses.append(float(out["loss"]))
    assert losses[-1] < losses[0]


def test_fl_prox_term_pulls_towards_global():
    """With a huge prox coefficient the update direction must oppose
    (p - pg), i.e. parameters move towards the global model."""
    x, y = _batch(17)
    st = M.init_fl_state(S0, 10)
    pg = jax.tree_util.tree_map(lambda p: p - 1.0, st["p"])  # global below p
    zeros = M.zeros_like_tree(st["p"])
    out = M.fl_step(st, pg, zeros, zeros, jnp.float32(1e4), x, y)
    # with mu=1e4 the prox gradient dominates: p must decrease towards pg
    w0 = st["p"]["fc1"]["w"]
    w1 = out["state"]["p"]["fc1"]["w"]
    assert float(jnp.mean(w1 - w0)) < 0


def test_fl_control_variates_shift_gradient():
    x, y = _batch(18)
    st = M.init_fl_state(S0, 10)
    zeros = M.zeros_like_tree(st["p"])
    ones = jax.tree_util.tree_map(lambda p: jnp.ones_like(p), st["p"])
    o0 = M.fl_step(st, st["p"], zeros, zeros, jnp.float32(0.0), x, y)
    o1 = M.fl_step(st, st["p"], ones, zeros, jnp.float32(0.0), x, y)
    d = float(jnp.abs(o0["state"]["p"]["fc2"]["w"]
                      - o1["state"]["p"]["fc2"]["w"]).max())
    assert d > 0


def test_init_determinism_and_seed_sensitivity():
    a = M.init_fl_state(jnp.float32(3.0), 10)
    b = M.init_fl_state(jnp.float32(3.0), 10)
    c = M.init_fl_state(jnp.float32(4.0), 10)
    assert_allclose(np.asarray(a["p"]["conv1"]["w"]),
                    np.asarray(b["p"]["conv1"]["w"]))
    assert float(jnp.abs(a["p"]["conv1"]["w"]
                         - c["p"]["conv1"]["w"]).max()) > 0
