"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

hypothesis sweeps shapes/label structures; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.masked_adam import (CHUNK, adam_leaf, adam_tree,
                                         bias_corrected_lr)
from compile.kernels.ntxent import ntxent_loss
from compile.kernels.ref import adam_ref, ntxent_grad_ref, ntxent_loss_ref

# ----------------------------------------------------------------------
# NT-Xent forward
# ----------------------------------------------------------------------


def _embed(seed, b, d):
    q = jax.random.normal(jax.random.PRNGKey(seed), (b, d), jnp.float32)
    return q / jnp.linalg.norm(q, axis=1, keepdims=True)


@settings(max_examples=25, deadline=None)
@given(b=st.sampled_from([4, 8, 16, 32]),
       d=st.sampled_from([8, 16, 64, 128]),
       nclass=st.integers(1, 10),
       seed=st.integers(0, 2**16))
def test_ntxent_fwd_matches_ref(b, d, nclass, seed):
    q = _embed(seed, b, d)
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0,
                           nclass).astype(jnp.float32)
    assert_allclose(np.asarray(ntxent_loss(q, y)),
                    np.asarray(ntxent_loss_ref(q, y)), rtol=2e-5, atol=2e-6)


@settings(max_examples=15, deadline=None)
@given(b=st.sampled_from([8, 32]), d=st.sampled_from([16, 64]),
       nclass=st.integers(1, 8), seed=st.integers(0, 2**16))
def test_ntxent_grad_matches_ref(b, d, nclass, seed):
    q = _embed(seed, b, d)
    y = jax.random.randint(jax.random.PRNGKey(seed + 7), (b,), 0,
                           nclass).astype(jnp.float32)
    g = jax.grad(lambda qq: ntxent_loss(qq, y))(q)
    assert_allclose(np.asarray(g), np.asarray(ntxent_grad_ref(q, y)),
                    rtol=1e-4, atol=1e-6)


def test_ntxent_no_positive_pairs_is_zero():
    """All-distinct labels => no positive pairs => loss 0, grad 0."""
    q = _embed(3, 8, 16)
    y = jnp.arange(8, dtype=jnp.float32)
    assert float(ntxent_loss(q, y)) == pytest.approx(0.0, abs=1e-6)
    g = jax.grad(lambda qq: ntxent_loss(qq, y))(q)
    assert float(jnp.abs(g).max()) == pytest.approx(0.0, abs=1e-6)


def test_ntxent_all_same_label():
    """One class => every off-diagonal pair is positive; finite loss."""
    q = _embed(4, 16, 32)
    y = jnp.zeros(16, jnp.float32)
    l = float(ntxent_loss(q, y))
    assert np.isfinite(l)
    assert_allclose(l, float(ntxent_loss_ref(q, y)), rtol=2e-5)


def test_ntxent_pulls_positives_together():
    """A gradient step on the loss must increase positive-pair similarity."""
    q = _embed(11, 16, 32)
    y = (jnp.arange(16) % 2).astype(jnp.float32)
    g = jax.grad(lambda qq: ntxent_loss(qq, y))(q)
    q2 = q - 0.1 * g
    q2 = q2 / jnp.linalg.norm(q2, axis=1, keepdims=True)
    assert float(ntxent_loss(q2, y)) < float(ntxent_loss(q, y))


def test_ntxent_permutation_invariant():
    q = _embed(5, 32, 64)
    y = jax.random.randint(jax.random.PRNGKey(9), (32,), 0, 4).astype(
        jnp.float32)
    perm = jax.random.permutation(jax.random.PRNGKey(10), 32)
    assert_allclose(float(ntxent_loss(q, y)),
                    float(ntxent_loss(q[perm], y[perm])), rtol=1e-5)


@pytest.mark.parametrize("tau", [0.05, 0.07, 0.2, 1.0])
def test_ntxent_tau_sweep(tau):
    q = _embed(6, 32, 64)
    y = jax.random.randint(jax.random.PRNGKey(6), (32,), 0, 5).astype(
        jnp.float32)
    assert_allclose(float(ntxent_loss(q, y, tau)),
                    float(ntxent_loss_ref(q, y, tau)), rtol=2e-5)


# ----------------------------------------------------------------------
# Masked Adam
# ----------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(shape=st.sampled_from([(7,), (16,), (CHUNK,), (CHUNK + 3,),
                              (3, 3, 3, 16), (33, 129), (2, CHUNK)]),
       t=st.integers(1, 1000), gated=st.booleans(),
       seed=st.integers(0, 2**16))
def test_adam_leaf_matches_ref(shape, t, gated, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    p = jax.random.normal(ks[0], shape, jnp.float32)
    g = jax.random.normal(ks[1], shape, jnp.float32)
    m = jax.random.normal(ks[2], shape, jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], shape, jnp.float32)) * 0.01
    gate = (jax.random.uniform(ks[4], shape) > 0.5).astype(
        jnp.float32) if gated else None
    tt = jnp.float32(t)
    lr_t = bias_corrected_lr(tt, 1e-3)
    pn, mn, vn = adam_leaf(p, g, m, v, gate, lr_t)
    pr, mr, vr = adam_ref(p, g, m, v, tt, 1e-3, gate)
    assert_allclose(np.asarray(pn), np.asarray(pr), rtol=1e-5, atol=1e-7)
    assert_allclose(np.asarray(mn), np.asarray(mr), rtol=1e-6, atol=1e-8)
    assert_allclose(np.asarray(vn), np.asarray(vr), rtol=1e-6, atol=1e-8)


def test_adam_gate_zero_freezes_params():
    """gate == 0 must leave parameters exactly untouched (eq. 7)."""
    p = jnp.ones((100,))
    g = jnp.full((100,), 3.0)
    zeros = jnp.zeros((100,))
    lr_t = bias_corrected_lr(jnp.float32(1), 1e-3)
    pn, mn, vn = adam_leaf(p, g, zeros, zeros, zeros, lr_t)
    assert_allclose(np.asarray(pn), np.asarray(p))
    # moments still accumulate (the mask gates the *update*, not the stats)
    assert float(jnp.abs(mn).max()) > 0


def test_adam_tree_structure_and_gating():
    tree = {"a": {"w": jnp.ones((5, 5)), "b": jnp.ones((5,))},
            "c": jnp.ones((CHUNK * 2 + 1,))}
    grads = jax.tree_util.tree_map(lambda x: jnp.ones_like(x) * 2.0, tree)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, tree)
    gates = jax.tree_util.tree_map(jnp.zeros_like, tree)
    gates["a"]["w"] = jnp.ones((5, 5))
    p2, m2, v2 = adam_tree(tree, grads, zeros, zeros, jnp.float32(1), 1e-3,
                           gates=gates)
    assert float(jnp.abs(p2["a"]["w"] - tree["a"]["w"]).max()) > 0
    assert_allclose(np.asarray(p2["a"]["b"]), np.asarray(tree["a"]["b"]))
    assert_allclose(np.asarray(p2["c"]), np.asarray(tree["c"]))
    assert jax.tree_util.tree_structure(p2) == jax.tree_util.tree_structure(tree)


def test_adam_descends_quadratic():
    """300 Adam steps on f(p) = ||p||^2 must reach near-zero."""
    p = jnp.full((64,), 5.0)
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    for t in range(1, 301):
        g = 2.0 * p
        lr_t = bias_corrected_lr(jnp.float32(t), 5e-2)
        p, m, v = adam_leaf(p, g, m, v, None, lr_t)
    assert float(jnp.abs(p).max()) < 1.0
