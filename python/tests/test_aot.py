"""AOT manifest / artifact contract tests.

These guard the Python<->Rust interface: every artifact referenced by the
manifest exists, input/output names are unique and ordered, state outputs
mirror state inputs (so Rust can write outputs back over the same buffers),
and the HLO text parses as an entry computation.
"""

import json
import os
import re

import pytest

from compile.aot import build_registry, config_meta

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST),
    reason="artifacts not built (run `make artifacts`)")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_registry_covers_manifest(manifest):
    reg = build_registry()
    assert set(manifest["artifacts"].keys()) == set(reg.keys())


def test_all_artifact_files_exist(manifest):
    for name, art in manifest["artifacts"].items():
        path = os.path.join(ART, art["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100, name


def test_hlo_text_has_entry(manifest):
    for name, art in manifest["artifacts"].items():
        with open(os.path.join(ART, art["file"])) as f:
            head = f.read(4096)
        assert "HloModule" in head, name
        assert "ENTRY" in head or "ENTRY" in open(
            os.path.join(ART, art["file"])).read(), name


def test_input_output_names_unique(manifest):
    for name, art in manifest["artifacts"].items():
        in_names = [i["name"] for i in art["inputs"]]
        out_names = [o["name"] for o in art["outputs"]]
        assert len(set(in_names)) == len(in_names), name
        assert len(set(out_names)) == len(out_names), name


def test_state_round_trip_layout(manifest):
    """Every step artifact's `state.*` outputs exactly mirror its
    `state.*` inputs (same names, shapes, order) — Rust relies on this to
    write outputs back over its TensorStore."""
    for name, art in manifest["artifacts"].items():
        if "_step" not in name and "client_bwd" not in name:
            continue
        sin = [(i["name"], tuple(i["shape"])) for i in art["inputs"]
               if i["name"].startswith("state.")]
        sout = [(o["name"], tuple(o["shape"])) for o in art["outputs"]
                if o["name"].startswith("state.")]
        assert sin == sout, name


def test_parameter_count_in_hlo(manifest):
    """The number of `parameter(i)` declarations in the entry computation
    matches the manifest input count."""
    for name, art in list(manifest["artifacts"].items()):
        text = open(os.path.join(ART, art["file"])).read()
        entry = text[text.index("ENTRY"):]
        params = set(re.findall(r"parameter\((\d+)\)", entry))
        assert len(params) == len(art["inputs"]), name


def test_f32_only(manifest):
    for name, art in manifest["artifacts"].items():
        for t in art["inputs"] + art["outputs"]:
            assert t["dtype"] == "float32", (name, t)


def test_config_meta_counts(manifest):
    meta = config_meta()
    assert manifest["configs"] == json.loads(json.dumps(meta))
    for cfg, m in meta.items():
        assert m["client_params"] + m["server_params"] == m["full_params"], cfg


def test_act_shape_consistency(manifest):
    for cfg, m in manifest["configs"].items():
        k = m["k"]
        art = manifest["artifacts"].get(f"{cfg}_client_step")
        if art is None:
            continue
        acts = [o for o in art["outputs"] if o["name"] == "acts"]
        assert len(acts) == 1
        assert acts[0]["shape"] == m["act_shape"], cfg


def test_init_outputs_match_step_state_inputs(manifest):
    """init_* artifact outputs align exactly with the step's state inputs."""
    pairs = [("c10_mu1_init_client", "c10_mu1_client_step"),
             ("c10_mu1_init_server", "c10_mu1_server_step"),
             ("c50_mu1_init_sl_server", "c50_mu1_sl_server_step"),
             ("c10_init_fl", "c10_fl_step")]
    for init_name, step_name in pairs:
        init = manifest["artifacts"][init_name]
        step = manifest["artifacts"][step_name]
        init_out = [(o["name"], tuple(o["shape"])) for o in init["outputs"]]
        step_state_in = [(i["name"], tuple(i["shape"]))
                         for i in step["inputs"]
                         if i["name"].startswith("state.")]
        assert init_out == step_state_in, init_name
