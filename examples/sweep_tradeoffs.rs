//! Figure-1 trade-off sweeps: accuracy vs bandwidth (varying kappa at
//! fixed compute), accuracy vs client compute (varying mu at fixed
//! bandwidth budget), accuracy vs per-round participation (the third
//! budget axis the pluggable scheduler opens: fewer sampled clients per
//! round = less traffic and less client compute per round), and accuracy
//! vs simulated wall-clock under the bounded-staleness async scheduler
//! (heterogeneous client speeds: a larger staleness bound stops the
//! synchronous barrier from waiting on stragglers every round, trading
//! staleness for virtual time), with the FL/SL baselines as reference
//! points. The staleness axis additionally compares cadence-only
//! staleness against true delayed gradients (`--delayed-gradients`:
//! stale clients train on the model snapshot they actually pulled,
//! DESIGN.md §8) on FedAvg, where the distinction bites — and overlays
//! the adaptive-bound controller (`--adaptive-bound`, DESIGN.md §9),
//! which walks the same frontier online instead of by grid search
//! (`results/fig1_adaptive_bound.csv`). A final sweep drops the round
//! barrier entirely: the discrete-event engine (`--engine events`,
//! DESIGN.md §11) runs AdaSplit under continuous merge policies
//! (merge-on-arrival, batch-of-k, time-window) with the same adaptive
//! bound controller, tracing where barrier-free merging lands on the
//! accuracy/sim-time frontier (`results/fig1_event_merge_policies.csv`).
//! The scenario sweep then opens the world (DESIGN.md §12): seeded churn
//! at increasing intensity plus a diurnal+flaky rate schedule on the
//! merge-on-arrival engine, tracing how much accuracy an open fleet
//! gives up at a given virtual wall-clock
//! (`results/fig1_scenario_churn.csv`).
//!
//! ```bash
//! cargo run --release --example sweep_tradeoffs -- --rounds 10 --samples 256
//! ```

use adasplit::config::{ExperimentConfig, ProtocolKind};
use adasplit::data::DatasetKind;
use adasplit::driver::{SpeedPreset, DEFAULT_BOUND_ARMS};
use adasplit::protocols::{run_protocol, run_protocol_recorded};
use adasplit::report::series::ascii_chart;
use adasplit::report::Series;
use adasplit::runtime::Runtime;
use adasplit::sim::{EngineKind, MergePolicyKind};

fn arg_usize(name: &str, default: usize) -> usize {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let rounds = arg_usize("--rounds", 8);
    let samples = arg_usize("--samples", 192);
    let test = arg_usize("--test-samples", 128);

    let rt = Runtime::load("artifacts")?;
    let base = ExperimentConfig::paper_default(DatasetKind::MixedCifar)
        .with_scale(rounds, samples, test);

    // accuracy vs bandwidth: sweep kappa (less local phase => more traffic)
    let mut bw_curve = Series::new("AdaSplit (kappa sweep)", "bandwidth_gb");
    for kappa in [0.3, 0.45, 0.6, 0.75, 0.9] {
        let r = run_protocol(&rt, &base.clone().with_kappa(kappa))?;
        println!(
            "kappa={kappa:<4} acc={:.2}% bw={:.4}GB cC={:.4}T",
            r.best_accuracy, r.bandwidth_gb, r.client_tflops
        );
        bw_curve.push(r.bandwidth_gb, r.best_accuracy);
    }

    // accuracy vs client compute: sweep mu (client model size)
    let mut c_curve = Series::new("AdaSplit (mu sweep)", "client_tflops");
    for mu in [0.2, 0.4, 0.6, 0.8] {
        let r = run_protocol(&rt, &base.clone().with_mu(mu))?;
        println!(
            "mu={mu:<4}    acc={:.2}% bw={:.4}GB cC={:.4}T",
            r.best_accuracy, r.bandwidth_gb, r.client_tflops
        );
        c_curve.push(r.client_tflops, r.best_accuracy);
    }

    // accuracy vs participation: sweep the per-round sampling fraction
    // (more clients for the scheduler to sample from than the default 5)
    let part_base = base.clone().with_clients(10);
    let mut p_curve = Series::new("AdaSplit (participation sweep)", "bandwidth_gb");
    for participation in [0.3, 0.5, 0.7, 1.0] {
        let r = run_protocol(&rt, &part_base.clone().with_participation(participation))?;
        println!(
            "p={participation:<4}   acc={:.2}% bw={:.4}GB cC={:.4}T sampled/round={:.1}",
            r.best_accuracy, r.bandwidth_gb, r.client_tflops, r.sampled_clients_per_round
        );
        p_curve.push(r.bandwidth_gb, r.best_accuracy);
    }

    // accuracy vs simulated wall-clock: sweep the staleness bound under
    // heterogeneous client speeds (stragglers preset). s = 0 is the
    // synchronous barrier — every round waits for the slowest device; a
    // larger bound lets fast clients merge while stragglers catch up,
    // shrinking the virtual wall-clock at some accuracy cost.
    let async_base = base
        .clone()
        .with_clients(10)
        .with_client_speeds(SpeedPreset::Stragglers)
        .with_straggler_frac(0.2);
    let mut s_curve = Series::new("AdaSplit (staleness sweep)", "sim_time");
    let mut worst_fixed_c3 = f64::INFINITY;
    println!("\nstaleness sweep (stragglers speeds, accuracy vs simulated wall-clock):");
    // NB: under non-uniform speeds the meter reports *link-time-weighted*
    // bandwidth (a straggler's bytes cost 10x link-time, DESIGN.md §7) —
    // not raw GB, and not comparable to the uniform-speed curves above
    println!("{:<8} {:>8} {:>10} {:>14}", "bound", "acc%", "simT", "bw (link-wt)");
    // the grid is exactly the controller's candidate set clipped to the
    // ceiling below, so the adaptive curve picks among the bounds this
    // sweep measures and the end-of-run C3 floor compares like with like
    let bound_ceiling = 4usize;
    let mut fixed_bounds: Vec<usize> =
        DEFAULT_BOUND_ARMS.iter().map(|&c| c.min(bound_ceiling)).collect();
    fixed_bounds.dedup();
    for bound in fixed_bounds {
        let r = run_protocol(&rt, &async_base.clone().with_staleness_bound(Some(bound)))?;
        println!(
            "s={bound:<6} {:>8.2} {:>10.2} {:>14.4}",
            r.best_accuracy, r.sim_time, r.bandwidth_gb
        );
        s_curve.push(r.sim_time, r.best_accuracy);
        worst_fixed_c3 = worst_fixed_c3.min(r.c3_score);
    }

    // the third curve: the UCB bound controller picks among the same
    // fixed bounds online (the default arm set clipped to the same
    // ceiling), one window per quarter of the run. The per-window
    // (sim_time, accuracy) checkpoints trace how the controller moves
    // along the frontier the fixed-bound grid search mapped offline.
    let adaptive_cfg = async_base
        .clone()
        .with_staleness_bound(Some(bound_ceiling))
        .with_adaptive_bound(true)
        .with_adapt_window((rounds / 4).max(1));
    let (ar, arec) = run_protocol_recorded(&rt, &adaptive_cfg)?;
    let mut a_curve = Series::new("AdaSplit (adaptive bound)", "sim_time");
    let w = adaptive_cfg.adapt_window;
    println!("\nadaptive bound (UCB over the clipped default arms, window {w} rounds):");
    println!("{:<10} {:>6} {:>8} {:>10}", "round", "bound", "acc%", "simT");
    for stat in &arec.rounds {
        if (stat.round + 1) % w == 0 || stat.round + 1 == arec.rounds.len() {
            println!(
                "r={:<8} {:>6} {:>8.2} {:>10.2}",
                stat.round, stat.bound, stat.accuracy_pct, stat.sim_time
            );
            a_curve.push(stat.sim_time, stat.accuracy_pct);
        }
    }
    println!(
        "adaptive: final bound {}, {} switch(es), c3={:.3} (worst fixed arm c3={:.3})",
        ar.final_bound, ar.bound_switches, ar.c3_score, worst_fixed_c3
    );

    // event-engine merge-policy sweep (DESIGN.md §11): the discrete-event
    // driver drops the round barrier and lets the server merge on its own
    // trigger — on every arrival, once K updates are pending, or on a
    // fixed sim-time cadence. Each policy runs under the same adaptive
    // bound controller and speed model as the adaptive curve above, so
    // the frontier points are directly comparable: barrier-free merging
    // vs barrier-driven merging, both steering the same staleness knob.
    let mut e_curve = Series::new("AdaSplit events (merge-policy sweep)", "sim_time");
    println!("\nevent-engine merge-policy sweep (adaptive bound, stragglers speeds):");
    println!(
        "{:<12} {:>8} {:>10} {:>7} {:>8}",
        "policy", "acc%", "simT", "bound", "events"
    );
    for policy in [
        MergePolicyKind::Arrival,
        MergePolicyKind::Batch(2),
        MergePolicyKind::Batch(4),
        MergePolicyKind::Window(2.0),
    ] {
        let cfg = adaptive_cfg
            .clone()
            .with_engine(EngineKind::Events)
            .with_merge_policy(policy);
        let r = run_protocol(&rt, &cfg)?;
        println!(
            "{:<12} {:>8.2} {:>10.2} {:>7} {:>8}",
            policy.id(),
            r.best_accuracy,
            r.sim_time,
            r.final_bound,
            r.events_processed
        );
        e_curve.push(r.sim_time, r.best_accuracy);
    }

    // scenario sweep (DESIGN.md §12): open the world on the arrival-merge
    // event engine — seeded Poisson churn at increasing intensity, then a
    // combined diurnal + flaky-link rate schedule on top of the strongest
    // churn point. Adaptive control stays off so every point shares one
    // fixed bound and the accuracy deltas are attributable to the
    // scenario alone.
    let scenario_base = async_base
        .clone()
        .with_staleness_bound(Some(bound_ceiling))
        .with_engine(EngineKind::Events)
        .with_merge_policy(MergePolicyKind::Arrival);
    let mut sc_curve = Series::new("AdaSplit events (scenario sweep)", "sim_time");
    println!("\nscenario sweep (arrival merges, fixed bound, open world):");
    println!(
        "{:<26} {:>8} {:>10} {:>7} {:>6}",
        "scenario", "acc%", "simT", "churn", "rate"
    );
    let churn_grid = ["join:0.05,leave:0.05", "join:0.15,leave:0.15", "join:0.3,leave:0.3"];
    for (label, churn, rates) in [
        ("closed world", None, None),
        ("churn 0.05", Some(churn_grid[0]), None),
        ("churn 0.15", Some(churn_grid[1]), None),
        ("churn 0.30", Some(churn_grid[2]), None),
        (
            "churn 0.30 + rates",
            Some(churn_grid[2]),
            Some("diurnal:8:0.4+flaky:0.1:4:1.5"),
        ),
    ] {
        let cfg = scenario_base
            .clone()
            .with_churn(churn.map(|s| s.parse()).transpose()?)
            .with_rate_schedule(rates.map(|s| s.parse()).transpose()?);
        let r = run_protocol(&rt, &cfg)?;
        println!(
            "{label:<26} {:>8.2} {:>10.2} {:>7} {:>6}",
            r.best_accuracy, r.sim_time, r.churn_events, r.rate_events
        );
        sc_curve.push(r.sim_time, r.best_accuracy);
    }

    // cadence-only vs true delayed gradients (--delayed-gradients):
    // per-client model versioning hands a client merging s rounds stale
    // the global snapshot it actually pulled s rounds ago. FedAvg is the
    // protocol where the distinction bites — its clients download the
    // global every round; AdaSplit clients never download server weights,
    // so the AdaSplit curve above is cadence-only by construction
    // (DESIGN.md §8).
    let fl_async = async_base.clone().with_protocol(ProtocolKind::FedAvg);
    let mut fd_cadence = Series::new("FedAvg (cadence-only)", "sim_time");
    let mut fd_delay = Series::new("FedAvg (true-delay)", "sim_time");
    println!("\nFedAvg staleness sweep: cadence-only vs true delayed gradients:");
    println!(
        "{:<8} {:>14} {:>14} {:>10} {:>10}",
        "bound", "cadence acc%", "delayed acc%", "simT", "max stale"
    );
    for bound in [0usize, 1, 2, 4] {
        let base_cfg = fl_async.clone().with_staleness_bound(Some(bound));
        let c = run_protocol(&rt, &base_cfg)?;
        let d = run_protocol(&rt, &base_cfg.clone().with_delayed_gradients(true))?;
        println!(
            "s={bound:<6} {:>14.2} {:>14.2} {:>10.2} {:>10}",
            c.best_accuracy, d.best_accuracy, d.sim_time, d.max_staleness
        );
        fd_cadence.push(c.sim_time, c.best_accuracy);
        fd_delay.push(d.sim_time, d.best_accuracy);
    }

    // baseline reference points
    let mut base_bw = Series::new("baselines", "bandwidth_gb");
    let mut base_c = Series::new("baselines", "client_tflops");
    for p in [ProtocolKind::FedAvg, ProtocolKind::SlBasic, ProtocolKind::SplitFed] {
        let r = run_protocol(&rt, &base.clone().with_protocol(p))?;
        println!(
            "{:<9} acc={:.2}% bw={:.4}GB cC={:.4}T",
            r.protocol, r.best_accuracy, r.bandwidth_gb, r.client_tflops
        );
        base_bw.push(r.bandwidth_gb, r.best_accuracy);
        base_c.push(r.client_tflops, r.best_accuracy);
    }

    println!("\n=== accuracy vs bandwidth (Fig. 1 left) ===");
    print!("{}", ascii_chart(&[bw_curve.clone(), base_bw.clone()], 60, 14));
    println!("\n=== accuracy vs client compute (Fig. 1 right) ===");
    print!("{}", ascii_chart(&[c_curve.clone(), base_c.clone()], 60, 14));
    println!("\n=== accuracy vs bandwidth under client sampling ===");
    print!("{}", ascii_chart(&[p_curve.clone()], 60, 14));
    println!("\n=== accuracy vs simulated wall-clock (staleness sweep) ===");
    print!("{}", ascii_chart(&[s_curve.clone(), a_curve.clone()], 60, 14));
    println!("\n=== accuracy vs simulated wall-clock (event-engine merge policies) ===");
    print!("{}", ascii_chart(&[a_curve.clone(), e_curve.clone()], 60, 14));
    println!("\n=== accuracy vs simulated wall-clock (open-world scenarios) ===");
    print!("{}", ascii_chart(&[sc_curve.clone()], 60, 14));
    println!("\n=== FedAvg staleness: cadence-only vs true delayed gradients ===");
    print!("{}", ascii_chart(&[fd_cadence.clone(), fd_delay.clone()], 60, 14));

    std::fs::create_dir_all("results")?;
    std::fs::write("results/fig1_bandwidth_curve.csv", bw_curve.to_csv())?;
    std::fs::write("results/fig1_compute_curve.csv", c_curve.to_csv())?;
    std::fs::write("results/fig1_participation_curve.csv", p_curve.to_csv())?;
    std::fs::write("results/fig1_staleness_curve.csv", s_curve.to_csv())?;
    std::fs::write("results/fig1_adaptive_bound.csv", a_curve.to_csv())?;
    std::fs::write("results/fig1_event_merge_policies.csv", e_curve.to_csv())?;
    std::fs::write("results/fig1_scenario_churn.csv", sc_curve.to_csv())?;
    std::fs::write("results/fig1_staleness_cadence_fl.csv", fd_cadence.to_csv())?;
    std::fs::write("results/fig1_staleness_true_delay_fl.csv", fd_delay.to_csv())?;
    std::fs::write("results/fig1_baseline_bw.csv", base_bw.to_csv())?;
    std::fs::write("results/fig1_baseline_compute.csv", base_c.to_csv())?;
    println!("\ncurves -> results/fig1_*.csv");

    // sanity floor, checked after every curve is on disk so a controller
    // regression never destroys the sweep's other outputs: picking among
    // the arms online must not end up below the worst fixed arm on the
    // same seed
    anyhow::ensure!(
        ar.c3_score >= worst_fixed_c3,
        "adaptive controller scored c3={:.4}, below the worst fixed bound's {:.4}",
        ar.c3_score,
        worst_fixed_c3
    );
    Ok(())
}
