//! Compare all seven protocols on one dataset — the shape of the paper's
//! Tables 1 and 2 at configurable scale.
//!
//! ```bash
//! cargo run --release --example compare_protocols -- --dataset mixed-noniid
//! cargo run --release --example compare_protocols -- --rounds 20 --samples 512 --seeds 3
//! cargo run --release --example compare_protocols -- --clients 20 --participation 0.25
//! ```

use adasplit::config::{ExperimentConfig, ProtocolKind};
use adasplit::data::DatasetKind;
use adasplit::protocols::run_seeds;
use adasplit::report::ResultTable;
use adasplit::runtime::Runtime;

fn arg(name: &str) -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == name).and_then(|i| argv.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let dataset: DatasetKind = arg("--dataset")
        .unwrap_or_else(|| "mixed-cifar".into())
        .parse()?;
    let rounds: usize = arg("--rounds").and_then(|v| v.parse().ok()).unwrap_or(8);
    let samples: usize = arg("--samples").and_then(|v| v.parse().ok()).unwrap_or(192);
    let test: usize = arg("--test-samples").and_then(|v| v.parse().ok()).unwrap_or(128);
    let n_seeds: usize = arg("--seeds").and_then(|v| v.parse().ok()).unwrap_or(1);
    let clients: usize = arg("--clients").and_then(|v| v.parse().ok()).unwrap_or(5);
    let participation: f64 = arg("--participation").and_then(|v| v.parse().ok()).unwrap_or(1.0);
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();

    let rt = Runtime::load("artifacts")?;
    let mut table = ResultTable::new(format!(
        "{} — {} rounds, {} samples/client, {} seed(s), participation {:.2}",
        dataset.name(),
        rounds,
        samples,
        n_seeds,
        participation
    ));

    for p in ProtocolKind::ALL {
        let cfg = ExperimentConfig::paper_default(dataset)
            .with_protocol(p)
            .with_scale(rounds, samples, test)
            .with_clients(clients)
            .with_participation(participation);
        let t0 = std::time::Instant::now();
        let (result, std) = run_seeds(&rt, &cfg, &seeds)?;
        println!(
            "{:<9} acc {:>6.2}±{:<5.2} bw {:>7.3}GB cC {:>6.3}T c3 {:.3}  [{:.0}s]",
            p.name(),
            result.best_accuracy,
            std,
            result.bandwidth_gb,
            result.client_tflops,
            result.c3_score,
            t0.elapsed().as_secs_f64()
        );
        table.add(p.name(), &result, std);
    }

    table.recompute_c3_measured(8.0);
    println!("\n{}", table.render());
    println!("(C3 uses measured budgets: B_max/C_max = worst baseline, paper §4.4)");
    println!("best by C3-Score: {}", table.best_by_c3().unwrap_or("-"));
    std::fs::create_dir_all("results")?;
    let path = format!("results/compare_{}_r{rounds}.csv", dataset.tag());
    table.write_csv(&path)?;
    println!("table -> {path}");
    Ok(())
}
