//! End-to-end validation driver (DESIGN.md §3, EXPERIMENTS.md §E2E).
//!
//! Trains AdaSplit on the Mixed-NonIID protocol — 5 clients, 5 synthetic
//! dataset families, a 50-class global head — for a full multi-round run
//! (hundreds of optimizer steps across clients + server), logging the loss
//! curve and per-round accuracy, and writes `results/e2e_adasplit_*.csv`
//! + `.json`. This proves all three layers compose: Pallas kernels inside
//! jax steps, AOT HLO artifacts, and the Rust coordinator on top.
//!
//! ```bash
//! cargo run --release --example train_adasplit            # default scale
//! cargo run --release --example train_adasplit -- --rounds 20 --samples 512
//! ```

use adasplit::config::ExperimentConfig;
use adasplit::data::DatasetKind;
use adasplit::protocols::run_protocol_recorded;
use adasplit::runtime::Runtime;

fn arg_usize(name: &str, default: usize) -> usize {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let rounds = arg_usize("--rounds", 12);
    let samples = arg_usize("--samples", 320);
    let test = arg_usize("--test-samples", 160);

    let rt = Runtime::load("artifacts")?;
    let cfg = ExperimentConfig::paper_default(DatasetKind::MixedNonIid)
        .with_scale(rounds, samples, test);
    println!(
        "E2E: AdaSplit on Mixed-NonIID, {} clients x {} samples, {} rounds \
         (kappa={}, eta={}, lambda={:e})",
        cfg.clients, cfg.samples_per_client, cfg.rounds, cfg.kappa, cfg.eta, cfg.lambda
    );

    let t0 = std::time::Instant::now();
    let (result, recorder) = run_protocol_recorded(&rt, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n round | phase  | client loss | accuracy | bandwidth | mask density");
    for r in &recorder.rounds {
        println!(
            " {:>5} | {:<6} | {:>11.4} | {:>7.2}% | {:>6.3} GB | {:>7.3}",
            r.round, r.phase, r.train_loss, r.accuracy_pct, r.bandwidth_gb, r.mask_density
        );
    }

    // loss must decrease over the local phase; accuracy must beat chance
    let first_loss = recorder.rounds.first().map(|r| r.train_loss).unwrap_or(0.0);
    let last_loss = recorder.rounds.last().map(|r| r.train_loss).unwrap_or(0.0);
    let chance = 100.0 / cfg.dataset.num_classes() as f64;
    println!(
        "\nloss {first_loss:.4} -> {last_loss:.4}; accuracy {:.2}% (chance {chance:.1}%)",
        result.best_accuracy
    );
    println!(
        "bandwidth {:.4} GB | client compute {:.4} TFLOPs (total {:.4}) | C3 {:.3} | {wall:.1}s",
        result.bandwidth_gb, result.client_tflops, result.total_tflops, result.c3_score
    );
    println!(
        "scheduler: participation {:.2}, {:.1} clients/round through the round driver",
        result.participation, result.sampled_clients_per_round
    );

    std::fs::create_dir_all("results")?;
    let stem = format!("results/e2e_adasplit_r{rounds}_s{samples}");
    recorder.write_csv(format!("{stem}.csv"))?;
    recorder.write_json(format!("{stem}.json"))?;
    std::fs::write(format!("{stem}_result.json"), result.to_json().to_string_pretty())?;
    println!("curves -> {stem}.csv / .json");

    if result.best_accuracy < chance * 1.5 {
        anyhow::bail!(
            "E2E FAILED: accuracy {:.2}% did not clear 1.5x chance",
            result.best_accuracy
        );
    }
    println!("E2E OK");
    Ok(())
}
