//! Quickstart: the smallest end-to-end AdaSplit run.
//!
//! ```bash
//! make artifacts                      # once: AOT-lower the jax graphs
//! cargo run --release --example quickstart
//! ```
//!
//! Runs 4 rounds (2 local + 2 global) of AdaSplit on the Mixed-CIFAR
//! protocol with 5 clients and prints the paper's headline metrics. Pass
//! `--trace` to watch the UCB orchestrator pick clients per iteration.

use adasplit::config::ExperimentConfig;
use adasplit::protocols::run_protocol_recorded;
use adasplit::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let trace = std::env::args().any(|a| a == "--trace");

    let rt = Runtime::load("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    let mut cfg = ExperimentConfig::quick_test();
    cfg.rounds = 4;
    cfg.kappa = 0.5; // 2 local rounds, then the server joins
    cfg.trace = trace;

    let (result, recorder) = run_protocol_recorded(&rt, &cfg)?;

    for r in &recorder.rounds {
        println!(
            "round {:>2} [{:>6}] client-loss={:.4} acc={:.2}% bw={:.4}GB participants={} selected={:?}",
            r.round,
            r.phase,
            r.train_loss,
            r.accuracy_pct,
            r.bandwidth_gb,
            r.participants.len(),
            r.selected
        );
    }
    if trace {
        println!("-- orchestrator trace --");
        for line in recorder.trace.iter().take(30) {
            println!("  {line}");
        }
    }
    println!(
        "\nAdaSplit: accuracy {:.2}%, bandwidth {:.4} GB, client compute {:.4} TFLOPs \
         (total {:.4}), C3-Score {:.3}",
        result.best_accuracy,
        result.bandwidth_gb,
        result.client_tflops,
        result.total_tflops,
        result.c3_score
    );
    println!("server mask density: {:.3} (1.0 = dense)", result.mask_density);
    Ok(())
}
