//! Runtime micro-benchmarks — the perf-pass instrument (EXPERIMENTS.md
//! §Perf). Times each hot-path artifact execution (client step, server
//! step, FL step, evals), host<->literal marshalling, data synthesis, and
//! the pure-Rust coordinator machinery (UCB, aggregation), so coordinator
//! overhead can be read off directly against the XLA step time.

use adasplit::config::ExperimentConfig;
use adasplit::data::{build_partition, DatasetKind, Rng, SyntheticDataset};
use adasplit::engine::ClientPool;
use adasplit::orchestrator::UcbOrchestrator;
use adasplit::protocols::Env;
use adasplit::runtime::{Runtime, Tensor, TensorStore};
use adasplit::util::bench::{bench, quick_mode};

fn main() -> anyhow::Result<()> {
    let iters = if quick_mode() { 5 } else { 20 };
    let rt = Runtime::load("artifacts")?;
    let cfg = ExperimentConfig::quick_test();
    let clients = build_partition(DatasetKind::MixedCifar, 5, 64, 32, 1.0, 0)?;
    let env = Env::new(&rt, &cfg, clients);

    let mut stats = Vec::new();

    // ---- artifact executions (the intended hot path) ----------------------
    let client_step = env.art_split("client_step")?;
    let server_step = env.art_split("server_step")?;
    let client_fwd = env.art_split("client_fwd")?;
    let server_eval = env.art_split("server_eval")?;
    let fl_step = env.art_ds("fl_step")?;

    let cstate = env.init_state("c10_mu1_init_client", 1.0)?;
    let sstate = env.init_state("c10_mu1_init_server", 2.0)?;
    let fstate = env.init_state("c10_init_fl", 3.0)?;
    let b = &env.train_batches(0, 0)[0];
    let zero_ga = Tensor::zeros(&rt.manifest.config("c10_mu1")?.act_shape);
    let beta = Tensor::scalar(0.0);
    let zero = Tensor::scalar(0.0);
    let lam = Tensor::scalar(1e-5);

    let acts = client_step
        .call(
            &[&cstate],
            &[("x", &b.x), ("y", &b.y), ("beta", &beta), ("grad_a", &zero_ga),
              ("use_grad", &zero)],
        )?
        .take("acts")?;

    stats.push(bench("artifact: client_step (B=32)", 2, iters, || {
        client_step
            .call(
                &[&cstate],
                &[("x", &b.x), ("y", &b.y), ("beta", &beta), ("grad_a", &zero_ga),
                  ("use_grad", &zero)],
            )
            .unwrap();
    }));
    stats.push(bench("artifact: server_step (masked)", 2, iters, || {
        server_step
            .call(&[&sstate], &[("a", &acts), ("y", &b.y), ("lam", &lam)])
            .unwrap();
    }));
    stats.push(bench("artifact: fl_step (full model)", 2, iters, || {
        let mut pg = adasplit::runtime::TensorStore::new();
        adasplit::protocols::copy_prefixed(&fstate, "state.p", &mut pg, "pg");
        let c = adasplit::protocols::zeros_prefixed(&fstate, "state.p", "c");
        let ci = adasplit::protocols::zeros_prefixed(&fstate, "state.p", "ci");
        fl_step
            .call(&[&fstate, &pg, &c, &ci], &[("prox_mu", &zero), ("x", &b.x), ("y", &b.y)])
            .unwrap();
    }));
    let croot = cstate.sub("state");
    stats.push(bench("artifact: client_fwd (eval)", 2, iters, || {
        client_fwd.call(&[&croot], &[("x", &b.x)]).unwrap();
    }));
    let sroot = sstate.sub("state");
    stats.push(bench("artifact: server_eval", 2, iters, || {
        server_eval
            .call(&[&sroot], &[("a", &acts), ("y", &b.y), ("valid", &b.valid)])
            .unwrap();
    }));

    // ---- coordinator-side machinery ---------------------------------------
    stats.push(bench("coord: batch synthesis (64 imgs)", 1, iters, || {
        let ds = SyntheticDataset::new(adasplit::data::Family::Cifar10Like, 10, 7);
        ds.generate(&[0, 1], 64, 0, 0);
    }));
    stats.push(bench("coord: epoch batching (512)", 1, iters, || {
        let c = build_partition(DatasetKind::MixedCifar, 1, 512, 32, 1.0, 0).unwrap();
        let mut rng = Rng::new(0);
        let _: Vec<_> =
            adasplit::data::BatchIter::train(&c[0].train_x, &c[0].train_y, 32, &mut rng)
                .collect();
    }));
    stats.push(bench("coord: UCB select+update x1000", 1, iters, || {
        let mut ucb = UcbOrchestrator::new(5, 0.87);
        for t in 0..1000u64 {
            let sel = ucb.select(3);
            let obs: Vec<(usize, f64)> =
                sel.iter().map(|&i| (i, (t % 7) as f64)).collect();
            ucb.update(&obs);
        }
    }));
    stats.push(bench("coord: fedavg aggregation (160k params x5)", 1, iters, || {
        let stores: Vec<_> = (0..5)
            .map(|i| {
                let mut s = adasplit::runtime::TensorStore::new();
                s.insert("state.p.w", Tensor::full(&[160_000], i as f32));
                s
            })
            .collect();
        let refs: Vec<&adasplit::runtime::TensorStore> = stores.iter().collect();
        let mut dst = stores[0].clone();
        dst.set_weighted_sum(&refs, &[0.2; 5], |k| k.starts_with("state.p")).unwrap();
    }));

    // ---- engine scaling: one training "round" (client_step fan-out) at
    //      1/2/4/8 workers, so the speedup lands in the bench trajectory --
    let n_par = 8usize;
    let par_states: Vec<TensorStore> = (0..n_par)
        .map(|i| env.init_state("c10_mu1_init_client", 10.0 + i as f32))
        .collect::<anyhow::Result<_>>()?;
    let mut round_stats = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        let pool = ClientPool::new(threads);
        let s = bench(
            &format!("engine: round of {n_par} client_steps @{threads}T"),
            1,
            iters,
            || {
                pool.run(n_par, |i| {
                    client_step
                        .call(
                            &[&par_states[i]],
                            &[("x", &b.x), ("y", &b.y), ("beta", &beta),
                              ("grad_a", &zero_ga), ("use_grad", &zero)],
                        )
                        .map(|_| ())
                })
                .unwrap();
            },
        );
        round_stats.push((threads, s.clone()));
        stats.push(s);
    }

    println!("\n== runtime_micro ==");
    for s in &stats {
        println!("{}", s.report());
    }

    // round-throughput summary across the threads axis
    let serial_mean = round_stats[0].1.mean_s;
    if !cfg!(feature = "parallel-xla")
        || std::env::var("ADASPLIT_PARALLEL_XLA").as_deref() != Ok("1")
    {
        println!(
            "\nnote: PJRT execution is serialized by default; build with \
             `--features parallel-xla` (requires the Rc->Arc-patched \
             vendored xla-rs, DESIGN.md §5) and set ADASPLIT_PARALLEL_XLA=1 \
             to measure true execution overlap"
        );
    }
    println!("\nengine round throughput ({n_par} clients/round):");
    for (threads, s) in &round_stats {
        println!(
            "  {threads} worker(s): {:>8.2} clients/s  speedup x{:.2}",
            n_par as f64 / s.mean_s,
            serial_mean / s.mean_s
        );
    }

    // coordinator overhead summary: pure-Rust work per training iteration
    // vs the artifact execution it wraps
    let art = stats[0].mean_s;
    let coord = stats[7].mean_s / 1000.0; // UCB per iteration
    println!(
        "\ncoordinator overhead per iteration (UCB) = {:.2}us = {:.4}% of client_step",
        coord * 1e6,
        100.0 * coord / art
    );
    Ok(())
}
