//! Runtime micro-benchmarks — the perf-pass instrument (EXPERIMENTS.md
//! §Perf). Times each hot-path artifact execution (client step, server
//! step, FL step, evals), host<->literal marshalling, data synthesis, and
//! the pure-Rust coordinator machinery (UCB, aggregation), so coordinator
//! overhead can be read off directly against the XLA step time.
//!
//! Results are tracked across PRs in `BENCH_results.json` (engine round
//! throughput over the threads axis, the deterministic mask-density
//! trajectory of a tiny AdaSplit run, the async-scheduler axis — the
//! deterministic `AsyncBounded` sim-time trajectory plus its planning
//! throughput — the delayed-gradient snapshot-ring axis, the
//! adaptive-bound controller axis (`bound_controller_steps_per_s`), the
//! persistent worker-pool axis (`pool_jobs_per_s`: warm-pool dispatch,
//! zero per-run spawns), the sharded client-state axis
//! (`shard_store_ops_per_s`: 500-of-100000 residency bookkeeping), and
//! the event-engine dispatch axis (`event_heap_events_per_s`: heap
//! push+pop floor of the discrete-event driver), and the open-world
//! scenario axis (`scenario_events_per_s`: seeded churn + rate-episode
//! synthesis and drain, DESIGN.md §12), and the static-analysis axis
//! (`detlint_files_per_s`: the D01–D05 rule catalogue over the whole
//! rust/src tree, DESIGN.md §13): all
//! pure Rust, so they measure and check even on artifact-less runners).
//! Default mode rewrites the file; `--check` compares against it
//! instead — trajectories must match exactly (they are deterministic),
//! throughput may not grossly regress, and the tracked file must carry
//! the async-scheduler and snapshot-ring keys — and exits 0 with a SKIP
//! note for the artifact-gated sections when artifacts are absent.

use std::collections::BTreeMap;

use adasplit::config::ExperimentConfig;
use adasplit::data::{build_partition, DatasetKind, Rng, SyntheticDataset};
use adasplit::driver::{
    AsyncBounded, BoundController, ClientSpeeds, ClientState, ClientStateStore, Scheduler,
    SnapshotRing, SpeedPreset, WindowDelta,
};
use adasplit::engine::ClientPool;
use adasplit::orchestrator::UcbOrchestrator;
use adasplit::protocols::{run_protocol_recorded, Env};
use adasplit::runtime::{Runtime, Tensor, TensorStore};
use adasplit::sim::{ChurnSpec, Event, EventHeap, EventKind, RateScheduleSpec, Scenario};
use adasplit::util::bench::{bench, quick_mode, BenchStats};
use adasplit::util::Json;

const TRACK_FILE: &str = "BENCH_results.json";

/// Deterministic async-scheduler fingerprint: the `AsyncBounded`
/// sim-time trajectory for a fixed fleet (64 clients, stragglers 0.2,
/// bound 2, cap 0.5, seed 7). Any drift is a real scheduling-semantics
/// change, not noise.
fn async_sim_trajectory() -> Vec<f64> {
    let speeds = ClientSpeeds::new(64, SpeedPreset::Stragglers, 0.2, 7);
    let mut s = AsyncBounded::new(64, 2, 0.5, &speeds);
    (0..32).map(|r| s.plan(r).sim_time).collect()
}

/// Async planning throughput (plans/s on a 512-client fleet) — the
/// coordinator-side cost of the virtual-clock simulation.
fn async_plan_bench(iters: usize) -> BenchStats {
    let speeds = ClientSpeeds::new(512, SpeedPreset::Lognormal { sigma: 0.5 }, 0.0, 3);
    bench("coord: async plan x200 (512 clients)", 1, iters, || {
        let mut s = AsyncBounded::new(512, 3, 0.25, &speeds);
        for r in 0..200 {
            std::hint::black_box(s.plan(r));
        }
    })
}

/// Snapshot-ring throughput (rounds/s): the delayed-gradient hot path on
/// the driver thread — push one round-start broadcast snapshot (~16 KiB
/// model) and resolve one stale version per round over a bound-3 ring.
/// Pure Rust, so it measures and checks even on artifact-less runners.
fn snapshot_ring_bench(iters: usize) -> BenchStats {
    let mut model = TensorStore::new();
    model.insert("pg.w", Tensor::full(&[4096], 1.0));
    bench("coord: snapshot ring push+get x64 (bound 3)", 1, iters, || {
        let mut ring = SnapshotRing::new(4);
        for r in 0..64usize {
            ring.push(r, model.clone()).unwrap();
            if r >= 3 {
                std::hint::black_box(ring.get(r - 3).unwrap());
            }
        }
    })
}

/// Bound-controller throughput (controller steps/s): one C3-shaped
/// reward + UCB arm re-selection per step over the default five-arm set
/// — the adaptive-bound hot path on the driver thread (one step per
/// adaptation window). Pure Rust, so it measures and checks even on
/// artifact-less runners.
fn bound_controller_bench(iters: usize) -> BenchStats {
    let budgets = adasplit::metrics::Budgets::paper_mixed_cifar();
    bench("coord: bound controller observe+select x1000", 1, iters, || {
        let mut c = BoundController::new(8, 5, 7, budgets);
        for w in 0..1000u64 {
            let d = WindowDelta {
                d_accuracy_pct: (w % 7) as f64 * 0.3,
                d_sim_time: 5.0 / (1.0 + c.current_bound() as f64),
                d_bandwidth_gb: 0.4,
                d_client_tflops: 0.2,
            };
            std::hint::black_box(c.observe_window(&d));
        }
    })
}

/// Persistent-pool dispatch throughput (jobs/s through a warm 4-worker
/// pool; 64 runs x 64 tiny jobs per iteration) — the per-client fan-out
/// overhead the engine pays once spawn/join is amortized away. The pool
/// is warmed before timing, so the number is pure dispatch, zero spawns.
fn pool_jobs_bench(iters: usize) -> BenchStats {
    let pool = ClientPool::new(4);
    pool.run(64, |_| Ok(())).unwrap(); // warm up: workers spawn here, once
    bench("engine: warm pool dispatch 64 runs x 64 jobs", 1, iters, || {
        for _ in 0..64 {
            pool.run(64, |i| Ok(std::hint::black_box(i * 2 + 1))).unwrap();
        }
    })
}

/// Per-iteration job count of [`pool_jobs_bench`].
const POOL_JOBS_PER_ITER: f64 = 64.0 * 64.0;

/// Sharded client-state bookkeeping throughput (ensure-loaded ops/s at
/// the 100000-client / 500-sample scale point): four rounds of
/// ensure_loaded + the resident-id walk per iteration. The sharded store
/// keeps this O(resident), so the number is flat in the fleet size.
fn shard_store_bench(iters: usize) -> BenchStats {
    let samples: Vec<Vec<usize>> = (0..4usize)
        .map(|r| {
            let mut s: Vec<usize> =
                (0..500usize).map(|j| (j * 97 + r * 13) % 100_000).collect();
            s.sort_unstable();
            s.dedup();
            s
        })
        .collect();
    bench("engine: sharded store 4 rounds x ~500 of 100k", 1, iters, || {
        let mut store = ClientStateStore::new(100_000);
        for sample in &samples {
            store.ensure_loaded(sample, |_| Ok(ClientState::new())).unwrap();
            std::hint::black_box(store.loaded_ids());
            std::hint::black_box(store.loaded_count());
        }
    })
}

/// Per-iteration op count of [`shard_store_bench`].
const SHARD_OPS_PER_ITER: f64 = 4.0 * 500.0;

/// Event-heap dispatch throughput (events/s): push then fully drain 4096
/// timestamped events with xorshift-scrambled pseudo-times and a rotating
/// kind mix — the discrete-event driver's per-event scheduling floor on
/// the driver thread. Deterministic (no ambient randomness) and pure
/// Rust, so it measures and checks even on artifact-less runners.
fn event_heap_bench(iters: usize) -> BenchStats {
    bench("coord: event heap push+pop x4096", 1, iters, || {
        let mut h = EventHeap::new();
        let mut x = 0x9e37_79b9_7f4a_7c15u64;
        for i in 0..EVENT_HEAP_EVENTS_PER_ITER as usize {
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            // non-negative finite times in [0, 64), with deliberate
            // collisions (quantized grid) to exercise the tie-break path
            let t = ((x >> 11) % 4096) as f64 / 64.0;
            let kind = match i % 4 {
                0 => EventKind::ClientFinish { client: i },
                1 => EventKind::ServerMerge { merge: i },
                2 => EventKind::Eval { merge: i },
                _ => EventKind::ControllerSwitch { merge: i },
            };
            h.push(Event::new(t, kind));
        }
        while let Some(e) = h.pop() {
            std::hint::black_box(e);
        }
    })
}

/// Per-iteration event count of [`event_heap_bench`].
const EVENT_HEAP_EVENTS_PER_ITER: f64 = 4096.0;

/// Scenario-stream throughput (events/s): synthesize and drain 1024
/// open-world events — seeded Poisson churn plus diurnal + flaky rate
/// episodes over a 64-client fleet, each pop pushing its successor —
/// the per-event cost of the scenario layer on the driver thread.
/// Deterministic (derived rng streams, fixed seed) and pure Rust, so it
/// measures and checks even on artifact-less runners.
fn scenario_events_bench(iters: usize) -> BenchStats {
    let churn: ChurnSpec = "join:0.6,leave:0.6".parse().unwrap();
    let rates: RateScheduleSpec = "diurnal:8:0.4+flaky:0.5:4:1.0".parse().unwrap();
    bench("coord: scenario synth+drain x1024 (64 clients)", 1, iters, || {
        let mut sc = Scenario::synth(64, Some(churn), rates, 11);
        let mut heap = EventHeap::new();
        sc.prime(&mut heap);
        for _ in 0..SCENARIO_EVENTS_PER_ITER as usize {
            let ev = heap.pop().expect("self-perpetuating processes never drain dry");
            match ev.kind {
                EventKind::ClientJoin { client } => {
                    std::hint::black_box(sc.on_join(client, ev.time, &mut heap));
                }
                EventKind::ClientLeave { client } => {
                    std::hint::black_box(sc.on_leave(client, ev.time, &mut heap));
                }
                EventKind::RateChange { client } => {
                    std::hint::black_box(sc.on_rate(client, ev.time, &mut heap));
                }
                _ => unreachable!("the scenario layer only schedules scenario kinds"),
            }
        }
    })
}

/// Per-iteration event count of [`scenario_events_bench`].
const SCENARIO_EVENTS_PER_ITER: f64 = 1024.0;

/// Static-analysis throughput (files/s): run the detlint rule catalogue
/// (D01–D05, DESIGN.md §13) over every file under rust/src. Sources are
/// pre-read, so the number is pure lexer+rules cost, not IO. Tracked so
/// the tier-1 lint pass stays effectively free as the tree grows —
/// detlint runs inside every `cargo test -q`. Returns the stats plus the
/// file count (the per-iteration unit, dynamic unlike the const axes).
fn detlint_files_bench(iters: usize) -> (BenchStats, f64) {
    let root = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src"));
    let sources: Vec<(String, String)> = adasplit::detlint::source_files(root)
        .expect("detlint walks rust/src")
        .into_iter()
        .map(|f| {
            let src = std::fs::read_to_string(&f).expect("detlint reads rust/src");
            (f.display().to_string(), src)
        })
        .collect();
    let n = sources.len() as f64;
    let stats = bench(
        &format!("lint: detlint full tree ({} files)", sources.len()),
        1,
        iters,
        || {
            for (path, src) in &sources {
                std::hint::black_box(adasplit::detlint::lint_source(path, src));
            }
        },
    );
    (stats, n)
}

fn check_async_axis(tracked: &Json, sim: &[f64]) -> anyhow::Result<()> {
    let md = tracked
        .opt("async_sim_time")
        .ok_or_else(|| anyhow::anyhow!(
            "tracked {TRACK_FILE} is missing the async-scheduler axis \
             (`async_sim_time`); re-record with the bench"
        ))?;
    anyhow::ensure!(
        tracked.opt("async_plan_rounds_per_s").is_some(),
        "tracked {TRACK_FILE} is missing `async_plan_rounds_per_s`"
    );
    anyhow::ensure!(
        tracked.opt("snapshot_ring_rounds_per_s").is_some(),
        "tracked {TRACK_FILE} is missing `snapshot_ring_rounds_per_s` \
         (delayed-gradient snapshot-ring axis); re-record with the bench"
    );
    anyhow::ensure!(
        tracked.opt("bound_controller_steps_per_s").is_some(),
        "tracked {TRACK_FILE} is missing `bound_controller_steps_per_s` \
         (adaptive-bound controller axis); re-record with the bench"
    );
    anyhow::ensure!(
        tracked.opt("pool_jobs_per_s").is_some(),
        "tracked {TRACK_FILE} is missing `pool_jobs_per_s` \
         (persistent worker-pool axis); re-record with the bench"
    );
    anyhow::ensure!(
        tracked.opt("shard_store_ops_per_s").is_some(),
        "tracked {TRACK_FILE} is missing `shard_store_ops_per_s` \
         (sharded client-state axis); re-record with the bench"
    );
    anyhow::ensure!(
        tracked.opt("event_heap_events_per_s").is_some(),
        "tracked {TRACK_FILE} is missing `event_heap_events_per_s` \
         (event-engine dispatch axis); re-record with the bench"
    );
    anyhow::ensure!(
        tracked.opt("scenario_events_per_s").is_some(),
        "tracked {TRACK_FILE} is missing `scenario_events_per_s` \
         (open-world scenario axis); re-record with the bench"
    );
    anyhow::ensure!(
        tracked.opt("detlint_files_per_s").is_some(),
        "tracked {TRACK_FILE} is missing `detlint_files_per_s` \
         (static-analysis axis); re-record with the bench"
    );
    let old: Vec<f64> = md
        .as_arr()?
        .iter()
        .map(|j| j.as_f64())
        .collect::<anyhow::Result<_>>()?;
    if old.is_empty() {
        println!("check: tracked async_sim_time empty (placeholder); key present — ok");
        return Ok(());
    }
    anyhow::ensure!(
        old.len() == sim.len(),
        "async_sim_time trajectory length changed: {} -> {}",
        old.len(),
        sim.len()
    );
    for (i, (a, b)) in old.iter().zip(sim).enumerate() {
        anyhow::ensure!(
            (a - b).abs() < 1e-9,
            "async_sim_time[{i}] drifted: {a} -> {b} (scheduling-semantics change?)"
        );
    }
    println!("check: async-scheduler sim-time trajectory matches ({} rounds)", old.len());
    Ok(())
}

fn results_json(
    stats: &[BenchStats],
    round_stats: &[(usize, BenchStats)],
    densities: &[f64],
    async_sim: &[f64],
    async_plan: &BenchStats,
    snap_ring: &BenchStats,
    bound_ctrl: &BenchStats,
    pool_jobs: &BenchStats,
    shard_store: &BenchStats,
    event_heap: &BenchStats,
    scenario: &BenchStats,
    detlint: (&BenchStats, f64),
    n_par: usize,
    quick: bool,
) -> Json {
    let mut stat_map = BTreeMap::new();
    for s in stats {
        stat_map.insert(s.name.clone(), Json::Num(s.mean_s));
    }
    let mut thr = BTreeMap::new();
    for (t, s) in round_stats {
        thr.insert(t.to_string(), Json::Num(n_par as f64 / s.mean_s));
    }
    let mut m = BTreeMap::new();
    m.insert("schema_version".into(), Json::Num(2.0));
    m.insert("quick".into(), Json::Num(if quick { 1.0 } else { 0.0 }));
    m.insert("stats_mean_s".into(), Json::Obj(stat_map));
    m.insert("engine_round_clients_per_s".into(), Json::Obj(thr));
    m.insert(
        "mask_density".into(),
        Json::Arr(densities.iter().map(|&d| Json::Num(d)).collect()),
    );
    m.insert(
        "async_sim_time".into(),
        Json::Arr(async_sim.iter().map(|&t| Json::Num(t)).collect()),
    );
    m.insert(
        "async_plan_rounds_per_s".into(),
        Json::Num(200.0 / async_plan.mean_s),
    );
    m.insert(
        "snapshot_ring_rounds_per_s".into(),
        Json::Num(64.0 / snap_ring.mean_s),
    );
    m.insert(
        "bound_controller_steps_per_s".into(),
        Json::Num(1000.0 / bound_ctrl.mean_s),
    );
    m.insert("pool_jobs_per_s".into(), Json::Num(POOL_JOBS_PER_ITER / pool_jobs.mean_s));
    m.insert(
        "shard_store_ops_per_s".into(),
        Json::Num(SHARD_OPS_PER_ITER / shard_store.mean_s),
    );
    m.insert(
        "event_heap_events_per_s".into(),
        Json::Num(EVENT_HEAP_EVENTS_PER_ITER / event_heap.mean_s),
    );
    m.insert(
        "scenario_events_per_s".into(),
        Json::Num(SCENARIO_EVENTS_PER_ITER / scenario.mean_s),
    );
    m.insert("detlint_files_per_s".into(), Json::Num(detlint.1 / detlint.0.mean_s));
    Json::Obj(m)
}

fn main() -> anyhow::Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    // the async-scheduler axis is pure Rust: it measures and checks even
    // without artifacts
    let async_sim = async_sim_trajectory();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        if check {
            match std::fs::read_to_string(TRACK_FILE) {
                Err(_) => println!(
                    "check: no tracked {TRACK_FILE}; run the bench without --check to create it"
                ),
                Ok(text) => check_async_axis(&Json::parse(&text)?, &async_sim)?,
            }
            println!(
                "runtime_micro --check: SKIP artifact-gated measurements (artifacts \
                 not built); bench compiled, async axis validated — check passes"
            );
            return Ok(());
        }
        anyhow::bail!("artifacts not built (run `make artifacts`)");
    }
    let iters = if quick_mode() || check { 5 } else { 20 };
    let rt = Runtime::load("artifacts")?;
    let cfg = ExperimentConfig::quick_test();
    let clients = build_partition(DatasetKind::MixedCifar, 5, 64, 32, 1.0, 0)?;
    let env = Env::new(&rt, &cfg, clients);

    let mut stats = Vec::new();

    // ---- artifact executions (the intended hot path) ----------------------
    let client_step = env.art_split("client_step")?;
    let server_step = env.art_split("server_step")?;
    let client_fwd = env.art_split("client_fwd")?;
    let server_eval = env.art_split("server_eval")?;
    let fl_step = env.art_ds("fl_step")?;

    let cstate = env.init_state("c10_mu1_init_client", 1.0)?;
    let sstate = env.init_state("c10_mu1_init_server", 2.0)?;
    let fstate = env.init_state("c10_init_fl", 3.0)?;
    let b = &env.train_batches(0, 0)[0];
    let zero_ga = Tensor::zeros(&rt.manifest.config("c10_mu1")?.act_shape);
    let beta = Tensor::scalar(0.0);
    let zero = Tensor::scalar(0.0);
    let lam = Tensor::scalar(1e-5);

    let acts = client_step
        .call(
            &[&cstate],
            &[("x", &b.x), ("y", &b.y), ("beta", &beta), ("grad_a", &zero_ga),
              ("use_grad", &zero)],
        )?
        .take("acts")?;

    stats.push(bench("artifact: client_step (B=32)", 2, iters, || {
        client_step
            .call(
                &[&cstate],
                &[("x", &b.x), ("y", &b.y), ("beta", &beta), ("grad_a", &zero_ga),
                  ("use_grad", &zero)],
            )
            .unwrap();
    }));
    stats.push(bench("artifact: server_step (masked)", 2, iters, || {
        server_step
            .call(&[&sstate], &[("a", &acts), ("y", &b.y), ("lam", &lam)])
            .unwrap();
    }));
    stats.push(bench("artifact: fl_step (full model)", 2, iters, || {
        let mut pg = adasplit::runtime::TensorStore::new();
        adasplit::protocols::copy_prefixed(&fstate, "state.p", &mut pg, "pg");
        let c = adasplit::protocols::zeros_prefixed(&fstate, "state.p", "c");
        let ci = adasplit::protocols::zeros_prefixed(&fstate, "state.p", "ci");
        fl_step
            .call(&[&fstate, &pg, &c, &ci], &[("prox_mu", &zero), ("x", &b.x), ("y", &b.y)])
            .unwrap();
    }));
    let croot = cstate.sub("state");
    stats.push(bench("artifact: client_fwd (eval)", 2, iters, || {
        client_fwd.call(&[&croot], &[("x", &b.x)]).unwrap();
    }));
    let sroot = sstate.sub("state");
    stats.push(bench("artifact: server_eval", 2, iters, || {
        server_eval
            .call(&[&sroot], &[("a", &acts), ("y", &b.y), ("valid", &b.valid)])
            .unwrap();
    }));

    // ---- coordinator-side machinery ---------------------------------------
    stats.push(bench("coord: batch synthesis (64 imgs)", 1, iters, || {
        let ds = SyntheticDataset::new(adasplit::data::Family::Cifar10Like, 10, 7);
        ds.generate(&[0, 1], 64, 0, 0);
    }));
    stats.push(bench("coord: epoch batching (512)", 1, iters, || {
        let c = build_partition(DatasetKind::MixedCifar, 1, 512, 32, 1.0, 0).unwrap();
        let c0 = c.get(0);
        let mut rng = Rng::new(0);
        let _: Vec<_> =
            adasplit::data::BatchIter::train(&c0.train_x, &c0.train_y, 32, &mut rng)
                .collect();
    }));
    let async_plan = async_plan_bench(iters);
    stats.push(async_plan.clone());
    let snap_ring = snapshot_ring_bench(iters);
    stats.push(snap_ring.clone());
    let bound_ctrl = bound_controller_bench(iters);
    stats.push(bound_ctrl.clone());
    let pool_jobs = pool_jobs_bench(iters);
    stats.push(pool_jobs.clone());
    let shard_store = shard_store_bench(iters);
    stats.push(shard_store.clone());
    let event_heap = event_heap_bench(iters);
    stats.push(event_heap.clone());
    let scenario = scenario_events_bench(iters);
    stats.push(scenario.clone());
    let (detlint, detlint_files) = detlint_files_bench(iters);
    stats.push(detlint.clone());
    stats.push(bench("coord: UCB select+update x1000", 1, iters, || {
        let mut ucb = UcbOrchestrator::new(5, 0.87);
        for t in 0..1000u64 {
            let sel = ucb.select(3);
            let obs: Vec<(usize, f64)> =
                sel.iter().map(|&i| (i, (t % 7) as f64)).collect();
            ucb.update(&obs);
        }
    }));
    stats.push(bench("coord: fedavg aggregation (160k params x5)", 1, iters, || {
        let stores: Vec<_> = (0..5)
            .map(|i| {
                let mut s = adasplit::runtime::TensorStore::new();
                s.insert("state.p.w", Tensor::full(&[160_000], i as f32));
                s
            })
            .collect();
        let refs: Vec<&adasplit::runtime::TensorStore> = stores.iter().collect();
        let mut dst = stores[0].clone();
        dst.set_weighted_sum(&refs, &[0.2; 5], |k| k.starts_with("state.p")).unwrap();
    }));

    // ---- engine scaling: one training "round" (client_step fan-out) at
    //      1/2/4/8 workers, so the speedup lands in the bench trajectory --
    let n_par = 8usize;
    let par_states: Vec<TensorStore> = (0..n_par)
        .map(|i| env.init_state("c10_mu1_init_client", 10.0 + i as f32))
        .collect::<anyhow::Result<_>>()?;
    let mut round_stats = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        let pool = ClientPool::new(threads);
        let s = bench(
            &format!("engine: round of {n_par} client_steps @{threads}T"),
            1,
            iters,
            || {
                pool.run(n_par, |i| {
                    client_step
                        .call(
                            &[&par_states[i]],
                            &[("x", &b.x), ("y", &b.y), ("beta", &beta),
                              ("grad_a", &zero_ga), ("use_grad", &zero)],
                        )
                        .map(|_| ())
                })
                .unwrap();
            },
        );
        round_stats.push((threads, s.clone()));
        stats.push(s);
    }

    println!("\n== runtime_micro ==");
    for s in &stats {
        println!("{}", s.report());
    }

    // round-throughput summary across the threads axis
    let serial_mean = round_stats[0].1.mean_s;
    if !cfg!(feature = "parallel-xla")
        || std::env::var("ADASPLIT_PARALLEL_XLA").as_deref() != Ok("1")
    {
        println!(
            "\nnote: PJRT execution is serialized by default; build with \
             `--features parallel-xla` (requires the Rc->Arc-patched \
             vendored xla-rs, DESIGN.md §5) and set ADASPLIT_PARALLEL_XLA=1 \
             to measure true execution overlap"
        );
    }
    println!("\nengine round throughput ({n_par} clients/round):");
    for (threads, s) in &round_stats {
        println!(
            "  {threads} worker(s): {:>8.2} clients/s  speedup x{:.2}",
            n_par as f64 / s.mean_s,
            serial_mean / s.mean_s
        );
    }

    // coordinator overhead summary: pure-Rust work per training iteration
    // vs the artifact execution it wraps
    let art = stats[0].mean_s;
    let coord = stats
        .iter()
        .find(|s| s.name.starts_with("coord: UCB"))
        .expect("UCB bench present")
        .mean_s
        / 1000.0; // UCB per iteration
    println!(
        "\ncoordinator overhead per iteration (UCB) = {:.2}us = {:.4}% of client_step",
        coord * 1e6,
        100.0 * coord / art
    );

    // ---- tracked results: threads axis + mask-density trajectory ----------
    // tiny deterministic AdaSplit run (1 local + 2 global rounds): the
    // per-round mask densities are a pure function of the seed, so any
    // drift between PRs is a real numerics change, not noise
    let mut traj_cfg = ExperimentConfig::quick_test();
    traj_cfg.kappa = 0.34;
    traj_cfg.threads = 1;
    let (_, traj) = run_protocol_recorded(&rt, &traj_cfg)?;
    let densities: Vec<f64> = traj.rounds.iter().map(|r| r.mask_density).collect();

    if check {
        match std::fs::read_to_string(TRACK_FILE) {
            Err(_) => println!(
                "check: no tracked {TRACK_FILE}; run the bench without --check to create it"
            ),
            Ok(text) => {
                let tracked = Json::parse(&text)?;
                if let Some(md) = tracked.opt("mask_density") {
                    let old: Vec<f64> = md
                        .as_arr()?
                        .iter()
                        .map(|j| j.as_f64())
                        .collect::<anyhow::Result<_>>()?;
                    if old.is_empty() {
                        println!("check: tracked mask_density empty (placeholder); skipping");
                    } else {
                        anyhow::ensure!(
                            old.len() == densities.len(),
                            "mask_density trajectory length changed: {} -> {}",
                            old.len(),
                            densities.len()
                        );
                        for (i, (a, b)) in old.iter().zip(&densities).enumerate() {
                            anyhow::ensure!(
                                (a - b).abs() < 1e-9,
                                "mask_density[{i}] drifted: {a} -> {b} (numerics change?)"
                            );
                        }
                        println!("check: mask_density trajectory matches ({} rounds)", old.len());
                    }
                }
                if let Some(thr) = tracked.opt("engine_round_clients_per_s") {
                    // timing is noisy across machines: only flag gross
                    // (>60%) regressions
                    for (t, s) in &round_stats {
                        if let Some(old) = thr.opt(&t.to_string()) {
                            let old = old.as_f64()?;
                            let new = n_par as f64 / s.mean_s;
                            anyhow::ensure!(
                                old <= 0.0 || new > old * 0.4,
                                "engine round throughput @{t}T regressed >60%: \
                                 {old:.2} -> {new:.2} clients/s"
                            );
                        }
                    }
                    println!("check: engine throughput within tolerance of tracked results");
                }
                check_async_axis(&tracked, &async_sim)?;
            }
        }
    } else {
        let json = results_json(
            &stats,
            &round_stats,
            &densities,
            &async_sim,
            &async_plan,
            &snap_ring,
            &bound_ctrl,
            &pool_jobs,
            &shard_store,
            &event_heap,
            &scenario,
            (&detlint, detlint_files),
            n_par,
            quick_mode(),
        );
        std::fs::write(TRACK_FILE, json.to_string_pretty())?;
        println!("tracked results -> {TRACK_FILE}");
    }
    Ok(())
}
