//! Runtime micro-benchmarks on the matrix harness (DESIGN.md §14).
//!
//! The grid lives in `benches/matrix.toml`; every measurement is a cell
//! in `adasplit::bench`'s runner, tracked per cell id in
//! `BENCH_results.json` (schema v3, v2 readable). Pure-Rust axes —
//! async-scheduler planning, the snapshot ring, the adaptive-bound
//! controller, the persistent worker pool, the sharded client-state
//! store, the event heap, the open-world scenario stream, the detlint
//! catalogue, plus UCB / aggregation / data-synthesis extras — run on
//! any machine; artifact execution cells (`artifact/*`) and the
//! engine-round grid (`round/t*/...`) require `make artifacts` and are
//! skipped loudly when absent.
//!
//! Default mode rewrites the tracked file; `--check` gates against it:
//! deterministic trajectories (`async_sim_time`, `mask_density`) must
//! match exactly, per-cell throughput must stay inside the tolerance
//! band declared in the config, placeholder (zero/empty) cells are
//! reported per key as "not yet recorded", and quick-mode numbers are
//! never compared against full-mode numbers — the gate SKIPs them with
//! an explicit note instead.

use std::path::Path;

use adasplit::bench::{check, writer, MatrixConfig, Runner};
use adasplit::config::ExperimentConfig;
use adasplit::data::{build_partition, DatasetKind, Rng, SyntheticDataset};
use adasplit::driver::{
    AsyncBounded, BoundController, ClientSpeeds, ClientState, ClientStateStore, Scheduler,
    SnapshotRing, SpeedPreset, WindowDelta,
};
use adasplit::engine::ClientPool;
use adasplit::orchestrator::UcbOrchestrator;
use adasplit::protocols::{run_protocol_recorded, Env};
use adasplit::runtime::{Runtime, Tensor, TensorStore};
use adasplit::sim::{ChurnSpec, Event, EventHeap, EventKind, RateScheduleSpec, Scenario};
use adasplit::util::bench::quick_mode;

const TRACK_FILE: &str = "BENCH_results.json";
const MATRIX_FILE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/benches/matrix.toml");

/// Deterministic async-scheduler fingerprint: the `AsyncBounded`
/// sim-time trajectory for a fixed fleet (64 clients, stragglers 0.2,
/// bound 2, cap 0.5, seed 7). Any drift is a real scheduling-semantics
/// change, not noise.
fn async_sim_trajectory() -> Vec<f64> {
    let speeds = ClientSpeeds::new(64, SpeedPreset::Stragglers, 0.2, 7);
    let mut s = AsyncBounded::new(64, 2, 0.5, &speeds);
    (0..32).map(|r| s.plan(r).sim_time).collect()
}

/// The pure-Rust cells: coordinator machinery with no artifact
/// dependency, so they measure (and gate) on any runner. Cell ids here
/// are the `axes.pure` names in `benches/matrix.toml`.
fn run_pure_cells(runner: &mut Runner) -> anyhow::Result<()> {
    // async-scheduler planning (plans/s over a 512-client fleet) + the
    // deterministic sim-time trajectory on the same cell
    let speeds = ClientSpeeds::new(512, SpeedPreset::Lognormal { sigma: 0.5 }, 0.0, 3);
    runner.run_cell("async_plan", 200.0, || {
        let mut s = AsyncBounded::new(512, 3, 0.25, &speeds);
        for r in 0..200 {
            std::hint::black_box(s.plan(r));
        }
    })?;
    runner.add_trajectory("async_plan", "async_sim_time", async_sim_trajectory())?;

    // delayed-gradient snapshot ring: push one ~16 KiB round-start
    // broadcast and resolve one stale version per round, bound-3 ring
    let mut model = TensorStore::new();
    model.insert("pg.w", Tensor::full(&[4096], 1.0));
    runner.run_cell("snapshot_ring", 64.0, || {
        let mut ring = SnapshotRing::new(4);
        for r in 0..64usize {
            ring.push(r, model.clone()).unwrap();
            if r >= 3 {
                std::hint::black_box(ring.get(r - 3).unwrap());
            }
        }
    })?;

    // adaptive-bound controller: one C3-shaped reward + UCB arm
    // re-selection per step over the default five-arm set
    let budgets = adasplit::metrics::Budgets::paper_mixed_cifar();
    runner.run_cell("bound_controller", 1000.0, || {
        let mut c = BoundController::new(8, 5, 7, budgets);
        for w in 0..1000u64 {
            let d = WindowDelta {
                d_accuracy_pct: (w % 7) as f64 * 0.3,
                d_sim_time: 5.0 / (1.0 + c.current_bound() as f64),
                d_bandwidth_gb: 0.4,
                d_client_tflops: 0.2,
            };
            std::hint::black_box(c.observe_window(&d));
        }
    })?;

    // persistent-pool dispatch: 64 runs x 64 tiny jobs through a warm
    // 4-worker pool — pure dispatch, zero spawns after the warm-up run
    let pool = ClientPool::new(4);
    pool.run(64, |_| Ok(()))?; // warm up: workers spawn here, once
    runner.run_cell("pool", 64.0 * 64.0, || {
        for _ in 0..64 {
            pool.run(64, |i| Ok(std::hint::black_box(i * 2 + 1))).unwrap();
        }
    })?;

    // sharded client-state bookkeeping at the 100000-client / 500-sample
    // scale point: ensure_loaded + the resident-id walk, O(resident)
    let samples: Vec<Vec<usize>> = (0..4usize)
        .map(|r| {
            let mut s: Vec<usize> =
                (0..500usize).map(|j| (j * 97 + r * 13) % 100_000).collect();
            s.sort_unstable();
            s.dedup();
            s
        })
        .collect();
    runner.run_cell("shard_store", 4.0 * 500.0, || {
        let mut store = ClientStateStore::new(100_000);
        for sample in &samples {
            store.ensure_loaded(sample, |_| Ok(ClientState::new())).unwrap();
            std::hint::black_box(store.loaded_ids());
            std::hint::black_box(store.loaded_count());
        }
    })?;

    // event-heap dispatch floor: push then fully drain 4096 events with
    // xorshift-scrambled pseudo-times (quantized to force tie-breaks)
    runner.run_cell("event_heap", 4096.0, || {
        let mut h = EventHeap::new();
        let mut x = 0x9e37_79b9_7f4a_7c15u64;
        for i in 0..4096usize {
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            let t = ((x >> 11) % 4096) as f64 / 64.0;
            let kind = match i % 4 {
                0 => EventKind::ClientFinish { client: i },
                1 => EventKind::ServerMerge { merge: i },
                2 => EventKind::Eval { merge: i },
                _ => EventKind::ControllerSwitch { merge: i },
            };
            h.push(Event::new(t, kind));
        }
        while let Some(e) = h.pop() {
            std::hint::black_box(e);
        }
    })?;

    // open-world scenario stream: synthesize and drain 1024 seeded
    // churn + rate-episode events, each pop pushing its successor
    let churn: ChurnSpec = "join:0.6,leave:0.6".parse().unwrap();
    let rates: RateScheduleSpec = "diurnal:8:0.4+flaky:0.5:4:1.0".parse().unwrap();
    runner.run_cell("scenario", 1024.0, || {
        let mut sc = Scenario::synth(64, Some(churn), rates, 11);
        let mut heap = EventHeap::new();
        sc.prime(&mut heap);
        for _ in 0..1024usize {
            let ev = heap.pop().expect("self-perpetuating processes never drain dry");
            match ev.kind {
                EventKind::ClientJoin { client } => {
                    std::hint::black_box(sc.on_join(client, ev.time, &mut heap));
                }
                EventKind::ClientLeave { client } => {
                    std::hint::black_box(sc.on_leave(client, ev.time, &mut heap));
                }
                EventKind::RateChange { client } => {
                    std::hint::black_box(sc.on_rate(client, ev.time, &mut heap));
                }
                _ => unreachable!("the scenario layer only schedules scenario kinds"),
            }
        }
    })?;

    // detlint catalogue (D01–D05) over the whole rust/src tree; sources
    // pre-read so the cell is pure lexer+rules cost, not IO
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src"));
    let sources: Vec<(String, String)> = adasplit::detlint::source_files(root)?
        .into_iter()
        .map(|f| {
            let src = std::fs::read_to_string(&f).expect("detlint reads rust/src");
            (f.display().to_string(), src)
        })
        .collect();
    runner.run_cell("detlint", sources.len() as f64, || {
        for (path, src) in &sources {
            std::hint::black_box(adasplit::detlint::lint_source(path, src));
        }
    })?;

    // coordinator extras: UCB select+update, FedAvg-style aggregation,
    // and the data-synthesis paths
    runner.run_cell("ucb", 1000.0, || {
        let mut ucb = UcbOrchestrator::new(5, 0.87);
        for t in 0..1000u64 {
            let sel = ucb.select(3);
            let obs: Vec<(usize, f64)> =
                sel.iter().map(|&i| (i, (t % 7) as f64)).collect();
            ucb.update(&obs);
        }
    })?;
    runner.run_cell("fedavg_agg", 5.0, || {
        let stores: Vec<_> = (0..5)
            .map(|i| {
                let mut s = TensorStore::new();
                s.insert("state.p.w", Tensor::full(&[160_000], i as f32));
                s
            })
            .collect();
        let refs: Vec<&TensorStore> = stores.iter().collect();
        let mut dst = stores[0].clone();
        dst.set_weighted_sum(&refs, &[0.2; 5], |k| k.starts_with("state.p")).unwrap();
    })?;
    runner.run_cell("batch_synthesis", 64.0, || {
        let ds = SyntheticDataset::new(adasplit::data::Family::Cifar10Like, 10, 7);
        ds.generate(&[0, 1], 64, 0, 0);
    })?;
    runner.run_cell("epoch_batching", 512.0, || {
        let c = build_partition(DatasetKind::MixedCifar, 1, 512, 32, 1.0, 0).unwrap();
        let c0 = c.get(0);
        let mut rng = Rng::new(0);
        let _: Vec<_> =
            adasplit::data::BatchIter::train(&c0.train_x, &c0.train_y, 32, &mut rng)
                .collect();
    })?;
    Ok(())
}

/// The artifact-gated cells: hot-path executions (`artifact/*`), the
/// engine-round grid from the matrix config (`round/t*/...`), and the
/// deterministic mask-density trajectory of a tiny AdaSplit run.
fn run_artifact_cells(runner: &mut Runner) -> anyhow::Result<()> {
    let rt = Runtime::load("artifacts")?;
    let cfg = ExperimentConfig::quick_test();
    let clients = build_partition(DatasetKind::MixedCifar, 5, 64, 32, 1.0, 0)?;
    let env = Env::new(&rt, &cfg, clients);

    let client_step = env.art_split("client_step")?;
    let server_step = env.art_split("server_step")?;
    let client_fwd = env.art_split("client_fwd")?;
    let server_eval = env.art_split("server_eval")?;
    let fl_step = env.art_ds("fl_step")?;

    let cstate = env.init_state("c10_mu1_init_client", 1.0)?;
    let sstate = env.init_state("c10_mu1_init_server", 2.0)?;
    let fstate = env.init_state("c10_init_fl", 3.0)?;
    let b = &env.train_batches(0, 0)[0];
    let zero_ga = Tensor::zeros(&rt.manifest.config("c10_mu1")?.act_shape);
    let beta = Tensor::scalar(0.0);
    let zero = Tensor::scalar(0.0);
    let lam = Tensor::scalar(1e-5);

    let acts = client_step
        .call(
            &[&cstate],
            &[("x", &b.x), ("y", &b.y), ("beta", &beta), ("grad_a", &zero_ga),
              ("use_grad", &zero)],
        )?
        .take("acts")?;

    // artifact executions warm twice: the first call may still be
    // faulting executable pages in
    runner.run_cell_warmup("artifact/client_step", 1.0, 2, || {
        client_step
            .call(
                &[&cstate],
                &[("x", &b.x), ("y", &b.y), ("beta", &beta), ("grad_a", &zero_ga),
                  ("use_grad", &zero)],
            )
            .unwrap();
    })?;
    runner.run_cell_warmup("artifact/server_step", 1.0, 2, || {
        server_step
            .call(&[&sstate], &[("a", &acts), ("y", &b.y), ("lam", &lam)])
            .unwrap();
    })?;
    runner.run_cell_warmup("artifact/fl_step", 1.0, 2, || {
        let mut pg = TensorStore::new();
        adasplit::protocols::copy_prefixed(&fstate, "state.p", &mut pg, "pg");
        let c = adasplit::protocols::zeros_prefixed(&fstate, "state.p", "c");
        let ci = adasplit::protocols::zeros_prefixed(&fstate, "state.p", "ci");
        fl_step
            .call(&[&fstate, &pg, &c, &ci], &[("prox_mu", &zero), ("x", &b.x), ("y", &b.y)])
            .unwrap();
    })?;
    let croot = cstate.sub("state");
    runner.run_cell_warmup("artifact/client_fwd", 1.0, 2, || {
        client_fwd.call(&[&croot], &[("x", &b.x)]).unwrap();
    })?;
    let sroot = sstate.sub("state");
    runner.run_cell_warmup("artifact/server_eval", 1.0, 2, || {
        server_eval
            .call(&[&sroot], &[("a", &acts), ("y", &b.y), ("valid", &b.valid)])
            .unwrap();
    })?;

    // engine-round grid: one training "round" (client_step fan-out) per
    // matrix cell, clients/s over the declared threads axis
    for spec in runner.cfg.grid_cells() {
        anyhow::ensure!(
            spec.scheduler == "sync" && spec.protocol == "ada-split",
            "matrix cell `{}`: only the sync/ada-split round is wired into \
             runtime_micro so far — extend run_artifact_cells for new axes",
            spec.id
        );
        let par_states: Vec<TensorStore> = (0..spec.clients)
            .map(|i| env.init_state("c10_mu1_init_client", 10.0 + i as f32))
            .collect::<anyhow::Result<_>>()?;
        let pool = ClientPool::new(spec.threads);
        runner.run_cell(&spec.id, spec.clients as f64, || {
            pool.run(spec.clients, |i| {
                client_step
                    .call(
                        &[&par_states[i]],
                        &[("x", &b.x), ("y", &b.y), ("beta", &beta),
                          ("grad_a", &zero_ga), ("use_grad", &zero)],
                    )
                    .map(|_| ())
            })
            .unwrap();
        })?;
    }
    if !cfg!(feature = "parallel-xla")
        || std::env::var("ADASPLIT_PARALLEL_XLA").as_deref() != Ok("1")
    {
        println!(
            "note: PJRT execution is serialized by default; build with \
             `--features parallel-xla` (requires the Rc->Arc-patched \
             vendored xla-rs, DESIGN.md §5) and set ADASPLIT_PARALLEL_XLA=1 \
             to measure true execution overlap"
        );
    }

    // tiny deterministic AdaSplit run (1 local + 2 global rounds): the
    // per-round mask densities are a pure function of the seed, so any
    // drift between PRs is a real numerics change, not noise
    let mut traj_cfg = ExperimentConfig::quick_test();
    traj_cfg.kappa = 0.34;
    traj_cfg.threads = 1;
    let (_, traj) = run_protocol_recorded(&rt, &traj_cfg)?;
    let densities: Vec<f64> = traj.rounds.iter().map(|r| r.mask_density).collect();
    runner.add_trajectory("traj/mask_density", "mask_density", densities)?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let check_mode = std::env::args().any(|a| a == "--check");
    let quick = quick_mode();
    let mcfg = MatrixConfig::load(Path::new(MATRIX_FILE))?;
    let mut runner = Runner::new(mcfg.clone(), quick);
    if check_mode && !quick {
        // checks want fast point estimates, but the run is NOT quick —
        // workload scale is unchanged, so full-mode comparison is valid
        runner.set_iters(mcfg.quick_iters)?;
    }

    run_pure_cells(&mut runner)?;

    let have_artifacts = Path::new("artifacts/manifest.json").exists();
    if have_artifacts {
        run_artifact_cells(&mut runner)?;
    } else {
        println!(
            "runtime_micro: SKIP artifact-gated cells (artifact/*, round/t*, \
             traj/mask_density) — artifacts not built (`make artifacts`); \
             pure-Rust cells still measured"
        );
    }

    let fresh = runner.into_report();
    println!("\n== runtime_micro (matrix: {}) ==", MATRIX_FILE);
    for cell in fresh.cells.values() {
        if let Some(s) = &cell.stats {
            println!("{}  -> {:>12.2} units/s", s.report(), cell.throughput_per_s);
        }
    }

    if check_mode {
        match std::fs::read_to_string(TRACK_FILE) {
            Err(_) => println!(
                "check: no tracked {TRACK_FILE}; run the bench without --check to create it"
            ),
            Ok(text) => {
                let tracked = writer::read_tracked(&text)?;
                let out = check(&mcfg, &tracked, &fresh);
                println!("\n== regression gate ==\n{}", out.render());
                anyhow::ensure!(
                    !out.failed(),
                    "runtime_micro --check: regression gate failed (see notes above)"
                );
                println!("runtime_micro --check: gate passed");
            }
        }
    } else {
        if !have_artifacts {
            println!(
                "note: writing a pure-axes-only tracked file (artifact cells absent); \
                 --check on this file will SKIP them explicitly"
            );
        }
        writer::write_tracked(Path::new(TRACK_FILE), &fresh)?;
        println!("tracked results -> {TRACK_FILE}");
    }
    Ok(())
}
