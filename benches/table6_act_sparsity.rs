//! Table 6 — sparsifying the split activations with an L1 penalty (beta)
//! to shrink the upload payload on Mixed-CIFAR.
//!
//! Expected shape (paper §6.4): bandwidth falls monotonically (and
//! eventually collapses) as beta grows; accuracy degrades gracefully then
//! sharply at extreme beta. Compute is unchanged.

use adasplit::config::ExperimentConfig;
use adasplit::data::DatasetKind;
use adasplit::protocols::run_seeds;
use adasplit::report::ResultTable;
use adasplit::runtime::Runtime;
use adasplit::util::bench::bench_scale;

fn main() -> anyhow::Result<()> {
    let (rounds, samples, test, n_seeds) = bench_scale();
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();
    let rt = Runtime::load("artifacts")?;

    let base = ExperimentConfig::paper_default(DatasetKind::MixedCifar)
        .with_scale(rounds, samples, test);
    let mut table =
        ResultTable::new(format!("Table 6 — activation L1 sweep (R={rounds})"));

    // the paper's Table-6 grid
    let betas: [f32; 7] = [0.0, 1e-7, 1e-6, 5e-6, 1e-5, 1e-4, 1e-1];
    let mut bws = Vec::new();
    let mut compute = Vec::new();
    for beta in betas {
        let cfg = base.clone().with_beta(beta);
        let (r, std) = run_seeds(&rt, &cfg, &seeds)?;
        eprintln!(
            "beta={beta:<7}: acc={:.2}% bw={:.5}GB cC={:.4}T",
            r.best_accuracy, r.bandwidth_gb, r.client_tflops
        );
        bws.push(r.bandwidth_gb);
        compute.push(r.client_tflops);
        table.add(format!("beta={beta}"), &r, std);
    }

    // shape checks: bandwidth falls with beta (collapse needs full-scale
    // runs — see EXPERIMENTS.md); compute is untouched by the codec
    assert!(
        bws.last().unwrap() < bws.first().unwrap(),
        "strong beta must reduce the payload: {bws:?}"
    );
    for c in &compute {
        assert!((c - compute[0]).abs() / compute[0] < 1e-6, "compute must not change");
    }

    println!("\n{}", table.render());
    std::fs::create_dir_all("results")?;
    table.write_csv("results/table6_act_sparsity.csv")?;
    println!("-> results/table6_act_sparsity.csv");
    Ok(())
}
