//! Figure 1 — the headline trade-off chart: accuracy vs bandwidth and
//! accuracy vs client compute on Mixed-NonIID, AdaSplit operating points
//! (kappa x eta grid / mu sweep) against every baseline as fixed points.

use adasplit::config::{ExperimentConfig, ProtocolKind};
use adasplit::data::DatasetKind;
use adasplit::protocols::run_protocol;
use adasplit::report::series::ascii_chart;
use adasplit::report::Series;
use adasplit::runtime::Runtime;
use adasplit::util::bench::bench_scale;

fn main() -> anyhow::Result<()> {
    let (rounds, samples, test, _) = bench_scale();
    let rt = Runtime::load("artifacts")?;
    let base = ExperimentConfig::paper_default(DatasetKind::MixedNonIid)
        .with_scale(rounds, samples, test);

    // bandwidth axis: kappa controls traffic at fixed client compute
    let mut ada_bw = Series::new("AdaSplit", "bandwidth_gb");
    for kappa in [0.3, 0.5, 0.7, 0.9] {
        let r = run_protocol(&rt, &base.clone().with_kappa(kappa))?;
        eprintln!("kappa={kappa}: acc={:.2}% bw={:.4}GB", r.best_accuracy, r.bandwidth_gb);
        ada_bw.push(r.bandwidth_gb, r.best_accuracy);
    }
    // compute axis: eta at fixed kappa scales server work per iteration;
    // mu scales client compute
    let mut ada_c = Series::new("AdaSplit", "client_tflops");
    for eta in [0.2, 0.6, 1.0] {
        let r = run_protocol(&rt, &base.clone().with_eta(eta))?;
        eprintln!("eta={eta}: acc={:.2}% cC={:.4}T", r.best_accuracy, r.client_tflops);
        ada_c.push(r.client_tflops, r.best_accuracy);
    }

    let mut base_bw = Series::new("baselines", "bandwidth_gb");
    let mut base_c = Series::new("baselines", "client_tflops");
    for p in [
        ProtocolKind::SlBasic,
        ProtocolKind::SplitFed,
        ProtocolKind::FedAvg,
        ProtocolKind::FedProx,
        ProtocolKind::Scaffold,
        ProtocolKind::FedNova,
    ] {
        let r = run_protocol(&rt, &base.clone().with_protocol(p))?;
        eprintln!(
            "{:<9}: acc={:.2}% bw={:.4}GB cC={:.4}T",
            r.protocol, r.best_accuracy, r.bandwidth_gb, r.client_tflops
        );
        base_bw.push(r.bandwidth_gb, r.best_accuracy);
        base_c.push(r.client_tflops, r.best_accuracy);
    }

    println!("\n=== Figure 1 (left): accuracy vs bandwidth ===");
    print!("{}", ascii_chart(&[ada_bw.clone(), base_bw.clone()], 64, 16));
    println!("\n=== Figure 1 (right): accuracy vs client compute ===");
    print!("{}", ascii_chart(&[ada_c.clone(), base_c.clone()], 64, 16));

    std::fs::create_dir_all("results")?;
    std::fs::write("results/fig1_adasplit_bandwidth.csv", ada_bw.to_csv())?;
    std::fs::write("results/fig1_adasplit_compute.csv", ada_c.to_csv())?;
    std::fs::write("results/fig1_baselines_bandwidth.csv", base_bw.to_csv())?;
    std::fs::write("results/fig1_baselines_compute.csv", base_c.to_csv())?;
    println!("-> results/fig1_*.csv");
    Ok(())
}
