//! Table 4 — varying the local-phase duration kappa on Mixed-CIFAR.
//!
//! Expected shape (paper §6.2): bandwidth and server compute fall sharply
//! as kappa grows (fewer global-phase rounds); client compute is flat;
//! accuracy degrades mildly.

use adasplit::config::ExperimentConfig;
use adasplit::data::DatasetKind;
use adasplit::protocols::run_seeds;
use adasplit::report::ResultTable;
use adasplit::runtime::Runtime;
use adasplit::util::bench::bench_scale;

fn main() -> anyhow::Result<()> {
    let (rounds, samples, test, n_seeds) = bench_scale();
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();
    let rt = Runtime::load("artifacts")?;

    let base = ExperimentConfig::paper_default(DatasetKind::MixedCifar)
        .with_scale(rounds, samples, test);
    let mut table = ResultTable::new(format!("Table 4 — local phase kappa (R={rounds})"));

    let mut prev_bw = f64::INFINITY;
    let mut prev_total = f64::INFINITY;
    for kappa in [0.3, 0.45, 0.6, 0.75, 0.9] {
        let cfg = base.clone().with_kappa(kappa);
        let (r, std) = run_seeds(&rt, &cfg, &seeds)?;
        eprintln!(
            "kappa={kappa}: acc={:.2}% bw={:.4}GB total={:.4}T",
            r.best_accuracy, r.bandwidth_gb, r.total_tflops
        );
        assert!(r.bandwidth_gb <= prev_bw, "bandwidth must fall with kappa");
        assert!(
            r.total_tflops <= prev_total,
            "total (server) compute must fall with kappa"
        );
        prev_bw = r.bandwidth_gb;
        prev_total = r.total_tflops;
        table.add(format!("kappa={kappa}"), &r, std);
    }

    println!("\n{}", table.render());
    std::fs::create_dir_all("results")?;
    table.write_csv("results/table4_kappa.csv")?;
    println!("-> results/table4_kappa.csv");
    Ok(())
}
