//! Table 3 — varying the client model size mu on Mixed-CIFAR.
//!
//! Expected shape (paper §6.1): client compute rises monotonically with
//! mu; bandwidth falls (deeper split activations are smaller); accuracy is
//! roughly flat with mild degradation at large mu (smaller server to
//! collaborate in).

use adasplit::config::ExperimentConfig;
use adasplit::data::DatasetKind;
use adasplit::protocols::run_seeds;
use adasplit::report::ResultTable;
use adasplit::runtime::Runtime;
use adasplit::util::bench::bench_scale;

fn main() -> anyhow::Result<()> {
    let (rounds, samples, test, n_seeds) = bench_scale();
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();
    let rt = Runtime::load("artifacts")?;

    let base = ExperimentConfig::paper_default(DatasetKind::MixedCifar)
        .with_scale(rounds, samples, test);
    let mut table = ResultTable::new(format!("Table 3 — client size mu (R={rounds})"));

    let mut prev_compute = 0.0;
    let mut prev_bw = f64::INFINITY;
    for mu in [0.2, 0.4, 0.6, 0.8] {
        let cfg = base.clone().with_mu(mu);
        let (r, std) = run_seeds(&rt, &cfg, &seeds)?;
        eprintln!(
            "mu={mu}: acc={:.2}% bw={:.4}GB cC={:.4}T",
            r.best_accuracy, r.bandwidth_gb, r.client_tflops
        );
        assert!(
            r.client_tflops > prev_compute,
            "client compute must rise with mu"
        );
        assert!(r.bandwidth_gb < prev_bw, "bandwidth must fall with mu");
        prev_compute = r.client_tflops;
        prev_bw = r.bandwidth_gb;
        table.add(format!("mu={mu}"), &r, std);
    }

    println!("\n{}", table.render());
    std::fs::create_dir_all("results")?;
    table.write_csv("results/table3_mu.csv")?;
    println!("-> results/table3_mu.csv");
    Ok(())
}
