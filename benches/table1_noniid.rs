//! Table 1 — Mixed-NonIID main results: all 7 protocols + the second
//! AdaSplit operating point (kappa=0.75), reporting Accuracy / Bandwidth /
//! Compute (client, total) / C3-Score.
//!
//! `cargo bench --bench table1_noniid` (add `-- --quick` for a smoke run).
//! Absolute numbers differ from the paper (synthetic data, CPU substrate);
//! the reproduction target is the *shape*: AdaSplit above both SL and FL
//! baselines on C3 with ~3x lower client compute than FL and a fraction of
//! SL's bandwidth.

use adasplit::config::{ExperimentConfig, ProtocolKind};
use adasplit::data::DatasetKind;
use adasplit::protocols::run_seeds;
use adasplit::report::ResultTable;
use adasplit::runtime::Runtime;
use adasplit::util::bench::bench_scale;

fn main() -> anyhow::Result<()> {
    let (rounds, samples, test, n_seeds) = bench_scale();
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();
    let rt = Runtime::load("artifacts")?;

    let base = ExperimentConfig::paper_default(DatasetKind::MixedNonIid)
        .with_scale(rounds, samples, test);
    let mut table = ResultTable::new(format!(
        "Table 1 — Mixed-NonIID (R={rounds}, {samples} samples/client)"
    ));

    for p in ProtocolKind::ALL {
        let cfg = base.clone().with_protocol(p);
        let t0 = std::time::Instant::now();
        let (r, std) = run_seeds(&rt, &cfg, &seeds)?;
        eprintln!("{:<22} {:>6.2}%  [{:.0}s]", p.name(), r.best_accuracy,
                  t0.elapsed().as_secs_f64());
        let label = if p == ProtocolKind::AdaSplit {
            "AdaSplit (k=.6, e=.6)".to_string()
        } else {
            p.name().to_string()
        };
        table.add(label, &r, std);
    }
    // second AdaSplit operating point from the paper's Table 1
    let cfg = base.clone().with_kappa(0.75);
    let (r, std) = run_seeds(&rt, &cfg, &seeds)?;
    table.add("AdaSplit (k=.75, e=.6)", &r, std);

    table.recompute_c3_measured(8.0);
    println!("\n{}", table.render());
    println!("(C3 uses measured budgets: B_max/C_max = worst baseline, paper §4.4)");
    println!("best by C3-Score: {}", table.best_by_c3().unwrap_or("-"));
    std::fs::create_dir_all("results")?;
    table.write_csv("results/table1_noniid.csv")?;
    println!("-> results/table1_noniid.csv");
    Ok(())
}
