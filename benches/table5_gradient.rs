//! Table 5 — kappa sweep on Mixed-NonIID with the server-gradient
//! ablation: row 1 trains the client with L_client only, row 2 with
//! L_client + the downloaded server gradient.
//!
//! Expected shape (paper §6.3): accuracy is largely insensitive to the
//! server gradient across every kappa, while its bandwidth column is ~2x
//! (activation-sized gradient flows back down).

use adasplit::config::ExperimentConfig;
use adasplit::data::DatasetKind;
use adasplit::protocols::run_seeds;
use adasplit::report::ResultTable;
use adasplit::runtime::Runtime;
use adasplit::util::bench::bench_scale;

fn main() -> anyhow::Result<()> {
    let (rounds, samples, test, n_seeds) = bench_scale();
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();
    let rt = Runtime::load("artifacts")?;

    let base = ExperimentConfig::paper_default(DatasetKind::MixedNonIid)
        .with_scale(rounds, samples, test);
    let mut table =
        ResultTable::new(format!("Table 5 — server-gradient ablation (R={rounds})"));

    for kappa in [0.3, 0.6, 0.9] {
        let cfg = base.clone().with_kappa(kappa);
        let (no_grad, std0) = run_seeds(&rt, &cfg, &seeds)?;

        let mut cfg_grad = base.clone().with_kappa(kappa);
        cfg_grad.server_grad_to_client = true;
        let (with_grad, std1) = run_seeds(&rt, &cfg_grad, &seeds)?;

        eprintln!(
            "kappa={kappa}: L_client {:.2}% @ {:.4}GB | +server-grad {:.2}% @ {:.4}GB",
            no_grad.best_accuracy,
            no_grad.bandwidth_gb,
            with_grad.best_accuracy,
            with_grad.bandwidth_gb
        );
        // (at --quick scale kappa=0.9 can leave zero global rounds: no
        // traffic either way, nothing to compare)
        if no_grad.bandwidth_gb > 0.0 {
            assert!(
                with_grad.bandwidth_gb > no_grad.bandwidth_gb * 1.5,
                "server gradient must roughly double the bandwidth"
            );
        }
        table.add(format!("k={kappa} L_client"), &no_grad, std0);
        table.add(format!("k={kappa} +serv-grad"), &with_grad, std1);
    }

    println!("\n{}", table.render());
    std::fs::create_dir_all("results")?;
    table.write_csv("results/table5_gradient.csv")?;
    println!("-> results/table5_gradient.csv");
    Ok(())
}
