//! Timestamped simulation events and the seeded min-heap that orders them.
//!
//! The event engine (DESIGN.md §11) replaces the round barrier with a
//! discrete-event loop: everything that happens — a client's work unit
//! completing, a server merge, an eval point, an adaptation-window
//! boundary — is an [`Event`] popped off one [`EventHeap`]. Determinism
//! across thread counts and repeat invocations reduces to one property:
//! the heap's drain order is a **total** order, a pure function of the
//! event set. Two events never compare "equal enough to race":
//!
//! * primary key — virtual time, compared as IEEE bits. Event times are
//!   non-negative finite (asserted on push), and for non-negative finite
//!   doubles the bit pattern orders exactly like the float, so the
//!   comparison is both correct and bit-stable;
//! * secondary key — the event-kind rank: at one instant, the *scenario*
//!   events that reshape the world land first — a fleet join
//!   ([`EventKind::ClientJoin`], rank 0), a departure
//!   ([`EventKind::ClientLeave`], rank 1), a rate episode boundary
//!   ([`EventKind::RateChange`], rank 2) — then the engine acts in the
//!   reshaped world: client arrivals ([`EventKind::ClientFinish`],
//!   rank 3), the merge that consumes them ([`EventKind::ServerMerge`],
//!   rank 4), the eval that observes the merged state
//!   ([`EventKind::Eval`], rank 5), and the controller switch that may
//!   re-aim the *next* window ([`EventKind::ControllerSwitch`], rank 6)
//!   — the causal order of the round loop, made explicit. Scenario
//!   ranks sit *below* `ClientFinish` so that a departure at instant t
//!   cancels a finish at t (the finish drains after the leave and is
//!   discarded as stale), never the other way around (DESIGN.md §12);
//! * tertiary key — the client id (scenario events and arrivals) or
//!   merge index (server events), so same-kind same-time events drain
//!   in id order, matching the ascending-client-id merge convention
//!   everywhere else (DESIGN.md §5).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// What a popped event means to the driver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Scenario: client `client` (re-)enters the fleet and starts a
    /// fresh work unit at the event instant (DESIGN.md §12).
    ClientJoin { client: usize },
    /// Scenario: client `client` departs; its in-flight work unit and
    /// any pending (finished, unmerged) update are discarded.
    ClientLeave { client: usize },
    /// Scenario: client `client`'s effective rate changes (flaky-link
    /// episode boundary, or a replayed trace line); its pending
    /// `ClientFinish` is re-timed.
    RateChange { client: usize },
    /// Client `client`'s in-flight work unit completes (its update is
    /// now pending at the server).
    ClientFinish { client: usize },
    /// Server merge number `merge` fires: fold pending updates in.
    ServerMerge { merge: usize },
    /// Observe the state after merge `merge`: eval cadence + recording.
    Eval { merge: usize },
    /// Adaptation-window boundary after merge `merge`: the bound
    /// controller credits the window and may switch arms.
    ControllerSwitch { merge: usize },
}

impl EventKind {
    /// Same-instant drain rank: scenario (join < leave < rate) <
    /// arrivals < merge < eval < switch.
    pub fn rank(&self) -> u8 {
        match self {
            EventKind::ClientJoin { .. } => 0,
            EventKind::ClientLeave { .. } => 1,
            EventKind::RateChange { .. } => 2,
            EventKind::ClientFinish { .. } => 3,
            EventKind::ServerMerge { .. } => 4,
            EventKind::Eval { .. } => 5,
            EventKind::ControllerSwitch { .. } => 6,
        }
    }

    /// Same-kind same-instant tie-break: client id for scenario events
    /// and arrivals, merge index for server-side events.
    fn index(&self) -> usize {
        match *self {
            EventKind::ClientJoin { client }
            | EventKind::ClientLeave { client }
            | EventKind::RateChange { client }
            | EventKind::ClientFinish { client } => client,
            EventKind::ServerMerge { merge }
            | EventKind::Eval { merge }
            | EventKind::ControllerSwitch { merge } => merge,
        }
    }
}

/// One timestamped simulation event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    /// Virtual time, in baseline-round units. Non-negative finite — the
    /// heap asserts this, because the bit-pattern comparison below is
    /// only order-preserving on that domain.
    pub time: f64,
    pub kind: EventKind,
}

impl Event {
    pub fn new(time: f64, kind: EventKind) -> Self {
        Self { time, kind }
    }

    /// The (time-bits, kind-rank, id) total-order key (DESIGN.md §11).
    pub fn key(&self) -> (u64, u8, usize) {
        (self.time.to_bits(), self.kind.rank(), self.kind.index())
    }
}

/// Keyed wrapper so the `BinaryHeap` orders by the deterministic key
/// alone. `Ord` and `Eq` both look only at the key, and the key
/// determines the event in every driver schedule (two distinct pending
/// events never share (time, rank, id)), so the ordering is consistent.
#[derive(Clone, Copy, Debug)]
struct Keyed(Event);

impl PartialEq for Keyed {
    fn eq(&self, other: &Self) -> bool {
        self.0.key() == other.0.key()
    }
}

impl Eq for Keyed {}

impl PartialOrd for Keyed {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Keyed {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.key().cmp(&other.0.key())
    }
}

/// Min-heap of pending events with deterministic total-order drain.
///
/// Insertion order is irrelevant by construction: `pop` always returns
/// the minimum (time, rank, id) key, so any permutation of the same
/// pushes drains identically (pinned by the `event_heap_*` suite).
#[derive(Debug, Default)]
pub struct EventHeap {
    heap: BinaryHeap<Reverse<Keyed>>,
    popped: usize,
}

impl EventHeap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Events popped so far (the run's `events_processed` counter).
    pub fn popped(&self) -> usize {
        self.popped
    }

    pub fn push(&mut self, event: Event) {
        assert!(
            event.time.is_finite() && event.time >= 0.0,
            "event time must be non-negative finite, got {} for {:?} \
             (bit-pattern ordering is only valid on that domain)",
            event.time,
            event.kind
        );
        self.heap.push(Reverse(Keyed(event)));
    }

    /// The earliest pending event under the total order.
    pub fn pop(&mut self) -> Option<Event> {
        let e = self.heap.pop().map(|Reverse(Keyed(e))| e);
        if e.is_some() {
            self.popped += 1;
        }
        e
    }

    /// Peek the next event without removing it.
    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek().map(|Reverse(Keyed(e))| e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finish(time: f64, client: usize) -> Event {
        Event::new(time, EventKind::ClientFinish { client })
    }

    fn merge(time: f64, m: usize) -> Event {
        Event::new(time, EventKind::ServerMerge { merge: m })
    }

    #[test]
    fn event_heap_pops_in_time_order() {
        let mut h = EventHeap::new();
        h.push(finish(3.0, 0));
        h.push(finish(1.0, 1));
        h.push(finish(2.0, 2));
        let order: Vec<f64> = std::iter::from_fn(|| h.pop()).map(|e| e.time).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
        assert_eq!(h.popped(), 3);
    }

    #[test]
    fn event_heap_simultaneous_events_drain_in_kind_then_id_order() {
        // at one instant: every arrival, then the merge, then eval, then
        // the controller — and arrivals in ascending client id
        let t = 4.25;
        let simultaneous = vec![
            Event::new(t, EventKind::ControllerSwitch { merge: 7 }),
            finish(t, 9),
            Event::new(t, EventKind::Eval { merge: 7 }),
            finish(t, 2),
            merge(t, 7),
            finish(t, 5),
        ];
        let expect: Vec<EventKind> = vec![
            EventKind::ClientFinish { client: 2 },
            EventKind::ClientFinish { client: 5 },
            EventKind::ClientFinish { client: 9 },
            EventKind::ServerMerge { merge: 7 },
            EventKind::Eval { merge: 7 },
            EventKind::ControllerSwitch { merge: 7 },
        ];
        // any insertion order drains the same way: try rotations and the
        // reversal (deterministic permutations, no ambient randomness)
        for shift in 0..simultaneous.len() {
            let mut h = EventHeap::new();
            for i in 0..simultaneous.len() {
                h.push(simultaneous[(i + shift) % simultaneous.len()]);
            }
            let got: Vec<EventKind> =
                std::iter::from_fn(|| h.pop()).map(|e| e.kind).collect();
            assert_eq!(got, expect, "rotation {shift}");
        }
        let mut h = EventHeap::new();
        for e in simultaneous.iter().rev() {
            h.push(*e);
        }
        let got: Vec<EventKind> = std::iter::from_fn(|| h.pop()).map(|e| e.kind).collect();
        assert_eq!(got, expect, "reversed insertion");
    }

    #[test]
    fn event_heap_scenario_events_drain_before_engine_events_at_one_instant() {
        // DESIGN.md §12: at one instant the scenario reshapes the world
        // first (join < leave < rate), then the engine acts in it — so a
        // same-instant departure cancels the client's finish, never the
        // other way around
        let t = 2.5;
        let simultaneous = vec![
            Event::new(t, EventKind::ServerMerge { merge: 3 }),
            Event::new(t, EventKind::RateChange { client: 4 }),
            finish(t, 1),
            Event::new(t, EventKind::ClientLeave { client: 1 }),
            Event::new(t, EventKind::ClientJoin { client: 6 }),
            Event::new(t, EventKind::RateChange { client: 0 }),
        ];
        let expect = vec![
            EventKind::ClientJoin { client: 6 },
            EventKind::ClientLeave { client: 1 },
            EventKind::RateChange { client: 0 },
            EventKind::RateChange { client: 4 },
            EventKind::ClientFinish { client: 1 },
            EventKind::ServerMerge { merge: 3 },
        ];
        for shift in 0..simultaneous.len() {
            let mut h = EventHeap::new();
            for i in 0..simultaneous.len() {
                h.push(simultaneous[(i + shift) % simultaneous.len()]);
            }
            let got: Vec<EventKind> =
                std::iter::from_fn(|| h.pop()).map(|e| e.kind).collect();
            assert_eq!(got, expect, "rotation {shift}");
        }
    }

    #[test]
    fn event_heap_scenario_ranks_preserve_the_engine_relative_order() {
        // inserting the scenario ranks must not perturb the pinned
        // relative order of the four engine kinds
        let kinds = [
            EventKind::ClientJoin { client: 0 },
            EventKind::ClientLeave { client: 0 },
            EventKind::RateChange { client: 0 },
            EventKind::ClientFinish { client: 0 },
            EventKind::ServerMerge { merge: 0 },
            EventKind::Eval { merge: 0 },
            EventKind::ControllerSwitch { merge: 0 },
        ];
        for w in kinds.windows(2) {
            assert!(w[0].rank() < w[1].rank(), "{:?} < {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn event_heap_time_dominates_kind_rank() {
        // a later arrival never jumps an earlier merge, rank notwithstanding
        let mut h = EventHeap::new();
        h.push(finish(2.0, 0));
        h.push(merge(1.0, 0));
        assert_eq!(h.pop().unwrap().kind, EventKind::ServerMerge { merge: 0 });
        assert_eq!(h.pop().unwrap().kind, EventKind::ClientFinish { client: 0 });
    }

    #[test]
    fn event_heap_orders_denormal_and_close_times_like_the_floats() {
        // bit-pattern ordering must agree with float ordering across the
        // tricky non-negative cases: 0.0, denormals, and 1-ulp neighbors
        let times = [0.0, f64::MIN_POSITIVE / 2.0, 1.0, 1.0 + f64::EPSILON, 1e300];
        let mut h = EventHeap::new();
        for (i, &t) in times.iter().rev().enumerate() {
            h.push(finish(t, i));
        }
        let drained: Vec<f64> = std::iter::from_fn(|| h.pop()).map(|e| e.time).collect();
        let mut sorted = times.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(drained, sorted);
    }

    #[test]
    #[should_panic(expected = "non-negative finite")]
    fn event_heap_rejects_nan_times() {
        EventHeap::new().push(finish(f64::NAN, 0));
    }

    #[test]
    #[should_panic(expected = "non-negative finite")]
    fn event_heap_rejects_negative_times() {
        EventHeap::new().push(finish(-1.0, 0));
    }

    #[test]
    fn event_heap_peek_does_not_advance() {
        let mut h = EventHeap::new();
        h.push(finish(1.0, 3));
        assert_eq!(h.peek().unwrap().kind, EventKind::ClientFinish { client: 3 });
        assert_eq!(h.len(), 1);
        assert_eq!(h.popped(), 0);
        assert!(h.pop().is_some());
        assert!(h.is_empty());
    }
}
