//! The scenario engine: seeded churn, time-varying rates, and the
//! deterministic JSONL trace format (DESIGN.md §12).
//!
//! Every run used to be closed-world — a fixed fleet, a static speed
//! distribution. This module opens it up on the event core: fleet
//! membership and per-client rates become *scenario events*
//! ([`EventKind::ClientJoin`] / [`EventKind::ClientLeave`] /
//! [`EventKind::RateChange`]) with their own ranks in the §11 total
//! order, so an open-world run is exactly as deterministic as a closed
//! one. Three sources of dynamics:
//!
//! * **Churn** (`--churn join:λ,leave:μ`): Poisson join/leave processes
//!   with exponential gaps. A departure discards the client's in-flight
//!   work and pending update (delayed-gradient versioning, DESIGN.md §8,
//!   already defines what that work meant); a join restarts the client
//!   fresh — its shard materializes through `Partition`'s lazy
//!   first-touch path, and its staleness base rebases so it can never
//!   owe merges from its absence.
//! * **Time-varying rates** (`--rate-schedule diurnal:P:A+flaky:R:S:L`):
//!   a diurnal speed curve sampled at work-unit start, plus seeded
//!   flaky-link episodes that slow one client sharply and *re-time its
//!   pending `ClientFinish`* through [`EventKind::RateChange`].
//! * **Trace replay** (`--trace-in`): a recorded (or hand-synthesized)
//!   JSONL stream of effective scenario events, replayed verbatim.
//!
//! ## Determinism
//!
//! The synthesized stream is a pure function of `(seed, spec, n)` and
//! nothing else: process gaps and victims come from derived [`Rng`]
//! streams, guards (never-empty fleet, join-targets-absent,
//! one-episode-per-client) read only scenario-internal state, and the
//! protocol/merge policy never feed back into the stream. Hence the
//! same config records the same `--trace-out` bytes under any protocol
//! or merge policy, and a replayed trace drives any run bit-identically
//! across thread counts and repeat invocations.

use std::collections::BTreeMap;
use std::str::FromStr;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::event::{Event, EventHeap, EventKind};
use crate::config::ExperimentConfig;
use crate::data::Rng;
use crate::driver::diurnal_multiplier;
use crate::util::Json;

/// Trace header `format` field — refuses to replay foreign JSONL.
pub const TRACE_FORMAT: &str = "adasplit-scenario";
/// Trace header `version` field — bump on any line-format change.
pub const TRACE_VERSION: usize = 1;

/// Seeded fleet churn (`--churn join:λ,leave:μ`): Poisson join and
/// leave processes, rates in events per unit of virtual time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnSpec {
    pub join: f64,
    pub leave: f64,
}

impl ChurnSpec {
    /// CLI/config id (`join:0.5,leave:0.3`), parse-roundtrip stable.
    pub fn id(&self) -> String {
        format!("join:{},leave:{}", self.join, self.leave)
    }
}

impl FromStr for ChurnSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let mut join = 0.0f64;
        let mut leave = 0.0f64;
        let mut seen = false;
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("churn part `{part}` (expected join:RATE or leave:RATE)"))?;
            let rate: f64 = val
                .parse()
                .map_err(|e| anyhow!("churn rate `{val}`: {e}"))?;
            ensure!(
                rate.is_finite() && rate >= 0.0,
                "churn rate must be non-negative finite, got {rate}"
            );
            match key {
                "join" => join = rate,
                "leave" => leave = rate,
                other => bail!("unknown churn key `{other}` (expected join | leave)"),
            }
            seen = true;
        }
        ensure!(
            seen && join + leave > 0.0,
            "churn spec `{s}` names no positive rate (expected e.g. join:0.5,leave:0.3)"
        );
        Ok(Self { join, leave })
    }
}

/// Diurnal speed curve: multiplier `1 + A*sin(2πt/P)` applied to work
/// units at start time (see [`diurnal_multiplier`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DiurnalSpec {
    pub period: f64,
    pub amplitude: f64,
}

/// Seeded flaky-link episodes: a Poisson process (rate `R`) picks a
/// victim, slows it by `S`x for an exponential episode (mean `L`), and
/// re-times its pending finish at both episode boundaries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlakySpec {
    pub rate: f64,
    pub slowdown: f64,
    pub mean_len: f64,
}

/// `--rate-schedule diurnal:P:A`, `flaky:R:S:L`, or both joined by `+`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RateScheduleSpec {
    pub diurnal: Option<DiurnalSpec>,
    pub flaky: Option<FlakySpec>,
}

impl RateScheduleSpec {
    /// CLI/config id, parse-roundtrip stable.
    pub fn id(&self) -> String {
        let mut parts = Vec::new();
        if let Some(d) = self.diurnal {
            parts.push(format!("diurnal:{}:{}", d.period, d.amplitude));
        }
        if let Some(f) = self.flaky {
            parts.push(format!("flaky:{}:{}:{}", f.rate, f.slowdown, f.mean_len));
        }
        parts.join("+")
    }
}

impl FromStr for RateScheduleSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let mut spec = RateScheduleSpec::default();
        for part in s.split('+') {
            let part = part.trim();
            if let Some(rest) = part.strip_prefix("diurnal:") {
                ensure!(spec.diurnal.is_none(), "duplicate diurnal part in `{s}`");
                let (p, a) = rest
                    .split_once(':')
                    .ok_or_else(|| anyhow!("diurnal spec `{part}` (expected diurnal:PERIOD:AMPLITUDE)"))?;
                let period: f64 = p.parse().map_err(|e| anyhow!("diurnal period `{p}`: {e}"))?;
                let amplitude: f64 =
                    a.parse().map_err(|e| anyhow!("diurnal amplitude `{a}`: {e}"))?;
                ensure!(
                    period.is_finite() && period > 0.0,
                    "diurnal period must be positive finite, got {period}"
                );
                ensure!(
                    amplitude > 0.0 && amplitude < 1.0,
                    "diurnal amplitude must be in (0, 1), got {amplitude}"
                );
                spec.diurnal = Some(DiurnalSpec { period, amplitude });
            } else if let Some(rest) = part.strip_prefix("flaky:") {
                ensure!(spec.flaky.is_none(), "duplicate flaky part in `{s}`");
                let fields: Vec<&str> = rest.split(':').collect();
                ensure!(
                    fields.len() == 3,
                    "flaky spec `{part}` (expected flaky:RATE:SLOWDOWN:MEAN_LEN)"
                );
                let rate: f64 = fields[0]
                    .parse()
                    .map_err(|e| anyhow!("flaky rate `{}`: {e}", fields[0]))?;
                let slowdown: f64 = fields[1]
                    .parse()
                    .map_err(|e| anyhow!("flaky slowdown `{}`: {e}", fields[1]))?;
                let mean_len: f64 = fields[2]
                    .parse()
                    .map_err(|e| anyhow!("flaky mean length `{}`: {e}", fields[2]))?;
                ensure!(
                    rate.is_finite() && rate > 0.0,
                    "flaky rate must be positive finite, got {rate}"
                );
                ensure!(
                    slowdown.is_finite() && slowdown > 1.0,
                    "flaky slowdown must be > 1 (it slows the link), got {slowdown}"
                );
                ensure!(
                    mean_len.is_finite() && mean_len > 0.0,
                    "flaky mean length must be positive finite, got {mean_len}"
                );
                spec.flaky = Some(FlakySpec { rate, slowdown, mean_len });
            } else {
                bail!(
                    "unknown rate-schedule part `{part}` \
                     (expected diurnal:P:A | flaky:R:S:L, joined by `+`)"
                );
            }
        }
        ensure!(
            spec.diurnal.is_some() || spec.flaky.is_some(),
            "rate schedule `{s}` is empty"
        );
        Ok(spec)
    }
}

/// One effective scenario event — the unit of the JSONL trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    pub time: f64,
    pub kind: TraceKind,
    pub client: usize,
}

/// What an effective scenario event did.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceKind {
    Join,
    Leave,
    /// The client's new speed multiplier (work-unit durations divide by
    /// it; `1.0` restores the base rate).
    Rate { mul: f64 },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Source {
    Synthetic,
    Replay,
}

/// One self-perpetuating Poisson process: its derived rng stream and
/// rate. Each popped process event draws the gap and victim of the next.
struct Proc {
    rng: Rng,
    rate: f64,
}

/// Exponential inter-event gap via inverse CDF, floored so two events
/// of one process can never collide at the same instant (`u = 0` would
/// otherwise yield a zero gap).
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    (-(1.0 - rng.next_f64()).ln() / rate).max(1e-9)
}

/// The scenario state machine the event driver consults: it resolves
/// popped scenario events into effects (guarded by scenario-internal
/// state only), schedules each process's successor event, and records
/// the effective stream for `--trace-out`.
pub struct Scenario {
    n: usize,
    source: Source,
    /// Scenario-side fleet membership. The [`ContinuousPolicy`] keeps
    /// its own mirror for merge bookkeeping; the driver applies every
    /// effective event to both, so they never diverge.
    ///
    /// [`ContinuousPolicy`]: super::policy::ContinuousPolicy
    active: Vec<bool>,
    /// Flaky-episode state: `Some(end-time bits)` while degraded. The
    /// end-time bits disambiguate a popped `RateChange` (episode end vs
    /// a new episode-start tick) without any payload in the event.
    restore_at: Vec<Option<u64>>,
    /// The one outstanding episode-start tick `(time bits, victim)` —
    /// used to keep a scheduled episode *end* from colliding with it.
    next_start: Option<(u64, usize)>,
    diurnal: Option<DiurnalSpec>,
    flaky: Option<FlakySpec>,
    join: Option<Proc>,
    leave: Option<Proc>,
    flaky_proc: Option<Proc>,
    replay: Vec<TraceEvent>,
    replay_next: usize,
    /// Effective events in drain order — the `--trace-out` payload.
    applied: Vec<TraceEvent>,
    joins: usize,
    leaves: usize,
    rates: usize,
}

impl Scenario {
    /// Build the run's scenario from its config: a trace replay when
    /// `--trace-in` is set, a seeded synthesis when churn or a rate
    /// schedule is, an inert recorder when only `--trace-out` is, and
    /// `None` for the (default) closed-world run.
    pub fn from_cfg(cfg: &ExperimentConfig) -> Result<Option<Scenario>> {
        let wants = cfg.churn.is_some()
            || cfg.rate_schedule.is_some()
            || cfg.trace_in.is_some()
            || cfg.trace_out.is_some();
        if !wants {
            return Ok(None);
        }
        if let Some(path) = &cfg.trace_in {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading scenario trace {path}"))?;
            return Ok(Some(Self::replay(cfg.clients, &text)?));
        }
        Ok(Some(Self::synth(
            cfg.clients,
            cfg.churn,
            cfg.rate_schedule.unwrap_or_default(),
            cfg.seed,
        )))
    }

    /// Seeded synthesis. The whole fleet starts active; each configured
    /// process gets its own derived rng stream.
    pub fn synth(
        n: usize,
        churn: Option<ChurnSpec>,
        rates: RateScheduleSpec,
        seed: u64,
    ) -> Scenario {
        let root = Rng::new(seed);
        let proc_for = |tag: &str, rate: f64| {
            (rate > 0.0).then(|| Proc { rng: root.derive(tag, 0), rate })
        };
        Scenario {
            n,
            source: Source::Synthetic,
            active: vec![true; n],
            restore_at: vec![None; n],
            next_start: None,
            diurnal: rates.diurnal,
            flaky: rates.flaky,
            join: churn.and_then(|c| proc_for("scenario-join", c.join)),
            leave: churn.and_then(|c| proc_for("scenario-leave", c.leave)),
            flaky_proc: rates.flaky.and_then(|f| proc_for("scenario-flaky", f.rate)),
            replay: Vec::new(),
            replay_next: 0,
            applied: Vec::new(),
            joins: 0,
            leaves: 0,
            rates: 0,
        }
    }

    /// Parse a recorded JSONL trace for replay. Validates the header,
    /// every line's fields, and non-decreasing times.
    pub fn replay(n: usize, text: &str) -> Result<Scenario> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header_line = lines
            .next()
            .ok_or_else(|| anyhow!("scenario trace is empty (missing header line)"))?;
        let header =
            Json::parse(header_line).context("scenario trace header is not valid JSON")?;
        ensure!(
            header.get("format")?.as_str()? == TRACE_FORMAT,
            "scenario trace header: format must be `{TRACE_FORMAT}`"
        );
        ensure!(
            header.get("version")?.as_usize()? == TRACE_VERSION,
            "scenario trace header: unsupported version (expected {TRACE_VERSION})"
        );
        let mut replay = Vec::new();
        let mut last_bits = 0u64;
        for (i, line) in lines.enumerate() {
            let lineno = i + 2;
            let j = Json::parse(line)
                .with_context(|| format!("scenario trace line {lineno}"))?;
            let time = j.get("t")?.as_f64()?;
            ensure!(
                time.is_finite() && time >= 0.0,
                "scenario trace line {lineno}: time must be non-negative finite, got {time}"
            );
            ensure!(
                time.to_bits() >= last_bits,
                "scenario trace line {lineno}: time regressed"
            );
            last_bits = time.to_bits();
            let client = j.get("client")?.as_usize()?;
            ensure!(
                client < n,
                "scenario trace line {lineno}: client {client} out of range (fleet size {n})"
            );
            let kind = match j.get("ev")?.as_str()? {
                "join" => TraceKind::Join,
                "leave" => TraceKind::Leave,
                "rate" => {
                    let mul = j.get("mul")?.as_f64()?;
                    ensure!(
                        mul.is_finite() && mul > 0.0,
                        "scenario trace line {lineno}: rate mul must be positive finite, got {mul}"
                    );
                    TraceKind::Rate { mul }
                }
                other => bail!("scenario trace line {lineno}: unknown ev `{other}`"),
            };
            replay.push(TraceEvent { time, kind, client });
        }
        Ok(Scenario {
            n,
            source: Source::Replay,
            active: vec![true; n],
            restore_at: vec![None; n],
            next_start: None,
            diurnal: None,
            flaky: None,
            join: None,
            leave: None,
            flaky_proc: None,
            replay,
            replay_next: 0,
            applied: Vec::new(),
            joins: 0,
            leaves: 0,
            rates: 0,
        })
    }

    /// Push the stream's head onto the heap: the first event of each
    /// synthesis process, or the first recorded trace line. Replay
    /// events enter one at a time (each pop pushes its successor), so
    /// the recorded drain order is preserved verbatim.
    pub fn prime(&mut self, heap: &mut EventHeap) {
        match self.source {
            Source::Replay => self.push_replay_head(heap),
            Source::Synthetic => {
                if let Some(p) = self.join.as_mut() {
                    let gap = exp_gap(&mut p.rng, p.rate);
                    let victim = p.rng.below(self.n);
                    heap.push(Event::new(gap, EventKind::ClientJoin { client: victim }));
                }
                if let Some(p) = self.leave.as_mut() {
                    let gap = exp_gap(&mut p.rng, p.rate);
                    let victim = p.rng.below(self.n);
                    heap.push(Event::new(gap, EventKind::ClientLeave { client: victim }));
                }
                if let Some(p) = self.flaky_proc.as_mut() {
                    let gap = exp_gap(&mut p.rng, p.rate);
                    let victim = p.rng.below(self.n);
                    self.next_start = Some((gap.to_bits(), victim));
                    heap.push(Event::new(gap, EventKind::RateChange { client: victim }));
                }
            }
        }
    }

    fn push_replay_head(&mut self, heap: &mut EventHeap) {
        if let Some(ev) = self.replay.get(self.replay_next) {
            let kind = match ev.kind {
                TraceKind::Join => EventKind::ClientJoin { client: ev.client },
                TraceKind::Leave => EventKind::ClientLeave { client: ev.client },
                TraceKind::Rate { .. } => EventKind::RateChange { client: ev.client },
            };
            heap.push(Event::new(ev.time, kind));
        }
    }

    /// Consume the replay cursor's event (the one that just popped) and
    /// push its successor.
    fn advance_replay(&mut self, heap: &mut EventHeap) -> TraceEvent {
        let ev = self.replay[self.replay_next];
        self.replay_next += 1;
        self.push_replay_head(heap);
        ev
    }

    /// A popped `ClientJoin { client }` at `t`: schedule the process's
    /// next event, then apply — only an absent client can (re-)join.
    /// Returns whether the join took effect.
    pub fn on_join(&mut self, client: usize, t: f64, heap: &mut EventHeap) -> bool {
        if self.source == Source::Replay {
            let ev = self.advance_replay(heap);
            debug_assert_eq!((ev.client, ev.time.to_bits()), (client, t.to_bits()));
        } else if let Some(p) = self.join.as_mut() {
            let gap = exp_gap(&mut p.rng, p.rate);
            let victim = p.rng.below(self.n);
            heap.push(Event::new(t + gap, EventKind::ClientJoin { client: victim }));
        }
        if self.active[client] {
            return false;
        }
        self.active[client] = true;
        self.record(TraceEvent { time: t, kind: TraceKind::Join, client });
        true
    }

    /// A popped `ClientLeave { client }` at `t`: schedule the process's
    /// next event, then apply — the last active client can never leave
    /// (the never-empty-merge contract needs someone in flight). Returns
    /// whether the departure took effect.
    pub fn on_leave(&mut self, client: usize, t: f64, heap: &mut EventHeap) -> bool {
        if self.source == Source::Replay {
            let ev = self.advance_replay(heap);
            debug_assert_eq!((ev.client, ev.time.to_bits()), (client, t.to_bits()));
        } else if let Some(p) = self.leave.as_mut() {
            let gap = exp_gap(&mut p.rng, p.rate);
            let victim = p.rng.below(self.n);
            heap.push(Event::new(t + gap, EventKind::ClientLeave { client: victim }));
        }
        let active_count = self.active.iter().filter(|&&a| a).count();
        if !self.active[client] || active_count <= 1 {
            return false;
        }
        self.active[client] = false;
        self.record(TraceEvent { time: t, kind: TraceKind::Leave, client });
        true
    }

    /// A popped `RateChange { client }` at `t`. In synthesis this is
    /// either the end of `client`'s degraded episode (matched by the
    /// stored end-time bits) or an episode-start tick of the flaky
    /// process; in replay it is the recorded multiplier verbatim.
    /// Returns the client's new speed multiplier when one applies.
    pub fn on_rate(&mut self, client: usize, t: f64, heap: &mut EventHeap) -> Option<f64> {
        if self.source == Source::Replay {
            let ev = self.advance_replay(heap);
            debug_assert_eq!((ev.client, ev.time.to_bits()), (client, t.to_bits()));
            let TraceKind::Rate { mul } = ev.kind else {
                debug_assert!(false, "replay cursor kind mismatch");
                return None;
            };
            self.record(TraceEvent { time: t, kind: TraceKind::Rate { mul }, client });
            return Some(mul);
        }
        let flaky = self.flaky?;
        if self.restore_at[client] == Some(t.to_bits()) {
            // episode end: restore the base rate
            self.restore_at[client] = None;
            self.record(TraceEvent { time: t, kind: TraceKind::Rate { mul: 1.0 }, client });
            return Some(1.0);
        }
        // episode-start tick. Draw this episode's length and the tick's
        // successor from the process stream unconditionally, so the
        // stream stays a pure function of the seed even when the tick
        // fizzles (victim already degraded).
        let (len, next) = match self.flaky_proc.as_mut() {
            Some(p) => {
                let len = exp_gap(&mut p.rng, 1.0 / flaky.mean_len);
                let gap = exp_gap(&mut p.rng, p.rate);
                let victim = p.rng.below(self.n);
                (len, Some((t + gap, victim)))
            }
            None => (flaky.mean_len, None),
        };
        if let Some((mut start, victim)) = next {
            // a start landing exactly on the victim's scheduled episode
            // end would share its (time, rank, id) key and be misread as
            // the end — bump one ulp (measure-zero with continuous
            // draws; the guard makes it impossible)
            if self.restore_at[victim] == Some(start.to_bits()) {
                start = f64::from_bits(start.to_bits() + 1);
            }
            self.next_start = Some((start.to_bits(), victim));
            heap.push(Event::new(start, EventKind::RateChange { client: victim }));
        }
        if self.restore_at[client].is_some() {
            return None;
        }
        let mut end = t + len;
        // symmetric key guard: the end must not collide with the one
        // outstanding start tick either
        if self.next_start == Some((end.to_bits(), client)) {
            end = f64::from_bits(end.to_bits() + 1);
        }
        self.restore_at[client] = Some(end.to_bits());
        heap.push(Event::new(end, EventKind::RateChange { client }));
        let mul = 1.0 / flaky.slowdown;
        self.record(TraceEvent { time: t, kind: TraceKind::Rate { mul }, client });
        Some(mul)
    }

    /// The diurnal speed multiplier at virtual time `t`, applied to
    /// work units at start time. Exactly `1.0` with no diurnal schedule
    /// (and at `t = 0`), so static runs stay bit-identical.
    pub fn diurnal_scale(&self, t: f64) -> f64 {
        match self.diurnal {
            Some(d) => diurnal_multiplier(t, d.period, d.amplitude),
            None => 1.0,
        }
    }

    /// `synthetic` or `replay` — the `RunResult::scenario` label.
    pub fn source_id(&self) -> &'static str {
        match self.source {
            Source::Synthetic => "synthetic",
            Source::Replay => "replay",
        }
    }

    /// Effective (joins, leaves, rate changes) applied so far.
    pub fn counts(&self) -> (usize, usize, usize) {
        (self.joins, self.leaves, self.rates)
    }

    /// The effective event stream, in drain order.
    pub fn applied(&self) -> &[TraceEvent] {
        &self.applied
    }

    /// Scenario-side membership view (the policy mirrors it).
    pub fn is_active(&self, client: usize) -> bool {
        self.active[client]
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    fn record(&mut self, ev: TraceEvent) {
        match ev.kind {
            TraceKind::Join => self.joins += 1,
            TraceKind::Leave => self.leaves += 1,
            TraceKind::Rate { .. } => self.rates += 1,
        }
        self.applied.push(ev);
    }

    /// Serialize the effective stream as JSONL: one header line, then
    /// one compact object per event. `f64` times and multipliers
    /// round-trip exactly through the shortest-representation number
    /// writer, so parsing this text back replays bit-identically.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let mut header = BTreeMap::new();
        header.insert("clients".to_string(), Json::Num(self.n as f64));
        header.insert("format".to_string(), Json::Str(TRACE_FORMAT.to_string()));
        header.insert("version".to_string(), Json::Num(TRACE_VERSION as f64));
        out.push_str(&Json::Obj(header).to_string_compact());
        out.push('\n');
        for ev in &self.applied {
            let mut o = BTreeMap::new();
            o.insert("client".to_string(), Json::Num(ev.client as f64));
            o.insert("t".to_string(), Json::Num(ev.time));
            match ev.kind {
                TraceKind::Join => {
                    o.insert("ev".to_string(), Json::Str("join".to_string()));
                }
                TraceKind::Leave => {
                    o.insert("ev".to_string(), Json::Str("leave".to_string()));
                }
                TraceKind::Rate { mul } => {
                    o.insert("ev".to_string(), Json::Str("rate".to_string()));
                    o.insert("mul".to_string(), Json::Num(mul));
                }
            }
            out.push_str(&Json::Obj(o).to_string_compact());
            out.push('\n');
        }
        out
    }

    /// Write the effective stream to `path` (`--trace-out`).
    pub fn write_trace(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_jsonl())
            .with_context(|| format!("writing scenario trace {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a scenario standalone (no protocols, no merges): prime,
    /// then pop and resolve scenario events until the cap.
    fn drive(sc: &mut Scenario, max_pops: usize) -> Vec<TraceEvent> {
        let mut heap = EventHeap::new();
        sc.prime(&mut heap);
        for _ in 0..max_pops {
            let Some(ev) = heap.pop() else { break };
            match ev.kind {
                EventKind::ClientJoin { client } => {
                    sc.on_join(client, ev.time, &mut heap);
                }
                EventKind::ClientLeave { client } => {
                    sc.on_leave(client, ev.time, &mut heap);
                }
                EventKind::RateChange { client } => {
                    sc.on_rate(client, ev.time, &mut heap);
                }
                other => panic!("engine event {other:?} in a scenario-only drive"),
            }
        }
        sc.applied().to_vec()
    }

    fn churn() -> ChurnSpec {
        "join:0.8,leave:0.6".parse().unwrap()
    }

    fn flaky_sched() -> RateScheduleSpec {
        "flaky:0.4:10:1.5".parse().unwrap()
    }

    #[test]
    fn scenario_churn_spec_parse_roundtrip_and_rejects_nonsense() {
        let c: ChurnSpec = "join:0.5,leave:0.3".parse().unwrap();
        assert_eq!(c, ChurnSpec { join: 0.5, leave: 0.3 });
        assert_eq!(c.id().parse::<ChurnSpec>().unwrap(), c);
        // one-sided specs are legal
        assert_eq!(
            "leave:0.25".parse::<ChurnSpec>().unwrap(),
            ChurnSpec { join: 0.0, leave: 0.25 }
        );
        for bad in ["", "join:0,leave:0", "join:-1", "join:inf", "churn:0.5", "join=0.5"] {
            assert!(bad.parse::<ChurnSpec>().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn scenario_rate_schedule_parse_roundtrip_and_rejects_nonsense() {
        let r: RateScheduleSpec = "diurnal:8:0.5+flaky:0.2:10:1.5".parse().unwrap();
        assert_eq!(r.diurnal, Some(DiurnalSpec { period: 8.0, amplitude: 0.5 }));
        assert_eq!(
            r.flaky,
            Some(FlakySpec { rate: 0.2, slowdown: 10.0, mean_len: 1.5 })
        );
        assert_eq!(r.id().parse::<RateScheduleSpec>().unwrap(), r);
        let d: RateScheduleSpec = "diurnal:4:0.25".parse().unwrap();
        assert!(d.flaky.is_none());
        assert_eq!(d.id().parse::<RateScheduleSpec>().unwrap(), d);
        for bad in [
            "",
            "diurnal:0:0.5",
            "diurnal:8:1.0",
            "diurnal:8:0",
            "flaky:0:10:1",
            "flaky:0.2:1:1",
            "flaky:0.2:10:0",
            "flaky:0.2:10",
            "tide:1:2",
            "diurnal:8:0.5+diurnal:4:0.2",
        ] {
            assert!(bad.parse::<RateScheduleSpec>().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn scenario_stream_is_a_pure_function_of_the_seed() {
        let run = |seed: u64| {
            let mut sc = Scenario::synth(6, Some(churn()), flaky_sched(), seed);
            drive(&mut sc, 400)
        };
        assert_eq!(run(7), run(7), "same seed, same effective stream");
        assert_ne!(run(7), run(8), "seed must matter");
    }

    #[test]
    fn scenario_guards_keep_the_fleet_nonempty_and_joins_target_absent_clients() {
        let mut sc = Scenario::synth(5, Some(churn()), RateScheduleSpec::default(), 3);
        let applied = drive(&mut sc, 600);
        assert!(!applied.is_empty(), "churn at these rates must produce events");
        let mut active = vec![true; 5];
        let mut last_bits = 0u64;
        for ev in &applied {
            assert!(ev.time.to_bits() >= last_bits, "stream time regressed");
            last_bits = ev.time.to_bits();
            match ev.kind {
                TraceKind::Join => {
                    assert!(!active[ev.client], "join targeted an active client");
                    active[ev.client] = true;
                }
                TraceKind::Leave => {
                    assert!(active[ev.client], "leave targeted an absent client");
                    assert!(
                        active.iter().filter(|&&a| a).count() > 1,
                        "last active client left"
                    );
                    active[ev.client] = false;
                }
                TraceKind::Rate { .. } => unreachable!("no rate schedule configured"),
            }
        }
        assert!(active.iter().any(|&a| a), "fleet emptied");
        assert_eq!(
            sc.active_count(),
            active.iter().filter(|&&a| a).count(),
            "scenario membership mirrors the applied stream"
        );
    }

    #[test]
    fn scenario_flaky_episodes_degrade_then_restore_per_client() {
        let mut sc = Scenario::synth(4, None, flaky_sched(), 11);
        let applied = drive(&mut sc, 400);
        assert!(!applied.is_empty());
        let mut degraded = vec![false; 4];
        for ev in &applied {
            let TraceKind::Rate { mul } = ev.kind else {
                unreachable!("no churn configured")
            };
            if mul < 1.0 {
                assert!((mul - 0.1).abs() < 1e-12, "slowdown 10 => mul 0.1");
                assert!(!degraded[ev.client], "episode started while degraded");
                degraded[ev.client] = true;
            } else {
                assert_eq!(mul, 1.0);
                assert!(degraded[ev.client], "restore without an episode");
                degraded[ev.client] = false;
            }
        }
    }

    #[test]
    fn trace_jsonl_roundtrip_replays_the_identical_stream() {
        let mut sc = Scenario::synth(6, Some(churn()), flaky_sched(), 42);
        let applied = drive(&mut sc, 500);
        let text = sc.to_jsonl();
        let mut replayed = Scenario::replay(6, &text).unwrap();
        // replay applies every recorded line verbatim
        let got = drive(&mut replayed, applied.len() + 10);
        assert_eq!(got, applied, "replayed stream differs from the recorded one");
        assert_eq!(replayed.source_id(), "replay");
        // and re-serializing the replay reproduces the bytes
        assert_eq!(replayed.to_jsonl(), text, "trace is not a serialization fixpoint");
        assert_eq!(replayed.counts(), sc.counts());
    }

    #[test]
    fn trace_replay_rejects_malformed_input() {
        let header = format!(
            "{{\"clients\":4,\"format\":\"{TRACE_FORMAT}\",\"version\":{TRACE_VERSION}}}"
        );
        for (bad, why) in [
            ("".to_string(), "empty"),
            ("{\"format\":\"other\",\"version\":1}".to_string(), "foreign format"),
            (
                format!("{{\"clients\":4,\"format\":\"{TRACE_FORMAT}\",\"version\":99}}"),
                "future version",
            ),
            (
                format!("{header}\n{{\"client\":9,\"ev\":\"join\",\"t\":1.0}}"),
                "client out of range",
            ),
            (
                format!(
                    "{header}\n{{\"client\":1,\"ev\":\"leave\",\"t\":2.0}}\n\
                     {{\"client\":2,\"ev\":\"leave\",\"t\":1.0}}"
                ),
                "time regression",
            ),
            (
                format!("{header}\n{{\"client\":1,\"ev\":\"rate\",\"mul\":0,\"t\":1.0}}"),
                "non-positive mul",
            ),
            (
                format!("{header}\n{{\"client\":1,\"ev\":\"vanish\",\"t\":1.0}}"),
                "unknown ev",
            ),
            (
                format!("{header}\n{{\"client\":1,\"ev\":\"leave\",\"t\":-1.0}}"),
                "negative time",
            ),
        ] {
            assert!(Scenario::replay(4, &bad).is_err(), "{why}");
        }
    }

    #[test]
    fn scenario_diurnal_scale_is_unity_without_a_schedule_and_at_t_zero() {
        let sc = Scenario::synth(4, Some(churn()), RateScheduleSpec::default(), 1);
        assert_eq!(sc.diurnal_scale(0.0).to_bits(), 1.0f64.to_bits());
        assert_eq!(sc.diurnal_scale(123.4).to_bits(), 1.0f64.to_bits());
        let sd = Scenario::synth(4, None, "diurnal:8:0.5".parse().unwrap(), 1);
        assert_eq!(sd.diurnal_scale(0.0).to_bits(), 1.0f64.to_bits(), "sin(0) = 0 exactly");
        assert!((sd.diurnal_scale(2.0) - 1.5).abs() < 1e-12, "peak at quarter period");
        assert!(sd.diurnal_scale(6.0) < 1.0, "trough at three quarters");
    }
}
