//! The discrete-event driver: the round barrier, dropped.
//!
//! `--engine events` replaces the per-round loop of [`crate::driver::run`]
//! with one seeded min-heap of timestamped events ([`EventHeap`],
//! DESIGN.md §11). Client work units complete ([`EventKind::ClientFinish`])
//! on per-client virtual clocks (the same [`ClientSpeeds`] model the
//! round schedulers use), the server folds pending updates in whenever
//! the configured [`MergePolicyKind`] says so
//! ([`EventKind::ServerMerge`]), evaluation observes the post-merge state
//! ([`EventKind::Eval`]), and the adaptive [`BoundController`] switches
//! arms at window boundaries ([`EventKind::ControllerSwitch`]).
//!
//! ## Two families of policy
//!
//! * **Degenerate** (`--merge-policy round`, the default): the event
//!   driver wraps the configured round [`Scheduler`] and replays its plan
//!   stream as events. Each merge is *armed* in two phases: popping the
//!   unarmed `ServerMerge{m}` asks the scheduler for the plan (reading
//!   `current_bound()` first, exactly like the round loop), schedules the
//!   participants' arrivals at the barrier instant, and re-pushes the
//!   merge at that instant; popping the armed merge executes the shared
//!   round body. Because the plan stream, the executed body
//!   ([`crate::driver::exec_round`]), and the recording cadence are the
//!   round driver's own, parity is structural — pinned bit-for-bit for
//!   all seven protocols in `tests/engine_determinism.rs`.
//! * **Continuous** (`arrival` / `batch:K` / `window:DT`): merges fire on
//!   arrivals, pending-count, or a sim-time cadence, under the bounded-
//!   staleness contract of [`ContinuousPolicy`]. This is the regime the
//!   round loop cannot express: a merge consumes whatever landed, clients
//!   restart immediately, and the "round" axis becomes the merge index.
//!
//! ## Scenarios (open-world runs)
//!
//! A [`Scenario`] (DESIGN.md §12) layers seeded churn, time-varying
//! rates, and trace replay over either continuous policy: its events
//! ([`EventKind::ClientJoin`] / [`EventKind::ClientLeave`] /
//! [`EventKind::RateChange`]) carry the lowest kind-ranks, so at any
//! instant the world is reshaped *before* engine events observe it. The
//! heap has no delete, so a departure or a rate re-time orphans the
//! client's pending `ClientFinish` in place — the stale event drains
//! and is discarded by [`ContinuousPolicy::expects_finish`]. Without a
//! scenario, every multiplier is exactly `1.0` and every client active,
//! so closed-world runs are bit-identical to the pre-scenario engine.
//!
//! Determinism: the heap's (time-bits, kind-rank, id) total order makes
//! the pop sequence a pure function of the event set; every decision
//! (plans, merge sets, controller switches, scenario effects) happens on
//! the driver thread; client work still fans out through the persistent
//! pool whose fan-in is thread-count invariant (DESIGN.md §10). Hence
//! replays are bit-stable across `--threads` and repeat invocations.

pub mod event;
pub mod policy;
pub mod scenario;

pub use event::{Event, EventHeap, EventKind};
pub use policy::{EngineKind, MergePolicyKind};
pub use scenario::{
    ChurnSpec, DiurnalSpec, FlakySpec, RateScheduleSpec, Scenario, TraceEvent, TraceKind,
    TRACE_FORMAT, TRACE_VERSION,
};

use anyhow::{bail, Result};

use crate::driver::{
    exec_round, scheduler_for, BoundController, ClientStateStore, Protocol, RoundPlan,
    RoundReport, SnapshotRing, WindowDelta, WindowMark,
};
use crate::driver::scratch_dir;
use crate::metrics::RoundStat;
use crate::protocols::{Env, RunResult};
use policy::{ContinuousPolicy, MergeDecision};

/// Scheduler name reported by the continuous policies (the degenerate
/// policy passes through the wrapped round scheduler's own name).
pub const EVENT_SCHEDULER_NAME: &str = "event-driven";

/// The scheduler name a run reports. Shared by the zero-round early
/// exit and the normal exit so the two can never disagree — seed
/// aggregation's scheduler-agreement check trips otherwise (the early
/// exit used to report the wrapped scheduler unconditionally).
pub(crate) fn reported_scheduler(continuous: bool, wrapped: &str) -> &str {
    if continuous {
        EVENT_SCHEDULER_NAME
    } else {
        wrapped
    }
}

/// Everything the `Eval` event needs to observe a merge that already
/// executed: its plan, the bound in effect when it was planned, and the
/// protocol's round report.
struct MergeOutcome {
    plan: RoundPlan,
    bound: usize,
    report: RoundReport,
}

/// Run `protocol` end to end on the event driver and return its result.
/// The run processes exactly `cfg.rounds` server merges; events still in
/// flight when the final merge's bookkeeping completes are discarded.
pub fn run_events<P: Protocol>(env: &mut Env, protocol: &mut P) -> Result<RunResult> {
    protocol.init_state(env)?;

    let (mut scheduler, speeds) = scheduler_for(env.cfg);
    let continuous = env.cfg.merge_policy != MergePolicyKind::Round;
    let mut policy = continuous.then(|| ContinuousPolicy::new(env.cfg, &speeds));
    // churn / rate schedules / trace record-replay (DESIGN.md §12) —
    // `None` for the (default) closed-world run
    let mut scenario = Scenario::from_cfg(env.cfg)?;
    if scenario.is_some() && !continuous {
        bail!(
            "scenario features (churn / rate-schedule / trace) require a \
             continuous merge policy, not `round`"
        );
    }

    // --adaptive-bound: same controller, same seeding, same window
    // semantics as the round driver — only the actuator differs (the
    // wrapped scheduler for the degenerate policy, the continuous
    // policy's own bound for the rest)
    let mut controller = if env.cfg.adaptive_bound {
        let c = BoundController::from_cfg(env.cfg);
        match policy.as_mut() {
            Some(p) => p.set_bound(c.current_bound(), 0),
            None => {
                scheduler.set_bound(c.current_bound(), 0);
            }
        }
        Some(c)
    } else {
        None
    };
    let mut window_mark = WindowMark::default();

    let mut store = if env.cfg.participation < 1.0 {
        ClientStateStore::with_spill(env.cfg.clients, scratch_dir(env.cfg.seed))?
    } else {
        ClientStateStore::new(env.cfg.clients)
    };
    let pool = env.pool();
    let mut ring: Option<SnapshotRing> = if env.cfg.delayed_gradients {
        let window = env.cfg.staleness_bound.unwrap_or(0) + 1;
        Some(if env.cfg.participation < 1.0 {
            SnapshotRing::with_spill(window, scratch_dir(env.cfg.seed))?
        } else {
            SnapshotRing::new(window)
        })
    } else {
        None
    };
    // pre-training baseline for the first window's Δaccuracy — identical
    // rationale and identical call to the round driver's
    if controller.is_some() {
        window_mark.accuracy = protocol.eval(env, &mut store)?;
    }

    let rounds = env.cfg.rounds;
    let mut heap = EventHeap::new();
    // degenerate: the plan cached between the arming pop and the
    // executing pop of one ServerMerge event
    let mut armed: Option<(usize, RoundPlan)> = None;
    // the merge awaiting its Eval event (at most one: Eval fires at the
    // merge instant, before any later merge can)
    let mut outcome: Option<MergeOutcome> = None;
    // continuous bookkeeping: the next merge index, and whether its
    // ServerMerge event is already on the heap
    let mut next_merge = 0usize;
    let mut merge_scheduled = false;
    // virtual instant of the last recorded merge (the window-end clock
    // reading the controller's Δsim_time is measured against)
    let mut last_sim_time = 0.0f64;

    // seed the heap
    match policy.as_mut() {
        None => {
            // degenerate: merge 0, unarmed, at the epoch
            heap.push(Event::new(0.0, EventKind::ServerMerge { merge: 0 }));
            merge_scheduled = true;
        }
        Some(p) => {
            // every client starts its first work unit at t = 0
            for i in 0..p.n_clients() {
                heap.push(Event::new(p.duration(i), EventKind::ClientFinish { client: i }));
            }
            if let MergePolicyKind::Window(dt) = p.mode() {
                heap.push(Event::new(dt, EventKind::ServerMerge { merge: 0 }));
                merge_scheduled = true;
            }
        }
    }

    if rounds == 0 {
        // Degenerate zero-round exit. Two pinned invariants: (a) the
        // reported scheduler goes through the same `continuous` branch
        // as the normal exit — seed aggregation's agreement check used
        // to trip when zero-round smoke runs mixed with real ones; (b)
        // the adaptive baseline eval above already landed in the meter
        // and recorder, which is exactly what the round driver does
        // before its loop, so zero-round parity holds as-is (both
        // pinned in tests/engine_determinism.rs).
        let name = reported_scheduler(continuous, scheduler.name());
        return finish_run(env, scenario.as_ref(), name, heap.popped());
    }

    // open the world only for runs that will actually drain the heap
    if let Some(sc) = scenario.as_mut() {
        sc.prime(&mut heap);
    }

    loop {
        let Some(ev) = heap.pop() else {
            bail!(
                "event heap drained with merge {next_merge}/{rounds} outstanding — \
                 a policy failed to schedule its next trigger"
            );
        };
        match ev.kind {
            // scenario events reshape the world (ranks 0–2: they drain
            // before any engine event at the same instant)
            EventKind::ClientJoin { client } => {
                let sc = scenario
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("scenario event without a scenario"))?;
                if sc.on_join(client, ev.time, &mut heap) {
                    let p = policy
                        .as_mut()
                        .ok_or_else(|| anyhow::anyhow!("churn without a continuous policy"))?;
                    let scale = sc.diurnal_scale(ev.time);
                    let ready = p.activate(client, ev.time, next_merge, scale);
                    heap.push(Event::new(ready, EventKind::ClientFinish { client }));
                }
            }
            EventKind::ClientLeave { client } => {
                let sc = scenario
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("scenario event without a scenario"))?;
                if sc.on_leave(client, ev.time, &mut heap) {
                    let p = policy
                        .as_mut()
                        .ok_or_else(|| anyhow::anyhow!("churn without a continuous policy"))?;
                    // the client's in-flight ClientFinish stays on the
                    // heap (no delete) — it drains later and is discarded
                    // by the expects_finish check below
                    p.deactivate(client);
                }
            }
            EventKind::RateChange { client } => {
                let sc = scenario
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("scenario event without a scenario"))?;
                if let Some(mul) = sc.on_rate(client, ev.time, &mut heap) {
                    let p = policy
                        .as_mut()
                        .ok_or_else(|| anyhow::anyhow!("rate change without a continuous policy"))?;
                    if let Some(ready) = p.set_rate(client, mul, ev.time) {
                        // re-time: the superseded finish is orphaned in
                        // place, the replacement carries the new rate
                        heap.push(Event::new(ready, EventKind::ClientFinish { client }));
                    }
                }
            }
            EventKind::ClientFinish { client } => match policy.as_mut() {
                // degenerate arrivals are decorative: the armed merge at
                // the same instant consumes them wholesale
                None => {}
                Some(p) => {
                    if scenario.is_some() && !p.expects_finish(client, ev.time) {
                        // orphaned by a departure or a rate re-time —
                        // lazy cancellation (the gate is scenario-only,
                        // so closed-world runs take the exact old path)
                        continue;
                    }
                    let trigger = p.on_finish(client, ev.time);
                    if trigger && !merge_scheduled && next_merge < rounds {
                        heap.push(Event::new(ev.time, EventKind::ServerMerge { merge: next_merge }));
                        merge_scheduled = true;
                    }
                }
            },
            EventKind::ServerMerge { merge } => {
                debug_assert_eq!(merge, next_merge, "merges fire in index order");
                match policy.as_mut() {
                    None => match armed.take() {
                        // phase 1 — arm: ask the wrapped scheduler for the
                        // plan (bound first, exactly like the round loop),
                        // schedule the barrier's arrivals, re-push the
                        // merge at the barrier instant
                        None => {
                            let bound = scheduler.current_bound();
                            let plan = scheduler.plan(merge);
                            for &i in &plan.participants {
                                heap.push(Event::new(
                                    plan.sim_time,
                                    EventKind::ClientFinish { client: i },
                                ));
                            }
                            heap.push(Event::new(plan.sim_time, EventKind::ServerMerge { merge }));
                            armed = Some((bound, plan));
                        }
                        // phase 2 — execute the shared round body
                        Some((bound, plan)) => {
                            let report = exec_round(
                                env,
                                protocol,
                                &mut store,
                                &mut ring,
                                &speeds,
                                &pool,
                                merge,
                                &plan.participants,
                                &plan.staleness,
                            )?;
                            heap.push(Event::new(plan.sim_time, EventKind::Eval { merge }));
                            outcome = Some(MergeOutcome { plan, bound, report });
                            next_merge = merge + 1;
                            merge_scheduled = false;
                        }
                    },
                    Some(p) => match p.decide(merge, ev.time) {
                        MergeDecision::Wait(t) => {
                            if t <= ev.time {
                                bail!(
                                    "merge policy wait time {t} does not advance past {} — \
                                     the event loop would livelock",
                                    ev.time
                                );
                            }
                            heap.push(Event::new(t, EventKind::ServerMerge { merge }));
                        }
                        MergeDecision::Fire(plan) => {
                            let bound = p.current_bound();
                            let report = exec_round(
                                env,
                                protocol,
                                &mut store,
                                &mut ring,
                                &speeds,
                                &pool,
                                merge,
                                &plan.participants,
                                &plan.staleness,
                            )?;
                            // next work units start at the merge instant
                            // under the diurnal curve then in effect
                            // (exactly 1.0 without a scenario)
                            let scale = scenario
                                .as_ref()
                                .map_or(1.0, |s| s.diurnal_scale(plan.sim_time));
                            for (i, t) in p.commit(merge, &plan, scale) {
                                heap.push(Event::new(t, EventKind::ClientFinish { client: i }));
                            }
                            heap.push(Event::new(plan.sim_time, EventKind::Eval { merge }));
                            outcome = Some(MergeOutcome { plan, bound, report });
                            next_merge = merge + 1;
                            merge_scheduled = false;
                        }
                    },
                }
            }
            EventKind::Eval { merge } => {
                let MergeOutcome { plan, bound, report } = outcome
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("eval event {merge} without a merge outcome"))?;
                let window_end = controller
                    .as_ref()
                    .is_some_and(|c| (merge + 1) % c.window() == 0);
                let eval_now =
                    merge % env.cfg.eval_every == 0 || merge + 1 == rounds || window_end;
                let accuracy = if eval_now {
                    protocol.eval(env, &mut store)?
                } else {
                    env.recorder.last_accuracy()
                };
                last_sim_time = plan.sim_time;
                env.recorder.push(RoundStat {
                    round: merge,
                    phase: report.phase,
                    train_loss: report.train_loss,
                    accuracy_pct: accuracy,
                    bandwidth_gb: env.meter.bandwidth_gb(),
                    client_tflops: env.meter.client_tflops(),
                    total_tflops: env.meter.total_tflops(),
                    mask_density: report.mask_density,
                    sim_time: plan.sim_time,
                    max_staleness: plan.staleness.iter().copied().max().unwrap_or(0),
                    bound,
                    selected: report.selected,
                    participants: plan.participants,
                    events: heap.popped(),
                });
                if window_end {
                    // the switch is its own event at the same instant —
                    // it handles both the controller step and scheduling
                    // the next merge, so bound switches land before the
                    // next plan exactly as in the round loop
                    heap.push(Event::new(ev.time, EventKind::ControllerSwitch { merge }));
                } else if merge + 1 == rounds {
                    break;
                } else {
                    schedule_next_merge(
                        &mut heap,
                        policy.as_ref(),
                        next_merge,
                        ev.time,
                        &mut merge_scheduled,
                    );
                }
            }
            EventKind::ControllerSwitch { merge } => {
                let ctrl = controller
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("controller event without a controller"))?;
                let accuracy = env.recorder.last_accuracy();
                // the window ends at the merge instant just recorded
                let sim_now = last_sim_time;
                let delta = WindowDelta {
                    d_accuracy_pct: accuracy - window_mark.accuracy,
                    d_sim_time: sim_now - window_mark.sim_time,
                    d_bandwidth_gb: env.meter.bandwidth_gb() - window_mark.bandwidth_gb,
                    d_client_tflops: env.meter.client_tflops() - window_mark.client_tflops,
                };
                window_mark = WindowMark {
                    accuracy,
                    sim_time: sim_now,
                    bandwidth_gb: env.meter.bandwidth_gb(),
                    client_tflops: env.meter.client_tflops(),
                };
                if merge + 1 < rounds {
                    let (next, reward) = ctrl.observe_window(&delta);
                    match policy.as_mut() {
                        Some(p) => p.set_bound(next, merge + 1),
                        None => {
                            scheduler.set_bound(next, merge + 1);
                        }
                    }
                    if env.recorder.trace_enabled {
                        env.recorder.trace(format!(
                            "adaptive: window ending round {merge} reward {reward:.4} -> bound {next}"
                        ));
                    }
                }
                if merge + 1 == rounds {
                    break;
                }
                schedule_next_merge(
                    &mut heap,
                    policy.as_ref(),
                    next_merge,
                    ev.time,
                    &mut merge_scheduled,
                );
            }
        }
    }

    let name = reported_scheduler(continuous, scheduler.name());
    finish_run(env, scenario.as_ref(), name, heap.popped())
}

/// Assemble the run's [`RunResult`] — shared by the zero-round early
/// exit and the normal exit. Folds in the scenario's effective-event
/// counts and source label, and writes the `--trace-out` JSONL last so
/// the recorded stream covers the whole run.
fn finish_run(
    env: &Env,
    scenario: Option<&Scenario>,
    name: &str,
    popped: usize,
) -> Result<RunResult> {
    let mut result = RunResult::from_env(env, &env.recorder, &env.meter, name);
    result.events_processed = popped;
    if let Some(sc) = scenario {
        let (joins, leaves, rates) = sc.counts();
        result.churn_events = joins + leaves;
        result.rate_events = rates;
        result.scenario = sc.source_id().to_string();
        if let Some(path) = &env.cfg.trace_out {
            sc.write_trace(path)?;
        }
    }
    Ok(result)
}

/// After merge `m - 1`'s bookkeeping, put merge `m`'s trigger on the
/// heap: unconditionally for the degenerate policy (the scheduler always
/// has a next plan), at `now + DT` for the time-window cadence (DT is
/// the *minimum* inter-merge gap — a merge deferred by a required
/// in-flight client pushes the whole cadence back), and only if the
/// pending set already satisfies the trigger for arrival/batch (a later
/// `ClientFinish` schedules it otherwise).
fn schedule_next_merge(
    heap: &mut EventHeap,
    policy: Option<&ContinuousPolicy>,
    next_merge: usize,
    now: f64,
    merge_scheduled: &mut bool,
) {
    match policy {
        None => {
            heap.push(Event::new(now, EventKind::ServerMerge { merge: next_merge }));
            *merge_scheduled = true;
        }
        Some(p) => match p.mode() {
            MergePolicyKind::Window(dt) => {
                heap.push(Event::new(now + dt, EventKind::ServerMerge { merge: next_merge }));
                *merge_scheduled = true;
            }
            _ => {
                if p.wants_merge() {
                    heap.push(Event::new(now, EventKind::ServerMerge { merge: next_merge }));
                    *merge_scheduled = true;
                }
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_reported_scheduler_is_exit_path_invariant() {
        // regression (satellite 1): both exit paths go through this
        // helper, so a continuous run always presents as the event
        // scheduler and zero-round smoke runs can aggregate with real
        // ones under any seed mix
        assert_eq!(reported_scheduler(true, "sync-all"), EVENT_SCHEDULER_NAME);
        assert_eq!(reported_scheduler(true, "async-bounded"), EVENT_SCHEDULER_NAME);
        assert_eq!(reported_scheduler(false, "sync-all"), "sync-all");
    }
}
