//! Merge policies for the event engine: when does the server fold
//! pending client updates in?
//!
//! The round drivers hard-wired one answer — "once per round, behind a
//! barrier". Under the event engine the answer is pluggable
//! (`--merge-policy`, DESIGN.md §11):
//!
//! * **`round`** (the default, and the only legal policy for the rounds
//!   engine) — the *degenerate* policy: the event driver wraps the
//!   configured [`Scheduler`](crate::driver::Scheduler) and replays its
//!   plan stream as events, bit-identical to the round loop. Implemented
//!   in [`crate::sim`] directly; this module only names it.
//! * **`arrival`** — merge-on-arrival: every client finish requests a
//!   merge (AdaptSFL-style parameter-server semantics, arXiv 2403.13101).
//! * **`batch:K`** — merge once `K` updates are pending.
//! * **`window:DT`** — merge every `DT` units of simulated time.
//!
//! All continuous policies share the bounded-staleness contract of
//! [`AsyncBounded`](crate::driver::AsyncBounded), restated over merge
//! indices instead of rounds: a client whose contribution would exceed
//! the staleness bound is *required* — the merge waits for it — and
//! `--participation` caps how many pending arrivals one merge absorbs
//! (the bound always wins). Staleness is the number of server merges a
//! contribution straddled, so the adaptive `BoundController` drives the
//! same knob on either engine.
//!
//! Under a scenario (DESIGN.md §12) the policy also tracks fleet
//! membership and per-client rate multipliers: departures drop a client
//! from the pending/required sets, joins rebase its staleness, and rate
//! changes re-time its in-flight work — all without touching the
//! contracts above. Without a scenario every multiplier is exactly
//! `1.0` and every client active, so the closed-world arithmetic is
//! bit-identical.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::config::ExperimentConfig;
use crate::driver::{ClientSpeeds, RoundPlan};

/// Which driver executes the run (`--engine` / `engine` config key).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineKind {
    /// The per-round barrier loop (`driver::run`) — the default.
    #[default]
    Rounds,
    /// The discrete-event driver (`sim::run_events`).
    Events,
}

impl EngineKind {
    pub fn id(&self) -> &'static str {
        match self {
            EngineKind::Rounds => "rounds",
            EngineKind::Events => "events",
        }
    }
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "rounds" => Ok(EngineKind::Rounds),
            "events" => Ok(EngineKind::Events),
            other => bail!("unknown engine `{other}` (expected rounds | events)"),
        }
    }
}

/// When the server merges (`--merge-policy` / `merge_policy` config key).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum MergePolicyKind {
    /// Degenerate: replay the configured round scheduler as events.
    #[default]
    Round,
    /// Merge whenever an update lands.
    Arrival,
    /// Merge once this many updates are pending.
    Batch(usize),
    /// Merge every this many units of simulated time.
    Window(f64),
}

impl MergePolicyKind {
    /// CLI/config id (`round`, `arrival`, `batch:4`, `window:1.5`).
    pub fn id(&self) -> String {
        match self {
            MergePolicyKind::Round => "round".to_string(),
            MergePolicyKind::Arrival => "arrival".to_string(),
            MergePolicyKind::Batch(k) => format!("batch:{k}"),
            MergePolicyKind::Window(dt) => format!("window:{dt}"),
        }
    }
}

impl std::str::FromStr for MergePolicyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        if s == "round" {
            return Ok(MergePolicyKind::Round);
        }
        if s == "arrival" {
            return Ok(MergePolicyKind::Arrival);
        }
        if let Some(v) = s.strip_prefix("batch:") {
            let k: usize = v
                .parse()
                .map_err(|e| anyhow::anyhow!("merge-policy batch size `{v}`: {e}"))?;
            ensure!(k >= 1, "merge-policy batch size must be >= 1, got {k}");
            return Ok(MergePolicyKind::Batch(k));
        }
        if let Some(v) = s.strip_prefix("window:") {
            let dt: f64 = v
                .parse()
                .map_err(|e| anyhow::anyhow!("merge-policy window span `{v}`: {e}"))?;
            ensure!(
                dt > 0.0 && dt.is_finite(),
                "merge-policy window span must be a positive finite sim-time, got {dt}"
            );
            return Ok(MergePolicyKind::Window(dt));
        }
        bail!("unknown merge policy `{s}` (expected round | arrival | batch:K | window:DT)")
    }
}

/// What a continuous policy answers when asked to fire merge `m` now.
pub(crate) enum MergeDecision {
    /// The merge proceeds with this plan (participants ascending-unique,
    /// staleness parallel, `sim_time` = the merge instant).
    Fire(RoundPlan),
    /// A staleness-required client is still in flight (or nothing is
    /// pending): re-ask at this later virtual time. Strictly after the
    /// current instant, so the event loop cannot livelock — the awaited
    /// `ClientFinish` drains first at that time (rank 0 < merge rank 1).
    Wait(f64),
}

/// Shared state machine of the non-degenerate merge policies: per-client
/// virtual completion clocks, the pending-update set, and bounded-
/// staleness bookkeeping over merge indices.
pub(crate) struct ContinuousPolicy {
    mode: MergePolicyKind,
    n: usize,
    /// staleness bound over merges (`None` = unbounded: nothing is ever
    /// required, the participation cap alone shapes merges)
    bound: Option<usize>,
    /// max pending arrivals absorbed per merge: `ceil(participation * N)`
    cap: usize,
    durations: Vec<f64>,
    /// completion time of each client's current work unit; for a pending
    /// client this is the arrival time of its finished update
    ready: Vec<f64>,
    /// arrival time of each pending (finished, unmerged) update
    pending: BTreeMap<usize, f64>,
    /// last merge index each client's update folded into (-1 = never)
    last_merge: Vec<i64>,
    /// per-client scenario speed multiplier (flaky links); a work unit's
    /// duration divides by it — all `1.0` without a scenario
    mul: Vec<f64>,
    /// fleet membership under churn: inactive clients are excluded from
    /// required sets, fallbacks, and merges — all `true` without one
    active: Vec<bool>,
    clock: f64,
}

impl ContinuousPolicy {
    pub(crate) fn new(cfg: &ExperimentConfig, speeds: &ClientSpeeds) -> Self {
        let n = cfg.clients;
        let cap = ((cfg.participation * n as f64).ceil() as usize).clamp(1, n.max(1));
        let durations: Vec<f64> = (0..n)
            .map(|i| speeds.round_duration(i).max(f64::MIN_POSITIVE))
            .collect();
        Self {
            mode: cfg.merge_policy,
            n,
            bound: cfg.staleness_bound,
            cap,
            ready: durations.clone(),
            durations,
            pending: BTreeMap::new(),
            last_merge: vec![-1; n],
            mul: vec![1.0; n],
            active: vec![true; n],
            clock: 0.0,
        }
    }

    pub(crate) fn mode(&self) -> MergePolicyKind {
        self.mode
    }

    pub(crate) fn n_clients(&self) -> usize {
        self.n
    }

    /// Virtual duration of one work unit for client `i`.
    pub(crate) fn duration(&self, i: usize) -> f64 {
        self.durations[i]
    }

    /// Duration of client `i`'s next work unit under the live scenario
    /// factors: base duration over (link multiplier × diurnal `scale`).
    /// With no scenario both factors are exactly `1.0`, so this equals
    /// `duration(i)` bit for bit (IEEE-754: `x / 1.0 == x`).
    pub(crate) fn unit_duration(&self, i: usize, scale: f64) -> f64 {
        self.durations[i] / (self.mul[i] * scale)
    }

    /// The staleness bound currently in effect (0 when unbounded, for
    /// reporting parity with the synchronous schedulers' `current_bound`).
    pub(crate) fn current_bound(&self) -> usize {
        self.bound.unwrap_or(0)
    }

    /// Client `i`'s update arrived at time `t`. Returns `true` when the
    /// policy wants a merge scheduled now (arrival/batch triggers; the
    /// time-window policy pre-schedules its own cadence).
    pub(crate) fn on_finish(&mut self, client: usize, t: f64) -> bool {
        self.pending.insert(client, t);
        self.ready[client] = t;
        self.wants_merge()
    }

    /// Does the pending set satisfy the policy's merge trigger?
    pub(crate) fn wants_merge(&self) -> bool {
        match self.mode {
            MergePolicyKind::Arrival => !self.pending.is_empty(),
            // effective batch = min(K, active fleet): a fleet shrunk
            // below K by churn (or an oversized K) must still merge —
            // pending ⊆ active, so a literal K could never be reached
            MergePolicyKind::Batch(k) => {
                let active = self.active.iter().filter(|&&a| a).count();
                self.pending.len() >= k.min(active.max(1))
            }
            // time-window merges fire on their own clock, not on arrivals
            MergePolicyKind::Window(_) => false,
            MergePolicyKind::Round => unreachable!("degenerate policy has no pending set"),
        }
    }

    /// Decide merge `m` at instant `now`.
    pub(crate) fn decide(&self, m: usize, now: f64) -> MergeDecision {
        let mi = m as i64;
        // required set: clients whose contribution would exceed the bound
        // if this merge passed them over — the same hard-bound rule as
        // AsyncBounded, restated over merge indices
        let required: Vec<usize> = match self.bound {
            Some(b) => (0..self.n)
                .filter(|&i| self.active[i] && mi - self.last_merge[i] > b as i64)
                .collect(),
            None => Vec::new(),
        };
        // a required client still in flight: the merge waits for it
        let in_flight_wait = required
            .iter()
            .filter(|&&i| !self.pending.contains_key(&i))
            .map(|&i| self.ready[i])
            .fold(f64::NEG_INFINITY, f64::max);
        if in_flight_wait > now {
            return MergeDecision::Wait(in_flight_wait);
        }
        if self.pending.is_empty() {
            // never-empty merge contract: with nothing pending, wait for
            // the fastest in-flight *active* client (every active client
            // is in flight here, and the scenario's last-leaver guard
            // keeps the active fleet non-empty)
            let earliest = (0..self.n)
                .filter(|&i| self.active[i])
                .map(|i| self.ready[i])
                .fold(f64::INFINITY, f64::min);
            return MergeDecision::Wait(earliest.max(now));
        }
        // merge set: required clients plus the earliest pending arrivals
        // (id tie-break) up to the participation cap — ascending-unique,
        // like every merge set in the codebase
        let limit = self.cap.max(required.len());
        let mut extras: Vec<(u64, usize)> = self
            .pending
            .iter()
            .filter(|(i, _)| match self.bound {
                Some(b) => mi - self.last_merge[**i] <= b as i64,
                None => true,
            })
            .map(|(&i, &arrival)| (arrival.to_bits(), i))
            .collect();
        extras.sort_unstable();
        let mut participants = required;
        participants.extend(
            extras
                .into_iter()
                .take(limit - participants.len())
                .map(|(_, i)| i),
        );
        participants.sort_unstable();
        let staleness: Vec<usize> = participants
            .iter()
            .map(|&i| (mi - 1 - self.last_merge[i]).max(0) as usize)
            .collect();
        MergeDecision::Fire(RoundPlan {
            participants,
            staleness,
            sim_time: self.clock.max(now),
        })
    }

    /// Apply a fired merge: advance the server clock, restart every
    /// participant's next work unit at the merge instant (under the
    /// diurnal `scale` and live link multipliers — both exactly `1.0`
    /// without a scenario), and return the (client, completion-time)
    /// pairs the driver schedules as `ClientFinish` events.
    pub(crate) fn commit(&mut self, m: usize, plan: &RoundPlan, scale: f64) -> Vec<(usize, f64)> {
        self.clock = self.clock.max(plan.sim_time);
        plan.participants
            .iter()
            .map(|&i| {
                self.last_merge[i] = m as i64;
                self.pending.remove(&i);
                self.ready[i] = self.clock + self.durations[i] / (self.mul[i] * scale);
                (i, self.ready[i])
            })
            .collect()
    }

    /// Runtime bound switch (the adaptive controller's actuator): same
    /// tighten-rebase semantics as `AsyncBounded::set_bound`, over merge
    /// indices — a client whose in-flight work would already be staler
    /// than the new bound re-pulls at the switch, so it is required in
    /// the very next merge and never reports staleness above the bound.
    pub(crate) fn set_bound(&mut self, bound: usize, next_merge: usize) {
        self.bound = Some(bound);
        let floor = next_merge as i64 - 1 - bound as i64;
        for lm in &mut self.last_merge {
            if *lm < floor {
                *lm = floor;
            }
        }
    }

    /// Client `c` leaves the fleet: discard its pending update (delayed-
    /// gradient versioning already defines what its in-flight work meant
    /// — once it is gone, nothing; DESIGN.md §8/§12) and exclude it from
    /// required sets and fallbacks until it rejoins. The scenario's
    /// last-leaver guard keeps the active fleet non-empty.
    pub(crate) fn deactivate(&mut self, c: usize) {
        self.active[c] = false;
        self.pending.remove(&c);
    }

    /// Client `c` (re-)joins at `now`, before merge `next_merge`: it
    /// starts a fresh work unit at the join instant, and its staleness
    /// base rebases so it owes nothing for its absence — staleness 0 if
    /// it lands in the very next merge, preserving staleness ≤ bound.
    /// Returns the completion time to schedule as its `ClientFinish`.
    pub(crate) fn activate(&mut self, c: usize, now: f64, next_merge: usize, scale: f64) -> f64 {
        self.active[c] = true;
        self.last_merge[c] = next_merge as i64 - 1;
        self.ready[c] = now + self.durations[c] / (self.mul[c] * scale);
        self.ready[c]
    }

    /// Scenario rate change for client `c` at `now`: store the new
    /// multiplier and, when `c` is active and mid-flight, re-time its
    /// current unit — the remaining stretch scales by old/new speed.
    /// Returns the new completion time to schedule as a replacement
    /// `ClientFinish` (the superseded event is discarded by
    /// [`Self::expects_finish`] when it pops — the heap has no delete);
    /// `None` when nothing is in flight to re-time.
    pub(crate) fn set_rate(&mut self, c: usize, new_mul: f64, now: f64) -> Option<f64> {
        let old = self.mul[c];
        self.mul[c] = new_mul;
        if old.to_bits() == new_mul.to_bits() || !self.active[c] || self.pending.contains_key(&c)
        {
            return None;
        }
        let remaining = self.ready[c] - now;
        if !(remaining > 0.0) {
            // the unit completes at this very instant: let it land
            return None;
        }
        self.ready[c] = now + remaining * (old / new_mul);
        Some(self.ready[c])
    }

    /// Lazy cancellation check: does a popped `ClientFinish { client }`
    /// at `t` correspond to the client's *current* work unit? False for
    /// events orphaned by a departure or a rate re-time.
    pub(crate) fn expects_finish(&self, c: usize, t: f64) -> bool {
        self.active[c] && !self.pending.contains_key(&c) && self.ready[c].to_bits() == t.to_bits()
    }

    pub(crate) fn is_active(&self, c: usize) -> bool {
        self.active[c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::SpeedPreset;

    fn cfg(n: usize, policy: MergePolicyKind, bound: Option<usize>, p: f64) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.clients = n;
        c.engine = EngineKind::Events;
        c.merge_policy = policy;
        c.staleness_bound = bound;
        c.participation = p;
        c.client_speeds = SpeedPreset::Stragglers;
        c.straggler_frac = 0.3;
        c
    }

    fn speeds_for(c: &ExperimentConfig) -> ClientSpeeds {
        ClientSpeeds::from_cfg(c)
    }

    /// Drive the policy like the event loop does, without protocols:
    /// collect `merges` plans and return them.
    fn simulate(c: &ExperimentConfig, merges: usize) -> Vec<RoundPlan> {
        let sp = speeds_for(c);
        let mut p = ContinuousPolicy::new(c, &sp);
        let mut finishes: Vec<(f64, usize)> =
            (0..c.clients).map(|i| (p.duration(i), i)).collect();
        let mut plans = Vec::new();
        let mut m = 0usize;
        let mut guard = 0usize;
        while m < merges {
            guard += 1;
            assert!(guard < 100_000, "policy simulation did not converge");
            // next arrival in (time, id) order — a hand-rolled stand-in
            // for the event heap
            finishes.sort_by(|a, b| {
                a.0.to_bits().cmp(&b.0.to_bits()).then(a.1.cmp(&b.1))
            });
            let now = if finishes.is_empty() {
                p.clock
            } else {
                let (t, i) = finishes.remove(0);
                p.on_finish(i, t);
                t
            };
            // greedily fire merges whenever the trigger is satisfied
            // (window cadence is exercised through the full driver tests)
            while m < merges && p.wants_merge() {
                match p.decide(m, now) {
                    MergeDecision::Wait(_) => break,
                    MergeDecision::Fire(plan) => {
                        for (i, t) in p.commit(m, &plan, 1.0) {
                            finishes.push((t, i));
                        }
                        plans.push(plan);
                        m += 1;
                    }
                }
            }
        }
        plans
    }

    #[test]
    fn policy_parse_roundtrip_and_rejects_nonsense() {
        assert_eq!("round".parse::<MergePolicyKind>().unwrap(), MergePolicyKind::Round);
        assert_eq!(
            "arrival".parse::<MergePolicyKind>().unwrap(),
            MergePolicyKind::Arrival
        );
        assert_eq!(
            "batch:4".parse::<MergePolicyKind>().unwrap(),
            MergePolicyKind::Batch(4)
        );
        assert_eq!(
            "window:1.5".parse::<MergePolicyKind>().unwrap(),
            MergePolicyKind::Window(1.5)
        );
        for bad in ["batch:0", "batch:x", "window:0", "window:-2", "window:inf", "eager"] {
            assert!(bad.parse::<MergePolicyKind>().is_err(), "{bad}");
        }
        for p in [
            MergePolicyKind::Round,
            MergePolicyKind::Arrival,
            MergePolicyKind::Batch(3),
            MergePolicyKind::Window(0.5),
        ] {
            assert_eq!(p.id().parse::<MergePolicyKind>().unwrap(), p, "{}", p.id());
        }
        assert_eq!("rounds".parse::<EngineKind>().unwrap(), EngineKind::Rounds);
        assert_eq!("events".parse::<EngineKind>().unwrap(), EngineKind::Events);
        assert!("rings".parse::<EngineKind>().is_err());
        assert_eq!(EngineKind::default(), EngineKind::Rounds);
        assert_eq!(MergePolicyKind::default(), MergePolicyKind::Round);
    }

    #[test]
    fn continuous_merge_sets_are_sorted_unique_nonempty_and_clock_monotone() {
        for mode in [MergePolicyKind::Arrival, MergePolicyKind::Batch(3)] {
            let c = cfg(12, mode, Some(3), 0.5);
            let plans = simulate(&c, 40);
            assert_eq!(plans.len(), 40);
            let mut prev = 0.0f64;
            for (m, plan) in plans.iter().enumerate() {
                assert!(!plan.participants.is_empty(), "{mode:?} merge {m}: empty");
                assert!(
                    plan.participants.windows(2).all(|w| w[0] < w[1]),
                    "{mode:?} merge {m}: not ascending-unique"
                );
                assert_eq!(plan.participants.len(), plan.staleness.len());
                assert!(plan.sim_time >= prev, "{mode:?} merge {m}: clock regressed");
                prev = plan.sim_time;
            }
        }
    }

    #[test]
    fn continuous_staleness_never_exceeds_the_bound() {
        for (mode, bound) in [
            (MergePolicyKind::Arrival, 2usize),
            (MergePolicyKind::Batch(4), 1),
            (MergePolicyKind::Batch(2), 5),
        ] {
            let c = cfg(16, mode, Some(bound), 0.25);
            for (m, plan) in simulate(&c, 60).iter().enumerate() {
                for (&i, &s) in plan.participants.iter().zip(&plan.staleness) {
                    assert!(
                        s <= bound,
                        "{mode:?} bound {bound} merge {m}: client {i} stale {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn continuous_replay_is_bit_stable() {
        let collect = |seed: u64| -> Vec<(Vec<usize>, Vec<usize>, u64)> {
            let mut c = cfg(14, MergePolicyKind::Batch(3), Some(2), 0.5);
            c.seed = seed;
            simulate(&c, 30)
                .into_iter()
                .map(|p| (p.participants, p.staleness, p.sim_time.to_bits()))
                .collect()
        };
        assert_eq!(collect(7), collect(7), "same seed, same merge stream");
        assert_ne!(collect(7), collect(8), "seed must matter");
    }

    #[test]
    fn batch_trigger_fires_at_k_pending() {
        let c = cfg(8, MergePolicyKind::Batch(3), None, 1.0);
        let sp = speeds_for(&c);
        let mut p = ContinuousPolicy::new(&c, &sp);
        assert!(!p.on_finish(0, 1.0));
        assert!(!p.on_finish(1, 1.0));
        assert!(p.on_finish(2, 1.0), "third pending update satisfies batch:3");
        // and an arrival policy fires on the very first pending update
        let ca = cfg(8, MergePolicyKind::Arrival, None, 1.0);
        let mut pa = ContinuousPolicy::new(&ca, &speeds_for(&ca));
        assert!(pa.on_finish(5, 0.5));
    }

    #[test]
    fn required_in_flight_client_defers_the_merge() {
        let c = cfg(4, MergePolicyKind::Arrival, Some(0), 1.0);
        let sp = speeds_for(&c);
        let mut p = ContinuousPolicy::new(&c, &sp);
        // bound 0: every client is required in merge 0; with only client 0
        // pending, the merge must wait for the slowest in-flight finish
        let d0 = p.duration(0);
        p.on_finish(0, d0);
        let latest = (0..4).map(|i| p.duration(i)).fold(f64::NEG_INFINITY, f64::max);
        match p.decide(0, d0) {
            MergeDecision::Wait(t) => {
                assert!(t > d0, "wait must be strictly later than now");
                assert_eq!(t.to_bits(), latest.to_bits(), "waits for slowest required");
            }
            MergeDecision::Fire(_) => {
                // only legal if client 0 is the slowest (no one else in
                // flight later) — impossible with stragglers at this seed
                panic!("merge fired while required clients were in flight")
            }
        }
    }

    #[test]
    fn empty_pending_set_waits_for_the_fastest_in_flight_client() {
        let c = cfg(6, MergePolicyKind::Window(0.5), Some(4), 1.0);
        let sp = speeds_for(&c);
        let p = ContinuousPolicy::new(&c, &sp);
        let earliest = (0..6).map(|i| p.duration(i)).fold(f64::INFINITY, f64::min);
        match p.decide(0, 0.5) {
            MergeDecision::Wait(t) => {
                assert_eq!(t.to_bits(), earliest.max(0.5).to_bits());
            }
            MergeDecision::Fire(_) => panic!("nothing is pending — the merge cannot fire"),
        }
    }

    #[test]
    fn participation_caps_extras_but_required_clients_always_merge() {
        let c = cfg(10, MergePolicyKind::Batch(2), Some(1), 0.2); // cap = 2
        for (m, plan) in simulate(&c, 50).iter().enumerate() {
            // |merge| <= max(cap, |required|); required is at most the fleet
            assert!(
                plan.participants.len() <= 10,
                "merge {m}: {} participants",
                plan.participants.len()
            );
            if plan.staleness.iter().all(|&s| s == 0) {
                assert!(
                    plan.participants.len() <= 2,
                    "merge {m}: all-fresh merge exceeded the cap"
                );
            }
        }
    }

    #[test]
    fn set_bound_tighten_rebases_like_async_bounded() {
        let c = cfg(12, MergePolicyKind::Arrival, Some(6), 0.25);
        let sp = speeds_for(&c);
        let mut p = ContinuousPolicy::new(&c, &sp);
        // seed some history: everyone pending at t=20, run a few merges
        for i in 0..12 {
            p.on_finish(i, 20.0 + i as f64 * 0.01);
        }
        for m in 0..4 {
            if let MergeDecision::Fire(plan) = p.decide(m, 25.0) {
                p.commit(m, &plan, 1.0);
            }
        }
        p.set_bound(1, 4);
        assert_eq!(p.current_bound(), 1);
        for lm in &p.last_merge {
            assert!(*lm >= 4 - 1 - 1, "tighten must clamp the staleness base");
        }
    }

    #[test]
    fn policy_set_bound_tighten_at_merge_zero_keeps_the_floor_sane() {
        let c = cfg(6, MergePolicyKind::Arrival, Some(4), 1.0);
        let mut p = ContinuousPolicy::new(&c, &speeds_for(&c));
        p.set_bound(0, 0);
        assert_eq!(p.current_bound(), 0);
        // floor = 0 - 1 - 0 = -1: the fresh "never merged" base survives
        assert!(p.last_merge.iter().all(|&lm| lm == -1));
        // and under bound 0 every client is required in merge 0, so the
        // decision waits (strictly later) for the in-flight fleet
        match p.decide(0, 0.0) {
            MergeDecision::Wait(t) => assert!(t > 0.0, "wait must strictly advance"),
            MergeDecision::Fire(_) => panic!("no one is pending yet"),
        }
    }

    #[test]
    fn policy_decide_with_every_client_required_fires_the_whole_fleet() {
        let c = cfg(8, MergePolicyKind::Arrival, Some(0), 0.125); // cap = 1
        let mut p = ContinuousPolicy::new(&c, &speeds_for(&c));
        for i in 0..8 {
            p.on_finish(i, 2.0 + i as f64 * 0.001);
        }
        match p.decide(0, 3.0) {
            MergeDecision::Fire(plan) => {
                // the required set overrides the participation cap
                assert_eq!(plan.participants, (0..8).collect::<Vec<_>>());
                assert!(plan.staleness.iter().all(|&s| s == 0));
            }
            MergeDecision::Wait(_) => panic!("everyone is pending — nothing to wait for"),
        }
    }

    #[test]
    fn policy_wait_times_strictly_advance_under_exact_duration_ties() {
        let c = cfg(5, MergePolicyKind::Window(0.25), Some(0), 1.0);
        let mut p = ContinuousPolicy::new(&c, &speeds_for(&c));
        // force every duration to collide in to_bits — the adversarial
        // tie case the event heap breaks by (rank, id)
        p.durations = vec![1.0; 5];
        p.ready = vec![1.0; 5];
        // window tick before anyone finishes: wait, strictly later
        match p.decide(0, 0.25) {
            MergeDecision::Wait(w) => {
                assert!(w > 0.25);
                assert_eq!(w.to_bits(), 1.0f64.to_bits());
            }
            MergeDecision::Fire(_) => panic!("nothing is pending"),
        }
        // all five finishes land at exactly t = 1.0 (identical bits)
        for i in 0..5 {
            p.on_finish(i, 1.0);
        }
        let plan = match p.decide(0, 1.0) {
            MergeDecision::Fire(plan) => plan,
            MergeDecision::Wait(_) => panic!("everyone pending and required — must fire"),
        };
        assert_eq!(plan.participants.len(), 5);
        for (i, t) in p.commit(0, &plan, 1.0) {
            assert!(t > 1.0, "client {i}: next finish must be strictly later");
            assert_eq!(t.to_bits(), 2.0f64.to_bits());
        }
        // and the next decision waits strictly past the merge instant
        match p.decide(1, 1.0) {
            MergeDecision::Wait(w) => {
                assert!(w > 1.0);
                assert_eq!(w.to_bits(), 2.0f64.to_bits());
            }
            MergeDecision::Fire(_) => panic!("nothing is pending after the commit"),
        }
    }

    #[test]
    fn policy_churn_departure_drops_pending_and_required_membership() {
        let c = cfg(6, MergePolicyKind::Arrival, Some(0), 1.0);
        let mut p = ContinuousPolicy::new(&c, &speeds_for(&c));
        p.durations = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        p.ready = p.durations.clone();
        p.on_finish(2, 3.0);
        p.deactivate(2);
        assert!(!p.is_active(2));
        // bound 0 requires every *active* client; 2 is gone with its
        // pending update, so the decision waits on the remaining fleet
        match p.decide(0, 3.0) {
            MergeDecision::Wait(t) => assert_eq!(t.to_bits(), 6.0f64.to_bits()),
            MergeDecision::Fire(_) => panic!("required clients are still in flight"),
        }
        // a departed client's orphaned finish is discarded, not merged
        assert!(!p.expects_finish(2, 3.0));
        // rejoin: fresh staleness base, new unit from the join instant
        let ready = p.activate(2, 3.5, 7, 1.0);
        assert!(p.is_active(2));
        assert_eq!(ready.to_bits(), (3.5 + 3.0).to_bits());
        assert_eq!(p.last_merge[2], 6, "rebased: staleness 0 at merge 7");
        assert!(p.expects_finish(2, ready));
    }

    #[test]
    fn policy_set_rate_retimes_in_flight_work_and_spares_pending() {
        let c = cfg(3, MergePolicyKind::Arrival, None, 1.0);
        let mut p = ContinuousPolicy::new(&c, &speeds_for(&c));
        p.durations = vec![4.0; 3];
        p.ready = vec![4.0; 3];
        // halfway through client 0's unit a 4x slowdown lands: the
        // remaining half stretches 4x
        let new = p.set_rate(0, 0.25, 2.0).expect("in flight: must re-time");
        assert_eq!(new.to_bits(), (2.0 + 2.0 * 4.0).to_bits());
        assert!(p.expects_finish(0, new));
        assert!(!p.expects_finish(0, 4.0), "superseded finish is orphaned");
        // a pending client's already-arrived update is not re-timed
        p.on_finish(1, 4.0);
        assert!(p.set_rate(1, 0.25, 4.5).is_none());
        // restoring the rate mid-flight shrinks the remainder back
        let back = p.set_rate(0, 1.0, 6.0).expect("still in flight");
        assert_eq!(back.to_bits(), 7.0f64.to_bits());
        // the next unit after a merge divides by the live multiplier
        p.on_finish(0, back);
        let plan = RoundPlan {
            participants: vec![0, 1],
            staleness: vec![0, 0],
            sim_time: back,
        };
        let next = p.commit(0, &plan, 1.0);
        assert_eq!(next[0].1.to_bits(), (back + 4.0).to_bits());
    }
}
