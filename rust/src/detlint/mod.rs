//! `detlint` — the repo-specific determinism linter (DESIGN.md §13).
//!
//! Every result this reproduction reports rests on determinism
//! invariants (client-id-order merges, seeded-only RNG, virtual clocks)
//! that runtime tests can only *sample*. This module checks the whole
//! class statically: [`lint_tree`] parses every file under `rust/src/`
//! and enforces the rule catalogue D01–D05 (see [`rules`]), and
//! `tests/determinism_lint.rs` runs it as a tier-1 test so a violation
//! fails `cargo test -q` with a file:line diagnostic.
//!
//! The pass is a hand-rolled lexical analysis ([`lexer`]) rather than a
//! `syn` AST walk: the build environment is offline (no registry), and
//! the crate's standing rule is to stub or gate missing dependencies
//! rather than add them. The lexer gives the properties that matter —
//! patterns never match inside strings/comments, `#[cfg(test)]` regions
//! are tracked, line numbers are exact — while keeping the linter
//! dependency-free and instant. If a `syn` dev-dependency ever becomes
//! available, `rules.rs` is the only file that would change: the
//! [`Finding`] contract and the fixture suite stay as-is.
//!
//! Suppression is explicit and audited: `// detlint: allow(D05, <reason>)`
//! on the offending line or the line above. A directive without a
//! justification is itself an error (D00).

pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use rules::lint_source;

/// The rule catalogue. D00 is reserved for malformed allow directives
/// themselves and cannot be allowed away.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// Malformed allow directive (unknown rule id or missing
    /// justification).
    D00,
    /// Iteration over `HashMap`/`HashSet` outside `#[cfg(test)]`.
    D01,
    /// `Instant::now` / `SystemTime::now` under `sim/`, `driver/`,
    /// `engine/`.
    D02,
    /// Ambient entropy (`thread_rng` / `from_entropy` / `rand::random` /
    /// `OsRng`) anywhere.
    D03,
    /// `unsafe` block or `unsafe impl` without a `// SAFETY:` comment.
    D04,
    /// Unordered float reduction (`.sum()` / `.fold`) in engine/driver
    /// merge paths outside `tree_reduce`.
    D05,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::D00 => "D00",
            Rule::D01 => "D01",
            Rule::D02 => "D02",
            Rule::D03 => "D03",
            Rule::D04 => "D04",
            Rule::D05 => "D05",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        match s {
            "D00" => Some(Rule::D00),
            "D01" => Some(Rule::D01),
            "D02" => Some(Rule::D02),
            "D03" => Some(Rule::D03),
            "D04" => Some(Rule::D04),
            "D05" => Some(Rule::D05),
            _ => None,
        }
    }
}

impl std::fmt::Display for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.id())
    }
}

/// One diagnostic: rule, repo path, 1-based line, and a message stating
/// the violated invariant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: Rule,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}:{}: {}", self.rule, self.path, self.line, self.msg)
    }
}

/// Render findings one per line (empty string for a clean tree) — the
/// form the tier-1 test prints on failure.
pub fn report(findings: &[Finding]) -> String {
    findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
}

/// Every `.rs` file under `root`, recursively, in sorted (deterministic)
/// order.
pub fn source_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut files = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
            .with_context(|| format!("detlint: cannot read {}", dir.display()))?
            .map(|e| e.map(|e| e.path()))
            .collect::<std::io::Result<_>>()
            .with_context(|| format!("detlint: cannot list {}", dir.display()))?;
        entries.sort();
        for path in entries {
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    Ok(files)
}

/// Lint every `.rs` file under `root` (typically `rust/src/`). Findings
/// come back sorted by (path, line, rule); an empty vec means the tree
/// is clean. Paths are reported repo-relative when `root` ends in
/// `rust/src`, so diagnostics match editor/CI conventions.
pub fn lint_tree(root: &Path) -> Result<Vec<Finding>> {
    let prefix = if root.ends_with("rust/src") { Some("rust/src") } else { None };
    let mut findings = Vec::new();
    for file in source_files(root)? {
        let rel = file.strip_prefix(root).unwrap_or(&file);
        let display = match prefix {
            Some(p) => format!("{p}/{}", rel.display()),
            None => rel.display().to_string(),
        };
        let src = std::fs::read_to_string(&file)
            .with_context(|| format!("detlint: cannot read {}", file.display()))?;
        findings.extend(rules::lint_source(&display, &src));
    }
    findings.sort_by(|a, b| (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule)));
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_roundtrip() {
        for rule in [Rule::D00, Rule::D01, Rule::D02, Rule::D03, Rule::D04, Rule::D05] {
            assert_eq!(Rule::parse(rule.id()), Some(rule));
        }
        assert_eq!(Rule::parse("D99"), None);
        assert_eq!(Rule::D02.to_string(), "D02");
    }

    #[test]
    fn finding_display_is_rule_path_line() {
        let f = Finding {
            rule: Rule::D01,
            path: "rust/src/x.rs".into(),
            line: 7,
            msg: "why".into(),
        };
        assert_eq!(f.to_string(), "D01 rust/src/x.rs:7: why");
        assert_eq!(report(&[f.clone(), f]).lines().count(), 2);
    }
}
