//! The determinism rule catalogue (D01–D05) and the allow-directive
//! escape hatch, evaluated over a [`lexer::Masked`] view of one file.
//!
//! Every pass works on masked code (comments and literal contents
//! blanked), so patterns never match inside strings or docs. Rules that
//! read comment *text* on purpose — `SAFETY:` for D04, allow directives
//! for suppression — use the per-line comment capture.
//!
//! The catalogue (DESIGN.md §13):
//! * **D01** — no iteration over `HashMap`/`HashSet` outside
//!   `#[cfg(test)]`: map order is nondeterministic, and every merge /
//!   report path must be a pure function of the seeded config.
//! * **D02** — no `Instant::now` / `SystemTime::now` under `sim/`,
//!   `driver/`, `engine/`: wall clock must never reach results.
//! * **D03** — no `thread_rng` / `from_entropy` / `rand::random` /
//!   `OsRng` anywhere (tests included): all RNG derives from seeds.
//! * **D04** — every `unsafe` block and `unsafe impl` carries a
//!   `// SAFETY:` comment (the static half of the soundness story; CI
//!   also denies `clippy::undocumented_unsafe_blocks`).
//! * **D05** — no unordered float reduction (`.sum()` / `.fold(`) in
//!   engine/driver merge paths outside `tree_reduce`; min/max folds and
//!   integer-annotated sums are order-insensitive and exempt.
//!
//! Suppression: `// detlint: allow(D05, <reason>)` on the flagged line
//! or the line directly above. A directive with an unknown rule id or an
//! empty reason is itself a finding (**D00**) — the escape hatch cannot
//! be used without a justification.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{self, Masked};
use super::{Finding, Rule};

/// Lint one file's source text under its (possibly virtual) repo path.
/// Returns findings with 1-based lines, sorted by (line, rule).
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let masked = lexer::mask(src);
    let test_lines = lexer::test_line_mask(&masked.code);
    let (allows, mut findings) = parse_allow_directives(path, &masked.comments);

    // keyed on (line, rule) so the D01 sub-checks can't double-report
    // one site they both catch
    let mut raw: BTreeMap<(usize, Rule), String> = BTreeMap::new();
    check_d01_map_iteration(&masked, &test_lines, &mut raw);
    check_d02_wall_clock(path, &masked, &test_lines, &mut raw);
    check_d03_ambient_entropy(&masked, &mut raw);
    check_d04_undocumented_unsafe(&masked, &test_lines, &mut raw);
    check_d05_float_reduction(path, &masked, &test_lines, &mut raw);

    for ((line, rule), msg) in raw {
        let suppressed = allows
            .get(&line)
            .or_else(|| line.checked_sub(1).and_then(|l| allows.get(&l)))
            .is_some_and(|set| set.contains(&rule));
        if !suppressed {
            findings.push(Finding { rule, path: path.to_string(), line: line + 1, msg });
        }
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

// ---- allow directives ------------------------------------------------------

/// Parse allow directives (shaped `detlint: allow(D05, <reason>)`) out
/// of per-line comment text. Valid directives populate the allow map
/// (0-based line → allowed rules); malformed ones become D00 findings
/// immediately.
fn parse_allow_directives(
    path: &str,
    comments: &[String],
) -> (BTreeMap<usize, BTreeSet<Rule>>, Vec<Finding>) {
    let mut allows: BTreeMap<usize, BTreeSet<Rule>> = BTreeMap::new();
    let mut findings = Vec::new();
    for (line, comment) in comments.iter().enumerate() {
        let Some(at) = comment.find("detlint:") else { continue };
        let rest = comment[at + "detlint:".len()..].trim_start();
        let bad = |why: &str| Finding {
            rule: Rule::D00,
            path: path.to_string(),
            line: line + 1,
            msg: format!("malformed detlint directive ({why}): `{}`", comment.trim()),
        };
        let Some(args) = rest.strip_prefix("allow(") else {
            findings.push(bad("expected `allow(<rule>, <reason>)`"));
            continue;
        };
        let Some(close) = args.find(')') else {
            findings.push(bad("unterminated allow(...)"));
            continue;
        };
        let inner = &args[..close];
        let (rule_txt, reason) = match inner.split_once(',') {
            Some((r, why)) => (r.trim(), why.trim()),
            None => (inner.trim(), ""),
        };
        let rule = Rule::parse(rule_txt);
        match rule {
            Some(rule) if rule != Rule::D00 && !reason.is_empty() => {
                allows.entry(line).or_default().insert(rule);
            }
            Some(Rule::D00) => findings.push(bad("D00 itself cannot be allowed")),
            Some(_) => findings.push(bad("missing justification string")),
            None => findings.push(bad("unknown rule id")),
        }
    }
    (allows, findings)
}

// ---- shared scanning helpers -----------------------------------------------

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Occurrences of `pat` in `code` at identifier boundaries: the char
/// before the match and the char after it must not extend an identifier.
/// (A `:` before the match is fine — `std::time::Instant::now` must
/// still match the `Instant::now` pattern.)
fn token_positions(code: &str, pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for (pos, _) in code.match_indices(pat) {
        let before_ok = pos == 0 || !is_ident(code[..pos].chars().next_back().unwrap_or(' '));
        let after_ok = !code[pos + pat.len()..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            out.push(pos);
        }
    }
    out
}

fn in_dirs(path: &str, dirs: &[&str]) -> bool {
    let p = path.replace('\\', "/");
    dirs.iter().any(|d| p.contains(&format!("/{d}/")) || p.starts_with(&format!("{d}/")))
}

/// The identifier ending at byte `end` (exclusive), if any.
fn ident_before(code: &str, end: usize) -> Option<&str> {
    let mut start = end;
    for (i, c) in code[..end].char_indices().rev() {
        if is_ident(c) {
            start = i;
        } else {
            break;
        }
    }
    (start < end).then(|| &code[start..end])
}

/// Skip whitespace backward from byte `end` (exclusive); returns the new
/// exclusive end.
fn skip_ws_back(code: &str, end: usize) -> usize {
    let mut e = end;
    for (i, c) in code[..end].char_indices().rev() {
        if c.is_whitespace() {
            e = i;
        } else {
            break;
        }
    }
    e
}

// ---- D01: HashMap/HashSet iteration ----------------------------------------

/// Wrapper types that are transparent for "what is the outermost
/// collection here" purposes: `cache: Mutex<HashMap<..>>` declares a
/// hash-map-shaped `cache`, but `shards: Vec<RwLock<HashMap<..>>>` is a
/// Vec (iterating *it* is ordered and fine).
const TYPE_WRAPPERS: [&str; 7] = ["Mutex", "RwLock", "Arc", "Box", "Option", "Rc", "RefCell"];

/// Methods that iterate a map/set in storage order.
const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// Iteration methods searched chain-wide (sub-check b): these only ever
/// exist on map/set-like receivers, so they are suspicious in any file
/// that mentions `HashMap`/`HashSet`, even when the receiver reached
/// them through an untyped closure or lock-guard binding.
const CHAIN_METHODS: [&str; 7] =
    ["keys", "values", "values_mut", "into_keys", "into_values", "drain", "retain"];

/// Names declared with the given collections as their outermost type
/// (after stripping [`TYPE_WRAPPERS`], `&`, `mut`, lifetimes), via
/// either a type annotation (`name: Mutex<HashMap<..>>`) or a direct
/// constructor binding (`let name = HashMap::new()`).
fn declared_names(code: &str, collections: [&str; 2]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for coll in collections {
        for pos in token_positions(code, coll) {
            // constructor binding: `name = HashMap::new()` (etc.)
            if code[pos + coll.len()..].starts_with("::") {
                let e = skip_ws_back(code, pos);
                if code[..e].ends_with('=') {
                    let e = skip_ws_back(code, e - 1);
                    if let Some(name) = ident_before(code, e) {
                        names.insert(name.to_string());
                    }
                }
                continue;
            }
            // type annotation: walk back over wrapper generics to the `:`
            if let Some(name) = annotated_name(code, pos) {
                names.insert(name);
            }
        }
    }
    names
}

/// If the collection token at `pos` is the outermost type of a
/// `name: <wrappers...><Collection>` annotation, return the name.
fn annotated_name(code: &str, pos: usize) -> Option<String> {
    let mut e = skip_ws_back(code, pos);
    loop {
        let before = &code[..e];
        if before.ends_with('<') {
            // a wrapper's generic bracket: the path segment before it
            // must be a transparent wrapper
            let seg_end = skip_ws_back(code, e - 1);
            let seg = ident_before(code, seg_end)?;
            if !TYPE_WRAPPERS.contains(&seg) {
                return None;
            }
            let mut s = seg_end - seg.len();
            // strip a leading path qualifier (`std::sync::Mutex<`)
            while code[..s].ends_with("::") {
                s = skip_ws_back(code, s - 2);
                let q = ident_before(code, s)?;
                s -= q.len();
            }
            e = skip_ws_back(code, s);
        } else if before.ends_with('&') {
            e = skip_ws_back(code, e - 1);
        } else if (before.ends_with("mut") || before.ends_with("dyn"))
            && !code[..e - 3].chars().next_back().is_some_and(is_ident)
        {
            e = skip_ws_back(code, e - 3);
        } else if before.ends_with("::") {
            // path qualifier on the collection itself
            e = skip_ws_back(code, e - 2);
            let q = ident_before(code, e)?;
            e = skip_ws_back(code, e - q.len());
        } else if before.ends_with(':') {
            // the annotation colon — the name sits just before it
            let ne = skip_ws_back(code, e - 1);
            return ident_before(code, ne).map(str::to_string);
        } else {
            return None;
        }
    }
}

fn check_d01_map_iteration(
    masked: &Masked,
    test_lines: &[bool],
    out: &mut BTreeMap<(usize, Rule), String>,
) {
    let code = &masked.code;
    let mentions_hash =
        !token_positions(code, "HashMap").is_empty() || !token_positions(code, "HashSet").is_empty();
    if !mentions_hash {
        return;
    }
    let starts = lexer::line_starts(code);
    let hash_names = declared_names(code, ["HashMap", "HashSet"]);
    let btree_names = declared_names(code, ["BTreeMap", "BTreeSet"]);
    let mut flag = |pos: usize, what: &str| {
        let line = lexer::line_of(&starts, pos);
        if !test_lines.get(line).copied().unwrap_or(false) {
            out.entry((line, Rule::D01)).or_insert_with(|| {
                format!("{what} iterates a HashMap/HashSet outside #[cfg(test)] (order-nondeterministic)")
            });
        }
    };

    // (a) declared-name taint: `name.iter()` / `for _ in name`
    for name in &hash_names {
        for pos in token_positions(code, name) {
            let after = &code[pos + name.len()..];
            if let Some(rest) = after.strip_prefix('.') {
                if let Some(m) = rest.split(|c: char| !is_ident(c)).next() {
                    if ITER_METHODS.contains(&m) && rest[m.len()..].starts_with('(') {
                        flag(pos, &format!("`{name}.{m}()`"));
                    }
                }
            }
            // `for x in name` / `for x in &name` / `for x in &mut name`
            let mut e = skip_ws_back(code, pos);
            while code[..e].ends_with('&') || code[..e].ends_with("mut") {
                e = if code[..e].ends_with('&') {
                    skip_ws_back(code, e - 1)
                } else {
                    skip_ws_back(code, e - 3)
                };
            }
            if ident_before(code, e) == Some("in") {
                flag(pos, &format!("`for _ in {name}`"));
            }
        }
    }

    // (b) chain methods that only exist on map/set receivers, reached
    // through untyped bindings (lock guards, closure params): flag
    // unless the receiver chain names a BTree-declared binding
    for m in CHAIN_METHODS {
        let pat = format!(".{m}(");
        for (pos, _) in code.match_indices(&pat) {
            let chain_start = chain_start(code, pos);
            let chain = &code[chain_start..pos];
            let exempt = chain
                .split(|c: char| !is_ident(c))
                .any(|id| !id.is_empty() && btree_names.contains(id));
            if !exempt {
                flag(pos, &format!("`.{m}()`"));
            }
        }
    }
}

/// Start of the receiver-chain expression ending at the `.` at `dot`:
/// scan back over idents, `.`/`(`/`)`/`[`/`]`/`?`/`&`/`*`, masked-string
/// quotes, and intra-line spaces. Stops at a newline so an unrelated
/// earlier expression can't leak exempting names into the chain.
fn chain_start(code: &str, dot: usize) -> usize {
    let mut start = dot;
    for (i, c) in code[..dot].char_indices().rev() {
        let chain_ch = is_ident(c)
            || matches!(c, '.' | '(' | ')' | '[' | ']' | '?' | '&' | '*' | '"' | ' ' | '\t');
        if chain_ch {
            start = i;
        } else {
            break;
        }
    }
    start
}

// ---- D02: wall clock -------------------------------------------------------

fn check_d02_wall_clock(
    path: &str,
    masked: &Masked,
    test_lines: &[bool],
    out: &mut BTreeMap<(usize, Rule), String>,
) {
    if !in_dirs(path, &["sim", "driver", "engine"]) {
        return;
    }
    let starts = lexer::line_starts(&masked.code);
    for pat in ["Instant::now", "SystemTime::now"] {
        for pos in token_positions(&masked.code, pat) {
            let line = lexer::line_of(&starts, pos);
            if !test_lines.get(line).copied().unwrap_or(false) {
                out.entry((line, Rule::D02)).or_insert_with(|| {
                    format!("`{pat}` in a deterministic module: wall clock must never reach results (use the seeded virtual clock)")
                });
            }
        }
    }
}

// ---- D03: ambient entropy --------------------------------------------------

fn check_d03_ambient_entropy(masked: &Masked, out: &mut BTreeMap<(usize, Rule), String>) {
    let starts = lexer::line_starts(&masked.code);
    for pat in ["thread_rng", "from_entropy", "rand::random", "OsRng"] {
        for pos in token_positions(&masked.code, pat) {
            let line = lexer::line_of(&starts, pos);
            out.entry((line, Rule::D03)).or_insert_with(|| {
                format!("`{pat}` is an ambient entropy source: all RNG must derive from the run seed (tests included)")
            });
        }
    }
}

// ---- D04: undocumented unsafe ----------------------------------------------

fn check_d04_undocumented_unsafe(
    masked: &Masked,
    test_lines: &[bool],
    out: &mut BTreeMap<(usize, Rule), String>,
) {
    let code = &masked.code;
    let starts = lexer::line_starts(code);
    let code_lines: Vec<&str> = code.lines().collect();
    for pos in token_positions(code, "unsafe") {
        let line = lexer::line_of(&starts, pos);
        if test_lines.get(line).copied().unwrap_or(false) {
            continue;
        }
        let after = code[pos + "unsafe".len()..].trim_start();
        let what = if after.starts_with('{') {
            "unsafe block"
        } else if after.starts_with("impl") {
            "unsafe impl"
        } else {
            // `unsafe fn` / `unsafe extern` / `unsafe trait` declarations
            // mark a contract for *callers*; D04 documents discharge
            // sites (blocks and impls), matching clippy's lint.
            continue;
        };
        if !has_safety_comment(masked, &code_lines, line) {
            out.entry((line, Rule::D04)).or_insert_with(|| {
                format!("{what} without a `// SAFETY:` comment stating the invariant that makes it sound")
            });
        }
    }
}

/// A `SAFETY:` comment counts if it is on the `unsafe` line itself or in
/// the contiguous comment/attribute block directly above it.
fn has_safety_comment(masked: &Masked, code_lines: &[&str], line: usize) -> bool {
    if masked.comments.get(line).is_some_and(|c| c.contains("SAFETY:")) {
        return true;
    }
    let mut l = line;
    while l > 0 {
        l -= 1;
        let comment = masked.comments.get(l).map(String::as_str).unwrap_or("");
        if comment.contains("SAFETY:") {
            return true;
        }
        let code_trim = code_lines.get(l).map(|s| s.trim()).unwrap_or("");
        let continues = !comment.is_empty() || code_trim.is_empty() || code_trim.starts_with("#[");
        if !continues {
            return false;
        }
    }
    false
}

// ---- D05: unordered float reduction ----------------------------------------

fn check_d05_float_reduction(
    path: &str,
    masked: &Masked,
    test_lines: &[bool],
    out: &mut BTreeMap<(usize, Rule), String>,
) {
    if !in_dirs(path, &["engine", "driver"]) {
        return;
    }
    let code = &masked.code;
    let starts = lexer::line_starts(code);
    let tree_reduce_spans = lexer::fn_body_lines(code, "tree_reduce");
    let exempt_line = |line: usize| {
        test_lines.get(line).copied().unwrap_or(false)
            || tree_reduce_spans.iter().any(|&(a, b)| line >= a && line <= b)
    };
    let mut flag = |pos: usize, msg: String| {
        let line = lexer::line_of(&starts, pos);
        if !exempt_line(line) {
            out.entry((line, Rule::D05)).or_insert(msg);
        }
    };

    for (pos, _) in code.match_indices(".sum") {
        let after = &code[pos + ".sum".len()..];
        if let Some(ty) = after.strip_prefix("::<").and_then(|t| t.split('>').next()) {
            if ty.contains("f32") || ty.contains("f64") {
                flag(pos, format!("`.sum::<{ty}>()` is an unordered float reduction in a merge path: use tree_reduce (or annotate an integer sum type)"));
            }
            // integer turbofish documents an order-insensitive sum
        } else if after.starts_with("()") {
            flag(
                pos,
                "`.sum()` in a merge path: float sums are order-sensitive — use tree_reduce, or make order-insensitivity explicit (`.sum::<usize>()` / allow)".to_string(),
            );
        }
    }

    for (pos, _) in code.match_indices(".fold(") {
        let args_from = pos + ".fold(".len();
        let args = balanced_paren_span(code, args_from - 1);
        // min/max combiners are order-insensitive (NaN-seeded reductions
        // like `.fold(f64::NAN, f64::max)` are the repo's eval idiom)
        if args.contains("::max") || args.contains("::min") || args.contains(".max(") || args.contains(".min(") {
            continue;
        }
        flag(
            pos,
            "`.fold(...)` in a merge path: sequential float folds are order-sensitive — use tree_reduce (min/max combiners are exempt)".to_string(),
        );
    }
}

/// The text inside the paren opening at `open` (balanced; clipped at EOF).
fn balanced_paren_span(code: &str, open: usize) -> &str {
    let bytes = code.as_bytes();
    let mut depth = 0usize;
    for (k, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return &code[open + 1..k];
                }
            }
            _ => {}
        }
    }
    &code[open + 1..]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(path: &str, src: &str) -> Vec<(Rule, usize)> {
        lint_source(path, src).into_iter().map(|f| (f.rule, f.line)).collect()
    }

    #[test]
    fn d01_flags_tainted_iteration_and_chain_methods() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> u32 {\n\
                   \x20   m.iter().map(|(_, v)| v).sum()\n\
                   }\n";
        assert_eq!(rules_of("rust/src/metrics/x.rs", src), vec![(Rule::D01, 3)]);

        // chain method through an untyped lock-guard binding
        let src = "use std::collections::HashMap;\n\
                   fn g(shard: &mut Shard) {\n\
                   \x20   shard.get_mut().expect(\"lock\").retain(|_, _| true);\n\
                   }\n";
        assert_eq!(rules_of("rust/src/metrics/x.rs", src), vec![(Rule::D01, 3)]);
    }

    #[test]
    fn d01_exempts_btree_vec_and_tests() {
        // BTreeMap chains, Vec-outermost declarations, and test regions
        let src = "use std::collections::{BTreeMap, HashMap};\n\
                   struct S { parts: BTreeMap<u32, u32>, shards: Vec<HashMap<u32, u32>> }\n\
                   impl S {\n\
                   \x20   fn ok(&self) -> usize { self.parts.keys().count() }\n\
                   \x20   fn also_ok(&self) -> usize { self.shards.len() }\n\
                   }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   fn t(m: &HashMap<u32, u32>) { for _ in m.iter() {} }\n\
                   }\n";
        assert_eq!(rules_of("rust/src/metrics/x.rs", src), vec![]);
    }

    #[test]
    fn d01_allow_directive_suppresses_with_reason() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> usize {\n\
                   \x20   // detlint: allow(D01, order-independent count)\n\
                   \x20   m.values().count()\n\
                   }\n";
        assert_eq!(rules_of("rust/src/metrics/x.rs", src), vec![]);
        // ...but a reason-less directive is a D00 and suppresses nothing
        let bad = src.replace(", order-independent count", "");
        let got = rules_of("rust/src/metrics/x.rs", &bad);
        assert_eq!(got, vec![(Rule::D00, 3), (Rule::D01, 4)]);
    }

    #[test]
    fn d02_scoped_to_deterministic_dirs() {
        let src = "fn t() -> std::time::Instant { std::time::Instant::now() }\n";
        assert_eq!(rules_of("rust/src/driver/x.rs", src), vec![(Rule::D02, 1)]);
        assert_eq!(rules_of("rust/src/util/bench.rs", src), vec![]);
    }

    #[test]
    fn d03_fires_everywhere_even_tests() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let _ = rand::thread_rng(); }\n}\n";
        assert_eq!(rules_of("rust/src/util/x.rs", src), vec![(Rule::D03, 3)]);
    }

    #[test]
    fn d04_requires_safety_on_blocks_and_impls() {
        let src = "unsafe impl Send for X {}\n";
        assert_eq!(rules_of("rust/src/runtime/x.rs", src), vec![(Rule::D04, 1)]);
        let ok = "// SAFETY: X owns no thread-affine state.\nunsafe impl Send for X {}\n";
        assert_eq!(rules_of("rust/src/runtime/x.rs", ok), vec![]);
        // multi-line comment block + attribute between comment and item
        let ok2 = "// SAFETY: disjoint indices —\n// no two workers alias.\n#[allow(dead_code)]\nunsafe impl Send for X {}\n";
        assert_eq!(rules_of("rust/src/runtime/x.rs", ok2), vec![]);
        // the second impl of a pair needs its own comment
        let pair = "// SAFETY: covers only the next line.\nunsafe impl Sync for X {}\nunsafe impl Send for X {}\n";
        assert_eq!(rules_of("rust/src/runtime/x.rs", pair), vec![(Rule::D04, 3)]);
    }

    #[test]
    fn d05_flags_sums_exempts_minmax_and_tree_reduce() {
        let src = "fn merge(xs: &[f32]) -> f32 { xs.iter().sum() }\n";
        assert_eq!(rules_of("rust/src/driver/x.rs", src), vec![(Rule::D05, 1)]);
        // out of scope dir
        assert_eq!(rules_of("rust/src/metrics/x.rs", src), vec![]);
        // min/max folds and integer turbofish are order-insensitive
        let ok = "fn m(xs: &[f64]) -> f64 { xs.iter().copied().fold(f64::NAN, f64::max) }\n\
                  fn b(xs: &[usize]) -> usize { xs.iter().sum::<usize>() }\n";
        assert_eq!(rules_of("rust/src/driver/x.rs", ok), vec![]);
        // tree_reduce's own body is the sanctioned reduction site
        let tr = "pub fn tree_reduce(items: Vec<f32>) -> f32 {\n    items.into_iter().fold(0.0, |a, b| a + b)\n}\n";
        assert_eq!(rules_of("rust/src/engine/x.rs", tr), vec![]);
    }

    #[test]
    fn patterns_inside_strings_and_comments_never_match() {
        let src = "fn f() {\n\
                   \x20   // mentions thread_rng and Instant::now in prose\n\
                   \x20   let msg = \"HashMap iter via thread_rng at Instant::now\";\n\
                   \x20   let _ = msg;\n\
                   }\n";
        assert_eq!(rules_of("rust/src/driver/x.rs", src), vec![]);
    }
}
