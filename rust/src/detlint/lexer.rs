//! Line-preserving source masking for the determinism linter.
//!
//! `detlint` (DESIGN.md §13) is a lexical pass, not a full parser: every
//! rule operates on a *masked* view of the source in which comment text,
//! string contents, and char-literal contents are blanked out (replaced
//! by spaces) while all code tokens and the line structure survive
//! byte-for-byte. That single transformation is what makes naive token
//! search sound: a rule pattern such as a wall-clock call or a map
//! iteration method can no longer match inside a doc comment, an error
//! message, or a test-name string. Comment text is captured separately,
//! per line, because two rules read it on purpose (`SAFETY:` comments
//! for D04, and the allow-directive escape hatch).
//!
//! The scanner understands the full Rust literal surface that appears in
//! this repo: line comments, nested block comments, plain and raw
//! strings (`r#"…"#`), byte strings, char and byte-char literals with
//! escapes, and the `'a`-vs-`'x'` lifetime/char ambiguity.

/// A masked view of one source file.
pub struct Masked {
    /// Source with comment text and literal contents blanked to spaces.
    /// Same length and identical newline positions as the input, so any
    /// byte offset maps to the same line in both views.
    pub code: String,
    /// Comment text captured per 0-based line (line + block comments on
    /// that line, concatenated). Empty string = no comment on the line.
    pub comments: Vec<String>,
}

struct Scanner {
    code: String,
    comments: Vec<String>,
    line: usize,
}

impl Scanner {
    fn new(cap: usize) -> Self {
        Self { code: String::with_capacity(cap), comments: vec![String::new()], line: 0 }
    }

    /// Emit a code character verbatim (tracks line structure).
    fn code_ch(&mut self, c: char) {
        self.code.push(c);
        if c == '\n' {
            self.newline();
        }
    }

    /// Emit a blanked (masked) character: newlines survive, everything
    /// else becomes a space of the same char count.
    fn blank_ch(&mut self, c: char) {
        if c == '\n' {
            self.code.push('\n');
            self.newline();
        } else {
            self.code.push(' ');
        }
    }

    /// Record a character of comment text on the current line (and blank
    /// it in the code view).
    fn comment_ch(&mut self, c: char) {
        if c == '\n' {
            self.code.push('\n');
            self.newline();
        } else {
            self.code.push(' ');
            self.comments[self.line].push(c);
        }
    }

    fn newline(&mut self) {
        self.line += 1;
        self.comments.push(String::new());
    }
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Mask one source file. See the module docs for the contract.
pub fn mask(src: &str) -> Masked {
    let chars: Vec<char> = src.chars().collect();
    let mut s = Scanner::new(src.len());
    // whether the previous *code* char continues an identifier — used to
    // tell the raw-string prefix `r"` from an identifier ending in `r`
    let mut prev_ident = false;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        match c {
            '/' if next == Some('/') => {
                s.blank_ch('/');
                s.blank_ch('/');
                i += 2;
                while i < chars.len() && chars[i] != '\n' {
                    s.comment_ch(chars[i]);
                    i += 1;
                }
                prev_ident = false;
            }
            '/' if next == Some('*') => {
                s.blank_ch('/');
                s.blank_ch('*');
                i += 2;
                let mut depth = 1usize;
                while i < chars.len() && depth > 0 {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        depth += 1;
                        s.comment_ch('/');
                        s.comment_ch('*');
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        s.blank_ch('*');
                        s.blank_ch('/');
                        i += 2;
                    } else {
                        s.comment_ch(chars[i]);
                        i += 1;
                    }
                }
                prev_ident = false;
            }
            '"' => {
                i = scan_string(&chars, i, &mut s);
                prev_ident = false;
            }
            'r' if !prev_ident && raw_string_hashes(&chars, i + 1).is_some() => {
                let hashes = raw_string_hashes(&chars, i + 1).unwrap_or(0);
                s.code_ch('r');
                i = scan_raw_string(&chars, i + 1, hashes, &mut s);
                prev_ident = false;
            }
            'b' if !prev_ident && next == Some('"') => {
                s.code_ch('b');
                i = scan_string(&chars, i + 1, &mut s);
                prev_ident = false;
            }
            'b' if !prev_ident && next == Some('\'') => {
                s.code_ch('b');
                i = scan_char_literal(&chars, i + 1, &mut s);
                prev_ident = false;
            }
            'b' if !prev_ident
                && next == Some('r')
                && raw_string_hashes(&chars, i + 2).is_some() =>
            {
                let hashes = raw_string_hashes(&chars, i + 2).unwrap_or(0);
                s.code_ch('b');
                s.code_ch('r');
                i = scan_raw_string(&chars, i + 2, hashes, &mut s);
                prev_ident = false;
            }
            '\'' => {
                // lifetime (`'a`, `'static`) vs char literal (`'x'`,
                // `'\n'`): a backslash or a close-quote two chars ahead
                // means char literal; an identifier char NOT followed by
                // a close quote means lifetime.
                let is_char_lit = match next {
                    Some('\\') => true,
                    Some(n) if is_ident(n) => chars.get(i + 2) == Some(&'\''),
                    Some(_) => true,
                    None => false,
                };
                if is_char_lit {
                    i = scan_char_literal(&chars, i, &mut s);
                } else {
                    s.code_ch('\'');
                    i += 1;
                }
                prev_ident = false;
            }
            _ => {
                s.code_ch(c);
                prev_ident = is_ident(c);
                i += 1;
            }
        }
    }
    Masked { code: s.code, comments: s.comments }
}

/// If `chars[from..]` starts `#*"` (zero or more hashes then a quote),
/// return the hash count — i.e. `from` sits right after a raw-string
/// `r` / `br` prefix.
fn raw_string_hashes(chars: &[char], from: usize) -> Option<usize> {
    let mut n = 0;
    let mut j = from;
    while chars.get(j) == Some(&'#') {
        n += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(n)
    } else {
        None
    }
}

/// Scan a plain string starting at the opening quote; returns the index
/// just past the closing quote. Contents are blanked; delimiters kept.
fn scan_string(chars: &[char], open: usize, s: &mut Scanner) -> usize {
    s.code_ch('"');
    let mut i = open + 1;
    while i < chars.len() {
        match chars[i] {
            '\\' => {
                s.blank_ch('\\');
                i += 1;
                if i < chars.len() {
                    s.blank_ch(chars[i]);
                    i += 1;
                }
            }
            '"' => {
                s.code_ch('"');
                return i + 1;
            }
            c => {
                s.blank_ch(c);
                i += 1;
            }
        }
    }
    i
}

/// Scan a raw string whose hashes start at `from` (right after the `r`);
/// returns the index just past the closing delimiter.
fn scan_raw_string(chars: &[char], from: usize, hashes: usize, s: &mut Scanner) -> usize {
    let mut i = from;
    for _ in 0..hashes {
        s.code_ch('#');
        i += 1;
    }
    s.code_ch('"');
    i += 1;
    while i < chars.len() {
        if chars[i] == '"' && (0..hashes).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
            s.code_ch('"');
            i += 1;
            for _ in 0..hashes {
                s.code_ch('#');
                i += 1;
            }
            return i;
        }
        s.blank_ch(chars[i]);
        i += 1;
    }
    i
}

/// Scan a char (or byte-char) literal starting at the opening quote;
/// returns the index just past the closing quote.
fn scan_char_literal(chars: &[char], open: usize, s: &mut Scanner) -> usize {
    s.code_ch('\'');
    let mut i = open + 1;
    if chars.get(i) == Some(&'\\') {
        s.blank_ch('\\');
        i += 1;
        if i < chars.len() {
            s.blank_ch(chars[i]);
            i += 1;
        }
    } else if i < chars.len() {
        s.blank_ch(chars[i]);
        i += 1;
    }
    if chars.get(i) == Some(&'\'') {
        s.code_ch('\'');
        i += 1;
    }
    i
}

// ---- line & region helpers -------------------------------------------------

/// Byte offsets where each line starts (line 0 starts at 0).
pub fn line_starts(code: &str) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, b) in code.bytes().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

/// 0-based line containing byte offset `pos`.
pub fn line_of(starts: &[usize], pos: usize) -> usize {
    match starts.binary_search(&pos) {
        Ok(l) => l,
        Err(l) => l - 1,
    }
}

/// Per-line flag: is this line inside a `#[cfg(test)]` item? Detected by
/// brace-matching forward from each `#[cfg(test)]` attribute in the
/// *masked* code (so the attribute text can't match inside a string). An
/// item that ends in `;` before any `{` (e.g. a cfg'd `use`) covers just
/// the statement's lines.
pub fn test_line_mask(code: &str) -> Vec<bool> {
    let starts = line_starts(code);
    let n_lines = starts.len();
    let mut mask = vec![false; n_lines];
    let bytes = code.as_bytes();
    for (pos, _) in code.match_indices("#[cfg(test)]") {
        let attr_line = line_of(&starts, pos);
        let mut j = pos + "#[cfg(test)]".len();
        // scan forward to the item's opening `{` (or terminating `;`)
        let mut open = None;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => {
                    open = Some(j);
                    break;
                }
                b';' => break,
                _ => j += 1,
            }
        }
        let end = match open {
            Some(open_pos) => {
                let mut depth = 0usize;
                let mut k = open_pos;
                loop {
                    if k >= bytes.len() {
                        break bytes.len().saturating_sub(1);
                    }
                    match bytes[k] {
                        b'{' => depth += 1,
                        b'}' => {
                            depth -= 1;
                            if depth == 0 {
                                break k;
                            }
                        }
                        _ => {}
                    }
                    k += 1;
                }
            }
            None => j.min(bytes.len().saturating_sub(1)),
        };
        let end_line = line_of(&starts, end);
        for flag in mask.iter_mut().take(end_line + 1).skip(attr_line) {
            *flag = true;
        }
    }
    mask
}

/// 0-based (start, end) line spans of the bodies of functions named
/// `name` in the masked code (used for the D05 `tree_reduce` exemption).
pub fn fn_body_lines(code: &str, name: &str) -> Vec<(usize, usize)> {
    let starts = line_starts(code);
    let bytes = code.as_bytes();
    let needle = format!("fn {name}");
    let mut spans = Vec::new();
    for (pos, _) in code.match_indices(&needle) {
        // token check: `fn` must not continue an identifier, and the name
        // must end at a non-identifier char
        if pos > 0 && is_ident(code[..pos].chars().next_back().unwrap_or(' ')) {
            continue;
        }
        let after = pos + needle.len();
        if code[after..].chars().next().is_some_and(is_ident) {
            continue;
        }
        let mut j = after;
        while j < bytes.len() && bytes[j] != b'{' && bytes[j] != b';' {
            j += 1;
        }
        if j >= bytes.len() || bytes[j] == b';' {
            continue;
        }
        let mut depth = 0usize;
        let mut k = j;
        let close = loop {
            if k >= bytes.len() {
                break bytes.len().saturating_sub(1);
            }
            match bytes[k] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break k;
                    }
                }
                _ => {}
            }
            k += 1;
        };
        spans.push((line_of(&starts, pos), line_of(&starts, close)));
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_comments_and_strings_preserving_lines() {
        let src = "let a = \"HashMap text\"; // trailing HashMap\nlet b = 2;\n";
        let m = mask(src);
        assert_eq!(m.code.lines().count(), src.lines().count());
        assert!(!m.code.contains("HashMap"), "masked: {:?}", m.code);
        assert!(m.comments[0].contains("trailing HashMap"));
        assert_eq!(m.comments[1], "");
        // delimiters survive so token boundaries stay visible
        assert!(m.code.contains("let a = \"            \";"));
    }

    #[test]
    fn masks_nested_block_comments_and_raw_strings() {
        let src = "/* outer /* inner thread_rng */ still */ let x = r#\"SystemTime::now\"#;\n";
        let m = mask(src);
        assert!(!m.code.contains("thread_rng"));
        assert!(!m.code.contains("SystemTime"));
        assert!(m.comments[0].contains("inner thread_rng"));
        assert!(m.code.contains("let x = r#\""));
    }

    #[test]
    fn distinguishes_lifetimes_from_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = 'x'; let n = '\\n'; c }\n";
        let m = mask(src);
        assert!(m.code.contains("&'a str"), "lifetime must survive: {:?}", m.code);
        assert!(!m.code.contains("'x'"), "char contents blanked: {:?}", m.code);
        assert!(m.code.contains("let c = ' '"));
    }

    #[test]
    fn byte_literals_and_ident_suffix_r() {
        let src = "let tr = b\"bytes\"; let c = b' '; let var = tr;\n";
        let m = mask(src);
        assert!(!m.code.contains("bytes"));
        assert!(m.code.contains("let var = tr;"), "ident ending in r untouched");
    }

    #[test]
    fn cfg_test_region_spans_the_braced_item() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let m = mask(src);
        let t = test_line_mask(&m.code);
        assert_eq!(t, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn fn_body_lines_finds_braced_bodies() {
        let src = "fn other() {}\nfn tree_reduce(x: u8) -> u8 {\n    x\n}\nfn next() {}\n";
        let m = mask(src);
        let spans = fn_body_lines(&m.code, "tree_reduce");
        assert_eq!(spans, vec![(1, 3)]);
        // `tree_reduce2` must not match `tree_reduce`
        let spans2 = fn_body_lines("fn tree_reduce2() {}\n", "tree_reduce");
        assert!(spans2.is_empty());
    }
}
