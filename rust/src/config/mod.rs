//! Typed experiment configuration with TOML-subset loading and validation.
//!
//! Every table/figure bench and every example drives the system through
//! this one struct, so sweeps are plain `cfg.with_*` chains. Config files
//! use the flat `key = value` / `[section]` format parsed by
//! `util::kvconf` (a strict subset of TOML).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::data::DatasetKind;
use crate::driver::SpeedPreset;
use crate::metrics::Budgets;
use crate::sim::{ChurnSpec, EngineKind, MergePolicyKind, RateScheduleSpec};
use crate::util::kvconf::KvConf;

/// Which training protocol to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtocolKind {
    AdaSplit,
    SlBasic,
    SplitFed,
    FedAvg,
    FedProx,
    Scaffold,
    FedNova,
}

impl ProtocolKind {
    pub const ALL: [ProtocolKind; 7] = [
        ProtocolKind::AdaSplit,
        ProtocolKind::SlBasic,
        ProtocolKind::SplitFed,
        ProtocolKind::FedAvg,
        ProtocolKind::FedProx,
        ProtocolKind::Scaffold,
        ProtocolKind::FedNova,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ProtocolKind::AdaSplit => "AdaSplit",
            ProtocolKind::SlBasic => "SL-basic",
            ProtocolKind::SplitFed => "SplitFed",
            ProtocolKind::FedAvg => "FedAvg",
            ProtocolKind::FedProx => "FedProx",
            ProtocolKind::Scaffold => "Scaffold",
            ProtocolKind::FedNova => "FedNova",
        }
    }

    /// kebab-case id used on the CLI and in config files.
    pub fn id(&self) -> &'static str {
        match self {
            ProtocolKind::AdaSplit => "ada-split",
            ProtocolKind::SlBasic => "sl-basic",
            ProtocolKind::SplitFed => "split-fed",
            ProtocolKind::FedAvg => "fed-avg",
            ProtocolKind::FedProx => "fed-prox",
            ProtocolKind::Scaffold => "scaffold",
            ProtocolKind::FedNova => "fed-nova",
        }
    }
}

impl std::str::FromStr for ProtocolKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        for p in Self::ALL {
            if s == p.id() || s.eq_ignore_ascii_case(p.name()) {
                return Ok(p);
            }
        }
        bail!(
            "unknown protocol `{s}` (expected one of: {})",
            Self::ALL.map(|p| p.id()).join(", ")
        )
    }
}

/// Parse a comma-separated candidate-bound list (`0,1,2,4,8`) for the
/// adaptive controller — shared by the config key and the CLI flag.
pub fn parse_arm_list(s: &str) -> Result<Vec<usize>> {
    let arms: Vec<usize> = s
        .split(',')
        .map(|part| {
            part.trim()
                .parse::<usize>()
                .map_err(|e| anyhow::anyhow!("`adapt_arms` entry `{part}`: {e}"))
        })
        .collect::<Result<_>>()?;
    ensure!(!arms.is_empty(), "adapt_arms must list at least one candidate bound");
    Ok(arms)
}

/// Full experiment configuration (paper §4.4 defaults).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub protocol: ProtocolKind,
    pub dataset: DatasetKind,
    /// number of clients N
    pub clients: usize,
    /// training rounds R
    pub rounds: usize,
    /// training samples per client (1 epoch/round over these)
    pub samples_per_client: usize,
    /// held-out test samples per client
    pub test_per_client: usize,
    /// geometric dataset-size imbalance across clients (1.0 = equal)
    pub imbalance: f64,
    /// experiment seed
    pub seed: u64,
    /// AdaSplit: local-phase fraction kappa (server joins after kappa*R)
    pub kappa: f64,
    /// AdaSplit: fraction of clients selected per iteration eta
    pub eta: f64,
    /// client model fraction mu in {0.2, 0.4, 0.6, 0.8}
    pub mu: f64,
    /// UCB discount gamma
    pub gamma: f64,
    /// mask L1 coefficient lambda (paper: 1e-5 CIFAR, 1e-3 NonIID)
    pub lambda: f32,
    /// activation L1 coefficient beta (Table 6; 0 = off)
    pub beta: f32,
    /// Table-5 ablation: also send server gradient to the client
    pub server_grad_to_client: bool,
    /// FedProx proximal coefficient
    pub prox_mu: f32,
    /// local epochs per round for FL protocols
    pub local_epochs: usize,
    /// evaluate every this many rounds (last round always evaluated)
    pub eval_every: usize,
    /// sparse-codec drop threshold: activations with |a| <= eps are not
    /// transmitted when beta > 0 (Table 6)
    pub sparse_eps: f32,
    /// resource budgets for the C3-Score
    pub budgets: Budgets,
    /// record per-iteration traces
    pub trace: bool,
    /// artifacts directory
    pub artifacts_dir: String,
    /// engine worker threads for per-client fan-out (0 = host parallelism)
    pub threads: usize,
    /// per-round client-participation fraction p in (0, 1]: each round the
    /// scheduler samples ceil(p * clients) clients (1.0 = everyone, the
    /// `SyncAll` scheduler; < 1.0 = seeded `SampledSync` subsampling with
    /// non-participant state spilled from memory). Under `AsyncBounded`
    /// this caps how many arrived updates the server absorbs per round
    /// (the staleness bound still wins).
    pub participation: f64,
    /// bounded-staleness async scheduling (`--staleness-bound s`): `Some(s)`
    /// runs the `AsyncBounded` scheduler — clients advance on per-client
    /// virtual clocks and the server merges updates up to `s` rounds
    /// stale; `None` (the default) keeps rounds synchronous. `Some(0)`
    /// with uniform speeds is bit-identical to `SyncAll`.
    pub staleness_bound: Option<usize>,
    /// per-client compute/network rate model (`--client-speeds`): uniform
    /// (default) | lognormal[:sigma] | stragglers
    pub client_speeds: SpeedPreset,
    /// fraction of slow clients under the `stragglers` speed preset
    pub straggler_frac: f64,
    /// aggregation down-weight per round of staleness in (0, 1]
    /// (`--stale-decay`): a contribution `k` rounds stale is weighted by
    /// `stale_decay^k` before renormalization
    pub stale_decay: f64,
    /// adaptive staleness bound (`--adaptive-bound`): a seeded UCB1
    /// controller re-picks the `AsyncBounded` bound from the candidate
    /// set every `adapt_window` rounds, rewarded by the window's
    /// C3-shaped accuracy-per-sim-time trade-off (DESIGN.md §9).
    /// Requires `staleness_bound` — the configured bound is the ceiling
    /// the candidate arms are clipped to (and sizes the delayed-gradient
    /// snapshot ring, which must cover every arm).
    pub adaptive_bound: bool,
    /// rounds per adaptation window (`--adapt-window`): the controller
    /// observes a reward and may switch arms only at window boundaries
    pub adapt_window: usize,
    /// explicit candidate bounds for the controller (`--adapt-arms
    /// 0,1,2`), clipped element-wise to `staleness_bound`; `None` uses
    /// the default set {0, 1, 2, 4, 8} (same clip). A singleton set
    /// degenerates to the equivalent fixed-bound run: the training
    /// trajectory and schedule are always identical, and the recorded
    /// metrics are bit-identical whenever the `eval_every` cadence
    /// already covers window boundaries (in particular at the default
    /// `eval_every = 1` — otherwise the adaptive run records extra,
    /// value-neutral eval points at the boundaries).
    pub adapt_arms: Option<Vec<usize>>,
    /// which driver executes the run (`--engine`): `rounds` (default)
    /// is the per-round barrier loop; `events` is the discrete-event
    /// driver (`sim::run_events`, DESIGN.md §11). With the default
    /// `round` merge policy the events engine replays the configured
    /// round scheduler bit-for-bit, so switching engines alone never
    /// changes results — only a continuous merge policy does.
    pub engine: EngineKind,
    /// when the server merges under the events engine
    /// (`--merge-policy`): `round` (default, the degenerate
    /// scheduler-replay policy) | `arrival` | `batch:K` | `window:DT`.
    /// Continuous policies require `engine = events`.
    pub merge_policy: MergePolicyKind,
    /// true delayed-gradient staleness (`--delayed-gradients`): the
    /// driver keeps a ring of round-start model snapshots and a client
    /// merging `s` rounds stale trains against the snapshot from `s`
    /// rounds ago — the broadcast it actually pulled — instead of the
    /// current server model (DESIGN.md §8). Requires `staleness_bound`
    /// (the snapshot window is the bound). `false` (the default) keeps
    /// PR 3's cadence-only staleness; `s = 0` is bit-identical either way.
    pub delayed_gradients: bool,
    /// seeded fleet churn (`--churn join:λ,leave:μ`): Poisson client
    /// arrival/departure processes on the event core (DESIGN.md §12).
    /// Requires a continuous merge policy.
    pub churn: Option<ChurnSpec>,
    /// time-varying client rates (`--rate-schedule
    /// diurnal:P:A+flaky:R:S:L`): a diurnal speed curve and/or seeded
    /// flaky-link episodes. Requires a continuous merge policy.
    pub rate_schedule: Option<RateScheduleSpec>,
    /// record the run's effective scenario event stream to this JSONL
    /// path (`--trace-out`). Requires a continuous merge policy.
    pub trace_out: Option<String>,
    /// replay a recorded scenario stream verbatim from this JSONL path
    /// (`--trace-in`). Excludes `churn`/`rate_schedule` — the trace *is*
    /// the scenario. Requires a continuous merge policy.
    pub trace_in: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            protocol: ProtocolKind::AdaSplit,
            dataset: DatasetKind::MixedCifar,
            clients: 5,
            rounds: 20,
            samples_per_client: 512,
            test_per_client: 256,
            imbalance: 1.0,
            seed: 0,
            kappa: 0.6,
            eta: 0.6,
            mu: 0.2,
            gamma: 0.87,
            lambda: 1e-5,
            beta: 0.0,
            server_grad_to_client: false,
            prox_mu: 0.01,
            local_epochs: 1,
            eval_every: 1,
            sparse_eps: 1e-4,
            budgets: Budgets::paper_mixed_cifar(),
            trace: false,
            artifacts_dir: "artifacts".into(),
            threads: 0,
            participation: 1.0,
            staleness_bound: None,
            client_speeds: SpeedPreset::Uniform,
            straggler_frac: 0.1,
            stale_decay: 0.5,
            adaptive_bound: false,
            adapt_window: 5,
            adapt_arms: None,
            engine: EngineKind::Rounds,
            merge_policy: MergePolicyKind::Round,
            delayed_gradients: false,
            churn: None,
            rate_schedule: None,
            trace_out: None,
            trace_in: None,
        }
    }
}

impl ExperimentConfig {
    /// A configuration small enough for CI / integration tests.
    pub fn quick_test() -> Self {
        Self {
            rounds: 3,
            samples_per_client: 64,
            test_per_client: 32,
            ..Self::default()
        }
    }

    /// Paper-default config for a dataset (budgets and lambda follow §4.4).
    pub fn paper_default(dataset: DatasetKind) -> Self {
        let (budgets, lambda) = match dataset {
            DatasetKind::MixedCifar => (Budgets::paper_mixed_cifar(), 1e-5),
            DatasetKind::MixedNonIid => (Budgets::paper_mixed_noniid(), 1e-3),
        };
        Self { dataset, budgets, lambda, ..Self::default() }
    }

    /// Parse from the TOML-subset text format. Unknown keys are rejected
    /// (typo safety); absent keys keep their defaults.
    pub fn from_kv_text(text: &str) -> Result<Self> {
        let kv = KvConf::parse(text)?;
        const KNOWN: &[&str] = &[
            "protocol", "dataset", "clients", "rounds", "samples_per_client",
            "test_per_client", "imbalance", "seed", "kappa", "eta", "mu",
            "gamma", "lambda", "beta", "server_grad_to_client", "prox_mu",
            "local_epochs", "eval_every", "sparse_eps", "trace",
            "artifacts_dir", "threads", "participation", "staleness_bound",
            "client_speeds", "straggler_frac", "stale_decay", "delayed_gradients",
            "adaptive_bound", "adapt_window", "adapt_arms", "engine", "merge_policy",
            "churn", "rate_schedule", "trace_out", "trace_in",
            "budgets.bandwidth_gb", "budgets.client_tflops", "budgets.temp",
        ];
        for k in kv.keys() {
            ensure!(KNOWN.contains(&k.as_str()), "unknown config key `{k}`");
        }
        let d = Self::default();
        let dataset: DatasetKind = kv.get_str("dataset", "mixed-cifar").parse()?;
        let paper = Self::paper_default(dataset);
        let cfg = Self {
            protocol: kv.get_str("protocol", "ada-split").parse()?,
            dataset,
            clients: kv.get_usize("clients", d.clients)?,
            rounds: kv.get_usize("rounds", d.rounds)?,
            samples_per_client: kv.get_usize("samples_per_client", d.samples_per_client)?,
            test_per_client: kv.get_usize("test_per_client", d.test_per_client)?,
            imbalance: kv.get_f64("imbalance", d.imbalance)?,
            seed: kv.get_u64("seed", d.seed)?,
            kappa: kv.get_f64("kappa", d.kappa)?,
            eta: kv.get_f64("eta", d.eta)?,
            mu: kv.get_f64("mu", d.mu)?,
            gamma: kv.get_f64("gamma", d.gamma)?,
            lambda: kv.get_f32("lambda", paper.lambda)?,
            beta: kv.get_f32("beta", d.beta)?,
            server_grad_to_client: kv.get_bool("server_grad_to_client", false)?,
            prox_mu: kv.get_f32("prox_mu", d.prox_mu)?,
            local_epochs: kv.get_usize("local_epochs", d.local_epochs)?,
            eval_every: kv.get_usize("eval_every", d.eval_every)?,
            sparse_eps: kv.get_f32("sparse_eps", d.sparse_eps)?,
            budgets: Budgets {
                bandwidth_gb: kv.get_f64("budgets.bandwidth_gb", paper.budgets.bandwidth_gb)?,
                client_tflops: kv
                    .get_f64("budgets.client_tflops", paper.budgets.client_tflops)?,
                temp: kv.get_f64("budgets.temp", paper.budgets.temp)?,
            },
            trace: kv.get_bool("trace", false)?,
            artifacts_dir: kv.get_str("artifacts_dir", &d.artifacts_dir),
            threads: kv.get_usize("threads", d.threads)?,
            participation: kv.get_f64("participation", d.participation)?,
            staleness_bound: kv
                .raw("staleness_bound")
                .map(|v| {
                    v.parse::<usize>()
                        .map_err(|e| anyhow::anyhow!("`staleness_bound` = `{v}`: {e}"))
                })
                .transpose()?,
            client_speeds: kv.get_str("client_speeds", &d.client_speeds.id()).parse()?,
            straggler_frac: kv.get_f64("straggler_frac", d.straggler_frac)?,
            stale_decay: kv.get_f64("stale_decay", d.stale_decay)?,
            adaptive_bound: kv.get_bool("adaptive_bound", false)?,
            adapt_window: kv.get_usize("adapt_window", d.adapt_window)?,
            adapt_arms: kv.raw("adapt_arms").map(parse_arm_list).transpose()?,
            engine: kv.get_str("engine", EngineKind::Rounds.id()).parse()?,
            merge_policy: kv
                .get_str("merge_policy", &MergePolicyKind::Round.id())
                .parse()?,
            delayed_gradients: kv.get_bool("delayed_gradients", false)?,
            churn: kv.raw("churn").map(|v| v.parse::<ChurnSpec>()).transpose()?,
            rate_schedule: kv
                .raw("rate_schedule")
                .map(|v| v.parse::<RateScheduleSpec>())
                .transpose()?,
            trace_out: kv.raw("trace_out").map(str::to_string),
            trace_in: kv.raw("trace_in").map(str::to_string),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load_toml(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_kv_text(&text)
    }

    /// Number of client-side blocks k for the configured mu.
    pub fn split_k(&self) -> usize {
        // mu in {0.2, 0.4, 0.6, 0.8} -> k in {1, 2, 3, 4}
        ((self.mu * 5.0).round() as usize).clamp(1, 4)
    }

    /// Artifact config tag, e.g. `c10_mu1`.
    pub fn config_tag(&self) -> String {
        format!("{}_mu{}", self.dataset.tag(), self.split_k())
    }

    /// Rounds spent in AdaSplit's local phase.
    pub fn local_rounds(&self) -> usize {
        ((self.kappa * self.rounds as f64).round() as usize).min(self.rounds)
    }

    /// Clients selected per global-phase iteration.
    pub fn selected_per_iter(&self) -> usize {
        ((self.eta * self.clients as f64).round() as usize).clamp(1, self.clients)
    }

    /// Resolved engine worker count (`threads == 0` means "use the host's
    /// available parallelism"). Never returns 0.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            crate::engine::available_threads()
        } else {
            self.threads
        }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.clients > 0, "clients must be > 0");
        ensure!(self.rounds > 0, "rounds must be > 0");
        ensure!((0.0..=1.0).contains(&self.kappa), "kappa in [0,1]");
        ensure!(self.eta > 0.0 && self.eta <= 1.0, "eta in (0,1]");
        ensure!((0.0..=1.0).contains(&self.gamma), "gamma in [0,1]");
        ensure!(
            self.participation > 0.0 && self.participation <= 1.0,
            "participation in (0,1]"
        );
        ensure!(
            (0.0..=1.0).contains(&self.straggler_frac),
            "straggler_frac in [0,1]"
        );
        ensure!(
            self.stale_decay > 0.0 && self.stale_decay <= 1.0,
            "stale_decay in (0,1]"
        );
        ensure!(
            !self.delayed_gradients || self.staleness_bound.is_some(),
            "delayed_gradients requires staleness_bound (the version ring \
             is sized by the bound; without async scheduling nothing is stale)"
        );
        ensure!(
            self.adapt_window > 0,
            "adapt_window must be > 0 (rounds per adaptation window)"
        );
        ensure!(
            !self.adaptive_bound || self.staleness_bound.is_some(),
            "adaptive_bound requires staleness_bound (the candidate arms are \
             clipped to it, and the delayed-gradient snapshot ring it sizes \
             must cover every arm the controller can pick)"
        );
        if let Some(arms) = &self.adapt_arms {
            ensure!(
                !arms.is_empty(),
                "adapt_arms must list at least one candidate bound"
            );
        }
        ensure!(
            self.merge_policy == MergePolicyKind::Round || self.engine == EngineKind::Events,
            "merge_policy `{}` requires the events engine (the rounds driver \
             only knows the barrier'd `round` policy; pass --engine events)",
            self.merge_policy.id()
        );
        if let MergePolicyKind::Batch(k) = self.merge_policy {
            ensure!(
                k <= self.clients,
                "merge_policy batch size must not exceed clients ({k} > {}): \
                 the pending set can never reach the trigger",
                self.clients
            );
        }
        let continuous = self.merge_policy != MergePolicyKind::Round;
        ensure!(
            self.churn.is_none() || continuous,
            "churn requires a continuous merge policy (the degenerate `round` \
             policy replays a closed-world scheduler; pass e.g. \
             --merge-policy arrival)"
        );
        ensure!(
            self.rate_schedule.is_none() || continuous,
            "rate_schedule requires a continuous merge policy (re-timing a \
             pending finish only exists on the event core's continuous path)"
        );
        ensure!(
            self.trace_out.is_none() || continuous,
            "trace_out requires a continuous merge policy (the scenario \
             stream is recorded by the event core's continuous path)"
        );
        ensure!(
            self.trace_in.is_none() || continuous,
            "trace_in requires a continuous merge policy (the replayed \
             stream drives the event core's continuous path)"
        );
        ensure!(
            self.trace_in.is_none() || (self.churn.is_none() && self.rate_schedule.is_none()),
            "trace_in replays a recorded scenario stream verbatim and \
             excludes churn/rate_schedule (the trace is the scenario)"
        );
        ensure!(
            (0.05..=0.95).contains(&self.mu),
            "mu must map to a lowered split (0.2/0.4/0.6/0.8)"
        );
        ensure!(self.imbalance > 0.0, "imbalance must be positive");
        ensure!(
            self.samples_per_client >= 32,
            "need at least one batch of training data per client"
        );
        // SL/FL variants only lowered at mu=0.2 (k=1); AdaSplit has all
        if self.protocol != ProtocolKind::AdaSplit {
            ensure!(
                self.split_k() == 1,
                "{} artifacts are lowered for mu=0.2 only",
                self.protocol.name()
            );
        }
        Ok(())
    }

    // -- sweep helpers -----------------------------------------------------

    pub fn with_protocol(mut self, p: ProtocolKind) -> Self {
        self.protocol = p;
        self
    }

    pub fn with_kappa(mut self, kappa: f64) -> Self {
        self.kappa = kappa;
        self
    }

    pub fn with_eta(mut self, eta: f64) -> Self {
        self.eta = eta;
        self
    }

    pub fn with_mu(mut self, mu: f64) -> Self {
        self.mu = mu;
        self
    }

    pub fn with_beta(mut self, beta: f32) -> Self {
        self.beta = beta;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_participation(mut self, participation: f64) -> Self {
        self.participation = participation;
        self
    }

    /// `Some(s)` runs the `AsyncBounded` scheduler with staleness bound
    /// `s`; `None` restores synchronous rounds.
    pub fn with_staleness_bound(mut self, bound: Option<usize>) -> Self {
        self.staleness_bound = bound;
        self
    }

    pub fn with_client_speeds(mut self, preset: SpeedPreset) -> Self {
        self.client_speeds = preset;
        self
    }

    pub fn with_straggler_frac(mut self, frac: f64) -> Self {
        self.straggler_frac = frac;
        self
    }

    pub fn with_stale_decay(mut self, decay: f64) -> Self {
        self.stale_decay = decay;
        self
    }

    /// `true` turns on the UCB bound controller (requires a
    /// `staleness_bound` ceiling for the candidate arms).
    pub fn with_adaptive_bound(mut self, adaptive: bool) -> Self {
        self.adaptive_bound = adaptive;
        self
    }

    pub fn with_adapt_window(mut self, window: usize) -> Self {
        self.adapt_window = window;
        self
    }

    /// Explicit candidate bounds for the controller (`None` restores the
    /// default {0, 1, 2, 4, 8} set).
    pub fn with_adapt_arms(mut self, arms: Option<Vec<usize>>) -> Self {
        self.adapt_arms = arms;
        self
    }

    /// Select the executing driver (`EngineKind::Events` for the
    /// discrete-event engine).
    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Select the server merge policy (continuous policies require the
    /// events engine).
    pub fn with_merge_policy(mut self, policy: MergePolicyKind) -> Self {
        self.merge_policy = policy;
        self
    }

    /// `true` turns on per-client model versioning: stale clients train
    /// against the snapshot they actually pulled (DESIGN.md §8).
    pub fn with_delayed_gradients(mut self, delayed: bool) -> Self {
        self.delayed_gradients = delayed;
        self
    }

    /// Seeded fleet churn on the event core (`None` restores the fixed
    /// fleet). Requires a continuous merge policy.
    pub fn with_churn(mut self, churn: Option<ChurnSpec>) -> Self {
        self.churn = churn;
        self
    }

    /// Time-varying client rates (`None` restores static rates).
    /// Requires a continuous merge policy.
    pub fn with_rate_schedule(mut self, schedule: Option<RateScheduleSpec>) -> Self {
        self.rate_schedule = schedule;
        self
    }

    /// Record the effective scenario stream to this JSONL path.
    pub fn with_trace_out(mut self, path: Option<String>) -> Self {
        self.trace_out = path;
        self
    }

    /// Replay a recorded scenario stream from this JSONL path (excludes
    /// churn/rate_schedule).
    pub fn with_trace_in(mut self, path: Option<String>) -> Self {
        self.trace_in = path;
        self
    }

    pub fn with_clients(mut self, clients: usize) -> Self {
        self.clients = clients;
        self
    }

    pub fn with_scale(mut self, rounds: usize, samples: usize, test: usize) -> Self {
        self.rounds = rounds;
        self.samples_per_client = samples;
        self.test_per_client = test;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_defaults() {
        let c = ExperimentConfig::default();
        assert_eq!(c.clients, 5);
        assert_eq!(c.rounds, 20);
        assert!((c.kappa - 0.6).abs() < 1e-9);
        assert!((c.eta - 0.6).abs() < 1e-9);
        assert!((c.gamma - 0.87).abs() < 1e-9);
        c.validate().unwrap();
    }

    #[test]
    fn split_k_mapping() {
        for (mu, k) in [(0.2, 1), (0.4, 2), (0.6, 3), (0.8, 4)] {
            assert_eq!(ExperimentConfig { mu, ..Default::default() }.split_k(), k);
        }
    }

    #[test]
    fn config_tag_tracks_dataset() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.config_tag(), "c10_mu1");
        c.dataset = DatasetKind::MixedNonIid;
        assert_eq!(c.config_tag(), "c50_mu1");
    }

    #[test]
    fn local_rounds_and_selection() {
        let c = ExperimentConfig::default();
        assert_eq!(c.local_rounds(), 12); // 0.6 * 20
        assert_eq!(c.selected_per_iter(), 3); // 0.6 * 5
    }

    #[test]
    fn validate_rejects_nonsense() {
        let mut c = ExperimentConfig::default();
        c.kappa = 1.5;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.protocol = ProtocolKind::FedAvg;
        c.mu = 0.4;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.samples_per_client = 8;
        assert!(c.validate().is_err());
    }

    #[test]
    fn kv_text_parsing() {
        let c = ExperimentConfig::from_kv_text(
            "protocol = \"fed-avg\"\nrounds = 7\ndataset = \"mixed-noniid\"\n\
             [budgets]\ntemp = 4.0\n",
        )
        .unwrap();
        assert_eq!(c.protocol, ProtocolKind::FedAvg);
        assert_eq!(c.rounds, 7);
        assert_eq!(c.clients, 5);
        assert_eq!(c.dataset, DatasetKind::MixedNonIid);
        // dataset-specific defaults applied
        assert!((c.budgets.bandwidth_gb - 84.64).abs() < 1e-9);
        assert!((c.budgets.temp - 4.0).abs() < 1e-9);
        assert!((c.lambda - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn kv_text_rejects_unknown_keys_and_bad_values() {
        assert!(ExperimentConfig::from_kv_text("roundz = 3\n").is_err());
        assert!(ExperimentConfig::from_kv_text("protocol = \"sgd\"\n").is_err());
        assert!(ExperimentConfig::from_kv_text("kappa = 2.0\n").is_err());
        assert!(ExperimentConfig::from_kv_text("participation = 0.0\n").is_err());
        assert!(ExperimentConfig::from_kv_text("participation = 1.5\n").is_err());
    }

    #[test]
    fn participation_default_parse_and_helper() {
        let d = ExperimentConfig::default();
        assert!((d.participation - 1.0).abs() < 1e-12, "default is full participation");
        let c = ExperimentConfig::from_kv_text("participation = 0.25\n").unwrap();
        assert!((c.participation - 0.25).abs() < 1e-12);
        let c = ExperimentConfig::default().with_participation(0.5).with_clients(64);
        assert!((c.participation - 0.5).abs() < 1e-12);
        assert_eq!(c.clients, 64);
        c.validate().unwrap();
    }

    #[test]
    fn threads_default_auto_and_parse() {
        let d = ExperimentConfig::default();
        assert_eq!(d.threads, 0, "default is auto");
        assert!(d.effective_threads() >= 1);
        let c = ExperimentConfig::from_kv_text("threads = 4\n").unwrap();
        assert_eq!(c.threads, 4);
        assert_eq!(c.effective_threads(), 4);
        assert_eq!(ExperimentConfig::default().with_threads(2).threads, 2);
    }

    #[test]
    fn async_scheduler_keys_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert_eq!(d.staleness_bound, None, "default is synchronous");
        assert_eq!(d.client_speeds, SpeedPreset::Uniform);
        assert!((d.straggler_frac - 0.1).abs() < 1e-12);
        assert!((d.stale_decay - 0.5).abs() < 1e-12);

        let c = ExperimentConfig::from_kv_text(
            "staleness_bound = 3\nclient_speeds = \"stragglers\"\n\
             straggler_frac = 0.25\nstale_decay = 0.8\n",
        )
        .unwrap();
        assert_eq!(c.staleness_bound, Some(3));
        assert_eq!(c.client_speeds, SpeedPreset::Stragglers);
        assert!((c.straggler_frac - 0.25).abs() < 1e-12);
        assert!((c.stale_decay - 0.8).abs() < 1e-12);

        let c = ExperimentConfig::from_kv_text("client_speeds = \"lognormal:0.7\"\n").unwrap();
        assert_eq!(c.client_speeds, SpeedPreset::Lognormal { sigma: 0.7 });
        assert_eq!(c.staleness_bound, None, "absent key stays synchronous");

        assert!(ExperimentConfig::from_kv_text("staleness_bound = -1\n").is_err());
        assert!(ExperimentConfig::from_kv_text("staleness_bound = fast\n").is_err());
        assert!(ExperimentConfig::from_kv_text("client_speeds = \"warp\"\n").is_err());
        assert!(ExperimentConfig::from_kv_text("straggler_frac = 1.5\n").is_err());
        assert!(ExperimentConfig::from_kv_text("stale_decay = 0.0\n").is_err());
        assert!(ExperimentConfig::from_kv_text("stale_decay = 1.5\n").is_err());

        let c = ExperimentConfig::default()
            .with_staleness_bound(Some(2))
            .with_client_speeds(SpeedPreset::Stragglers)
            .with_straggler_frac(0.3)
            .with_stale_decay(0.9);
        assert_eq!(c.staleness_bound, Some(2));
        c.validate().unwrap();
        assert_eq!(c.with_staleness_bound(None).staleness_bound, None);
    }

    #[test]
    fn delayed_gradients_key_parses_and_requires_a_bound() {
        let d = ExperimentConfig::default();
        assert!(!d.delayed_gradients, "default is cadence-only staleness");

        let c = ExperimentConfig::from_kv_text(
            "staleness_bound = 2\ndelayed_gradients = true\n",
        )
        .unwrap();
        assert!(c.delayed_gradients);
        assert_eq!(c.staleness_bound, Some(2));

        // versioning without a staleness bound is a config error, not a
        // silent no-op
        assert!(ExperimentConfig::from_kv_text("delayed_gradients = true\n").is_err());
        assert!(ExperimentConfig::from_kv_text("delayed_gradients = maybe\n").is_err());

        let c = ExperimentConfig::default()
            .with_staleness_bound(Some(1))
            .with_delayed_gradients(true);
        c.validate().unwrap();
        assert!(c.clone().with_delayed_gradients(false).validate().is_ok());
        assert!(c.with_staleness_bound(None).validate().is_err());
    }

    #[test]
    fn adaptive_bound_keys_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert!(!d.adaptive_bound, "default is a fixed bound");
        assert_eq!(d.adapt_window, 5);
        assert_eq!(d.adapt_arms, None);

        let c = ExperimentConfig::from_kv_text(
            "staleness_bound = 4\nadaptive_bound = true\nadapt_window = 3\n\
             adapt_arms = \"0, 2,4\"\n",
        )
        .unwrap();
        assert!(c.adaptive_bound);
        assert_eq!(c.adapt_window, 3);
        assert_eq!(c.adapt_arms, Some(vec![0, 2, 4]));

        assert!(ExperimentConfig::from_kv_text("adapt_arms = \"fast\"\n").is_err());
        assert!(ExperimentConfig::from_kv_text("adapt_arms = \"\"\n").is_err());
        assert!(ExperimentConfig::from_kv_text("adaptive_bound = maybe\n").is_err());

        let c = ExperimentConfig::default()
            .with_staleness_bound(Some(2))
            .with_adaptive_bound(true)
            .with_adapt_window(4)
            .with_adapt_arms(Some(vec![0, 2]));
        c.validate().unwrap();
        assert!(c.clone().with_adapt_arms(None).validate().is_ok());
        assert!(c.with_staleness_bound(None).validate().is_err());
    }

    #[test]
    fn invalid_combinations_yield_distinct_error_messages() {
        // every invalid combination must produce its own actionable
        // message — a shared or shuffled error would send the user
        // hunting in the wrong place. The matrix pins (input -> message
        // fragment) and cross-checks that all fragments are distinct.
        let matrix: Vec<(ExperimentConfig, &str)> = vec![
            (
                ExperimentConfig::default().with_adaptive_bound(true),
                "adaptive_bound requires staleness_bound",
            ),
            (
                ExperimentConfig::default()
                    .with_staleness_bound(Some(2))
                    .with_adaptive_bound(true)
                    .with_adapt_window(0),
                "adapt_window must be > 0",
            ),
            (
                ExperimentConfig::default().with_delayed_gradients(true),
                "delayed_gradients requires staleness_bound",
            ),
            (
                ExperimentConfig::default().with_stale_decay(0.0),
                "stale_decay in (0,1]",
            ),
            (
                ExperimentConfig::default().with_stale_decay(1.5),
                "stale_decay in (0,1]",
            ),
            (
                ExperimentConfig::default()
                    .with_staleness_bound(Some(2))
                    .with_adaptive_bound(true)
                    .with_adapt_arms(Some(vec![])),
                "adapt_arms must list at least one candidate bound",
            ),
            (
                ExperimentConfig::default().with_merge_policy(MergePolicyKind::Arrival),
                "requires the events engine",
            ),
            (
                ExperimentConfig::default()
                    .with_engine(EngineKind::Events)
                    .with_merge_policy(MergePolicyKind::Batch(99)),
                "batch size must not exceed clients",
            ),
            (
                ExperimentConfig::default()
                    .with_churn(Some(ChurnSpec { join: 0.5, leave: 0.3 })),
                "churn requires a continuous merge policy",
            ),
            (
                ExperimentConfig::default()
                    .with_rate_schedule(Some(RateScheduleSpec::default())),
                "rate_schedule requires a continuous merge policy",
            ),
            (
                ExperimentConfig::default().with_trace_out(Some("t.jsonl".into())),
                "trace_out requires a continuous merge policy",
            ),
            (
                ExperimentConfig::default().with_trace_in(Some("t.jsonl".into())),
                "trace_in requires a continuous merge policy",
            ),
            (
                ExperimentConfig::default()
                    .with_engine(EngineKind::Events)
                    .with_merge_policy(MergePolicyKind::Arrival)
                    .with_trace_in(Some("t.jsonl".into()))
                    .with_churn(Some(ChurnSpec { join: 0.5, leave: 0.3 })),
                "excludes churn/rate_schedule",
            ),
        ];
        for (cfg, fragment) in &matrix {
            let err = cfg.validate().expect_err(fragment).to_string();
            assert!(
                err.contains(fragment),
                "expected `{fragment}` in `{err}`"
            );
        }
        // distinctness: each failure mode names its own knob
        let fragments: std::collections::BTreeSet<&str> =
            matrix.iter().map(|(_, f)| *f).collect();
        assert_eq!(fragments.len(), 12, "twelve distinct messages across the matrix");

        // the same combinations are rejected on the text-config path too
        assert!(ExperimentConfig::from_kv_text("churn = \"join:0.5\"\n").is_err());
        assert!(ExperimentConfig::from_kv_text(
            "engine = \"events\"\nmerge_policy = \"arrival\"\n\
             trace_in = \"t.jsonl\"\nchurn = \"join:0.5\"\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_kv_text("adaptive_bound = true\n").is_err());
        assert!(ExperimentConfig::from_kv_text(
            "staleness_bound = 2\nadaptive_bound = true\nadapt_window = 0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_kv_text("delayed_gradients = true\n").is_err());
        assert!(ExperimentConfig::from_kv_text("stale_decay = 0.0\n").is_err());
        assert!(ExperimentConfig::from_kv_text("merge_policy = \"arrival\"\n").is_err());
        assert!(ExperimentConfig::from_kv_text(
            "engine = \"events\"\nmerge_policy = \"batch:99\"\n"
        )
        .is_err());
    }

    #[test]
    fn engine_and_merge_policy_keys_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert_eq!(d.engine, EngineKind::Rounds, "default is the round loop");
        assert_eq!(d.merge_policy, MergePolicyKind::Round);

        let c = ExperimentConfig::from_kv_text(
            "engine = \"events\"\nmerge_policy = \"batch:3\"\n",
        )
        .unwrap();
        assert_eq!(c.engine, EngineKind::Events);
        assert_eq!(c.merge_policy, MergePolicyKind::Batch(3));

        // the events engine with the default degenerate policy is legal
        // (that is the bit-parity configuration)
        let c = ExperimentConfig::from_kv_text("engine = \"events\"\n").unwrap();
        assert_eq!(c.merge_policy, MergePolicyKind::Round);

        assert!(ExperimentConfig::from_kv_text("engine = \"barrier\"\n").is_err());
        assert!(ExperimentConfig::from_kv_text("merge_policy = \"batch:0\"\n").is_err());
        assert!(ExperimentConfig::from_kv_text("merge_policy = \"window:-1\"\n").is_err());

        let c = ExperimentConfig::default()
            .with_engine(EngineKind::Events)
            .with_merge_policy(MergePolicyKind::Window(0.5));
        c.validate().unwrap();
        assert!(c.with_engine(EngineKind::Rounds).validate().is_err());
    }

    #[test]
    fn scenario_keys_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert_eq!(d.churn, None, "default is a closed world");
        assert_eq!(d.rate_schedule, None);
        assert_eq!(d.trace_out, None);
        assert_eq!(d.trace_in, None);

        let c = ExperimentConfig::from_kv_text(
            "engine = \"events\"\nmerge_policy = \"arrival\"\n\
             churn = \"join:0.5,leave:0.3\"\n\
             rate_schedule = \"diurnal:8:0.5+flaky:0.2:10:1.5\"\n\
             trace_out = \"run.jsonl\"\n",
        )
        .unwrap();
        assert_eq!(c.churn, Some(ChurnSpec { join: 0.5, leave: 0.3 }));
        let rs = c.rate_schedule.unwrap();
        assert!(rs.diurnal.is_some() && rs.flaky.is_some());
        assert_eq!(c.trace_out.as_deref(), Some("run.jsonl"));

        // replay excludes synthesis knobs but stands alone fine
        let c = ExperimentConfig::from_kv_text(
            "engine = \"events\"\nmerge_policy = \"batch:2\"\ntrace_in = \"run.jsonl\"\n",
        )
        .unwrap();
        assert_eq!(c.trace_in.as_deref(), Some("run.jsonl"));

        assert!(ExperimentConfig::from_kv_text(
            "engine = \"events\"\nmerge_policy = \"arrival\"\nchurn = \"join:-1\"\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_kv_text(
            "engine = \"events\"\nmerge_policy = \"arrival\"\nrate_schedule = \"tide:1\"\n"
        )
        .is_err());

        let c = ExperimentConfig::default()
            .with_engine(EngineKind::Events)
            .with_merge_policy(MergePolicyKind::Arrival)
            .with_churn(Some(ChurnSpec { join: 1.0, leave: 0.5 }))
            .with_rate_schedule(Some("diurnal:4:0.25".parse().unwrap()))
            .with_trace_out(Some("out.jsonl".into()));
        c.validate().unwrap();
        assert!(c.clone().with_merge_policy(MergePolicyKind::Round).validate().is_err());
        assert!(c.with_trace_in(Some("in.jsonl".into())).validate().is_err());
    }

    #[test]
    fn arm_list_parsing() {
        assert_eq!(parse_arm_list("0,1,2,4,8").unwrap(), vec![0, 1, 2, 4, 8]);
        assert_eq!(parse_arm_list(" 3 ").unwrap(), vec![3]);
        assert!(parse_arm_list("").is_err());
        assert!(parse_arm_list("1,x").is_err());
        assert!(parse_arm_list("1,-2").is_err());
    }

    #[test]
    fn protocol_roundtrip_ids() {
        for p in ProtocolKind::ALL {
            let back: ProtocolKind = p.id().parse().unwrap();
            assert_eq!(back, p);
        }
    }
}
