//! Synchronization facade for the engine: `std` primitives normally,
//! [loom](https://docs.rs/loom)'s model-checked doubles under
//! `--cfg loom` (DESIGN.md §13).
//!
//! The pool's concurrency core — the job channel, the shared-receiver
//! mutex, the atomic claim index, the `DoneGuard` send-on-drop — is
//! exactly the kind of code loom exists for: its correctness argument is
//! about *orderings*, which unit tests can only sample. Routing every
//! primitive through this one module lets `tests/loom_pool.rs` explore
//! all interleavings of the dispatch protocol without the production
//! build carrying any extra dependency: `loom` is not in Cargo.toml at
//! all (offline builds never resolve it); the CI loom job adds it as a
//! `[target.'cfg(loom)']` dependency before building with
//! `RUSTFLAGS="--cfg loom"`, which is the only configuration in which
//! the `loom::` paths below are ever compiled.
//!
//! Loom API deltas the engine accommodates (see `engine/mod.rs`):
//! * no `Mutex::get_mut` / `Mutex::into_inner` — the pool uses `lock()`
//!   even where `&mut self` would allow the faster accessors;
//! * no `available_parallelism` — `available_threads()` reports a fixed
//!   2 under loom;
//! * no unwind modeling — the worker's `catch_unwind` containment is
//!   compiled out under loom (models run panic-free tasks).

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{mpsc, Arc, Mutex};
#[cfg(loom)]
pub use loom::thread;

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{mpsc, Arc, Mutex};
#[cfg(not(loom))]
pub use std::thread;
