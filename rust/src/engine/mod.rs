//! Parallel client-execution engine: a persistent worker pool that fans
//! per-client work out across OS threads and merges the results back in
//! client-id order.
//!
//! The determinism contract (DESIGN.md §5): a fan-out closure may read
//! shared state (`&Env`, compiled artifacts, round-start snapshots) and
//! mutate only *its own* slot, and every reduction over the returned
//! per-client values happens on the caller's thread in client-id order.
//! Because the accumulation tree is fixed by construction — independent of
//! how indices land on workers — a run with `--threads 8` is bit-identical
//! to `--threads 1`, which executes the very same closures inline in the
//! same order.
//!
//! The pool is deliberately dependency-free (`std::thread` + an mpsc job
//! channel + an atomic work index). Workers are spawned lazily on the
//! first parallel `run*` call and then *persist*: subsequent calls enqueue
//! a lifetime-erased job instead of paying spawn/join, which is what makes
//! per-step fan-outs (AdaSplit's per-iteration exchanges especially)
//! cheap. Within a run, workers claim indices from a shared counter, so a
//! slow client (compile hit, big batch list) does not stall the others.
//! Dropping the pool closes the job channel and joins every worker.
//!
//! **Fail-fast**: once any index returns an error, workers stop claiming
//! *new* indices (already-claimed work runs to completion). This cannot
//! change which error is reported: claims are handed out in ascending
//! order, so every index below the lowest-failing one was claimed before
//! it and completes — the lowest-index error still wins, deterministically.

pub mod sync;

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use self::sync::{mpsc, thread, Arc, AtomicBool, AtomicUsize, Mutex, Ordering};

/// Worker threads available on this host (>= 1). Under `--cfg loom` the
/// host has no meaning (the model explores schedules, not CPUs), so this
/// reports a fixed small width to keep the state space bounded.
pub fn available_threads() -> usize {
    #[cfg(loom)]
    {
        2
    }
    #[cfg(not(loom))]
    {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Divide a thread budget across a nesting level with `n` independent
/// units of work: returns `(outer, per_unit)` where `outer` units run
/// concurrently and each gets `per_unit` threads for its own inner
/// fan-outs. Division (not multiplication) keeps total concurrency ~
/// `budget` however deep the nesting (`compare` → `run_seeds` → per-run
/// pool). Both components are >= 1.
pub fn split_budget(budget: usize, n: usize) -> (usize, usize) {
    let outer = budget.min(n).max(1);
    (outer, (budget / outer).max(1))
}

/// Anything the engine can fan client work out over. Implemented by the
/// protocol `Env`; kept as a trait so the engine has no protocol
/// dependency.
pub trait ParallelEnv {
    fn n_clients(&self) -> usize;
    /// Resolved worker count (never 0).
    fn threads(&self) -> usize;
    /// A long-lived pool whose warmed workers should be reused for this
    /// env's fan-outs. The default (`None`) makes [`par_clients`] fall
    /// back to a transient pool, preserving the old per-call behaviour
    /// for envs that don't carry one.
    fn shared_pool(&self) -> Option<&ClientPool> {
        None
    }
}

/// Fan `f(i)` out over clients `0..env.n_clients()` and return the results
/// in client-id order. Reuses the env's shared pool when it has one (no
/// spawn after warm-up); see [`ClientPool::run`] for the execution
/// contract.
pub fn par_clients<E, T, F>(env: &E, f: F) -> Result<Vec<T>>
where
    E: ParallelEnv,
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    match env.shared_pool() {
        Some(pool) => pool.run(env.n_clients(), f),
        None => par_indexed(env.threads(), env.n_clients(), f),
    }
}

/// The claim loop shared by every parallel entry point (caller thread and
/// pool workers alike): claim ascending indices from `next`, stop as soon
/// as `failed` is observed or the range is exhausted, and hand each
/// claimed index to `run_one` exactly once.
///
/// Factored out so the fail-fast/claim semantics live in one place and
/// can be pinned directly by tests (no sleep-based racing required).
pub(crate) fn worker_loop<R>(next: &AtomicUsize, failed: &AtomicBool, n: usize, run_one: &R)
where
    R: Fn(usize) + ?Sized,
{
    loop {
        // fail-fast: stop claiming new indices after any failure
        if failed.load(Ordering::Acquire) {
            break;
        }
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        run_one(i);
    }
}

/// A lifetime-erased unit of pool work: "run this borrowed closure, then
/// signal completion". The dispatcher guarantees (by blocking on the
/// completion channel) that the borrow outlives every use, so the
/// `'static` on the reference is a promise kept by control flow, not by
/// the type system — see [`ClientPool::fan_out`].
struct Job {
    task: &'static (dyn Fn() + Sync),
    done: DoneGuard,
}

/// Signals job completion on drop, so the dispatcher is released even if
/// the task panics on a worker (the unwind drops the guard).
struct DoneGuard(mpsc::Sender<()>);

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let _ = self.0.send(());
    }
}

/// Long-lived worker threads + the sending half of their job channel.
struct PoolCore {
    job_tx: mpsc::Sender<Job>,
    handles: Vec<thread::JoinHandle<()>>,
}

/// A sized, persistent worker pool for round-level fan-out/fan-in.
///
/// `threads == 0` means "auto" (host parallelism). With one thread every
/// `run*` call degenerates to an inline serial loop over the same closures
/// in the same order — the basis of the serial/parallel equivalence
/// guarantee.
///
/// Workers (`threads - 1` of them; the calling thread always participates
/// as the final worker) are spawned lazily on the first parallel call and
/// then parked on the job channel between calls: after warm-up, a `run*`
/// call costs two channel hops instead of a spawn/join cycle. Dropping
/// the pool closes the channel and joins every worker.
pub struct ClientPool {
    threads: usize,
    core: Mutex<Option<PoolCore>>,
    spawned: AtomicUsize,
}

impl std::fmt::Debug for ClientPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ClientPool")
            .field("threads", &self.threads)
            .field("spawned", &self.spawned.load(Ordering::Relaxed))
            .finish()
    }
}

impl ClientPool {
    pub fn new(threads: usize) -> Self {
        Self {
            threads: if threads == 0 { available_threads() } else { threads },
            core: Mutex::new(None),
            spawned: AtomicUsize::new(0),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Total worker threads spawned over this pool's lifetime. After
    /// warm-up this is exactly `threads - 1` and never grows again — the
    /// observable "zero spawns per call" property.
    pub fn spawned_workers(&self) -> usize {
        self.spawned.load(Ordering::Relaxed)
    }

    /// Run `f(0..n)` on the pool; results come back in index order.
    /// Errors are surfaced deterministically: the lowest-index failure
    /// wins, regardless of which worker hit it first.
    pub fn run<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        let workers = self.threads.max(1).min(n.max(1));
        if workers <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }

        let next = AtomicUsize::new(0);
        let failed = AtomicBool::new(false);
        let slots: Vec<Mutex<Option<Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let run_one = |i: usize| {
            let r = f(i);
            if r.is_err() {
                failed.store(true, Ordering::Release);
            }
            *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
        };
        let task = || worker_loop(&next, &failed, n, &run_one);
        self.fan_out(workers - 1, &task);
        collect_slots(slots)
    }

    /// Run `f(i, &mut states[i])` on the pool with each worker holding an
    /// exclusive borrow of its claimed slot; results in index order,
    /// lowest-index error wins.
    pub fn run_mut<S, T, F>(&self, states: &mut [S], f: F) -> Result<Vec<T>>
    where
        S: Send,
        T: Send,
        F: Fn(usize, &mut S) -> Result<T> + Sync,
    {
        let n = states.len();
        let base = SlicePtr(states.as_mut_ptr());
        self.run(n, move |i| {
            // SAFETY: `i` is claimed exactly once from the atomic work
            // index, so this is the only live borrow of `states[i]`; the
            // pool's fan-in blocks until every worker is done, so no
            // borrow outlives this call while `states` is reborrowed.
            let slot = unsafe { &mut *base.0.add(i) };
            f(i, slot)
        })
    }

    /// Dispatch `extra` copies of `task` to pool workers, run it once on
    /// the calling thread, and block until every dispatched copy has
    /// finished. Blocking here is what makes the lifetime erasure in
    /// [`Job`] sound: `task`'s borrows of the caller's stack stay alive
    /// until no worker can still be executing it.
    fn fan_out(&self, extra: usize, task: &(dyn Fn() + Sync)) {
        if extra == 0 {
            task();
            return;
        }
        // SAFETY: the erased reference is only reachable through jobs
        // whose completion (send-or-drop of the DoneGuard) we await below
        // before returning, so it never outlives the frame it borrows.
        let task_static: &'static (dyn Fn() + Sync) = unsafe { std::mem::transmute(task) };
        let (done_tx, done_rx) = mpsc::channel();
        let job_tx = self.ensure_workers();
        for _ in 0..extra {
            let job = Job { task: task_static, done: DoneGuard(done_tx.clone()) };
            if job_tx.send(job).is_err() {
                // channel closed (cannot happen while `self` is alive,
                // but degrade to caller-only execution rather than hang)
                break;
            }
        }
        drop(done_tx);
        task();
        // Ok = a worker finished one copy; Err = every outstanding guard
        // is gone (all copies finished, some by unwinding). Either way no
        // worker can still hold the erased borrow once this loop exits.
        while done_rx.recv().is_ok() {}
    }

    /// Lazily spawn the long-lived workers (`threads - 1`; the caller is
    /// the last worker) and hand back the job sender. Workers share one
    /// receiver behind a mutex: a parked worker blocks in `recv`, the
    /// rest queue on the lock — pickup is serialised, execution is not.
    fn ensure_workers(&self) -> mpsc::Sender<Job> {
        let mut core = self.core.lock().unwrap_or_else(|e| e.into_inner());
        if core.is_none() {
            let (job_tx, job_rx) = mpsc::channel::<Job>();
            let job_rx = Arc::new(Mutex::new(job_rx));
            let workers = self.threads.saturating_sub(1);
            let mut handles = Vec::with_capacity(workers);
            for _ in 0..workers {
                let rx = Arc::clone(&job_rx);
                handles.push(thread::spawn(move || loop {
                    let job = {
                        let rx = rx.lock().unwrap_or_else(|e| e.into_inner());
                        match rx.recv() {
                            Ok(job) => job,
                            // sender dropped: pool is shutting down
                            Err(_) => return,
                        }
                    };
                    // A panicking task must not kill the worker (later
                    // jobs would queue forever); containment here turns
                    // it into an empty slot, reported by the fan-in as a
                    // deterministic error. `job.done` signals on drop.
                    // (loom has no unwind modeling; model tasks are
                    // panic-free, so containment compiles out there.)
                    #[cfg(not(loom))]
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job.task));
                    #[cfg(loom)]
                    (job.task)();
                }));
            }
            self.spawned.fetch_add(workers, Ordering::Relaxed);
            *core = Some(PoolCore { job_tx, handles });
        }
        core.as_ref().expect("pool core just initialised").job_tx.clone()
    }
}

impl Drop for ClientPool {
    fn drop(&mut self) {
        // `lock()` rather than `get_mut()`: we hold `&mut self` so the
        // lock is uncontended, and loom's Mutex models no `get_mut`
        let core = self.core.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(core) = core {
            // closing the channel wakes every parked worker with RecvError
            drop(core.job_tx);
            for handle in core.handles {
                let _ = handle.join();
            }
        }
    }
}

/// Execute `f(i)` for `i in 0..n` on up to `threads` workers and return
/// the results in index order. Convenience wrapper over a transient
/// [`ClientPool`] (spawn + join per call) — hot per-round paths should
/// hold a pool and call [`ClientPool::run`] instead.
pub fn par_indexed<T, F>(threads: usize, n: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    ClientPool::new(threads.max(1)).run(n, f)
}

/// Raw-pointer wrapper that lets pool workers carve disjoint `&mut`
/// element borrows out of one slice. Soundness relies on the atomic work
/// index handing every slot index to exactly one worker.
#[derive(Clone, Copy)]
struct SlicePtr<S>(*mut S);

// SAFETY: `SlicePtr` is only shared between workers that access disjoint
// indices (each index is claimed exactly once from the atomic counter),
// so concurrent `&mut` borrows never alias.
unsafe impl<S: Send> Sync for SlicePtr<S> {}
// SAFETY: same disjointness argument as `Sync` above; moving the copied
// pointer to a worker transfers access to the claimed slots it will
// reach, never duplicates a live `&mut`, and `S: Send` keeps the
// elements themselves sound to touch from that thread.
unsafe impl<S: Send> Send for SlicePtr<S> {}

/// Execute `f(i, &mut states[i])` for every slot on up to `threads`
/// workers; results in index order, lowest-index error wins. Convenience
/// wrapper over a transient [`ClientPool`], like [`par_indexed`].
pub fn par_slice_mut<S, T, F>(threads: usize, states: &mut [S], f: F) -> Result<Vec<T>>
where
    S: Send,
    T: Send,
    F: Fn(usize, &mut S) -> Result<T> + Sync,
{
    ClientPool::new(threads.max(1)).run_mut(states, f)
}

/// In-order fan-in. Scanning ascending indices makes the lowest-index
/// error win; under fail-fast, every index below the lowest error was
/// claimed before it (claims are handed out in order) and completed, so
/// the scan always reaches that error before any unclaimed `None` slot.
fn collect_slots<T>(slots: Vec<Mutex<Option<Result<T>>>>) -> Result<Vec<T>> {
    let mut out = Vec::with_capacity(slots.len());
    for (i, slot) in slots.iter().enumerate() {
        // `lock()` + `take()` rather than `into_inner()`: the fan-in only
        // runs after every worker finished (so the lock is uncontended),
        // and loom's Mutex models no `into_inner`
        let taken = slot.lock().unwrap_or_else(|e| e.into_inner()).take();
        match taken {
            Some(r) => out.push(r?),
            None => return Err(anyhow!("engine: slot {i} produced no result")),
        }
    }
    Ok(out)
}

/// Stable shard assignment for a client id: a SplitMix64 bit-mix reduced
/// to `shards` buckets. A pure function of the id — identical across
/// runs, platforms, and thread counts — so sharded stores place (and
/// find) every client deterministically, independent of insertion order
/// or scheduling.
pub fn stable_shard(id: usize, shards: usize) -> usize {
    debug_assert!(shards > 0, "stable_shard needs at least one shard");
    let mut z = (id as u64).wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z % shards as u64) as usize
}

// ---- deterministic tree reduction -----------------------------------------

/// Fold an id-ordered list of per-client values into one through a
/// balanced tree of adjacent-pair combines. The reduction shape is a pure
/// function of `items.len()` — independent of thread count or worker
/// schedule — so every thread count produces the bit-identical result,
/// and large fan-ins avoid the left-leaning error accumulation of a
/// sequential fold. Returns `None` for an empty input.
pub fn tree_reduce<T, C>(mut items: Vec<T>, mut combine: C) -> Option<T>
where
    C: FnMut(T, T) -> T,
{
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(combine(a, b)),
                None => next.push(a),
            }
        }
        items = next;
    }
    items.pop()
}

// ---- order-preserving progress streaming ----------------------------------

/// Sending half of an order-preserving progress channel: workers emit
/// `(index, line)` from inside a fan-out as each unit finishes.
pub struct ProgressSink {
    tx: Mutex<mpsc::Sender<(usize, String)>>,
}

impl ProgressSink {
    /// Emit one progress line for unit `index`. Never blocks; if the
    /// receiver is gone the line is dropped.
    pub fn emit(&self, index: usize, line: impl Into<String>) {
        if let Ok(tx) = self.tx.lock() {
            tx.send((index, line.into())).ok();
        }
    }
}

/// Receiving half: iterate to get lines back **in index order**, each
/// yielded as soon as it *and every lower index* have finished — so
/// progress streams during the fan-out instead of printing in one burst
/// after the fan-in, and the output order never depends on scheduling.
/// Out-of-order completions are buffered; once every sink clone is
/// dropped, any buffered remainder drains in index order.
pub struct OrderedProgress {
    rx: mpsc::Receiver<(usize, String)>,
    pending: BTreeMap<usize, String>,
    next: usize,
}

/// Create an order-preserving progress channel.
pub fn ordered_progress() -> (ProgressSink, OrderedProgress) {
    let (tx, rx) = mpsc::channel();
    (
        ProgressSink { tx: Mutex::new(tx) },
        OrderedProgress { rx, pending: BTreeMap::new(), next: 0 },
    )
}

impl Iterator for OrderedProgress {
    type Item = String;

    fn next(&mut self) -> Option<String> {
        loop {
            if let Some(line) = self.pending.remove(&self.next) {
                self.next += 1;
                return Some(line);
            }
            match self.rx.recv() {
                Ok((i, line)) => {
                    self.pending.insert(i, line);
                }
                // channel closed: drain whatever arrived, still in order
                Err(_) => match self.pending.pop_first() {
                    Some((i, line)) => {
                        self.next = i + 1;
                        return Some(line);
                    }
                    None => return None,
                },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order() {
        for threads in [1, 2, 4, 8] {
            let v = par_indexed(threads, 64, |i| Ok(i * i)).unwrap();
            assert_eq!(v, (0..64).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn serial_and_parallel_agree_on_float_work() {
        // per-index work is self-contained, so any thread count must
        // produce bit-identical values
        let work = |i: usize| -> Result<f64> {
            let mut acc = 0.0f64;
            for k in 1..200 {
                acc += ((i * k) as f64).sin() / k as f64;
            }
            Ok(acc)
        };
        let serial = par_indexed(1, 32, work).unwrap();
        for threads in [2, 4, 8] {
            assert_eq!(serial, par_indexed(threads, 32, work).unwrap());
        }
    }

    #[test]
    fn lowest_index_error_wins() {
        for threads in [1, 4] {
            let r = par_indexed(threads, 16, |i| {
                if i % 5 == 3 {
                    Err(anyhow!("boom {i}"))
                } else {
                    Ok(i)
                }
            });
            assert_eq!(r.unwrap_err().to_string(), "boom 3", "threads={threads}");
        }
    }

    #[test]
    fn run_mut_updates_every_slot_exactly_once() {
        for threads in [1, 3, 8] {
            let mut xs: Vec<u64> = (0..40).collect();
            let doubled = ClientPool::new(threads)
                .run_mut(&mut xs, |i, x| {
                    *x *= 2;
                    Ok(i as u64)
                })
                .unwrap();
            assert_eq!(xs, (0..40).map(|i| i * 2).collect::<Vec<_>>());
            assert_eq!(doubled, (0..40).collect::<Vec<_>>());
        }
    }

    #[test]
    fn split_budget_divides_not_multiplies() {
        assert_eq!(split_budget(8, 7), (7, 1));
        assert_eq!(split_budget(16, 7), (7, 2));
        assert_eq!(split_budget(2, 7), (2, 1));
        assert_eq!(split_budget(8, 3), (3, 2));
        assert_eq!(split_budget(1, 5), (1, 1));
        assert_eq!(split_budget(0, 5), (1, 1));
        assert_eq!(split_budget(4, 0), (1, 4));
        // total concurrency never exceeds the budget (when budget >= 1)
        for budget in 1..20 {
            for n in 1..10 {
                let (outer, per) = split_budget(budget, n);
                assert!(outer * per <= budget.max(1), "budget={budget} n={n}");
            }
        }
    }

    #[test]
    fn pool_resolves_auto_threads() {
        assert!(ClientPool::new(0).threads() >= 1);
        assert_eq!(ClientPool::new(3).threads(), 3);
        assert!(available_threads() >= 1);
    }

    /// Deterministic pin of the fail-fast claim semantics, driving
    /// [`worker_loop`] directly (no sleeps, no races): each simulated
    /// worker observes `failed` before its next claim because the failing
    /// unit sets it *before returning* and every other unit spins until
    /// the flag is visible. So each worker executes at most one unit, and
    /// only from the first batch of claims.
    #[test]
    fn fail_fast_stops_claiming_new_indices() {
        const WORKERS: usize = 4;
        const N: usize = 400;
        let next = AtomicUsize::new(0);
        let failed = AtomicBool::new(false);
        let executed: Vec<AtomicBool> = (0..N).map(|_| AtomicBool::new(false)).collect();
        let run_one = |i: usize| {
            executed[i].store(true, Ordering::Relaxed);
            if i == 0 {
                // the "error": published before run_one returns, exactly
                // as the engine's run_one stores `failed` before looping
                failed.store(true, Ordering::Release);
            } else {
                // every other unit holds its worker until the failure is
                // globally visible — the deterministic stand-in for "slow
                // work still in flight when the error lands"
                while !failed.load(Ordering::Acquire) {
                    std::hint::spin_loop();
                }
            }
        };
        std::thread::scope(|scope| {
            for _ in 0..WORKERS {
                scope.spawn(|| worker_loop(&next, &failed, N, &run_one));
            }
        });
        let ran: Vec<usize> =
            (0..N).filter(|&i| executed[i].load(Ordering::Relaxed)).collect();
        // at most one claimed unit per worker, and claims are handed out
        // in ascending order, so only the first WORKERS indices can run
        assert!(ran.len() <= WORKERS, "each worker runs at most one unit, ran {ran:?}");
        assert!(ran.contains(&0), "the failing unit itself must have run");
        assert!(
            ran.iter().all(|&i| i < WORKERS),
            "claims are ascending: executed set must be within the first batch, ran {ran:?}"
        );
    }

    #[test]
    fn fail_fast_preserves_lowest_index_error_in_run_mut() {
        for threads in [1, 4] {
            let mut xs: Vec<u64> = (0..64).collect();
            let r = ClientPool::new(threads).run_mut(&mut xs, |i, _| {
                if i % 7 == 5 {
                    Err(anyhow!("boom {i}"))
                } else {
                    Ok(())
                }
            });
            assert_eq!(r.unwrap_err().to_string(), "boom 5", "threads={threads}");
        }
    }

    #[test]
    fn pool_reuse_is_bit_identical_to_fresh_pools() {
        let work = |i: usize| -> Result<f64> {
            let mut acc = 0.0f64;
            for k in 1..100 {
                acc += ((i * k) as f64).cos() / k as f64;
            }
            Ok(acc)
        };
        let pool = ClientPool::new(4);
        let first = pool.run(48, work).unwrap();
        for call in 0..3 {
            // reused persistent pool vs a fresh transient pool per call
            assert_eq!(pool.run(48, work).unwrap(), first, "reuse call {call}");
            assert_eq!(par_indexed(4, 48, work).unwrap(), first, "fresh call {call}");
        }
    }

    #[test]
    fn pool_spawns_no_threads_after_warmup() {
        let pool = ClientPool::new(4);
        assert_eq!(pool.spawned_workers(), 0, "workers are spawned lazily");
        pool.run(32, |i| Ok(i)).unwrap();
        let after_warmup = pool.spawned_workers();
        assert_eq!(after_warmup, 3, "threads - 1 workers; the caller is the last worker");
        for _ in 0..5 {
            pool.run(32, |i| Ok(i)).unwrap();
            pool.run_mut(&mut [0u8; 32], |_, x| {
                *x += 1;
                Ok(())
            })
            .unwrap();
        }
        assert_eq!(pool.spawned_workers(), after_warmup, "no spawns per call after warm-up");
    }

    #[test]
    fn pool_drop_joins_all_workers() {
        let in_flight = Arc::new(AtomicUsize::new(0));
        let pool = ClientPool::new(4);
        let counter = Arc::clone(&in_flight);
        pool.run(64, move |_| {
            counter.fetch_add(1, Ordering::SeqCst);
            counter.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        drop(pool); // joins: no worker can still be executing afterwards
        assert_eq!(in_flight.load(Ordering::SeqCst), 0);
        assert_eq!(Arc::strong_count(&in_flight), 1, "drop released every worker's capture");
    }

    #[test]
    fn pool_serial_path_never_spawns() {
        let pool = ClientPool::new(1);
        pool.run(64, |i| Ok(i)).unwrap();
        assert_eq!(pool.spawned_workers(), 0, "threads=1 stays inline");
        let many = ClientPool::new(8);
        many.run(1, |i| Ok(i)).unwrap();
        assert_eq!(many.spawned_workers(), 0, "singleton input stays inline");
    }

    #[test]
    fn tree_reduce_shape_is_input_length_only() {
        assert_eq!(tree_reduce(Vec::<u32>::new(), |a, b| a + b), None);
        assert_eq!(tree_reduce(vec![7u32], |a, b| a + b), Some(7));
        // record the combine order as (left, right) pairs over indices
        for n in 2..20usize {
            let mut pairs = Vec::new();
            let total = tree_reduce(
                (0..n).map(|i| (i, i)).collect::<Vec<_>>(),
                |(la, lsum), (ra, rsum)| {
                    pairs.push((la, ra));
                    (la, lsum + rsum)
                },
            )
            .unwrap();
            assert_eq!(total.1, n * (n - 1) / 2, "n={n}");
            // first-level combines are exactly the adjacent pairs —
            // shape is fixed by n, never by scheduling
            for (k, &(l, r)) in pairs.iter().take(n / 2).enumerate() {
                assert_eq!((l, r), (2 * k, 2 * k + 1), "n={n} level-0 pair {k}");
            }
        }
    }

    #[test]
    fn shard_assignment_is_stable_and_roughly_balanced() {
        const SHARDS: usize = 16;
        let mut counts = [0usize; SHARDS];
        for id in 0..100_000usize {
            let s = stable_shard(id, SHARDS);
            assert!(s < SHARDS);
            // pure function of the id: a second lookup never disagrees
            assert_eq!(s, stable_shard(id, SHARDS));
            counts[s] += 1;
        }
        // a bit-mix over sequential ids should land well within 2x of the
        // uniform share per bucket — catches degenerate hashes like id % n
        // collapsing when ids share low bits
        let expect = 100_000 / SHARDS;
        for (s, &c) in counts.iter().enumerate() {
            assert!(
                c > expect / 2 && c < expect * 2,
                "shard {s} holds {c} of 100000 ids (uniform share {expect})"
            );
        }
        // pinned values: the assignment is part of the on-disk/spill layout,
        // so a silent hash change must fail loudly
        let pinned: Vec<usize> = (0..8).map(|id| stable_shard(id, SHARDS)).collect();
        assert_eq!(pinned, vec![15, 1, 14, 13, 10, 10, 0, 7]);
    }

    #[test]
    fn ordered_progress_streams_in_index_order() {
        let (sink, progress) = ordered_progress();
        // emit wildly out of order, from multiple threads
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in [3usize, 1, 4, 0, 2] {
                    sink.emit(i, format!("line {i}"));
                }
            });
        });
        drop(sink);
        let lines: Vec<String> = progress.collect();
        assert_eq!(lines, (0..5).map(|i| format!("line {i}")).collect::<Vec<_>>());
    }

    #[test]
    fn ordered_progress_yields_early_prefix_before_channel_closes() {
        let (sink, mut progress) = ordered_progress();
        sink.emit(1, "b");
        sink.emit(0, "a");
        // index 0 and 1 are both available: the iterator must yield them
        // without waiting for the sink to drop
        assert_eq!(progress.next().as_deref(), Some("a"));
        assert_eq!(progress.next().as_deref(), Some("b"));
        drop(sink);
        assert_eq!(progress.next(), None);
    }

    #[test]
    fn ordered_progress_drains_gaps_after_close() {
        let (sink, progress) = ordered_progress();
        sink.emit(2, "two");
        sink.emit(5, "five");
        drop(sink);
        // indices 0,1,3,4 never reported: remaining lines still come out
        // in ascending index order
        assert_eq!(progress.collect::<Vec<_>>(), vec!["two".to_string(), "five".to_string()]);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        assert!(par_indexed(4, 0, |_| Ok(0u8)).unwrap().is_empty());
        assert_eq!(par_indexed(4, 1, Ok).unwrap(), vec![0]);
        let mut one = [7u32];
        ClientPool::new(4).run_mut(&mut one, |_, x| { *x += 1; Ok(()) }).unwrap();
        assert_eq!(one[0], 8);
    }
}
