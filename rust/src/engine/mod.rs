//! Parallel client-execution engine: a scoped-thread worker pool that fans
//! per-client work out across OS threads and merges the results back in
//! client-id order.
//!
//! The determinism contract (DESIGN.md §5): a fan-out closure may read
//! shared state (`&Env`, compiled artifacts, round-start snapshots) and
//! mutate only *its own* slot, and every reduction over the returned
//! per-client values happens on the caller's thread in client-id order.
//! Because the accumulation tree is fixed by construction — independent of
//! how indices land on workers — a run with `--threads 8` is bit-identical
//! to `--threads 1`, which executes the very same closures inline in the
//! same order.
//!
//! The pool is deliberately dependency-free (`std::thread::scope` + an
//! atomic work index): workers claim indices from a shared counter, so a
//! slow client (compile hit, big batch list) does not stall the others.
//!
//! **Fail-fast**: once any index returns an error, workers stop claiming
//! *new* indices (already-claimed work runs to completion). This cannot
//! change which error is reported: claims are handed out in ascending
//! order, so every index below the lowest-failing one was claimed before
//! it and completes — the lowest-index error still wins, deterministically.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

use anyhow::{anyhow, Result};

/// Worker threads available on this host (>= 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Divide a thread budget across a nesting level with `n` independent
/// units of work: returns `(outer, per_unit)` where `outer` units run
/// concurrently and each gets `per_unit` threads for its own inner
/// fan-outs. Division (not multiplication) keeps total concurrency ~
/// `budget` however deep the nesting (`compare` → `run_seeds` → per-run
/// pool). Both components are >= 1.
pub fn split_budget(budget: usize, n: usize) -> (usize, usize) {
    let outer = budget.min(n).max(1);
    (outer, (budget / outer).max(1))
}

/// Anything the engine can fan client work out over. Implemented by the
/// protocol `Env`; kept as a trait so the engine has no protocol
/// dependency.
pub trait ParallelEnv {
    fn n_clients(&self) -> usize;
    /// Resolved worker count (never 0).
    fn threads(&self) -> usize;
}

/// Fan `f(i)` out over clients `0..env.n_clients()` and return the results
/// in client-id order. See [`par_indexed`] for the execution contract.
pub fn par_clients<E, T, F>(env: &E, f: F) -> Result<Vec<T>>
where
    E: ParallelEnv,
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    par_indexed(env.threads(), env.n_clients(), f)
}

/// A sized worker pool for round-level fan-out/fan-in.
///
/// `threads == 0` means "auto" (host parallelism). With one thread every
/// `run*` call degenerates to an inline serial loop over the same closures
/// in the same order — the basis of the serial/parallel equivalence
/// guarantee.
#[derive(Clone, Copy, Debug)]
pub struct ClientPool {
    threads: usize,
}

impl ClientPool {
    pub fn new(threads: usize) -> Self {
        Self { threads: if threads == 0 { available_threads() } else { threads } }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0..n)` on the pool; results come back in index order.
    pub fn run<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        par_indexed(self.threads, n, f)
    }

    /// Run `f(i, &mut states[i])` on the pool with each worker holding an
    /// exclusive borrow of its claimed slot; results in index order.
    pub fn run_mut<S, T, F>(&self, states: &mut [S], f: F) -> Result<Vec<T>>
    where
        S: Send,
        T: Send,
        F: Fn(usize, &mut S) -> Result<T> + Sync,
    {
        par_slice_mut(self.threads, states, f)
    }
}

/// Execute `f(i)` for `i in 0..n` on up to `threads` workers and return
/// the results in index order. Errors are surfaced deterministically: the
/// lowest-index failure wins, regardless of which worker hit it first.
pub fn par_indexed<T, F>(threads: usize, n: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    let workers = threads.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                // fail-fast: stop claiming new indices after any failure
                if failed.load(Ordering::Acquire) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                if r.is_err() {
                    failed.store(true, Ordering::Release);
                }
                *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
            });
        }
    });

    collect_slots(slots)
}

/// Raw-pointer wrapper that lets scoped workers carve disjoint `&mut`
/// element borrows out of one slice. Soundness relies on the atomic work
/// index handing every slot index to exactly one worker.
struct SlicePtr<S>(*mut S);

// SAFETY: `SlicePtr` is only shared between scoped workers that access
// disjoint indices (each index is claimed exactly once from the atomic
// counter), so concurrent `&mut` borrows never alias.
unsafe impl<S: Send> Sync for SlicePtr<S> {}

/// Execute `f(i, &mut states[i])` for every slot on up to `threads`
/// workers; results in index order, lowest-index error wins.
pub fn par_slice_mut<S, T, F>(threads: usize, states: &mut [S], f: F) -> Result<Vec<T>>
where
    S: Send,
    T: Send,
    F: Fn(usize, &mut S) -> Result<T> + Sync,
{
    let n = states.len();
    let workers = threads.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return states.iter_mut().enumerate().map(|(i, s)| f(i, s)).collect();
    }

    let base = SlicePtr(states.as_mut_ptr());
    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                // fail-fast: stop claiming new indices after any failure
                if failed.load(Ordering::Acquire) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // SAFETY: `i` was claimed exactly once above, so this is
                // the only live borrow of `states[i]`; the scope outlives
                // no borrow (workers join before `states` is touched
                // again).
                let slot = unsafe { &mut *base.0.add(i) };
                let r = f(i, slot);
                if r.is_err() {
                    failed.store(true, Ordering::Release);
                }
                *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
            });
        }
    });

    collect_slots(slots)
}

/// In-order fan-in. Scanning ascending indices makes the lowest-index
/// error win; under fail-fast, every index below the lowest error was
/// claimed before it (claims are handed out in order) and completed, so
/// the scan always reaches that error before any unclaimed `None` slot.
fn collect_slots<T>(slots: Vec<Mutex<Option<Result<T>>>>) -> Result<Vec<T>> {
    let mut out = Vec::with_capacity(slots.len());
    for (i, slot) in slots.into_iter().enumerate() {
        match slot.into_inner().unwrap_or_else(|e| e.into_inner()) {
            Some(r) => out.push(r?),
            None => return Err(anyhow!("engine: slot {i} produced no result")),
        }
    }
    Ok(out)
}

// ---- order-preserving progress streaming ----------------------------------

/// Sending half of an order-preserving progress channel: workers emit
/// `(index, line)` from inside a fan-out as each unit finishes.
pub struct ProgressSink {
    tx: Mutex<mpsc::Sender<(usize, String)>>,
}

impl ProgressSink {
    /// Emit one progress line for unit `index`. Never blocks; if the
    /// receiver is gone the line is dropped.
    pub fn emit(&self, index: usize, line: impl Into<String>) {
        if let Ok(tx) = self.tx.lock() {
            tx.send((index, line.into())).ok();
        }
    }
}

/// Receiving half: iterate to get lines back **in index order**, each
/// yielded as soon as it *and every lower index* have finished — so
/// progress streams during the fan-out instead of printing in one burst
/// after the fan-in, and the output order never depends on scheduling.
/// Out-of-order completions are buffered; once every sink clone is
/// dropped, any buffered remainder drains in index order.
pub struct OrderedProgress {
    rx: mpsc::Receiver<(usize, String)>,
    pending: BTreeMap<usize, String>,
    next: usize,
}

/// Create an order-preserving progress channel.
pub fn ordered_progress() -> (ProgressSink, OrderedProgress) {
    let (tx, rx) = mpsc::channel();
    (
        ProgressSink { tx: Mutex::new(tx) },
        OrderedProgress { rx, pending: BTreeMap::new(), next: 0 },
    )
}

impl Iterator for OrderedProgress {
    type Item = String;

    fn next(&mut self) -> Option<String> {
        loop {
            if let Some(line) = self.pending.remove(&self.next) {
                self.next += 1;
                return Some(line);
            }
            match self.rx.recv() {
                Ok((i, line)) => {
                    self.pending.insert(i, line);
                }
                // channel closed: drain whatever arrived, still in order
                Err(_) => match self.pending.pop_first() {
                    Some((i, line)) => {
                        self.next = i + 1;
                        return Some(line);
                    }
                    None => return None,
                },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order() {
        for threads in [1, 2, 4, 8] {
            let v = par_indexed(threads, 64, |i| Ok(i * i)).unwrap();
            assert_eq!(v, (0..64).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn serial_and_parallel_agree_on_float_work() {
        // per-index work is self-contained, so any thread count must
        // produce bit-identical values
        let work = |i: usize| -> Result<f64> {
            let mut acc = 0.0f64;
            for k in 1..200 {
                acc += ((i * k) as f64).sin() / k as f64;
            }
            Ok(acc)
        };
        let serial = par_indexed(1, 32, work).unwrap();
        for threads in [2, 4, 8] {
            assert_eq!(serial, par_indexed(threads, 32, work).unwrap());
        }
    }

    #[test]
    fn lowest_index_error_wins() {
        for threads in [1, 4] {
            let r = par_indexed(threads, 16, |i| {
                if i % 5 == 3 {
                    Err(anyhow!("boom {i}"))
                } else {
                    Ok(i)
                }
            });
            assert_eq!(r.unwrap_err().to_string(), "boom 3", "threads={threads}");
        }
    }

    #[test]
    fn run_mut_updates_every_slot_exactly_once() {
        for threads in [1, 3, 8] {
            let mut xs: Vec<u64> = (0..40).collect();
            let doubled = ClientPool::new(threads)
                .run_mut(&mut xs, |i, x| {
                    *x *= 2;
                    Ok(i as u64)
                })
                .unwrap();
            assert_eq!(xs, (0..40).map(|i| i * 2).collect::<Vec<_>>());
            assert_eq!(doubled, (0..40).collect::<Vec<_>>());
        }
    }

    #[test]
    fn split_budget_divides_not_multiplies() {
        assert_eq!(split_budget(8, 7), (7, 1));
        assert_eq!(split_budget(16, 7), (7, 2));
        assert_eq!(split_budget(2, 7), (2, 1));
        assert_eq!(split_budget(8, 3), (3, 2));
        assert_eq!(split_budget(1, 5), (1, 1));
        assert_eq!(split_budget(0, 5), (1, 1));
        assert_eq!(split_budget(4, 0), (1, 4));
        // total concurrency never exceeds the budget (when budget >= 1)
        for budget in 1..20 {
            for n in 1..10 {
                let (outer, per) = split_budget(budget, n);
                assert!(outer * per <= budget.max(1), "budget={budget} n={n}");
            }
        }
    }

    #[test]
    fn pool_resolves_auto_threads() {
        assert!(ClientPool::new(0).threads() >= 1);
        assert_eq!(ClientPool::new(3).threads(), 3);
        assert!(available_threads() >= 1);
    }

    #[test]
    fn fail_fast_stops_claiming_new_indices() {
        use std::sync::atomic::AtomicUsize;
        // index 0 fails immediately; every other index sleeps. Without
        // fail-fast all 400 indices would execute; with it, each worker
        // stops after at most the one unit it already claimed.
        let executed = AtomicUsize::new(0);
        let r = par_indexed(4, 400, |i| {
            executed.fetch_add(1, Ordering::Relaxed);
            if i == 0 {
                Err(anyhow!("boom 0"))
            } else {
                std::thread::sleep(std::time::Duration::from_millis(2));
                Ok(i)
            }
        });
        assert_eq!(r.unwrap_err().to_string(), "boom 0");
        let ran = executed.load(Ordering::Relaxed);
        assert!(ran < 400, "fail-fast must skip most work (ran {ran}/400)");
    }

    #[test]
    fn fail_fast_preserves_lowest_index_error_in_run_mut() {
        for threads in [1, 4] {
            let mut xs: Vec<u64> = (0..64).collect();
            let r = ClientPool::new(threads).run_mut(&mut xs, |i, _| {
                if i % 7 == 5 {
                    Err(anyhow!("boom {i}"))
                } else {
                    Ok(())
                }
            });
            assert_eq!(r.unwrap_err().to_string(), "boom 5", "threads={threads}");
        }
    }

    #[test]
    fn ordered_progress_streams_in_index_order() {
        let (sink, progress) = ordered_progress();
        // emit wildly out of order, from multiple threads
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in [3usize, 1, 4, 0, 2] {
                    sink.emit(i, format!("line {i}"));
                }
            });
        });
        drop(sink);
        let lines: Vec<String> = progress.collect();
        assert_eq!(lines, (0..5).map(|i| format!("line {i}")).collect::<Vec<_>>());
    }

    #[test]
    fn ordered_progress_yields_early_prefix_before_channel_closes() {
        let (sink, mut progress) = ordered_progress();
        sink.emit(1, "b");
        sink.emit(0, "a");
        // index 0 and 1 are both available: the iterator must yield them
        // without waiting for the sink to drop
        assert_eq!(progress.next().as_deref(), Some("a"));
        assert_eq!(progress.next().as_deref(), Some("b"));
        drop(sink);
        assert_eq!(progress.next(), None);
    }

    #[test]
    fn ordered_progress_drains_gaps_after_close() {
        let (sink, progress) = ordered_progress();
        sink.emit(2, "two");
        sink.emit(5, "five");
        drop(sink);
        // indices 0,1,3,4 never reported: remaining lines still come out
        // in ascending index order
        assert_eq!(progress.collect::<Vec<_>>(), vec!["two".to_string(), "five".to_string()]);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        assert!(par_indexed(4, 0, |_| Ok(0u8)).unwrap().is_empty());
        assert_eq!(par_indexed(4, 1, Ok).unwrap(), vec![0]);
        let mut one = [7u32];
        ClientPool::new(4).run_mut(&mut one, |_, x| { *x += 1; Ok(()) }).unwrap();
        assert_eq!(one[0], 8);
    }
}
