//! Config-matrix bench harness with a regression gate (DESIGN.md §14).
//!
//! Four pieces, consumed by `benches/runtime_micro.rs`:
//!
//! * [`config`] — the declarative grid (threads × clients × scheduler ×
//!   protocol), run shape, tolerance bands, and required pure-Rust axes,
//!   parsed from the committed `benches/matrix.toml` via
//!   [`crate::util::kvconf`];
//! * [`runner`] — deterministic cell enumeration and timing through the
//!   hardened [`crate::util::bench`] harness, plus the [`runner::check`]
//!   gate (exact trajectories, banded throughput, explicit
//!   not-yet-recorded reporting, quick/full-mode refusal);
//! * [`counters`] — best-effort procfs counters bracketing each cell;
//! * [`writer`] — `BENCH_results.json` schema v3 with a v2-reading
//!   migration shim.

pub mod config;
pub mod counters;
pub mod runner;
pub mod writer;

pub use config::{CellSpec, MatrixConfig};
pub use counters::Counters;
pub use runner::{check, BenchReport, CellRecord, GateOutcome, GateStatus, Runner};
