//! Declarative bench-matrix configuration (DESIGN.md §14).
//!
//! The grid lives in a committed kv file (`benches/matrix.toml`) parsed
//! by the in-tree TOML subset ([`crate::util::kvconf`]): sections
//! flatten to dotted keys and list axes are comma-separated strings, so
//! no new dependency is needed in the offline build environment. The
//! config declares three things the runner and the gate both read:
//!
//! * the engine-round grid (`matrix.threads` × `matrix.clients` ×
//!   `matrix.schedulers` × `matrix.protocols`), enumerated into cells
//!   with stable ids by [`MatrixConfig::grid_cells`];
//! * run shape (`run.warmup`, `run.iters`, `run.quick_iters`);
//! * gate parameters: the default throughput tolerance band
//!   (`gate.band`), optional per-cell overrides (`gate.band.<cell>`),
//!   and the pure-Rust axes the gate must report on even when their
//!   tracked values are placeholders (`axes.pure`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::kvconf::KvConf;

/// One engine-round grid point: the Cartesian coordinates of a timed
/// cell plus the stable id it is tracked under in `BENCH_results.json`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CellSpec {
    pub id: String,
    pub threads: usize,
    pub clients: usize,
    pub scheduler: String,
    pub protocol: String,
}

/// Parsed bench matrix + gate parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixConfig {
    /// `matrix.threads` — engine fan-out widths of the round grid.
    pub threads: Vec<usize>,
    /// `matrix.clients` — clients per timed round.
    pub clients: Vec<usize>,
    /// `matrix.schedulers` — scheduler / merge-policy axis.
    pub schedulers: Vec<String>,
    /// `matrix.protocols` — protocol axis.
    pub protocols: Vec<String>,
    /// `run.warmup` — unrecorded runs before timing each cell.
    pub warmup: usize,
    /// `run.iters` — timed iterations per cell in full mode.
    pub iters: usize,
    /// `run.quick_iters` — timed iterations per cell in quick mode.
    pub quick_iters: usize,
    /// `gate.band` — default allowed fractional throughput drop before
    /// `--check` fails a cell (0.6 ⇒ new ≥ 40% of tracked passes).
    pub default_band: f64,
    /// `gate.band.<cell>` — per-cell-id (or id-prefix) band overrides.
    pub bands: BTreeMap<String, f64>,
    /// `axes.pure` — pure-Rust cell ids the gate requires a tracked
    /// measurement for, reporting each placeholder as "not yet
    /// recorded" instead of passing silently.
    pub pure_axes: Vec<String>,
}

fn parse_str_list(raw: &str) -> Vec<String> {
    raw.split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

fn parse_usize_list(key: &str, raw: &str) -> Result<Vec<usize>> {
    parse_str_list(raw)
        .iter()
        .map(|s| s.parse::<usize>().with_context(|| format!("`{key}` entry `{s}`: not a usize")))
        .collect()
}

fn check_band(key: &str, band: f64) -> Result<()> {
    ensure!(
        band > 0.0 && band <= 1.0,
        "`{key}` = {band}: the tolerance band is a fractional drop and must lie in (0, 1]"
    );
    Ok(())
}

impl MatrixConfig {
    /// Parse a matrix config from kv text. Absent keys take the
    /// defaults of the committed `benches/matrix.toml`; degenerate
    /// values (empty axes, zero iterations, out-of-range bands) are
    /// rejected here so the runner and gate never see them.
    pub fn parse(text: &str) -> Result<Self> {
        let kv = KvConf::parse(text)?;
        let threads = parse_usize_list("matrix.threads", &kv.get_str("matrix.threads", "1"))?;
        let clients = parse_usize_list("matrix.clients", &kv.get_str("matrix.clients", "8"))?;
        let schedulers = parse_str_list(&kv.get_str("matrix.schedulers", "sync"));
        let protocols = parse_str_list(&kv.get_str("matrix.protocols", "ada-split"));
        let warmup = kv.get_usize("run.warmup", 1)?;
        let iters = kv.get_usize("run.iters", 20)?;
        let quick_iters = kv.get_usize("run.quick_iters", 5)?;
        let default_band = kv.get_f64("gate.band", 0.6)?;
        let pure_axes = parse_str_list(&kv.get_str("axes.pure", ""));

        let mut bands = BTreeMap::new();
        for key in kv.keys() {
            if let Some(cell) = key.strip_prefix("gate.band.") {
                let band = kv.get_f64(key, default_band)?;
                check_band(key, band)?;
                bands.insert(cell.to_string(), band);
            }
        }

        ensure!(!threads.is_empty(), "`matrix.threads` must declare at least one value");
        ensure!(!clients.is_empty(), "`matrix.clients` must declare at least one value");
        ensure!(!schedulers.is_empty(), "`matrix.schedulers` must declare at least one value");
        ensure!(!protocols.is_empty(), "`matrix.protocols` must declare at least one value");
        ensure!(
            threads.iter().all(|&t| t >= 1),
            "`matrix.threads` entries must be >= 1"
        );
        ensure!(
            clients.iter().all(|&c| c >= 1),
            "`matrix.clients` entries must be >= 1"
        );
        ensure!(iters >= 1, "`run.iters` must be >= 1 (a zero-iteration cell has no samples)");
        ensure!(quick_iters >= 1, "`run.quick_iters` must be >= 1");
        check_band("gate.band", default_band)?;

        Ok(Self {
            threads,
            clients,
            schedulers,
            protocols,
            warmup,
            iters,
            quick_iters,
            default_band,
            bands,
            pure_axes,
        })
    }

    /// Load and parse `path` (typically `benches/matrix.toml`).
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("bench matrix config: cannot read {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("bench matrix config: {}", path.display()))
    }

    /// The tolerance band for one cell: the longest `gate.band.*`
    /// override whose key equals the id or is a `/`-prefix of it, else
    /// the default band.
    pub fn band_for(&self, cell_id: &str) -> f64 {
        self.bands
            .iter()
            .filter(|(k, _)| cell_id == k.as_str() || cell_id.starts_with(&format!("{k}/")))
            .max_by_key(|(k, _)| k.len())
            .map(|(_, &b)| b)
            .unwrap_or(self.default_band)
    }

    /// Enumerate the engine-round grid in a deterministic order —
    /// threads-major, then clients, scheduler, protocol, each axis in
    /// its declared list order — so cell ids and the tracked file are
    /// stable across invocations and machines.
    pub fn grid_cells(&self) -> Vec<CellSpec> {
        let mut out = Vec::new();
        for &threads in &self.threads {
            for &clients in &self.clients {
                for scheduler in &self.schedulers {
                    for protocol in &self.protocols {
                        out.push(CellSpec {
                            id: format!("round/t{threads}/c{clients}/{scheduler}/{protocol}"),
                            threads,
                            clients,
                            scheduler: scheduler.clone(),
                            protocol: protocol.clone(),
                        });
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "[matrix]\n\
                          threads = \"1,2\"\n\
                          clients = \"8\"\n\
                          schedulers = \"sync\"\n\
                          protocols = \"ada-split\"\n\
                          [run]\n\
                          warmup = 1\n\
                          iters = 20\n\
                          quick_iters = 5\n\
                          [gate]\n\
                          band = 0.6\n\
                          band.detlint = 0.5\n\
                          [axes]\n\
                          pure = \"pool,event_heap\"\n";

    #[test]
    fn parses_grid_gate_and_axes() {
        let c = MatrixConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.threads, vec![1, 2]);
        assert_eq!(c.clients, vec![8]);
        assert_eq!(c.schedulers, vec!["sync"]);
        assert_eq!(c.protocols, vec!["ada-split"]);
        assert_eq!((c.warmup, c.iters, c.quick_iters), (1, 20, 5));
        assert!((c.default_band - 0.6).abs() < 1e-12);
        assert!((c.band_for("detlint") - 0.5).abs() < 1e-12, "exact override applies");
        assert!((c.band_for("pool") - 0.6).abs() < 1e-12, "default applies elsewhere");
        assert_eq!(c.pure_axes, vec!["pool", "event_heap"]);
    }

    #[test]
    fn band_overrides_match_by_prefix_longest_wins() {
        let c = MatrixConfig::parse(
            "[gate]\nband = 0.6\nband.round = 0.4\nband.round/t8 = 0.2\n",
        )
        .unwrap();
        assert!((c.band_for("round/t1/c8/sync/ada-split") - 0.4).abs() < 1e-12);
        assert!((c.band_for("round/t8/c8/sync/ada-split") - 0.2).abs() < 1e-12);
        assert!((c.band_for("roundabout") - 0.6).abs() < 1e-12, "prefix match is /-delimited");
    }

    #[test]
    fn cell_enumeration_is_deterministic_and_ordered() {
        let c = MatrixConfig::parse(SAMPLE).unwrap();
        let a = c.grid_cells();
        let b = c.grid_cells();
        assert_eq!(a, b, "repeat enumeration must be identical");
        let ids: Vec<&str> = a.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["round/t1/c8/sync/ada-split", "round/t2/c8/sync/ada-split"]);
        assert_eq!(a[1].threads, 2);
        assert_eq!(a[1].clients, 8);
    }

    #[test]
    fn grid_is_a_full_cartesian_product_in_declared_order() {
        let c = MatrixConfig::parse(
            "[matrix]\nthreads = \"2,1\"\nclients = \"4,8\"\n\
             schedulers = \"sync\"\nprotocols = \"a,b\"\n",
        )
        .unwrap();
        let ids: Vec<String> = c.grid_cells().into_iter().map(|s| s.id).collect();
        assert_eq!(
            ids,
            vec![
                "round/t2/c4/sync/a",
                "round/t2/c4/sync/b",
                "round/t2/c8/sync/a",
                "round/t2/c8/sync/b",
                "round/t1/c4/sync/a",
                "round/t1/c4/sync/b",
                "round/t1/c8/sync/a",
                "round/t1/c8/sync/b",
            ],
            "threads-major, declared list order preserved (not sorted)"
        );
    }

    #[test]
    fn committed_matrix_file_parses_and_covers_the_pure_axes() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/benches/matrix.toml");
        let c = MatrixConfig::load(Path::new(path)).unwrap();
        assert!(c.grid_cells().len() >= 4, "committed grid spans the threads axis");
        for axis in [
            "async_plan",
            "snapshot_ring",
            "bound_controller",
            "pool",
            "shard_store",
            "event_heap",
            "scenario",
            "detlint",
        ] {
            assert!(
                c.pure_axes.iter().any(|a| a == axis),
                "committed matrix.toml must require pure axis `{axis}`"
            );
        }
    }

    #[test]
    fn rejects_degenerate_configs() {
        assert!(MatrixConfig::parse("[run]\niters = 0\n").is_err(), "zero iters");
        assert!(MatrixConfig::parse("[run]\nquick_iters = 0\n").is_err(), "zero quick iters");
        assert!(MatrixConfig::parse("[gate]\nband = 0\n").is_err(), "band must be > 0");
        assert!(MatrixConfig::parse("[gate]\nband = 1.5\n").is_err(), "band must be <= 1");
        assert!(MatrixConfig::parse("[gate]\nband.pool = 2\n").is_err(), "override checked too");
        assert!(MatrixConfig::parse("[matrix]\nthreads = \"\"\n").is_err(), "empty axis");
        assert!(MatrixConfig::parse("[matrix]\nthreads = \"0\"\n").is_err(), "zero threads");
        assert!(MatrixConfig::parse("[matrix]\nthreads = \"two\"\n").is_err(), "non-numeric");
    }

    #[test]
    fn defaults_cover_an_empty_file() {
        let c = MatrixConfig::parse("").unwrap();
        assert_eq!(c.threads, vec![1]);
        assert_eq!(c.grid_cells().len(), 1);
        assert!(c.pure_axes.is_empty());
    }
}
