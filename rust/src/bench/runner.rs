//! The matrix bench runner and the regression gate (DESIGN.md §14).
//!
//! [`Runner`] times cells declared by the [`super::config`] matrix
//! through the hardened [`crate::util::bench`] harness, bracketing each
//! timed region with best-effort [`super::counters`] samples, and
//! accumulates per-cell records into a [`BenchReport`]. Deterministic
//! trajectories (e.g. the `AsyncBounded` sim-time fingerprint) attach
//! to cells by name.
//!
//! [`check`] is the gate `runtime_micro --check` runs over a tracked
//! report: deterministic trajectories must match *exactly* (they are
//! pure functions of the seeded config — drift is a semantics change,
//! not noise), throughput is compared per cell inside the tolerance
//! band the config declares, zero/empty tracked cells are reported
//! per-key as "not yet recorded" instead of passing silently, and
//! quick-mode numbers are never compared against full-mode numbers —
//! a mode mismatch SKIPs the throughput comparison with an explicit
//! note.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::util::bench::{try_bench, BenchStats};

use super::config::MatrixConfig;
use super::counters::{self, Counters};

/// Exact-match tolerance for deterministic trajectories. This is a
/// float-print round-trip guard, not a noise band.
pub const TRAJECTORY_EPS: f64 = 1e-9;

/// One tracked matrix cell: timing stats, derived throughput, attached
/// deterministic trajectories, and best-effort counters.
#[derive(Clone, Debug, PartialEq)]
pub struct CellRecord {
    pub id: String,
    /// Timing summary; `None` for trajectory-only cells and for cells
    /// migrated from the flat v2 schema (which kept only throughput).
    pub stats: Option<BenchStats>,
    /// Work units per timed iteration (jobs, events, files, …); 0 for
    /// trajectory-only and migrated cells.
    pub units_per_iter: f64,
    /// `units_per_iter / mean_s` — the gate-facing number. 0 means
    /// "not yet recorded": the gate reports it per-key instead of
    /// treating presence as coverage.
    pub throughput_per_s: f64,
    /// Named deterministic trajectories, compared exactly by the gate.
    pub trajectories: BTreeMap<String, Vec<f64>>,
    /// Best-effort counters; context only, never gated.
    pub counters: Option<Counters>,
    /// Whether this cell was measured under a quick-mode (shrunk)
    /// workload. The gate refuses cross-mode throughput comparison.
    pub quick: bool,
}

impl CellRecord {
    /// A cell that only carries trajectories (no timed region).
    pub fn trajectory_only(id: &str, quick: bool) -> Self {
        CellRecord {
            id: id.to_string(),
            stats: None,
            units_per_iter: 0.0,
            throughput_per_s: 0.0,
            trajectories: BTreeMap::new(),
            counters: None,
            quick,
        }
    }

    /// Whether the cell carries a usable throughput measurement.
    pub fn recorded(&self) -> bool {
        self.throughput_per_s > 0.0
    }
}

/// Everything one bench invocation measured: the schema-v3 payload.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    /// Run-level quick flag (workload scale, not iteration count).
    pub quick: bool,
    /// Cells keyed by id — BTreeMap so the written file is
    /// deterministically ordered.
    pub cells: BTreeMap<String, CellRecord>,
}

impl BenchReport {
    pub fn new(quick: bool) -> Self {
        BenchReport { quick, cells: BTreeMap::new() }
    }
}

/// Times matrix cells and accumulates a [`BenchReport`].
pub struct Runner {
    pub cfg: MatrixConfig,
    pub report: BenchReport,
    iters: usize,
}

impl Runner {
    pub fn new(cfg: MatrixConfig, quick: bool) -> Self {
        let iters = if quick { cfg.quick_iters } else { cfg.iters };
        Runner { cfg, report: BenchReport::new(quick), iters }
    }

    /// Timed iterations per cell for this run.
    pub fn iters(&self) -> usize {
        self.iters
    }

    /// Override the iteration count (e.g. `--check` uses the quick
    /// count for its fresh point estimates without marking the run
    /// quick — iteration count is sampling, quick is workload scale).
    pub fn set_iters(&mut self, iters: usize) -> Result<()> {
        ensure!(iters >= 1, "runner iters must be >= 1 (got {iters})");
        self.iters = iters;
        Ok(())
    }

    /// Time one cell with the config's warmup.
    pub fn run_cell<F: FnMut()>(&mut self, id: &str, units_per_iter: f64, f: F) -> Result<()> {
        let warmup = self.cfg.warmup;
        self.run_cell_warmup(id, units_per_iter, warmup, f)
    }

    /// Time one cell with an explicit warmup count (artifact cells warm
    /// twice: the first call may still be faulting executable pages in).
    pub fn run_cell_warmup<F: FnMut()>(
        &mut self,
        id: &str,
        units_per_iter: f64,
        warmup: usize,
        f: F,
    ) -> Result<()> {
        ensure!(
            !self.report.cells.contains_key(id),
            "duplicate bench cell id `{id}` — cell ids must be unique within a run"
        );
        ensure!(units_per_iter > 0.0, "cell `{id}`: units_per_iter must be > 0");
        let before = counters::sample();
        let stats = try_bench(id, warmup, self.iters, f)?;
        let after = counters::sample();
        let throughput_per_s =
            if stats.mean_s > 0.0 { units_per_iter / stats.mean_s } else { 0.0 };
        let rec = CellRecord {
            id: id.to_string(),
            stats: Some(stats),
            units_per_iter,
            throughput_per_s,
            trajectories: BTreeMap::new(),
            counters: Some(counters::delta(&before, &after)),
            quick: self.report.quick,
        };
        self.report.cells.insert(id.to_string(), rec);
        Ok(())
    }

    /// Attach a deterministic trajectory to a cell, creating a
    /// trajectory-only cell if the id is new. Values must be finite
    /// (NaN would make the exact-match gate vacuously fail forever).
    pub fn add_trajectory(&mut self, cell_id: &str, name: &str, values: Vec<f64>) -> Result<()> {
        ensure!(
            values.iter().all(|v| v.is_finite()),
            "trajectory `{name}` on cell `{cell_id}` contains a non-finite value"
        );
        let quick = self.report.quick;
        let cell = self
            .report
            .cells
            .entry(cell_id.to_string())
            .or_insert_with(|| CellRecord::trajectory_only(cell_id, quick));
        ensure!(
            !cell.trajectories.contains_key(name),
            "duplicate trajectory `{name}` on cell `{cell_id}`"
        );
        cell.trajectories.insert(name.to_string(), values);
        Ok(())
    }

    pub fn into_report(self) -> BenchReport {
        self.report
    }
}

// ---- the regression gate ---------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateStatus {
    /// Compared and inside the contract.
    Pass,
    /// Compared and outside the contract — the gate exits nonzero.
    Fail,
    /// Comparison refused (mode mismatch) or impossible on this runner
    /// (tracked cell not measured here); explicitly noted, not fatal.
    Skip,
    /// The tracked side is zero/empty: this axis has never been proven.
    /// Reported per-key so CI output shows the gap instead of implying
    /// coverage; not fatal.
    NotRecorded,
}

impl GateStatus {
    pub fn label(self) -> &'static str {
        match self {
            GateStatus::Pass => "ok",
            GateStatus::Fail => "FAIL",
            GateStatus::Skip => "SKIP",
            GateStatus::NotRecorded => "NOT-RECORDED",
        }
    }
}

/// One per-key gate verdict.
#[derive(Clone, Debug)]
pub struct GateNote {
    pub key: String,
    pub status: GateStatus,
    pub msg: String,
}

/// Every verdict of one gate evaluation, in emission order (fresh cells
/// sorted by id, then required-axis and unmeasured-cell sweeps).
#[derive(Clone, Debug, Default)]
pub struct GateOutcome {
    pub notes: Vec<GateNote>,
}

impl GateOutcome {
    fn push(&mut self, key: &str, status: GateStatus, msg: String) {
        self.notes.push(GateNote { key: key.to_string(), status, msg });
    }

    /// True when any comparison failed — the gate's exit condition.
    pub fn failed(&self) -> bool {
        self.notes.iter().any(|n| n.status == GateStatus::Fail)
    }

    /// (pass, fail, skip, not-recorded) counts.
    pub fn counts(&self) -> (usize, usize, usize, usize) {
        let c = |s: GateStatus| self.notes.iter().filter(|n| n.status == s).count();
        (
            c(GateStatus::Pass),
            c(GateStatus::Fail),
            c(GateStatus::Skip),
            c(GateStatus::NotRecorded),
        )
    }

    /// Render one line per note plus a summary line.
    pub fn render(&self) -> String {
        let mut lines: Vec<String> = self
            .notes
            .iter()
            .map(|n| format!("  [{}] {}: {}", n.status.label(), n.key, n.msg))
            .collect();
        let (pass, fail, skip, not_recorded) = self.counts();
        lines.push(format!(
            "  gate: {pass} pass, {fail} fail, {skip} skip, {not_recorded} not yet recorded"
        ));
        lines.join("\n")
    }
}

fn mode_name(quick: bool) -> &'static str {
    if quick {
        "quick-mode"
    } else {
        "full-mode"
    }
}

/// Evaluate the regression gate: `fresh` (this run) against `tracked`
/// (the committed `BENCH_results.json`), under the config's bands and
/// required pure axes. See the module docs for the semantics.
pub fn check(cfg: &MatrixConfig, tracked: &BenchReport, fresh: &BenchReport) -> GateOutcome {
    let mut out = GateOutcome::default();
    let mode_mismatch = tracked.quick != fresh.quick;
    if mode_mismatch {
        out.push(
            "mode",
            GateStatus::Skip,
            format!(
                "tracked file holds {} numbers but this is a {} run — refusing every \
                 throughput comparison (shrunk workloads are not comparable); \
                 deterministic trajectories are mode-independent and still checked",
                mode_name(tracked.quick),
                mode_name(fresh.quick)
            ),
        );
    }

    for (id, new) in &fresh.cells {
        let old = tracked.cells.get(id);

        // Deterministic trajectories: exact match, mode-independent.
        for (tname, tvals) in &new.trajectories {
            let key = format!("{id}.{tname}");
            match old.and_then(|c| c.trajectories.get(tname)) {
                None => out.push(
                    &key,
                    GateStatus::NotRecorded,
                    "deterministic trajectory not yet recorded — run the bench without \
                     --check to record it"
                        .to_string(),
                ),
                Some(oldv) if oldv.is_empty() => out.push(
                    &key,
                    GateStatus::NotRecorded,
                    "tracked trajectory is empty (placeholder) — not yet recorded".to_string(),
                ),
                Some(oldv) => {
                    if oldv.len() != tvals.len() {
                        out.push(
                            &key,
                            GateStatus::Fail,
                            format!("trajectory length changed: {} -> {}", oldv.len(), tvals.len()),
                        );
                    } else if let Some((i, (a, b))) = oldv
                        .iter()
                        .zip(tvals)
                        .enumerate()
                        .find(|(_, (a, b))| (**a - **b).abs() > TRAJECTORY_EPS)
                    {
                        out.push(
                            &key,
                            GateStatus::Fail,
                            format!(
                                "[{i}] drifted: {a} -> {b} — trajectories are deterministic, \
                                 so this is a semantics change, not noise"
                            ),
                        );
                    } else {
                        out.push(
                            &key,
                            GateStatus::Pass,
                            format!("exact match ({} points)", tvals.len()),
                        );
                    }
                }
            }
        }

        // Throughput: banded comparison, refused across modes.
        if new.recorded() {
            match old {
                None => out.push(
                    id,
                    GateStatus::NotRecorded,
                    "cell not yet recorded in the tracked file".to_string(),
                ),
                Some(oldc) if !oldc.recorded() => out.push(
                    id,
                    GateStatus::NotRecorded,
                    "tracked value is zero/empty (placeholder) — this axis is unproven \
                     until the bench records it"
                        .to_string(),
                ),
                Some(oldc) if mode_mismatch || oldc.quick != new.quick => out.push(
                    id,
                    GateStatus::Skip,
                    format!(
                        "mode mismatch (tracked {}, fresh {}) — throughput comparison refused",
                        mode_name(oldc.quick),
                        mode_name(new.quick)
                    ),
                ),
                Some(oldc) => {
                    let band = cfg.band_for(id);
                    let floor = oldc.throughput_per_s * (1.0 - band);
                    if new.throughput_per_s < floor {
                        out.push(
                            id,
                            GateStatus::Fail,
                            format!(
                                "throughput regressed beyond the {:.0}% band: {:.2} -> {:.2} \
                                 units/s (floor {:.2})",
                                band * 100.0,
                                oldc.throughput_per_s,
                                new.throughput_per_s,
                                floor
                            ),
                        );
                    } else {
                        out.push(
                            id,
                            GateStatus::Pass,
                            format!(
                                "{:.2} units/s vs tracked {:.2} (band {:.0}%)",
                                new.throughput_per_s,
                                oldc.throughput_per_s,
                                band * 100.0
                            ),
                        );
                    }
                }
            }
        }
    }

    // Required pure-Rust axes must carry a tracked measurement; each
    // placeholder is called out by name (once — the per-cell sweep may
    // already have noted it).
    for axis in &cfg.pure_axes {
        let recorded = tracked.cells.get(axis).is_some_and(|c| c.recorded());
        let already_noted = out
            .notes
            .iter()
            .any(|n| n.key == *axis && n.status == GateStatus::NotRecorded);
        if !recorded && !already_noted {
            out.push(
                axis,
                GateStatus::NotRecorded,
                "required pure-Rust axis has no tracked measurement — unproven until the \
                 bench records it"
                    .to_string(),
            );
        }
    }

    // Tracked cells this run did not measure: artifact-gated sections
    // absent on this runner, or a shrunk matrix. Explicit, not silent.
    for id in tracked.cells.keys() {
        if !fresh.cells.contains_key(id) {
            out.push(
                id,
                GateStatus::Skip,
                "tracked cell not measured in this run (artifact-gated section absent on \
                 this runner, or the matrix no longer declares it)"
                    .to_string(),
            );
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MatrixConfig {
        MatrixConfig::parse("[gate]\nband = 0.6\n[axes]\npure = \"pool\"\n").unwrap()
    }

    fn cell(id: &str, throughput: f64, quick: bool) -> CellRecord {
        CellRecord {
            id: id.to_string(),
            stats: None,
            units_per_iter: 1.0,
            throughput_per_s: throughput,
            trajectories: BTreeMap::new(),
            counters: None,
            quick,
        }
    }

    fn report(cells: Vec<CellRecord>, quick: bool) -> BenchReport {
        BenchReport { quick, cells: cells.into_iter().map(|c| (c.id.clone(), c)).collect() }
    }

    #[test]
    fn run_cell_records_stats_counters_and_rejects_duplicates() {
        let mut r = Runner::new(MatrixConfig::parse("").unwrap(), true);
        assert_eq!(r.iters(), 5, "quick mode uses run.quick_iters");
        r.run_cell("unit", 10.0, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        })
        .unwrap();
        assert!(r.run_cell("unit", 10.0, || {}).is_err(), "duplicate id must be rejected");
        assert!(r.run_cell("zero-units", 0.0, || {}).is_err(), "zero units must be rejected");
        let rec = &r.report.cells["unit"];
        assert!(rec.quick);
        assert_eq!(rec.stats.as_ref().unwrap().iters, 5);
        assert!(rec.counters.is_some(), "counters recorded (possibly unavailable)");
    }

    #[test]
    fn trajectories_attach_and_reject_nan_and_duplicates() {
        let mut r = Runner::new(MatrixConfig::parse("").unwrap(), false);
        r.add_trajectory("traj/x", "x", vec![1.0, 2.0]).unwrap();
        assert!(r.add_trajectory("traj/x", "x", vec![1.0]).is_err(), "duplicate name");
        assert!(r.add_trajectory("traj/y", "y", vec![f64::NAN]).is_err(), "NaN rejected");
        let rec = &r.report.cells["traj/x"];
        assert!(!rec.recorded(), "trajectory-only cells carry no throughput");
        assert_eq!(rec.trajectories["x"], vec![1.0, 2.0]);
    }

    #[test]
    fn in_band_throughput_passes_and_improvement_is_never_flagged() {
        let tracked = report(vec![cell("pool", 100.0, false)], false);
        for fresh_thr in [50.0, 100.0, 400.0] {
            let fresh = report(vec![cell("pool", fresh_thr, false)], false);
            let out = check(&cfg(), &tracked, &fresh);
            assert!(!out.failed(), "throughput {fresh_thr} should pass:\n{}", out.render());
        }
    }

    #[test]
    fn out_of_band_throughput_fails() {
        let tracked = report(vec![cell("pool", 100.0, false)], false);
        let fresh = report(vec![cell("pool", 30.0, false)], false); // floor = 40
        let out = check(&cfg(), &tracked, &fresh);
        assert!(out.failed(), "30 < 100 * (1 - 0.6) must fail:\n{}", out.render());
        assert!(out.notes.iter().any(|n| n.key == "pool" && n.status == GateStatus::Fail));
    }

    #[test]
    fn perturbed_trajectory_fails_and_within_eps_passes() {
        let mut t = cell("async_plan", 10.0, false);
        t.trajectories.insert("async_sim_time".to_string(), vec![1.0, 2.0, 3.0]);
        let mut perturbed = t.clone();
        perturbed
            .trajectories
            .insert("async_sim_time".to_string(), vec![1.0, 2.0, 3.0 + 1e-6]);
        let tracked = report(vec![t.clone()], false);

        let out = check(&cfg(), &tracked, &report(vec![perturbed], false));
        assert!(out.failed(), "1e-6 drift must fail:\n{}", out.render());

        let mut jittered = t.clone();
        jittered
            .trajectories
            .insert("async_sim_time".to_string(), vec![1.0, 2.0, 3.0 + 1e-12]);
        let out = check(&cfg(), &tracked, &report(vec![jittered], false));
        assert!(!out.failed(), "sub-eps print jitter must pass:\n{}", out.render());
    }

    #[test]
    fn trajectory_length_change_fails() {
        let mut t = cell("async_plan", 10.0, false);
        t.trajectories.insert("async_sim_time".to_string(), vec![1.0, 2.0]);
        let mut longer = t.clone();
        longer.trajectories.insert("async_sim_time".to_string(), vec![1.0, 2.0, 3.0]);
        let out = check(&cfg(), &report(vec![t], false), &report(vec![longer], false));
        assert!(out.failed(), "{}", out.render());
    }

    #[test]
    fn placeholder_zero_reports_not_recorded_once_and_passes() {
        let tracked = report(vec![cell("pool", 0.0, false)], false);
        let fresh = report(vec![cell("pool", 50.0, false)], false);
        let out = check(&cfg(), &tracked, &fresh);
        assert!(!out.failed(), "placeholders must not fail the gate:\n{}", out.render());
        let notes: Vec<_> = out
            .notes
            .iter()
            .filter(|n| n.key == "pool" && n.status == GateStatus::NotRecorded)
            .collect();
        assert_eq!(notes.len(), 1, "exactly one not-yet-recorded note per key:\n{}", out.render());
    }

    #[test]
    fn empty_tracked_trajectory_reports_not_recorded() {
        let mut t = cell("async_plan", 10.0, false);
        t.trajectories.insert("async_sim_time".to_string(), Vec::new());
        let mut f = cell("async_plan", 10.0, false);
        f.trajectories.insert("async_sim_time".to_string(), vec![1.0]);
        let out = check(&cfg(), &report(vec![t], false), &report(vec![f], false));
        assert!(!out.failed(), "{}", out.render());
        assert!(out.notes.iter().any(|n| n.key == "async_plan.async_sim_time"
            && n.status == GateStatus::NotRecorded));
    }

    #[test]
    fn quick_vs_full_mode_is_refused_not_compared() {
        // If the gate compared across modes this would be a gross
        // "regression"; the mode-mismatch rule must SKIP it instead.
        let tracked = report(vec![cell("pool", 100.0, false)], false);
        let fresh = report(vec![cell("pool", 1.0, true)], true);
        let out = check(&cfg(), &tracked, &fresh);
        assert!(!out.failed(), "mode mismatch must SKIP, not fail:\n{}", out.render());
        assert!(out.notes.iter().any(|n| n.key == "pool" && n.status == GateStatus::Skip));
        assert!(out.notes.iter().any(|n| n.key == "mode" && n.status == GateStatus::Skip));
    }

    #[test]
    fn per_cell_mode_mismatch_is_refused_even_when_run_modes_agree() {
        // A quick-mode record left in a full-mode file (the pre-v3 bug:
        // quick and full numbers silently mixed) must still be refused.
        let tracked = report(vec![cell("pool", 1.0, true)], false);
        let fresh = report(vec![cell("pool", 100.0, false)], false);
        let out = check(&cfg(), &tracked, &fresh);
        assert!(!out.failed(), "{}", out.render());
        assert!(out.notes.iter().any(|n| n.key == "pool" && n.status == GateStatus::Skip));
    }

    #[test]
    fn missing_required_axis_is_reported() {
        let tracked = report(Vec::new(), false);
        let fresh = report(Vec::new(), false);
        let out = check(&cfg(), &tracked, &fresh);
        assert!(!out.failed());
        assert!(
            out.notes
                .iter()
                .any(|n| n.key == "pool" && n.status == GateStatus::NotRecorded),
            "required axis `pool` must be called out:\n{}",
            out.render()
        );
    }

    #[test]
    fn unmeasured_tracked_cells_skip_loudly() {
        let tracked = report(vec![cell("artifact/client_step", 10.0, false)], false);
        let fresh = report(Vec::new(), false);
        let out = check(&cfg(), &tracked, &fresh);
        assert!(!out.failed());
        assert!(out
            .notes
            .iter()
            .any(|n| n.key == "artifact/client_step" && n.status == GateStatus::Skip));
    }

    #[test]
    fn clean_self_comparison_passes_with_no_gaps() {
        let mut c = cell("pool", 100.0, false);
        c.trajectories.insert("x".to_string(), vec![1.0, 2.0]);
        let tracked = report(vec![c.clone()], false);
        let fresh = report(vec![c], false);
        let out = check(&cfg(), &tracked, &fresh);
        assert!(!out.failed(), "{}", out.render());
        assert!(
            out.notes.iter().all(|n| n.status == GateStatus::Pass),
            "a freshly written file must compare clean:\n{}",
            out.render()
        );
    }
}
