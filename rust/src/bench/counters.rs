//! Best-effort per-cell process counters (DESIGN.md §14).
//!
//! The offline container bakes in no perf tooling and the crate adds no
//! dependencies, so counters come from procfs text: CPU time from
//! `/proc/self/stat` (utime/stime, kernel clock ticks), cumulative IO
//! from `/proc/self/io` (`rchar`/`wchar` — often permission-gated in
//! containers), and the peak-RSS high-water mark from
//! `/proc/self/status` (`VmHWM`). Every probe degrades to
//! "unavailable" on non-Linux hosts or sandboxed readers instead of
//! failing the bench — the rusage-style fallback is simply whichever
//! subset of probes still answers.
//!
//! Counters are *context*, never gated numbers: they are recorded
//! per-cell in `BENCH_results.json` for a human reading the file, and
//! the regression gate never compares them (CPU ticks and IO bytes are
//! scheduler- and kernel-version-dependent, so banding them would only
//! manufacture flakes).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::util::Json;

/// Raw cumulative process readings at one instant. Deltas of two
/// samples bracket a cell's timed region.
#[derive(Clone, Copy, Debug, Default)]
pub struct CounterSample {
    utime_ticks: u64,
    stime_ticks: u64,
    rchar_bytes: u64,
    wchar_bytes: u64,
    stat_available: bool,
    io_available: bool,
}

/// Per-cell counter deltas (plus the end-of-cell `VmHWM` high-water
/// mark, which the kernel only reports cumulatively).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Counters {
    /// Whether the CPU-time probe answered (false ⇒ every delta is 0
    /// and means "unknown", not "free").
    pub available: bool,
    /// Whether the IO probe answered (`/proc/self/io` is frequently
    /// unreadable inside containers even when stat is fine).
    pub io_available: bool,
    pub utime_ticks: f64,
    pub stime_ticks: f64,
    pub rchar_bytes: f64,
    pub wchar_bytes: f64,
    /// Peak resident set at the end of the cell, in kB (0 if unknown).
    pub vm_hwm_kb: f64,
}

fn read_cpu_ticks() -> Option<(u64, u64)> {
    let text = std::fs::read_to_string("/proc/self/stat").ok()?;
    // comm (field 2) is parenthesized and may contain spaces; fields
    // resume after the last ')'. utime/stime are fields 14/15, i.e.
    // indices 11/12 of the post-comm tail.
    let tail = &text[text.rfind(')')? + 1..];
    let fields: Vec<&str> = tail.split_whitespace().collect();
    let utime = fields.get(11)?.parse().ok()?;
    let stime = fields.get(12)?.parse().ok()?;
    Some((utime, stime))
}

fn read_io_bytes() -> Option<(u64, u64)> {
    let text = std::fs::read_to_string("/proc/self/io").ok()?;
    let mut rchar = None;
    let mut wchar = None;
    for line in text.lines() {
        if let Some(v) = line.strip_prefix("rchar:") {
            rchar = v.trim().parse().ok();
        } else if let Some(v) = line.strip_prefix("wchar:") {
            wchar = v.trim().parse().ok();
        }
    }
    Some((rchar?, wchar?))
}

fn read_vm_hwm_kb() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(v) = line.strip_prefix("VmHWM:") {
            return v.trim().trim_end_matches("kB").trim().parse().ok();
        }
    }
    None
}

/// Snapshot the cumulative counters now. Infallible: probes that fail
/// mark themselves unavailable in the sample.
pub fn sample() -> CounterSample {
    let mut s = CounterSample::default();
    if let Some((u, k)) = read_cpu_ticks() {
        s.utime_ticks = u;
        s.stime_ticks = k;
        s.stat_available = true;
    }
    if let Some((r, w)) = read_io_bytes() {
        s.rchar_bytes = r;
        s.wchar_bytes = w;
        s.io_available = true;
    }
    s
}

/// The per-cell delta between two samples taken around a timed region.
pub fn delta(start: &CounterSample, end: &CounterSample) -> Counters {
    let available = start.stat_available && end.stat_available;
    let io_available = start.io_available && end.io_available;
    Counters {
        available,
        io_available,
        utime_ticks: if available {
            end.utime_ticks.saturating_sub(start.utime_ticks) as f64
        } else {
            0.0
        },
        stime_ticks: if available {
            end.stime_ticks.saturating_sub(start.stime_ticks) as f64
        } else {
            0.0
        },
        rchar_bytes: if io_available {
            end.rchar_bytes.saturating_sub(start.rchar_bytes) as f64
        } else {
            0.0
        },
        wchar_bytes: if io_available {
            end.wchar_bytes.saturating_sub(start.wchar_bytes) as f64
        } else {
            0.0
        },
        vm_hwm_kb: read_vm_hwm_kb().unwrap_or(0) as f64,
    }
}

impl Counters {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("available".to_string(), Json::Bool(self.available));
        m.insert("io_available".to_string(), Json::Bool(self.io_available));
        m.insert("utime_ticks".to_string(), Json::Num(self.utime_ticks));
        m.insert("stime_ticks".to_string(), Json::Num(self.stime_ticks));
        m.insert("rchar_bytes".to_string(), Json::Num(self.rchar_bytes));
        m.insert("wchar_bytes".to_string(), Json::Num(self.wchar_bytes));
        m.insert("vm_hwm_kb".to_string(), Json::Num(self.vm_hwm_kb));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Counters> {
        Ok(Counters {
            available: j.get("available")?.as_bool()?,
            io_available: j.get("io_available")?.as_bool()?,
            utime_ticks: j.get("utime_ticks")?.as_f64()?,
            stime_ticks: j.get("stime_ticks")?.as_f64()?,
            rchar_bytes: j.get("rchar_bytes")?.as_f64()?,
            wchar_bytes: j.get("wchar_bytes")?.as_f64()?,
            vm_hwm_kb: j.get("vm_hwm_kb")?.as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_never_fails_and_deltas_are_nonnegative() {
        let a = sample();
        // burn a little CPU so a tick *may* elapse (not asserted — tick
        // granularity is 10ms and this must not flake)
        let mut acc = 0u64;
        for i in 0..200_000u64 {
            acc = acc.wrapping_add(i ^ (acc << 1));
        }
        std::hint::black_box(acc);
        let b = sample();
        let d = delta(&a, &b);
        assert!(d.utime_ticks >= 0.0 && d.stime_ticks >= 0.0);
        assert!(d.rchar_bytes >= 0.0 && d.wchar_bytes >= 0.0);
        if !d.available {
            assert_eq!((d.utime_ticks, d.stime_ticks), (0.0, 0.0), "unavailable means zeroed");
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let c = Counters {
            available: true,
            io_available: false,
            utime_ticks: 12.0,
            stime_ticks: 3.0,
            rchar_bytes: 0.0,
            wchar_bytes: 0.0,
            vm_hwm_kb: 20480.0,
        };
        let text = c.to_json().to_string_pretty();
        let back = Counters::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        assert!(Counters::from_json(&Json::parse("{}").unwrap()).is_err());
    }
}
