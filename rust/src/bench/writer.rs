//! `BENCH_results.json` schema v3: structured per-cell records, plus a
//! migration shim that reads the flat v2 schema (DESIGN.md §14).
//!
//! v3 layout:
//!
//! ```json
//! {
//!   "schema_version": 3,
//!   "quick": false,
//!   "cells": {
//!     "<cell_id>": {
//!       "cell_id": "...",
//!       "stats": {"name", "iters", "mean_s", "p50_s", "p95_s", "min_s"} | null,
//!       "units_per_iter": 64.0,
//!       "throughput_per_s": 0.0,
//!       "trajectories": {"<name>": [..]},
//!       "counters": {..} | null,
//!       "quick": false
//!     }
//!   }
//! }
//! ```
//!
//! `throughput_per_s == 0` means "not yet recorded" and the gate reports
//! it per key. The v2 reader maps each flat `*_per_s` key onto its v3
//! cell id, the `engine_round_clients_per_s` thread table onto
//! `round/t{N}/...` grid cells at the v2 bench's hard-coded coordinates,
//! and the two v2 trajectory arrays onto their owning cells, so a
//! pre-migration tracked file still gates a post-migration run.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::bench::BenchStats;
use crate::util::Json;

use super::counters::Counters;
use super::runner::{BenchReport, CellRecord};

/// The schema this build writes.
pub const SCHEMA_VERSION: usize = 3;

/// v2 flat throughput keys → v3 cell ids.
const V2_AXES: [(&str, &str); 8] = [
    ("async_plan_rounds_per_s", "async_plan"),
    ("snapshot_ring_rounds_per_s", "snapshot_ring"),
    ("bound_controller_steps_per_s", "bound_controller"),
    ("pool_jobs_per_s", "pool"),
    ("shard_store_ops_per_s", "shard_store"),
    ("event_heap_events_per_s", "event_heap"),
    ("scenario_events_per_s", "scenario"),
    ("detlint_files_per_s", "detlint"),
];

// The v2 bench hard-coded its engine-round grid to 8 clients under the
// sync scheduler and the ada-split protocol; its thread table migrates
// onto the v3 grid cells at those coordinates.
const V2_ROUND_CLIENTS: usize = 8;
const V2_ROUND_SCHEDULER: &str = "sync";
const V2_ROUND_PROTOCOL: &str = "ada-split";

fn f64_arr(j: &Json) -> Result<Vec<f64>> {
    j.as_arr()?.iter().map(|v| v.as_f64()).collect()
}

fn stats_to_json(s: &BenchStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(s.name.clone()));
    m.insert("iters".to_string(), Json::Num(s.iters as f64));
    m.insert("mean_s".to_string(), Json::Num(s.mean_s));
    m.insert("p50_s".to_string(), Json::Num(s.p50_s));
    m.insert("p95_s".to_string(), Json::Num(s.p95_s));
    m.insert("min_s".to_string(), Json::Num(s.min_s));
    Json::Obj(m)
}

fn stats_from_json(j: &Json) -> Result<BenchStats> {
    Ok(BenchStats {
        name: j.get("name")?.as_str()?.to_string(),
        iters: j.get("iters")?.as_usize()?,
        mean_s: j.get("mean_s")?.as_f64()?,
        p50_s: j.get("p50_s")?.as_f64()?,
        p95_s: j.get("p95_s")?.as_f64()?,
        min_s: j.get("min_s")?.as_f64()?,
    })
}

fn cell_to_json(c: &CellRecord) -> Json {
    let mut m = BTreeMap::new();
    m.insert("cell_id".to_string(), Json::Str(c.id.clone()));
    m.insert(
        "stats".to_string(),
        c.stats.as_ref().map(stats_to_json).unwrap_or(Json::Null),
    );
    m.insert("units_per_iter".to_string(), Json::Num(c.units_per_iter));
    m.insert("throughput_per_s".to_string(), Json::Num(c.throughput_per_s));
    m.insert(
        "trajectories".to_string(),
        Json::Obj(
            c.trajectories
                .iter()
                .map(|(k, v)| {
                    (k.clone(), Json::Arr(v.iter().map(|&x| Json::Num(x)).collect()))
                })
                .collect(),
        ),
    );
    m.insert(
        "counters".to_string(),
        c.counters.as_ref().map(Counters::to_json).unwrap_or(Json::Null),
    );
    m.insert("quick".to_string(), Json::Bool(c.quick));
    Json::Obj(m)
}

fn cell_from_json(id: &str, j: &Json) -> Result<CellRecord> {
    let stats = match j.get("stats")? {
        Json::Null => None,
        s => Some(stats_from_json(s)?),
    };
    let counters = match j.get("counters")? {
        Json::Null => None,
        c => Some(Counters::from_json(c)?),
    };
    let mut trajectories = BTreeMap::new();
    for (name, vals) in j.get("trajectories")?.as_obj()? {
        trajectories.insert(name.clone(), f64_arr(vals)?);
    }
    Ok(CellRecord {
        id: id.to_string(),
        stats,
        units_per_iter: j.get("units_per_iter")?.as_f64()?,
        throughput_per_s: j.get("throughput_per_s")?.as_f64()?,
        trajectories,
        counters,
        quick: j.get("quick")?.as_bool()?,
    })
}

/// Serialize a report as schema v3.
pub fn report_to_json(r: &BenchReport) -> Json {
    let mut top = BTreeMap::new();
    top.insert("schema_version".to_string(), Json::Num(SCHEMA_VERSION as f64));
    top.insert("quick".to_string(), Json::Bool(r.quick));
    top.insert(
        "cells".to_string(),
        Json::Obj(r.cells.iter().map(|(k, c)| (k.clone(), cell_to_json(c))).collect()),
    );
    Json::Obj(top)
}

fn from_v3(j: &Json) -> Result<BenchReport> {
    let quick = j.get("quick")?.as_bool()?;
    let mut cells = BTreeMap::new();
    for (id, cj) in j.get("cells")?.as_obj()? {
        // the map key is authoritative; the embedded cell_id is for
        // humans reading the file
        let cell = cell_from_json(id, cj).with_context(|| format!("cell `{id}`"))?;
        cells.insert(id.clone(), cell);
    }
    Ok(BenchReport { quick, cells })
}

/// A cell migrated from a flat v2 throughput key: no stats, no units,
/// just the tracked number (0 stays "not yet recorded").
fn migrated_cell(id: &str, throughput: f64, quick: bool) -> CellRecord {
    CellRecord {
        id: id.to_string(),
        stats: None,
        units_per_iter: 0.0,
        throughput_per_s: throughput,
        trajectories: BTreeMap::new(),
        counters: None,
        quick,
    }
}

fn migrate_v2(j: &Json) -> Result<BenchReport> {
    // v2 recorded quick as a 0/1 number; tolerate a bool for safety.
    let quick = match j.opt("quick") {
        Some(Json::Num(x)) => *x != 0.0,
        Some(Json::Bool(b)) => *b,
        _ => false,
    };
    let mut cells: BTreeMap<String, CellRecord> = BTreeMap::new();

    for (v2_key, cell_id) in V2_AXES {
        if let Some(v) = j.opt(v2_key) {
            let thr = v.as_f64().with_context(|| format!("v2 key `{v2_key}`"))?;
            cells.insert(cell_id.to_string(), migrated_cell(cell_id, thr, quick));
        }
    }

    if let Some(table) = j.opt("engine_round_clients_per_s") {
        for (threads, v) in table.as_obj()? {
            let t: usize = threads.parse().with_context(|| {
                format!("v2 engine_round_clients_per_s thread key `{threads}`")
            })?;
            let id = format!(
                "round/t{t}/c{V2_ROUND_CLIENTS}/{V2_ROUND_SCHEDULER}/{V2_ROUND_PROTOCOL}"
            );
            let thr = v.as_f64().with_context(|| format!("v2 round cell t={t}"))?;
            cells.insert(id.clone(), migrated_cell(&id, thr, quick));
        }
    }

    if let Some(t) = j.opt("async_sim_time") {
        let vals = f64_arr(t).context("v2 key `async_sim_time`")?;
        let cell = cells
            .entry("async_plan".to_string())
            .or_insert_with(|| migrated_cell("async_plan", 0.0, quick));
        cell.trajectories.insert("async_sim_time".to_string(), vals);
    }

    if let Some(t) = j.opt("mask_density") {
        let vals = f64_arr(t).context("v2 key `mask_density`")?;
        let cell = cells
            .entry("traj/mask_density".to_string())
            .or_insert_with(|| migrated_cell("traj/mask_density", 0.0, quick));
        cell.trajectories.insert("mask_density".to_string(), vals);
    }

    Ok(BenchReport { quick, cells })
}

/// Parse a tracked `BENCH_results.json`, accepting schema v3 natively
/// and v2 through the migration shim.
pub fn report_from_json(j: &Json) -> Result<BenchReport> {
    match j.get("schema_version")?.as_usize()? {
        3 => from_v3(j),
        2 => migrate_v2(j),
        other => bail!(
            "unsupported BENCH_results schema version {other} (this build reads v2 and v3)"
        ),
    }
}

/// Parse tracked results from file text.
pub fn read_tracked(text: &str) -> Result<BenchReport> {
    report_from_json(&Json::parse(text).context("BENCH_results.json: parse error")?)
        .context("BENCH_results.json")
}

/// Write a report to `path` as pretty-printed schema v3.
pub fn write_tracked(path: &Path, r: &BenchReport) -> Result<()> {
    let mut text = report_to_json(r).to_string_pretty();
    text.push('\n');
    std::fs::write(path, text)
        .with_context(|| format!("cannot write bench results to {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> BenchReport {
        let mut cells = BTreeMap::new();
        let mut pool = CellRecord {
            id: "pool".to_string(),
            stats: Some(BenchStats {
                name: "pool".to_string(),
                iters: 20,
                mean_s: 0.0125,
                p50_s: 0.012,
                p95_s: 0.02,
                min_s: 0.011,
            }),
            units_per_iter: 4096.0,
            throughput_per_s: 327680.0,
            trajectories: BTreeMap::new(),
            counters: Some(Counters {
                available: true,
                io_available: false,
                utime_ticks: 3.0,
                stime_ticks: 1.0,
                rchar_bytes: 0.0,
                wchar_bytes: 0.0,
                vm_hwm_kb: 20480.0,
            }),
            quick: false,
        };
        pool.trajectories.insert("x".to_string(), vec![0.5, 1.25, 2.0]);
        cells.insert(pool.id.clone(), pool);
        let traj = CellRecord {
            id: "traj/mask_density".to_string(),
            stats: None,
            units_per_iter: 0.0,
            throughput_per_s: 0.0,
            trajectories: BTreeMap::from([(
                "mask_density".to_string(),
                vec![0.31, 0.29],
            )]),
            counters: None,
            quick: false,
        };
        cells.insert(traj.id.clone(), traj);
        BenchReport { quick: false, cells }
    }

    #[test]
    fn v3_roundtrip_is_lossless() {
        let r = sample_report();
        let text = report_to_json(&r).to_string_pretty();
        let back = read_tracked(&text).unwrap();
        assert_eq!(back, r, "schema v3 must round-trip exactly");
    }

    #[test]
    fn v2_migrates_axes_trajectories_and_round_grid() {
        let v2 = r#"{
            "schema_version": 2,
            "quick": 0,
            "pool_jobs_per_s": 1000.5,
            "event_heap_events_per_s": 0,
            "async_plan_rounds_per_s": 12.25,
            "async_sim_time": [0.5, 1.5],
            "mask_density": [0.3],
            "engine_round_clients_per_s": {"1": 8.5, "4": 30.0}
        }"#;
        let r = read_tracked(v2).unwrap();
        assert!(!r.quick);
        assert!((r.cells["pool"].throughput_per_s - 1000.5).abs() < 1e-12);
        assert!(
            !r.cells["event_heap"].recorded(),
            "present-but-zero v2 keys migrate as not-yet-recorded"
        );
        assert!(
            !r.cells.contains_key("scenario"),
            "absent v2 keys do not materialize cells"
        );
        let ap = &r.cells["async_plan"];
        assert!((ap.throughput_per_s - 12.25).abs() < 1e-12);
        assert_eq!(ap.trajectories["async_sim_time"], vec![0.5, 1.5]);
        assert_eq!(
            r.cells["traj/mask_density"].trajectories["mask_density"],
            vec![0.3]
        );
        let round = &r.cells["round/t4/c8/sync/ada-split"];
        assert!((round.throughput_per_s - 30.0).abs() < 1e-12);
        assert!(r.cells.contains_key("round/t1/c8/sync/ada-split"));
        assert!(round.stats.is_none() && round.counters.is_none(), "v2 kept only throughput");
    }

    #[test]
    fn committed_tracked_file_reads_and_is_explicit_about_placeholders() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_results.json");
        let text = std::fs::read_to_string(path).unwrap();
        let r = read_tracked(&text).unwrap();
        assert!(r.cells.contains_key("pool"), "tracked file must carry the pure axes");
        // The committed file is a placeholder until a toolchain-equipped
        // runner records it; every cell must therefore read as
        // not-yet-recorded, never as silently-passing coverage.
        for (id, c) in &r.cells {
            assert!(!c.recorded(), "placeholder cell `{id}` must not claim a measurement");
        }
    }

    #[test]
    fn unknown_schema_version_is_rejected() {
        let err = read_tracked(r#"{"schema_version": 7, "cells": {}}"#).unwrap_err();
        assert!(
            format!("{err:#}").contains("unsupported BENCH_results schema version 7"),
            "got: {err:#}"
        );
        assert!(read_tracked(r#"{"no_version": true}"#).is_err());
    }
}
