//! Round schedulers: which clients participate in each round.
//!
//! The driver asks the scheduler once per round, on the driver thread, so
//! participant selection is a pure function of (experiment seed, round) —
//! never of thread count or worker timing. Two schedulers ship today:
//!
//! * [`SyncAll`] — every client, every round (the pre-redesign behavior).
//! * [`SampledSync`] — per-round subsampling of `ceil(p * N)` clients
//!   (FedLite-style client sampling, arXiv 2201.11865), seeded and
//!   deterministic across thread counts and repeated invocations.
//!
//! The planned async/staleness mode (ROADMAP) is a third implementor: it
//! returns the clients whose simulated completion time falls inside the
//! round boundary, without touching protocol code.

use crate::config::ExperimentConfig;
use crate::data::Rng;

/// Per-round client-participation policy.
///
/// `participants` must return ascending, unique client ids (the driver
/// fans out and merges in id order), and must be deterministic given the
/// construction parameters and `round`.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;
    /// Ascending client ids participating in `round`.
    fn participants(&mut self, round: usize) -> Vec<usize>;
    /// Clients sampled per round (for reporting).
    fn sampled_per_round(&self) -> usize;
}

/// Every client, every round — today's synchronous behavior.
pub struct SyncAll {
    n: usize,
}

impl SyncAll {
    pub fn new(n_clients: usize) -> Self {
        Self { n: n_clients }
    }
}

impl Scheduler for SyncAll {
    fn name(&self) -> &'static str {
        "sync-all"
    }

    fn participants(&mut self, _round: usize) -> Vec<usize> {
        (0..self.n).collect()
    }

    fn sampled_per_round(&self) -> usize {
        self.n
    }
}

/// Synchronous rounds over a per-round random subsample of
/// `ceil(participation * N)` clients.
///
/// The sample for round `r` is drawn from an RNG stream derived as
/// (seed -> "sampled-sync" -> r), so it is identical across `--threads`
/// values and across repeated invocations with the same seed, and
/// independent of every other random decision in the run (data synthesis,
/// shuffling) — adding sampling does not perturb the data a client sees.
pub struct SampledSync {
    n: usize,
    per_round: usize,
    rng: Rng,
}

impl SampledSync {
    pub fn new(n_clients: usize, participation: f64, seed: u64) -> Self {
        let per_round =
            ((participation * n_clients as f64).ceil() as usize).clamp(1, n_clients.max(1));
        Self {
            n: n_clients,
            per_round,
            rng: Rng::new(seed),
        }
    }
}

impl Scheduler for SampledSync {
    fn name(&self) -> &'static str {
        "sampled-sync"
    }

    fn participants(&mut self, round: usize) -> Vec<usize> {
        if self.per_round == self.n {
            // p = 1.0 degenerates to SyncAll exactly (bit-identity contract)
            return (0..self.n).collect();
        }
        let mut r = self.rng.derive("sampled-sync", round as u64);
        let mut ids = r.permutation(self.n);
        ids.truncate(self.per_round);
        ids.sort_unstable();
        ids
    }

    fn sampled_per_round(&self) -> usize {
        self.per_round
    }
}

/// Scheduler configured by the experiment (`participation` key /
/// `--participation` flag; 1.0 = full participation).
pub fn scheduler_for(cfg: &ExperimentConfig) -> Box<dyn Scheduler> {
    if cfg.participation < 1.0 {
        Box::new(SampledSync::new(cfg.clients, cfg.participation, cfg.seed))
    } else {
        Box::new(SyncAll::new(cfg.clients))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_all_selects_everyone() {
        let mut s = SyncAll::new(4);
        assert_eq!(s.participants(0), vec![0, 1, 2, 3]);
        assert_eq!(s.participants(17), vec![0, 1, 2, 3]);
        assert_eq!(s.sampled_per_round(), 4);
    }

    #[test]
    fn full_participation_sampling_equals_sync_all() {
        let mut all = SyncAll::new(6);
        let mut sampled = SampledSync::new(6, 1.0, 9);
        for round in 0..20 {
            assert_eq!(sampled.participants(round), all.participants(round));
        }
    }

    #[test]
    fn sample_size_is_ceil_and_clamped() {
        assert_eq!(SampledSync::new(8, 0.25, 0).sampled_per_round(), 2);
        assert_eq!(SampledSync::new(8, 0.26, 0).sampled_per_round(), 3);
        assert_eq!(SampledSync::new(8, 0.01, 0).sampled_per_round(), 1);
        assert_eq!(SampledSync::new(5, 1.0, 0).sampled_per_round(), 5);
    }

    #[test]
    fn samples_are_sorted_unique_and_deterministic() {
        let mut a = SampledSync::new(64, 0.25, 7);
        let mut b = SampledSync::new(64, 0.25, 7);
        let mut c = SampledSync::new(64, 0.25, 8);
        let mut differs = false;
        for round in 0..50 {
            let pa = a.participants(round);
            assert_eq!(pa.len(), 16);
            assert!(pa.windows(2).all(|w| w[0] < w[1]), "sorted+unique");
            assert!(*pa.last().unwrap() < 64);
            assert_eq!(pa, b.participants(round), "same seed, same sample");
            if pa != c.participants(round) {
                differs = true;
            }
        }
        assert!(differs, "different seeds must differ somewhere");
    }

    #[test]
    fn rounds_draw_different_samples() {
        let mut s = SampledSync::new(32, 0.5, 3);
        let r0 = s.participants(0);
        let mut any_diff = false;
        for round in 1..10 {
            if s.participants(round) != r0 {
                any_diff = true;
            }
        }
        assert!(any_diff, "per-round subsampling must vary across rounds");
    }

    #[test]
    fn repeated_queries_for_one_round_agree() {
        // stateless per-round derivation: asking twice is harmless
        let mut s = SampledSync::new(16, 0.5, 11);
        assert_eq!(s.participants(3), s.participants(3));
    }

    #[test]
    fn scheduler_for_picks_by_participation() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(scheduler_for(&cfg).name(), "sync-all");
        cfg.participation = 0.5;
        assert_eq!(scheduler_for(&cfg).name(), "sampled-sync");
    }
}
