//! Round schedulers: which clients participate in each round, how stale
//! their contributions are, and how much virtual wall-clock the round
//! costs.
//!
//! The driver asks the scheduler once per round, on the driver thread, so
//! the plan is a pure function of (experiment seed, round) — never of
//! thread count or worker timing. Three schedulers ship:
//!
//! * [`SyncAll`] — every client, every round (the pre-redesign behavior);
//!   the round's virtual duration is the slowest client's.
//! * [`SampledSync`] — per-round subsampling of `ceil(p * N)` clients
//!   (FedLite-style client sampling, arXiv 2201.11865), seeded and
//!   deterministic across thread counts and repeated invocations.
//! * [`AsyncBounded`] — bounded-staleness async rounds over a per-client
//!   virtual clock driven by the seeded [`ClientSpeeds`] model
//!   (`--staleness-bound s` / `--client-speeds`): each client advances at
//!   its own rate, the server merges whichever updates have arrived, and
//!   no contribution is ever staler than `s` rounds (clients at the bound
//!   are waited for). `s = 0` with uniform speeds reproduces [`SyncAll`]
//!   bit-for-bit (pinned by `tests/engine_determinism.rs`).

use crate::config::ExperimentConfig;
use crate::data::Rng;
use crate::driver::speed::ClientSpeeds;

/// One round's schedule: who merges, how stale each contribution is, and
/// the virtual wall-clock at which the merge happens.
pub struct RoundPlan {
    /// Ascending, unique client ids merging this round.
    pub participants: Vec<usize>,
    /// Per-participant staleness in rounds (parallel to `participants`):
    /// how many server rounds elapsed while the contribution was in
    /// flight. `0` = fresh (the synchronous case). Never exceeds the
    /// scheduler's staleness bound.
    pub staleness: Vec<usize>,
    /// Simulated wall-clock at the round's merge, in baseline-round units
    /// (monotone non-decreasing across rounds).
    pub sim_time: f64,
}

/// Per-round client-participation policy.
///
/// `plan` must return ascending, unique client ids (the driver fans out
/// and merges in id order), and must be deterministic given the
/// construction parameters and the *sequence* of `plan` calls: a
/// scheduler may carry simulation state across rounds ([`AsyncBounded`]
/// advances virtual clocks and staleness bookkeeping on every call), so
/// the contract is one `plan` per round, in round order — the driver's
/// usage. Replaying the same call sequence replays the same plans
/// bit-for-bit; the stateless schedulers are additionally insensitive to
/// repeated queries.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// The round's participants, staleness, and virtual merge time.
    /// Advances the scheduler to the next round — call once per round.
    fn plan(&mut self, round: usize) -> RoundPlan;

    /// Ascending client ids that `plan(round)` would select, **without
    /// advancing the scheduler**: a true peek against the current
    /// virtual state. Peeking any number of times never perturbs a
    /// subsequent `plan` stream, and `participants(r)` always equals the
    /// participants of the `plan(r)` issued next (pinned by the
    /// `async_clock_unaffected_by_participants_peek` regression test —
    /// the pre-fix default delegated to `plan`, so mixing the two
    /// double-advanced a stateful scheduler's clock).
    fn participants(&self, round: usize) -> Vec<usize>;

    /// Clients sampled per round (for reporting).
    fn sampled_per_round(&self) -> usize;

    /// The staleness bound currently in effect: how stale a merged
    /// contribution may be, in rounds. Synchronous schedulers are always
    /// fresh, so the default is `0`; [`AsyncBounded`] reports its live
    /// (possibly controller-switched) bound.
    fn current_bound(&self) -> usize {
        0
    }

    /// Switch the staleness bound before `next_round` is planned — the
    /// adaptive controller's actuator, only ever called on a window
    /// boundary. Returns `true` when the scheduler supports runtime
    /// bound switching ([`AsyncBounded`]); the synchronous schedulers
    /// have no bound to move and return `false` untouched.
    ///
    /// Implementations must preserve the scheduler invariants across the
    /// switch: the merge set stays non-empty, the server clock stays
    /// monotone, and no contribution merged from `next_round` on is
    /// staler than the *new* bound (pinned by the `adaptive_*` property
    /// suite in `tests/engine_determinism.rs`).
    fn set_bound(&mut self, bound: usize, next_round: usize) -> bool {
        let _ = (bound, next_round);
        false
    }
}

/// Every client, every round — today's synchronous behavior. Each round's
/// virtual duration is the slowest participant's round duration (`1.0`
/// under uniform speeds, so the clock reads in rounds).
pub struct SyncAll {
    n: usize,
    round_time: f64,
    clock: f64,
}

impl SyncAll {
    pub fn new(n_clients: usize) -> Self {
        Self { n: n_clients, round_time: 1.0, clock: 0.0 }
    }

    /// Synchronous rounds timed under a heterogeneous speed model: the
    /// barrier waits for the slowest device every round. (The fleet is
    /// never empty — `clients > 0` is a config invariant, and
    /// `slowest_duration` asserts it rather than silently freezing the
    /// clock.)
    pub fn with_speeds(n_clients: usize, speeds: &ClientSpeeds) -> Self {
        let all: Vec<usize> = (0..n_clients).collect();
        Self {
            n: n_clients,
            round_time: speeds.slowest_duration(&all),
            clock: 0.0,
        }
    }
}

impl Scheduler for SyncAll {
    fn name(&self) -> &'static str {
        "sync-all"
    }

    fn plan(&mut self, _round: usize) -> RoundPlan {
        self.clock += self.round_time;
        RoundPlan {
            participants: (0..self.n).collect(),
            staleness: vec![0; self.n],
            sim_time: self.clock,
        }
    }

    fn participants(&self, _round: usize) -> Vec<usize> {
        (0..self.n).collect()
    }

    fn sampled_per_round(&self) -> usize {
        self.n
    }
}

/// Synchronous rounds over a per-round random subsample of
/// `ceil(participation * N)` clients.
///
/// The sample for round `r` is drawn from an RNG stream derived as
/// (seed -> "sampled-sync" -> r), so it is identical across `--threads`
/// values and across repeated invocations with the same seed, and
/// independent of every other random decision in the run (data synthesis,
/// shuffling) — adding sampling does not perturb the data a client sees.
/// The round's virtual duration is the slowest *sampled* client's.
pub struct SampledSync {
    n: usize,
    per_round: usize,
    rng: Rng,
    speeds: ClientSpeeds,
    clock: f64,
}

impl SampledSync {
    pub fn new(n_clients: usize, participation: f64, seed: u64) -> Self {
        let uniform =
            ClientSpeeds::new(n_clients, crate::driver::SpeedPreset::Uniform, 0.0, seed);
        Self::with_speeds(n_clients, participation, seed, &uniform)
    }

    pub fn with_speeds(
        n_clients: usize,
        participation: f64,
        seed: u64,
        speeds: &ClientSpeeds,
    ) -> Self {
        let per_round =
            ((participation * n_clients as f64).ceil() as usize).clamp(1, n_clients.max(1));
        Self {
            n: n_clients,
            per_round,
            rng: Rng::new(seed),
            speeds: speeds.clone(),
            clock: 0.0,
        }
    }

    fn sample(&self, round: usize) -> Vec<usize> {
        if self.per_round == self.n {
            // p = 1.0 degenerates to SyncAll exactly (bit-identity contract)
            return (0..self.n).collect();
        }
        // Floyd's k-of-n sampling: k draws and O(k) memory, instead of
        // materializing (and shuffling) an O(fleet) permutation per round.
        // Uniform over k-subsets; the round-keyed stream keeps it
        // deterministic across threads and repeated peeks.
        let mut r = self.rng.derive("sampled-sync", round as u64);
        let k = self.per_round;
        let mut chosen = std::collections::BTreeSet::new();
        for j in (self.n - k)..self.n {
            let t = r.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        // BTreeSet iteration is ascending: sorted + unique by construction
        chosen.into_iter().collect()
    }
}

impl Scheduler for SampledSync {
    fn name(&self) -> &'static str {
        "sampled-sync"
    }

    fn plan(&mut self, round: usize) -> RoundPlan {
        let participants = self.sample(round);
        // the sample is never empty (per_round >= 1 by construction), so
        // the slowest duration is a real positive barrier time
        self.clock += self.speeds.slowest_duration(&participants);
        RoundPlan {
            staleness: vec![0; participants.len()],
            sim_time: self.clock,
            participants,
        }
    }

    fn participants(&self, round: usize) -> Vec<usize> {
        // the per-round sample derives from a round-keyed RNG stream, so
        // peeking is naturally stateless
        self.sample(round)
    }

    fn sampled_per_round(&self) -> usize {
        self.per_round
    }
}

/// Bounded-staleness asynchronous rounds over per-client virtual clocks.
///
/// Every client computes continuously at its own speed: client `i`'s
/// current work unit completes at virtual time `ready[i]`. A server round
/// `r` merges at time `T_r`:
///
/// 1. **Required set** — clients whose contribution would exceed the
///    staleness bound `s` if they sat this round out (`r - last_sync >
///    s`). The merge waits for the slowest of them (`T_r = max ready`),
///    which is what makes the bound *hard*: no merged update is ever
///    staler than `s` rounds.
/// 2. **Empty-merge fallback** — when no one is required (large `s`,
///    early rounds), the server waits for the fastest in-flight client
///    instead, so the merge set is never empty.
/// 3. **Arrivals** — every client whose work finished by `T_r` is
///    eligible; the merge set takes the required clients plus the
///    earliest finishers (id tie-break) up to `max(ceil(p*N), |required|)`
///    — `--participation` caps how much the server absorbs per round,
///    but the staleness bound always wins.
///
/// Merged clients restart their next unit at `T_r`; capped-out arrivals
/// keep their finished update pending (its staleness grows until the
/// bound forces it in). The server clock is clamped monotone.
///
/// A participant's staleness is the number of server rounds its work
/// straddled: `r - 1 - last_sync` (0 when it also merged in round
/// `r - 1`). With `s = 0` every client is required every round, the plan
/// degenerates to [`SyncAll`] (same participants, zero staleness), and
/// under uniform speeds the virtual clock matches too — the bit-parity
/// contract.
pub struct AsyncBounded {
    n: usize,
    bound: usize,
    cap: usize,
    durations: Vec<f64>,
    /// virtual completion time of each client's in-flight work unit
    ready: Vec<f64>,
    /// last round each client merged (-1 = never)
    last_sync: Vec<i64>,
    clock: f64,
}

impl AsyncBounded {
    pub fn new(
        n_clients: usize,
        staleness_bound: usize,
        participation: f64,
        speeds: &ClientSpeeds,
    ) -> Self {
        let cap =
            ((participation * n_clients as f64).ceil() as usize).clamp(1, n_clients.max(1));
        let durations: Vec<f64> = (0..n_clients)
            .map(|i| speeds.round_duration(i).max(f64::MIN_POSITIVE))
            .collect();
        Self {
            n: n_clients,
            bound: staleness_bound,
            cap,
            ready: durations.clone(),
            durations,
            last_sync: vec![-1; n_clients],
            clock: 0.0,
        }
    }

    pub fn staleness_bound(&self) -> usize {
        self.bound
    }

    /// Round `round`'s full merge computation against the current
    /// virtual state, *without applying it*: the returned plan's
    /// `sim_time` is the would-be post-merge server clock. `plan`
    /// applies the outcome; `participants` discards it, which is what
    /// makes peeking side-effect free.
    fn compute(&self, round: usize) -> RoundPlan {
        let r = round as i64;
        let required: Vec<usize> = (0..self.n)
            .filter(|&i| r - self.last_sync[i] > self.bound as i64)
            .collect();

        // merge trigger: wait for the slowest required client; with no one
        // required, wait for the fastest in-flight client so the merge set
        // is never empty
        let trigger = if required.is_empty() {
            self.ready.iter().copied().fold(f64::INFINITY, f64::min)
        } else {
            required
                .iter()
                .map(|&i| self.ready[i])
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let clock = self.clock.max(trigger);

        // non-required arrivals, earliest completion first (id tie-break),
        // up to `cap` total: a bounded max-heap over (ready-bits, id) keys
        // keeps the per-round allocation proportional to the merge set
        // instead of collecting and sorting every arrival in the fleet.
        // `ready` times are strictly positive finite (durations clamp to
        // MIN_POSITIVE, the clock is monotone from 0), so the IEEE bit
        // pattern orders exactly like the float — the same (ready, id)
        // selection the old full sort made, pinned against the naive
        // reference by `optimized_merge_selection_matches_naive_reference`.
        let extra = self.cap.max(required.len()) - required.len();
        let mut best: std::collections::BinaryHeap<(u64, usize)> =
            std::collections::BinaryHeap::with_capacity(extra + 1);
        if extra > 0 {
            for i in 0..self.n {
                if self.ready[i] > clock || required.binary_search(&i).is_ok() {
                    continue;
                }
                best.push((self.ready[i].to_bits(), i));
                if best.len() > extra {
                    best.pop();
                }
            }
        }
        let mut merge = required;
        merge.extend(best.into_iter().map(|(_, i)| i));
        merge.sort_unstable();

        let staleness: Vec<usize> = merge
            .iter()
            .map(|&i| (r - 1 - self.last_sync[i]).max(0) as usize)
            .collect();
        RoundPlan { participants: merge, staleness, sim_time: clock }
    }

    /// The pre-optimization merge computation (materialize + full sort of
    /// every arrival), kept verbatim as the semantic reference for the
    /// bounded-heap fast path above. Test-only.
    #[cfg(test)]
    fn compute_naive(&self, round: usize) -> RoundPlan {
        let r = round as i64;
        let required: Vec<usize> = (0..self.n)
            .filter(|&i| r - self.last_sync[i] > self.bound as i64)
            .collect();
        let mut is_required = vec![false; self.n];
        for &i in &required {
            is_required[i] = true;
        }
        let trigger = if required.is_empty() {
            self.ready.iter().copied().fold(f64::INFINITY, f64::min)
        } else {
            required
                .iter()
                .map(|&i| self.ready[i])
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let clock = self.clock.max(trigger);
        let mut arrived: Vec<usize> =
            (0..self.n).filter(|&i| self.ready[i] <= clock).collect();
        arrived.sort_by(|&a, &b| {
            self.ready[a]
                .partial_cmp(&self.ready[b])
                .expect("virtual times are finite")
                .then(a.cmp(&b))
        });
        let limit = self.cap.max(required.len());
        let mut merge = required;
        for &i in &arrived {
            if merge.len() >= limit {
                break;
            }
            if !is_required[i] {
                merge.push(i);
            }
        }
        merge.sort_unstable();
        let staleness: Vec<usize> = merge
            .iter()
            .map(|&i| (r - 1 - self.last_sync[i]).max(0) as usize)
            .collect();
        RoundPlan { participants: merge, staleness, sim_time: clock }
    }
}

impl Scheduler for AsyncBounded {
    fn name(&self) -> &'static str {
        "async-bounded"
    }

    fn plan(&mut self, round: usize) -> RoundPlan {
        let plan = self.compute(round);
        self.clock = plan.sim_time;
        for &i in &plan.participants {
            self.last_sync[i] = round as i64;
            self.ready[i] = self.clock + self.durations[i];
        }
        plan
    }

    fn participants(&self, round: usize) -> Vec<usize> {
        self.compute(round).participants
    }

    fn sampled_per_round(&self) -> usize {
        self.cap
    }

    fn current_bound(&self) -> usize {
        self.bound
    }

    /// Runtime bound switch (the adaptive controller's actuator).
    ///
    /// Loosening only widens future staleness allowances — no state
    /// moves. Tightening re-bases: a client whose in-flight work would
    /// already be staler than the new bound re-pulls at the switch — its
    /// staleness base (`last_sync`) is clamped up to the floor the new
    /// bound implies at `next_round`, so it is *required* in the very
    /// next merge and its contribution reports staleness ≤ the new
    /// bound. That is the honest semantic, not bookkeeping sleight of
    /// hand: `client_round` work actually executes at the merge round
    /// against the snapshot `staleness` names (DESIGN.md §8), so a
    /// smaller declared staleness means the client genuinely trains
    /// against the fresher model it just re-pulled. Completion times
    /// (`ready`) and the server clock are untouched, so clock
    /// monotonicity and plan determinism are preserved, and re-setting
    /// the current bound is a pure no-op (`last_sync >= round - 1 -
    /// bound` already holds under a constant bound — the singleton-arm
    /// bit-parity contract).
    fn set_bound(&mut self, bound: usize, next_round: usize) -> bool {
        self.bound = bound;
        let floor = next_round as i64 - 1 - bound as i64;
        for ls in &mut self.last_sync {
            if *ls < floor {
                *ls = floor;
            }
        }
        true
    }
}

/// Scheduler configured by the experiment: `staleness_bound` set picks
/// [`AsyncBounded`]; otherwise `participation < 1.0` picks
/// [`SampledSync`]; the default is [`SyncAll`]. Returns the experiment's
/// [`ClientSpeeds`] alongside — the scheduler's virtual clock and the
/// driver's per-client cost scaling must come from the *same* fleet, so
/// it is built exactly once here.
pub fn scheduler_for(cfg: &ExperimentConfig) -> (Box<dyn Scheduler>, ClientSpeeds) {
    let speeds = ClientSpeeds::from_cfg(cfg);
    let scheduler: Box<dyn Scheduler> = if let Some(bound) = cfg.staleness_bound {
        Box::new(AsyncBounded::new(cfg.clients, bound, cfg.participation, &speeds))
    } else if cfg.participation < 1.0 {
        Box::new(SampledSync::with_speeds(
            cfg.clients,
            cfg.participation,
            cfg.seed,
            &speeds,
        ))
    } else {
        Box::new(SyncAll::with_speeds(cfg.clients, &speeds))
    };
    (scheduler, speeds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::speed::SpeedPreset;

    fn speeds(n: usize, preset: SpeedPreset, frac: f64, seed: u64) -> ClientSpeeds {
        ClientSpeeds::new(n, preset, frac, seed)
    }

    #[test]
    fn sync_all_selects_everyone() {
        let s = SyncAll::new(4);
        assert_eq!(s.participants(0), vec![0, 1, 2, 3]);
        assert_eq!(s.participants(17), vec![0, 1, 2, 3]);
        assert_eq!(s.sampled_per_round(), 4);
    }

    #[test]
    fn sync_all_clock_counts_rounds_under_uniform_speeds() {
        let mut s = SyncAll::with_speeds(3, &speeds(3, SpeedPreset::Uniform, 0.0, 0));
        for round in 0..5 {
            let plan = s.plan(round);
            assert_eq!(plan.sim_time, (round + 1) as f64);
            assert!(plan.staleness.iter().all(|&st| st == 0));
        }
    }

    #[test]
    fn sync_all_clock_waits_for_the_slowest_device() {
        let sp = speeds(40, SpeedPreset::Stragglers, 0.3, 5);
        let slowest = sp.slowest_duration(&(0..40).collect::<Vec<_>>());
        assert!(slowest > 1.0, "seed must produce at least one straggler");
        let mut s = SyncAll::with_speeds(40, &sp);
        assert_eq!(s.plan(0).sim_time, slowest);
        assert_eq!(s.plan(1).sim_time, 2.0 * slowest);
    }

    #[test]
    fn full_participation_sampling_equals_sync_all() {
        let all = SyncAll::new(6);
        let sampled = SampledSync::new(6, 1.0, 9);
        for round in 0..20 {
            assert_eq!(sampled.participants(round), all.participants(round));
        }
    }

    #[test]
    fn sample_size_is_ceil_and_clamped() {
        assert_eq!(SampledSync::new(8, 0.25, 0).sampled_per_round(), 2);
        assert_eq!(SampledSync::new(8, 0.26, 0).sampled_per_round(), 3);
        assert_eq!(SampledSync::new(8, 0.01, 0).sampled_per_round(), 1);
        assert_eq!(SampledSync::new(5, 1.0, 0).sampled_per_round(), 5);
    }

    #[test]
    fn samples_are_sorted_unique_and_deterministic() {
        let a = SampledSync::new(64, 0.25, 7);
        let b = SampledSync::new(64, 0.25, 7);
        let c = SampledSync::new(64, 0.25, 8);
        let mut differs = false;
        for round in 0..50 {
            let pa = a.participants(round);
            assert_eq!(pa.len(), 16);
            assert!(pa.windows(2).all(|w| w[0] < w[1]), "sorted+unique");
            assert!(*pa.last().unwrap() < 64);
            assert_eq!(pa, b.participants(round), "same seed, same sample");
            if pa != c.participants(round) {
                differs = true;
            }
        }
        assert!(differs, "different seeds must differ somewhere");
    }

    #[test]
    fn rounds_draw_different_samples() {
        let s = SampledSync::new(32, 0.5, 3);
        let r0 = s.participants(0);
        let mut any_diff = false;
        for round in 1..10 {
            if s.participants(round) != r0 {
                any_diff = true;
            }
        }
        assert!(any_diff, "per-round subsampling must vary across rounds");
    }

    #[test]
    fn repeated_queries_for_one_round_agree() {
        // participants() is a non-advancing peek: asking twice is harmless
        let s = SampledSync::new(16, 0.5, 11);
        assert_eq!(s.participants(3), s.participants(3));
    }

    #[test]
    fn scheduler_for_picks_by_config() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(scheduler_for(&cfg).0.name(), "sync-all");
        cfg.participation = 0.5;
        assert_eq!(scheduler_for(&cfg).0.name(), "sampled-sync");
        cfg.staleness_bound = Some(2);
        assert_eq!(scheduler_for(&cfg).0.name(), "async-bounded");
        // the returned speeds are the fleet the scheduler was built over
        let (_, speeds) = scheduler_for(&cfg);
        assert_eq!(speeds.len(), cfg.clients);
    }

    // ---- AsyncBounded -----------------------------------------------------

    #[test]
    fn async_s0_uniform_degenerates_to_sync_all() {
        let sp = speeds(7, SpeedPreset::Uniform, 0.0, 3);
        let mut sync = SyncAll::with_speeds(7, &sp);
        let mut async_s = AsyncBounded::new(7, 0, 1.0, &sp);
        for round in 0..24 {
            let a = sync.plan(round);
            let b = async_s.plan(round);
            assert_eq!(a.participants, b.participants, "round {round}");
            assert_eq!(b.staleness, vec![0; 7], "round {round}");
            assert_eq!(a.sim_time, b.sim_time, "round {round}");
        }
    }

    #[test]
    fn no_merged_update_is_staler_than_the_bound() {
        for (bound, p, preset, frac) in [
            (0usize, 1.0, SpeedPreset::Stragglers, 0.3),
            (1, 0.5, SpeedPreset::Stragglers, 0.25),
            (2, 0.25, SpeedPreset::Lognormal { sigma: 0.8 }, 0.0),
            (4, 0.1, SpeedPreset::Stragglers, 0.5),
            (3, 1.0, SpeedPreset::Lognormal { sigma: 1.5 }, 0.0),
        ] {
            let sp = speeds(24, preset, frac, 13);
            let mut s = AsyncBounded::new(24, bound, p, &sp);
            for round in 0..80 {
                let plan = s.plan(round);
                for (&i, &st) in plan.participants.iter().zip(&plan.staleness) {
                    assert!(
                        st <= bound,
                        "bound {bound} p {p} round {round}: client {i} stale {st}"
                    );
                }
            }
        }
    }

    #[test]
    fn every_client_merges_at_least_every_bound_plus_one_rounds() {
        let sp = speeds(12, SpeedPreset::Stragglers, 0.4, 21);
        let bound = 2usize;
        let mut s = AsyncBounded::new(12, bound, 0.25, &sp);
        let mut last = vec![-1i64; 12];
        for round in 0..60 {
            for i in s.plan(round).participants {
                last[i] = round as i64;
            }
            for (i, &l) in last.iter().enumerate() {
                assert!(
                    round as i64 - l <= bound as i64,
                    "client {i} unmerged for more than {bound} rounds at round {round}"
                );
            }
        }
    }

    #[test]
    fn merge_sets_are_sorted_unique_nonempty_and_clock_monotone() {
        let sp = speeds(16, SpeedPreset::Stragglers, 0.9, 2);
        let mut s = AsyncBounded::new(16, 5, 0.05, &sp);
        let mut prev_t = 0.0f64;
        for round in 0..100 {
            let plan = s.plan(round);
            assert!(!plan.participants.is_empty(), "round {round}: empty merge set");
            assert!(
                plan.participants.windows(2).all(|w| w[0] < w[1]),
                "round {round}: not ascending-unique"
            );
            assert_eq!(plan.participants.len(), plan.staleness.len());
            assert!(plan.sim_time >= prev_t, "round {round}: clock went backwards");
            prev_t = plan.sim_time;
        }
    }

    #[test]
    fn async_plans_are_repeat_construction_deterministic() {
        let collect = |seed: u64| -> Vec<(Vec<usize>, Vec<usize>, u64)> {
            let sp = speeds(20, SpeedPreset::Lognormal { sigma: 0.7 }, 0.0, seed);
            let mut s = AsyncBounded::new(20, 3, 0.5, &sp);
            (0..40)
                .map(|r| {
                    let p = s.plan(r);
                    (p.participants, p.staleness, p.sim_time.to_bits())
                })
                .collect()
        };
        assert_eq!(collect(5), collect(5));
        assert_ne!(collect(5), collect(6), "seed must matter");
    }

    #[test]
    fn fast_clients_merge_more_often_than_stragglers() {
        let sp = speeds(50, SpeedPreset::Stragglers, 0.3, 17);
        let mut s = AsyncBounded::new(50, 4, 0.5, &sp);
        let mut merges = vec![0usize; 50];
        for round in 0..200 {
            for i in s.plan(round).participants {
                merges[i] += 1;
            }
        }
        let (mut fast_total, mut fast_n, mut slow_total, mut slow_n) = (0usize, 0usize, 0usize, 0usize);
        for i in 0..50 {
            if sp.round_duration(i) > 1.0 {
                slow_total += merges[i];
                slow_n += 1;
            } else {
                fast_total += merges[i];
                fast_n += 1;
            }
        }
        assert!(slow_n > 0 && fast_n > 0, "seed must mix fast and slow");
        let fast_rate = fast_total as f64 / fast_n as f64;
        let slow_rate = slow_total as f64 / slow_n as f64;
        assert!(
            fast_rate > slow_rate,
            "fast {fast_rate:.1} merges/client should exceed slow {slow_rate:.1}"
        );
        // ... but the bound still guarantees stragglers a floor
        assert!(
            merges.iter().all(|&m| m >= 200 / 5),
            "bound 4 => every client merges at least every 5th round"
        );
    }

    #[test]
    fn synchronous_schedulers_have_no_bound_to_move() {
        let mut sync = SyncAll::new(4);
        assert_eq!(sync.current_bound(), 0);
        assert!(!sync.set_bound(3, 0), "SyncAll has no runtime bound");
        let mut sampled = SampledSync::new(8, 0.5, 1);
        assert_eq!(sampled.current_bound(), 0);
        assert!(!sampled.set_bound(3, 5));
    }

    #[test]
    fn set_bound_to_the_current_bound_is_a_plan_level_no_op() {
        // re-applying the active bound between rounds (what the adaptive
        // driver does when the controller keeps its arm — and always,
        // with a singleton arm set) must leave the plan stream
        // bit-identical to an untouched scheduler
        let sp = speeds(20, SpeedPreset::Stragglers, 0.3, 11);
        let mut clean = AsyncBounded::new(20, 3, 0.5, &sp);
        let mut reset = AsyncBounded::new(20, 3, 0.5, &sp);
        for round in 0..50 {
            assert!(reset.set_bound(3, round), "AsyncBounded supports switching");
            let a = clean.plan(round);
            let b = reset.plan(round);
            assert_eq!(a.participants, b.participants, "round {round}");
            assert_eq!(a.staleness, b.staleness, "round {round}");
            assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "round {round}");
        }
    }

    #[test]
    fn set_bound_tighten_rebases_so_staleness_respects_the_new_bound() {
        let sp = speeds(24, SpeedPreset::Stragglers, 0.4, 5);
        let mut s = AsyncBounded::new(24, 6, 0.25, &sp);
        for round in 0..12 {
            s.plan(round);
        }
        // mid-run tighten 6 -> 1: the stale backlog re-pulls at the
        // switch, so from round 12 on nothing merges staler than 1
        assert!(s.set_bound(1, 12));
        assert_eq!(s.current_bound(), 1);
        let mut prev_t = 0.0f64;
        for round in 12..40 {
            let plan = s.plan(round);
            assert!(!plan.participants.is_empty(), "round {round}");
            for (&i, &st) in plan.participants.iter().zip(&plan.staleness) {
                assert!(st <= 1, "round {round}: client {i} stale {st} > tightened bound");
            }
            assert!(plan.sim_time >= prev_t, "round {round}: clock went backwards");
            prev_t = plan.sim_time;
        }
    }

    #[test]
    fn set_bound_loosen_lets_staleness_grow_only_to_the_new_bound() {
        let sp = speeds(16, SpeedPreset::Stragglers, 0.5, 9);
        let mut s = AsyncBounded::new(16, 0, 0.5, &sp);
        for round in 0..5 {
            let plan = s.plan(round);
            assert!(plan.staleness.iter().all(|&st| st == 0), "s=0 is all-fresh");
        }
        assert!(s.set_bound(4, 5));
        let mut saw_stale = false;
        for round in 5..60 {
            let plan = s.plan(round);
            for &st in &plan.staleness {
                assert!(st <= 4, "round {round}: stale {st} > loosened bound");
                saw_stale |= st > 0;
            }
        }
        assert!(saw_stale, "a loosened bound under stragglers must admit staleness");
    }

    #[test]
    fn optimized_merge_selection_matches_naive_reference() {
        // the bounded-heap fast path must reproduce the old materialize-
        // and-sort selection bit-for-bit, including under mid-stream bound
        // switches (the adaptive controller's adversarial case)
        for (n, bound, p, preset, frac, seed) in [
            (24usize, 0usize, 1.0, SpeedPreset::Stragglers, 0.3, 13u64),
            (24, 2, 0.25, SpeedPreset::Lognormal { sigma: 0.8 }, 0.0, 13),
            (16, 5, 0.05, SpeedPreset::Stragglers, 0.9, 2),
            (30, 6, 0.2, SpeedPreset::Lognormal { sigma: 0.6 }, 0.0, 9),
            (12, 1, 0.5, SpeedPreset::Uniform, 0.0, 7),
        ] {
            let sp = speeds(n, preset, frac, seed);
            let mut s = AsyncBounded::new(n, bound, p, &sp);
            for round in 0..80 {
                if round == 30 {
                    s.set_bound(bound + 3, round);
                }
                if round == 55 {
                    s.set_bound(bound, round);
                }
                let fast = s.compute(round);
                let naive = s.compute_naive(round);
                assert_eq!(fast.participants, naive.participants, "round {round} n {n}");
                assert_eq!(fast.staleness, naive.staleness, "round {round} n {n}");
                assert_eq!(
                    fast.sim_time.to_bits(),
                    naive.sim_time.to_bits(),
                    "round {round} n {n}"
                );
                s.plan(round);
            }
        }
    }

    #[test]
    fn floyd_sampling_is_sorted_unique_and_in_range_at_scale() {
        // the O(k) sampler's invariants at a fleet size where the old
        // permutation path would have allocated 100k-entry scratch
        let s = SampledSync::new(100_000, 0.005, 42);
        assert_eq!(s.sampled_per_round(), 500);
        for round in 0..5 {
            let ids = s.participants(round);
            assert_eq!(ids.len(), 500, "round {round}");
            assert!(ids.windows(2).all(|w| w[0] < w[1]), "round {round}: sorted+unique");
            assert!(*ids.last().unwrap() < 100_000);
            assert_eq!(ids, s.participants(round), "round {round}: peek-stable");
        }
        assert_ne!(s.participants(0), s.participants(1), "rounds draw fresh samples");
    }

    #[test]
    fn participation_caps_the_merge_set_unless_the_bound_overrides() {
        let sp = speeds(30, SpeedPreset::Lognormal { sigma: 0.6 }, 0.0, 9);
        let mut s = AsyncBounded::new(30, 6, 0.2, &sp); // cap = ceil(0.2*30) = 6
        let mut last = vec![-1i64; 30];
        for round in 0..60 {
            // recompute the required set externally: clients whose staleness
            // would exceed the bound if they sat this round out
            let required = (0..30).filter(|&i| round as i64 - last[i] > 6).count();
            let plan = s.plan(round);
            assert!(
                plan.participants.len() <= 6.max(required),
                "round {round}: |merge| {} > max(cap 6, required {required})",
                plan.participants.len()
            );
            for &i in &plan.participants {
                last[i] = round as i64;
            }
        }
        // s=0 forces everyone regardless of the cap
        let mut s0 = AsyncBounded::new(30, 0, 0.2, &sp);
        assert_eq!(s0.plan(0).participants.len(), 30);
    }
}
