//! Per-client model versioning for true delayed-gradient staleness
//! (`--delayed-gradients`, DESIGN.md §8).
//!
//! PR 3's `AsyncBounded` scheduler models staleness only at the
//! scheduling level: a client reported `s` rounds stale still trains
//! against the *current* server model, which is physically impossible on
//! a real asynchronous fleet — the client pulled its weights `s` rounds
//! ago and has not seen a broadcast since. This module closes that gap:
//!
//! * [`SnapshotRing`] keeps the last `staleness_bound + 1` round-start
//!   broadcast snapshots (the server-side state a participant downloads,
//!   [`Protocol::broadcast_state`](crate::driver::Protocol::broadcast_state)).
//!   Memory is O(bound) snapshots; under per-round sampling the ring
//!   follows the [`ClientStateStore`](crate::driver::ClientStateStore)
//!   residency discipline — only the newest snapshot stays resident, the
//!   rest spill to scratch through the same bit-exact codec as spilled
//!   client state.
//! * [`ModelVersion`] is the cheap shareable handle the driver threads
//!   into each stale participant's `ClientCtx`: the snapshot from round
//!   `r - s_i`, i.e. the model the client actually pulled.
//! * [`resolve_versions`] maps one round's staleness vector to handles,
//!   fetching each distinct version once (at most one disk read per
//!   spilled snapshot per round).
//!
//! Fresh participants (`s = 0`) get no handle and read the protocol's
//! live round-start state, so the default cadence-only mode and the
//! `s = 0` degenerate case stay bit-identical to the unversioned driver.

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::driver::store::{read_snapshot, write_snapshot};
use crate::runtime::TensorStore;

/// The server broadcast state one client actually pulled: a shared
/// handle to the round-`round` snapshot.
#[derive(Clone)]
pub struct ModelVersion {
    round: usize,
    state: Arc<TensorStore>,
}

impl ModelVersion {
    /// The round whose start this snapshot captures (`r - s_i` for a
    /// participant merging at round `r` with staleness `s_i`).
    pub fn round(&self) -> usize {
        self.round
    }

    /// The snapshotted broadcast state (read-only; shared across the
    /// round's workers).
    pub fn state(&self) -> &TensorStore {
        &self.state
    }
}

enum Snap {
    Resident(Arc<TensorStore>),
    Spilled(PathBuf),
}

/// Ring of round-start broadcast snapshots, bounded by the staleness
/// window: after `push(r, ..)` the ring holds rounds
/// `r - capacity + 1 ..= r`, exactly the versions a round-`r` merge can
/// reference (`s <= bound`, capacity = bound + 1).
pub struct SnapshotRing {
    capacity: usize,
    entries: VecDeque<(usize, Snap)>,
    spill_dir: Option<PathBuf>,
}

impl SnapshotRing {
    /// All-resident ring (full-participation runs keep O(bound)
    /// snapshots in memory, mirroring the client-state store).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            entries: VecDeque::new(),
            spill_dir: None,
        }
    }

    /// Ring that keeps only the newest snapshot resident and spills the
    /// older window to scratch files under `dir` (created here, removed
    /// on drop) — the residency discipline of a sampled run.
    pub fn with_spill(capacity: usize, dir: PathBuf) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating snapshot spill dir {dir:?}"))?;
        Ok(Self {
            capacity: capacity.max(1),
            entries: VecDeque::new(),
            spill_dir: Some(dir),
        })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Snapshots currently resident in memory (introspection / tests).
    pub fn resident_count(&self) -> usize {
        self.entries
            .iter()
            .filter(|(_, s)| matches!(s, Snap::Resident(_)))
            .count()
    }

    /// Record round `round`'s broadcast snapshot and evict everything
    /// that has rotated out of the staleness window. Rounds must be
    /// pushed in ascending order (the driver's round loop).
    pub fn push(&mut self, round: usize, state: TensorStore) -> Result<()> {
        if let Some((last, _)) = self.entries.back() {
            anyhow::ensure!(
                *last < round,
                "snapshot ring: round {round} pushed after round {last}"
            );
        }
        // under spilling only the newest snapshot is resident: write the
        // previous head out before the new one takes its place
        if let Some(dir) = self.spill_dir.clone() {
            if let Some((r, snap)) = self.entries.back_mut() {
                if let Snap::Resident(state) = snap {
                    let path = dir.join(format!("snapshot_{r}.bin"));
                    write_snapshot(&path, state)
                        .with_context(|| format!("spilling snapshot for round {r}"))?;
                    *snap = Snap::Spilled(path);
                }
            }
        }
        self.entries.push_back((round, Snap::Resident(Arc::new(state))));
        while self.entries.len() > self.capacity {
            if let Some((_, Snap::Spilled(path))) = self.entries.pop_front() {
                std::fs::remove_file(path).ok();
            }
        }
        Ok(())
    }

    /// The snapshot captured at the start of `round`. Spilled snapshots
    /// are read transiently (the file stays authoritative), so a `get`
    /// never grows the resident set past the newest snapshot.
    pub fn get(&self, round: usize) -> Result<ModelVersion> {
        let Some((_, snap)) = self.entries.iter().find(|(r, _)| *r == round) else {
            bail!(
                "snapshot ring: round {round} outside the retained window \
                 ({:?}..={:?})",
                self.entries.front().map(|(r, _)| *r),
                self.entries.back().map(|(r, _)| *r),
            );
        };
        let state = match snap {
            Snap::Resident(state) => Arc::clone(state),
            Snap::Spilled(path) => Arc::new(
                read_snapshot(path)
                    .with_context(|| format!("reloading snapshot for round {round}"))?,
            ),
        };
        Ok(ModelVersion { round, state })
    }
}

impl Drop for SnapshotRing {
    fn drop(&mut self) {
        if let Some(dir) = &self.spill_dir {
            std::fs::remove_dir_all(dir).ok();
        }
    }
}

/// One round's per-participant pulled versions, parallel to `staleness`:
/// a participant with staleness `s > 0` gets the round-`round - s`
/// snapshot handle, fresh participants get `None` (read the live state).
/// An empty ring (the protocol broadcasts no server state — see
/// [`Protocol::broadcast_state`](crate::driver::Protocol::broadcast_state))
/// resolves everyone to `None`: staleness stays cadence-only there.
pub fn resolve_versions(
    ring: &SnapshotRing,
    round: usize,
    staleness: &[usize],
) -> Result<Vec<Option<ModelVersion>>> {
    if ring.is_empty() {
        return Ok(vec![None; staleness.len()]);
    }
    let mut cache: BTreeMap<usize, ModelVersion> = BTreeMap::new();
    staleness
        .iter()
        .map(|&s| {
            if s == 0 {
                return Ok(None);
            }
            let r = round.checked_sub(s).ok_or_else(|| {
                anyhow::anyhow!("staleness {s} exceeds round index {round}")
            })?;
            if let Some(v) = cache.get(&r) {
                return Ok(Some(v.clone()));
            }
            let v = ring.get(r)?;
            cache.insert(r, v.clone());
            Ok(Some(v))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::store::scratch_dir;
    use crate::runtime::Tensor;

    /// A snapshot whose contents identify the round it was taken at.
    fn snap(round: usize) -> TensorStore {
        let mut s = TensorStore::new();
        s.insert("pg.w", Tensor::full(&[3], round as f32));
        s
    }

    fn snap_round(v: &ModelVersion) -> f32 {
        v.state().get("pg.w").unwrap().data()[0]
    }

    #[test]
    fn ring_retains_exactly_the_staleness_window() {
        let mut ring = SnapshotRing::new(3); // bound 2
        for r in 0..6 {
            ring.push(r, snap(r)).unwrap();
        }
        assert_eq!(ring.len(), 3);
        for r in 3..6 {
            let v = ring.get(r).unwrap();
            assert_eq!(v.round(), r);
            assert_eq!(snap_round(&v), r as f32);
        }
        assert!(ring.get(2).is_err(), "rotated out of the window");
        assert!(ring.push(5, snap(5)).is_err(), "rounds must ascend");
    }

    #[test]
    fn spilling_ring_keeps_one_resident_and_roundtrips_bit_exact() {
        let dir = scratch_dir(46);
        let mut ring = SnapshotRing::with_spill(4, dir.clone()).unwrap();
        let odd = |r: usize| {
            let mut s = TensorStore::new();
            s.insert(
                "pg.w",
                Tensor::new(vec![3], vec![r as f32, -0.0, f32::MIN_POSITIVE / 2.0]).unwrap(),
            );
            s
        };
        for r in 0..4 {
            ring.push(r, odd(r)).unwrap();
        }
        assert_eq!(ring.resident_count(), 1, "only the newest stays resident");
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 3);
        for r in 0..4 {
            let v = ring.get(r).unwrap();
            let bits: Vec<u32> =
                v.state().get("pg.w").unwrap().data().iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> =
                odd(r).get("pg.w").unwrap().data().iter().map(|x| x.to_bits()).collect();
            assert_eq!(bits, want, "round {r} round-trips bit-exact");
        }
        // transient reads never consumed the files or grew residency
        assert_eq!(ring.resident_count(), 1);
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 3);
        // eviction removes the rotated-out file
        ring.push(4, odd(4)).unwrap();
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 3, "round 0 file removed");
        assert!(ring.get(0).is_err());
        drop(ring);
        assert!(!dir.exists(), "spill dir removed on drop");
    }

    #[test]
    fn resolve_hands_round_minus_s_weights_and_leaves_fresh_clients_live() {
        let mut ring = SnapshotRing::new(5); // bound 4
        for r in 0..=5 {
            ring.push(r, snap(r)).unwrap();
        }
        // round 5, participants with staleness [0, 2, 4, 2]
        let versions = resolve_versions(&ring, 5, &[0, 2, 4, 2]).unwrap();
        assert!(versions[0].is_none(), "fresh client reads the live state");
        let v1 = versions[1].as_ref().unwrap();
        assert_eq!(v1.round(), 3, "s=2 at round 5 pulled round 3");
        assert_eq!(snap_round(v1), 3.0);
        let v2 = versions[2].as_ref().unwrap();
        assert_eq!(v2.round(), 1);
        assert_eq!(snap_round(v2), 1.0);
        // equal staleness shares one fetched handle
        let v3 = versions[3].as_ref().unwrap();
        assert!(Arc::ptr_eq(&v1.state, &v3.state), "distinct versions fetched once");
        // a staleness outside the retained window is an invariant violation
        assert!(resolve_versions(&ring, 5, &[5]).is_err());
    }

    #[test]
    fn empty_ring_resolves_everyone_to_cadence_only() {
        let ring = SnapshotRing::new(3);
        let versions = resolve_versions(&ring, 7, &[0, 2, 3]).unwrap();
        assert!(versions.iter().all(|v| v.is_none()));
    }
}
