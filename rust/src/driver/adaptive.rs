//! Adaptive staleness-bound controller: online UCB1 over a candidate set
//! of bounds, rewarded by the C3-shaped trade-off each window achieved.
//!
//! The async scheduler (DESIGN.md §7) takes the staleness bound `s` as a
//! fixed knob, so the accuracy-vs-sim-time frontier had to be found by
//! offline grid search (`sweep_tradeoffs`'s staleness axis). This module
//! turns that axis into the system's first online control loop: the
//! driver runs the configured protocol in windows of `adapt_window`
//! rounds, evaluates at every window boundary, and hands the controller
//! the window's deltas — accuracy gained, simulated wall-clock spent,
//! budget-normalized bandwidth/compute consumed. The controller treats
//! each candidate bound as a bandit arm (UCB1), shapes the deltas into a
//! bounded reward (accuracy gain *per unit simulated time*, decayed by
//! the window's C3 cost factor — eq. 9's resource half), and switches
//! [`super::AsyncBounded`] to the chosen arm at the next window boundary
//! via [`super::Scheduler::set_bound`].
//!
//! ## Determinism contract (DESIGN.md §9)
//!
//! Every controller decision is a pure function of (experiment seed,
//! reward stream): the arm set is a sorted clip of the candidate list,
//! the initial exploration order is a seeded permutation, selection
//! breaks ties by lowest arm index, and rewards derive from run metrics
//! that are themselves thread-count invariant. Same seed ⇒ identical arm
//! sequence across repeat invocations and worker counts (pinned by the
//! `adaptive_*` suite in `tests/engine_determinism.rs`). Switches land
//! only on window boundaries, so within a window the schedule is exactly
//! a fixed-bound schedule — and a singleton candidate set degenerates to
//! the fixed-bound run: identical training and schedule always, and
//! bit-identical recorded metrics whenever the `eval_every` cadence
//! already covers the window boundaries (the default `eval_every = 1`
//! trivially does; a sparser cadence only gains extra, value-neutral
//! eval points at the boundaries).

use crate::config::ExperimentConfig;
use crate::data::Rng;
use crate::metrics::{cost_decay, Budgets};

/// Default candidate bounds, clipped element-wise to the configured
/// `staleness_bound` (so the controller never schedules staler than the
/// snapshot ring retains) and deduplicated.
pub const DEFAULT_BOUND_ARMS: [usize; 5] = [0, 1, 2, 4, 8];

/// Floor on a window's simulated duration when normalizing the reward —
/// a zero-length window (degenerate, but reachable with an adversarial
/// speed model) must not divide the accuracy delta by zero.
const MIN_WINDOW_SIM_TIME: f64 = 1e-9;

/// Gain applied to the accuracy-per-sim-time rate before squashing:
/// realistic per-window rates are small (a few accuracy points over a
/// handful of baseline-round units), so without it every arm's reward
/// would collapse onto tanh's flat origin and the exploitation term
/// could never separate the arms within a practical horizon.
const RATE_SCALE: f64 = 25.0;

/// One window's observed deltas (window end minus window start), the
/// controller's entire view of the run.
#[derive(Clone, Copy, Debug)]
pub struct WindowDelta {
    /// accuracy change over the window, in percentage points (may be
    /// negative — a regressing window is a below-neutral reward)
    pub d_accuracy_pct: f64,
    /// simulated wall-clock the window consumed, in baseline-round units
    pub d_sim_time: f64,
    /// link-time-weighted bandwidth the window consumed, in GB
    pub d_bandwidth_gb: f64,
    /// client compute the window consumed, in TFLOPs
    pub d_client_tflops: f64,
}

/// Seeded UCB1 controller over candidate staleness bounds.
#[derive(Clone, Debug)]
pub struct BoundController {
    /// sorted, unique candidate bounds (the arm set)
    arms: Vec<usize>,
    /// rounds per adaptation window
    window: usize,
    /// budgets shaping the reward's cost-decay factor
    budgets: Budgets,
    /// windows observed per arm
    counts: Vec<u64>,
    /// summed rewards per arm
    sums: Vec<f64>,
    /// total windows observed (the t of UCB1)
    t: u64,
    /// index (into `arms`) of the arm currently applied
    current: usize,
    /// seeded order in which unplayed arms are explored first
    explore_order: Vec<usize>,
    /// arm changes made so far
    switches: usize,
}

impl BoundController {
    /// Controller over an explicit candidate set. `arms` must be
    /// non-empty and `window > 0` (config validation enforces both on
    /// the user-facing path).
    pub fn with_arms(mut arms: Vec<usize>, window: usize, seed: u64, budgets: Budgets) -> Self {
        assert!(!arms.is_empty(), "bound controller needs at least one arm");
        assert!(window > 0, "adapt window must be at least one round");
        arms.sort_unstable();
        arms.dedup();
        let mut rng = Rng::new(seed).derive("bound-controller", 0);
        let explore_order = rng.permutation(arms.len());
        let current = explore_order[0];
        Self {
            counts: vec![0; arms.len()],
            sums: vec![0.0; arms.len()],
            t: 0,
            current,
            explore_order,
            switches: 0,
            arms,
            window,
            budgets,
        }
    }

    /// Controller over `candidates` (default [`DEFAULT_BOUND_ARMS`])
    /// clipped element-wise to `max_bound` and deduplicated — e.g.
    /// `max_bound = 3` gives arms `{0, 1, 2, 3}`.
    pub fn new(max_bound: usize, window: usize, seed: u64, budgets: Budgets) -> Self {
        let arms = DEFAULT_BOUND_ARMS.iter().map(|&c| c.min(max_bound)).collect();
        Self::with_arms(arms, window, seed, budgets)
    }

    /// Controller configured by the experiment: arms from `adapt_arms`
    /// (default candidates otherwise) clipped to `staleness_bound`.
    pub fn from_cfg(cfg: &ExperimentConfig) -> Self {
        let max_bound = cfg.staleness_bound.unwrap_or(0);
        match &cfg.adapt_arms {
            Some(list) => {
                let arms = list.iter().map(|&c| c.min(max_bound)).collect();
                Self::with_arms(arms, cfg.adapt_window, cfg.seed, cfg.budgets)
            }
            None => Self::new(max_bound, cfg.adapt_window, cfg.seed, cfg.budgets),
        }
    }

    /// The sorted, unique arm set.
    pub fn arms(&self) -> &[usize] {
        &self.arms
    }

    /// Rounds per adaptation window.
    pub fn window(&self) -> usize {
        self.window
    }

    /// The staleness bound currently applied.
    pub fn current_bound(&self) -> usize {
        self.arms[self.current]
    }

    /// Arm changes made so far.
    pub fn switches(&self) -> usize {
        self.switches
    }

    /// Windows observed so far.
    pub fn windows_observed(&self) -> u64 {
        self.t
    }

    /// The C3-shaped reward in [0, 1] for one window's deltas: the
    /// accuracy gained per unit simulated time (squashed through tanh,
    /// so `0.5` is the no-change neutral point and regressions land
    /// below it), decayed by the window's budget-normalized resource
    /// spend — the resource half of eq. 9 via [`cost_decay`], which
    /// treats degenerate zero budgets as saturated axes instead of
    /// poisoning the reward with NaN.
    pub fn shaped_reward(&self, d: &WindowDelta) -> f64 {
        let decay = cost_decay(
            d.d_bandwidth_gb.max(0.0),
            d.d_client_tflops.max(0.0),
            &self.budgets,
        );
        let rate = (d.d_accuracy_pct / 100.0) / d.d_sim_time.max(MIN_WINDOW_SIM_TIME);
        let gain = 0.5 * (1.0 + (rate * RATE_SCALE).tanh());
        (gain * decay).clamp(0.0, 1.0)
    }

    /// Credit the just-finished window to the current arm and pick the
    /// arm for the next window. Returns the next window's staleness
    /// bound (the caller applies it via `Scheduler::set_bound` at the
    /// window boundary — switches never land mid-window) together with
    /// the reward actually credited, so callers log the controller's
    /// real decision input instead of recomputing it.
    pub fn observe_window(&mut self, delta: &WindowDelta) -> (usize, f64) {
        let reward = self.shaped_reward(delta);
        self.counts[self.current] += 1;
        self.sums[self.current] += reward;
        self.t += 1;
        let next = self.select();
        if next != self.current {
            self.switches += 1;
            self.current = next;
        }
        (self.arms[self.current], reward)
    }

    /// UCB1 arm selection: unplayed arms first (in the seeded
    /// exploration order), then argmax of `mean + sqrt(2 ln t / n)`
    /// with a deterministic lowest-index tie-break.
    fn select(&self) -> usize {
        for &i in &self.explore_order {
            if self.counts[i] == 0 {
                return i;
            }
        }
        let ln_t = (self.t.max(1) as f64).ln();
        let mut best = 0;
        let mut best_score = f64::NEG_INFINITY;
        for i in 0..self.arms.len() {
            let n = self.counts[i] as f64;
            let score = self.sums[i] / n + (2.0 * ln_t / n).sqrt();
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn budgets() -> Budgets {
        Budgets::new(10.0, 10.0)
    }

    fn delta(d_acc: f64, d_sim: f64) -> WindowDelta {
        WindowDelta {
            d_accuracy_pct: d_acc,
            d_sim_time: d_sim,
            d_bandwidth_gb: 1.0,
            d_client_tflops: 1.0,
        }
    }

    #[test]
    fn arm_set_is_the_clipped_deduped_candidate_list() {
        assert_eq!(BoundController::new(8, 5, 0, budgets()).arms(), &[0, 1, 2, 4, 8]);
        assert_eq!(BoundController::new(4, 5, 0, budgets()).arms(), &[0, 1, 2, 4]);
        assert_eq!(BoundController::new(3, 5, 0, budgets()).arms(), &[0, 1, 2, 3]);
        assert_eq!(BoundController::new(0, 5, 0, budgets()).arms(), &[0]);
        let c = BoundController::with_arms(vec![7, 2, 2, 0], 3, 1, budgets());
        assert_eq!(c.arms(), &[0, 2, 7], "sorted + deduped");
    }

    #[test]
    fn from_cfg_clips_explicit_arms_to_the_bound() {
        let cfg = ExperimentConfig {
            staleness_bound: Some(3),
            ..ExperimentConfig::default()
        };
        assert_eq!(BoundController::from_cfg(&cfg).arms(), &[0, 1, 2, 3]);
        let cfg = ExperimentConfig { adapt_arms: Some(vec![1, 5]), ..cfg };
        assert_eq!(BoundController::from_cfg(&cfg).arms(), &[1, 3], "5 clips to 3");
        let cfg = ExperimentConfig { adapt_arms: Some(vec![2]), ..cfg };
        let c = BoundController::from_cfg(&cfg);
        assert_eq!(c.arms(), &[2], "singleton candidate set");
        assert_eq!(c.current_bound(), 2);
    }

    #[test]
    fn singleton_arm_never_switches() {
        let mut c = BoundController::with_arms(vec![2], 4, 9, budgets());
        for w in 0..50 {
            let (next, reward) = c.observe_window(&delta((w % 3) as f64 - 1.0, 4.0));
            assert_eq!(next, 2);
            assert!((0.0..=1.0).contains(&reward));
        }
        assert_eq!(c.switches(), 0);
        assert_eq!(c.windows_observed(), 50);
    }

    #[test]
    fn reward_is_bounded_neutral_at_no_change_and_ordered() {
        let c = BoundController::new(4, 5, 0, budgets());
        // no accuracy change, no cost: exactly the neutral 0.5
        let neutral = c.shaped_reward(&WindowDelta {
            d_accuracy_pct: 0.0,
            d_sim_time: 5.0,
            d_bandwidth_gb: 0.0,
            d_client_tflops: 0.0,
        });
        assert!((neutral - 0.5).abs() < 1e-12);
        // gains beat stalls beat regressions; everything stays in [0,1]
        let up = c.shaped_reward(&delta(3.0, 5.0));
        let flat = c.shaped_reward(&delta(0.0, 5.0));
        let down = c.shaped_reward(&delta(-3.0, 5.0));
        assert!(up > flat && flat > down, "{up} > {flat} > {down}");
        for r in [up, flat, down] {
            assert!((0.0..=1.0).contains(&r));
        }
        // the same gain achieved in less simulated time is worth more
        assert!(c.shaped_reward(&delta(3.0, 2.0)) > c.shaped_reward(&delta(3.0, 10.0)));
        // heavier resource spend decays the reward
        let mut cheap = delta(3.0, 5.0);
        cheap.d_bandwidth_gb = 0.1;
        assert!(c.shaped_reward(&cheap) > c.shaped_reward(&delta(3.0, 5.0)));
    }

    #[test]
    fn reward_survives_degenerate_windows_and_budgets() {
        // zero-length window, zero budgets, negative meter deltas
        // (defensive): the reward must stay finite and in [0,1]
        let c = BoundController::new(2, 1, 0, Budgets::new(0.0, 0.0));
        let r = c.shaped_reward(&WindowDelta {
            d_accuracy_pct: 50.0,
            d_sim_time: 0.0,
            d_bandwidth_gb: -1.0,
            d_client_tflops: 0.0,
        });
        assert!(r.is_finite() && (0.0..=1.0).contains(&r), "{r}");
    }

    #[test]
    fn every_arm_is_explored_once_before_any_repeat() {
        let mut c = BoundController::new(8, 5, 13, budgets());
        let mut seen = std::collections::BTreeSet::new();
        seen.insert(c.current_bound());
        for _ in 0..c.arms().len() - 1 {
            seen.insert(c.observe_window(&delta(1.0, 5.0)).0);
        }
        assert_eq!(seen.len(), c.arms().len(), "each of the 5 arms played once");
    }

    #[test]
    fn controller_is_seed_deterministic() {
        let run = |seed: u64| -> Vec<usize> {
            let mut c = BoundController::new(8, 5, seed, budgets());
            let mut bounds = vec![c.current_bound()];
            for w in 0..30u64 {
                // synthetic but arm-sensitive reward stream: higher
                // bounds "finish" the window in less simulated time
                let d_sim = 10.0 / (1.0 + c.current_bound() as f64);
                bounds.push(c.observe_window(&delta(0.5 + (w % 4) as f64 * 0.1, d_sim)).0);
            }
            bounds
        };
        assert_eq!(run(7), run(7), "same seed, same arm sequence");
        // the seed only permutes initial exploration; across a spread of
        // seeds at least two sequences must differ (all-equal would mean
        // the seeding is dead)
        let first = run(0);
        assert!(
            (1..64).any(|s| run(s) != first),
            "64 seeds produced one identical arm sequence"
        );
    }

    #[test]
    fn exploitation_converges_to_the_clearly_best_arm() {
        // the reward gap must be wide for UCB1 to exploit within a short
        // horizon (suboptimal arms are revisited ~2 ln t / gap² times):
        // arm 4 posts near-maximal windows, every other arm regresses
        let mut c = BoundController::new(4, 5, 3, budgets());
        let observe = |c: &mut BoundController| {
            let good = c.current_bound() == 4;
            let d = WindowDelta {
                d_accuracy_pct: if good { 40.0 } else { -40.0 },
                d_sim_time: 2.0,
                d_bandwidth_gb: 0.0,
                d_client_tflops: 0.0,
            };
            c.observe_window(&d);
        };
        for _ in 0..400 {
            observe(&mut c);
        }
        // count the trailing choices: the best arm must dominate late play
        let mut tail = 0;
        for _ in 0..20 {
            if c.current_bound() == 4 {
                tail += 1;
            }
            observe(&mut c);
        }
        assert!(tail >= 15, "best arm chosen {tail}/20 late windows");
    }
}
