//! The generic round driver: one loop to run them all.
//!
//! Pre-redesign, every protocol was a closed `run(&mut env)` monolith that
//! hard-coded the synchronous all-clients-every-round loop. This module
//! inverts that: a protocol now only describes *what a client does in a
//! round* ([`Protocol::client_round`]) and *how the server folds the
//! results in* ([`Protocol::merge_round`]), while [`run`] owns the round
//! loop, per-round participant selection ([`Scheduler`]), the engine
//! fan-out, cost-meter merging, and round recording. Scheduling features
//! (client sampling today; async/staleness and heterogeneous client
//! speeds next, see ROADMAP) land here once instead of seven times.
//!
//! ## Determinism contract (DESIGN.md §5–§6)
//!
//! The driver preserves the engine's bit-identity guarantee:
//!
//! * participants are chosen on the driver thread (pure function of seed
//!   and round);
//! * `client_round` closures run on the worker pool and may touch only
//!   their own [`ClientState`] plus read-only shared state;
//! * per-client [`CostMeter`] deltas and protocol updates merge on the
//!   driver thread in ascending client-id order;
//! * `merge_round` / `end_round` run sequentially on the driver thread.
//!
//! A protocol whose training exchange is inherently sequential (SL-basic,
//! SplitFed: one shared server model updated per batch) sets
//! [`Protocol::fan_out`] to `false` and runs the exchange inside
//! `merge_round` — the loop shape is still owned here.

mod scheduler;
mod store;

pub use scheduler::{scheduler_for, SampledSync, Scheduler, SyncAll};
pub use store::{scratch_dir, ClientState, ClientStateStore};

use anyhow::{bail, Result};

use crate::metrics::{CostMeter, RoundStat};
use crate::protocols::{Env, RunResult};

/// Read-only context handed to one client's round work on a worker.
pub struct ClientCtx<'e, 'a> {
    pub env: &'e Env<'a>,
    pub round: usize,
    /// Exchange step within the round (`0..Protocol::steps(round)`).
    pub step: usize,
    /// The client id this closure is running for.
    pub client: usize,
}

/// What one client hands back from a round step: the protocol-specific
/// payload plus the client-side cost delta the driver merges in id order.
pub struct ClientUpdate<U> {
    pub meter: CostMeter,
    pub inner: U,
}

impl<U> ClientUpdate<U> {
    pub fn new(inner: U) -> Self {
        Self { meter: CostMeter::new(), inner }
    }
}

/// What a round reports into the run recorder.
pub struct RoundReport {
    /// `train`, or AdaSplit's `local` / `global`.
    pub phase: String,
    pub train_loss: f64,
    /// Mean server-mask density (AdaSplit; 1.0 otherwise).
    pub mask_density: f64,
    /// Clients that did server-side work this round (UCB picks for
    /// AdaSplit; the participant set otherwise).
    pub selected: Vec<usize>,
}

/// A distributed-training protocol, decomposed into the client-step /
/// server-merge API the [`run`] driver schedules.
///
/// Call order per run: `init_state` once, then per round:
/// `begin_round` -> (`client_round`* -> `merge_round`) x `steps` ->
/// `end_round` -> `eval` (on eval rounds). `steps(round)` is consulted
/// after `begin_round`, so a protocol may size its exchange count from
/// the round's participants (AdaSplit: max batch count).
pub trait Protocol: Sync {
    /// Per-client payload type carried from `client_round` to `merge_round`.
    type Update: Send;

    fn name(&self) -> &'static str;

    /// One-time server-side state initialization.
    fn init_state(&mut self, env: &mut Env) -> Result<()>;

    /// Build one client's initial state — must be a pure function of the
    /// experiment seed and `client`, because the pooled store calls it
    /// lazily on the client's *first participation* (which depends on the
    /// scheduler) and first-touch timing must not change values.
    fn init_client(&self, env: &Env, client: usize) -> Result<ClientState>;

    /// Number of client-step/server-merge exchanges in `round`. Valid
    /// after `begin_round(round)`.
    fn steps(&self, round: usize) -> usize {
        let _ = round;
        1
    }

    /// Whether `client_round` fans out over the engine pool. Protocols
    /// whose exchange is an inherent chain return `false` and do the
    /// whole step inside `merge_round`.
    fn fan_out(&self) -> bool {
        true
    }

    /// Per-round setup on the driver thread (round-start snapshots, batch
    /// materialization, scratch resets).
    fn begin_round(&mut self, env: &mut Env, round: usize, participants: &[usize]) -> Result<()> {
        let _ = (env, round, participants);
        Ok(())
    }

    /// One participant's work for step `ctx.step`: runs on a worker, may
    /// mutate only `state`, reads shared state through `&self`/`ctx.env`.
    fn client_round(
        &self,
        ctx: &ClientCtx<'_, '_>,
        state: &mut ClientState,
    ) -> Result<ClientUpdate<Self::Update>> {
        let _ = (ctx, state);
        bail!("{} has no parallel client phase", self.name())
    }

    /// Fold the step's client updates (ascending client-id order) into
    /// server state on the driver thread. Server-side costs are metered
    /// here via `env.meter`.
    fn merge_round(
        &mut self,
        env: &mut Env,
        store: &mut ClientStateStore,
        round: usize,
        step: usize,
        participants: &[usize],
        updates: Vec<(usize, Self::Update)>,
    ) -> Result<()>;

    /// Round-boundary server work (aggregation, broadcasts); reports the
    /// round's stats.
    fn end_round(
        &mut self,
        env: &mut Env,
        store: &mut ClientStateStore,
        round: usize,
        participants: &[usize],
    ) -> Result<RoundReport>;

    /// Mean per-client test accuracy (%) under the current state.
    fn eval(&self, env: &Env, store: &mut ClientStateStore) -> Result<f64>;
}

/// Run `protocol` end to end under the configured scheduler and return
/// its result. This is the only round loop in the codebase.
pub fn run<P: Protocol>(env: &mut Env, protocol: &mut P) -> Result<RunResult> {
    protocol.init_state(env)?;

    let mut scheduler = scheduler_for(env.cfg);
    // Spilling is active only under real subsampling: a full-participation
    // run keeps every client resident and never touches the disk.
    let mut store = if env.cfg.participation < 1.0 {
        ClientStateStore::with_spill(env.cfg.clients, scratch_dir(env.cfg.seed))?
    } else {
        ClientStateStore::new(env.cfg.clients)
    };
    let pool = env.pool();

    for round in 0..env.cfg.rounds {
        let participants = scheduler.participants(round);
        // evict last round's inactive clients first, then materialize the
        // round's sample: peak residency ~ |old ∪ new|, not total clients
        store.spill_except(&participants)?;
        store.ensure_loaded(&participants, |i| protocol.init_client(env, i))?;

        protocol.begin_round(env, round, &participants)?;
        let steps = protocol.steps(round);
        for step in 0..steps {
            let updates: Vec<(usize, P::Update)> = if protocol.fan_out() {
                let raw = {
                    let p: &P = protocol;
                    let env_ref: &Env = env;
                    let mut states = store.loaded_mut(&participants)?;
                    pool.run_mut(&mut states, |j, state| {
                        let ctx = ClientCtx {
                            env: env_ref,
                            round,
                            step,
                            client: participants[j],
                        };
                        p.client_round(&ctx, state)
                    })?
                };
                // fan-in on the driver thread, ascending client-id order
                let mut merged = Vec::with_capacity(raw.len());
                for (j, u) in raw.into_iter().enumerate() {
                    env.meter.merge(&u.meter);
                    merged.push((participants[j], u.inner));
                }
                merged
            } else {
                Vec::new()
            };
            protocol.merge_round(env, &mut store, round, step, &participants, updates)?;
        }
        let report = protocol.end_round(env, &mut store, round, &participants)?;

        let eval_now = round % env.cfg.eval_every == 0 || round + 1 == env.cfg.rounds;
        let accuracy = if eval_now {
            protocol.eval(env, &mut store)?
        } else {
            env.recorder.last_accuracy()
        };

        env.recorder.push(RoundStat {
            round,
            phase: report.phase,
            train_loss: report.train_loss,
            accuracy_pct: accuracy,
            bandwidth_gb: env.meter.bandwidth_gb(),
            client_tflops: env.meter.client_tflops(),
            total_tflops: env.meter.total_tflops(),
            mask_density: report.mask_density,
            selected: report.selected,
            participants,
        });
    }

    Ok(RunResult::from_env(env, &env.recorder, &env.meter))
}
