//! The generic round driver: one loop to run them all.
//!
//! Pre-redesign, every protocol was a closed `run(&mut env)` monolith that
//! hard-coded the synchronous all-clients-every-round loop. This module
//! inverts that: a protocol now only describes *what a client does in a
//! round* ([`Protocol::client_round`]) and *how the server folds the
//! results in* ([`Protocol::merge_round`]), while [`run`] owns the round
//! loop, per-round participant selection ([`Scheduler`]), the engine
//! fan-out, cost-meter merging, and round recording. Scheduling features
//! (client sampling, bounded-staleness async rounds with heterogeneous
//! client speeds) land here once instead of seven times.
//!
//! ## Determinism contract (DESIGN.md §5–§7)
//!
//! The driver preserves the engine's bit-identity guarantee:
//!
//! * the round plan (participants, staleness, virtual clock) is computed
//!   on the driver thread (pure function of seed and round);
//! * `client_round` closures run on the worker pool and may touch only
//!   their own [`ClientState`] plus read-only shared state;
//! * per-client [`CostMeter`] deltas (scaled by the client's
//!   [`ClientSpeeds`] rates under a heterogeneous speed model) combine on
//!   the driver thread through a balanced tree over the id-ordered
//!   participant list ([`crate::engine::tree_reduce`], DESIGN.md §10),
//!   and protocol updates merge in ascending client-id order;
//! * `merge_round` / `end_round` run sequentially on the driver thread,
//!   under the round's published staleness-decay multipliers (DESIGN.md
//!   §7) when the async scheduler reports stale contributions;
//! * under `--delayed-gradients`, per-participant [`ModelVersion`]
//!   handles are resolved on the driver thread from the [`SnapshotRing`]
//!   of round-start broadcast snapshots and shared read-only with the
//!   workers, so a stale client trains against the model it actually
//!   pulled without perturbing thread-count invariance (DESIGN.md §8).
//!
//! A protocol whose training exchange is inherently sequential (SL-basic,
//! SplitFed: one shared server model updated per batch) sets
//! [`Protocol::fan_out`] to `false` and runs the exchange inside
//! `merge_round` — the loop shape is still owned here.

mod adaptive;
mod scheduler;
mod speed;
mod store;
mod versioning;

pub use adaptive::{BoundController, WindowDelta, DEFAULT_BOUND_ARMS};
pub use scheduler::{scheduler_for, AsyncBounded, RoundPlan, SampledSync, Scheduler, SyncAll};
pub use speed::{diurnal_multiplier, ClientSpeeds, SpeedPreset, STRAGGLER_SLOWDOWN};
pub use store::{scratch_dir, ClientState, ClientStateStore};
pub use versioning::{resolve_versions, ModelVersion, SnapshotRing};

use std::cell::RefCell;
use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::metrics::{CostMeter, RoundStat};
use crate::protocols::{Env, RunResult};
use crate::runtime::TensorStore;

// ---- staleness-decay context ----------------------------------------------
//
// Aggregation weights live inside the protocols (data-size weights,
// FedNova taus), but *how much a stale contribution counts* is scheduler
// policy. To keep the seven protocol files scheduler-agnostic (DESIGN.md
// §6–§7), the driver publishes the round's per-participant decay
// multipliers here before running the merge, and
// `protocols::common::round_weights` folds them in. Merges run
// sequentially on the driver thread, so a thread-local is deterministic:
// the scope is set and cleared around `merge_round`/`end_round` of one
// round, on one thread.

thread_local! {
    static STALE_DECAY: RefCell<Option<BTreeMap<usize, f32>>> = const { RefCell::new(None) };
}

/// Scoped publication of one round's staleness-decay multipliers; the
/// context clears when the scope drops (including on early `?` returns).
pub(crate) struct DecayScope {
    _private: (),
}

impl DecayScope {
    /// Publish `decay^staleness[j]` for each participant. The driver only
    /// opens a scope when some contribution is stale, so fully-fresh
    /// rounds (every synchronous scheduler, and async rounds where
    /// everyone kept up) take the verbatim-weights path bit-for-bit.
    pub(crate) fn publish(participants: &[usize], staleness: &[usize], decay: f32) -> Self {
        let map: BTreeMap<usize, f32> = participants
            .iter()
            .zip(staleness)
            .map(|(&i, &s)| (i, decay.powi(s as i32)))
            .collect();
        STALE_DECAY.with(|d| *d.borrow_mut() = Some(map));
        DecayScope { _private: () }
    }
}

impl Drop for DecayScope {
    fn drop(&mut self) {
        STALE_DECAY.with(|d| *d.borrow_mut() = None);
    }
}

/// The current round's per-participant staleness-decay multipliers, in
/// `participants` order — `None` unless the driver published a scope for
/// this round (i.e. unless some contribution is stale). Participants the
/// scheduler did not report (defensive) count as fresh (`1.0`).
pub fn stale_decay_multipliers(participants: &[usize]) -> Option<Vec<f32>> {
    STALE_DECAY.with(|d| {
        d.borrow().as_ref().map(|m| {
            participants
                .iter()
                .map(|i| m.get(i).copied().unwrap_or(1.0))
                .collect()
        })
    })
}

/// Read-only context handed to one client's round work on a worker.
pub struct ClientCtx<'e, 'a> {
    pub env: &'e Env<'a>,
    pub round: usize,
    /// Exchange step within the round (`0..Protocol::steps(round)`).
    pub step: usize,
    /// The client id this closure is running for.
    pub client: usize,
    /// Under `--delayed-gradients`, the server broadcast snapshot this
    /// client actually pulled (round `round - staleness`); `None` when
    /// the client is fresh or versioning is off — read the protocol's
    /// live round-start state (DESIGN.md §8).
    pub version: Option<ModelVersion>,
}

impl ClientCtx<'_, '_> {
    /// The server-side store this client's round work reads: the
    /// versioned snapshot it pulled when the driver handed one, the
    /// protocol's live round-start store otherwise. Fresh clients take
    /// the live path, so cadence-only runs are bit-identical to the
    /// unversioned driver.
    pub fn server_store<'s>(&'s self, live: &'s TensorStore) -> &'s TensorStore {
        match &self.version {
            Some(v) => v.state(),
            None => live,
        }
    }
}

/// What one client hands back from a round step: the protocol-specific
/// payload plus the client-side cost delta the driver merges in id order.
pub struct ClientUpdate<U> {
    pub meter: CostMeter,
    pub inner: U,
}

impl<U> ClientUpdate<U> {
    pub fn new(inner: U) -> Self {
        Self { meter: CostMeter::new(), inner }
    }
}

/// What a round reports into the run recorder.
pub struct RoundReport {
    /// `train`, or AdaSplit's `local` / `global`.
    pub phase: String,
    pub train_loss: f64,
    /// Mean server-mask density (AdaSplit; 1.0 otherwise).
    pub mask_density: f64,
    /// Clients that did server-side work this round (UCB picks for
    /// AdaSplit; the participant set otherwise).
    pub selected: Vec<usize>,
}

/// A distributed-training protocol, decomposed into the client-step /
/// server-merge API the [`run`] driver schedules.
///
/// Call order per run: `init_state` once, then per round:
/// `begin_round` -> (`client_round`* -> `merge_round`) x `steps` ->
/// `end_round` -> `eval` (on eval rounds). `steps(round)` is consulted
/// after `begin_round`, so a protocol may size its exchange count from
/// the round's participants (AdaSplit: max batch count).
pub trait Protocol: Sync {
    /// Per-client payload type carried from `client_round` to `merge_round`.
    type Update: Send;

    fn name(&self) -> &'static str;

    /// One-time server-side state initialization.
    fn init_state(&mut self, env: &mut Env) -> Result<()>;

    /// Build one client's initial state — must be a pure function of the
    /// experiment seed and `client`, because the pooled store calls it
    /// lazily on the client's *first participation* (which depends on the
    /// scheduler) and first-touch timing must not change values.
    fn init_client(&self, env: &Env, client: usize) -> Result<ClientState>;

    /// Number of client-step/server-merge exchanges in `round`. Valid
    /// after `begin_round(round)`.
    fn steps(&self, round: usize) -> usize {
        let _ = round;
        1
    }

    /// Whether `client_round` fans out over the engine pool. Protocols
    /// whose exchange is an inherent chain return `false` and do the
    /// whole step inside `merge_round`.
    fn fan_out(&self) -> bool {
        true
    }

    /// The server-side state a participant downloads at round start —
    /// everything `client_round` reads from the server (FL family: the
    /// round-start global as `pg.*`, plus Scaffold's control variate
    /// `c.*`). Under `--delayed-gradients` the driver snapshots this
    /// into the version ring every round and hands stale participants
    /// the snapshot from the round they actually pulled (DESIGN.md §8).
    ///
    /// `None` (the default) declares that clients read no server state
    /// in `client_round` — AdaSplit's local objective never downloads
    /// server weights, and SL-basic / SplitFed run their inherently
    /// sequential exchange against the single live server model — so
    /// staleness for those protocols stays a participation-cadence
    /// effect (their per-client state still lags genuinely, because it
    /// is only touched on participation).
    fn broadcast_state(&self) -> Option<TensorStore> {
        None
    }

    /// Per-round setup on the driver thread (round-start snapshots, batch
    /// materialization, scratch resets).
    fn begin_round(&mut self, env: &mut Env, round: usize, participants: &[usize]) -> Result<()> {
        let _ = (env, round, participants);
        Ok(())
    }

    /// One participant's work for step `ctx.step`: runs on a worker, may
    /// mutate only `state`, reads shared state through `&self`/`ctx.env`.
    fn client_round(
        &self,
        ctx: &ClientCtx<'_, '_>,
        state: &mut ClientState,
    ) -> Result<ClientUpdate<Self::Update>> {
        let _ = (ctx, state);
        bail!("{} has no parallel client phase", self.name())
    }

    /// Fold the step's client updates (ascending client-id order) into
    /// server state on the driver thread. Server-side costs are metered
    /// here via `env.meter`.
    fn merge_round(
        &mut self,
        env: &mut Env,
        store: &mut ClientStateStore,
        round: usize,
        step: usize,
        participants: &[usize],
        updates: Vec<(usize, Self::Update)>,
    ) -> Result<()>;

    /// Round-boundary server work (aggregation, broadcasts); reports the
    /// round's stats.
    fn end_round(
        &mut self,
        env: &mut Env,
        store: &mut ClientStateStore,
        round: usize,
        participants: &[usize],
    ) -> Result<RoundReport>;

    /// Mean per-client test accuracy (%) under the current state.
    fn eval(&self, env: &Env, store: &mut ClientStateStore) -> Result<f64>;
}

/// Metric snapshot at the last adaptation-window boundary: the window
/// reward is shaped from "end minus mark" deltas. Shared with the event
/// driver, whose `ControllerSwitch` events carry the same bookkeeping.
#[derive(Clone, Copy, Default)]
pub(crate) struct WindowMark {
    pub(crate) accuracy: f64,
    pub(crate) sim_time: f64,
    pub(crate) bandwidth_gb: f64,
    pub(crate) client_tflops: f64,
}

/// Execute one merge's worth of protocol work for the given participant
/// set: residency management, version resolution, decay scope, the
/// fan-out/fan-in step loop, and the server merge.
///
/// This is the shared round body of *both* drivers — the round loop in
/// [`run`] and the event loop in [`crate::sim::run_events`] call it with
/// the plans their schedulers/policies produce, so degenerate-policy
/// bit-parity (DESIGN.md §11) is structural: identical plans feed the
/// identical code path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exec_round<P: Protocol>(
    env: &mut Env,
    protocol: &mut P,
    store: &mut ClientStateStore,
    ring: &mut Option<SnapshotRing>,
    speeds: &ClientSpeeds,
    pool: &std::sync::Arc<crate::engine::ClientPool>,
    round: usize,
    participants: &[usize],
    staleness: &[usize],
) -> Result<RoundReport> {
    // evict last round's inactive clients first, then materialize the
    // round's sample: peak residency ~ |old ∪ new|, not total clients
    store.spill_except(participants)?;
    store.ensure_loaded(participants, |i| protocol.init_client(env, i))?;
    if store.spilling() {
        // dataset shards follow the same residency discipline as
        // client state: cache only the round's sample, regenerate
        // others on demand (they are pure functions of (seed, client))
        env.clients.retain(participants);
    }

    protocol.begin_round(env, round, participants)?;
    // version ring: capture this round's broadcast state, then hand
    // each stale participant the snapshot it actually pulled (round
    // `round - s_i`); fresh participants read the live state
    let versions: Option<Vec<Option<ModelVersion>>> = match ring.as_mut() {
        Some(ring) => {
            if let Some(broadcast) = protocol.broadcast_state() {
                ring.push(round, broadcast)?;
            }
            Some(resolve_versions(ring, round, staleness)?)
        }
        None => None,
    };
    // stale contributions are down-weighted in the round's merges
    // (round_weights, DESIGN.md §7); fully-fresh rounds skip the scope
    // so the verbatim-weights path stays bit-identical
    let decay_scope = staleness.iter().any(|&s| s > 0).then(|| {
        DecayScope::publish(participants, staleness, env.cfg.stale_decay as f32)
    });
    let steps = protocol.steps(round);
    for step in 0..steps {
        let updates: Vec<(usize, P::Update)> = if protocol.fan_out() {
            let raw = {
                let p: &P = protocol;
                let env_ref: &Env = env;
                let versions_ref = &versions;
                let mut states = store.loaded_mut(participants)?;
                pool.run_mut(&mut states, |j, state| {
                    let ctx = ClientCtx {
                        env: env_ref,
                        round,
                        step,
                        client: participants[j],
                        version: versions_ref.as_ref().and_then(|v| v[j].clone()),
                    };
                    p.client_round(&ctx, state)
                })?
            };
            // fan-in on the driver thread: per-client deltas (scaled
            // against the budgets under heterogeneous speeds) combine
            // through a balanced tree whose shape is a pure function
            // of the id-ordered participant list, then fold into the
            // run meter once — the reduce order depends on client ids
            // only, never the thread schedule, so threads N ≡ 1 holds
            // at any fan-out width (DESIGN.md §10)
            let mut merged = Vec::with_capacity(raw.len());
            let mut deltas = Vec::with_capacity(raw.len());
            for (j, u) in raw.into_iter().enumerate() {
                let i = participants[j];
                let delta = if speeds.is_uniform() {
                    u.meter
                } else {
                    let mut d = CostMeter::new();
                    d.merge_scaled(&u.meter, speeds.compute_scale(i), speeds.net_scale(i));
                    d
                };
                deltas.push(delta);
                merged.push((i, u.inner));
            }
            let combined = crate::engine::tree_reduce(deltas, |mut a, b| {
                a.merge(&b);
                a
            });
            if let Some(round_delta) = combined {
                env.meter.merge(&round_delta);
            }
            merged
        } else {
            Vec::new()
        };
        protocol.merge_round(env, store, round, step, participants, updates)?;
    }
    let report = protocol.end_round(env, store, round, participants)?;
    drop(decay_scope);
    Ok(report)
}

/// Run `protocol` end to end under the configured scheduler and return
/// its result. This is the round-barrier driver; `--engine events`
/// selects [`crate::sim::run_events`] instead, which shares
/// [`exec_round`] so the two agree bit-for-bit on identical plans.
pub fn run<P: Protocol>(env: &mut Env, protocol: &mut P) -> Result<RunResult> {
    protocol.init_state(env)?;

    // one construction: the scheduler's virtual clock and the fan-in cost
    // scaling below share the same fleet
    let (mut scheduler, speeds) = scheduler_for(env.cfg);
    // --adaptive-bound: the UCB controller picks its seeded first arm
    // before round 0 and re-decides at every window boundary; every
    // decision runs on the driver thread off thread-count-invariant
    // metrics, so adaptivity never perturbs the determinism contract
    // (DESIGN.md §9)
    let mut controller = if env.cfg.adaptive_bound {
        let c = BoundController::from_cfg(env.cfg);
        scheduler.set_bound(c.current_bound(), 0);
        Some(c)
    } else {
        None
    };
    let mut window_mark = WindowMark::default();
    // Spilling is active only under real subsampling: a full-participation
    // run keeps every client resident and never touches the disk.
    let mut store = if env.cfg.participation < 1.0 {
        ClientStateStore::with_spill(env.cfg.clients, scratch_dir(env.cfg.seed))?
    } else {
        ClientStateStore::new(env.cfg.clients)
    };
    let pool = env.pool();
    // --delayed-gradients: ring of round-start broadcast snapshots over
    // the staleness window (O(bound) snapshots). Under per-round sampling
    // it follows the client-state residency discipline: only the newest
    // snapshot stays resident, older ones spill to scratch (DESIGN.md §8).
    let mut ring: Option<SnapshotRing> = if env.cfg.delayed_gradients {
        let window = env.cfg.staleness_bound.unwrap_or(0) + 1;
        Some(if env.cfg.participation < 1.0 {
            // scratch_dir mints a unique directory per call, so the ring
            // owns (and removes on drop) its whole spill dir
            SnapshotRing::with_spill(window, scratch_dir(env.cfg.seed))?
        } else {
            SnapshotRing::new(window)
        })
    } else {
        None
    };
    // the first window's Δaccuracy needs a pre-training baseline: an
    // untrained model already scores around chance, and measuring the
    // first window from 0% would credit the seed-chosen first arm with
    // the entire warm-up jump — an inflated mean it would carry through
    // every later UCB comparison. The eval is value-neutral (it reads
    // `&Env`, and state/shard materialization is a pure function of the
    // seed), so non-adaptive parity is untouched.
    if controller.is_some() {
        window_mark.accuracy = protocol.eval(env, &mut store)?;
    }

    for round in 0..env.cfg.rounds {
        // the bound in effect while this round was planned (0 for the
        // synchronous schedulers) — recorded per round so the adaptive
        // trajectory is visible on the CSV/JSON axes
        let bound = scheduler.current_bound();
        let RoundPlan { participants, staleness, sim_time } = scheduler.plan(round);
        let report = exec_round(
            env,
            protocol,
            &mut store,
            &mut ring,
            &speeds,
            &pool,
            round,
            &participants,
            &staleness,
        )?;

        // the controller needs a fresh accuracy reading at every window
        // boundary (its Δaccuracy signal), so adaptivity widens the eval
        // cadence instead of reusing a stale reading
        let window_end = controller
            .as_ref()
            .is_some_and(|c| (round + 1) % c.window() == 0);
        let eval_now =
            round % env.cfg.eval_every == 0 || round + 1 == env.cfg.rounds || window_end;
        let accuracy = if eval_now {
            protocol.eval(env, &mut store)?
        } else {
            env.recorder.last_accuracy()
        };

        env.recorder.push(RoundStat {
            round,
            phase: report.phase,
            train_loss: report.train_loss,
            accuracy_pct: accuracy,
            bandwidth_gb: env.meter.bandwidth_gb(),
            client_tflops: env.meter.client_tflops(),
            total_tflops: env.meter.total_tflops(),
            mask_density: report.mask_density,
            sim_time,
            max_staleness: staleness.iter().copied().max().unwrap_or(0),
            bound,
            selected: report.selected,
            participants,
            // the barrier loop pops no events; the event driver records
            // its heap's cumulative pop count here
            events: 0,
        });

        // window boundary: credit the finished window to the current arm
        // and switch the scheduler to the UCB pick for the next one —
        // switches only ever land here, never mid-window
        if window_end {
            if let Some(ctrl) = controller.as_mut() {
                let sim_now = sim_time;
                let delta = WindowDelta {
                    d_accuracy_pct: accuracy - window_mark.accuracy,
                    d_sim_time: sim_now - window_mark.sim_time,
                    d_bandwidth_gb: env.meter.bandwidth_gb() - window_mark.bandwidth_gb,
                    d_client_tflops: env.meter.client_tflops() - window_mark.client_tflops,
                };
                window_mark = WindowMark {
                    accuracy,
                    sim_time: sim_now,
                    bandwidth_gb: env.meter.bandwidth_gb(),
                    client_tflops: env.meter.client_tflops(),
                };
                if round + 1 < env.cfg.rounds {
                    let (next, reward) = ctrl.observe_window(&delta);
                    scheduler.set_bound(next, round + 1);
                    if env.recorder.trace_enabled {
                        env.recorder.trace(format!(
                            "adaptive: window ending round {round} reward {reward:.4} -> bound {next}"
                        ));
                    }
                }
            }
        }
    }

    Ok(RunResult::from_env(env, &env.recorder, &env.meter, scheduler.name()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::round_weights;

    #[test]
    fn no_decay_context_outside_a_scope() {
        assert!(stale_decay_multipliers(&[0, 1, 2]).is_none());
    }

    #[test]
    fn decay_scope_publishes_and_clears_on_drop() {
        {
            let _scope = DecayScope::publish(&[1, 4, 7], &[0, 2, 1], 0.5);
            let m = stale_decay_multipliers(&[1, 4, 7]).expect("scope active");
            assert_eq!(m, vec![1.0, 0.25, 0.5], "decay^staleness");
            // unknown ids count as fresh
            assert_eq!(stale_decay_multipliers(&[3]).unwrap(), vec![1.0]);
        }
        assert!(stale_decay_multipliers(&[1]).is_none(), "cleared on drop");
    }

    #[test]
    fn stale_decay_weights_renormalize_to_one() {
        let weights = vec![0.25f32, 0.25, 0.5];
        let participants = [0usize, 2];
        let _scope = DecayScope::publish(&participants, &[0, 2], 0.5);
        let w = round_weights(&weights, &participants);
        assert_eq!(w.len(), 2);
        let sum: f32 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "renormalized sum {sum}");
        // the stale client (staleness 2 => x0.25) is down-weighted
        // relative to its fresh-weights share: raw 0.25 vs 0.5*0.25=0.125
        assert!((w[0] - 0.25 / 0.375).abs() < 1e-6);
        assert!((w[1] - 0.125 / 0.375).abs() < 1e-6);
        assert!(w[0] > w[1], "fresh client outweighs the bigger-but-stale one");
    }

    #[test]
    fn fresh_rounds_leave_round_weights_verbatim() {
        // no scope: full participation returns the weights bitwise
        let weights = vec![0.1f32, 0.2, 0.3, 0.4];
        assert_eq!(round_weights(&weights, &[0, 1, 2, 3]), weights);
        // a scope with all-fresh multipliers still renormalizes over the
        // sampled subset exactly like the sync path
        let _scope = DecayScope::publish(&[1, 3], &[0, 0], 0.5);
        let w = round_weights(&weights, &[1, 3]);
        assert!((w[0] - 0.2 / 0.6).abs() < 1e-6);
        assert!((w[1] - 0.4 / 0.6).abs() < 1e-6);
    }
}
