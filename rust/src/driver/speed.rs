//! Per-client compute/network speed model for the async scheduler and the
//! heterogeneous-device cost accounting (AdaptSFL-style, arXiv 2403.13101).
//!
//! Every client gets two rate multipliers — compute and network, `1.0` =
//! the baseline device — drawn from a seeded preset. Rates are derived
//! per client id from the experiment seed (`seed -> "client-speed" -> i`),
//! so they are:
//!
//! * **reproducible across runs** — same seed, same fleet;
//! * **stable across client counts** — client `i`'s rates are the same
//!   whether the run has 10 clients or 1000 (growing the fleet appends
//!   devices, it does not reshuffle the existing ones);
//! * **independent of every other random decision** — enabling a speed
//!   model never perturbs data synthesis, shuffling, or sampling.
//!
//! A client's simulated round duration splits one baseline time unit
//! between compute and network ([`COMPUTE_SHARE`]/[`NET_SHARE`]), so the
//! uniform preset yields exactly `1.0` per round and the virtual
//! wall-clock of a synchronous run reads in "rounds of the baseline
//! device".

use anyhow::{ensure, Result};

use crate::config::ExperimentConfig;
use crate::data::Rng;

/// Fraction of a baseline round spent computing.
pub const COMPUTE_SHARE: f64 = 0.8;
/// Fraction of a baseline round spent on the network.
pub const NET_SHARE: f64 = 0.2;
/// Rate multiplier of a straggler device under the `stragglers` preset.
pub const STRAGGLER_SLOWDOWN: f64 = 10.0;
/// Default lognormal sigma when `lognormal` is given without a value.
pub const DEFAULT_LOGNORMAL_SIGMA: f64 = 0.5;

/// Diurnal fleet-speed multiplier at virtual time `t`:
/// `1 + amplitude * sin(2πt / period)` — the scenario engine's
/// time-varying load curve (DESIGN.md §12), layered multiplicatively
/// over the per-client [`ClientSpeeds`] rates. A work unit samples the
/// curve once, at its start instant. Pure, stateless, and exactly `1.0`
/// at `t = 0` (`sin(0)` is exact), so opening a run with a diurnal
/// schedule never perturbs the initial seeding arithmetic.
pub fn diurnal_multiplier(t: f64, period: f64, amplitude: f64) -> f64 {
    1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period).sin()
}

/// How per-client rates are drawn (`--client-speeds` / `client_speeds`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum SpeedPreset {
    /// Every client is the baseline device (rates 1.0) — the default, and
    /// one half of the `AsyncBounded(s=0) == SyncAll` bit-parity contract.
    #[default]
    Uniform,
    /// Rates `exp(sigma * z)`, `z ~ N(0, 1)`, drawn independently for
    /// compute and network per client.
    Lognormal { sigma: f64 },
    /// A seeded fraction (`--straggler-frac`) of clients runs
    /// [`STRAGGLER_SLOWDOWN`]x slower on both axes; the rest are baseline.
    Stragglers,
}

impl SpeedPreset {
    /// CLI/config id (`uniform`, `lognormal:0.5`, `stragglers`).
    pub fn id(&self) -> String {
        match self {
            SpeedPreset::Uniform => "uniform".to_string(),
            SpeedPreset::Lognormal { sigma } => format!("lognormal:{sigma}"),
            SpeedPreset::Stragglers => "stragglers".to_string(),
        }
    }
}

impl std::str::FromStr for SpeedPreset {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        if s == "uniform" {
            return Ok(SpeedPreset::Uniform);
        }
        if s == "stragglers" {
            return Ok(SpeedPreset::Stragglers);
        }
        if s == "lognormal" {
            return Ok(SpeedPreset::Lognormal { sigma: DEFAULT_LOGNORMAL_SIGMA });
        }
        if let Some(v) = s.strip_prefix("lognormal:") {
            let sigma: f64 = v
                .parse()
                .map_err(|e| anyhow::anyhow!("lognormal sigma `{v}`: {e}"))?;
            ensure!(
                sigma > 0.0 && sigma <= 3.0,
                "lognormal sigma must be in (0, 3], got {sigma}"
            );
            return Ok(SpeedPreset::Lognormal { sigma });
        }
        anyhow::bail!(
            "unknown speed model `{s}` (expected uniform | lognormal[:sigma] | stragglers)"
        )
    }
}

/// Per-client rate multipliers for one run, computed on demand.
///
/// The model stores only its parameters (preset, straggler fraction, root
/// stream) — **O(1) memory however large the fleet** — and derives client
/// `i`'s rates from its independent stream `seed -> "client-speed" -> i`
/// at each lookup. Because every id always had its own derived stream,
/// the lazy values are bit-identical to the old eagerly-materialized
/// vectors; a sampled round now touches O(sample) streams instead of
/// paying an O(fleet) allocation up front.
#[derive(Clone, Debug)]
pub struct ClientSpeeds {
    n: usize,
    preset: SpeedPreset,
    straggler_frac: f64,
    root: Rng,
}

impl ClientSpeeds {
    pub fn new(n_clients: usize, preset: SpeedPreset, straggler_frac: f64, seed: u64) -> Self {
        Self { n: n_clients, preset, straggler_frac, root: Rng::new(seed) }
    }

    /// Speeds for the experiment's fleet (`client_speeds`,
    /// `straggler_frac`, `seed` config keys).
    pub fn from_cfg(cfg: &ExperimentConfig) -> Self {
        Self::new(cfg.clients, cfg.client_speeds, cfg.straggler_frac, cfg.seed)
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// All clients are the baseline device — the bit-parity fast path:
    /// the driver then merges cost deltas unscaled, exactly as before the
    /// speed model existed.
    pub fn is_uniform(&self) -> bool {
        self.preset == SpeedPreset::Uniform
    }

    /// `(compute, net)` rate multipliers for one client: a pure function
    /// of (seed, client) — never of the fleet size or of which other
    /// clients were looked up first.
    pub fn rates(&self, client: usize) -> (f64, f64) {
        debug_assert!(client < self.n, "client {client} out of fleet 0..{}", self.n);
        // one independent stream per client id
        let mut r = self.root.derive("client-speed", client as u64);
        match self.preset {
            SpeedPreset::Uniform => (1.0, 1.0),
            SpeedPreset::Lognormal { sigma } => {
                let c = (sigma * r.normal()).exp();
                let nw = (sigma * r.normal()).exp();
                (c, nw)
            }
            SpeedPreset::Stragglers => {
                if r.next_f64() < self.straggler_frac {
                    (1.0 / STRAGGLER_SLOWDOWN, 1.0 / STRAGGLER_SLOWDOWN)
                } else {
                    (1.0, 1.0)
                }
            }
        }
    }

    /// Virtual duration of one round of client work, in baseline-round
    /// units (`1.0` for the baseline device).
    pub fn round_duration(&self, client: usize) -> f64 {
        let (compute, net) = self.rates(client);
        COMPUTE_SHARE / compute + NET_SHARE / net
    }

    /// Longest round duration over a participant set (what a synchronous
    /// barrier waits for).
    ///
    /// An empty participant set is a scheduler invariant violation —
    /// merge sets are never empty (`clients > 0` is validated, sample
    /// sizes clamp to >= 1, and `AsyncBounded` has the fastest-client
    /// fallback) — so it trips a debug assertion. In release builds it
    /// returns `NaN`, which poisons the virtual clock *visibly* (a
    /// monotonicity check or recorded sim-time comparison fails) instead
    /// of the old behavior of returning `0.0` and silently freezing the
    /// clock.
    pub fn slowest_duration(&self, clients: &[usize]) -> f64 {
        debug_assert!(
            !clients.is_empty(),
            "slowest_duration of an empty participant set (scheduler invariant violation)"
        );
        clients
            .iter()
            .map(|&i| self.round_duration(i))
            .fold(f64::NAN, f64::max)
    }

    /// Compute-budget multiplier: FLOPs on a slow device cost
    /// proportionally more device-time against the compute budget.
    pub fn compute_scale(&self, client: usize) -> f64 {
        1.0 / self.rates(client).0
    }

    /// Bandwidth-budget multiplier: bytes over a slow link cost
    /// proportionally more link-time against the bandwidth budget.
    pub fn net_scale(&self, client: usize) -> f64 {
        1.0 / self.rates(client).1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_all_baseline_and_unit_duration() {
        let s = ClientSpeeds::new(6, SpeedPreset::Uniform, 0.3, 9);
        assert!(s.is_uniform());
        for i in 0..6 {
            assert_eq!(s.round_duration(i), 1.0, "COMPUTE_SHARE + NET_SHARE = 1");
            assert_eq!(s.compute_scale(i), 1.0);
            assert_eq!(s.net_scale(i), 1.0);
        }
        assert_eq!(s.slowest_duration(&[0, 3, 5]), 1.0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "empty participant set")]
    fn empty_participant_set_trips_the_invariant_assertion() {
        let s = ClientSpeeds::new(4, SpeedPreset::Uniform, 0.0, 0);
        let _ = s.slowest_duration(&[]);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn empty_participant_set_poisons_the_clock_in_release() {
        // release builds surface the violation as NaN (visible downstream)
        // rather than 0.0 (a silently frozen virtual clock)
        let s = ClientSpeeds::new(4, SpeedPreset::Uniform, 0.0, 0);
        assert!(s.slowest_duration(&[]).is_nan());
    }

    #[test]
    fn speed_model_is_reproducible_across_runs() {
        for preset in [
            SpeedPreset::Uniform,
            SpeedPreset::Lognormal { sigma: 0.5 },
            SpeedPreset::Stragglers,
        ] {
            let a = ClientSpeeds::new(32, preset, 0.25, 7);
            let b = ClientSpeeds::new(32, preset, 0.25, 7);
            for i in 0..32 {
                assert_eq!(a.rates(i), b.rates(i), "{preset:?} client {i}");
                // lookups are pure: repeating one changes nothing
                assert_eq!(a.rates(i), a.rates(i), "{preset:?} client {i}");
            }
        }
    }

    #[test]
    fn speed_model_is_stable_across_client_counts() {
        // growing the fleet appends devices; existing ones keep their rates
        for preset in [SpeedPreset::Lognormal { sigma: 0.8 }, SpeedPreset::Stragglers] {
            let small = ClientSpeeds::new(8, preset, 0.3, 11);
            let large = ClientSpeeds::new(64, preset, 0.3, 11);
            for i in 0..8 {
                assert_eq!(small.rates(i), large.rates(i), "{preset:?} client {i}");
            }
        }
    }

    #[test]
    fn seeds_matter_for_random_presets() {
        let a = ClientSpeeds::new(64, SpeedPreset::Lognormal { sigma: 0.5 }, 0.0, 1);
        let b = ClientSpeeds::new(64, SpeedPreset::Lognormal { sigma: 0.5 }, 0.0, 2);
        let ca: Vec<u64> = (0..64).map(|i| a.rates(i).0.to_bits()).collect();
        let cb: Vec<u64> = (0..64).map(|i| b.rates(i).0.to_bits()).collect();
        assert_ne!(ca, cb);
    }

    #[test]
    fn stragglers_are_slowed_by_the_fixed_factor() {
        let s = ClientSpeeds::new(400, SpeedPreset::Stragglers, 0.25, 3);
        let mut slow = 0usize;
        for i in 0..400 {
            let d = s.round_duration(i);
            if d > 1.0 {
                assert!((d - STRAGGLER_SLOWDOWN).abs() < 1e-9, "client {i}: {d}");
                assert!((s.compute_scale(i) - STRAGGLER_SLOWDOWN).abs() < 1e-9);
                slow += 1;
            } else {
                assert_eq!(d, 1.0);
            }
        }
        // seeded Bernoulli(0.25) over 400 clients: loose 3-sigma band
        assert!((60..=140).contains(&slow), "straggler count {slow}");
    }

    #[test]
    fn lognormal_rates_are_positive_and_spread() {
        let s = ClientSpeeds::new(128, SpeedPreset::Lognormal { sigma: 0.5 }, 0.0, 5);
        assert!(!s.is_uniform());
        let mut distinct = std::collections::BTreeSet::new();
        for i in 0..128 {
            let (compute, net) = s.rates(i);
            assert!(compute > 0.0 && net > 0.0);
            assert!(s.round_duration(i) > 0.0);
            distinct.insert(compute.to_bits());
        }
        assert!(distinct.len() > 100, "rates should be spread, not collapsed");
    }

    #[test]
    fn scenario_diurnal_multiplier_is_exact_at_zero_and_bounded() {
        assert_eq!(diurnal_multiplier(0.0, 8.0, 0.5).to_bits(), 1.0f64.to_bits());
        // peak at a quarter period, trough at three quarters
        assert!((diurnal_multiplier(2.0, 8.0, 0.5) - 1.5).abs() < 1e-12);
        assert!((diurnal_multiplier(6.0, 8.0, 0.5) - 0.5).abs() < 1e-12);
        // amplitude < 1 keeps the multiplier strictly positive everywhere
        for k in 0..64 {
            let t = k as f64 * 0.37;
            let m = diurnal_multiplier(t, 5.0, 0.99);
            assert!(m > 0.0 && m < 2.0, "t={t}: {m}");
        }
    }

    #[test]
    fn preset_parsing_roundtrip() {
        assert_eq!("uniform".parse::<SpeedPreset>().unwrap(), SpeedPreset::Uniform);
        assert_eq!(
            "stragglers".parse::<SpeedPreset>().unwrap(),
            SpeedPreset::Stragglers
        );
        assert_eq!(
            "lognormal".parse::<SpeedPreset>().unwrap(),
            SpeedPreset::Lognormal { sigma: DEFAULT_LOGNORMAL_SIGMA }
        );
        assert_eq!(
            "lognormal:1.2".parse::<SpeedPreset>().unwrap(),
            SpeedPreset::Lognormal { sigma: 1.2 }
        );
        assert!("lognormal:-1".parse::<SpeedPreset>().is_err());
        assert!("warp".parse::<SpeedPreset>().is_err());
        assert_eq!(SpeedPreset::default(), SpeedPreset::Uniform);
        assert_eq!(SpeedPreset::Stragglers.id(), "stragglers");
    }
}
