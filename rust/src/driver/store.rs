//! Pooled per-client state: lazy initialization plus spill-to-disk, so a
//! sampled run's resident memory tracks the *active* participant set, not
//! the total client count.
//!
//! Each client owns a [`ClientState`] — a small named group of
//! `TensorStore`s (`"model"`, `"ci"`, `"mask"`, `"pending"`, ... — the
//! protocol picks the slots). The [`ClientStateStore`] tracks each client
//! in one of three states:
//!
//! * **Uninit** — the client has never participated; nothing is held
//!   (not even a placeholder: absence from the shard maps *is* the
//!   state, so a never-sampled client costs zero bytes). State is
//!   materialized on first participation via the protocol's
//!   `init_client` (a pure function of the experiment seed, so *when* a
//!   client is first initialized never changes its values).
//! * **Loaded** — resident in memory (the active sample).
//! * **Spilled** — serialized to a scratch file (bit-exact f32 round
//!   trip), reloaded on the client's next participation.
//!
//! Storage is sharded: ids map to a fixed set of hash-map shards via the
//! engine's [`stable_shard`] bit-mix (a pure function of the id, so
//! placement is reproducible across runs and thread counts), and a
//! sorted resident-id index makes every per-round bookkeeping operation
//! — `loaded_ids`, `loaded_count`, `resident_bytes`, `spill_except` —
//! O(resident), never O(fleet). A `--clients 100000, p=0.005` run pays
//! for ~500 states per round, not 100000 slots.
//!
//! Spilling is enabled by the driver only when per-round sampling is
//! active (`participation < 1.0`); a full-participation run keeps every
//! client loaded and never touches the disk, which is one ingredient of
//! the `SampledSync(p=1.0) == SyncAll` bit-identity guarantee.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::engine::stable_shard;
use crate::runtime::{Tensor, TensorStore};

/// One client's named state group.
#[derive(Clone, Debug, Default)]
pub struct ClientState {
    parts: BTreeMap<String, TensorStore>,
}

impl ClientState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, slot: impl Into<String>, store: TensorStore) {
        self.parts.insert(slot.into(), store);
    }

    pub fn get(&self, slot: &str) -> Result<&TensorStore> {
        self.parts
            .get(slot)
            .ok_or_else(|| anyhow::anyhow!("client-state slot `{slot}` missing"))
    }

    pub fn get_mut(&mut self, slot: &str) -> Result<&mut TensorStore> {
        self.parts
            .get_mut(slot)
            .ok_or_else(|| anyhow::anyhow!("client-state slot `{slot}` missing"))
    }

    /// Disjoint `&mut` borrows of two distinct slots (e.g. an FL client's
    /// model and its control variate inside one fan-out closure).
    pub fn pair_mut(
        &mut self,
        a: &str,
        b: &str,
    ) -> Result<(&mut TensorStore, &mut TensorStore)> {
        ensure!(a != b, "pair_mut needs two distinct slots");
        let mut sa = None;
        let mut sb = None;
        for (k, v) in self.parts.iter_mut() {
            if k == a {
                sa = Some(v);
            } else if k == b {
                sb = Some(v);
            }
        }
        match (sa, sb) {
            (Some(x), Some(y)) => Ok((x, y)),
            (None, _) => bail!("client-state slot `{a}` missing"),
            (_, None) => bail!("client-state slot `{b}` missing"),
        }
    }

    /// Remove and return one tensor (e.g. a pending stale gradient).
    pub fn take_tensor(&mut self, slot: &str, key: &str) -> Option<Tensor> {
        let store = self.parts.get_mut(slot)?;
        if !store.contains(key) {
            return None;
        }
        // rebuild without the key (TensorStore has no remove; the pending
        // slot holds at most one small tensor, so this stays cheap)
        let mut taken = None;
        let mut rest = TensorStore::new();
        for (k, v) in store.iter() {
            if k == key {
                taken = Some(v.clone());
            } else {
                rest.insert(k.clone(), v.clone());
            }
        }
        *store = rest;
        taken
    }

    pub fn parts(&self) -> impl Iterator<Item = (&String, &TensorStore)> {
        self.parts.iter()
    }

    /// Resident payload in bytes (f32 tensors only; keys ignored).
    pub fn byte_size(&self) -> usize {
        self.parts.values().map(|s| s.byte_size()).sum::<usize>()
    }
}

enum Slot {
    Loaded(ClientState),
    Spilled(PathBuf),
}

/// Number of hash-map shards a store spreads its clients over. Fixed (not
/// thread-count dependent) so placement never varies between runs.
pub const STORE_SHARDS: usize = 16;

/// Pooled per-client state with lazy init and optional spill-to-disk.
///
/// Clients live in [`STORE_SHARDS`] hash-map shards keyed by id (shard
/// choice = [`stable_shard`]); an id absent from its shard is **Uninit**.
/// A sorted resident-id index keeps every bookkeeping query O(resident).
pub struct ClientStateStore {
    n_clients: usize,
    shards: Vec<HashMap<usize, Slot>>,
    /// Ids currently `Loaded`, in sorted order. Invariant: `resident`
    /// contains exactly the ids whose shard entry is `Slot::Loaded`.
    resident: BTreeSet<usize>,
    spill_dir: Option<PathBuf>,
}

impl ClientStateStore {
    /// All-resident store (no spilling): full-participation behavior.
    pub fn new(n_clients: usize) -> Self {
        Self {
            n_clients,
            shards: (0..STORE_SHARDS).map(|_| HashMap::new()).collect(),
            resident: BTreeSet::new(),
            spill_dir: None,
        }
    }

    /// Store that spills non-active clients to scratch files under `dir`
    /// (created here, removed on drop).
    pub fn with_spill(n_clients: usize, dir: PathBuf) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {dir:?}"))?;
        let mut store = Self::new(n_clients);
        store.spill_dir = Some(dir);
        Ok(store)
    }

    pub fn len(&self) -> usize {
        self.n_clients
    }

    pub fn is_empty(&self) -> bool {
        self.n_clients == 0
    }

    pub fn spilling(&self) -> bool {
        self.spill_dir.is_some()
    }

    pub fn loaded_count(&self) -> usize {
        self.resident.len()
    }

    /// Every client — including never-sampled ones — is currently resident.
    pub fn all_loaded(&self) -> bool {
        self.resident.len() == self.n_clients
    }

    pub fn loaded_ids(&self) -> Vec<usize> {
        self.resident.iter().copied().collect()
    }

    /// Resident bytes across loaded states (introspection / tests).
    pub fn resident_bytes(&self) -> usize {
        self.resident
            .iter()
            .map(|&id| match self.shards[stable_shard(id, STORE_SHARDS)].get(&id) {
                Some(Slot::Loaded(c)) => c.byte_size(),
                _ => unreachable!("resident index out of sync for client {id}"),
            })
            .sum::<usize>()
    }

    /// Make every id in `ids` resident, initializing first-timers via
    /// `init` and reloading spilled ones.
    pub fn ensure_loaded<F>(&mut self, ids: &[usize], init: F) -> Result<()>
    where
        F: Fn(usize) -> Result<ClientState>,
    {
        for &id in ids {
            ensure!(id < self.n_clients, "client {id} out of range");
            let sh = stable_shard(id, STORE_SHARDS);
            match self.shards[sh].get(&id) {
                Some(Slot::Loaded(_)) => {}
                Some(Slot::Spilled(path)) => {
                    let path = path.clone();
                    let state = read_state(&path)
                        .with_context(|| format!("reloading client {id}"))?;
                    std::fs::remove_file(&path).ok();
                    self.shards[sh].insert(id, Slot::Loaded(state));
                    self.resident.insert(id);
                }
                None => {
                    let state = init(id)?;
                    self.shards[sh].insert(id, Slot::Loaded(state));
                    self.resident.insert(id);
                }
            }
        }
        Ok(())
    }

    /// Spill every resident client *not* in `keep` (sorted ids). No-op
    /// unless spilling is enabled. Walks the resident index, so a round's
    /// eviction pass costs O(resident · log keep), independent of the
    /// fleet size.
    pub fn spill_except(&mut self, keep: &[usize]) -> Result<usize> {
        if self.spill_dir.is_none() {
            return Ok(0);
        }
        let evict: Vec<usize> = self
            .resident
            .iter()
            .copied()
            .filter(|id| keep.binary_search(id).is_err())
            .collect();
        for &id in &evict {
            self.spill_one(id)?;
        }
        Ok(evict.len())
    }

    pub fn get(&self, id: usize) -> Result<&ClientState> {
        if id >= self.n_clients {
            bail!("client {id} out of range");
        }
        match self.shards[stable_shard(id, STORE_SHARDS)].get(&id) {
            Some(Slot::Loaded(s)) => Ok(s),
            _ => bail!("client {id} not resident"),
        }
    }

    pub fn get_mut(&mut self, id: usize) -> Result<&mut ClientState> {
        if id >= self.n_clients {
            bail!("client {id} out of range");
        }
        match self.shards[stable_shard(id, STORE_SHARDS)].get_mut(&id) {
            Some(Slot::Loaded(s)) => Ok(s),
            _ => bail!("client {id} not resident"),
        }
    }

    /// Disjoint `&mut` borrows of the resident states for `ids`
    /// (ascending, unique), in id order — the shape `ClientPool::run_mut`
    /// fans out over.
    pub fn loaded_mut(&mut self, ids: &[usize]) -> Result<Vec<&mut ClientState>> {
        let mut out = Vec::with_capacity(ids.len());
        let mut prev: Option<usize> = None;
        for &id in ids {
            ensure!(
                prev.map_or(true, |p| id > p),
                "loaded_mut ids must be ascending and unique"
            );
            prev = Some(id);
            ensure!(id < self.n_clients, "client {id} out of range");
            match self.shards[stable_shard(id, STORE_SHARDS)].get_mut(&id) {
                Some(Slot::Loaded(s)) => {
                    // SAFETY: ids are strictly ascending (checked above),
                    // so every (shard, key) pair is visited at most once
                    // and the borrows are disjoint; the maps are not
                    // mutated while the views are live, so the value
                    // addresses stay stable.
                    out.push(unsafe { &mut *(s as *mut ClientState) });
                }
                _ => bail!("client {id} not resident"),
            }
        }
        Ok(out)
    }

    /// Visit every client in id order with its state (read-only), lazily
    /// materializing as needed, without growing the resident set past
    /// `keep` (sorted ids). The visit cannot mutate state (it only sees
    /// `&ClientState`), which makes the sweep cheap under spilling:
    ///
    /// * resident clients are visited in place;
    /// * spilled clients outside `keep` are read **without consuming the
    ///   spill file** and dropped after the visit — the file stays
    ///   authoritative, so a repeated read-only sweep (per-round
    ///   evaluation) does zero disk writes;
    /// * never-initialized clients are initialized, visited, and (when
    ///   spilling and outside `keep`) written out once, so later sweeps
    ///   take the read-only path.
    pub fn visit_all<I, F>(&mut self, keep: &[usize], init: I, mut f: F) -> Result<()>
    where
        I: Fn(usize) -> Result<ClientState>,
        F: FnMut(usize, &ClientState) -> Result<()>,
    {
        enum Disposition {
            Resident,
            OnDisk(PathBuf),
            Fresh,
        }
        for id in 0..self.n_clients {
            let kept = keep.binary_search(&id).is_ok();
            let sh = stable_shard(id, STORE_SHARDS);
            let disp = match self.shards[sh].get(&id) {
                Some(Slot::Loaded(_)) => Disposition::Resident,
                Some(Slot::Spilled(path)) => Disposition::OnDisk(path.clone()),
                None => Disposition::Fresh,
            };
            match disp {
                Disposition::Resident => {}
                Disposition::OnDisk(path) => {
                    let state =
                        read_state(&path).with_context(|| format!("reloading client {id}"))?;
                    if kept {
                        std::fs::remove_file(&path).ok();
                        self.shards[sh].insert(id, Slot::Loaded(state));
                        self.resident.insert(id);
                    } else {
                        f(id, &state)?;
                        continue;
                    }
                }
                Disposition::Fresh => {
                    let state = init(id)?;
                    if self.spilling() && !kept {
                        let dir = self.spill_dir.clone().expect("spilling implies dir");
                        let path = dir.join(format!("client_{id}.bin"));
                        write_state(&path, &state)
                            .with_context(|| format!("spilling client {id}"))?;
                        f(id, &state)?;
                        self.shards[sh].insert(id, Slot::Spilled(path));
                        continue;
                    }
                    self.shards[sh].insert(id, Slot::Loaded(state));
                    self.resident.insert(id);
                }
            }
            match self.shards[sh].get(&id) {
                Some(Slot::Loaded(state)) => f(id, state)?,
                _ => unreachable!("client {id} must be resident here"),
            }
            // a resident client outside `keep` (caller shrank the keep
            // set) still gets evicted after its visit under spilling
            if self.spilling() && !kept {
                self.spill_one(id)?;
            }
        }
        Ok(())
    }

    fn spill_one(&mut self, id: usize) -> Result<()> {
        let Some(dir) = self.spill_dir.clone() else {
            return Ok(());
        };
        let sh = stable_shard(id, STORE_SHARDS);
        if let Some(Slot::Loaded(state)) = self.shards[sh].get(&id) {
            let path = dir.join(format!("client_{id}.bin"));
            write_state(&path, state).with_context(|| format!("spilling client {id}"))?;
            self.shards[sh].insert(id, Slot::Spilled(path));
            self.resident.remove(&id);
        }
        Ok(())
    }
}

impl Drop for ClientStateStore {
    fn drop(&mut self) {
        if let Some(dir) = &self.spill_dir {
            std::fs::remove_dir_all(dir).ok();
        }
    }
}

// ---- spill codec -----------------------------------------------------------
//
// Little-endian binary, bit-exact f32 round trip:
//   magic "ACS1"
//   u32 n_parts { u32 slot_len, slot, u32 n_tensors
//     { u32 key_len, key, u32 ndim, u32 dims[ndim], f32 data[prod(dims)] } }

const MAGIC: &[u8; 4] = b"ACS1";

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u32(r)? as usize;
    ensure!(len <= 1 << 20, "spill file: oversized string");
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

fn write_store(w: &mut impl Write, store: &TensorStore) -> Result<()> {
    write_u32(w, store.len() as u32)?;
    for (key, t) in store.iter() {
        write_str(w, key)?;
        write_u32(w, t.shape().len() as u32)?;
        for &d in t.shape() {
            write_u32(w, d as u32)?;
        }
        for &v in t.data() {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_store(r: &mut impl Read) -> Result<TensorStore> {
    let n_tensors = read_u32(r)? as usize;
    let mut store = TensorStore::new();
    for _ in 0..n_tensors {
        let key = read_str(r)?;
        let ndim = read_u32(r)? as usize;
        ensure!(ndim <= 8, "spill file: bad rank");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(r)? as usize);
        }
        let len: usize = shape.iter().product();
        let mut data = Vec::with_capacity(len);
        let mut b = [0u8; 4];
        for _ in 0..len {
            r.read_exact(&mut b)?;
            data.push(f32::from_le_bytes(b));
        }
        store.insert(key, Tensor::new(shape, data)?);
    }
    Ok(store)
}

fn write_state(path: &Path, state: &ClientState) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    write_u32(&mut w, state.parts.len() as u32)?;
    for (slot, store) in state.parts() {
        write_str(&mut w, slot)?;
        write_store(&mut w, store)?;
    }
    w.flush()?;
    Ok(())
}

fn read_state(path: &Path) -> Result<ClientState> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "spill file: bad magic");
    let n_parts = read_u32(&mut r)? as usize;
    let mut state = ClientState::new();
    for _ in 0..n_parts {
        let slot = read_str(&mut r)?;
        state.insert(slot, read_store(&mut r)?);
    }
    Ok(state)
}

// ---- model-snapshot codec (delayed-gradient version ring) ------------------
//
// A driver model snapshot is one bare `TensorStore`; it rides the same
// bit-exact little-endian container as spilled client state (a single
// part named `snapshot`), so the version ring inherits the spill codec's
// round-trip guarantees verbatim.

pub(crate) fn write_snapshot(path: &Path, store: &TensorStore) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    write_u32(&mut w, 1)?;
    write_str(&mut w, "snapshot")?;
    write_store(&mut w, store)?;
    w.flush()?;
    Ok(())
}

pub(crate) fn read_snapshot(path: &Path) -> Result<TensorStore> {
    let mut state = read_state(path)?;
    match state.parts.remove("snapshot") {
        Some(s) => Ok(s),
        None => bail!("snapshot file {path:?}: missing `snapshot` part"),
    }
}

/// Unique scratch directory for one run's spill files.
pub fn scratch_dir(seed: u64) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "adasplit-spill-{}-s{seed}-{n}",
        std::process::id()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(v: f32) -> ClientState {
        let mut model = TensorStore::new();
        model.insert("state.p.w", Tensor::new(vec![2, 3], vec![v; 6]).unwrap());
        model.insert("state.t", Tensor::scalar(v));
        let mut s = ClientState::new();
        s.insert("model", model);
        s.insert("pending", TensorStore::new());
        s
    }

    #[test]
    fn lazy_init_runs_once_per_client() {
        let mut store = ClientStateStore::new(4);
        let inits = std::cell::Cell::new(0);
        let init = |i: usize| {
            inits.set(inits.get() + 1);
            Ok(state(i as f32))
        };
        store.ensure_loaded(&[1, 3], init).unwrap();
        store.ensure_loaded(&[1, 3], init).unwrap();
        assert_eq!(inits.get(), 2);
        assert_eq!(store.loaded_count(), 2);
        assert!(!store.all_loaded());
        assert_eq!(store.get(1).unwrap().get("model").unwrap().get("state.t").unwrap().item(), 1.0);
        assert!(store.get(0).is_err());
    }

    #[test]
    fn loaded_mut_hands_out_disjoint_slots_in_id_order() {
        let mut store = ClientStateStore::new(5);
        store.ensure_loaded(&[0, 2, 4], |i| Ok(state(i as f32))).unwrap();
        let mut views = store.loaded_mut(&[0, 2, 4]).unwrap();
        assert_eq!(views.len(), 3);
        for (j, v) in views.iter_mut().enumerate() {
            v.get_mut("model").unwrap().get_mut("state.t").unwrap().scale(10.0);
            let expect = (j * 2) as f32 * 10.0;
            assert_eq!(v.get("model").unwrap().get("state.t").unwrap().item(), expect);
        }
        assert!(store.loaded_mut(&[1]).is_err(), "non-resident rejected");
    }

    #[test]
    fn spill_roundtrip_is_bit_exact() {
        let dir = scratch_dir(42);
        let mut store = ClientStateStore::with_spill(3, dir).unwrap();
        store.ensure_loaded(&[0, 1, 2], |i| {
            let mut s = state(i as f32 + 0.1);
            // exercise odd values incl. negative zero and subnormals
            s.get_mut("model").unwrap().insert(
                "state.odd",
                Tensor::new(vec![3], vec![-0.0, f32::MIN_POSITIVE / 2.0, 1e-38]).unwrap(),
            );
            Ok(s)
        }).unwrap();
        let before: Vec<u32> = store.get(1).unwrap().get("model").unwrap().get("state.odd")
            .unwrap().data().iter().map(|v| v.to_bits()).collect();
        let spilled = store.spill_except(&[0]).unwrap();
        assert_eq!(spilled, 2);
        assert_eq!(store.loaded_count(), 1);
        store.ensure_loaded(&[1], |_| unreachable!("spilled, not uninit")).unwrap();
        let after: Vec<u32> = store.get(1).unwrap().get("model").unwrap().get("state.odd")
            .unwrap().data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(before, after);
        assert_eq!(
            store.get(1).unwrap().get("model").unwrap().get("state.p.w").unwrap().shape(),
            &[2, 3]
        );
    }

    #[test]
    fn visit_all_bounds_residency_to_keep_set() {
        let dir = scratch_dir(43);
        let mut store = ClientStateStore::with_spill(6, dir).unwrap();
        store.ensure_loaded(&[2, 3], |i| Ok(state(i as f32))).unwrap();
        let mut seen = Vec::new();
        store
            .visit_all(&[2, 3], |i| Ok(state(i as f32)), |i, s| {
                seen.push((i, s.get("model").unwrap().get("state.t").unwrap().item()));
                Ok(())
            })
            .unwrap();
        assert_eq!(seen, (0..6).map(|i| (i, i as f32)).collect::<Vec<_>>());
        // only the keep set stays resident after the sweep
        assert_eq!(store.loaded_ids(), vec![2, 3]);
    }

    #[test]
    fn repeated_readonly_sweeps_reuse_spill_files_without_reinit() {
        let dir = scratch_dir(44);
        let mut store = ClientStateStore::with_spill(5, dir.clone()).unwrap();
        // first sweep: keep {1}; others are initialized and written once
        store
            .visit_all(&[1], |i| Ok(state(i as f32)), |_, _| Ok(()))
            .unwrap();
        assert_eq!(store.loaded_ids(), vec![1]);
        let count_files = || std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(count_files(), 4);
        // second sweep: spilled clients must come off their files (init
        // would panic) and the files must survive the read-only visit
        let mut seen = Vec::new();
        store
            .visit_all(
                &[1],
                |i| panic!("client {i} re-initialized on a read-only sweep"),
                |i, s| {
                    seen.push((i, s.get("model").unwrap().get("state.t").unwrap().item()));
                    Ok(())
                },
            )
            .unwrap();
        assert_eq!(seen, (0..5).map(|i| (i, i as f32)).collect::<Vec<_>>());
        assert_eq!(count_files(), 4, "read-only sweep must not consume spill files");
        assert_eq!(store.loaded_ids(), vec![1]);
    }

    #[test]
    fn snapshot_codec_roundtrip_is_bit_exact() {
        let dir = scratch_dir(45);
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = TensorStore::new();
        s.insert(
            "pg.w",
            Tensor::new(vec![2, 2], vec![-0.0, 1.5, f32::MIN_POSITIVE / 2.0, -3.25]).unwrap(),
        );
        s.insert("c.w", Tensor::scalar(0.125));
        let path = dir.join("snap_0.bin");
        write_snapshot(&path, &s).unwrap();
        let back = read_snapshot(&path).unwrap();
        assert_eq!(back.len(), 2);
        let bits = |st: &TensorStore, k: &str| -> Vec<u32> {
            st.get(k).unwrap().data().iter().map(|v| v.to_bits()).collect()
        };
        assert_eq!(bits(&s, "pg.w"), bits(&back, "pg.w"));
        assert_eq!(bits(&s, "c.w"), bits(&back, "c.w"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn no_spill_mode_keeps_everything_resident() {
        let mut store = ClientStateStore::new(3);
        store.ensure_loaded(&[0, 1, 2], |i| Ok(state(i as f32))).unwrap();
        assert_eq!(store.spill_except(&[0]).unwrap(), 0);
        assert!(store.all_loaded());
    }

    #[test]
    fn shard_residency_tracks_sample_not_fleet() {
        // a fleet-scale store costs nothing until clients materialize:
        // only the sampled ids ever occupy memory or bookkeeping
        let mut store = ClientStateStore::new(100_000);
        assert_eq!(store.len(), 100_000);
        assert_eq!(store.loaded_count(), 0);
        assert_eq!(store.resident_bytes(), 0);
        let sample: Vec<usize> = (0..500).map(|j| j * 200 + 7).collect();
        store.ensure_loaded(&sample, |i| Ok(state(i as f32))).unwrap();
        assert_eq!(store.loaded_count(), 500);
        assert_eq!(store.loaded_ids(), sample, "sorted id order preserved");
        assert!(!store.all_loaded());
        let per_state = state(0.0).byte_size();
        assert_eq!(store.resident_bytes(), 500 * per_state);
        // unsampled ids are absent, not placeholders
        assert!(store.get(8).is_err());
        // disjoint &mut across shard collisions (500 ids over 16 shards
        // guarantees many same-shard neighbors)
        let mut views = store.loaded_mut(&sample).unwrap();
        for v in views.iter_mut() {
            v.get_mut("model").unwrap().get_mut("state.t").unwrap().scale(2.0);
        }
        for (j, &id) in sample.iter().enumerate() {
            let got = store.get(id).unwrap().get("model").unwrap().get("state.t").unwrap().item();
            assert_eq!(got, id as f32 * 2.0, "sample index {j}");
        }
    }

    #[test]
    fn shard_spill_except_walks_resident_only() {
        let dir = scratch_dir(46);
        let mut store = ClientStateStore::with_spill(100_000, dir.clone()).unwrap();
        let sample = [3usize, 41, 999, 7_000, 31_337, 54_321, 70_001, 99_999];
        store.ensure_loaded(&sample, |i| Ok(state(i as f32))).unwrap();
        let keep = [41usize, 31_337, 99_999];
        let spilled = store.spill_except(&keep).unwrap();
        assert_eq!(spilled, sample.len() - keep.len());
        assert_eq!(store.loaded_ids(), keep.to_vec());
        assert_eq!(
            std::fs::read_dir(&dir).unwrap().count(),
            sample.len() - keep.len(),
            "one spill file per evicted client"
        );
        // a second pass over the same keep set evicts nothing
        assert_eq!(store.spill_except(&keep).unwrap(), 0);
        // spilled clients reload from disk, never re-init
        store
            .ensure_loaded(&[3, 7_000], |i| panic!("client {i} re-initialized"))
            .unwrap();
        assert_eq!(store.loaded_ids(), vec![3, 41, 7_000, 31_337, 99_999]);
        assert_eq!(
            store.get(7_000).unwrap().get("model").unwrap().get("state.t").unwrap().item(),
            7_000.0
        );
    }

    #[test]
    fn pair_mut_and_take_tensor() {
        let mut s = state(1.0);
        s.insert("ci", {
            let mut t = TensorStore::new();
            t.insert("ci.w", Tensor::scalar(5.0));
            t
        });
        let (model, ci) = s.pair_mut("model", "ci").unwrap();
        model.get_mut("state.t").unwrap().scale(2.0);
        ci.get_mut("ci.w").unwrap().scale(3.0);
        assert_eq!(s.get("ci").unwrap().get("ci.w").unwrap().item(), 15.0);
        assert!(s.pair_mut("model", "model").is_err());
        assert!(s.take_tensor("pending", "grad_a").is_none());
        s.get_mut("pending").unwrap().insert("grad_a", Tensor::scalar(9.0));
        assert_eq!(s.take_tensor("pending", "grad_a").unwrap().item(), 9.0);
        assert!(s.take_tensor("pending", "grad_a").is_none());
    }
}
