//! SplitFed (Thapa et al., 2020): split learning + federated averaging of
//! the client-side models after every round.
//!
//! Each client keeps its own client model; training within a round is the
//! same synchronous SL exchange as SL-basic, and at the round boundary the
//! fed server averages the client models (weights only; Adam moments stay
//! local) and broadcasts the average — costing 2 x client-params per
//! client per round on top of the activation traffic.
//!
//! **Driver mapping** (DESIGN.md §6): the per-batch exchange updates one
//! shared server model in visiting order, so `fan_out` is `false` and the
//! chain runs inside `merge_round`, streaming batches one client at a
//! time (bounded memory) at any `--threads`; per-client models live in
//! the pooled [`ClientStateStore`], so sampled runs only keep the round's
//! participants resident. Fed-averaging and the broadcast cover the
//! participant set, with weights renormalized over it under sampling.

use std::sync::Arc;

use anyhow::Result;

use crate::driver::{ClientState, ClientStateStore, Protocol, RoundReport};
use crate::protocols::common::{
    data_weights, eval_split, eval_split_streamed, round_weights, Env,
};
use crate::runtime::{Artifact, TensorStore};

/// SplitFed behind the [`Protocol`] trait.
pub struct SplitFedProtocol {
    client_fwd: Arc<Artifact>,
    server_step: Arc<Artifact>,
    server_eval: Arc<Artifact>,
    client_bwd: Arc<Artifact>,
    init_client_artifact: String,
    init_server_artifact: String,
    server_state: TensorStore,
    weights: Vec<f32>,
    fwd_flops: f64,
    bwd_flops: f64,
    server_flops: f64,
    act_bytes: usize,
    fed_bytes: usize,
    loss_sum: f64,
    loss_count: f64,
}

impl SplitFedProtocol {
    pub fn new(env: &Env) -> Result<Self> {
        let cfg = env.cfg;
        let k = cfg.split_k();
        let tag = cfg.config_tag();
        Ok(Self {
            client_fwd: env.art_split("client_fwd")?,
            server_step: env.art_split("sl_server_step")?,
            server_eval: env.art_split("sl_server_eval")?,
            client_bwd: env.art_split("client_bwd")?,
            init_client_artifact: format!("{tag}_init_sl_client"),
            init_server_artifact: format!("{tag}_init_sl_server"),
            server_state: TensorStore::new(),
            weights: data_weights(&env.clients),
            fwd_flops: env.spec.client_fwd_step_flops(k),
            bwd_flops: env.spec.client_bwd_step_flops(k),
            server_flops: env.spec.server_step_flops(k, false),
            act_bytes: env.spec.act_batch_bytes(k),
            fed_bytes: env.spec.client_params(k) * 4,
            loss_sum: 0.0,
            loss_count: 0.0,
        })
    }
}

impl Protocol for SplitFedProtocol {
    type Update = ();

    fn name(&self) -> &'static str {
        "SplitFed"
    }

    fn init_state(&mut self, env: &mut Env) -> Result<()> {
        self.server_state = env.init_state(&self.init_server_artifact, env.server_seed())?;
        Ok(())
    }

    fn init_client(&self, env: &Env, client: usize) -> Result<ClientState> {
        let model = env.init_state(&self.init_client_artifact, env.client_seed(client))?;
        let mut state = ClientState::new();
        state.insert("model", model);
        Ok(state)
    }

    fn fan_out(&self) -> bool {
        false
    }

    fn begin_round(
        &mut self,
        _env: &mut Env,
        _round: usize,
        _participants: &[usize],
    ) -> Result<()> {
        self.loss_sum = 0.0;
        self.loss_count = 0.0;
        Ok(())
    }

    fn merge_round(
        &mut self,
        env: &mut Env,
        store: &mut ClientStateStore,
        round: usize,
        _step: usize,
        participants: &[usize],
        _updates: Vec<(usize, ())>,
    ) -> Result<()> {
        // visiting order shuffled per round (SplitFed trains clients in
        // parallel; sequential visits in shuffled order approximate the
        // same update stream on a single shared server model)
        let mut order: Vec<usize> = participants.to_vec();
        env.rng.derive("splitfed-order", round as u64).shuffle(&mut order);

        for &i in &order {
            for b in env.train_batches(i, round) {
                let model = store.get_mut(i)?.get_mut("model")?;
                let root = model.sub("state");
                let fwd = self.client_fwd.call(&[&root], &[("x", &b.x)])?;
                let acts = fwd.get("acts")?;
                env.meter.add_client_flops(self.fwd_flops);
                let up = env.up_payload_bytes(acts);
                env.meter.add_up(up);

                let mut out = self
                    .server_step
                    .call(&[&self.server_state], &[("a", acts), ("y", &b.y)])?;
                out.write_state(&mut self.server_state);
                self.loss_sum += out.scalar("loss")? as f64;
                self.loss_count += 1.0;
                env.meter.add_server_flops(self.server_flops);
                env.meter.add_down(self.act_bytes);

                let grad_a = out.take("grad_a")?;
                let mut cb = self
                    .client_bwd
                    .call(&[&*model], &[("x", &b.x), ("grad_a", &grad_a)])?;
                cb.write_state(model);
                env.meter.add_client_flops(self.bwd_flops);
            }
        }
        Ok(())
    }

    fn end_round(
        &mut self,
        env: &mut Env,
        store: &mut ClientStateStore,
        _round: usize,
        participants: &[usize],
    ) -> Result<RoundReport> {
        // federated averaging of the participating client models (pc.* only)
        let w = round_weights(&self.weights, participants);
        let mut refs: Vec<&TensorStore> = Vec::with_capacity(participants.len());
        for &i in participants {
            refs.push(store.get(i)?.get("model")?);
        }
        let mut avg = refs[0].clone();
        avg.set_weighted_sum(&refs, &w, |key| key.starts_with("state.pc."))?;
        drop(refs);
        let avg_keys: Vec<String> = avg.keys_under("state.pc").cloned().collect();
        for &i in participants {
            let s = store.get_mut(i)?.get_mut("model")?;
            for key in &avg_keys {
                s.insert(key.clone(), avg.get(key)?.clone());
            }
            // upload own model, download the average
            env.meter.add_up(self.fed_bytes);
            env.meter.add_down(self.fed_bytes);
        }
        Ok(RoundReport {
            phase: "train".into(),
            train_loss: if self.loss_count > 0.0 {
                self.loss_sum / self.loss_count
            } else {
                0.0
            },
            mask_density: 1.0,
            selected: participants.to_vec(),
        })
    }

    fn eval(&self, env: &Env, store: &mut ClientStateStore) -> Result<f64> {
        let n = env.cfg.clients;
        let server_root = self.server_state.sub("state");
        let acc = if store.all_loaded() {
            // full-participation path: identical to the pre-redesign eval
            let mut roots = Vec::with_capacity(n);
            for i in 0..n {
                roots.push(store.get(i)?.get("model")?.sub("state"));
            }
            eval_split(env, &self.client_fwd, &self.server_eval, &roots, |_| {
                vec![server_root.clone()]
            })?
        } else {
            eval_split_streamed(
                env,
                &self.client_fwd,
                &self.server_eval,
                store,
                |i| self.init_client(env, i),
                |st: &ClientState| Ok(st.get("model")?.sub("state")),
                |_, _: &ClientState| Ok(vec![server_root.clone()]),
            )?
        };
        Ok(acc.mean_client_pct())
    }
}
