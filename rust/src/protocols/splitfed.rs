//! SplitFed (Thapa et al., 2020): split learning + federated averaging of
//! the client-side models after every round.
//!
//! Each client keeps its own client model; training within a round is the
//! same synchronous SL exchange as SL-basic, and at the round boundary the
//! fed server averages the client models (weights only; Adam moments stay
//! local) and broadcasts the average — costing 2 x client-params per
//! client per round on top of the activation traffic.
//!
//! **Parallelism** (DESIGN.md §5): the per-batch exchange updates one
//! shared server model in visiting order, so training stays sequential at
//! any `--threads` and streams batches one client at a time (bounded
//! memory); the engine fans out the split evaluation, which is
//! per-client independent.

use anyhow::Result;

use crate::metrics::RoundStat;
use crate::protocols::common::{data_weights, eval_split, Env};
use crate::protocols::RunResult;
use crate::runtime::TensorStore;

pub fn run(env: &mut Env) -> Result<RunResult> {
    let cfg = env.cfg;
    let k = cfg.split_k();
    let n = cfg.clients;
    let tag = cfg.config_tag();

    let client_fwd = env.art_split("client_fwd")?;
    let server_step = env.art_split("sl_server_step")?;
    let server_eval = env.art_split("sl_server_eval")?;
    let client_bwd = env.art_split("client_bwd")?;

    let mut client_states: Vec<TensorStore> = (0..n)
        .map(|i| env.init_state(&format!("{tag}_init_sl_client"), env.client_seed(i)))
        .collect::<Result<_>>()?;
    let mut server_state =
        env.init_state(&format!("{tag}_init_sl_server"), env.server_seed())?;

    let weights = data_weights(&env.clients);
    let fwd_flops = env.spec.client_fwd_step_flops(k);
    let bwd_flops = env.spec.client_bwd_step_flops(k);
    let server_flops = env.spec.server_step_flops(k, false);
    let act_bytes = env.spec.act_batch_bytes(k);
    let fed_bytes = env.spec.client_params(k) * 4;

    for round in 0..cfg.rounds {
        let mut loss_sum = 0.0;
        let mut loss_count = 0.0;

        // visiting order shuffled per round (SplitFed trains clients in
        // parallel; sequential visits in shuffled order approximate the
        // same update stream on a single shared server model)
        let mut order: Vec<usize> = (0..n).collect();
        env.rng.derive("splitfed-order", round as u64).shuffle(&mut order);

        for &i in &order {
            for b in env.train_batches(i, round) {
                let root = client_states[i].sub("state");
                let fwd = client_fwd.call(&[&root], &[("x", &b.x)])?;
                let acts = fwd.get("acts")?;
                env.meter.add_client_flops(fwd_flops);
                let up = env.up_payload_bytes(acts);
                env.meter.add_up(up);

                let mut out =
                    server_step.call(&[&server_state], &[("a", acts), ("y", &b.y)])?;
                out.write_state(&mut server_state);
                loss_sum += out.scalar("loss")? as f64;
                loss_count += 1.0;
                env.meter.add_server_flops(server_flops);
                env.meter.add_down(act_bytes);

                let grad_a = out.take("grad_a")?;
                let mut cb = client_bwd.call(
                    &[&client_states[i]],
                    &[("x", &b.x), ("grad_a", &grad_a)],
                )?;
                cb.write_state(&mut client_states[i]);
                env.meter.add_client_flops(bwd_flops);
            }
        }

        // federated averaging of the client models (pc.* only)
        let refs: Vec<&TensorStore> = client_states.iter().collect();
        let mut avg = client_states[0].clone();
        avg.set_weighted_sum(&refs, &weights, |key| key.starts_with("state.pc."))?;
        let avg_keys: Vec<String> = avg.keys_under("state.pc").cloned().collect();
        for s in client_states.iter_mut() {
            for key in &avg_keys {
                s.insert(key.clone(), avg.get(key)?.clone());
            }
            // upload own model, download the average
            env.meter.add_up(fed_bytes);
            env.meter.add_down(fed_bytes);
        }

        let eval_now = round % cfg.eval_every == 0 || round + 1 == cfg.rounds;
        let accuracy = if eval_now {
            let roots: Vec<TensorStore> =
                client_states.iter().map(|s| s.sub("state")).collect();
            let server_root = server_state.sub("state");
            let acc = eval_split(env, &client_fwd, &server_eval, &roots, |_| {
                vec![server_root.clone()]
            })?;
            acc.mean_client_pct()
        } else {
            env.recorder.last_accuracy()
        };

        env.recorder.push(RoundStat {
            round,
            phase: "train".into(),
            train_loss: if loss_count > 0.0 { loss_sum / loss_count } else { 0.0 },
            accuracy_pct: accuracy,
            bandwidth_gb: env.meter.bandwidth_gb(),
            client_tflops: env.meter.client_tflops(),
            total_tflops: env.meter.total_tflops(),
            mask_density: 1.0,
            selected: (0..n).collect(),
        });
    }

    Ok(RunResult::from_env(env, &env.recorder, &env.meter))
}
