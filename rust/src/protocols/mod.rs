//! Protocol implementations: AdaSplit (the paper's contribution) plus the
//! six baselines it is evaluated against.
//!
//! Every protocol is a state machine over `TensorStore`s driven by the
//! AOT-compiled step artifacts; the only numerics that happen in Rust are
//! FedAvg-family parameter aggregation (plain weighted sums) and the UCB
//! bookkeeping — everything differentiable lives in the artifacts.
//!
//! No protocol owns a round loop: each one implements the
//! [`crate::driver::Protocol`] client-step/server-merge API and is run by
//! the generic [`crate::driver`] round driver, which owns participant
//! scheduling (`--participation`), the [`crate::engine`] fan-out
//! (`cfg.threads`), cost-meter merging, and round recording. Results
//! merge in client-id order, so every protocol is bit-identical across
//! thread counts (DESIGN.md §5–§6).

mod adasplit;
mod common;
mod fedavg;
mod fednova;
mod fedprox;
mod flbase;
mod scaffold;
mod sl_basic;
mod splitfed;

use anyhow::{ensure, Result};

use crate::config::{ExperimentConfig, ProtocolKind};
use crate::data::build_partition;
use crate::driver;
use crate::engine::par_indexed;
use crate::metrics::{c3_score, CostMeter, Recorder};
use crate::runtime::Runtime;
use crate::sim::{self, EngineKind};
use crate::util::Json;

pub use common::{
    copy_prefixed, data_weights, eval_fl, eval_split, eval_split_client, eval_split_streamed,
    round_server_store, round_weights, zeros_prefixed, Env,
};

/// Outcome of one protocol run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub protocol: String,
    pub dataset: String,
    /// final mean per-client test accuracy (%)
    pub accuracy: f64,
    /// converged accuracy = best eval point (%), the paper's convention
    pub best_accuracy: f64,
    pub bandwidth_gb: f64,
    pub client_tflops: f64,
    pub total_tflops: f64,
    pub c3_score: f64,
    /// mean server-mask density at the end (AdaSplit; 1.0 otherwise)
    pub mask_density: f64,
    pub rounds: usize,
    /// configured per-round participation fraction (1.0 = all clients)
    pub participation: f64,
    /// mean clients sampled per round by the scheduler
    pub sampled_clients_per_round: f64,
    /// scheduler the run used (`sync-all` | `sampled-sync` | `async-bounded`)
    pub scheduler: String,
    /// total simulated wall-clock of the run, in baseline-round units
    /// (the scheduler's virtual clock at the last merge; `rounds` for a
    /// synchronous run over uniform client speeds)
    pub sim_time: f64,
    /// staleness of the stalest contribution merged anywhere in the run,
    /// in rounds (0 for every synchronous scheduler; never exceeds the
    /// `AsyncBounded` staleness bound)
    pub max_staleness: usize,
    /// staleness-versioning mode: `true` = per-client model versioning
    /// (`--delayed-gradients`, stale clients trained against the snapshot
    /// they pulled); `false` = PR 3 cadence-only staleness
    pub delayed_gradients: bool,
    /// `true` = the UCB bound controller re-picked the staleness bound
    /// online (`--adaptive-bound`); `false` = the bound was a fixed flag
    pub adaptive: bool,
    /// staleness bound in effect for the final round (the configured
    /// bound for a fixed async run; 0 for synchronous schedulers; the
    /// controller's last arm under `--adaptive-bound`)
    pub final_bound: usize,
    /// rounds whose bound differed from the previous round's — 0 for
    /// every fixed-bound run, and for an adaptive run whose controller
    /// kept one arm throughout (e.g. a singleton candidate set)
    pub bound_switches: usize,
    /// which driver executed the run (`rounds` | `events`)
    pub engine: String,
    /// server merge policy (`round` for both the rounds driver and the
    /// degenerate event policy; `arrival` / `batch:K` / `window:DT` for
    /// continuous event-driven merging)
    pub merge_policy: String,
    /// events popped off the heap by the event driver (0 under the
    /// rounds engine — the barrier loop processes no events)
    pub events_processed: usize,
    /// effective churn events (joins + leaves) the scenario applied —
    /// 0 for closed-world runs (DESIGN.md §12)
    pub churn_events: usize,
    /// effective rate-change events the scenario applied (flaky-link
    /// episode boundaries, or replayed rate lines)
    pub rate_events: usize,
    /// scenario source: `none` (closed world) | `synthetic` | `replay`
    pub scenario: String,
}

impl RunResult {
    /// JSON export (results/ directory, EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("protocol".into(), Json::Str(self.protocol.clone()));
        m.insert("dataset".into(), Json::Str(self.dataset.clone()));
        m.insert("accuracy".into(), Json::Num(self.accuracy));
        m.insert("best_accuracy".into(), Json::Num(self.best_accuracy));
        m.insert("bandwidth_gb".into(), Json::Num(self.bandwidth_gb));
        m.insert("client_tflops".into(), Json::Num(self.client_tflops));
        m.insert("total_tflops".into(), Json::Num(self.total_tflops));
        m.insert("c3_score".into(), Json::Num(self.c3_score));
        m.insert("mask_density".into(), Json::Num(self.mask_density));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("participation".into(), Json::Num(self.participation));
        m.insert(
            "sampled_clients_per_round".into(),
            Json::Num(self.sampled_clients_per_round),
        );
        m.insert("scheduler".into(), Json::Str(self.scheduler.clone()));
        m.insert("sim_time".into(), Json::Num(self.sim_time));
        m.insert("max_staleness".into(), Json::Num(self.max_staleness as f64));
        m.insert("delayed_gradients".into(), Json::Bool(self.delayed_gradients));
        m.insert("adaptive".into(), Json::Bool(self.adaptive));
        m.insert("final_bound".into(), Json::Num(self.final_bound as f64));
        m.insert("bound_switches".into(), Json::Num(self.bound_switches as f64));
        m.insert("engine".into(), Json::Str(self.engine.clone()));
        m.insert("merge_policy".into(), Json::Str(self.merge_policy.clone()));
        m.insert(
            "events_processed".into(),
            Json::Num(self.events_processed as f64),
        );
        m.insert("churn_events".into(), Json::Num(self.churn_events as f64));
        m.insert("rate_events".into(), Json::Num(self.rate_events as f64));
        m.insert("scenario".into(), Json::Str(self.scenario.clone()));
        Json::Obj(m)
    }

    pub(crate) fn from_env(
        env: &Env,
        recorder: &Recorder,
        meter: &CostMeter,
        scheduler: &str,
    ) -> Self {
        let best = recorder.best_accuracy();
        let acc = recorder.last_accuracy();
        let mask_density = recorder
            .rounds
            .last()
            .map(|r| r.mask_density)
            .unwrap_or(1.0);
        let sampled_clients_per_round = if recorder.rounds.is_empty() {
            env.cfg.clients as f64
        } else {
            recorder.rounds.iter().map(|r| r.participants.len() as f64).sum::<f64>()
                / recorder.rounds.len() as f64
        };
        Self {
            protocol: env.cfg.protocol.name().to_string(),
            dataset: env.cfg.dataset.name().to_string(),
            accuracy: acc,
            best_accuracy: best,
            bandwidth_gb: meter.bandwidth_gb(),
            client_tflops: meter.client_tflops(),
            total_tflops: meter.total_tflops(),
            c3_score: c3_score(best, meter.bandwidth_gb(), meter.client_tflops(), &env.cfg.budgets),
            mask_density,
            rounds: env.cfg.rounds,
            participation: env.cfg.participation,
            sampled_clients_per_round,
            scheduler: scheduler.to_string(),
            sim_time: recorder.rounds.last().map(|r| r.sim_time).unwrap_or(0.0),
            max_staleness: recorder.rounds.iter().map(|r| r.max_staleness).max().unwrap_or(0),
            delayed_gradients: env.cfg.delayed_gradients,
            adaptive: env.cfg.adaptive_bound,
            final_bound: recorder.rounds.last().map(|r| r.bound).unwrap_or(0),
            bound_switches: recorder
                .rounds
                .windows(2)
                .filter(|w| w[1].bound != w[0].bound)
                .count(),
            engine: env.cfg.engine.id().to_string(),
            merge_policy: env.cfg.merge_policy.id(),
            // the event driver overwrites these with its heap's pop
            // count and the scenario's effective-event bookkeeping
            events_processed: 0,
            churn_events: 0,
            rate_events: 0,
            scenario: "none".to_string(),
        }
    }
}

/// Run the configured protocol end to end and return its result.
pub fn run_protocol(rt: &Runtime, cfg: &ExperimentConfig) -> Result<RunResult> {
    run_protocol_recorded(rt, cfg).map(|(r, _)| r)
}

/// Like [`run_protocol`] but also hands back the full round-by-round
/// recorder (training curves, traces) for examples and figure benches.
pub fn run_protocol_recorded(
    rt: &Runtime,
    cfg: &ExperimentConfig,
) -> Result<(RunResult, Recorder)> {
    cfg.validate()?;
    run_protocol_recorded_unvalidated(rt, cfg)
}

/// Test-support entry: [`run_protocol_recorded`] minus the
/// [`ExperimentConfig::validate`] gate, so regression suites can drive
/// edge configs the CLI refuses (e.g. zero-round smoke runs pinning the
/// two engines' exit-path parity). Not part of the public surface.
#[doc(hidden)]
pub fn run_protocol_recorded_unvalidated(
    rt: &Runtime,
    cfg: &ExperimentConfig,
) -> Result<(RunResult, Recorder)> {
    let clients = build_partition(
        cfg.dataset,
        cfg.clients,
        cfg.samples_per_client,
        cfg.test_per_client,
        cfg.imbalance,
        cfg.seed,
    )?;
    let mut env = Env::new(rt, cfg, clients);
    // every protocol runs through one generic driver — the round loop or
    // the event loop per `--engine` (`dispatch`); the match only picks
    // the Protocol-trait implementation
    fn dispatch<P: driver::Protocol>(env: &mut Env, p: &mut P) -> Result<RunResult> {
        match env.cfg.engine {
            EngineKind::Rounds => driver::run(env, p),
            EngineKind::Events => sim::run_events(env, p),
        }
    }
    let result = match cfg.protocol {
        ProtocolKind::AdaSplit => {
            let mut p = adasplit::AdaSplitProtocol::new(&env)?;
            dispatch(&mut env, &mut p)?
        }
        ProtocolKind::SlBasic => {
            let mut p = sl_basic::SlBasicProtocol::new(&env)?;
            dispatch(&mut env, &mut p)?
        }
        ProtocolKind::SplitFed => {
            let mut p = splitfed::SplitFedProtocol::new(&env)?;
            dispatch(&mut env, &mut p)?
        }
        ProtocolKind::FedAvg => {
            let mut p = fedavg::protocol(&env)?;
            dispatch(&mut env, &mut p)?
        }
        ProtocolKind::FedProx => {
            let mut p = fedprox::protocol(&env)?;
            dispatch(&mut env, &mut p)?
        }
        ProtocolKind::Scaffold => {
            let mut p = scaffold::protocol(&env)?;
            dispatch(&mut env, &mut p)?
        }
        ProtocolKind::FedNova => {
            let mut p = fednova::protocol(&env)?;
            dispatch(&mut env, &mut p)?
        }
    };
    Ok((result, env.recorder))
}

/// Run `seeds.len()` independent runs and aggregate mean/std accuracy
/// (resources are averaged; they are deterministic given the config).
///
/// Runs are independent, so they fan out over the engine. The thread
/// budget is *divided*, not multiplied, across nesting levels: with
/// budget B and S seeds, min(B, S) runs execute concurrently and each
/// run's inner engine pool gets B / min(B, S) workers — so total
/// concurrency stays ~B rather than B^2. Aggregation walks the results
/// in seed order and per-run metrics are thread-count invariant, so the
/// aggregate does not depend on how the budget splits.
pub fn run_seeds(
    rt: &Runtime,
    cfg: &ExperimentConfig,
    seeds: &[u64],
) -> Result<(RunResult, f64)> {
    ensure!(!seeds.is_empty(), "run_seeds needs at least one seed");
    let (outer, per_run) = crate::engine::split_budget(cfg.effective_threads(), seeds.len());
    let run_cfg = cfg.clone().with_threads(per_run);
    let results: Vec<RunResult> = par_indexed(outer, seeds.len(), |j| {
        run_protocol(rt, &run_cfg.clone().with_seed(seeds[j]))
    })?;
    aggregate_seed_results(&results, &cfg.budgets)
}

/// Fold per-seed [`RunResult`]s into one aggregate row (+ accuracy std).
///
/// Aggregation semantics, per field class:
/// * **means** — accuracies, resources, `sim_time`, sampled clients:
///   scalar metrics that vary with the seed average coherently;
/// * **max-of-max** — `max_staleness` is already a per-run maximum, so
///   the aggregate reports the stalest merge across *all* seeds (an
///   averaged maximum would understate the bound actually exercised);
///   `final_bound` and `bound_switches` follow the same rule: the
///   controller's trajectory is seed-dependent, so the aggregate reports
///   the upper envelope (the loosest endpoint and the most switching any
///   seed saw) rather than an average that describes no run;
///   `events_processed` joins this class — event counts vary with the
///   seed's merge timing, and the envelope is the honest "how much event
///   traffic did this config generate" number; `churn_events` and
///   `rate_events` likewise (the scenario stream is seed-dependent);
/// * **invariants** — `scheduler`, `delayed_gradients`, `adaptive`,
///   `engine`, `merge_policy`, and `scenario` are functions of the
///   config, not the seed: all runs must agree, and the aggregate
///   carries the shared value (checked, so a future seed-dependent
///   scheduler choice fails loudly instead of reporting seed 0's).
pub fn aggregate_seed_results(
    results: &[RunResult],
    budgets: &crate::metrics::Budgets,
) -> Result<(RunResult, f64)> {
    ensure!(!results.is_empty(), "aggregate needs at least one result");
    for r in results {
        ensure!(
            r.scheduler == results[0].scheduler,
            "seed runs disagree on scheduler: `{}` vs `{}`",
            results[0].scheduler,
            r.scheduler
        );
        ensure!(
            r.delayed_gradients == results[0].delayed_gradients,
            "seed runs disagree on the delayed-gradients mode"
        );
        ensure!(
            r.adaptive == results[0].adaptive,
            "seed runs disagree on the adaptive-bound mode"
        );
        ensure!(
            r.engine == results[0].engine,
            "seed runs disagree on engine mode: `{}` vs `{}`",
            results[0].engine,
            r.engine
        );
        ensure!(
            r.merge_policy == results[0].merge_policy,
            "seed runs disagree on merge policy: `{}` vs `{}`",
            results[0].merge_policy,
            r.merge_policy
        );
        ensure!(
            r.scenario == results[0].scenario,
            "seed runs disagree on scenario source: `{}` vs `{}`",
            results[0].scenario,
            r.scenario
        );
    }
    let accs: Vec<f64> = results.iter().map(|r| r.best_accuracy).collect();
    let (mean, std) = crate::metrics::mean_std(&accs);
    let avg = |f: fn(&RunResult) -> f64| -> f64 {
        results.iter().map(f).sum::<f64>() / results.len() as f64
    };
    let mut agg = results[0].clone();
    agg.accuracy = avg(|r| r.accuracy);
    agg.best_accuracy = mean;
    agg.bandwidth_gb = avg(|r| r.bandwidth_gb);
    agg.client_tflops = avg(|r| r.client_tflops);
    agg.total_tflops = avg(|r| r.total_tflops);
    agg.mask_density = avg(|r| r.mask_density);
    agg.sampled_clients_per_round = avg(|r| r.sampled_clients_per_round);
    agg.sim_time = avg(|r| r.sim_time);
    agg.max_staleness = results.iter().map(|r| r.max_staleness).max().unwrap_or(0);
    agg.final_bound = results.iter().map(|r| r.final_bound).max().unwrap_or(0);
    agg.bound_switches = results.iter().map(|r| r.bound_switches).max().unwrap_or(0);
    agg.events_processed = results.iter().map(|r| r.events_processed).max().unwrap_or(0);
    agg.churn_events = results.iter().map(|r| r.churn_events).max().unwrap_or(0);
    agg.rate_events = results.iter().map(|r| r.rate_events).max().unwrap_or(0);
    agg.c3_score = c3_score(mean, agg.bandwidth_gb, agg.client_tflops, budgets);
    Ok((agg, std))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Budgets;

    fn result(best: f64, sim: f64, max_stale: usize, scheduler: &str, delayed: bool) -> RunResult {
        RunResult {
            protocol: "FedAvg".into(),
            dataset: "MixedCIFAR".into(),
            accuracy: best - 1.0,
            best_accuracy: best,
            bandwidth_gb: 2.0,
            client_tflops: 1.0,
            total_tflops: 3.0,
            c3_score: 0.0,
            mask_density: 1.0,
            rounds: 4,
            participation: 1.0,
            sampled_clients_per_round: 5.0,
            scheduler: scheduler.into(),
            sim_time: sim,
            max_staleness: max_stale,
            delayed_gradients: delayed,
            adaptive: false,
            final_bound: 0,
            bound_switches: 0,
            engine: "rounds".into(),
            merge_policy: "round".into(),
            events_processed: 0,
            churn_events: 0,
            rate_events: 0,
            scenario: "none".into(),
        }
    }

    #[test]
    fn seed_aggregation_means_maxes_and_invariants() {
        let budgets = Budgets::paper_mixed_cifar();
        let results = vec![
            result(60.0, 8.0, 1, "async-bounded", true),
            result(70.0, 12.0, 3, "async-bounded", true),
        ];
        let (agg, std) = aggregate_seed_results(&results, &budgets).unwrap();
        assert_eq!(agg.best_accuracy, 65.0, "best accuracy is the mean");
        assert_eq!(agg.accuracy, 64.0);
        assert_eq!(agg.sim_time, 10.0, "sim_time averages across seeds");
        assert_eq!(agg.max_staleness, 3, "max-of-max, not mean or seed 0's");
        assert_eq!(agg.scheduler, "async-bounded");
        assert!(agg.delayed_gradients);
        assert!(std > 0.0);

        // config-derived fields must agree across seeds
        let mixed = vec![
            result(60.0, 8.0, 1, "async-bounded", true),
            result(70.0, 12.0, 3, "sync-all", true),
        ];
        assert!(aggregate_seed_results(&mixed, &budgets).is_err());
        let mixed_mode = vec![
            result(60.0, 8.0, 1, "async-bounded", true),
            result(70.0, 12.0, 3, "async-bounded", false),
        ];
        assert!(aggregate_seed_results(&mixed_mode, &budgets).is_err());
        assert!(aggregate_seed_results(&[], &budgets).is_err());
    }

    #[test]
    fn seed_aggregation_reports_the_adaptive_upper_envelope() {
        let budgets = Budgets::paper_mixed_cifar();
        let mut a = result(60.0, 8.0, 1, "async-bounded", false);
        a.adaptive = true;
        a.final_bound = 1;
        a.bound_switches = 4;
        let mut b = result(70.0, 12.0, 3, "async-bounded", false);
        b.adaptive = true;
        b.final_bound = 4;
        b.bound_switches = 2;
        let (agg, _) = aggregate_seed_results(&[a.clone(), b.clone()], &budgets).unwrap();
        assert!(agg.adaptive);
        assert_eq!(agg.final_bound, 4, "loosest endpoint across seeds");
        assert_eq!(agg.bound_switches, 4, "most controller activity across seeds");
        // the adaptive mode is config-derived: seeds must agree
        let mut fixed = b;
        fixed.adaptive = false;
        assert!(aggregate_seed_results(&[a, fixed], &budgets).is_err());
    }

    #[test]
    fn seed_aggregation_checks_engine_agreement_and_envelopes_event_counts() {
        let budgets = Budgets::paper_mixed_cifar();
        let mut a = result(60.0, 8.0, 1, "event-driven", false);
        a.engine = "events".into();
        a.merge_policy = "batch:3".into();
        a.events_processed = 120;
        let mut b = result(70.0, 12.0, 3, "event-driven", false);
        b.engine = "events".into();
        b.merge_policy = "batch:3".into();
        b.events_processed = 95;
        let (agg, _) = aggregate_seed_results(&[a.clone(), b.clone()], &budgets).unwrap();
        assert_eq!(agg.engine, "events");
        assert_eq!(agg.merge_policy, "batch:3");
        assert_eq!(
            agg.events_processed, 120,
            "event traffic reports the upper envelope across seeds"
        );

        // engine and merge policy are config-derived: seeds must agree
        let mut rounds_run = b.clone();
        rounds_run.engine = "rounds".into();
        rounds_run.merge_policy = "round".into();
        let err = aggregate_seed_results(&[a.clone(), rounds_run], &budgets)
            .expect_err("mixed engines must be rejected")
            .to_string();
        assert!(err.contains("engine mode"), "names the disagreeing axis: {err}");
        let mut other_policy = b;
        other_policy.merge_policy = "arrival".into();
        assert!(aggregate_seed_results(&[a, other_policy], &budgets).is_err());
    }

    #[test]
    fn seed_aggregation_checks_scenario_agreement_and_envelopes_its_counts() {
        let budgets = Budgets::paper_mixed_cifar();
        let mut a = result(60.0, 8.0, 1, "event-driven", false);
        a.engine = "events".into();
        a.merge_policy = "arrival".into();
        a.scenario = "synthetic".into();
        a.churn_events = 7;
        a.rate_events = 2;
        let mut b = result(70.0, 12.0, 3, "event-driven", false);
        b.engine = "events".into();
        b.merge_policy = "arrival".into();
        b.scenario = "synthetic".into();
        b.churn_events = 4;
        b.rate_events = 9;
        let (agg, _) = aggregate_seed_results(&[a.clone(), b.clone()], &budgets).unwrap();
        assert_eq!(agg.scenario, "synthetic");
        assert_eq!(agg.churn_events, 7, "churn traffic is the upper envelope");
        assert_eq!(agg.rate_events, 9, "rate traffic is the upper envelope");

        // scenario source is config-derived: seeds must agree
        let mut closed = b;
        closed.scenario = "none".into();
        let err = aggregate_seed_results(&[a, closed], &budgets)
            .expect_err("mixed scenario sources must be rejected")
            .to_string();
        assert!(err.contains("scenario"), "names the disagreeing axis: {err}");
    }

    #[test]
    fn run_result_json_round_trips_the_event_engine_axis() {
        let mut r = result(70.0, 9.0, 2, "event-driven", false);
        r.engine = "events".into();
        r.merge_policy = "window:1.5".into();
        r.events_processed = 240;
        let parsed = Json::parse(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(parsed.get("engine").unwrap().as_str().unwrap(), "events");
        assert_eq!(
            parsed.get("merge_policy").unwrap().as_str().unwrap(),
            "window:1.5"
        );
        assert_eq!(
            parsed.get("events_processed").unwrap().as_usize().unwrap(),
            240
        );

        let fixed = result(50.0, 4.0, 0, "sync-all", false);
        let parsed = Json::parse(&fixed.to_json().to_string_pretty()).unwrap();
        assert_eq!(parsed.get("engine").unwrap().as_str().unwrap(), "rounds");
        assert_eq!(parsed.get("merge_policy").unwrap().as_str().unwrap(), "round");
        assert_eq!(parsed.get("events_processed").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn run_result_json_round_trips_the_scenario_axis() {
        let mut r = result(70.0, 9.0, 2, "event-driven", false);
        r.engine = "events".into();
        r.scenario = "replay".into();
        r.churn_events = 11;
        r.rate_events = 5;
        let parsed = Json::parse(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(parsed.get("scenario").unwrap().as_str().unwrap(), "replay");
        assert_eq!(parsed.get("churn_events").unwrap().as_usize().unwrap(), 11);
        assert_eq!(parsed.get("rate_events").unwrap().as_usize().unwrap(), 5);

        let closed = result(50.0, 4.0, 0, "sync-all", false);
        let parsed = Json::parse(&closed.to_json().to_string_pretty()).unwrap();
        assert_eq!(parsed.get("scenario").unwrap().as_str().unwrap(), "none");
        assert_eq!(parsed.get("churn_events").unwrap().as_usize().unwrap(), 0);
        assert_eq!(parsed.get("rate_events").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn run_result_json_round_trips_the_adaptive_axis() {
        // the JSON export is the results/-directory interchange format:
        // pin that the adaptive trajectory fields survive a write+parse
        // round trip with their values (not just their presence)
        let mut r = result(70.0, 9.0, 2, "async-bounded", false);
        r.adaptive = true;
        r.final_bound = 4;
        r.bound_switches = 3;
        let parsed = Json::parse(&r.to_json().to_string_pretty()).unwrap();
        assert!(parsed.get("adaptive").unwrap().as_bool().unwrap());
        assert_eq!(parsed.get("final_bound").unwrap().as_usize().unwrap(), 4);
        assert_eq!(parsed.get("bound_switches").unwrap().as_usize().unwrap(), 3);

        let fixed = result(50.0, 4.0, 0, "sync-all", false);
        let parsed = Json::parse(&fixed.to_json().to_string_pretty()).unwrap();
        assert!(!parsed.get("adaptive").unwrap().as_bool().unwrap());
        assert_eq!(parsed.get("final_bound").unwrap().as_usize().unwrap(), 0);
        assert_eq!(parsed.get("bound_switches").unwrap().as_usize().unwrap(), 0);
    }
}
