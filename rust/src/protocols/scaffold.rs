//! SCAFFOLD (Karimireddy et al., 2021): stochastic controlled averaging.
//! Local gradients are corrected by (c - ci); after each round the client
//! control variate is refreshed with option II of the paper
//! (ci' = ci - c + (pg - p_i)/(K lr)) and the server variate follows.
//! Communication is doubled (model + control variate each way), matching
//! the paper's Tables 1-2 bandwidth column (2x FedAvg).

use anyhow::Result;

use crate::protocols::flbase::{FlProtocol, FlVariant};
use crate::protocols::Env;

pub fn protocol(env: &Env) -> Result<FlProtocol> {
    FlProtocol::new(env, FlVariant::Scaffold)
}
