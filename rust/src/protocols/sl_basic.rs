//! SL-basic (Gupta & Raskar, 2018): classic split learning.
//!
//! One logical client model is handed from client to client (peer-to-peer
//! weight transfer) in round-robin order; within a client's turn, every
//! iteration is a synchronous fwd -> server-step -> grad download ->
//! client-bwd exchange. The server model is shared and updated
//! sequentially — exactly the regime whose non-IID pathology AdaSplit
//! fixes (paper §2.2 D3).
//!
//! **Parallelism** (DESIGN.md §5): the training exchange is an inherent
//! chain (one traveling client model, one shared server model updated per
//! batch), so it stays sequential at any `--threads` and streams batches
//! one client at a time (bounded memory); the engine fans out the split
//! evaluation, which is per-client independent.

use anyhow::Result;

use crate::metrics::RoundStat;
use crate::protocols::common::{eval_split, Env};
use crate::protocols::RunResult;
use crate::runtime::TensorStore;

pub fn run(env: &mut Env) -> Result<RunResult> {
    let cfg = env.cfg;
    let k = cfg.split_k();
    let n = cfg.clients;
    let tag = cfg.config_tag();

    let client_fwd = env.art_split("client_fwd")?;
    let server_step = env.art_split("sl_server_step")?;
    let server_eval = env.art_split("sl_server_eval")?;
    let client_bwd = env.art_split("client_bwd")?;

    // a single shared client model, passed around peer-to-peer
    let mut client_state: TensorStore =
        env.init_state(&format!("{tag}_init_sl_client"), env.client_seed(0))?;
    let mut server_state: TensorStore =
        env.init_state(&format!("{tag}_init_sl_server"), env.server_seed())?;

    let fwd_flops = env.spec.client_fwd_step_flops(k);
    let bwd_flops = env.spec.client_bwd_step_flops(k);
    let server_flops = env.spec.server_step_flops(k, false);
    let act_bytes = env.spec.act_batch_bytes(k);
    let handoff_bytes = env.spec.client_params(k) * 4;

    for round in 0..cfg.rounds {
        let mut loss_sum = 0.0;
        let mut loss_count = 0.0;

        for i in 0..n {
            for b in env.train_batches(i, round) {
                // client fwd (uses the traveling client model)
                let root = client_state.sub("state");
                let fwd = client_fwd.call(&[&root], &[("x", &b.x)])?;
                let acts = fwd.get("acts")?;
                env.meter.add_client_flops(fwd_flops);
                let up = env.up_payload_bytes(acts);
                env.meter.add_up(up);

                // server: train + emit grad_a
                let mut out =
                    server_step.call(&[&server_state], &[("a", acts), ("y", &b.y)])?;
                out.write_state(&mut server_state);
                loss_sum += out.scalar("loss")? as f64;
                loss_count += 1.0;
                env.meter.add_server_flops(server_flops);
                env.meter.add_down(act_bytes);

                // client bwd from the downloaded gradient
                let grad_a = out.take("grad_a")?;
                let mut cb = client_bwd.call(
                    &[&client_state],
                    &[("x", &b.x), ("grad_a", &grad_a)],
                )?;
                cb.write_state(&mut client_state);
                env.meter.add_client_flops(bwd_flops);
            }
            // hand the client model to the next client (peer transfer)
            if i + 1 < n {
                env.meter.add_peer(handoff_bytes);
            }
        }

        let eval_now = round % cfg.eval_every == 0 || round + 1 == cfg.rounds;
        let accuracy = if eval_now {
            // every client evaluates with the (single) traveling model
            let roots: Vec<TensorStore> = (0..n).map(|_| client_state.sub("state")).collect();
            let server_root = server_state.sub("state");
            let acc = eval_split(env, &client_fwd, &server_eval, &roots, |_| {
                vec![server_root.clone()]
            })?;
            acc.mean_client_pct()
        } else {
            env.recorder.last_accuracy()
        };

        env.recorder.push(RoundStat {
            round,
            phase: "train".into(),
            train_loss: if loss_count > 0.0 { loss_sum / loss_count } else { 0.0 },
            accuracy_pct: accuracy,
            bandwidth_gb: env.meter.bandwidth_gb(),
            client_tflops: env.meter.client_tflops(),
            total_tflops: env.meter.total_tflops(),
            mask_density: 1.0,
            selected: (0..n).collect(),
        });
    }

    Ok(RunResult::from_env(env, &env.recorder, &env.meter))
}
