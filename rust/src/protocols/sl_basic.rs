//! SL-basic (Gupta & Raskar, 2018): classic split learning.
//!
//! One logical client model is handed from client to client (peer-to-peer
//! weight transfer) in round-robin order; within a client's turn, every
//! iteration is a synchronous fwd -> server-step -> grad download ->
//! client-bwd exchange. The server model is shared and updated
//! sequentially — exactly the regime whose non-IID pathology AdaSplit
//! fixes (paper §2.2 D3).
//!
//! **Driver mapping** (DESIGN.md §6): the training exchange is an
//! inherent chain (one traveling client model, one shared server model
//! updated per batch), so `fan_out` is `false` and the whole chain runs
//! inside `merge_round` on the driver thread, streaming batches one
//! client at a time (bounded memory) at any `--threads`. There is no
//! per-client state at all — the traveling model lives in the protocol —
//! so the pooled store stays empty. Under per-round sampling the model
//! visits only the sampled clients.

use std::sync::Arc;

use anyhow::Result;

use crate::driver::{ClientState, ClientStateStore, Protocol, RoundReport};
use crate::protocols::common::{eval_split, Env};
use crate::runtime::{Artifact, TensorStore};

/// SL-basic behind the [`Protocol`] trait.
pub struct SlBasicProtocol {
    client_fwd: Arc<Artifact>,
    server_step: Arc<Artifact>,
    server_eval: Arc<Artifact>,
    client_bwd: Arc<Artifact>,
    init_client_artifact: String,
    init_server_artifact: String,
    /// a single shared client model, passed around peer-to-peer
    client_state: TensorStore,
    server_state: TensorStore,
    fwd_flops: f64,
    bwd_flops: f64,
    server_flops: f64,
    act_bytes: usize,
    handoff_bytes: usize,
    loss_sum: f64,
    loss_count: f64,
}

impl SlBasicProtocol {
    pub fn new(env: &Env) -> Result<Self> {
        let cfg = env.cfg;
        let k = cfg.split_k();
        let tag = cfg.config_tag();
        Ok(Self {
            client_fwd: env.art_split("client_fwd")?,
            server_step: env.art_split("sl_server_step")?,
            server_eval: env.art_split("sl_server_eval")?,
            client_bwd: env.art_split("client_bwd")?,
            init_client_artifact: format!("{tag}_init_sl_client"),
            init_server_artifact: format!("{tag}_init_sl_server"),
            client_state: TensorStore::new(),
            server_state: TensorStore::new(),
            fwd_flops: env.spec.client_fwd_step_flops(k),
            bwd_flops: env.spec.client_bwd_step_flops(k),
            server_flops: env.spec.server_step_flops(k, false),
            act_bytes: env.spec.act_batch_bytes(k),
            handoff_bytes: env.spec.client_params(k) * 4,
            loss_sum: 0.0,
            loss_count: 0.0,
        })
    }
}

impl Protocol for SlBasicProtocol {
    type Update = ();

    fn name(&self) -> &'static str {
        "SL-basic"
    }

    fn init_state(&mut self, env: &mut Env) -> Result<()> {
        self.client_state = env.init_state(&self.init_client_artifact, env.client_seed(0))?;
        self.server_state = env.init_state(&self.init_server_artifact, env.server_seed())?;
        Ok(())
    }

    fn init_client(&self, _env: &Env, _client: usize) -> Result<ClientState> {
        // the traveling model is protocol state, not per-client state
        Ok(ClientState::new())
    }

    fn fan_out(&self) -> bool {
        false
    }

    fn begin_round(
        &mut self,
        _env: &mut Env,
        _round: usize,
        _participants: &[usize],
    ) -> Result<()> {
        self.loss_sum = 0.0;
        self.loss_count = 0.0;
        Ok(())
    }

    fn merge_round(
        &mut self,
        env: &mut Env,
        _store: &mut ClientStateStore,
        round: usize,
        _step: usize,
        participants: &[usize],
        _updates: Vec<(usize, ())>,
    ) -> Result<()> {
        for (idx, &i) in participants.iter().enumerate() {
            for b in env.train_batches(i, round) {
                // client fwd (uses the traveling client model)
                let root = self.client_state.sub("state");
                let fwd = self.client_fwd.call(&[&root], &[("x", &b.x)])?;
                let acts = fwd.get("acts")?;
                env.meter.add_client_flops(self.fwd_flops);
                let up = env.up_payload_bytes(acts);
                env.meter.add_up(up);

                // server: train + emit grad_a
                let mut out = self
                    .server_step
                    .call(&[&self.server_state], &[("a", acts), ("y", &b.y)])?;
                out.write_state(&mut self.server_state);
                self.loss_sum += out.scalar("loss")? as f64;
                self.loss_count += 1.0;
                env.meter.add_server_flops(self.server_flops);
                env.meter.add_down(self.act_bytes);

                // client bwd from the downloaded gradient
                let grad_a = out.take("grad_a")?;
                let mut cb = self
                    .client_bwd
                    .call(&[&self.client_state], &[("x", &b.x), ("grad_a", &grad_a)])?;
                cb.write_state(&mut self.client_state);
                env.meter.add_client_flops(self.bwd_flops);
            }
            // hand the client model to the next client (peer transfer)
            if idx + 1 < participants.len() {
                env.meter.add_peer(self.handoff_bytes);
            }
        }
        Ok(())
    }

    fn end_round(
        &mut self,
        _env: &mut Env,
        _store: &mut ClientStateStore,
        _round: usize,
        participants: &[usize],
    ) -> Result<RoundReport> {
        Ok(RoundReport {
            phase: "train".into(),
            train_loss: if self.loss_count > 0.0 {
                self.loss_sum / self.loss_count
            } else {
                0.0
            },
            mask_density: 1.0,
            selected: participants.to_vec(),
        })
    }

    fn eval(&self, env: &Env, _store: &mut ClientStateStore) -> Result<f64> {
        // every client evaluates with the (single) traveling model
        let n = env.cfg.clients;
        let roots: Vec<TensorStore> = (0..n).map(|_| self.client_state.sub("state")).collect();
        let server_root = self.server_state.sub("state");
        let acc = eval_split(env, &self.client_fwd, &self.server_eval, &roots, |_| {
            vec![server_root.clone()]
        })?;
        Ok(acc.mean_client_pct())
    }
}
