//! FedProx (Li et al., 2020): FedAvg plus a proximal term
//! mu/2 ||p - pg||^2 in each local objective, damping client drift on
//! heterogeneous data. The gradient correction prox_mu * (p - pg) is
//! applied inside the `fl_step` artifact.

use anyhow::Result;

use crate::protocols::flbase::{run_fl, FlVariant};
use crate::protocols::{Env, RunResult};

pub fn run(env: &mut Env) -> Result<RunResult> {
    run_fl(env, FlVariant::FedProx)
}
