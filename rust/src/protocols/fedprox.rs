//! FedProx (Li et al., 2020): FedAvg plus a proximal term
//! mu/2 ||p - pg||^2 in each local objective, damping client drift on
//! heterogeneous data. The gradient correction prox_mu * (p - pg) is
//! applied inside the `fl_step` artifact.

use anyhow::Result;

use crate::protocols::flbase::{FlProtocol, FlVariant};
use crate::protocols::Env;

pub fn protocol(env: &Env) -> Result<FlProtocol> {
    FlProtocol::new(env, FlVariant::FedProx)
}
