//! Shared machinery for the federated-learning baselines.
//!
//! All four FL protocols drive the same `fl_step` artifact
//! (grad' = grad + prox_mu (p - pg) + (c - ci), then Adam) and differ only
//! in the hyperparameters they feed and how the server aggregates:
//!
//! * **FedAvg**   — prox_mu = 0, c = ci = 0, data-weighted averaging.
//! * **FedProx**  — prox_mu > 0, same averaging.
//! * **Scaffold** — control variates c/ci maintained here (option II of
//!   the paper: ci' = ci - c + (pg - p_i)/(K_i * lr)), payload doubled.
//!   Every client reads the *round-start* server variate c and the
//!   aggregate c update applies at the round boundary (the paper's server
//!   step), which is what makes the clients independent within a round.
//! * **FedNova**  — normalized averaging of local *updates*:
//!   p' = pg - tau_eff * sum_i w_i (pg - p_i)/tau_i, tau_eff = sum w_i tau_i.
//!
//! **Parallelism** (DESIGN.md §5): clients train independently from the
//! round-start global snapshot, so the whole per-client round (download,
//! local epochs, variate refresh) fans out over the engine pool; losses,
//! step counts, cost deltas, and Scaffold's c updates merge in client-id
//! order, so runs are bit-identical at any thread count.

use anyhow::Result;

use crate::metrics::RoundStat;
use crate::protocols::common::{copy_prefixed, data_weights, eval_fl, zeros_prefixed, Env};
use crate::protocols::RunResult;
use crate::runtime::{Tensor, TensorStore};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FlVariant {
    FedAvg,
    FedProx,
    Scaffold,
    FedNova,
}

/// What one client's local round hands back to the merge step.
struct ClientRound {
    loss_sum: f64,
    loss_count: f64,
    /// local steps taken (tau_i)
    tau: usize,
    /// Scaffold: (ci' - ci_old) per parameter suffix, keys `d.{s}`
    dci: Option<TensorStore>,
}

/// Scaffold server-variate update, applied once per client at the round
/// boundary: `c.{s} += d.{s} / N` where `d.{s} = ci' - ci_old`. All
/// clients of a round train against the round-start `c` (option II of the
/// paper — see the module doc); this replaced the pre-engine behavior of
/// applying each client's delta mid-round, which is a deliberate,
/// paper-faithful numerics change pinned by the unit test below.
fn apply_c_update(
    c_store: &mut TensorStore,
    suffixes: &[String],
    deltas: &TensorStore,
    n: usize,
) -> Result<()> {
    for s in suffixes {
        let mut d = deltas.get(&format!("d.{s}"))?.clone();
        d.scale(1.0 / n as f32);
        c_store.get_mut(&format!("c.{s}"))?.axpy(1.0, &d)?;
    }
    Ok(())
}

pub fn run_fl(env: &mut Env, variant: FlVariant) -> Result<RunResult> {
    let cfg = env.cfg;
    let n = cfg.clients;
    let tag = cfg.dataset.tag();

    let fl_step = env.art_ds("fl_step")?;
    let fl_eval = env.art_ds("fl_eval")?;

    // per-client full-model states (Adam moments stay local across rounds)
    let mut client_states: Vec<TensorStore> = (0..n)
        .map(|i| env.init_state(&format!("{tag}_init_fl"), env.client_seed(i)))
        .collect::<Result<_>>()?;

    // the global model: canonical keys `p.*` (feedable to fl_eval)
    let mut global = TensorStore::new();
    copy_prefixed(&client_states[0], "state.p", &mut global, "p");

    // control variates (Scaffold) / zero placeholders (everyone else)
    let mut c_store = zeros_prefixed(&client_states[0], "state.p", "c");
    let mut ci_stores: Vec<TensorStore> = (0..n)
        .map(|_| zeros_prefixed(&client_states[0], "state.p", "ci"))
        .collect();

    let weights = data_weights(&env.clients);
    let prox_mu = Tensor::scalar(match variant {
        FlVariant::FedProx => cfg.prox_mu,
        _ => 0.0,
    });
    let lr = env.rt.manifest.lr;
    let step_flops = env.spec.fl_step_flops();
    let model_bytes = env.spec.full_params() * 4;
    // parameter suffixes ("conv1.w", ...) for aggregation arithmetic
    let suffixes: Vec<String> = global
        .names()
        .map(|k| k.strip_prefix("p.").unwrap().to_string())
        .collect();

    let pool = env.pool();

    for round in 0..cfg.rounds {
        let mut loss_sum = 0.0;
        let mut loss_count = 0.0;

        // snapshot of the round-start global model as `pg.*`
        let mut pg_store = TensorStore::new();
        copy_prefixed(&global, "p", &mut pg_store, "pg");
        let mut taus = vec![0usize; n];

        // -- per-client local rounds, fanned out over the pool: client i
        //    mutates only its own model state and control variate --------
        let mut pairs: Vec<(&mut TensorStore, &mut TensorStore)> =
            client_states.iter_mut().zip(ci_stores.iter_mut()).collect();
        let outcomes = pool.run_mut(&mut pairs, |i, pair| {
            let (cs, ci) = &mut *pair;
            // download the global model
            for s in &suffixes {
                let t = global.get(&format!("p.{s}"))?.clone();
                cs.insert(format!("state.p.{s}"), t);
            }

            let mut loss_sum = 0.0;
            let mut loss_count = 0.0;
            let mut tau = 0usize;
            for _epoch in 0..cfg.local_epochs {
                for b in env.train_batches(i, round) {
                    let mut out = fl_step.call(
                        &[&**cs, &pg_store, &c_store, &**ci],
                        &[("prox_mu", &prox_mu), ("x", &b.x), ("y", &b.y)],
                    )?;
                    out.write_state(cs);
                    loss_sum += out.scalar("loss")? as f64;
                    loss_count += 1.0;
                    tau += 1;
                }
            }

            let mut dci = None;
            if variant == FlVariant::Scaffold && tau > 0 {
                // ci' = ci - c + (pg - p_i) / (K_i * lr)
                let scale = 1.0 / (tau as f32 * lr);
                let mut deltas = TensorStore::new();
                for s in &suffixes {
                    let pg = pg_store.get(&format!("pg.{s}"))?;
                    let pi = cs.get(&format!("state.p.{s}"))?;
                    let cg = c_store.get(&format!("c.{s}"))?;
                    let civ = ci.get_mut(&format!("ci.{s}"))?;
                    let ci_old = civ.clone();
                    civ.axpy(-1.0, cg)?;
                    let mut delta = pg.clone();
                    delta.axpy(-1.0, pi)?;
                    delta.scale(scale);
                    civ.axpy(1.0, &delta)?;
                    // hand the raw ci' - ci_old back for the server's
                    // round-boundary c update
                    let mut d = civ.clone();
                    d.axpy(-1.0, &ci_old)?;
                    deltas.insert(format!("d.{s}"), d);
                }
                dci = Some(deltas);
            }
            Ok(ClientRound { loss_sum, loss_count, tau, dci })
        })?;
        drop(pairs);

        // -- merge in client-id order (thread-count independent) ----------
        for (i, cr) in outcomes.iter().enumerate() {
            loss_sum += cr.loss_sum;
            loss_count += cr.loss_count;
            taus[i] = cr.tau;
            env.meter.add_down(model_bytes);
            if variant == FlVariant::Scaffold {
                env.meter.add_down(model_bytes); // c travels with the model
            }
            for _ in 0..cr.tau {
                env.meter.add_client_flops(step_flops);
            }
            // upload the trained model
            env.meter.add_up(model_bytes);
            if variant == FlVariant::Scaffold {
                env.meter.add_up(model_bytes); // ci update travels back
            }
            if let Some(deltas) = &cr.dci {
                apply_c_update(&mut c_store, &suffixes, deltas, n)?;
            }
        }

        // ---- aggregation --------------------------------------------------
        match variant {
            FlVariant::FedNova => {
                let tau_eff: f32 = weights
                    .iter()
                    .zip(&taus)
                    .map(|(w, &t)| w * t as f32)
                    .sum();
                for s in &suffixes {
                    let pg = pg_store.get(&format!("pg.{s}"))?.clone();
                    // normalized update direction sum_i w_i (pg - p_i)/tau_i
                    let mut d = Tensor::zeros(pg.shape());
                    for i in 0..n {
                        if taus[i] == 0 {
                            continue;
                        }
                        let mut di = pg.clone();
                        di.axpy(-1.0, client_states[i].get(&format!("state.p.{s}"))?)?;
                        d.axpy(weights[i] / taus[i] as f32, &di)?;
                    }
                    let mut p_new = pg;
                    p_new.axpy(-tau_eff, &d)?;
                    global.insert(format!("p.{s}"), p_new);
                }
            }
            _ => {
                for s in &suffixes {
                    let shape = global.get(&format!("p.{s}"))?.shape().to_vec();
                    let mut acc = Tensor::zeros(&shape);
                    for i in 0..n {
                        acc.axpy(weights[i], client_states[i].get(&format!("state.p.{s}"))?)?;
                    }
                    global.insert(format!("p.{s}"), acc);
                }
            }
        }

        // ---- eval ----------------------------------------------------------
        let eval_now = round % cfg.eval_every == 0 || round + 1 == cfg.rounds;
        let accuracy = if eval_now {
            eval_fl(env, &fl_eval, &global)?.mean_client_pct()
        } else {
            env.recorder.last_accuracy()
        };

        env.recorder.push(RoundStat {
            round,
            phase: "train".into(),
            train_loss: if loss_count > 0.0 { loss_sum / loss_count } else { 0.0 },
            accuracy_pct: accuracy,
            bandwidth_gb: env.meter.bandwidth_gb(),
            client_tflops: env.meter.client_tflops(),
            total_tflops: env.meter.total_tflops(),
            mask_density: 1.0,
            selected: (0..n).collect(),
        });
    }

    Ok(RunResult::from_env(env, &env.recorder, &env.meter))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pins the round-boundary Scaffold semantics (option II): the merge
    /// applies every client's raw `ci' - ci_old` delta against the
    /// round-start `c`, so the post-round variate is exactly
    /// `c0 + Σ_i d_i / N` — no client's delta feeds into another client's
    /// update within the round (values chosen to be exact in f32).
    #[test]
    fn scaffold_c_update_is_round_boundary_mean_of_deltas() {
        let suffixes = vec!["w".to_string()];
        let mut c = TensorStore::new();
        c.insert("c.w", Tensor::new(vec![2], vec![0.5, -0.5]).unwrap());

        let mut d0 = TensorStore::new();
        d0.insert("d.w", Tensor::new(vec![2], vec![1.0, 2.0]).unwrap());
        let mut d1 = TensorStore::new();
        d1.insert("d.w", Tensor::new(vec![2], vec![-3.0, 4.0]).unwrap());

        apply_c_update(&mut c, &suffixes, &d0, 2).unwrap();
        apply_c_update(&mut c, &suffixes, &d1, 2).unwrap();

        // c0 + (d0 + d1) / N
        assert_eq!(c.get("c.w").unwrap().data(), &[0.5 - 1.0, -0.5 + 3.0]);
        // client deltas are read-only inputs to the merge
        assert_eq!(d0.get("d.w").unwrap().data(), &[1.0, 2.0]);
        assert_eq!(d1.get("d.w").unwrap().data(), &[-3.0, 4.0]);
    }
}
