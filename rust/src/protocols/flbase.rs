//! Shared machinery for the federated-learning baselines.
//!
//! All four FL protocols drive the same `fl_step` artifact
//! (grad' = grad + prox_mu (p - pg) + (c - ci), then Adam) and differ only
//! in the hyperparameters they feed and how the server aggregates:
//!
//! * **FedAvg**   — prox_mu = 0, c = ci = 0, data-weighted averaging.
//! * **FedProx**  — prox_mu > 0, same averaging.
//! * **Scaffold** — control variates c/ci maintained here (option II of
//!   the paper: ci' = ci - c + (pg - p_i)/(K_i * lr)), payload doubled.
//! * **FedNova**  — normalized averaging of local *updates*:
//!   p' = pg - tau_eff * sum_i w_i (pg - p_i)/tau_i, tau_eff = sum w_i tau_i.

use anyhow::Result;

use crate::metrics::RoundStat;
use crate::protocols::common::{copy_prefixed, data_weights, eval_fl, zeros_prefixed, Env};
use crate::protocols::RunResult;
use crate::runtime::{Tensor, TensorStore};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FlVariant {
    FedAvg,
    FedProx,
    Scaffold,
    FedNova,
}

pub fn run_fl(env: &mut Env, variant: FlVariant) -> Result<RunResult> {
    let cfg = env.cfg;
    let n = cfg.clients;
    let tag = cfg.dataset.tag();

    let fl_step = env.art_ds("fl_step")?;
    let fl_eval = env.art_ds("fl_eval")?;

    // per-client full-model states (Adam moments stay local across rounds)
    let mut client_states: Vec<TensorStore> = (0..n)
        .map(|i| env.init_state(&format!("{tag}_init_fl"), env.client_seed(i)))
        .collect::<Result<_>>()?;

    // the global model: canonical keys `p.*` (feedable to fl_eval)
    let mut global = TensorStore::new();
    copy_prefixed(&client_states[0], "state.p", &mut global, "p");

    // control variates (Scaffold) / zero placeholders (everyone else)
    let mut c_store = zeros_prefixed(&client_states[0], "state.p", "c");
    let mut ci_stores: Vec<TensorStore> = (0..n)
        .map(|_| zeros_prefixed(&client_states[0], "state.p", "ci"))
        .collect();

    let weights = data_weights(&env.clients);
    let prox_mu = Tensor::scalar(match variant {
        FlVariant::FedProx => cfg.prox_mu,
        _ => 0.0,
    });
    let lr = env.rt.manifest.lr;
    let step_flops = env.spec.fl_step_flops();
    let model_bytes = env.spec.full_params() * 4;
    // parameter suffixes ("conv1.w", ...) for aggregation arithmetic
    let suffixes: Vec<String> = global
        .names()
        .map(|k| k.strip_prefix("p.").unwrap().to_string())
        .collect();

    for round in 0..cfg.rounds {
        let mut loss_sum = 0.0;
        let mut loss_count = 0.0;

        // snapshot of the round-start global model as `pg.*`
        let mut pg_store = TensorStore::new();
        copy_prefixed(&global, "p", &mut pg_store, "pg");
        let mut taus = vec![0usize; n];

        for i in 0..n {
            // download the global model
            for s in &suffixes {
                let t = global.get(&format!("p.{s}"))?.clone();
                client_states[i].insert(format!("state.p.{s}"), t);
            }
            env.meter.add_down(model_bytes);
            if variant == FlVariant::Scaffold {
                env.meter.add_down(model_bytes); // c travels with the model
            }

            for _epoch in 0..cfg.local_epochs {
                for b in env.train_batches(i, round) {
                    let mut out = fl_step.call(
                        &[&client_states[i], &pg_store, &c_store, &ci_stores[i]],
                        &[("prox_mu", &prox_mu), ("x", &b.x), ("y", &b.y)],
                    )?;
                    out.write_state(&mut client_states[i]);
                    loss_sum += out.scalar("loss")? as f64;
                    loss_count += 1.0;
                    taus[i] += 1;
                    env.meter.add_client_flops(step_flops);
                }
            }

            // upload the trained model
            env.meter.add_up(model_bytes);
            if variant == FlVariant::Scaffold {
                env.meter.add_up(model_bytes); // ci update travels back
            }

            if variant == FlVariant::Scaffold && taus[i] > 0 {
                // ci' = ci - c + (pg - p_i) / (K_i * lr)
                let scale = 1.0 / (taus[i] as f32 * lr);
                for s in &suffixes {
                    let pg = pg_store.get(&format!("pg.{s}"))?;
                    let pi = client_states[i].get(&format!("state.p.{s}"))?;
                    let cg = c_store.get(&format!("c.{s}"))?.clone();
                    let ci = ci_stores[i].get_mut(&format!("ci.{s}"))?;
                    let ci_old = ci.clone();
                    ci.axpy(-1.0, &cg)?;
                    let mut delta = pg.clone();
                    delta.axpy(-1.0, pi)?;
                    delta.scale(scale);
                    ci.axpy(1.0, &delta)?;
                    // server-side running update c += (ci' - ci_old)/N
                    let mut dci = ci.clone();
                    dci.axpy(-1.0, &ci_old)?;
                    dci.scale(1.0 / n as f32);
                    c_store.get_mut(&format!("c.{s}"))?.axpy(1.0, &dci)?;
                }
            }
        }

        // ---- aggregation --------------------------------------------------
        match variant {
            FlVariant::FedNova => {
                let tau_eff: f32 = weights
                    .iter()
                    .zip(&taus)
                    .map(|(w, &t)| w * t as f32)
                    .sum();
                for s in &suffixes {
                    let pg = pg_store.get(&format!("pg.{s}"))?.clone();
                    // normalized update direction sum_i w_i (pg - p_i)/tau_i
                    let mut d = Tensor::zeros(pg.shape());
                    for i in 0..n {
                        if taus[i] == 0 {
                            continue;
                        }
                        let mut di = pg.clone();
                        di.axpy(-1.0, client_states[i].get(&format!("state.p.{s}"))?)?;
                        d.axpy(weights[i] / taus[i] as f32, &di)?;
                    }
                    let mut p_new = pg;
                    p_new.axpy(-tau_eff, &d)?;
                    global.insert(format!("p.{s}"), p_new);
                }
            }
            _ => {
                for s in &suffixes {
                    let shape = global.get(&format!("p.{s}"))?.shape().to_vec();
                    let mut acc = Tensor::zeros(&shape);
                    for i in 0..n {
                        acc.axpy(weights[i], client_states[i].get(&format!("state.p.{s}"))?)?;
                    }
                    global.insert(format!("p.{s}"), acc);
                }
            }
        }

        // ---- eval ----------------------------------------------------------
        let eval_now = round % cfg.eval_every == 0 || round + 1 == cfg.rounds;
        let accuracy = if eval_now {
            eval_fl(env, &fl_eval, &global)?.mean_client_pct()
        } else {
            env.recorder.last_accuracy()
        };

        env.recorder.push(RoundStat {
            round,
            phase: "train".into(),
            train_loss: if loss_count > 0.0 { loss_sum / loss_count } else { 0.0 },
            accuracy_pct: accuracy,
            bandwidth_gb: env.meter.bandwidth_gb(),
            client_tflops: env.meter.client_tflops(),
            total_tflops: env.meter.total_tflops(),
            mask_density: 1.0,
            selected: (0..n).collect(),
        });
    }

    Ok(RunResult::from_env(env, &env.recorder, &env.meter))
}
