//! Shared machinery for the federated-learning baselines, as one
//! [`Protocol`] implementation driven by the generic round driver.
//!
//! All four FL protocols drive the same `fl_step` artifact
//! (grad' = grad + prox_mu (p - pg) + (c - ci), then Adam) and differ only
//! in the hyperparameters they feed and how the server aggregates:
//!
//! * **FedAvg**   — prox_mu = 0, c = ci = 0, data-weighted averaging.
//! * **FedProx**  — prox_mu > 0, same averaging.
//! * **Scaffold** — control variates c/ci maintained here (option II of
//!   the paper: ci' = ci - c + (pg - p_i)/(K_i * lr)), payload doubled.
//!   Every client reads the *round-start* server variate c and the
//!   aggregate c update applies at the round boundary (the paper's server
//!   step), which is what makes the clients independent within a round.
//! * **FedNova**  — normalized averaging of local *updates*:
//!   p' = pg - tau_eff * sum_i w_i (pg - p_i)/tau_i, tau_eff = sum w_i tau_i.
//!
//! **Driver mapping**: one exchange step per round. `client_round` is the
//! whole local round (download the round-start global snapshot, local
//! epochs, Scaffold variate refresh) and runs on the engine pool;
//! `merge_round` folds losses/taus/variate deltas in client-id order;
//! `end_round` aggregates. Under per-round sampling only the participant
//! set trains and aggregation weights renormalize over it (with full
//! participation the original weights are used verbatim, keeping
//! `participation = 1.0` bit-identical to the pre-redesign loop).
//!
//! Every server-side read in `client_round` (the `pg.*` round-start
//! global and Scaffold's `c.*`) goes through [`round_server_store`], so
//! under `--delayed-gradients` a stale client genuinely trains against
//! the broadcast it pulled `s` rounds ago ([`Protocol::broadcast_state`],
//! DESIGN.md §8); the resulting stale model/variate deltas then merge
//! into the *current* server state, down-weighted by the PR 3 decay
//! scope — classic delayed-gradient application.

use std::sync::Arc;

use anyhow::Result;

use crate::driver::{ClientCtx, ClientState, ClientStateStore, ClientUpdate, Protocol, RoundReport};
use crate::protocols::common::{
    copy_prefixed, data_weights, eval_fl, round_server_store, round_weights, zeros_prefixed, Env,
};
use crate::runtime::{Artifact, Tensor, TensorStore};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FlVariant {
    FedAvg,
    FedProx,
    Scaffold,
    FedNova,
}

impl FlVariant {
    fn protocol_name(&self) -> &'static str {
        match self {
            FlVariant::FedAvg => "FedAvg",
            FlVariant::FedProx => "FedProx",
            FlVariant::Scaffold => "Scaffold",
            FlVariant::FedNova => "FedNova",
        }
    }
}

/// What one client's local round hands back to the merge step.
pub struct FlClientRound {
    loss_sum: f64,
    loss_count: f64,
    /// local steps taken (tau_i)
    tau: usize,
    /// Scaffold: (ci' - ci_old) per parameter suffix, keys `d.{s}`
    dci: Option<TensorStore>,
}

/// Scaffold server-variate update, applied once per client at the round
/// boundary: `c.{s} += d.{s} / N` where `d.{s} = ci' - ci_old` and N is
/// the *total* client count (the paper's server step — under sampling the
/// variate moves by |S|/N of the mean participant delta). All clients of
/// a round train against the round-start `c` (option II of the paper —
/// see the module doc).
fn apply_c_update(
    c_store: &mut TensorStore,
    suffixes: &[String],
    deltas: &TensorStore,
    n: usize,
) -> Result<()> {
    for s in suffixes {
        let mut d = deltas.get(&format!("d.{s}"))?.clone();
        d.scale(1.0 / n as f32);
        c_store.get_mut(&format!("c.{s}"))?.axpy(1.0, &d)?;
    }
    Ok(())
}

/// The four FedAvg-family baselines behind the [`Protocol`] trait.
pub struct FlProtocol {
    variant: FlVariant,
    fl_step: Arc<Artifact>,
    fl_eval: Arc<Artifact>,
    init_artifact: String,
    /// client 0's init output, kept so `init_client(0)` reuses it instead
    /// of re-running the init artifact (it is a pure function of the seed)
    init0: TensorStore,
    /// the global model: canonical keys `p.*` (feedable to fl_eval)
    global: TensorStore,
    /// server control variate `c.*` (zeros unless Scaffold)
    c_store: TensorStore,
    /// parameter suffixes ("conv1.w", ...) for aggregation arithmetic
    suffixes: Vec<String>,
    /// data-size weights over all clients
    weights: Vec<f32>,
    prox_mu: Tensor,
    lr: f32,
    step_flops: f64,
    model_bytes: usize,
    // -- per-round scratch --
    /// round-start global snapshot as `pg.*`
    pg_store: TensorStore,
    taus: Vec<usize>,
    loss_sum: f64,
    loss_count: f64,
}

impl FlProtocol {
    pub fn new(env: &Env, variant: FlVariant) -> Result<Self> {
        let cfg = env.cfg;
        Ok(Self {
            variant,
            fl_step: env.art_ds("fl_step")?,
            fl_eval: env.art_ds("fl_eval")?,
            init_artifact: format!("{}_init_fl", cfg.dataset.tag()),
            init0: TensorStore::new(),
            global: TensorStore::new(),
            c_store: TensorStore::new(),
            suffixes: Vec::new(),
            weights: data_weights(&env.clients),
            prox_mu: Tensor::scalar(match variant {
                FlVariant::FedProx => cfg.prox_mu,
                _ => 0.0,
            }),
            lr: env.rt.manifest.lr,
            step_flops: env.spec.fl_step_flops(),
            model_bytes: env.spec.full_params() * 4,
            pg_store: TensorStore::new(),
            taus: vec![0; cfg.clients],
            loss_sum: 0.0,
            loss_count: 0.0,
        })
    }

    fn is_scaffold(&self) -> bool {
        self.variant == FlVariant::Scaffold
    }
}

impl Protocol for FlProtocol {
    type Update = FlClientRound;

    fn name(&self) -> &'static str {
        self.variant.protocol_name()
    }

    fn init_state(&mut self, env: &mut Env) -> Result<()> {
        // the global model starts as client 0's init (the pre-redesign
        // behavior); the init output is kept so client 0's own lazy
        // first-touch reuses it instead of re-running the artifact
        self.init0 = env.init_state(&self.init_artifact, env.client_seed(0))?;
        self.global = TensorStore::new();
        copy_prefixed(&self.init0, "state.p", &mut self.global, "p");
        self.c_store = zeros_prefixed(&self.init0, "state.p", "c");
        self.suffixes = self
            .global
            .names()
            .map(|k| k.strip_prefix("p.").unwrap().to_string())
            .collect();
        Ok(())
    }

    fn init_client(&self, env: &Env, client: usize) -> Result<ClientState> {
        // per-client full-model state (Adam moments stay local across
        // rounds) + control variate (zeros placeholder unless Scaffold)
        let model = if client == 0 {
            self.init0.clone()
        } else {
            env.init_state(&self.init_artifact, env.client_seed(client))?
        };
        let ci = zeros_prefixed(&model, "state.p", "ci");
        let mut state = ClientState::new();
        state.insert("model", model);
        state.insert("ci", ci);
        Ok(state)
    }

    fn broadcast_state(&self) -> Option<TensorStore> {
        // what a client downloads at round start: the round-start global
        // (under the `pg.*` keys the step artifact reads) plus the server
        // control variate `c.*` (zeros unless Scaffold). The driver
        // snapshots this under --delayed-gradients so a stale client
        // trains against the global it actually pulled.
        let mut b = TensorStore::new();
        copy_prefixed(&self.global, "p", &mut b, "pg");
        for (k, v) in self.c_store.iter() {
            b.insert(k.clone(), v.clone());
        }
        Some(b)
    }

    fn begin_round(
        &mut self,
        _env: &mut Env,
        _round: usize,
        _participants: &[usize],
    ) -> Result<()> {
        self.pg_store = TensorStore::new();
        copy_prefixed(&self.global, "p", &mut self.pg_store, "pg");
        self.taus.iter_mut().for_each(|t| *t = 0);
        self.loss_sum = 0.0;
        self.loss_count = 0.0;
        Ok(())
    }

    fn client_round(
        &self,
        ctx: &ClientCtx<'_, '_>,
        state: &mut ClientState,
    ) -> Result<ClientUpdate<FlClientRound>> {
        let env = ctx.env;
        let i = ctx.client;
        let (cs, ci) = state.pair_mut("model", "ci")?;

        // round-start server state: the versioned snapshot this client
        // actually pulled under --delayed-gradients, the live round-start
        // stores otherwise. `pg.*` is begin_round's copy of the global
        // `p.*`, so the live path reads the same bits as before.
        let pg_store = round_server_store(ctx, &self.pg_store);
        let c_store = round_server_store(ctx, &self.c_store);

        // download the (possibly stale) global model
        for s in &self.suffixes {
            let t = pg_store.get(&format!("pg.{s}"))?.clone();
            cs.insert(format!("state.p.{s}"), t);
        }

        let mut loss_sum = 0.0;
        let mut loss_count = 0.0;
        let mut tau = 0usize;
        for _epoch in 0..env.cfg.local_epochs {
            for b in env.train_batches(i, ctx.round) {
                let mut out = self.fl_step.call(
                    &[&*cs, pg_store, c_store, &*ci],
                    &[("prox_mu", &self.prox_mu), ("x", &b.x), ("y", &b.y)],
                )?;
                out.write_state(cs);
                loss_sum += out.scalar("loss")? as f64;
                loss_count += 1.0;
                tau += 1;
            }
        }

        let mut dci = None;
        if self.is_scaffold() && tau > 0 {
            // ci' = ci - c + (pg - p_i) / (K_i * lr)
            let scale = 1.0 / (tau as f32 * self.lr);
            let mut deltas = TensorStore::new();
            for s in &self.suffixes {
                let pg = pg_store.get(&format!("pg.{s}"))?;
                let pi = cs.get(&format!("state.p.{s}"))?;
                let cg = c_store.get(&format!("c.{s}"))?;
                let civ = ci.get_mut(&format!("ci.{s}"))?;
                let ci_old = civ.clone();
                civ.axpy(-1.0, cg)?;
                let mut delta = pg.clone();
                delta.axpy(-1.0, pi)?;
                delta.scale(scale);
                civ.axpy(1.0, &delta)?;
                // hand the raw ci' - ci_old back for the server's
                // round-boundary c update
                let mut d = civ.clone();
                d.axpy(-1.0, &ci_old)?;
                deltas.insert(format!("d.{s}"), d);
            }
            dci = Some(deltas);
        }

        // client-side cost delta: the driver merges these in client-id
        // order, reproducing the pre-redesign serial accounting exactly
        let mut update = ClientUpdate::new(FlClientRound { loss_sum, loss_count, tau, dci });
        update.meter.add_down(self.model_bytes);
        if self.is_scaffold() {
            update.meter.add_down(self.model_bytes); // c travels with the model
        }
        for _ in 0..tau {
            update.meter.add_client_flops(self.step_flops);
        }
        update.meter.add_up(self.model_bytes);
        if self.is_scaffold() {
            update.meter.add_up(self.model_bytes); // ci update travels back
        }
        Ok(update)
    }

    fn merge_round(
        &mut self,
        env: &mut Env,
        _store: &mut ClientStateStore,
        _round: usize,
        _step: usize,
        _participants: &[usize],
        updates: Vec<(usize, FlClientRound)>,
    ) -> Result<()> {
        // client-id order (thread-count independent)
        for (i, cr) in &updates {
            self.loss_sum += cr.loss_sum;
            self.loss_count += cr.loss_count;
            self.taus[*i] = cr.tau;
            if let Some(deltas) = &cr.dci {
                apply_c_update(&mut self.c_store, &self.suffixes, deltas, env.cfg.clients)?;
            }
        }
        Ok(())
    }

    fn end_round(
        &mut self,
        _env: &mut Env,
        store: &mut ClientStateStore,
        _round: usize,
        participants: &[usize],
    ) -> Result<RoundReport> {
        let w = round_weights(&self.weights, participants);
        match self.variant {
            FlVariant::FedNova => {
                let tau_eff: f32 = w
                    .iter()
                    .zip(participants)
                    .map(|(wi, &i)| wi * self.taus[i] as f32)
                    .sum();
                for s in &self.suffixes {
                    let pg = self.pg_store.get(&format!("pg.{s}"))?.clone();
                    // normalized update direction sum_i w_i (pg - p_i)/tau_i
                    let mut d = Tensor::zeros(pg.shape());
                    for (j, &i) in participants.iter().enumerate() {
                        if self.taus[i] == 0 {
                            continue;
                        }
                        let mut di = pg.clone();
                        di.axpy(-1.0, store.get(i)?.get("model")?.get(&format!("state.p.{s}"))?)?;
                        d.axpy(w[j] / self.taus[i] as f32, &di)?;
                    }
                    let mut p_new = pg;
                    p_new.axpy(-tau_eff, &d)?;
                    self.global.insert(format!("p.{s}"), p_new);
                }
            }
            _ => {
                for s in &self.suffixes {
                    let shape = self.global.get(&format!("p.{s}"))?.shape().to_vec();
                    let mut acc = Tensor::zeros(&shape);
                    for (j, &i) in participants.iter().enumerate() {
                        acc.axpy(w[j], store.get(i)?.get("model")?.get(&format!("state.p.{s}"))?)?;
                    }
                    self.global.insert(format!("p.{s}"), acc);
                }
            }
        }
        Ok(RoundReport {
            phase: "train".into(),
            train_loss: if self.loss_count > 0.0 {
                self.loss_sum / self.loss_count
            } else {
                0.0
            },
            mask_density: 1.0,
            selected: participants.to_vec(),
        })
    }

    fn eval(&self, env: &Env, _store: &mut ClientStateStore) -> Result<f64> {
        // FL evaluates the *global* model on every client's test set — no
        // per-client state is needed, so sampling never touches this path
        Ok(eval_fl(env, &self.fl_eval, &self.global)?.mean_client_pct())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pins the round-boundary Scaffold semantics (option II): the merge
    /// applies every client's raw `ci' - ci_old` delta against the
    /// round-start `c`, so the post-round variate is exactly
    /// `c0 + Σ_i d_i / N` — no client's delta feeds into another client's
    /// update within the round (values chosen to be exact in f32).
    #[test]
    fn scaffold_c_update_is_round_boundary_mean_of_deltas() {
        let suffixes = vec!["w".to_string()];
        let mut c = TensorStore::new();
        c.insert("c.w", Tensor::new(vec![2], vec![0.5, -0.5]).unwrap());

        let mut d0 = TensorStore::new();
        d0.insert("d.w", Tensor::new(vec![2], vec![1.0, 2.0]).unwrap());
        let mut d1 = TensorStore::new();
        d1.insert("d.w", Tensor::new(vec![2], vec![-3.0, 4.0]).unwrap());

        apply_c_update(&mut c, &suffixes, &d0, 2).unwrap();
        apply_c_update(&mut c, &suffixes, &d1, 2).unwrap();

        // c0 + (d0 + d1) / N
        assert_eq!(c.get("c.w").unwrap().data(), &[0.5 - 1.0, -0.5 + 3.0]);
        // client deltas are read-only inputs to the merge
        assert_eq!(d0.get("d.w").unwrap().data(), &[1.0, 2.0]);
        assert_eq!(d1.get("d.w").unwrap().data(), &[-3.0, 4.0]);
    }
}
