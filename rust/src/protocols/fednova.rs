//! FedNova (Wang et al., 2020): normalized averaging. Clients may take
//! different numbers of local steps tau_i (data imbalance); averaging raw
//! parameters would bias toward heavy clients, so the server averages
//! *normalized update directions* instead:
//!   p' = pg - tau_eff * sum_i w_i (pg - p_i)/tau_i.
//! With equal tau_i this reduces to FedAvg.

use anyhow::Result;

use crate::protocols::flbase::{FlProtocol, FlVariant};
use crate::protocols::Env;

pub fn protocol(env: &Env) -> Result<FlProtocol> {
    FlProtocol::new(env, FlVariant::FedNova)
}
