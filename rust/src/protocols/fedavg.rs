//! FedAvg (McMahan et al., 2016): local SGD/Adam epochs + data-weighted
//! parameter averaging. Eq. 3 of the paper with p_i = n_i / sum(n);
//! under per-round sampling the weights renormalize over the sampled set.

use anyhow::Result;

use crate::protocols::flbase::{FlProtocol, FlVariant};
use crate::protocols::Env;

pub fn protocol(env: &Env) -> Result<FlProtocol> {
    FlProtocol::new(env, FlVariant::FedAvg)
}
