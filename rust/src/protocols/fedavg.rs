//! FedAvg (McMahan et al., 2016): local SGD/Adam epochs + data-weighted
//! parameter averaging. Eq. 3 of the paper with p_i = n_i / sum(n).

use anyhow::Result;

use crate::protocols::flbase::{run_fl, FlVariant};
use crate::protocols::{Env, RunResult};

pub fn run(env: &mut Env) -> Result<RunResult> {
    run_fl(env, FlVariant::FedAvg)
}
