//! Shared protocol machinery: the run environment, state initialization,
//! split-model evaluation, and FedAvg-family parameter plumbing.
//!
//! Evaluation fans out over the engine worker pool — per-client accuracy
//! partials are merged in client-id order (`AccuracyAccum::merge`), so the
//! result is independent of the thread count.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::data::{Batch, BatchIter, Partition, Rng};
use crate::driver::{ClientCtx, ClientState, ClientStateStore};
use crate::engine::{par_clients, ClientPool, ParallelEnv};
use crate::metrics::{AccuracyAccum, CostMeter, Recorder};
use crate::model::ModelSpec;
use crate::runtime::{Artifact, Runtime, Tensor, TensorStore};

/// Everything a protocol run needs.
pub struct Env<'a> {
    pub rt: &'a Runtime,
    pub cfg: &'a ExperimentConfig,
    /// client shards, generated lazily on first touch (the driver keeps
    /// the cache pointed at the active sample under per-round sampling)
    pub clients: Partition,
    pub spec: ModelSpec,
    pub meter: CostMeter,
    pub recorder: Recorder,
    pub rng: Rng,
    /// the run's persistent worker pool: spawned lazily on the first
    /// parallel fan-out, then reused by every per-round / per-step
    /// fan-out (no spawn/join per call)
    pool: Arc<ClientPool>,
}

impl<'a> Env<'a> {
    pub fn new(rt: &'a Runtime, cfg: &'a ExperimentConfig, clients: Partition) -> Self {
        let spec = ModelSpec::from_manifest(&rt.manifest, cfg.dataset.num_classes());
        Self {
            rt,
            cfg,
            clients,
            spec,
            meter: CostMeter::new(),
            recorder: Recorder::new(cfg.trace),
            rng: Rng::new(cfg.seed),
            pool: Arc::new(ClientPool::new(cfg.effective_threads())),
        }
    }

    /// Split-config artifact, e.g. `c10_mu1_client_step`.
    pub fn art_split(&self, suffix: &str) -> Result<Arc<Artifact>> {
        self.rt.artifact(&format!("{}_{suffix}", self.cfg.config_tag()))
    }

    /// Dataset-level artifact (FL family), e.g. `c10_fl_step`.
    pub fn art_ds(&self, suffix: &str) -> Result<Arc<Artifact>> {
        self.rt.artifact(&format!("{}_{suffix}", self.cfg.dataset.tag()))
    }

    /// The run's persistent worker pool, sized by the experiment config
    /// (`--threads`). The `Arc` handle lets the driver hold the pool
    /// across rounds while `&mut self` borrows of the env come and go;
    /// every handle shares the same warmed workers.
    pub fn pool(&self) -> Arc<ClientPool> {
        Arc::clone(&self.pool)
    }

    /// Run an `init_*` artifact and return the fresh state store
    /// (keys rooted at `state.`).
    pub fn init_state(&self, artifact: &str, seed: f32) -> Result<TensorStore> {
        let art = self.rt.artifact(artifact)?;
        let out = art.call(&[], &[("seed", &Tensor::scalar(seed))])?;
        Ok(out.into_state())
    }

    /// Per-client deterministic init seed.
    pub fn client_seed(&self, client: usize) -> f32 {
        (self.cfg.seed as f32) * 1000.0 + client as f32 + 1.0
    }

    /// Server init seed (distinct from every client seed).
    pub fn server_seed(&self) -> f32 {
        (self.cfg.seed as f32) * 1000.0 + 999.0
    }

    /// Fresh per-round training batches for one client.
    pub fn train_batches(&self, client: usize, round: usize) -> Vec<Batch> {
        let c = self.clients.get(client);
        let mut rng = self
            .rng
            .derive("epoch", (round as u64) << 32 | client as u64);
        BatchIter::train(&c.train_x, &c.train_y, self.spec.batch, &mut rng).collect()
    }

    /// Upload payload bytes for one activation batch (plus labels).
    ///
    /// With beta > 0 (Table-6 path) the activations are shipped in a
    /// bitmap sparse codec — 1 bit of occupancy per position + 4 bytes per
    /// surviving value, dropping everything with |a| <= sparse_eps — and
    /// the cheaper of {dense, sparse} encoding is charged. At beta = 0 the
    /// payload is the dense f32 batch.
    pub fn up_payload_bytes(&self, acts: &Tensor) -> usize {
        let labels = self.spec.label_batch_bytes();
        let dense = acts.byte_size();
        if self.cfg.beta > 0.0 {
            let sparse = acts.len().div_ceil(8) + acts.nnz(self.cfg.sparse_eps) * 4;
            sparse.min(dense) + labels
        } else {
            dense + labels
        }
    }
}

impl ParallelEnv for Env<'_> {
    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    fn threads(&self) -> usize {
        self.cfg.effective_threads()
    }

    fn shared_pool(&self) -> Option<&ClientPool> {
        Some(&self.pool)
    }
}

/// One client's split-model evaluation sweep: `client_fwd` on the
/// client's params, then `server_eval` on the provided store stack, over
/// every test batch. Shared by the parallel ([`eval_split`]) and
/// streaming ([`eval_split_streamed`]) paths, so both produce identical
/// arithmetic per client.
pub fn eval_split_client(
    env: &Env,
    client_fwd: &Artifact,
    server_eval: &Artifact,
    i: usize,
    client_root: &TensorStore,
    stacks: &[TensorStore],
    part: &mut AccuracyAccum,
) -> Result<()> {
    // test-split-only read: out-of-sample clients skip train synthesis
    let c = env.clients.get_for_eval(i);
    let stack_refs: Vec<&TensorStore> = stacks.iter().collect();
    for b in BatchIter::eval(&c.test_x, &c.test_y, env.spec.batch) {
        let fwd = client_fwd.call(&[client_root], &[("x", &b.x)])?;
        let acts = fwd.get("acts")?;
        let out = server_eval.call(
            &stack_refs,
            &[("a", acts), ("y", &b.y), ("valid", &b.valid)],
        )?;
        part.add(i, out.scalar("correct")? as f64, b.n_valid as f64);
    }
    Ok(())
}

/// Evaluate a split model: per client, run `client_fwd` on the client's
/// params then the provided server-eval artifact. `server_stores(i)` yields
/// the store stack for client `i`'s server-side evaluation (shared server
/// params, plus the client's mask store for AdaSplit).
///
/// Clients are evaluated concurrently on the engine pool (all inputs are
/// read-only); per-client partials merge in client-id order.
pub fn eval_split<F>(
    env: &Env,
    client_fwd: &Artifact,
    server_eval: &Artifact,
    client_roots: &[TensorStore],
    server_stores: F,
) -> Result<AccuracyAccum>
where
    F: Fn(usize) -> Vec<TensorStore> + Sync,
{
    let n = env.clients.len();
    let parts = par_clients(env, |i| {
        let stacks = server_stores(i);
        let mut part = AccuracyAccum::new(n);
        eval_split_client(env, client_fwd, server_eval, i, &client_roots[i], &stacks, &mut part)?;
        Ok(part)
    })?;
    let mut acc = AccuracyAccum::new(n);
    for part in &parts {
        acc.merge(part);
    }
    Ok(acc)
}

/// Split-model evaluation against the pooled [`ClientStateStore`]: visits
/// clients sequentially in id order, lazily materializing never-sampled
/// clients via `init` and re-spilling non-active ones right after their
/// sweep — resident memory stays bounded by the active sample even while
/// every client's test set is evaluated. Per-client partials merge in id
/// order through the same [`eval_split_client`] arithmetic as the
/// parallel path, so the result is independent of which path ran.
pub fn eval_split_streamed<I, R, S>(
    env: &Env,
    client_fwd: &Artifact,
    server_eval: &Artifact,
    store: &mut ClientStateStore,
    init: I,
    client_root: R,
    server_stores: S,
) -> Result<AccuracyAccum>
where
    I: Fn(usize) -> Result<ClientState>,
    R: Fn(&ClientState) -> Result<TensorStore>,
    S: Fn(usize, &ClientState) -> Result<Vec<TensorStore>>,
{
    let n = env.clients.len();
    let keep = store.loaded_ids();
    let mut acc = AccuracyAccum::new(n);
    store.visit_all(&keep, init, |i, state| {
        let root = client_root(state)?;
        let stacks = server_stores(i, state)?;
        let mut part = AccuracyAccum::new(n);
        eval_split_client(env, client_fwd, server_eval, i, &root, &stacks, &mut part)?;
        acc.merge(&part);
        Ok(())
    })?;
    Ok(acc)
}

/// Evaluate the full FL model on every client's test set (concurrently;
/// the global store is read-only).
pub fn eval_fl(env: &Env, fl_eval: &Artifact, global_p: &TensorStore) -> Result<AccuracyAccum> {
    let n = env.clients.len();
    let parts = par_clients(env, |i| {
        let c = env.clients.get_for_eval(i);
        let mut part = AccuracyAccum::new(n);
        for b in BatchIter::eval(&c.test_x, &c.test_y, env.spec.batch) {
            let out = fl_eval.call(
                &[global_p],
                &[("x", &b.x), ("y", &b.y), ("valid", &b.valid)],
            )?;
            part.add(i, out.scalar("correct")? as f64, b.n_valid as f64);
        }
        Ok(part)
    })?;
    let mut acc = AccuracyAccum::new(n);
    for part in &parts {
        acc.merge(part);
    }
    Ok(acc)
}

/// The round-start server store one client's `client_round` reads: the
/// versioned snapshot the client actually pulled when the driver runs
/// with `--delayed-gradients` and the scheduler reports it stale
/// (`ClientCtx::version`, DESIGN.md §8), the protocol's live store
/// otherwise. Protocols route every server-side read in `client_round`
/// through this, so true delayed-gradient semantics need no per-protocol
/// loop changes — and fresh clients take the live path, keeping the
/// cadence-only mode bit-identical.
pub fn round_server_store<'s>(
    ctx: &'s ClientCtx<'_, '_>,
    live: &'s TensorStore,
) -> &'s TensorStore {
    ctx.server_store(live)
}

/// Copy tensors from `src` to `dst`, rewriting a key prefix
/// (e.g. `state.p` -> `pg`). Returns the number of tensors copied.
pub fn copy_prefixed(src: &TensorStore, from: &str, dst: &mut TensorStore, to: &str) -> usize {
    let from_dot = format!("{from}.");
    let mut n = 0;
    for (k, v) in src.iter() {
        if let Some(rest) = k.strip_prefix(&from_dot) {
            dst.insert(format!("{to}.{rest}"), v.clone());
            n += 1;
        } else if k == from {
            dst.insert(to.to_string(), v.clone());
            n += 1;
        }
    }
    n
}

/// Build a zero-filled store mirroring `src`'s tensors under a new prefix.
pub fn zeros_prefixed(src: &TensorStore, from: &str, to: &str) -> TensorStore {
    let from_dot = format!("{from}.");
    let mut out = TensorStore::new();
    for (k, v) in src.iter() {
        if let Some(rest) = k.strip_prefix(&from_dot) {
            out.insert(format!("{to}.{rest}"), Tensor::zeros(v.shape()));
        }
    }
    out
}

/// Data-size weights p_i = n_i / sum(n) for FedAvg-family aggregation.
/// Sizes are known without materializing any shard, so this never
/// triggers lazy data generation.
pub fn data_weights(clients: &Partition) -> Vec<f32> {
    let total: usize = (0..clients.len()).map(|i| clients.train_len(i)).sum();
    (0..clients.len())
        .map(|i| clients.train_len(i) as f32 / total as f32)
        .collect()
}

/// Aggregation weights for one round's participant set: the full-client
/// weights verbatim when everyone participates (bit-parity with the
/// pre-redesign all-clients loop — no division by a computed ~1.0 sum),
/// renormalized over the sampled set otherwise.
///
/// When the driver has published staleness-decay multipliers for the
/// round (`AsyncBounded` with at least one stale contribution — see
/// [`crate::driver::stale_decay_multipliers`] and DESIGN.md §7), each
/// participant's weight is multiplied by `decay^staleness` before
/// renormalization, so stale updates count less and the weights still
/// sum to 1. Fresh rounds never open the scope, keeping both synchronous
/// paths bit-identical.
pub fn round_weights(weights: &[f32], participants: &[usize]) -> Vec<f32> {
    if let Some(decay) = crate::driver::stale_decay_multipliers(participants) {
        let raw: Vec<f32> = participants
            .iter()
            .zip(&decay)
            .map(|(&i, &m)| weights[i] * m)
            .collect();
        let sum: f32 = raw.iter().sum();
        return raw.iter().map(|w| w / sum).collect();
    }
    if participants.len() == weights.len() {
        return weights.to_vec();
    }
    let sum: f32 = participants.iter().map(|&i| weights[i]).sum();
    participants.iter().map(|&i| weights[i] / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_prefixed_rewrites() {
        let mut src = TensorStore::new();
        src.insert("state.p.w", Tensor::full(&[2], 1.0));
        src.insert("state.p.b", Tensor::full(&[2], 2.0));
        src.insert("state.m.w", Tensor::full(&[2], 3.0));
        let mut dst = TensorStore::new();
        assert_eq!(copy_prefixed(&src, "state.p", &mut dst, "pg"), 2);
        assert_eq!(dst.get("pg.w").unwrap().data()[0], 1.0);
        assert!(dst.get("pg.b").is_ok());
        assert!(dst.get("m.w").is_err());
    }

    #[test]
    fn round_weights_full_set_is_verbatim_and_subsets_renormalize() {
        let w = vec![0.1f32, 0.2, 0.3, 0.4];
        // full participation: bitwise-identical weights, no renormalization
        assert_eq!(round_weights(&w, &[0, 1, 2, 3]), w);
        // subset: renormalized over the participants
        let sub = round_weights(&w, &[1, 3]);
        assert_eq!(sub.len(), 2);
        assert!((sub[0] - 0.2 / 0.6).abs() < 1e-6);
        assert!((sub[1] - 0.4 / 0.6).abs() < 1e-6);
        assert!((sub.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zeros_prefixed_mirrors_shapes() {
        let mut src = TensorStore::new();
        src.insert("state.p.w", Tensor::full(&[3, 2], 5.0));
        let z = zeros_prefixed(&src, "state.p", "c");
        assert_eq!(z.get("c.w").unwrap().shape(), &[3, 2]);
        assert_eq!(z.get("c.w").unwrap().mean_abs(), 0.0);
    }
}
