//! AdaSplit (paper §3): the system contribution.
//!
//! * **Computation** — clients train with the local NT-Xent objective
//!   (no server gradient); the server only trains in the *global phase*
//!   (rounds >= kappa * R).
//! * **Communication** — P_si = 0 (no gradient download); in the global
//!   phase only the eta*N clients picked per iteration by the UCB
//!   orchestrator upload activations. With beta > 0, activations are L1-
//!   sparsified and shipped in a sparse encoding (Table 6).
//! * **Collaboration** — each client updates only the sparse partition of
//!   the server model allowed by its binarized mask (eq. 7); masks are
//!   learned with an L1 penalty (eq. 8) inside the `server_step` artifact.
//!
//! The Table-5 ablation (`server_grad_to_client`) additionally returns the
//! server's activation gradient to the selected client, which injects it
//! into its *next* local step (one-iteration-stale, documented in
//! DESIGN.md) — this is the row-2 "L_client + L_server" configuration.
//! The pending gradient lives in the client's `"pending"` state slot, so
//! it follows the client through the pooled store under sampling.
//!
//! **Driver mapping** (DESIGN.md §6): one exchange step per training
//! iteration `t` — `steps(round)` is the round's max batch count.
//! `client_round` is one local client step (fans out over the engine
//! pool; each client touches only its own state); `merge_round` folds
//! losses in client-id order and then runs the orchestrated server phase
//! sequentially (selected clients update the shared server model in
//! selection order, exactly as before the redesign). Under per-round
//! sampling only the participant set takes local steps and the UCB picks
//! among them.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::data::Batch;
use crate::driver::{ClientCtx, ClientState, ClientStateStore, ClientUpdate, Protocol, RoundReport};
use crate::orchestrator::UcbOrchestrator;
use crate::protocols::common::{eval_split, eval_split_streamed, Env};
use crate::runtime::{Artifact, Tensor, TensorStore};

/// Is this a per-client (mask) server-state key, as opposed to the shared
/// server parameters?
fn is_mask_key(k: &str) -> bool {
    k.starts_with("state.mask.") || k.starts_with("state.mm.") || k.starts_with("state.vm.")
}

/// AdaSplit behind the [`Protocol`] trait.
pub struct AdaSplitProtocol {
    client_step: Arc<Artifact>,
    client_fwd: Arc<Artifact>,
    server_step: Arc<Artifact>,
    server_eval: Arc<Artifact>,
    init_client_artifact: String,
    init_server_artifact: String,
    /// shared server parameters + their Adam state + step counter
    server_shared: TensorStore,
    /// per-client mask init (cloned into each client's `"mask"` slot)
    mask_template: TensorStore,
    ucb: UcbOrchestrator,
    zero_grad: Tensor,
    beta: Tensor,
    lam: Tensor,
    local_rounds: usize,
    n_select: usize,
    client_step_flops: f64,
    server_step_flops: f64,
    act_bytes: usize,
    // -- per-round scratch --
    /// the round's training batches, keyed by participant id — sized by
    /// the sample, not the fleet (lookups only; never iterated, so the
    /// map's order cannot leak into results)
    batches: HashMap<usize, Vec<Batch>>,
    t_max: usize,
    loss_sum: f64,
    loss_count: f64,
    density_sum: f64,
    density_count: f64,
    round_selected: Vec<usize>,
}

impl AdaSplitProtocol {
    pub fn new(env: &Env) -> Result<Self> {
        let cfg = env.cfg;
        let k = cfg.split_k();
        let act_shape: Vec<usize> = env.rt.manifest.config(&cfg.config_tag())?.act_shape.clone();
        Ok(Self {
            client_step: env.art_split("client_step")?,
            client_fwd: env.art_split("client_fwd")?,
            server_step: env.art_split("server_step")?,
            server_eval: env.art_split("server_eval")?,
            init_client_artifact: format!("{}_init_client", cfg.config_tag()),
            init_server_artifact: format!("{}_init_server", cfg.config_tag()),
            server_shared: TensorStore::new(),
            mask_template: TensorStore::new(),
            ucb: UcbOrchestrator::new(cfg.clients, cfg.gamma),
            zero_grad: Tensor::zeros(&act_shape),
            beta: Tensor::scalar(cfg.beta),
            lam: Tensor::scalar(cfg.lambda),
            local_rounds: cfg.local_rounds(),
            n_select: cfg.selected_per_iter(),
            client_step_flops: env.spec.client_step_flops(k),
            server_step_flops: env.spec.server_step_flops(k, true),
            act_bytes: env.spec.act_batch_bytes(k),
            batches: HashMap::new(),
            t_max: 0,
            loss_sum: 0.0,
            loss_count: 0.0,
            density_sum: 0.0,
            density_count: 0.0,
            round_selected: Vec::new(),
        })
    }
}

impl Protocol for AdaSplitProtocol {
    /// `(loss, acts)` for a client that had a batch this step.
    type Update = Option<(f64, Tensor)>;

    fn name(&self) -> &'static str {
        "AdaSplit"
    }

    fn init_state(&mut self, env: &mut Env) -> Result<()> {
        let server_init = env.init_state(&self.init_server_artifact, env.server_seed())?;
        self.server_shared = TensorStore::new();
        self.mask_template = TensorStore::new();
        for (key, t) in server_init.iter() {
            if is_mask_key(key) {
                self.mask_template.insert(key.clone(), t.clone());
            } else {
                self.server_shared.insert(key.clone(), t.clone());
            }
        }
        Ok(())
    }

    fn init_client(&self, env: &Env, client: usize) -> Result<ClientState> {
        let model = env.init_state(&self.init_client_artifact, env.client_seed(client))?;
        let mut state = ClientState::new();
        state.insert("model", model);
        state.insert("mask", self.mask_template.clone());
        // Table-5 ablation: stale server gradient to inject next local step
        state.insert("pending", TensorStore::new());
        Ok(state)
    }

    fn steps(&self, _round: usize) -> usize {
        self.t_max
    }

    fn begin_round(&mut self, env: &mut Env, round: usize, participants: &[usize]) -> Result<()> {
        // per-client batches draw from per-client derived RNG streams, so
        // materializing them concurrently is order-independent; the fan-out
        // reuses the run's persistent worker pool
        let pool = env.pool();
        let env_ref: &Env = env;
        let lists: Vec<Vec<Batch>> = pool.run(participants.len(), |j| {
            Ok(env_ref.train_batches(participants[j], round))
        })?;
        self.batches.clear();
        for (j, list) in lists.into_iter().enumerate() {
            self.batches.insert(participants[j], list);
        }
        self.t_max = participants
            .iter()
            .map(|&i| self.batches[&i].len())
            .max()
            .unwrap_or(0);
        self.loss_sum = 0.0;
        self.loss_count = 0.0;
        self.density_sum = 0.0;
        self.density_count = 0.0;
        self.round_selected = Vec::new();
        Ok(())
    }

    fn client_round(
        &self,
        ctx: &ClientCtx<'_, '_>,
        state: &mut ClientState,
    ) -> Result<ClientUpdate<Self::Update>> {
        let i = ctx.client;
        let Some(b) = self.batches.get(&i).and_then(|list| list.get(ctx.step)) else {
            // this client's shard ran out of batches before t_max
            return Ok(ClientUpdate::new(None));
        };
        // pending (stale) server gradient from the client's own state slot
        let pending = state.take_tensor("pending", "grad_a");
        // avoid cloning the (large) zero gradient on the default path
        let (ga, use_grad): (&Tensor, f32) = match &pending {
            Some(g) => (g, 1.0),
            None => (&self.zero_grad, 0.0),
        };
        let cs = state.get_mut("model")?;
        let mut out = self.client_step.call(
            &[&*cs],
            &[
                ("x", &b.x),
                ("y", &b.y),
                ("beta", &self.beta),
                ("grad_a", ga),
                ("use_grad", &Tensor::scalar(use_grad)),
            ],
        )?;
        out.write_state(cs);
        let mut update =
            ClientUpdate::new(Some((out.scalar("loss")? as f64, out.take("acts")?)));
        update.meter.add_client_flops(self.client_step_flops);
        Ok(update)
    }

    fn merge_round(
        &mut self,
        env: &mut Env,
        store: &mut ClientStateStore,
        round: usize,
        step: usize,
        _participants: &[usize],
        updates: Vec<(usize, Self::Update)>,
    ) -> Result<()> {
        // -- fold client losses/activations in client-id order ------------
        // keyed scratch sized by this step's active set, not the fleet
        // (lookups only — map order never observed)
        let mut acts: HashMap<usize, Tensor> = HashMap::with_capacity(updates.len());
        let mut active: Vec<usize> = Vec::new();
        for (i, inner) in updates {
            if let Some((loss, a)) = inner {
                self.loss_sum += loss;
                self.loss_count += 1.0;
                acts.insert(i, a);
                active.push(i);
            }
        }

        // -- global phase: orchestrated server training --------------------
        let global_phase = round >= self.local_rounds;
        if global_phase && !active.is_empty() {
            let selected = self.ucb.select_among(&active, self.n_select);
            let mut observed = Vec::with_capacity(selected.len());
            for &i in &selected {
                let a = acts.get(&i).expect("active client has acts");
                let y = &self.batches[&i][step].y;
                let mask_state = store.get_mut(i)?.get_mut("mask")?;
                let mut out = self.server_step.call(
                    &[&self.server_shared, &*mask_state],
                    &[("a", a), ("y", y), ("lam", &self.lam)],
                )?;
                out.write_state_filtered(&mut self.server_shared, |key| !is_mask_key(key));
                out.write_state_filtered(mask_state, is_mask_key);
                let loss = out.scalar("loss")? as f64;
                observed.push((i, loss));
                self.density_sum += out.scalar("mask_density")? as f64;
                self.density_count += 1.0;

                let up = env.up_payload_bytes(a);
                env.meter.add_server_flops(self.server_step_flops);
                env.meter.add_up(up);
                if env.cfg.server_grad_to_client {
                    let grad = out.take("grad_a")?;
                    store.get_mut(i)?.get_mut("pending")?.insert("grad_a", grad);
                    env.meter.add_down(self.act_bytes);
                }
                env.recorder.trace(format!(
                    "r{round} t{step} client{i} server_loss={loss:.4}"
                ));
            }
            self.ucb.update(&observed);
            for s in selected {
                if !self.round_selected.contains(&s) {
                    self.round_selected.push(s);
                }
            }
        }
        Ok(())
    }

    fn end_round(
        &mut self,
        _env: &mut Env,
        _store: &mut ClientStateStore,
        round: usize,
        _participants: &[usize],
    ) -> Result<RoundReport> {
        let global_phase = round >= self.local_rounds;
        Ok(RoundReport {
            phase: if global_phase { "global".into() } else { "local".into() },
            train_loss: if self.loss_count > 0.0 {
                self.loss_sum / self.loss_count
            } else {
                0.0
            },
            mask_density: if self.density_count > 0.0 {
                self.density_sum / self.density_count
            } else {
                1.0
            },
            selected: self.round_selected.clone(),
        })
    }

    fn eval(&self, env: &Env, store: &mut ClientStateStore) -> Result<f64> {
        let n = env.cfg.clients;
        let shared_root = self.server_shared.sub("state");
        let acc = if store.all_loaded() {
            // full-participation path: identical to the pre-redesign eval
            // (parallel over clients, partials merged in id order)
            let mut roots = Vec::with_capacity(n);
            let mut mask_roots = Vec::with_capacity(n);
            for i in 0..n {
                let st = store.get(i)?;
                roots.push(st.get("model")?.sub("state"));
                mask_roots.push(st.get("mask")?.sub("state"));
            }
            eval_split(env, &self.client_fwd, &self.server_eval, &roots, |i| {
                vec![shared_root.clone(), mask_roots[i].clone()]
            })?
        } else {
            // sampled path: stream clients through the pooled store so
            // residency stays bounded by the active sample
            eval_split_streamed(
                env,
                &self.client_fwd,
                &self.server_eval,
                store,
                |i| self.init_client(env, i),
                |st: &ClientState| Ok(st.get("model")?.sub("state")),
                |_, st: &ClientState| Ok(vec![shared_root.clone(), st.get("mask")?.sub("state")]),
            )?
        };
        Ok(acc.mean_client_pct())
    }
}
