//! AdaSplit (paper §3): the system contribution.
//!
//! * **Computation** — clients train with the local NT-Xent objective
//!   (no server gradient); the server only trains in the *global phase*
//!   (rounds >= kappa * R).
//! * **Communication** — P_si = 0 (no gradient download); in the global
//!   phase only the eta*N clients picked per iteration by the UCB
//!   orchestrator upload activations. With beta > 0, activations are L1-
//!   sparsified and shipped in a sparse encoding (Table 6).
//! * **Collaboration** — each client updates only the sparse partition of
//!   the server model allowed by its binarized mask (eq. 7); masks are
//!   learned with an L1 penalty (eq. 8) inside the `server_step` artifact.
//!
//! The Table-5 ablation (`server_grad_to_client`) additionally returns the
//! server's activation gradient to the selected client, which injects it
//! into its *next* local step (one-iteration-stale, documented in
//! DESIGN.md) — this is the row-2 "L_client + L_server" configuration.

//! **Parallelism** (DESIGN.md §5): within an iteration the local client
//! steps are independent (each touches only its own state and pending
//! gradient), so they fan out over the engine pool; the orchestrated
//! server phase stays sequential because every selected client updates the
//! shared server model in selection order. Losses, activations, and cost
//! deltas merge in client-id order, so the run is bit-identical at any
//! thread count.

use anyhow::Result;

use crate::engine::par_clients;
use crate::metrics::RoundStat;
use crate::orchestrator::UcbOrchestrator;
use crate::protocols::common::{eval_split, Env};
use crate::protocols::RunResult;
use crate::runtime::{Tensor, TensorStore};

/// Is this a per-client (mask) server-state key, as opposed to the shared
/// server parameters?
fn is_mask_key(k: &str) -> bool {
    k.starts_with("state.mask.") || k.starts_with("state.mm.") || k.starts_with("state.vm.")
}

pub fn run(env: &mut Env) -> Result<RunResult> {
    let cfg = env.cfg;
    let k = cfg.split_k();
    let n = cfg.clients;

    let client_step = env.art_split("client_step")?;
    let client_fwd = env.art_split("client_fwd")?;
    let server_step = env.art_split("server_step")?;
    let server_eval = env.art_split("server_eval")?;

    // ---- state ----------------------------------------------------------
    let mut client_states: Vec<TensorStore> = (0..n)
        .map(|i| {
            env.init_state(
                &format!("{}_init_client", cfg.config_tag()),
                env.client_seed(i),
            )
        })
        .collect::<Result<_>>()?;

    let server_init = env.init_state(
        &format!("{}_init_server", cfg.config_tag()),
        env.server_seed(),
    )?;
    // shared server parameters + their Adam state + step counter
    let mut server_shared = TensorStore::new();
    // per-client masks + their Adam state
    let mut mask_states: Vec<TensorStore> = vec![TensorStore::new(); n];
    for (key, t) in server_init.iter() {
        if is_mask_key(key) {
            for m in mask_states.iter_mut() {
                m.insert(key.clone(), t.clone());
            }
        } else {
            server_shared.insert(key.clone(), t.clone());
        }
    }

    let mut ucb = UcbOrchestrator::new(n, cfg.gamma);
    let act_shape: Vec<usize> = env.rt.manifest.config(&cfg.config_tag())?.act_shape.clone();
    let zero_grad = Tensor::zeros(&act_shape);
    // Table-5 ablation: stale server gradient to inject next local step
    let mut pending_grad: Vec<Option<Tensor>> = vec![None; n];

    let beta = Tensor::scalar(cfg.beta);
    let lam = Tensor::scalar(cfg.lambda);
    let local_rounds = cfg.local_rounds();
    let n_select = cfg.selected_per_iter();

    let client_step_flops = env.spec.client_step_flops(k);
    let server_step_flops = env.spec.server_step_flops(k, true);
    let act_bytes = env.spec.act_batch_bytes(k);

    let pool = env.pool();

    // ---- rounds ----------------------------------------------------------
    for round in 0..cfg.rounds {
        let global_phase = round >= local_rounds;
        // per-client batches draw from per-client derived RNG streams, so
        // materializing them concurrently is order-independent
        let batches: Vec<Vec<crate::data::Batch>> =
            par_clients(&*env, |i| Ok(env.train_batches(i, round)))?;
        let t_max = batches.iter().map(|b| b.len()).max().unwrap_or(0);

        let mut loss_sum = 0.0;
        let mut loss_count = 0.0;
        let mut density_sum = 0.0;
        let mut density_count = 0.0;
        let mut round_selected: Vec<usize> = Vec::new();

        for t in 0..t_max {
            // -- local client steps (every client, every phase), fanned
            //    out over the pool: client i touches only its own state --
            let active: Vec<usize> = (0..n).filter(|&i| t < batches[i].len()).collect();
            // pending (stale) server gradients are taken on this thread,
            // read-only inside the fan-out
            let taken: Vec<Option<Tensor>> =
                active.iter().map(|&i| pending_grad[i].take()).collect();
            // disjoint &mut views of the active clients' states, in
            // ascending client-id order (matching `active`)
            let mut active_states: Vec<&mut TensorStore> = client_states
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| active.binary_search(i).is_ok())
                .map(|(_, s)| s)
                .collect();
            let stepped = pool.run_mut(&mut active_states, |j, state| {
                let b = &batches[active[j]][t];
                // avoid cloning the (large) zero gradient on the default path
                let (ga, use_grad): (&Tensor, f32) = match &taken[j] {
                    Some(g) => (g, 1.0),
                    None => (&zero_grad, 0.0),
                };
                let mut out = client_step.call(
                    &[&**state],
                    &[
                        ("x", &b.x),
                        ("y", &b.y),
                        ("beta", &beta),
                        ("grad_a", ga),
                        ("use_grad", &Tensor::scalar(use_grad)),
                    ],
                )?;
                out.write_state(state);
                Ok((out.scalar("loss")? as f64, out.take("acts")?))
            })?;
            // merge in client-id order (thread-count independent)
            let mut acts: Vec<Option<Tensor>> = vec![None; n];
            for (j, (loss, a)) in stepped.into_iter().enumerate() {
                loss_sum += loss;
                loss_count += 1.0;
                acts[active[j]] = Some(a);
                env.meter.add_client_flops(client_step_flops);
            }

            // -- global phase: orchestrated server training ----------------
            if global_phase && !active.is_empty() {
                let selected = ucb.select_among(&active, n_select);
                let mut observed = Vec::with_capacity(selected.len());
                for &i in &selected {
                    let a = acts[i].as_ref().expect("active client has acts");
                    let y = &batches[i][t].y;
                    let mut out = server_step.call(
                        &[&server_shared, &mask_states[i]],
                        &[("a", a), ("y", y), ("lam", &lam)],
                    )?;
                    out.write_state_filtered(&mut server_shared, |key| !is_mask_key(key));
                    out.write_state_filtered(&mut mask_states[i], is_mask_key);
                    let loss = out.scalar("loss")? as f64;
                    observed.push((i, loss));
                    density_sum += out.scalar("mask_density")? as f64;
                    density_count += 1.0;

                    let up = env.up_payload_bytes(a);
                    env.meter.add_server_flops(server_step_flops);
                    env.meter.add_up(up);
                    if cfg.server_grad_to_client {
                        pending_grad[i] = Some(out.take("grad_a")?);
                        env.meter.add_down(act_bytes);
                    }
                    env.recorder.trace(format!(
                        "r{round} t{t} client{i} server_loss={loss:.4}"
                    ));
                }
                ucb.update(&observed);
                for s in selected {
                    if !round_selected.contains(&s) {
                        round_selected.push(s);
                    }
                }
            }
        }

        // -- eval ----------------------------------------------------------
        let eval_now = round % cfg.eval_every == 0 || round + 1 == cfg.rounds;
        let accuracy = if eval_now {
            let roots: Vec<TensorStore> =
                client_states.iter().map(|s| s.sub("state")).collect();
            let shared_root = server_shared.sub("state");
            let mask_roots: Vec<TensorStore> =
                mask_states.iter().map(|s| s.sub("state")).collect();
            let acc = eval_split(env, &client_fwd, &server_eval, &roots, |i| {
                vec![shared_root.clone(), mask_roots[i].clone()]
            })?;
            acc.mean_client_pct()
        } else {
            env.recorder.last_accuracy()
        };

        env.recorder.push(RoundStat {
            round,
            phase: if global_phase { "global".into() } else { "local".into() },
            train_loss: if loss_count > 0.0 { loss_sum / loss_count } else { 0.0 },
            accuracy_pct: accuracy,
            bandwidth_gb: env.meter.bandwidth_gb(),
            client_tflops: env.meter.client_tflops(),
            total_tflops: env.meter.total_tflops(),
            mask_density: if density_count > 0.0 {
                density_sum / density_count
            } else {
                1.0
            },
            selected: round_selected,
        });
    }

    Ok(RunResult::from_env(env, &env.recorder, &env.meter))
}
