//! A compiled HLO artifact plus its manifest signature, callable with
//! named host tensors.
//!
//! The jax function behind every artifact takes a single dict argument and
//! returns a dict; the manifest records the flattened order of both, so a
//! call here is: resolve each input name to a `Tensor`, build XLA literals
//! in manifest order, execute, decompose the result tuple, and hand back a
//! name -> tensor map. `state.*` outputs can be written back onto a
//! `TensorStore` in one call (the layouts are guaranteed to mirror the
//! inputs by `python/tests/test_aot.py::test_state_round_trip_layout`).

use std::collections::BTreeMap;

use anyhow::{anyhow, ensure, Context, Result};
use xla::{ElementType, Literal, PjRtLoadedExecutable};

use super::manifest::ArtifactSpec;
use super::store::TensorStore;
use super::tensor::Tensor;

/// Compiled executable + signature.
pub struct Artifact {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
}

/// Named outputs of one artifact execution.
#[derive(Debug, Default)]
pub struct CallOutput {
    map: BTreeMap<String, Tensor>,
}

impl CallOutput {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("output `{name}` missing"))
    }

    pub fn take(&mut self, name: &str) -> Result<Tensor> {
        self.map
            .remove(name)
            .ok_or_else(|| anyhow!("output `{name}` missing"))
    }

    pub fn scalar(&self, name: &str) -> Result<f32> {
        Ok(self.get(name)?.item())
    }

    /// Move every `state.*` output over the matching entries of `store`.
    /// Tensors are *moved* out of the output map (zero-copy write-back on
    /// the hot loop — see EXPERIMENTS.md §Perf).
    pub fn write_state(&mut self, store: &mut TensorStore) {
        self.write_state_filtered(store, |_| true)
    }

    /// Move the `state.*` outputs whose name passes `pred` into `store`
    /// (used to split shared server params from per-client masks).
    pub fn write_state_filtered<F: Fn(&str) -> bool>(&mut self, store: &mut TensorStore, pred: F) {
        let keys: Vec<String> = self
            .map
            .keys()
            .filter(|k| (k.as_str() == "state" || k.starts_with("state.")) && pred(k))
            .cloned()
            .collect();
        for k in keys {
            if let Some(v) = self.map.remove(&k) {
                store.insert(k, v);
            }
        }
    }

    /// Move every `state.*` output into a fresh store (for init artifacts).
    pub fn into_state(self) -> TensorStore {
        let mut store = TensorStore::new();
        for (k, v) in self.map {
            if k == "state" || k.starts_with("state.") {
                store.insert(k, v);
            }
        }
        store
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, t.shape(), bytes)
        .map_err(|e| anyhow!("literal from shape {:?}: {e}", t.shape()))
}

fn literal_to_tensor(lit: &Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec: {e}"))?;
    Tensor::new(shape.to_vec(), data)
}

impl Artifact {
    pub(crate) fn new(name: String, spec: ArtifactSpec, exe: PjRtLoadedExecutable) -> Self {
        Self { name, spec, exe }
    }

    /// Execute with inputs resolved by name: `extras` first (batch data,
    /// hyperparameters), then the `stores` in order (persistent state —
    /// e.g. AdaSplit passes [shared server store, per-client mask store]).
    /// Every manifest input must resolve; shapes are validated.
    pub fn call(
        &self,
        stores: &[&TensorStore],
        extras: &[(&str, &Tensor)],
    ) -> Result<CallOutput> {
        let mut literals = Vec::with_capacity(self.spec.inputs.len());
        for input in &self.spec.inputs {
            let tensor = extras
                .iter()
                .find(|(n, _)| *n == input.name)
                .map(|(_, t)| *t)
                .or_else(|| stores.iter().find_map(|s| s.get(&input.name).ok()))
                .ok_or_else(|| {
                    anyhow!("artifact `{}`: input `{}` unresolved", self.name, input.name)
                })?;
            ensure!(
                tensor.shape() == input.shape.as_slice(),
                "artifact `{}`: input `{}` shape {:?} != manifest {:?}",
                self.name,
                input.name,
                tensor.shape(),
                input.shape
            );
            literals.push(tensor_to_literal(tensor)?);
        }

        let result = self
            .exe
            .execute::<Literal>(&literals)
            .map_err(|e| anyhow!("executing `{}`: {e}", self.name))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching `{}` result: {e}", self.name))?;
        // aot.py lowers with return_tuple=True: root is a tuple of outputs
        // in manifest order.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow!("decomposing `{}` tuple: {e}", self.name))?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact `{}`: got {} outputs, manifest says {}",
            self.name,
            parts.len(),
            self.spec.outputs.len()
        );

        let mut map = BTreeMap::new();
        for (lit, out) in parts.iter().zip(&self.spec.outputs) {
            let t = literal_to_tensor(lit, &out.shape)
                .with_context(|| format!("output `{}` of `{}`", out.name, self.name))?;
            map.insert(out.name.clone(), t);
        }
        Ok(CallOutput { map })
    }
}
