//! A compiled HLO artifact plus its manifest signature, callable with
//! named host tensors.
//!
//! The jax function behind every artifact takes a single dict argument and
//! returns a dict; the manifest records the flattened order of both, so a
//! call here is: resolve each input name to a `Tensor`, build XLA literals
//! in manifest order, execute, decompose the result tuple, and hand back a
//! name -> tensor map. `state.*` outputs can be written back onto a
//! `TensorStore` in one call (the layouts are guaranteed to mirror the
//! inputs by `python/tests/test_aot.py::test_state_round_trip_layout`).

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

use anyhow::{anyhow, ensure, Context, Result};
use xla::{ElementType, Literal, PjRtLoadedExecutable};

use super::manifest::ArtifactSpec;
use super::store::TensorStore;
use super::tensor::Tensor;

/// Compiled executable + signature.
pub struct Artifact {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
}

// SAFETY: engine workers share compiled artifacts via `Arc<Artifact>` and
// only ever take `&self` (`Artifact::call`). PJRT loaded executables are
// designed for concurrent execution — every call builds its own input
// literals and output buffers, and the executable itself is immutable
// after compilation. One caveat does not live in this crate: the xla-rs
// wrapper refcounts its client handle non-atomically (`Rc`) and
// `execute()` clones the handle into every returned buffer, so the
// handle-touching windows (execute *and* compile — see
// `Runtime::artifact`) run under one process-wide lock by default
// ([`xla_exec_guard`]). Only a build compiled with the `parallel-xla`
// feature — set exclusively by vendored xla-rs builds carrying the
// Rc->Arc patch (DESIGN.md §5) — honors `ADASPLIT_PARALLEL_XLA=1` to
// drop the lock and overlap executions; everything outside those
// windows is unconditionally safe to run concurrently.
unsafe impl Send for Artifact {}
// SAFETY: same argument as `Send` above — `&Artifact` calls are
// read-only over an executable that is immutable after compilation,
// with the non-atomic handle-refcount windows serialized by
// `xla_exec_guard` unless the patched `parallel-xla` build opts out.
unsafe impl Sync for Artifact {}

// Compile-time tie between the feature and the patched vendor: the
// Rc->Arc patch (DESIGN.md §5) also exports this marker const, so
// building with `parallel-xla` against an *unpatched* xla-rs fails right
// here instead of producing a binary whose unlocked mode is unsound.
#[cfg(feature = "parallel-xla")]
const _: bool = xla::ATOMIC_CLIENT_HANDLE;

/// Process-wide serialization of the PJRT client-handle windows (execute
/// launch + result fetch + buffer drops, and compilation). On by default
/// because upstream xla-rs refcounts the handle with `Rc`; costs the
/// engine its artifact-execution overlap but keeps marshalling, batching,
/// evaluation fan-out, and reduction parallel. Run results are identical
/// either way — the lock only sequences execution.
///
/// Dropping the lock requires *both* the `parallel-xla` cargo feature
/// (set only by builds whose vendored xla-rs carries the Rc->Arc patch,
/// DESIGN.md §5) and `ADASPLIT_PARALLEL_XLA=1` at runtime. The env var
/// alone is refused with a warning: deployment config must not be able
/// to flip an unpatched build into undefined behavior.
pub(crate) fn xla_exec_guard() -> Option<MutexGuard<'static, ()>> {
    static PARALLEL: OnceLock<bool> = OnceLock::new();
    static LOCK: Mutex<()> = Mutex::new(());
    let parallel = *PARALLEL.get_or_init(|| {
        let requested = std::env::var("ADASPLIT_PARALLEL_XLA").map(|v| v == "1").unwrap_or(false);
        if requested && !cfg!(feature = "parallel-xla") {
            eprintln!(
                "adasplit: ignoring ADASPLIT_PARALLEL_XLA=1 — this build lacks the \
                 `parallel-xla` cargo feature (vendored xla-rs without the Rc->Arc \
                 patch; unlocked execution would be unsound)"
            );
            return false;
        }
        requested
    });
    (!parallel).then(|| LOCK.lock().unwrap_or_else(|e| e.into_inner()))
}

/// Named outputs of one artifact execution.
#[derive(Debug, Default)]
pub struct CallOutput {
    map: BTreeMap<String, Tensor>,
}

impl CallOutput {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("output `{name}` missing"))
    }

    pub fn take(&mut self, name: &str) -> Result<Tensor> {
        self.map
            .remove(name)
            .ok_or_else(|| anyhow!("output `{name}` missing"))
    }

    pub fn scalar(&self, name: &str) -> Result<f32> {
        Ok(self.get(name)?.item())
    }

    /// Move every `state.*` output over the matching entries of `store`.
    /// Tensors are *moved* out of the output map (zero-copy write-back on
    /// the hot loop — see EXPERIMENTS.md §Perf).
    pub fn write_state(&mut self, store: &mut TensorStore) {
        self.write_state_filtered(store, |_| true)
    }

    /// Move the `state.*` outputs whose name passes `pred` into `store`
    /// (used to split shared server params from per-client masks).
    pub fn write_state_filtered<F: Fn(&str) -> bool>(&mut self, store: &mut TensorStore, pred: F) {
        let keys: Vec<String> = self
            .map
            .keys()
            .filter(|k| (k.as_str() == "state" || k.starts_with("state.")) && pred(k))
            .cloned()
            .collect();
        for k in keys {
            if let Some(v) = self.map.remove(&k) {
                store.insert(k, v);
            }
        }
    }

    /// Move every `state.*` output into a fresh store (for init artifacts).
    pub fn into_state(self) -> TensorStore {
        let mut store = TensorStore::new();
        for (k, v) in self.map {
            if k == "state" || k.starts_with("state.") {
                store.insert(k, v);
            }
        }
        store
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    // SAFETY: `t.data()` is a live `&[f32]`, so reinterpreting it as
    // bytes is valid for the full borrow: alignment only loosens
    // (4 -> 1), the length is exactly `len * 4` bytes of initialized
    // memory, and the byte view is read-only and ends before the
    // `&[f32]` borrow does (the literal copies out of it).
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, t.shape(), bytes)
        .map_err(|e| anyhow!("literal from shape {:?}: {e}", t.shape()))
}

fn literal_to_tensor(lit: &Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec: {e}"))?;
    Tensor::new(shape.to_vec(), data)
}

impl Artifact {
    pub(crate) fn new(name: String, spec: ArtifactSpec, exe: PjRtLoadedExecutable) -> Self {
        Self { name, spec, exe }
    }

    /// Execute with inputs resolved by name: `extras` first (batch data,
    /// hyperparameters), then the `stores` in order (persistent state —
    /// e.g. AdaSplit passes [shared server store, per-client mask store]).
    /// Every manifest input must resolve; shapes are validated.
    pub fn call(
        &self,
        stores: &[&TensorStore],
        extras: &[(&str, &Tensor)],
    ) -> Result<CallOutput> {
        let mut literals = Vec::with_capacity(self.spec.inputs.len());
        for input in &self.spec.inputs {
            let tensor = extras
                .iter()
                .find(|(n, _)| *n == input.name)
                .map(|(_, t)| *t)
                .or_else(|| stores.iter().find_map(|s| s.get(&input.name).ok()))
                .ok_or_else(|| {
                    anyhow!("artifact `{}`: input `{}` unresolved", self.name, input.name)
                })?;
            ensure!(
                tensor.shape() == input.shape.as_slice(),
                "artifact `{}`: input `{}` shape {:?} != manifest {:?}",
                self.name,
                input.name,
                tensor.shape(),
                input.shape
            );
            literals.push(tensor_to_literal(tensor)?);
        }

        // held (when enabled) until the buffers in `result` drop at the
        // end of this call — the full client-handle clone/drop window
        let _serial_guard = xla_exec_guard();
        let result = self
            .exe
            .execute::<Literal>(&literals)
            .map_err(|e| anyhow!("executing `{}`: {e}", self.name))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching `{}` result: {e}", self.name))?;
        // aot.py lowers with return_tuple=True: root is a tuple of outputs
        // in manifest order.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow!("decomposing `{}` tuple: {e}", self.name))?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact `{}`: got {} outputs, manifest says {}",
            self.name,
            parts.len(),
            self.spec.outputs.len()
        );

        let mut map = BTreeMap::new();
        for (lit, out) in parts.iter().zip(&self.spec.outputs) {
            let t = literal_to_tensor(lit, &out.shape)
                .with_context(|| format!("output `{}` of `{}`", out.name, self.name))?;
            map.insert(out.name.clone(), t);
        }
        Ok(CallOutput { map })
    }
}
