//! `TensorStore`: named host-side state (parameters, optimizer moments,
//! masks) for one logical entity — a client model, the server model, one
//! per-client mask set, an FL model copy.
//!
//! Keys are the manifest tensor names (`state.pc.conv1.w`, ...). Artifact
//! calls read their `state.*` inputs from a store and write the matching
//! outputs back, so protocol code never touches tensor layouts.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::tensor::Tensor;

/// An ordered name -> tensor map (BTreeMap keeps deterministic iteration,
/// which keeps checksums and tests reproducible).
#[derive(Clone, Debug, Default)]
pub struct TensorStore {
    map: BTreeMap<String, Tensor>,
}

impl TensorStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("tensor `{name}` not in store"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("tensor `{name}` not in store"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.map.iter()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&String, &mut Tensor)> {
        self.map.iter_mut()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// Keys under a dotted prefix, e.g. `prefix("state.pc")`.
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a String> + 'a {
        self.map
            .keys()
            .filter(move |k| k.as_str() == prefix || k.starts_with(&format!("{prefix}.")))
    }

    /// Sub-store view (cloned) of all tensors under a prefix, re-rooted:
    /// `sub("state")` maps `state.pc.w` -> `pc.w`.
    pub fn sub(&self, prefix: &str) -> TensorStore {
        let dot = format!("{prefix}.");
        let mut out = TensorStore::new();
        for (k, v) in &self.map {
            if let Some(rest) = k.strip_prefix(&dot) {
                out.insert(rest.to_string(), v.clone());
            }
        }
        out
    }

    /// Total number of scalar elements across all tensors.
    pub fn numel(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Total dense payload in bytes (f32).
    pub fn byte_size(&self) -> usize {
        self.numel() * 4
    }

    /// In-place: `self[k] = sum_i w_i * others_i[k]` over matching keys.
    /// Used by FedAvg-family aggregation. Keys present in `self` but not in
    /// the key filter are left untouched.
    pub fn set_weighted_sum<F>(
        &mut self,
        others: &[&TensorStore],
        weights: &[f32],
        key_filter: F,
    ) -> Result<()>
    where
        F: Fn(&str) -> bool,
    {
        ensure!(others.len() == weights.len(), "weights/stores mismatch");
        let keys: Vec<String> = self
            .map
            .keys()
            .filter(|k| key_filter(k))
            .cloned()
            .collect();
        for k in keys {
            let mut acc = Tensor::zeros(self.map[&k].shape());
            for (o, &w) in others.iter().zip(weights) {
                acc.axpy(w, o.get(&k)?)?;
            }
            self.map.insert(k, acc);
        }
        Ok(())
    }

    /// A cheap structural checksum (sum of mean-abs per tensor) used by
    /// integration tests to detect unintended state mutation.
    pub fn checksum(&self) -> f64 {
        self.map
            .values()
            .map(|t| t.mean_abs() as f64)
            .sum()
    }

    /// True if any tensor holds a NaN/Inf — used for failure injection and
    /// divergence guards in long runs.
    pub fn has_non_finite(&self) -> bool {
        self.map.values().any(|t| t.has_non_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(v: f32) -> TensorStore {
        let mut s = TensorStore::new();
        s.insert("state.p.w", Tensor::full(&[2, 2], v));
        s.insert("state.p.b", Tensor::full(&[2], v));
        s.insert("state.t", Tensor::scalar(v));
        s
    }

    #[test]
    fn sub_reroots_prefix() {
        let s = store(1.0);
        let sub = s.sub("state");
        assert!(sub.contains("p.w"));
        assert!(sub.contains("t"));
        assert_eq!(sub.len(), 3);
    }

    #[test]
    fn weighted_sum_averages() {
        let mut dst = store(0.0);
        let a = store(1.0);
        let b = store(3.0);
        dst.set_weighted_sum(&[&a, &b], &[0.5, 0.5], |k| k.starts_with("state.p"))
            .unwrap();
        assert_eq!(dst.get("state.p.w").unwrap().data()[0], 2.0);
        // filtered-out key untouched
        assert_eq!(dst.get("state.t").unwrap().item(), 0.0);
    }

    #[test]
    fn numel_and_bytes() {
        let s = store(1.0);
        assert_eq!(s.numel(), 4 + 2 + 1);
        assert_eq!(s.byte_size(), 7 * 4);
    }

    #[test]
    fn missing_key_errors() {
        let s = store(1.0);
        assert!(s.get("nope").is_err());
    }
}
