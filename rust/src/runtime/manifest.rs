//! Manifest loader: the contract emitted by `python/compile/aot.py`.
//!
//! The manifest pins, per artifact, the exact flattened tensor order of its
//! inputs and outputs (jax pytree paths), plus per-split-config metadata
//! (activation shapes, parameter counts) used by the analytic cost model.
//! Parsed with the in-tree JSON parser (`util::json`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// One named tensor slot of an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j.get("shape")?.usize_arr()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// Signature of one HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)?.as_arr()?.iter().map(TensorSpec::from_json).collect()
        };
        Ok(Self {
            file: j.get("file")?.as_str()?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// Per split-config metadata (`c10_mu1`, ..., `c50_mu1`).
#[derive(Clone, Debug)]
pub struct ConfigMeta {
    pub num_classes: usize,
    pub k: usize,
    pub act_shape: Vec<usize>,
    pub client_params: usize,
    pub server_params: usize,
    pub proj_params: usize,
    pub full_params: usize,
}

impl ConfigMeta {
    /// Bytes of one dense split-activation batch (f32).
    pub fn act_bytes(&self) -> usize {
        self.act_shape.iter().product::<usize>() * 4
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            num_classes: j.get("num_classes")?.as_usize()?,
            k: j.get("k")?.as_usize()?,
            act_shape: j.get("act_shape")?.usize_arr()?,
            client_params: j.get("client_params")?.as_usize()?,
            server_params: j.get("server_params")?.as_usize()?,
            proj_params: j.get("proj_params")?.as_usize()?,
            full_params: j.get("full_params")?.as_usize()?,
        })
    }
}

/// The full `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub img: usize,
    pub proj_dim: usize,
    pub lr: f32,
    pub tau: f32,
    pub mask_thresh: f32,
    pub conv_channels: Vec<usize>,
    pub fc1: usize,
    pub configs: BTreeMap<String, ConfigMeta>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut configs = BTreeMap::new();
        for (k, v) in j.get("configs")?.as_obj()? {
            configs.insert(k.clone(), ConfigMeta::from_json(v).context(k.clone())?);
        }
        let mut artifacts = BTreeMap::new();
        for (k, v) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(k.clone(), ArtifactSpec::from_json(v).context(k.clone())?);
        }
        Ok(Self {
            batch: j.get("batch")?.as_usize()?,
            img: j.get("img")?.as_usize()?,
            proj_dim: j.get("proj_dim")?.as_usize()?,
            lr: j.get("lr")?.as_f64()? as f32,
            tau: j.get("tau")?.as_f64()? as f32,
            mask_thresh: j.get("mask_thresh")?.as_f64()? as f32,
            conv_channels: j.get("conv_channels")?.usize_arr()?,
            fc1: j.get("fc1")?.as_usize()?,
            configs,
            artifacts,
        })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::from_json_text(&text).with_context(|| format!("parsing {path:?}"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))
    }

    /// Metadata for a split config tag like `c10_mu1`.
    pub fn config(&self, tag: &str) -> Result<&ConfigMeta> {
        self.configs
            .get(tag)
            .ok_or_else(|| anyhow!("config `{tag}` not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let json = r#"{
            "batch": 32, "img": 32, "proj_dim": 64, "lr": 0.001,
            "tau": 0.07, "mask_thresh": 0.01,
            "conv_channels": [16, 32, 64], "fc1": 128,
            "configs": {"c10_mu1": {"num_classes": 10, "k": 1,
                "act_shape": [32, 16, 16, 16], "client_params": 448,
                "server_params": 100, "proj_params": 10,
                "full_params": 548}},
            "artifacts": {"a": {"file": "a.hlo.txt",
                "inputs": [{"name": "x", "shape": [2, 3], "dtype": "float32"}],
                "outputs": []}}
        }"#;
        let m = Manifest::from_json_text(json).unwrap();
        assert_eq!(m.artifact("a").unwrap().inputs[0].numel(), 6);
        assert_eq!(m.config("c10_mu1").unwrap().act_bytes(), 32 * 16 * 16 * 16 * 4);
        assert!(m.artifact("nope").is_err());
        assert!((m.lr - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn missing_field_is_error() {
        assert!(Manifest::from_json_text("{\"batch\": 32}").is_err());
    }
}
