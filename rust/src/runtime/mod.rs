//! L3 runtime: loads AOT-compiled HLO-text artifacts and executes them on
//! the PJRT CPU client (`xla` crate).
//!
//! The interchange contract with the Python build path is the **manifest**
//! (`artifacts/manifest.json`): for every artifact it records the flattened
//! input/output tensor order (pytree paths from `aot.py`), so this module
//! can marshal flat `f32` host buffers without knowing anything about the
//! model. See DESIGN.md §4.

mod artifact;
mod client;
mod manifest;
mod store;
mod tensor;

pub use artifact::{Artifact, CallOutput};
pub use client::Runtime;
pub use manifest::{ConfigMeta, Manifest, TensorSpec};
pub use store::TensorStore;
pub use tensor::{weighted_sum, Tensor};
