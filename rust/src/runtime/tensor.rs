//! Host-side tensor: a shape plus a flat `f32` buffer.
//!
//! Everything that crosses the L3/L2 boundary is `f32` (enforced by
//! `python/tests/test_aot.py::test_f32_only`), so a single concrete type
//! suffices and all protocol state lives in plain `Vec<f32>` buffers.

use anyhow::{ensure, Result};

/// A dense row-major `f32` tensor on the host.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape + data; validates the element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        ensure!(
            n == data.len(),
            "shape {:?} wants {} elements, got {}",
            shape,
            n,
            data.len()
        );
        Ok(Self { shape, data })
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// All-`v` tensor of the given shape.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Scalar (rank-0) tensor.
    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Scalar value of a rank-0 / single-element tensor.
    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1, "item() on non-scalar");
        self.data[0]
    }

    /// Size in bytes when transmitted densely (f32).
    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }

    /// Number of elements with |x| > `eps` (sparse-payload accounting).
    pub fn nnz(&self, eps: f32) -> usize {
        self.data.iter().filter(|x| x.abs() > eps).count()
    }

    /// Elementwise in-place: `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        ensure!(self.shape == other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Mean absolute value (diagnostics).
    pub fn mean_abs(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|x| x.abs()).sum::<f32>() / self.data.len() as f32
    }

    /// True if any element is NaN or infinite.
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }
}

/// Weighted average of tensors: `sum_i w_i * t_i` (weights need not sum to
/// one — FedNova exploits this). All shapes must match.
pub fn weighted_sum(tensors: &[&Tensor], weights: &[f32]) -> Result<Tensor> {
    ensure!(!tensors.is_empty(), "weighted_sum of nothing");
    ensure!(tensors.len() == weights.len(), "weights/tensors mismatch");
    let mut out = Tensor::zeros(tensors[0].shape());
    for (t, &w) in tensors.iter().zip(weights) {
        out.axpy(w, t)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar(4.5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.item(), 4.5);
    }

    #[test]
    fn nnz_counts_above_eps() {
        let t = Tensor::new(vec![4], vec![0.0, 1e-6, 0.5, -2.0]).unwrap();
        assert_eq!(t.nnz(1e-4), 2);
        assert_eq!(t.nnz(0.0), 3);
    }

    #[test]
    fn axpy_and_weighted_sum() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![3.0, 2.0, 1.0]).unwrap();
        let avg = weighted_sum(&[&a, &b], &[0.5, 0.5]).unwrap();
        assert_eq!(avg.data(), &[2.0, 2.0, 2.0]);
    }

    #[test]
    fn axpy_shape_mismatch_errors() {
        let mut a = Tensor::zeros(&[2]);
        let b = Tensor::zeros(&[3]);
        assert!(a.axpy(1.0, &b).is_err());
    }
}
