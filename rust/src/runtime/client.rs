//! The `Runtime`: a PJRT CPU client plus a compile-on-demand artifact cache.
//!
//! HLO *text* is the interchange format (see DESIGN.md §4): jax >= 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; `HloModuleProto::from_text_file` reassigns ids and round-trips
//! cleanly. Compilation is lazy and cached — a protocol run touches only
//! the handful of artifacts for its split config.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};
use xla::PjRtClient;

use super::artifact::Artifact;
use super::manifest::Manifest;

pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
}

impl Runtime {
    /// Load the manifest and spin up the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, manifest, dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Platform string of the underlying PJRT client (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling on first use) the named artifact.
    pub fn artifact(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))
        .context("run `make artifacts`?")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling `{name}`: {e}"))?;
        let artifact = Rc::new(Artifact::new(name.to_string(), spec, exe));
        self.cache
            .borrow_mut()
            .insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Number of artifacts compiled so far (diagnostics / perf logging).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
