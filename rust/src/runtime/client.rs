//! The `Runtime`: a PJRT CPU client plus a compile-on-demand artifact cache.
//!
//! HLO *text* is the interchange format (see DESIGN.md §4): jax >= 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; `HloModuleProto::from_text_file` reassigns ids and round-trips
//! cleanly. Compilation is lazy and cached — a protocol run touches only
//! the handful of artifacts for its split config.
//!
//! The runtime is shared across engine worker threads (DESIGN.md §5): the
//! cache is lock-based and compiled artifacts are handed out as `Arc`s.
//! Compilation runs outside the cache lock (hits never stall behind a
//! compile); the client-handle window inside it is serialized by the same
//! lock as artifact execution (`xla_exec_guard`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};
use xla::PjRtClient;

use super::artifact::Artifact;
use super::manifest::Manifest;

pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Artifact>>>,
}

// SAFETY: the engine shares the runtime across scoped worker threads by
// reference only. The PJRT CPU client is internally synchronized for
// concurrent compile/execute calls, and the artifact cache is guarded by
// the mutex above. Compilation clones the wrapper's client handle into
// the new executable, so `Runtime::artifact` takes the same process-wide
// handle lock as `Artifact::call` (`xla_exec_guard`, on by default) —
// compile never overlaps an execute window's non-atomic refcount traffic
// unless `ADASPLIT_PARALLEL_XLA=1` asserts an Rc->Arc-patched xla-rs
// build (DESIGN.md §5).
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load the manifest and spin up the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, manifest, dir, cache: Mutex::new(HashMap::new()) })
    }

    /// Platform string of the underlying PJRT client (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling on first use) the named artifact. Safe to call from
    /// any engine worker; the returned `Arc` can be shared across threads.
    ///
    /// Compilation happens *outside* the cache lock so cache hits never
    /// stall behind an in-flight compile (or the execute it may be queued
    /// behind); a concurrent first touch of the same artifact may compile
    /// it twice, with the loser's executable discarded — the cache keeps
    /// exactly one.
    pub fn artifact(&self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self
            .cache
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(name)
        {
            return Ok(a.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))
        .context("run `make artifacts`?")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        // compile clones the client handle into the executable: take the
        // same handle lock as Artifact::call so it never races an
        // in-flight execute window (no-op under ADASPLIT_PARALLEL_XLA=1)
        let exe = {
            let _handle_guard = super::artifact::xla_exec_guard();
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling `{name}`: {e}"))?
        };
        let artifact = Arc::new(Artifact::new(name.to_string(), spec, exe));
        let mut cache = self.cache.lock().unwrap_or_else(|e| e.into_inner());
        Ok(cache
            .entry(name.to_string())
            .or_insert(artifact)
            .clone())
    }

    /// Number of artifacts compiled so far (diagnostics / perf logging).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}
