//! The `Runtime`: a PJRT CPU client plus a compile-on-demand artifact cache.
//!
//! HLO *text* is the interchange format (see DESIGN.md §4): jax >= 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; `HloModuleProto::from_text_file` reassigns ids and round-trips
//! cleanly. Compilation is lazy and cached — a protocol run touches only
//! the handful of artifacts for its split config.
//!
//! The runtime is shared across engine worker threads (DESIGN.md §5): the
//! cache maps each artifact name to a `OnceLock` slot, so every artifact
//! compiles exactly once — concurrent first-touchers of the same name
//! block on the slot, while hits and first touches of *other* names only
//! graze the cache mutex. The client-handle window inside compilation is
//! serialized by the same lock as artifact execution (`xla_exec_guard`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, Context, Result};
use xla::PjRtClient;

use super::artifact::Artifact;
use super::manifest::Manifest;

/// One cache entry: per-name once-cell so a concurrent first touch never
/// compiles twice (a losing duplicate executable would be dropped outside
/// `xla_exec_guard`, racing the client handle's non-atomic refcount).
/// Errors are stored as strings (`anyhow::Error` is not `Clone`) and the
/// slot is evicted on failure so a later call can retry.
type CacheSlot = Arc<OnceLock<Result<Arc<Artifact>, String>>>;

pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, CacheSlot>>,
}

// SAFETY: the engine shares the runtime across scoped worker threads by
// reference only. The PJRT CPU client is internally synchronized for
// concurrent compile/execute calls, and the artifact cache is guarded by
// the mutex above. Compilation clones the wrapper's client handle into
// the new executable, so `Runtime::artifact` takes the same process-wide
// handle lock as `Artifact::call` (`xla_exec_guard`, on by default) —
// compile never overlaps an execute window's non-atomic refcount traffic
// unless the build carries the `parallel-xla` feature (Rc->Arc-patched
// vendored xla-rs, DESIGN.md §5) *and* `ADASPLIT_PARALLEL_XLA=1` is set.
// The per-name `OnceLock` slots additionally guarantee no duplicate
// executable is ever created and dropped: every `PjRtLoadedExecutable`
// that exists is the cached one, created under the handle lock and
// destroyed only when the `Runtime` itself drops.
unsafe impl Send for Runtime {}
// SAFETY: same argument as `Send` above — shared `&Runtime` access is
// serialized by the artifact-cache mutex, the per-name `OnceLock` slots,
// and the process-wide PJRT handle lock around every compile/execute.
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load the manifest and spin up the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, manifest, dir, cache: Mutex::new(HashMap::new()) })
    }

    /// Platform string of the underlying PJRT client (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling on first use) the named artifact. Safe to call from
    /// any engine worker; the returned `Arc` can be shared across threads.
    ///
    /// Exactly-once compile: the cache mutex is held only long enough to
    /// fetch/insert the name's `OnceLock` slot, then the first caller runs
    /// the compile inside `get_or_init` while concurrent first-touchers of
    /// the *same* name block on the slot (hits and other names proceed).
    /// No duplicate executable is ever created, so no PJRT handle is
    /// dropped outside `xla_exec_guard` (see the `Runtime` SAFETY note).
    pub fn artifact(&self, name: &str) -> Result<Arc<Artifact>> {
        let slot: CacheSlot = self
            .cache
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(name.to_string())
            .or_default()
            .clone();
        match slot.get_or_init(|| self.compile_artifact(name).map_err(|e| format!("{e:#}"))) {
            Ok(a) => Ok(a.clone()),
            Err(msg) => {
                // evict the failed slot — unless a retry already replaced
                // it — so a later call (e.g. after `make artifacts`) can
                // compile afresh instead of replaying the cached error
                let mut cache = self.cache.lock().unwrap_or_else(|e| e.into_inner());
                if cache.get(name).is_some_and(|s| Arc::ptr_eq(s, &slot)) {
                    cache.remove(name);
                }
                Err(anyhow!("{msg}"))
            }
        }
    }

    fn compile_artifact(&self, name: &str) -> Result<Arc<Artifact>> {
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))
        .context("run `make artifacts`?")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        // compile clones the client handle into the executable: take the
        // same handle lock as Artifact::call so it never races an
        // in-flight execute window (no-op when the lock is disabled)
        let exe = {
            let _handle_guard = super::artifact::xla_exec_guard();
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling `{name}`: {e}"))?
        };
        Ok(Arc::new(Artifact::new(name.to_string(), spec, exe)))
    }

    /// Number of artifacts compiled so far (diagnostics / perf logging).
    pub fn compiled_count(&self) -> usize {
        self.cache
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            // detlint: allow(D01, order-independent count over cache slots)
            .values()
            .filter(|s| s.get().is_some_and(|r| r.is_ok()))
            .count()
    }
}
