//! Analytic model description: the Rust-side mirror of the backbone
//! defined in `python/compile/model.py`, used for FLOP accounting (paper
//! eq. 1) and payload sizing (paper eq. 2). Kept in sync with the manifest
//! (cross-checked by integration tests against the manifest's parameter
//! counts).

pub mod spec;

pub use spec::ModelSpec;
