//! Layer-by-layer FLOP and parameter model of the backbone.
//!
//! Backbone (NHWC, 32x32x3 inputs):
//!   conv1 3->16 (3x3 SAME, relu, maxpool/2)
//!   conv2 16->32, conv3 32->64 (same pattern)
//!   fc1 1024->128 (relu), fc2 128->C
//!
//! The client owns the first `k` blocks (mu = k/5 in the paper's terms,
//! with defaults k=1 <=> mu=0.2); the server owns the rest. FLOP counts
//! use the standard multiply-accumulate = 2 FLOPs convention; backward
//! passes are charged 2x forward (grad w.r.t. weights + inputs).

use crate::runtime::Manifest;

/// Static architecture constants + derived counts.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub img: usize,
    pub batch: usize,
    pub conv_channels: Vec<usize>,
    pub fc1: usize,
    pub proj_dim: usize,
    pub num_classes: usize,
}

impl ModelSpec {
    pub fn from_manifest(m: &Manifest, num_classes: usize) -> Self {
        Self {
            img: m.img,
            batch: m.batch,
            conv_channels: m.conv_channels.clone(),
            fc1: m.fc1,
            proj_dim: m.proj_dim,
            num_classes,
        }
    }

    /// Sane defaults matching `python/compile/model.py` (tests only).
    pub fn default_for(num_classes: usize) -> Self {
        Self {
            img: 32,
            batch: 32,
            conv_channels: vec![16, 32, 64],
            fc1: 128,
            proj_dim: 64,
            num_classes,
        }
    }

    pub const N_BLOCKS: usize = 5;

    /// Spatial side length at the *input* of conv block `i` (0-based).
    fn side_in(&self, i: usize) -> usize {
        self.img >> i
    }

    /// Channels at the input of block `i`.
    fn ch_in(&self, i: usize) -> usize {
        if i == 0 {
            3
        } else {
            self.conv_channels[i - 1]
        }
    }

    /// Flattened dimension entering fc1.
    pub fn flat_dim(&self) -> usize {
        let side = self.img >> self.conv_channels.len();
        side * side * self.conv_channels[self.conv_channels.len() - 1]
    }

    /// Parameter count of block `i` (weights + bias).
    pub fn block_params(&self, i: usize) -> usize {
        match i {
            0..=2 => 3 * 3 * self.ch_in(i) * self.conv_channels[i] + self.conv_channels[i],
            3 => self.flat_dim() * self.fc1 + self.fc1,
            4 => self.fc1 * self.num_classes + self.num_classes,
            _ => panic!("block {i} out of range"),
        }
    }

    /// Forward FLOPs of block `i`, per sample.
    pub fn block_fwd_flops(&self, i: usize) -> f64 {
        match i {
            0..=2 => {
                let side = self.side_in(i);
                // conv output is side x side (SAME padding), then pooled
                let out_elems = (side * side * self.conv_channels[i]) as f64;
                let mac = 2.0 * 9.0 * self.ch_in(i) as f64;
                // + bias/relu (1) + maxpool (~3 compares per output)
                out_elems * (mac + 1.0) + out_elems * 0.75 * 3.0
            }
            3 => 2.0 * (self.flat_dim() * self.fc1) as f64,
            4 => 2.0 * (self.fc1 * self.num_classes) as f64,
            _ => panic!("block {i} out of range"),
        }
    }

    /// Per-sample forward FLOPs through blocks `[0, k)` (client side).
    pub fn client_fwd_flops(&self, k: usize) -> f64 {
        (0..k).map(|i| self.block_fwd_flops(i)).sum()
    }

    /// Per-sample forward FLOPs through blocks `[k, 5)` (server side).
    pub fn server_fwd_flops(&self, k: usize) -> f64 {
        (k..Self::N_BLOCKS).map(|i| self.block_fwd_flops(i)).sum()
    }

    pub fn full_fwd_flops(&self) -> f64 {
        self.client_fwd_flops(Self::N_BLOCKS)
    }

    /// Projection-head FLOPs per sample (GAP + dense, fwd).
    pub fn proj_fwd_flops(&self, k: usize) -> f64 {
        let d = self.act_feature_dim(k);
        (self.act_elems(k) + 2 * d * self.proj_dim) as f64
    }

    /// Elements of one split activation (per sample).
    pub fn act_elems(&self, k: usize) -> usize {
        if k <= self.conv_channels.len() {
            let side = self.img >> k;
            side * side * self.conv_channels[k - 1]
        } else {
            self.fc1
        }
    }

    fn act_feature_dim(&self, k: usize) -> usize {
        if k <= self.conv_channels.len() {
            self.conv_channels[k - 1]
        } else {
            self.fc1
        }
    }

    /// Dense payload bytes of one activation batch (f32).
    pub fn act_batch_bytes(&self, k: usize) -> usize {
        self.act_elems(k) * self.batch * 4
    }

    /// Labels payload for one batch.
    pub fn label_batch_bytes(&self) -> usize {
        self.batch * 4
    }

    pub fn client_params(&self, k: usize) -> usize {
        (0..k).map(|i| self.block_params(i)).sum()
    }

    pub fn server_params(&self, k: usize) -> usize {
        (k..Self::N_BLOCKS).map(|i| self.block_params(i)).sum()
    }

    pub fn full_params(&self) -> usize {
        self.client_params(Self::N_BLOCKS)
    }

    pub fn proj_params(&self, k: usize) -> usize {
        self.act_feature_dim(k) * self.proj_dim + self.proj_dim
    }

    // ---- per-call training FLOPs (whole batch), bwd = 2x fwd ----

    /// AdaSplit / SL client-local train step (fwd + bwd + head).
    pub fn client_step_flops(&self, k: usize) -> f64 {
        3.0 * (self.client_fwd_flops(k) + self.proj_fwd_flops(k)) * self.batch as f64
    }

    /// Client forward only (SL fwd, eval, Table-5 extra pass).
    pub fn client_fwd_step_flops(&self, k: usize) -> f64 {
        self.client_fwd_flops(k) * self.batch as f64
    }

    /// Client backward from injected grad (SL client bwd).
    pub fn client_bwd_step_flops(&self, k: usize) -> f64 {
        2.0 * self.client_fwd_flops(k) * self.batch as f64
    }

    /// Server train step; `masked` adds the mask multiply/update work.
    pub fn server_step_flops(&self, k: usize, masked: bool) -> f64 {
        let base = 3.0 * self.server_fwd_flops(k) * self.batch as f64;
        if masked {
            // p*m fwd, gate apply, mask adam: ~6 ops per server parameter
            base + 6.0 * self.server_params(k) as f64
        } else {
            base
        }
    }

    /// Server eval forward for one batch.
    pub fn server_eval_flops(&self, k: usize) -> f64 {
        self.server_fwd_flops(k) * self.batch as f64
    }

    /// Full-model FL train step for one batch (all on client).
    pub fn fl_step_flops(&self) -> f64 {
        3.0 * self.full_fwd_flops() * self.batch as f64
    }

    pub fn fl_eval_flops(&self) -> f64 {
        self.full_fwd_flops() * self.batch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_python_model() {
        // mirrors python/compile/model.py: conv1 448, conv2 4640,
        // conv3 18496, fc1 131200, fc2 1290 (C=10)
        let s = ModelSpec::default_for(10);
        assert_eq!(s.block_params(0), 3 * 3 * 3 * 16 + 16);
        assert_eq!(s.block_params(1), 3 * 3 * 16 * 32 + 32);
        assert_eq!(s.block_params(2), 3 * 3 * 32 * 64 + 64);
        assert_eq!(s.flat_dim(), 4 * 4 * 64);
        assert_eq!(s.block_params(3), 1024 * 128 + 128);
        assert_eq!(s.block_params(4), 128 * 10 + 10);
        assert_eq!(s.full_params(), 448 + 4640 + 18496 + 131200 + 1290);
    }

    #[test]
    fn split_partitions_params() {
        let s = ModelSpec::default_for(50);
        for k in 1..=4 {
            assert_eq!(s.client_params(k) + s.server_params(k), s.full_params());
        }
    }

    #[test]
    fn act_shapes_match_python() {
        let s = ModelSpec::default_for(10);
        assert_eq!(s.act_elems(1), 16 * 16 * 16);
        assert_eq!(s.act_elems(2), 8 * 8 * 32);
        assert_eq!(s.act_elems(3), 4 * 4 * 64);
        assert_eq!(s.act_elems(4), 128);
        assert_eq!(s.act_batch_bytes(1), 32 * 16 * 16 * 16 * 4);
    }

    #[test]
    fn flops_monotonic_in_k() {
        let s = ModelSpec::default_for(10);
        for k in 1..4 {
            assert!(s.client_fwd_flops(k + 1) > s.client_fwd_flops(k));
            assert!(s.server_fwd_flops(k + 1) < s.server_fwd_flops(k));
        }
        let total = s.client_fwd_flops(2) + s.server_fwd_flops(2);
        assert!((total - s.full_fwd_flops()).abs() < 1e-6);
    }

    #[test]
    fn fl_step_dominates_client_step() {
        // the whole point of split learning: client-side work shrinks
        let s = ModelSpec::default_for(10);
        assert!(s.fl_step_flops() > 2.0 * s.client_step_flops(1));
    }
}
