//! Paper-style reporting: result tables (Tables 1-6) and trade-off curves
//! (Figure 1) rendered as aligned text / CSV.

pub mod series;
pub mod table;

pub use series::Series;
pub use table::ResultTable;
