//! Result table renderer matching the paper's column layout:
//! Method | Accuracy | Bandwidth (GB) | Compute (TFLOPs) | C3-Score.

use std::fmt::Write as _;

use crate::protocols::RunResult;

/// One printable results table.
#[derive(Clone, Debug, Default)]
pub struct ResultTable {
    pub title: String,
    rows: Vec<Row>,
}

#[derive(Clone, Debug)]
struct Row {
    method: String,
    accuracy: f64,
    acc_std: f64,
    bandwidth_gb: f64,
    client_tflops: f64,
    total_tflops: f64,
    c3: f64,
}

impl ResultTable {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), rows: Vec::new() }
    }

    pub fn add(&mut self, method: impl Into<String>, r: &RunResult, acc_std: f64) {
        self.rows.push(Row {
            method: method.into(),
            accuracy: r.best_accuracy,
            acc_std,
            bandwidth_gb: r.bandwidth_gb,
            client_tflops: r.client_tflops,
            total_tflops: r.total_tflops,
            c3: r.c3_score,
        });
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Recompute every row's C3-Score with *measured* budgets — the
    /// paper's §4.4 convention: B_max / C_max are set to the highest
    /// bandwidth and client-compute consumption among the table's own
    /// methods (the worst-performing baseline), so the score discriminates
    /// at any experiment scale.
    pub fn recompute_c3_measured(&mut self, temp: f64) {
        let b_max = self.rows.iter().map(|r| r.bandwidth_gb).fold(1e-12, f64::max);
        let c_max = self.rows.iter().map(|r| r.client_tflops).fold(1e-12, f64::max);
        let budgets = crate::metrics::Budgets { bandwidth_gb: b_max, client_tflops: c_max, temp };
        for r in &mut self.rows {
            r.c3 = crate::metrics::c3_score(r.accuracy, r.bandwidth_gb, r.client_tflops, &budgets);
        }
    }

    /// Method name with the best (highest) C3-Score.
    pub fn best_by_c3(&self) -> Option<&str> {
        self.rows
            .iter()
            .max_by(|a, b| a.c3.partial_cmp(&b.c3).unwrap())
            .map(|r| r.method.as_str())
    }

    /// Render an aligned text table (the paper's layout).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let _ = writeln!(
            out,
            "{:<28} {:>16} {:>14} {:>20} {:>9}",
            "Method", "Accuracy", "Bandwidth(GB)", "Compute(TFLOPs)", "C3-Score"
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{:<28} {:>10.2}±{:<5.2} {:>14.3} {:>12.2} ({:<6.2}) {:>8.3}",
                r.method,
                r.accuracy,
                r.acc_std,
                r.bandwidth_gb,
                r.client_tflops,
                r.total_tflops,
                r.c3
            );
        }
        out
    }

    /// CSV export for EXPERIMENTS.md / downstream plotting.
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("method,accuracy,acc_std,bandwidth_gb,client_tflops,total_tflops,c3\n");
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{},{:.3},{:.3},{:.4},{:.4},{:.4},{:.4}",
                r.method, r.accuracy, r.acc_std, r.bandwidth_gb, r.client_tflops,
                r.total_tflops, r.c3
            );
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(acc: f64, c3: f64) -> RunResult {
        RunResult {
            protocol: "X".into(),
            dataset: "d".into(),
            accuracy: acc,
            best_accuracy: acc,
            bandwidth_gb: 1.0,
            client_tflops: 2.0,
            total_tflops: 3.0,
            c3_score: c3,
            mask_density: 1.0,
            rounds: 5,
            participation: 1.0,
            sampled_clients_per_round: 5.0,
            scheduler: "sync-all".into(),
            sim_time: 5.0,
            max_staleness: 0,
            delayed_gradients: false,
            adaptive: false,
            final_bound: 0,
            bound_switches: 0,
        }
    }

    #[test]
    fn renders_rows_and_best() {
        let mut t = ResultTable::new("Table X");
        t.add("A", &result(80.0, 0.7), 0.1);
        t.add("B", &result(90.0, 0.9), 0.2);
        assert_eq!(t.len(), 2);
        assert_eq!(t.best_by_c3(), Some("B"));
        let text = t.render();
        assert!(text.contains("Table X"));
        assert!(text.contains("A"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
    }
}
