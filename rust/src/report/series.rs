//! Trade-off curve series (Figure 1): (resource, accuracy) points per
//! method, renderable as CSV or a quick ASCII scatter.

use std::fmt::Write as _;

/// A named series of (x = resource, y = accuracy) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub x_label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>, x_label: impl Into<String>) -> Self {
        Self { name: name.into(), x_label: x_label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// True when accuracy is (weakly) increasing with resources — the
    /// sanity property of any trade-off curve.
    pub fn roughly_monotone(&self, tolerance: f64) -> bool {
        let mut pts = self.points.clone();
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        pts.windows(2).all(|w| w[1].1 >= w[0].1 - tolerance)
    }

    pub fn to_csv(&self) -> String {
        let mut out = format!("{},accuracy\n", self.x_label);
        for (x, y) in &self.points {
            let _ = writeln!(out, "{x:.5},{y:.3}");
        }
        out
    }
}

/// Render several series as a compact ASCII chart (y = accuracy %).
pub fn ascii_chart(series: &[Series], width: usize, height: usize) -> String {
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.clone()).collect();
    if all.is_empty() {
        return String::from("(empty chart)\n");
    }
    let (xmin, xmax) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), p| (lo.min(p.0), hi.max(p.0)));
    let (ymin, ymax) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), p| (lo.min(p.1), hi.max(p.1)));
    let xspan = (xmax - xmin).max(1e-9);
    let yspan = (ymax - ymin).max(1e-9);
    let mut grid = vec![vec![b' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = b"*+ox#@"[si % 6];
        for &(x, y) in &s.points {
            let col = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row = (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
            grid[height - 1 - row][col] = glyph;
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "accuracy {ymin:.1}%..{ymax:.1}%  x: {xmin:.2}..{xmax:.2}");
    for row in grid {
        let _ = writeln!(out, "|{}", String::from_utf8_lossy(&row));
    }
    for (si, s) in series.iter().enumerate() {
        let _ = writeln!(out, "  {} = {}", b"*+ox#@"[si % 6] as char, s.name);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_detection() {
        let mut s = Series::new("a", "gb");
        s.push(1.0, 50.0);
        s.push(2.0, 60.0);
        s.push(3.0, 59.5);
        assert!(s.roughly_monotone(1.0));
        assert!(!s.roughly_monotone(0.1));
    }

    #[test]
    fn csv_and_chart() {
        let mut s = Series::new("a", "gb");
        s.push(1.0, 50.0);
        s.push(2.0, 80.0);
        assert_eq!(s.to_csv().lines().count(), 3);
        let chart = ascii_chart(&[s], 20, 5);
        assert!(chart.contains('*'));
    }
}
