//! `CostMeter`: running totals of computation (eq. 1) and communication
//! (eq. 2), split by side so the paper's "client compute (total compute)"
//! column falls out directly.

/// Accumulates FLOPs and payload bytes over a run.
#[derive(Clone, Debug, Default)]
pub struct CostMeter {
    /// FLOPs executed on client devices (sum over clients).
    pub client_flops: f64,
    /// FLOPs executed on the server.
    pub server_flops: f64,
    /// Bytes transmitted client -> server (P_is).
    pub up_bytes: f64,
    /// Bytes transmitted server -> client (P_si).
    pub down_bytes: f64,
    /// Client-to-client bytes (SL-basic weight handoff).
    pub peer_bytes: f64,
}

impl CostMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_client_flops(&mut self, f: f64) {
        self.client_flops += f;
    }

    pub fn add_server_flops(&mut self, f: f64) {
        self.server_flops += f;
    }

    pub fn add_up(&mut self, bytes: usize) {
        self.up_bytes += bytes as f64;
    }

    pub fn add_down(&mut self, bytes: usize) {
        self.down_bytes += bytes as f64;
    }

    pub fn add_peer(&mut self, bytes: usize) {
        self.peer_bytes += bytes as f64;
    }

    /// Total bandwidth in GB (10^9 bytes, as the paper reports).
    pub fn bandwidth_gb(&self) -> f64 {
        (self.up_bytes + self.down_bytes + self.peer_bytes) / 1e9
    }

    /// Client compute in TFLOPs (the paper's headline "Compute" number).
    pub fn client_tflops(&self) -> f64 {
        self.client_flops / 1e12
    }

    /// Total (client + server) compute in TFLOPs — the parenthesized
    /// column of Tables 1-4.
    pub fn total_tflops(&self) -> f64 {
        (self.client_flops + self.server_flops) / 1e12
    }

    /// Merge another meter (engine fan-in and multi-seed aggregation).
    /// Per-client deltas are merged on the caller's thread in client-id
    /// order, keeping parallel runs bit-identical to serial ones.
    pub fn merge(&mut self, other: &CostMeter) {
        self.client_flops += other.client_flops;
        self.server_flops += other.server_flops;
        self.up_bytes += other.up_bytes;
        self.down_bytes += other.down_bytes;
        self.peer_bytes += other.peer_bytes;
    }

    /// Merge a per-client delta under a heterogeneous device model
    /// (DESIGN.md §7): the client's FLOPs are scaled by `compute_scale`
    /// (device-time against the compute budget — a half-speed device's
    /// FLOPs cost twice the budget) and its up/down/peer bytes by
    /// `net_scale` (link-time against the bandwidth budget). Server-side
    /// FLOPs stay unscaled (the server is the baseline). With both scales
    /// at `1.0` this is exactly [`CostMeter::merge`] — the driver takes
    /// the plain-merge branch under uniform speeds anyway, keeping the
    /// default path bit-identical to the pre-speed-model accounting.
    pub fn merge_scaled(&mut self, other: &CostMeter, compute_scale: f64, net_scale: f64) {
        self.client_flops += other.client_flops * compute_scale;
        self.server_flops += other.server_flops;
        self.up_bytes += other.up_bytes * net_scale;
        self.down_bytes += other.down_bytes * net_scale;
        self.peer_bytes += other.peer_bytes * net_scale;
    }

    /// Scale all counters (e.g. average over seeds).
    pub fn scale(&mut self, s: f64) {
        self.client_flops *= s;
        self.server_flops *= s;
        self.up_bytes *= s;
        self.down_bytes *= s;
        self.peer_bytes *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_and_units() {
        let mut m = CostMeter::new();
        m.add_client_flops(2e12);
        m.add_server_flops(1e12);
        m.add_up(500_000_000);
        m.add_down(500_000_000);
        assert!((m.bandwidth_gb() - 1.0).abs() < 1e-9);
        assert!((m.client_tflops() - 2.0).abs() < 1e-9);
        assert!((m.total_tflops() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn merge_covers_every_field() {
        let mut delta = CostMeter::new();
        delta.add_client_flops(1.0);
        delta.add_server_flops(2.0);
        delta.add_up(3);
        delta.add_down(4);
        delta.add_peer(5);
        let mut total = CostMeter::new();
        total.merge(&delta);
        total.merge(&delta);
        assert_eq!(total.client_flops, 2.0);
        assert_eq!(total.server_flops, 4.0);
        assert_eq!(total.up_bytes, 6.0);
        assert_eq!(total.down_bytes, 8.0);
        assert_eq!(total.peer_bytes, 10.0);
    }

    #[test]
    fn merge_scaled_applies_per_axis_rates() {
        let mut delta = CostMeter::new();
        delta.add_client_flops(10.0);
        delta.add_server_flops(8.0);
        delta.add_up(100);
        delta.add_down(200);
        delta.add_peer(400);
        let mut total = CostMeter::new();
        total.merge_scaled(&delta, 2.0, 0.5);
        assert_eq!(total.client_flops, 20.0, "client compute scaled by device rate");
        assert_eq!(total.server_flops, 8.0, "server compute stays baseline");
        assert_eq!(total.up_bytes, 50.0);
        assert_eq!(total.down_bytes, 100.0);
        assert_eq!(total.peer_bytes, 200.0);
        // unit scales degenerate to the plain merge bit-for-bit
        let mut a = CostMeter::new();
        let mut b = CostMeter::new();
        a.merge(&delta);
        b.merge_scaled(&delta, 1.0, 1.0);
        assert_eq!(a.client_flops.to_bits(), b.client_flops.to_bits());
        assert_eq!(a.up_bytes.to_bits(), b.up_bytes.to_bits());
    }

    #[test]
    fn merge_and_scale() {
        let mut a = CostMeter::new();
        a.add_up(1000);
        let mut b = CostMeter::new();
        b.add_up(3000);
        a.merge(&b);
        a.scale(0.5);
        assert!((a.up_bytes - 2000.0).abs() < 1e-9);
    }
}
