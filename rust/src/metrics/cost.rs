//! `CostMeter`: running totals of computation (eq. 1) and communication
//! (eq. 2), split by side so the paper's "client compute (total compute)"
//! column falls out directly.

/// Accumulates FLOPs and payload bytes over a run.
#[derive(Clone, Debug, Default)]
pub struct CostMeter {
    /// FLOPs executed on client devices (sum over clients).
    pub client_flops: f64,
    /// FLOPs executed on the server.
    pub server_flops: f64,
    /// Bytes transmitted client -> server (P_is).
    pub up_bytes: f64,
    /// Bytes transmitted server -> client (P_si).
    pub down_bytes: f64,
    /// Client-to-client bytes (SL-basic weight handoff).
    pub peer_bytes: f64,
}

impl CostMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_client_flops(&mut self, f: f64) {
        self.client_flops += f;
    }

    pub fn add_server_flops(&mut self, f: f64) {
        self.server_flops += f;
    }

    pub fn add_up(&mut self, bytes: usize) {
        self.up_bytes += bytes as f64;
    }

    pub fn add_down(&mut self, bytes: usize) {
        self.down_bytes += bytes as f64;
    }

    pub fn add_peer(&mut self, bytes: usize) {
        self.peer_bytes += bytes as f64;
    }

    /// Total bandwidth in GB (10^9 bytes, as the paper reports).
    pub fn bandwidth_gb(&self) -> f64 {
        (self.up_bytes + self.down_bytes + self.peer_bytes) / 1e9
    }

    /// Client compute in TFLOPs (the paper's headline "Compute" number).
    pub fn client_tflops(&self) -> f64 {
        self.client_flops / 1e12
    }

    /// Total (client + server) compute in TFLOPs — the parenthesized
    /// column of Tables 1-4.
    pub fn total_tflops(&self) -> f64 {
        (self.client_flops + self.server_flops) / 1e12
    }

    /// Merge another meter (engine fan-in and multi-seed aggregation).
    /// Per-client deltas are merged on the caller's thread in client-id
    /// order, keeping parallel runs bit-identical to serial ones.
    pub fn merge(&mut self, other: &CostMeter) {
        self.client_flops += other.client_flops;
        self.server_flops += other.server_flops;
        self.up_bytes += other.up_bytes;
        self.down_bytes += other.down_bytes;
        self.peer_bytes += other.peer_bytes;
    }

    /// Scale all counters (e.g. average over seeds).
    pub fn scale(&mut self, s: f64) {
        self.client_flops *= s;
        self.server_flops *= s;
        self.up_bytes *= s;
        self.down_bytes *= s;
        self.peer_bytes *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_and_units() {
        let mut m = CostMeter::new();
        m.add_client_flops(2e12);
        m.add_server_flops(1e12);
        m.add_up(500_000_000);
        m.add_down(500_000_000);
        assert!((m.bandwidth_gb() - 1.0).abs() < 1e-9);
        assert!((m.client_tflops() - 2.0).abs() < 1e-9);
        assert!((m.total_tflops() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn merge_covers_every_field() {
        let mut delta = CostMeter::new();
        delta.add_client_flops(1.0);
        delta.add_server_flops(2.0);
        delta.add_up(3);
        delta.add_down(4);
        delta.add_peer(5);
        let mut total = CostMeter::new();
        total.merge(&delta);
        total.merge(&delta);
        assert_eq!(total.client_flops, 2.0);
        assert_eq!(total.server_flops, 4.0);
        assert_eq!(total.up_bytes, 6.0);
        assert_eq!(total.down_bytes, 8.0);
        assert_eq!(total.peer_bytes, 10.0);
    }

    #[test]
    fn merge_and_scale() {
        let mut a = CostMeter::new();
        a.add_up(1000);
        let mut b = CostMeter::new();
        b.add_up(3000);
        a.merge(&b);
        a.scale(0.5);
        assert!((a.up_bytes - 2000.0).abs() < 1e-9);
    }
}
