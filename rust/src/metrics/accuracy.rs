//! Accuracy aggregation: per-client correct/total accumulation and
//! mean +/- std over independent runs (the paper reports both).

/// Accumulates correct/total counts (optionally per client).
#[derive(Clone, Debug, Default)]
pub struct AccuracyAccum {
    correct: f64,
    total: f64,
    per_client: Vec<(f64, f64)>,
}

impl AccuracyAccum {
    pub fn new(n_clients: usize) -> Self {
        Self { correct: 0.0, total: 0.0, per_client: vec![(0.0, 0.0); n_clients] }
    }

    pub fn add(&mut self, client: usize, correct: f64, total: f64) {
        self.correct += correct;
        self.total += total;
        if client < self.per_client.len() {
            self.per_client[client].0 += correct;
            self.per_client[client].1 += total;
        }
    }

    /// Overall accuracy in percent.
    pub fn accuracy_pct(&self) -> f64 {
        if self.total == 0.0 {
            0.0
        } else {
            100.0 * self.correct / self.total
        }
    }

    /// Per-client accuracies in percent.
    pub fn per_client_pct(&self) -> Vec<f64> {
        self.per_client
            .iter()
            .map(|(c, t)| if *t == 0.0 { 0.0 } else { 100.0 * c / t })
            .collect()
    }

    /// Unweighted mean of per-client accuracies (the paper's convention
    /// for heterogeneous client datasets).
    pub fn mean_client_pct(&self) -> f64 {
        let v = self.per_client_pct();
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    }
}

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_overall_and_per_client() {
        let mut a = AccuracyAccum::new(2);
        a.add(0, 8.0, 10.0);
        a.add(1, 5.0, 10.0);
        assert!((a.accuracy_pct() - 65.0).abs() < 1e-9);
        assert_eq!(a.per_client_pct(), vec![80.0, 50.0]);
        assert!((a.mean_client_pct() - 65.0).abs() < 1e-9);
    }

    #[test]
    fn empty_is_zero() {
        let a = AccuracyAccum::new(0);
        assert_eq!(a.accuracy_pct(), 0.0);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-9);
        assert!((s - 2.0).abs() < 1e-9);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }
}
