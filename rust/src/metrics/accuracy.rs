//! Accuracy aggregation: per-client correct/total accumulation and
//! mean +/- std over independent runs (the paper reports both).

/// Accumulates correct/total counts (optionally per client).
#[derive(Clone, Debug, Default)]
pub struct AccuracyAccum {
    correct: f64,
    total: f64,
    per_client: Vec<(f64, f64)>,
}

impl AccuracyAccum {
    pub fn new(n_clients: usize) -> Self {
        Self { correct: 0.0, total: 0.0, per_client: vec![(0.0, 0.0); n_clients] }
    }

    pub fn add(&mut self, client: usize, correct: f64, total: f64) {
        self.correct += correct;
        self.total += total;
        if client < self.per_client.len() {
            self.per_client[client].0 += correct;
            self.per_client[client].1 += total;
        }
    }

    /// Overall accuracy in percent.
    pub fn accuracy_pct(&self) -> f64 {
        if self.total == 0.0 {
            0.0
        } else {
            100.0 * self.correct / self.total
        }
    }

    /// Per-client accuracies in percent.
    pub fn per_client_pct(&self) -> Vec<f64> {
        self.per_client
            .iter()
            .map(|(c, t)| if *t == 0.0 { 0.0 } else { 100.0 * c / t })
            .collect()
    }

    /// Merge another accumulator (engine fan-in: per-worker partials are
    /// combined on the caller's thread in client-id order, so parallel
    /// eval is bit-identical to serial eval).
    pub fn merge(&mut self, other: &AccuracyAccum) {
        self.correct += other.correct;
        self.total += other.total;
        if self.per_client.len() < other.per_client.len() {
            self.per_client.resize(other.per_client.len(), (0.0, 0.0));
        }
        for (d, s) in self.per_client.iter_mut().zip(&other.per_client) {
            d.0 += s.0;
            d.1 += s.1;
        }
    }

    /// Unweighted mean of per-client accuracies (the paper's convention
    /// for heterogeneous client datasets).
    pub fn mean_client_pct(&self) -> f64 {
        let v = self.per_client_pct();
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    }
}

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_overall_and_per_client() {
        let mut a = AccuracyAccum::new(2);
        a.add(0, 8.0, 10.0);
        a.add(1, 5.0, 10.0);
        assert!((a.accuracy_pct() - 65.0).abs() < 1e-9);
        assert_eq!(a.per_client_pct(), vec![80.0, 50.0]);
        assert!((a.mean_client_pct() - 65.0).abs() < 1e-9);
    }

    #[test]
    fn empty_is_zero() {
        let a = AccuracyAccum::new(0);
        assert_eq!(a.accuracy_pct(), 0.0);
    }

    #[test]
    fn merge_matches_serial_adds() {
        // serial accumulation ...
        let mut serial = AccuracyAccum::new(3);
        serial.add(0, 8.0, 10.0);
        serial.add(1, 5.0, 10.0);
        serial.add(2, 2.0, 4.0);
        // ... must equal per-client partials merged in id order
        let mut merged = AccuracyAccum::new(3);
        for (i, (c, t)) in [(8.0, 10.0), (5.0, 10.0), (2.0, 4.0)].iter().enumerate() {
            let mut part = AccuracyAccum::new(3);
            part.add(i, *c, *t);
            merged.merge(&part);
        }
        assert_eq!(serial.accuracy_pct(), merged.accuracy_pct());
        assert_eq!(serial.per_client_pct(), merged.per_client_pct());
        assert_eq!(serial.mean_client_pct(), merged.mean_client_pct());
    }

    #[test]
    fn merge_grows_to_larger_accumulator() {
        let mut a = AccuracyAccum::new(1);
        a.add(0, 1.0, 2.0);
        let mut b = AccuracyAccum::new(3);
        b.add(2, 3.0, 4.0);
        a.merge(&b);
        assert_eq!(a.per_client_pct().len(), 3);
        assert_eq!(a.per_client_pct()[2], 75.0);
        assert!((a.accuracy_pct() - 100.0 * 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-9);
        assert!((s - 2.0).abs() < 1e-9);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }
}
