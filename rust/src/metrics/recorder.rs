//! Run recorder: per-round training curves + event traces, exportable as
//! CSV/JSON into `results/` for EXPERIMENTS.md and the figure benches.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::Json;

/// One row of the training curve.
#[derive(Clone, Debug)]
pub struct RoundStat {
    pub round: usize,
    /// `local` or `global` (AdaSplit phases; other protocols: `train`)
    pub phase: String,
    pub train_loss: f64,
    pub accuracy_pct: f64,
    pub bandwidth_gb: f64,
    pub client_tflops: f64,
    pub total_tflops: f64,
    /// mean active-mask density on the server (AdaSplit; 1.0 otherwise)
    pub mask_density: f64,
    /// simulated wall-clock at the round's merge, in baseline-round units
    /// (the scheduler's virtual clock; `round + 1` for a synchronous run
    /// over uniform client speeds)
    pub sim_time: f64,
    /// staleness of the round's most stale merged contribution, in rounds
    /// (0 for every synchronous scheduler; never exceeds the
    /// `AsyncBounded` staleness bound)
    pub max_staleness: usize,
    /// staleness bound in effect while the round was planned (0 for the
    /// synchronous schedulers; the configured bound for a fixed async
    /// run; the controller's current arm under `--adaptive-bound`, so
    /// the column traces the bound trajectory)
    pub bound: usize,
    /// clients selected this round (AdaSplit orchestrator; the round's
    /// participant set otherwise)
    pub selected: Vec<usize>,
    /// clients sampled into the round by the scheduler (all clients under
    /// `SyncAll`; the per-round subsample under `SampledSync`)
    pub participants: Vec<usize>,
    /// cumulative events processed by the event driver when this row was
    /// recorded (0 under the rounds engine — the barrier loop pops no
    /// events). Under `--engine events` the row's "round" is its merge
    /// index, and this column traces event traffic along the run.
    pub events: usize,
}

/// Collects `RoundStat`s plus free-form trace lines.
#[derive(Debug, Default)]
pub struct Recorder {
    pub rounds: Vec<RoundStat>,
    pub trace: Vec<String>,
    pub trace_enabled: bool,
}

impl Recorder {
    pub fn new(trace_enabled: bool) -> Self {
        Self { rounds: Vec::new(), trace: Vec::new(), trace_enabled }
    }

    pub fn push(&mut self, stat: RoundStat) {
        self.rounds.push(stat);
    }

    pub fn trace(&mut self, line: impl Into<String>) {
        if self.trace_enabled {
            self.trace.push(line.into());
        }
    }

    pub fn last_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.accuracy_pct).unwrap_or(0.0)
    }

    /// Best accuracy seen at any eval point (converged accuracy proxy).
    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.accuracy_pct)
            .fold(0.0, f64::max)
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path).context("creating csv")?;
        writeln!(
            f,
            "round,phase,train_loss,accuracy_pct,bandwidth_gb,client_tflops,total_tflops,mask_density,sim_time,max_staleness,bound,n_selected,n_participants,events"
        )?;
        for r in &self.rounds {
            writeln!(
                f,
                "{},{},{:.6},{:.3},{:.6},{:.6},{:.6},{:.4},{:.4},{},{},{},{},{}",
                r.round,
                r.phase,
                r.train_loss,
                r.accuracy_pct,
                r.bandwidth_gb,
                r.client_tflops,
                r.total_tflops,
                r.mask_density,
                r.sim_time,
                r.max_staleness,
                r.bound,
                r.selected.len(),
                r.participants.len(),
                r.events
            )?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rounds
                .iter()
                .map(|r| {
                    let mut m = std::collections::BTreeMap::new();
                    m.insert("round".into(), Json::Num(r.round as f64));
                    m.insert("phase".into(), Json::Str(r.phase.clone()));
                    m.insert("train_loss".into(), Json::Num(r.train_loss));
                    m.insert("accuracy_pct".into(), Json::Num(r.accuracy_pct));
                    m.insert("bandwidth_gb".into(), Json::Num(r.bandwidth_gb));
                    m.insert("client_tflops".into(), Json::Num(r.client_tflops));
                    m.insert("total_tflops".into(), Json::Num(r.total_tflops));
                    m.insert("mask_density".into(), Json::Num(r.mask_density));
                    m.insert("sim_time".into(), Json::Num(r.sim_time));
                    m.insert("max_staleness".into(), Json::Num(r.max_staleness as f64));
                    m.insert("bound".into(), Json::Num(r.bound as f64));
                    m.insert(
                        "selected".into(),
                        Json::Arr(r.selected.iter().map(|&s| Json::Num(s as f64)).collect()),
                    );
                    m.insert(
                        "participants".into(),
                        Json::Arr(
                            r.participants.iter().map(|&s| Json::Num(s as f64)).collect(),
                        ),
                    );
                    m.insert("events".into(), Json::Num(r.events as f64));
                    Json::Obj(m)
                })
                .collect(),
        )
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty()).context("writing json")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(round: usize, acc: f64) -> RoundStat {
        RoundStat {
            round,
            phase: "train".into(),
            train_loss: 1.0,
            accuracy_pct: acc,
            bandwidth_gb: 0.1,
            client_tflops: 0.2,
            total_tflops: 0.3,
            mask_density: 1.0,
            sim_time: round as f64 + 1.0,
            max_staleness: 0,
            bound: 2,
            selected: vec![0, 1],
            participants: vec![0, 1, 2],
            events: round * 7,
        }
    }

    #[test]
    fn best_and_last() {
        let mut r = Recorder::new(false);
        r.push(stat(0, 50.0));
        r.push(stat(1, 70.0));
        r.push(stat(2, 65.0));
        assert_eq!(r.last_accuracy(), 65.0);
        assert_eq!(r.best_accuracy(), 70.0);
    }

    #[test]
    fn trace_gating() {
        let mut r = Recorder::new(false);
        r.trace("hidden");
        assert!(r.trace.is_empty());
        let mut r = Recorder::new(true);
        r.trace("shown");
        assert_eq!(r.trace.len(), 1);
    }

    #[test]
    fn csv_roundtrip() {
        let mut r = Recorder::new(false);
        r.push(stat(0, 10.0));
        let dir = std::env::temp_dir().join("adasplit_test_csv");
        let path = dir.join("curve.csv");
        r.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() == 2);
        assert!(text.contains("accuracy_pct"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_rows_carry_the_bound_trajectory() {
        let mut r = Recorder::new(false);
        r.push(stat(0, 10.0));
        let json = r.to_json();
        let rows = json.as_arr().unwrap();
        assert_eq!(rows[0].get("bound").unwrap().as_usize().unwrap(), 2);
        assert_eq!(rows[0].get("events").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn csv_header_and_every_row_have_the_same_column_count() {
        // the header literal and the row format string are maintained by
        // hand: a field added to `RoundStat` and threaded into only one
        // of them would silently skew every downstream CSV consumer, so
        // pin that they always agree column-for-column
        let mut r = Recorder::new(false);
        r.push(stat(0, 10.0));
        r.push(stat(1, 55.5));
        r.push(stat(2, 42.0));
        let dir = std::env::temp_dir().join("adasplit_test_csv_columns");
        let path = dir.join("curve.csv");
        r.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let header = lines.next().expect("header line");
        let columns = header.split(',').count();
        assert!(columns >= 14, "expected the full RoundStat column set");
        assert!(
            header.split(',').any(|c| c == "bound"),
            "adaptive bound trajectory column missing from the header"
        );
        assert!(
            header.split(',').any(|c| c == "events"),
            "event-engine traffic column missing from the header"
        );
        let mut rows = 0;
        for (i, line) in lines.enumerate() {
            assert_eq!(
                line.split(',').count(),
                columns,
                "row {i} column count != header ({header})"
            );
            rows += 1;
        }
        assert_eq!(rows, 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
