//! Cost accounting (paper eqs. 1-2), the C3-Score (eq. 9), accuracy
//! aggregation, and run recording.

pub mod accuracy;
pub mod c3;
pub mod cost;
pub mod recorder;

pub use accuracy::{mean_std, AccuracyAccum};
pub use c3::{c3_score, cost_decay, Budgets};
pub use cost::CostMeter;
pub use recorder::{Recorder, RoundStat};
