//! C3-Score (paper eq. 9): a single bounded score trading accuracy against
//! bandwidth and client-compute consumption under explicit budgets.
//!
//!   C3 = (A / A_max) * exp(-(B/B_max + C/C_max) / T)
//!
//! The paper does not print T; calibrating against every published row of
//! Tables 1-2 gives T ~= 8 (e.g. FedAvg on Mixed-NonIID: 0.8221 *
//! exp(-(0.0282 + 1.0)/8) = 0.723 vs the paper's 0.72), so 8.0 is the
//! default temperature.

/// Resource budgets (paper §4.3: set to the worst-performing baseline's
/// consumption on each dataset).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Budgets {
    /// bandwidth budget B_max in GB
    pub bandwidth_gb: f64,
    /// client-compute budget C_max in TFLOPs
    pub client_tflops: f64,
    /// scaling temperature T
    pub temp: f64,
}

impl Budgets {
    pub fn new(bandwidth_gb: f64, client_tflops: f64) -> Self {
        Self { bandwidth_gb, client_tflops, temp: 8.0 }
    }

    /// The paper's published budgets for each dataset protocol.
    pub fn paper_mixed_cifar() -> Self {
        Self::new(35.94, 11.77)
    }

    pub fn paper_mixed_noniid() -> Self {
        Self::new(84.64, 17.13)
    }
}

/// One budget axis's normalized consumption. A non-positive budget is a
/// degenerate "no allowance" axis: the naive `consumed / 0.0` would give
/// `inf` (or `NaN` at `0/0`), which then poisons every downstream
/// consumer of the score — notably the adaptive bound controller's
/// reward. The defined limit treats a zero-budget axis as *saturated*:
/// it contributes exactly its full share (`1.0`, i.e. an `exp(-1/T)`
/// decay factor), the same as spending a positive budget to the brim.
fn axis_hat(consumed: f64, budget: f64) -> f64 {
    if budget <= 0.0 {
        1.0
    } else {
        (consumed / budget).max(0.0)
    }
}

/// The C3 cost-decay factor `exp(-(B/B_max + C/C_max) / T)` in (0, 1]:
/// the resource half of the score, reused by the adaptive bound
/// controller to shape per-window rewards. Degenerate (zero) budget axes
/// count as saturated — see [`axis_hat`] — so the factor is always a
/// finite, positive number.
pub fn cost_decay(bandwidth_gb: f64, client_tflops: f64, b: &Budgets) -> f64 {
    let b_hat = axis_hat(bandwidth_gb, b.bandwidth_gb);
    let c_hat = axis_hat(client_tflops, b.client_tflops);
    (-(b_hat + c_hat) / b.temp).exp()
}

/// C3-Score of a method. `accuracy_pct` in [0, 100].
pub fn c3_score(accuracy_pct: f64, bandwidth_gb: f64, client_tflops: f64, b: &Budgets) -> f64 {
    let a_hat = (accuracy_pct / 100.0).clamp(0.0, 1.0);
    a_hat * cost_decay(bandwidth_gb, client_tflops, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_zero_one() {
        let b = Budgets::new(10.0, 10.0);
        for &(a, bw, c) in
            &[(0.0, 0.0, 0.0), (100.0, 0.0, 0.0), (100.0, 1e6, 1e6), (55.0, 5.0, 5.0)]
        {
            let s = c3_score(a, bw, c, &b);
            assert!((0.0..=1.0).contains(&s), "{s}");
        }
    }

    #[test]
    fn perfect_free_method_scores_one() {
        let b = Budgets::new(10.0, 10.0);
        assert!((c3_score(100.0, 0.0, 0.0, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_each_axis() {
        let b = Budgets::new(10.0, 10.0);
        assert!(c3_score(90.0, 1.0, 1.0, &b) > c3_score(80.0, 1.0, 1.0, &b));
        assert!(c3_score(90.0, 1.0, 1.0, &b) > c3_score(90.0, 2.0, 1.0, &b));
        assert!(c3_score(90.0, 1.0, 1.0, &b) > c3_score(90.0, 1.0, 2.0, &b));
    }

    #[test]
    fn zero_budget_axes_are_saturated_not_nan() {
        // B_max == 0: the bandwidth axis is a defined limit (full decay
        // share exp(-1/T)), not a division by zero
        let b0 = Budgets::new(0.0, 10.0);
        let s = c3_score(80.0, 5.0, 5.0, &b0);
        assert!(s.is_finite(), "zero bandwidth budget must not produce NaN/inf");
        let expect = 0.8 * (-(1.0 + 0.5) / b0.temp).exp();
        assert!((s - expect).abs() < 1e-12, "got {s}, expected {expect}");
        // ... even when consumption on that axis is also zero (0/0)
        assert!(c3_score(80.0, 0.0, 5.0, &b0).is_finite());

        // C_max == 0: same on the compute axis
        let c0 = Budgets::new(10.0, 0.0);
        let s = c3_score(80.0, 5.0, 0.0, &c0);
        let expect = 0.8 * (-(0.5 + 1.0) / c0.temp).exp();
        assert!((s - expect).abs() < 1e-12, "got {s}, expected {expect}");

        // both axes degenerate: both saturated, score still in (0, 1]
        let bc0 = Budgets::new(0.0, 0.0);
        let s = c3_score(100.0, 123.0, 456.0, &bc0);
        let expect = (-2.0 / bc0.temp).exp();
        assert!((s - expect).abs() < 1e-12, "got {s}, expected {expect}");
        assert!((0.0..=1.0).contains(&s));
    }

    #[test]
    fn cost_decay_is_bounded_finite_and_monotone() {
        let b = Budgets::new(10.0, 10.0);
        assert!((cost_decay(0.0, 0.0, &b) - 1.0).abs() < 1e-12, "free is no decay");
        assert!(cost_decay(5.0, 5.0, &b) > cost_decay(10.0, 5.0, &b));
        assert!(cost_decay(5.0, 5.0, &b) > cost_decay(5.0, 10.0, &b));
        for budgets in [b, Budgets::new(0.0, 10.0), Budgets::new(0.0, 0.0)] {
            let d = cost_decay(1e9, 1e9, &budgets);
            assert!(d.is_finite() && d > 0.0 && d <= 1.0, "{d}");
        }
    }

    #[test]
    fn reproduces_paper_rows_with_t8() {
        // Table 1 (Mixed-NonIID): budgets B=84.64 GB, C=17.13 TFLOPs
        let b = Budgets::paper_mixed_noniid();
        let cases = [
            // (acc, bw, client compute, published C3)
            (84.65, 84.54, 3.76, 0.72),  // SL-basic
            (84.67, 84.64, 3.76, 0.73),  // SplitFed
            (82.21, 2.39, 17.13, 0.72),  // FedAvg
            (85.09, 2.39, 17.13, 0.75),  // FedProx
            (88.88, 9.71, 5.38, 0.85),   // AdaSplit k=0.6
            (87.11, 2.43, 5.38, 0.83),   // AdaSplit k=0.75
        ];
        for (acc, bw, c, published) in cases {
            let s = c3_score(acc, bw, c, &b);
            assert!(
                (s - published).abs() < 0.015,
                "acc={acc}: got {s:.3}, paper {published}"
            );
        }
    }

    #[test]
    fn reproduces_paper_rows_mixed_cifar() {
        let b = Budgets::paper_mixed_cifar();
        let cases = [
            (67.90, 34.88, 1.66, 0.59), // SL-basic
            (91.31, 2.39, 11.77, 0.79), // FedAvg
            (91.92, 2.85, 2.38, 0.89),  // AdaSplit
        ];
        for (acc, bw, c, published) in cases {
            let s = c3_score(acc, bw, c, &b);
            assert!(
                (s - published).abs() < 0.02,
                "acc={acc}: got {s:.3}, paper {published}"
            );
        }
    }
}
