//! C3-Score (paper eq. 9): a single bounded score trading accuracy against
//! bandwidth and client-compute consumption under explicit budgets.
//!
//!   C3 = (A / A_max) * exp(-(B/B_max + C/C_max) / T)
//!
//! The paper does not print T; calibrating against every published row of
//! Tables 1-2 gives T ~= 8 (e.g. FedAvg on Mixed-NonIID: 0.8221 *
//! exp(-(0.0282 + 1.0)/8) = 0.723 vs the paper's 0.72), so 8.0 is the
//! default temperature.

/// Resource budgets (paper §4.3: set to the worst-performing baseline's
/// consumption on each dataset).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Budgets {
    /// bandwidth budget B_max in GB
    pub bandwidth_gb: f64,
    /// client-compute budget C_max in TFLOPs
    pub client_tflops: f64,
    /// scaling temperature T
    pub temp: f64,
}

impl Budgets {
    pub fn new(bandwidth_gb: f64, client_tflops: f64) -> Self {
        Self { bandwidth_gb, client_tflops, temp: 8.0 }
    }

    /// The paper's published budgets for each dataset protocol.
    pub fn paper_mixed_cifar() -> Self {
        Self::new(35.94, 11.77)
    }

    pub fn paper_mixed_noniid() -> Self {
        Self::new(84.64, 17.13)
    }
}

/// C3-Score of a method. `accuracy_pct` in [0, 100].
pub fn c3_score(accuracy_pct: f64, bandwidth_gb: f64, client_tflops: f64, b: &Budgets) -> f64 {
    let a_hat = (accuracy_pct / 100.0).clamp(0.0, 1.0);
    let b_hat = (bandwidth_gb / b.bandwidth_gb).max(0.0);
    let c_hat = (client_tflops / b.client_tflops).max(0.0);
    a_hat * (-(b_hat + c_hat) / b.temp).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_zero_one() {
        let b = Budgets::new(10.0, 10.0);
        for &(a, bw, c) in
            &[(0.0, 0.0, 0.0), (100.0, 0.0, 0.0), (100.0, 1e6, 1e6), (55.0, 5.0, 5.0)]
        {
            let s = c3_score(a, bw, c, &b);
            assert!((0.0..=1.0).contains(&s), "{s}");
        }
    }

    #[test]
    fn perfect_free_method_scores_one() {
        let b = Budgets::new(10.0, 10.0);
        assert!((c3_score(100.0, 0.0, 0.0, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_each_axis() {
        let b = Budgets::new(10.0, 10.0);
        assert!(c3_score(90.0, 1.0, 1.0, &b) > c3_score(80.0, 1.0, 1.0, &b));
        assert!(c3_score(90.0, 1.0, 1.0, &b) > c3_score(90.0, 2.0, 1.0, &b));
        assert!(c3_score(90.0, 1.0, 1.0, &b) > c3_score(90.0, 1.0, 2.0, &b));
    }

    #[test]
    fn reproduces_paper_rows_with_t8() {
        // Table 1 (Mixed-NonIID): budgets B=84.64 GB, C=17.13 TFLOPs
        let b = Budgets::paper_mixed_noniid();
        let cases = [
            // (acc, bw, client compute, published C3)
            (84.65, 84.54, 3.76, 0.72),  // SL-basic
            (84.67, 84.64, 3.76, 0.73),  // SplitFed
            (82.21, 2.39, 17.13, 0.72),  // FedAvg
            (85.09, 2.39, 17.13, 0.75),  // FedProx
            (88.88, 9.71, 5.38, 0.85),   // AdaSplit k=0.6
            (87.11, 2.43, 5.38, 0.83),   // AdaSplit k=0.75
        ];
        for (acc, bw, c, published) in cases {
            let s = c3_score(acc, bw, c, &b);
            assert!(
                (s - published).abs() < 0.015,
                "acc={acc}: got {s:.3}, paper {published}"
            );
        }
    }

    #[test]
    fn reproduces_paper_rows_mixed_cifar() {
        let b = Budgets::paper_mixed_cifar();
        let cases = [
            (67.90, 34.88, 1.66, 0.59), // SL-basic
            (91.31, 2.39, 11.77, 0.79), // FedAvg
            (91.92, 2.85, 2.38, 0.89),  // AdaSplit
        ];
        for (acc, bw, c, published) in cases {
            let s = c3_score(acc, bw, c, &b);
            assert!(
                (s - published).abs() < 0.02,
                "acc={acc}: got {s:.3}, paper {published}"
            );
        }
    }
}
