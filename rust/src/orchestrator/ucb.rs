//! UCB1 advantage scoring over clients (paper eq. 6).
//!
//!   A_i = l_i / s_i + sqrt(2 log T / s_i)
//!
//! with gamma-discounted running sums l_i (server losses) and s_i
//! (selection indicators). Unselected clients impute their loss as the
//! mean of their two most recent values (paper §3.2), and losses are
//! initialized to 100 for t = 0, 1 so every client is explored early.
//!
//! ## Sparse representation
//!
//! The state is kept only for clients that have ever been *observed*
//! (handed a real loss in [`UcbOrchestrator::update`]). Two facts make
//! this exact, not approximate:
//!
//! * a never-observed ("virgin") client only ever imputes, and its
//!   imputed loss is `(INIT_LOSS + INIT_LOSS) / 2 = INIT_LOSS` exactly —
//!   so all virgin clients share one bitwise-identical trajectory,
//!   advanced in O(1) per update (`virgin`);
//! * an observed client that misses later updates evolves by imputation
//!   from its own `last` pair — a pure function of its stored state — so
//!   its missed steps replay lazily on read (`Arm::catch_up`), and the
//!   replayed sequence is the exact f64 op sequence the dense version
//!   would have executed.
//!
//! Per-update cost is therefore O(observed) and selection is
//! O(materialized + k): under AdaSplit's `eta`-sampling that is
//! O(sample), closing the last O(fleet)-per-round structure (ROADMAP).
//! Bit-parity against the dense recurrence is pinned by
//! `sparse_matches_dense_bit_for_bit` below.

use std::collections::BTreeMap;

pub const INIT_LOSS: f64 = 100.0;

/// One client's discounted-UCB state, plus how many orchestrator updates
/// it has folded in (so lagging arms can replay their imputation gap).
#[derive(Clone, Copy, Debug)]
struct Arm {
    /// discounted loss sum l_i
    l: f64,
    /// discounted selection count s_i
    s: f64,
    /// last two observed/imputed losses
    last: [f64; 2],
    /// orchestrator updates already folded into this arm
    steps: u64,
}

impl Arm {
    /// One update step — the exact op sequence of the dense recurrence:
    /// impute-or-observe, discount-and-add, shift the loss history.
    fn step(&mut self, gamma: f64, observed: Option<f64>, sel: f64) {
        let li = observed.unwrap_or((self.last[0] + self.last[1]) / 2.0);
        self.l = gamma * self.l + li;
        self.s = gamma * self.s + sel;
        self.last = [li, self.last[0]];
        self.steps += 1;
    }

    /// Replay the imputation-only steps this arm missed while unobserved.
    fn catch_up(&mut self, gamma: f64, target: u64) {
        while self.steps < target {
            self.step(gamma, None, 0.0);
        }
    }
}

/// Discounted-UCB client selector, sparse over observed clients.
#[derive(Clone, Debug)]
pub struct UcbOrchestrator {
    gamma: f64,
    n: usize,
    /// clients observed at least once, keyed by id
    arms: BTreeMap<usize, Arm>,
    /// the shared trajectory of every never-observed client (kept
    /// current: `virgin.steps` == updates elapsed)
    virgin: Arm,
}

impl UcbOrchestrator {
    pub fn new(n_clients: usize, gamma: f64) -> Self {
        Self {
            gamma,
            n: n_clients,
            arms: BTreeMap::new(),
            // seed with the t=0,1 initial losses so s_i > 0 from the start
            virgin: Arm {
                l: INIT_LOSS * 2.0,
                s: 2.0,
                last: [INIT_LOSS; 2],
                steps: 0,
            },
        }
    }

    pub fn n_clients(&self) -> usize {
        self.n
    }

    /// Updates elapsed so far.
    fn updates(&self) -> u64 {
        self.virgin.steps
    }

    /// The T of eq. 6 (starts at 2: the two seeded pseudo-iterations).
    fn t(&self) -> u64 {
        2 + self.updates()
    }

    fn advantage_of(arm: &Arm, t: u64) -> f64 {
        if arm.s <= 0.0 {
            return f64::INFINITY;
        }
        let exploit = arm.l / arm.s;
        let explore = (2.0 * (t.max(2) as f64).ln() / arm.s).sqrt();
        exploit + explore
    }

    /// Client `i`'s state brought current (a lagging arm replays its
    /// imputation gap on a copy; the stored state is untouched).
    fn current_arm(&self, i: usize) -> Arm {
        match self.arms.get(&i) {
            Some(a) => {
                let mut c = *a;
                c.catch_up(self.gamma, self.updates());
                c
            }
            None => self.virgin,
        }
    }

    /// Advantage A_i (eq. 6). Never-selected clients get +inf.
    pub fn advantage(&self, i: usize) -> f64 {
        Self::advantage_of(&self.current_arm(i), self.t())
    }

    /// The dense selector's comparator: advantage descending, index
    /// ascending among ties (including the all-virgin +inf/equal ties).
    fn rank(a: &(usize, f64), b: &(usize, f64)) -> std::cmp::Ordering {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    }

    /// Pick the `k` clients with the highest advantage (deterministic
    /// tie-break by index).
    pub fn select(&self, k: usize) -> Vec<usize> {
        let k = k.min(self.n);
        let t = self.t();
        let mut cand: Vec<(usize, f64)> = self
            .arms
            .iter()
            .map(|(&i, _)| (i, Self::advantage_of(&self.current_arm(i), t)))
            .collect();
        // virgin clients all score the same bitwise-identical advantage,
        // and ties break by ascending index — so only the k lowest-index
        // virgins can ever make the cut. Walk the gaps between observed
        // ids to find them: O(observed + k), never O(fleet).
        let virgin_adv = Self::advantage_of(&self.virgin, t);
        let mut picked = 0;
        let mut next = 0usize;
        for &key in self.arms.keys() {
            while next < key.min(self.n) && picked < k {
                cand.push((next, virgin_adv));
                picked += 1;
                next += 1;
            }
            next = next.max(key + 1);
            if picked == k {
                break;
            }
        }
        while picked < k && next < self.n {
            cand.push((next, virgin_adv));
            picked += 1;
            next += 1;
        }
        cand.sort_by(Self::rank);
        cand.truncate(k);
        let mut idx: Vec<usize> = cand.into_iter().map(|(i, _)| i).collect();
        idx.sort_unstable();
        idx
    }

    /// Top-`k` selection restricted to `candidates` (clients that actually
    /// have a batch this iteration).
    pub fn select_among(&self, candidates: &[usize], k: usize) -> Vec<usize> {
        let t = self.t();
        let mut cand: Vec<(usize, f64)> = candidates
            .iter()
            .map(|&i| (i, Self::advantage_of(&self.current_arm(i), t)))
            .collect();
        cand.sort_by(Self::rank);
        cand.truncate(k.min(candidates.len()));
        let mut idx: Vec<usize> = cand.into_iter().map(|(i, _)| i).collect();
        idx.sort_unstable();
        idx
    }

    /// Advance one iteration: `observed` carries (client, server_loss) for
    /// selected clients; everyone else imputes the mean of their last two.
    pub fn update(&mut self, observed: &[(usize, f64)]) {
        // last write wins for a repeated client, like the dense version's
        // overwrite into its per-client loss slot
        let mut seen: BTreeMap<usize, f64> = BTreeMap::new();
        for &(i, li) in observed {
            debug_assert!(i < self.n, "client {i} out of range (n = {})", self.n);
            seen.insert(i, li);
        }
        let target = self.updates();
        for (i, li) in seen {
            let arm = self.arms.entry(i).or_insert(self.virgin);
            arm.catch_up(self.gamma, target);
            arm.step(self.gamma, Some(li), 1.0);
        }
        // every still-virgin client advances through the one shared
        // trajectory (its imputed loss is exactly INIT_LOSS forever)
        self.virgin.step(self.gamma, None, 0.0);
    }

    /// Clients materialized out of the virgin pool (observed >= once).
    #[cfg(test)]
    fn materialized(&self) -> usize {
        self.arms.len()
    }

    /// Test-only view of a client's brought-current (l, s, last) state.
    #[cfg(test)]
    fn state_of(&self, i: usize) -> (f64, f64, [f64; 2]) {
        let a = self.current_arm(i);
        (a.l, a.s, a.last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The pre-sparse dense implementation, kept verbatim as the
    /// bit-parity reference.
    #[derive(Clone, Debug)]
    struct DenseUcb {
        gamma: f64,
        l: Vec<f64>,
        s: Vec<f64>,
        last: Vec<[f64; 2]>,
        t: u64,
    }

    impl DenseUcb {
        fn new(n_clients: usize, gamma: f64) -> Self {
            Self {
                gamma,
                l: vec![INIT_LOSS * 2.0; n_clients],
                s: vec![2.0; n_clients],
                last: vec![[INIT_LOSS; 2]; n_clients],
                t: 2,
            }
        }

        fn advantage(&self, i: usize) -> f64 {
            if self.s[i] <= 0.0 {
                return f64::INFINITY;
            }
            let exploit = self.l[i] / self.s[i];
            let explore = (2.0 * (self.t.max(2) as f64).ln() / self.s[i]).sqrt();
            exploit + explore
        }

        fn select(&self, k: usize) -> Vec<usize> {
            let mut idx: Vec<usize> = (0..self.l.len()).collect();
            idx.sort_by(|&a, &b| {
                self.advantage(b)
                    .partial_cmp(&self.advantage(a))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            idx.truncate(k.min(self.l.len()));
            idx.sort_unstable();
            idx
        }

        fn select_among(&self, candidates: &[usize], k: usize) -> Vec<usize> {
            let mut idx: Vec<usize> = candidates.to_vec();
            idx.sort_by(|&a, &b| {
                self.advantage(b)
                    .partial_cmp(&self.advantage(a))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            idx.truncate(k.min(candidates.len()));
            idx.sort_unstable();
            idx
        }

        fn update(&mut self, observed: &[(usize, f64)]) {
            let n = self.l.len();
            let mut loss = vec![None; n];
            let mut sel = vec![0.0; n];
            for &(i, li) in observed {
                loss[i] = Some(li);
                sel[i] = 1.0;
            }
            for i in 0..n {
                let li = loss[i].unwrap_or((self.last[i][0] + self.last[i][1]) / 2.0);
                self.l[i] = self.gamma * self.l[i] + li;
                self.s[i] = self.gamma * self.s[i] + sel[i];
                self.last[i] = [li, self.last[i][0]];
            }
            self.t += 1;
        }
    }

    /// SplitMix64: deterministic pseudo-randomness for the parity drive.
    fn mix(x: &mut u64) -> u64 {
        *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = *x;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    #[test]
    fn sparse_matches_dense_bit_for_bit() {
        for gamma in [0.87, 1.0, 0.5] {
            let n = 13;
            let mut sparse = UcbOrchestrator::new(n, gamma);
            let mut dense = DenseUcb::new(n, gamma);
            let mut seed = 0x5eed_0000 + (gamma * 1e6) as u64;
            for round in 0..80 {
                // a pseudo-random observation set, sometimes empty,
                // sometimes with a repeated client (last write must win)
                let bits = mix(&mut seed);
                let mut obs: Vec<(usize, f64)> = (0..n)
                    .filter(|i| bits & (1 << i) != 0)
                    .map(|i| (i, ((mix(&mut seed) % 1000) as f64) / 100.0))
                    .collect();
                if round % 7 == 3 {
                    if let Some(&(i, _)) = obs.first() {
                        obs.push((i, ((mix(&mut seed) % 1000) as f64) / 100.0));
                    }
                }
                sparse.update(&obs);
                dense.update(&obs);
                for i in 0..n {
                    let (a, b) = (sparse.advantage(i), dense.advantage(i));
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "gamma {gamma} round {round} client {i}: sparse {a} != dense {b}"
                    );
                }
                for k in [0, 1, 3, n, n + 2] {
                    assert_eq!(
                        sparse.select(k),
                        dense.select(k),
                        "gamma {gamma} round {round} select({k})"
                    );
                }
                let among: Vec<usize> = (0..n).filter(|i| i % 3 != 1).collect();
                assert_eq!(
                    sparse.select_among(&among, 4),
                    dense.select_among(&among, 4),
                    "gamma {gamma} round {round} select_among"
                );
            }
        }
    }

    #[test]
    fn state_stays_sparse_in_the_observed_set() {
        let mut o = UcbOrchestrator::new(100_000, 0.87);
        for round in 0..50 {
            o.update(&[(round, 1.0), (round + 7, 2.0)]);
        }
        assert!(
            o.materialized() <= 100,
            "per-arm state must track the observed set, not the fleet: {}",
            o.materialized()
        );
        // fleet-sized reads still work — any virgin client shares the
        // one imputation trajectory
        assert_eq!(
            o.advantage(99_999).to_bits(),
            o.advantage(50_000).to_bits()
        );
    }

    #[test]
    fn initial_selection_is_uniformly_scored() {
        let o = UcbOrchestrator::new(5, 0.9);
        let adv: Vec<f64> = (0..5).map(|i| o.advantage(i)).collect();
        for w in adv.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-12);
        }
        assert_eq!(o.select(3).len(), 3);
    }

    #[test]
    fn high_loss_clients_win_exploitation() {
        let mut o = UcbOrchestrator::new(3, 0.9);
        for _ in 0..50 {
            // client 2 keeps reporting a big loss, others small
            let sel = o.select(3);
            let obs: Vec<(usize, f64)> = sel
                .iter()
                .map(|&i| (i, if i == 2 { 5.0 } else { 0.1 }))
                .collect();
            o.update(&obs);
        }
        assert!(o.advantage(2) > o.advantage(0));
        assert!(o.select(1) == vec![2]);
    }

    #[test]
    fn exploration_revisits_starved_clients() {
        let mut o = UcbOrchestrator::new(2, 0.87);
        // only ever select client 0, with moderate loss
        for _ in 0..200 {
            o.update(&[(0, 1.0)]);
        }
        // client 1's s_i decays toward 0 => exploration term blows up
        assert!(
            o.advantage(1) > o.advantage(0),
            "starved client must eventually dominate: {} vs {}",
            o.advantage(1),
            o.advantage(0)
        );
    }

    #[test]
    fn select_k_clamps_and_sorts() {
        let o = UcbOrchestrator::new(4, 0.9);
        assert_eq!(o.select(10), vec![0, 1, 2, 3]);
        assert_eq!(o.select(0), Vec::<usize>::new());
    }

    #[test]
    fn unselected_loss_imputation() {
        let mut o = UcbOrchestrator::new(2, 1.0);
        o.update(&[(0, 10.0)]); // client 1 imputes (100+100)/2 = 100
        // l_1 = 200 + 100; l_0 = 200 + 10
        let (l0, _, _) = o.state_of(0);
        let (l1, _, _) = o.state_of(1);
        assert!(l1 > l0);
        o.update(&[(0, 10.0), (1, 0.5)]);
        // client 1's imputed history now includes the real 0.5
        let (_, _, last1) = o.state_of(1);
        assert_eq!(last1[0], 0.5);
    }
}
