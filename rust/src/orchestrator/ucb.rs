//! UCB1 advantage scoring over clients (paper eq. 6).
//!
//!   A_i = l_i / s_i + sqrt(2 log T / s_i)
//!
//! with gamma-discounted running sums l_i (server losses) and s_i
//! (selection indicators). Unselected clients impute their loss as the
//! mean of their two most recent values (paper §3.2), and losses are
//! initialized to 100 for t = 0, 1 so every client is explored early.

/// Discounted-UCB client selector.
#[derive(Clone, Debug)]
pub struct UcbOrchestrator {
    gamma: f64,
    /// discounted loss sum per client (l_i)
    l: Vec<f64>,
    /// discounted selection count per client (s_i)
    s: Vec<f64>,
    /// last two observed/imputed losses per client
    last: Vec<[f64; 2]>,
    /// total iterations elapsed (the T of eq. 6)
    t: u64,
}

pub const INIT_LOSS: f64 = 100.0;

impl UcbOrchestrator {
    pub fn new(n_clients: usize, gamma: f64) -> Self {
        Self {
            gamma,
            // seed with the t=0,1 initial losses so s_i > 0 from the start
            l: vec![INIT_LOSS * 2.0; n_clients],
            s: vec![2.0; n_clients],
            last: vec![[INIT_LOSS; 2]; n_clients],
            t: 2,
        }
    }

    pub fn n_clients(&self) -> usize {
        self.l.len()
    }

    /// Advantage A_i (eq. 6). Never-selected clients get +inf.
    pub fn advantage(&self, i: usize) -> f64 {
        if self.s[i] <= 0.0 {
            return f64::INFINITY;
        }
        let exploit = self.l[i] / self.s[i];
        let explore = (2.0 * (self.t.max(2) as f64).ln() / self.s[i]).sqrt();
        exploit + explore
    }

    /// Pick the `k` clients with the highest advantage (deterministic
    /// tie-break by index).
    pub fn select(&self, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.l.len()).collect();
        idx.sort_by(|&a, &b| {
            self.advantage(b)
                .partial_cmp(&self.advantage(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(k.min(self.l.len()));
        idx.sort_unstable();
        idx
    }

    /// Top-`k` selection restricted to `candidates` (clients that actually
    /// have a batch this iteration).
    pub fn select_among(&self, candidates: &[usize], k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = candidates.to_vec();
        idx.sort_by(|&a, &b| {
            self.advantage(b)
                .partial_cmp(&self.advantage(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(k.min(candidates.len()));
        idx.sort_unstable();
        idx
    }

    /// Advance one iteration: `observed` carries (client, server_loss) for
    /// selected clients; everyone else imputes the mean of their last two.
    pub fn update(&mut self, observed: &[(usize, f64)]) {
        let n = self.l.len();
        let mut loss = vec![None; n];
        let mut sel = vec![0.0; n];
        for &(i, li) in observed {
            loss[i] = Some(li);
            sel[i] = 1.0;
        }
        for i in 0..n {
            let li = loss[i].unwrap_or((self.last[i][0] + self.last[i][1]) / 2.0);
            self.l[i] = self.gamma * self.l[i] + li;
            self.s[i] = self.gamma * self.s[i] + sel[i];
            self.last[i] = [li, self.last[i][0]];
        }
        self.t += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_selection_is_uniformly_scored() {
        let o = UcbOrchestrator::new(5, 0.9);
        let adv: Vec<f64> = (0..5).map(|i| o.advantage(i)).collect();
        for w in adv.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-12);
        }
        assert_eq!(o.select(3).len(), 3);
    }

    #[test]
    fn high_loss_clients_win_exploitation() {
        let mut o = UcbOrchestrator::new(3, 0.9);
        for _ in 0..50 {
            // client 2 keeps reporting a big loss, others small
            let sel = o.select(3);
            let obs: Vec<(usize, f64)> = sel
                .iter()
                .map(|&i| (i, if i == 2 { 5.0 } else { 0.1 }))
                .collect();
            o.update(&obs);
        }
        assert!(o.advantage(2) > o.advantage(0));
        assert!(o.select(1) == vec![2]);
    }

    #[test]
    fn exploration_revisits_starved_clients() {
        let mut o = UcbOrchestrator::new(2, 0.87);
        // only ever select client 0, with moderate loss
        for _ in 0..200 {
            o.update(&[(0, 1.0)]);
        }
        // client 1's s_i decays toward 0 => exploration term blows up
        assert!(
            o.advantage(1) > o.advantage(0),
            "starved client must eventually dominate: {} vs {}",
            o.advantage(1),
            o.advantage(0)
        );
    }

    #[test]
    fn select_k_clamps_and_sorts() {
        let o = UcbOrchestrator::new(4, 0.9);
        assert_eq!(o.select(10), vec![0, 1, 2, 3]);
        assert_eq!(o.select(0), Vec::<usize>::new());
    }

    #[test]
    fn unselected_loss_imputation() {
        let mut o = UcbOrchestrator::new(2, 1.0);
        o.update(&[(0, 10.0)]); // client 1 imputes (100+100)/2 = 100
        // l_1 = 200 + 100; l_0 = 200 + 10
        assert!(o.l[1] > o.l[0]);
        o.update(&[(0, 10.0), (1, 0.5)]);
        // client 1's imputed history now includes the real 0.5
        assert_eq!(o.last[1][0], 0.5);
    }
}
