//! The AdaSplit orchestrator (paper §3.2): per-iteration UCB client
//! selection that prioritizes clients whose data the server model is worst
//! at (exploitation) while guaranteeing coverage (exploration).

pub mod ucb;

pub use ucb::UcbOrchestrator;
