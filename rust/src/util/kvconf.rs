//! TOML-subset config parser: `key = value` lines, `[section]` headers
//! (flattened to `section.key`), `#` comments, bare strings/quoted
//! strings/numbers/bools. Covers everything `configs/*.toml` uses.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

/// Parsed flat key -> raw-value map.
#[derive(Clone, Debug, Default)]
pub struct KvConf {
    map: BTreeMap<String, String>,
}

impl KvConf {
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: malformed section header `{raw}`", ln + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got `{raw}`", ln + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.starts_with('"') && val.ends_with('"') && val.len() >= 2 {
                val = val[1..val.len() - 1].to_string();
            }
            ensure!(!key.is_empty(), "line {}: empty key", ln + 1);
            map.insert(key, val);
        }
        Ok(Self { map })
    }

    pub fn raw(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("`{key}` = `{v}`: {e}")),
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        Ok(self.get_f64(key, default as f64)? as f32)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("`{key}` = `{v}`: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("`{key}` = `{v}`: {e}")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.map.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => bail!("`{key}` = `{v}`: expected true/false"),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.map
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_and_types() {
        let c = KvConf::parse(
            "# experiment\nprotocol = \"ada-split\"\nrounds = 7 # inline\n\
             kappa = 0.75\ntrace = true\n[budgets]\nbandwidth_gb = 35.94\n",
        )
        .unwrap();
        assert_eq!(c.get_str("protocol", ""), "ada-split");
        assert_eq!(c.get_usize("rounds", 0).unwrap(), 7);
        assert!((c.get_f64("kappa", 0.0).unwrap() - 0.75).abs() < 1e-12);
        assert!(c.get_bool("trace", false).unwrap());
        assert!((c.get_f64("budgets.bandwidth_gb", 0.0).unwrap() - 35.94).abs() < 1e-9);
    }

    #[test]
    fn defaults_apply() {
        let c = KvConf::parse("").unwrap();
        assert_eq!(c.get_usize("rounds", 20).unwrap(), 20);
        assert_eq!(c.get_str("dataset", "mixed-cifar"), "mixed-cifar");
    }

    #[test]
    fn rejects_malformed() {
        assert!(KvConf::parse("no_equals_here\n").is_err());
        assert!(KvConf::parse("[unclosed\n").is_err());
        let c = KvConf::parse("rounds = seven\n").unwrap();
        assert!(c.get_usize("rounds", 0).is_err());
    }
}
