//! Small in-tree utilities that replace registry crates unavailable in the
//! offline build environment (see Cargo.toml note): a JSON value type +
//! recursive-descent parser/writer (for `artifacts/manifest.json` and run
//! exports), a TOML-subset config parser, and a micro-benchmark harness
//! underpinning the [`crate::bench`] matrix runner and the `benches/`
//! targets.

pub mod bench;
pub mod json;
pub mod kvconf;

pub use json::Json;
