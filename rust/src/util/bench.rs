//! Micro-benchmark harness used by the `benches/` targets (criterion is
//! unavailable offline). Supports warmup, N timed iterations, and
//! mean/p50/p95 reporting, plus a `--quick` env knob the table benches use
//! to shrink workload scale.

use std::time::Instant;

use anyhow::{ensure, Result};

/// Timing summary of one benchmark.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>4} iters  mean {:>10.4}s  p50 {:>10.4}s  p95 {:>10.4}s  min {:>10.4}s",
            self.name, self.iters, self.mean_s, self.p50_s, self.p95_s, self.min_s
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
/// `iters == 0` is rejected with a clear error (the summary would
/// otherwise index an empty sample vector / divide by zero).
pub fn try_bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> Result<BenchStats> {
    ensure!(
        iters >= 1,
        "bench `{name}`: iters must be >= 1 — a zero-iteration run has no samples to summarize"
    );
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pick = |q: f64| samples[((samples.len() - 1) as f64 * q).round() as usize];
    Ok(BenchStats {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: pick(0.5),
        p95_s: pick(0.95),
        min_s: samples[0],
    })
}

/// Panicking wrapper around [`try_bench`] for bench `main`s where an
/// invalid iteration count is a programming error. The panic message
/// carries the same context the `Result` would.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchStats {
    try_bench(name, warmup, iters, f).unwrap_or_else(|e| panic!("{e}"))
}

/// True when `ADASPLIT_BENCH_QUICK=1` or `--quick` is on the CLI — table
/// benches then run a reduced workload (fewer rounds/samples, 1 seed).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("ADASPLIT_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Scale hint for table benches: (rounds, samples/client, test/client,
/// n_seeds). Full mode approaches the paper's scale; quick mode is a
/// smoke-level run.
pub fn bench_scale() -> (usize, usize, usize, usize) {
    if quick_mode() {
        (4, 96, 64, 1)
    } else {
        (10, 256, 128, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench("noop", 1, 16, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p95_s);
        assert_eq!(s.iters, 16);
    }

    #[test]
    fn zero_iters_is_a_clear_error() {
        let err = try_bench("empty", 0, 0, || {}).unwrap_err();
        assert!(
            err.to_string().contains("iters must be >= 1"),
            "error must explain the constraint, got: {err}"
        );
        assert!(err.to_string().contains("empty"), "error must name the bench");
    }

    #[test]
    #[should_panic(expected = "iters must be >= 1")]
    fn bench_zero_iters_panics_with_context() {
        bench("empty", 0, 0, || {});
    }
}
