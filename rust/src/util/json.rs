//! Minimal JSON: a value enum, a strict recursive-descent parser, and a
//! writer. Covers the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, bools, null) — enough for the artifact manifest and
//! for exporting run results.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, ensure, Result};

/// A JSON value. Objects use BTreeMap for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.i == p.b.len(), "trailing junk at byte {}", p.i);
        Ok(v)
    }

    // ---- typed accessors --------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking for `{key}`)"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        ensure!(x >= 0.0 && x.fract() == 0.0, "not a non-negative integer: {x}");
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer -------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = if pretty { "  ".repeat(indent + 1) } else { String::new() };
        let pad_close = if pretty { "  ".repeat(indent) } else { String::new() };
        let nl = if pretty { "\n" } else { "" };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    out.push_str(nl);
                    out.push_str(&pad_close);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    out.push_str(nl);
                    out.push_str(&pad_close);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(
            self.peek()? == c,
            "expected `{}` at byte {}, found `{}`",
            c as char,
            self.i,
            self.peek()? as char
        );
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected `,` or `]`, got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape `\\{}`", e as char),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        ensure!(start + len <= self.b.len(), "truncated UTF-8");
                        s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse().map_err(|e| anyhow!("bad number `{text}`: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let j = Json::parse(
            r#"{"batch": 32, "lr": 0.001, "arr": [1, 2, 3],
                "nested": {"a": {"shape": [32, 16], "dtype": "float32"}},
                "neg": -1e-5, "flag": true, "nothing": null}"#,
        )
        .unwrap();
        assert_eq!(j.get("batch").unwrap().as_usize().unwrap(), 32);
        assert!((j.get("lr").unwrap().as_f64().unwrap() - 0.001).abs() < 1e-12);
        assert_eq!(j.get("arr").unwrap().usize_arr().unwrap(), vec![1, 2, 3]);
        assert_eq!(
            j.get("nested").unwrap().get("a").unwrap().get("dtype").unwrap()
                .as_str().unwrap(),
            "float32"
        );
        assert_eq!(j.get("flag").unwrap(), &Json::Bool(true));
        assert!(j.get("flag").unwrap().as_bool().unwrap());
        assert!(j.get("batch").unwrap().as_bool().is_err(), "numbers are not bools");
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\c\nd\u{41}");
        let out = Json::Str("x\"\n\\y".into()).to_string_compact();
        assert_eq!(Json::parse(&out).unwrap().as_str().unwrap(), "x\"\n\\y");
    }

    #[test]
    fn writer_roundtrips() {
        let src = r#"{"a": [1, 2.5, {"b": "c"}], "d": false}"#;
        let j = Json::parse(src).unwrap();
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        let compact = j.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), j);
    }

    #[test]
    fn rejects_junk() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("{\"a\": 1} x").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("{\"k\": \"héllo ☃\"}").unwrap();
        assert_eq!(j.get("k").unwrap().as_str().unwrap(), "héllo ☃");
    }
}
