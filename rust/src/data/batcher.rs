//! Batch iteration over a client's materialized data.
//!
//! Training artifacts have a static batch dimension (baked into the HLO),
//! so the train iterator drops the ragged tail; the eval iterator instead
//! pads the final batch and carries a validity mask, which the eval
//! artifacts multiply into their correct/loss sums.

use crate::data::rng::Rng;
use crate::data::synthetic::PIXELS;
use crate::runtime::Tensor;

/// One marshalled batch, ready to feed an artifact.
pub struct Batch {
    pub x: Tensor,
    pub y: Tensor,
    /// 1.0 for real samples, 0.0 for padding (eval only; all-ones in train)
    pub valid: Tensor,
    pub n_valid: usize,
}

/// Epoch iterator over (x, y) with reshuffling per epoch.
pub struct BatchIter<'a> {
    x: &'a [f32],
    y: &'a [f32],
    batch: usize,
    img: usize,
    order: Vec<usize>,
    pos: usize,
    pad_tail: bool,
}

impl<'a> BatchIter<'a> {
    /// Training iterator: shuffled, tail dropped.
    pub fn train(x: &'a [f32], y: &'a [f32], batch: usize, rng: &mut Rng) -> Self {
        let mut order: Vec<usize> = (0..y.len()).collect();
        rng.shuffle(&mut order);
        Self { x, y, batch, img: 32, order, pos: 0, pad_tail: false }
    }

    /// Eval iterator: in order, tail padded with a validity mask.
    pub fn eval(x: &'a [f32], y: &'a [f32], batch: usize) -> Self {
        Self {
            x,
            y,
            batch,
            img: 32,
            order: (0..y.len()).collect(),
            pos: 0,
            pad_tail: true,
        }
    }

    /// Number of batches this iterator will yield.
    pub fn n_batches(&self) -> usize {
        if self.pad_tail {
            self.order.len().div_ceil(self.batch)
        } else {
            self.order.len() / self.batch
        }
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        let remaining = self.order.len().saturating_sub(self.pos);
        let take = remaining.min(self.batch);
        if take == 0 || (!self.pad_tail && take < self.batch) {
            return None;
        }
        let b = self.batch;
        let mut xb = vec![0.0f32; b * PIXELS];
        let mut yb = vec![0.0f32; b];
        let mut vb = vec![0.0f32; b];
        for i in 0..take {
            let src = self.order[self.pos + i];
            xb[i * PIXELS..(i + 1) * PIXELS]
                .copy_from_slice(&self.x[src * PIXELS..(src + 1) * PIXELS]);
            yb[i] = self.y[src];
            vb[i] = 1.0;
        }
        self.pos += take;
        Some(Batch {
            x: Tensor::new(vec![b, self.img, self.img, 3], xb).expect("batch x"),
            y: Tensor::new(vec![b], yb).expect("batch y"),
            valid: Tensor::new(vec![b], vb).expect("batch valid"),
            n_valid: take,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(n: usize) -> (Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..n * PIXELS).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..n).map(|i| i as f32).collect();
        (x, y)
    }

    #[test]
    fn train_drops_tail_and_shuffles() {
        let (x, y) = data(70);
        let mut rng = Rng::new(1);
        let it = BatchIter::train(&x, &y, 32, &mut rng);
        assert_eq!(it.n_batches(), 2);
        let batches: Vec<Batch> = it.collect();
        assert_eq!(batches.len(), 2);
        // shuffled: the first batch should not be exactly 0..32
        let first: Vec<f32> = batches[0].y.data().to_vec();
        assert_ne!(first, (0..32).map(|i| i as f32).collect::<Vec<_>>());
        assert!(batches.iter().all(|b| b.n_valid == 32));
    }

    #[test]
    fn eval_pads_tail_with_mask() {
        let (x, y) = data(40);
        let it = BatchIter::eval(&x, &y, 32);
        assert_eq!(it.n_batches(), 2);
        let batches: Vec<Batch> = it.collect();
        assert_eq!(batches[1].n_valid, 8);
        let v = batches[1].valid.data();
        assert_eq!(v.iter().filter(|&&m| m == 1.0).count(), 8);
        assert_eq!(v[8..].iter().filter(|&&m| m == 0.0).count(), 24);
        // order preserved in eval
        assert_eq!(batches[0].y.data()[0], 0.0);
        assert_eq!(batches[1].y.data()[7], 39.0);
    }

    #[test]
    fn epoch_covers_every_sample_once() {
        let (x, y) = data(64);
        let mut rng = Rng::new(2);
        let mut seen: Vec<f32> = BatchIter::train(&x, &y, 32, &mut rng)
            .flat_map(|b| b.y.data().to_vec())
            .collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(seen, (0..64).map(|i| i as f32).collect::<Vec<_>>());
    }
}
