//! Deterministic, dependency-free RNG: SplitMix64 for stream derivation,
//! xoshiro256++ for generation. Every random decision in the coordinator
//! (data synthesis, shuffling, tie-breaking) flows from an experiment seed
//! through named sub-streams, so runs are exactly reproducible.

/// xoshiro256++ generator with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream for a named purpose. Streams derived
    /// with different tags (or indices) are statistically independent.
    pub fn derive(&self, tag: &str, index: u64) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for b in tag.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= index.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(h ^ self.s[0])
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift bounded sampling (bias negligible at u64)
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.next_f64().max(1e-300), self.next_f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_streams_differ() {
        let root = Rng::new(7);
        let mut a = root.derive("data", 0);
        let mut b = root.derive("data", 1);
        let mut c = root.derive("shuffle", 0);
        assert_ne!(a.next_u64(), b.next_u64());
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(4);
        let mut p = r.permutation(50);
        p.sort_unstable();
        assert_eq!(p, (0..50).collect::<Vec<_>>());
    }
}
