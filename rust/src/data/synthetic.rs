//! Procedural image families standing in for the paper's five benchmark
//! datasets (MNIST, FMNIST, Not-MNIST, CIFAR-10, CIFAR-100).
//!
//! Each family renders 32x32x3 images as a sum of low-frequency Gaussian
//! blobs whose parameters are drawn per *class* (the prototype) plus
//! per-*sample* jitter (translation, amplitude, additive noise). Family
//! knobs control:
//!
//! * `grayscale` — MNIST/FMNIST/Not-MNIST replicate one channel;
//! * `noise` / `jitter` — difficulty (CIFAR100-like is hardest);
//! * `proto_scale`, `n_blobs` — how separated class prototypes are;
//! * `base` — a family-wide background offset so *families* are mutually
//!   far apart while MNIST-like/FMNIST-like stay relatively close,
//!   reproducing the paper's "variable pairwise heterogeneity".
//!
//! Rendering is deterministic in (family, class, sample-index, seed).

use crate::data::rng::Rng;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const PIXELS: usize = IMG * IMG * CHANNELS;

/// The five dataset families of the Mixed-NonIID protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    MnistLike,
    FmnistLike,
    NotMnistLike,
    Cifar10Like,
    Cifar100Like,
}

impl Family {
    pub const ALL: [Family; 5] = [
        Family::MnistLike,
        Family::FmnistLike,
        Family::NotMnistLike,
        Family::Cifar10Like,
        Family::Cifar100Like,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Family::MnistLike => "mnist-like",
            Family::FmnistLike => "fmnist-like",
            Family::NotMnistLike => "notmnist-like",
            Family::Cifar10Like => "cifar10-like",
            Family::Cifar100Like => "cifar100-like",
        }
    }

    fn knobs(&self) -> FamilyKnobs {
        match self {
            // MNIST-like and FMNIST-like share a base offset (low pairwise
            // heterogeneity between them), differ in texture. Noise levels
            // are calibrated so a centrally-trained copy of the backbone
            // lands in the high-80s/low-90s (headroom for protocol
            // comparisons, like the paper's CIFAR numbers) rather than
            // saturating at 100%.
            Family::MnistLike => FamilyKnobs {
                grayscale: true, n_blobs: 4, proto_scale: 1.2,
                noise: 0.45, jitter: 3, base: [0.10, 0.10, 0.10],
            },
            Family::FmnistLike => FamilyKnobs {
                grayscale: true, n_blobs: 7, proto_scale: 1.0,
                noise: 0.60, jitter: 3, base: [0.12, 0.12, 0.12],
            },
            Family::NotMnistLike => FamilyKnobs {
                grayscale: true, n_blobs: 3, proto_scale: 1.5,
                noise: 0.55, jitter: 4, base: [-0.25, -0.25, -0.25],
            },
            Family::Cifar10Like => FamilyKnobs {
                grayscale: false, n_blobs: 6, proto_scale: 0.8,
                noise: 0.80, jitter: 4, base: [0.30, -0.10, -0.30],
            },
            // hardest: weak prototypes, strong noise, far from the rest
            Family::Cifar100Like => FamilyKnobs {
                grayscale: false, n_blobs: 8, proto_scale: 0.55,
                noise: 1.0, jitter: 5, base: [-0.30, 0.25, 0.10],
            },
        }
    }

    fn seed_tag(&self) -> u64 {
        match self {
            Family::MnistLike => 1,
            Family::FmnistLike => 2,
            Family::NotMnistLike => 3,
            Family::Cifar10Like => 4,
            Family::Cifar100Like => 5,
        }
    }
}

struct FamilyKnobs {
    grayscale: bool,
    n_blobs: usize,
    proto_scale: f32,
    noise: f32,
    jitter: i32,
    base: [f32; 3],
}

/// One Gaussian blob of a class prototype.
#[derive(Clone, Debug)]
struct Blob {
    cx: f32,
    cy: f32,
    sigma: f32,
    amp: [f32; 3],
}

/// A renderable class prototype.
#[derive(Clone, Debug)]
struct Prototype {
    blobs: Vec<Blob>,
    base: [f32; 3],
}

impl Prototype {
    /// Render with per-sample translation into `out` (NHWC layout).
    fn render(&self, dx: f32, dy: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), PIXELS);
        for (i, v) in out.iter_mut().enumerate() {
            *v = self.base[i % CHANNELS];
        }
        for blob in &self.blobs {
            let cx = blob.cx + dx;
            let cy = blob.cy + dy;
            let inv2s2 = 1.0 / (2.0 * blob.sigma * blob.sigma);
            // bounding box: beyond 3 sigma the blob is negligible
            let r = (3.0 * blob.sigma).ceil() as i64;
            let x0 = ((cx as i64) - r).max(0) as usize;
            let x1 = (((cx as i64) + r).min(IMG as i64 - 1)) as usize;
            let y0 = ((cy as i64) - r).max(0) as usize;
            let y1 = (((cy as i64) + r).min(IMG as i64 - 1)) as usize;
            for y in y0..=y1 {
                let fy = y as f32 - cy;
                for x in x0..=x1 {
                    let fx = x as f32 - cx;
                    let g = (-(fx * fx + fy * fy) * inv2s2).exp();
                    let px = (y * IMG + x) * CHANNELS;
                    for c in 0..CHANNELS {
                        out[px + c] += blob.amp[c] * g;
                    }
                }
            }
        }
    }
}

/// A generated dataset: one family, `n_classes` class prototypes, plus
/// sampling machinery. Samples are materialized lazily (`sample`) or in
/// bulk (`generate`).
pub struct SyntheticDataset {
    pub family: Family,
    pub n_classes: usize,
    protos: Vec<Prototype>,
    knobs: FamilyKnobs,
    seed: u64,
}

impl SyntheticDataset {
    pub fn new(family: Family, n_classes: usize, seed: u64) -> Self {
        let knobs = family.knobs();
        let root = Rng::new(seed ^ (family.seed_tag() << 32));
        let mut protos = Vec::with_capacity(n_classes);
        for class in 0..n_classes {
            let mut r = root.derive("proto", class as u64);
            let mut blobs = Vec::with_capacity(knobs.n_blobs);
            for _ in 0..knobs.n_blobs {
                let amp0 = r.normal_f32(0.0, knobs.proto_scale);
                let amp = if knobs.grayscale {
                    [amp0, amp0, amp0]
                } else {
                    [
                        amp0,
                        r.normal_f32(0.0, knobs.proto_scale),
                        r.normal_f32(0.0, knobs.proto_scale),
                    ]
                };
                blobs.push(Blob {
                    cx: r.uniform(6.0, IMG as f64 - 6.0) as f32,
                    cy: r.uniform(6.0, IMG as f64 - 6.0) as f32,
                    sigma: r.uniform(2.0, 6.0) as f32,
                    amp,
                });
            }
            protos.push(Prototype { blobs, base: knobs.base });
        }
        Self { family, n_classes, protos, knobs, seed }
    }

    /// Render sample `idx` of class `class` into `out` (NHWC f32).
    pub fn sample_into(&self, class: usize, idx: u64, out: &mut [f32]) {
        let mut r = Rng::new(self.seed ^ (self.family.seed_tag() << 32))
            .derive("sample", (class as u64) << 32 | idx);
        let j = self.knobs.jitter as f64;
        let dx = r.uniform(-j, j) as f32;
        let dy = r.uniform(-j, j) as f32;
        self.protos[class].render(dx, dy, out);
        let gain = 1.0 + r.normal_f32(0.0, 0.1);
        for v in out.iter_mut() {
            *v = (*v * gain + r.normal_f32(0.0, self.knobs.noise)).clamp(-3.0, 3.0);
        }
    }

    pub fn sample(&self, class: usize, idx: u64) -> Vec<f32> {
        let mut out = vec![0.0; PIXELS];
        self.sample_into(class, idx, &mut out);
        out
    }

    /// Generate `n` samples for the given classes (round-robin), returning
    /// (images concatenated NHWC, labels). `label_offset` shifts labels
    /// into a global label space (Mixed-NonIID).
    pub fn generate(
        &self,
        classes: &[usize],
        n: usize,
        label_offset: usize,
        index_offset: u64,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut xs = vec![0.0f32; n * PIXELS];
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let class = classes[i % classes.len()];
            self.sample_into(class, index_offset + i as u64, &mut xs[i * PIXELS..(i + 1) * PIXELS]);
            ys.push((label_offset + class) as f32);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_rendering() {
        let d = SyntheticDataset::new(Family::MnistLike, 10, 7);
        assert_eq!(d.sample(3, 11), d.sample(3, 11));
    }

    #[test]
    fn samples_differ_across_index_and_class() {
        let d = SyntheticDataset::new(Family::Cifar10Like, 10, 7);
        assert_ne!(d.sample(0, 0), d.sample(0, 1));
        assert_ne!(d.sample(0, 0), d.sample(1, 0));
    }

    #[test]
    fn grayscale_families_replicate_channels_in_prototype() {
        let d = SyntheticDataset::new(Family::MnistLike, 4, 3);
        // render prototype directly (no noise): channels identical
        let mut out = vec![0.0; PIXELS];
        d.protos[0].render(0.0, 0.0, &mut out);
        for px in out.chunks(3) {
            assert!((px[0] - px[1]).abs() < 1e-6 && (px[1] - px[2]).abs() < 1e-6);
        }
    }

    #[test]
    fn class_structure_is_learnable() {
        // nearest-prototype classification on clean renders must beat
        // chance by a wide margin => classes are separable
        let d = SyntheticDataset::new(Family::Cifar10Like, 5, 9);
        let mut protos = Vec::new();
        for c in 0..5 {
            let mut out = vec![0.0; PIXELS];
            d.protos[c].render(0.0, 0.0, &mut out);
            protos.push(out);
        }
        let mut correct = 0;
        let total = 100;
        for i in 0..total {
            let c = i % 5;
            let s = d.sample(c, i as u64);
            let best = (0..5)
                .min_by(|&a, &b| {
                    let da: f32 = s.iter().zip(&protos[a]).map(|(x, p)| (x - p).powi(2)).sum();
                    let db: f32 = s.iter().zip(&protos[b]).map(|(x, p)| (x - p).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == c {
                correct += 1;
            }
        }
        assert!(correct > 60, "nearest-proto acc {correct}/100");
    }

    #[test]
    fn family_bases_separate_families() {
        let a = SyntheticDataset::new(Family::MnistLike, 2, 1).sample(0, 0);
        let b = SyntheticDataset::new(Family::Cifar100Like, 2, 1).sample(0, 0);
        let mean_a: f32 = a.iter().sum::<f32>() / a.len() as f32;
        let mean_b: f32 = b.iter().sum::<f32>() / b.len() as f32;
        assert!((mean_a - mean_b).abs() > 0.05);
    }

    #[test]
    fn generate_respects_label_offset() {
        let d = SyntheticDataset::new(Family::FmnistLike, 10, 2);
        let (xs, ys) = d.generate(&[0, 1], 6, 20, 0);
        assert_eq!(xs.len(), 6 * PIXELS);
        assert_eq!(ys, vec![20.0, 21.0, 20.0, 21.0, 20.0, 21.0]);
    }
}
